//! Autotuner integration tests: golden-trace planner decisions (the
//! cost model's three headline outcomes) and the live-migration
//! bit-identity property over every source mapping × recommended
//! target × thread count.

use llama::blob::{alloc_view, HeapAlloc};
use llama::extents::Dyn;
use llama::mapping::MemoryAccess;
use llama::record::{ScalarType, Selection};
use llama::testing::{forall, Rng};
use llama::tune::{migrate_live, AccessTrace, Candidate, FieldTrace, Planner};

llama::record! {
    pub struct R, mod r {
        a: f64,
        b: f32,
        c: u32,
        d: i16,
    }
}

/// A hand-built golden trace (stable, no heatmap).
fn golden(n: usize, rows: &[(&str, ScalarType, u64, u64, Option<u32>)]) -> AccessTrace {
    AccessTrace {
        record: "R".into(),
        n,
        origin: None,
        stable: true,
        fields: rows
            .iter()
            .map(|&(name, ty, reads, writes, value_bits)| FieldTrace {
                field: name.into(),
                ty,
                reads,
                writes,
                value_bits,
            })
            .collect(),
        heat: None,
    }
}

/// The hot/cold golden trace: two heavily-accessed leading fields, two
/// nearly-idle trailing ones. The hot set {a, b} covers > 90% of the
/// accesses and is a contiguous proper prefix, so `Split` is offered —
/// and wins: it matches SoA-MB's hot traffic, pays only ~15 units of
/// cold de-vectorization, and saves a 64-unit blob fee (3 blobs vs 4).
fn hotcold_trace() -> AccessTrace {
    golden(
        256,
        &[
            ("a", ScalarType::F64, 100_000, 10_000, None),
            ("b", ScalarType::F32, 100_000, 10_000, None),
            ("c", ScalarType::U32, 5, 0, None),
            ("d", ScalarType::I16, 5, 0, None),
        ],
    )
}

/// The uniform golden trace: every field equally accessed. The hot set
/// is the whole record (no Split candidate), and SoA-MB edges out
/// SoA-SB because the single-blob seam fee on 4000 hot writes (200
/// units) exceeds the 192-unit blob-fee saving.
fn uniform_trace() -> AccessTrace {
    golden(
        256,
        &[
            ("a", ScalarType::F64, 10_000, 1_000, None),
            ("b", ScalarType::F32, 10_000, 1_000, None),
            ("c", ScalarType::U32, 10_000, 1_000, None),
            ("d", ScalarType::I16, 10_000, 1_000, None),
        ],
    )
}

/// The narrow-int golden trace: a huge, rarely-touched all-integral
/// record whose observed values fit 10 bits. Capacity dominates
/// traffic, so bitpack's 4× per-access CPU fee is irrelevant next to
/// shrinking every 32-bit column to 10 bits.
fn narrow_int_trace() -> AccessTrace {
    golden(
        1_000_000,
        &[
            ("k", ScalarType::U32, 1_000, 0, Some(10)),
            ("l", ScalarType::U16, 1_000, 0, Some(6)),
        ],
    )
}

#[test]
fn golden_hotcold_trace_plans_split() {
    let plan = Planner::new().recommend(&hotcold_trace());
    assert_eq!(
        plan.chosen,
        Candidate::Split { hot: Selection::new(0, 2) },
        "hot/cold trace must split at the hot prefix:\n{}",
        plan.render_table()
    );
    assert_eq!(plan.hot, vec![0, 1]);
    // The margin is the blob fee minus the cold de-vectorization.
    let split = plan.scored[0].1.total();
    let soa_mb = plan
        .scored
        .iter()
        .find(|(c, _)| *c == Candidate::SoaMb)
        .map(|(_, cost)| cost.total())
        .unwrap();
    assert!(soa_mb - split > 40.0 && soa_mb - split < 64.0, "margin {}", soa_mb - split);
}

#[test]
fn golden_uniform_trace_plans_soa_mb() {
    let plan = Planner::new().recommend(&uniform_trace());
    assert_eq!(
        plan.chosen,
        Candidate::SoaMb,
        "uniform trace must pick plain multi-blob SoA:\n{}",
        plan.render_table()
    );
    // No Split candidate: the hot set is the whole record.
    assert_eq!(plan.hot, vec![0, 1, 2, 3]);
    assert!(!plan.scored.iter().any(|(c, _)| matches!(c, Candidate::Split { .. })));
    // AoS pays the full un-vectorized traffic: ~2x total.
    let soa = plan.scored[0].1.total();
    let aos = plan
        .scored
        .iter()
        .find(|(c, _)| *c == Candidate::Aos)
        .map(|(_, cost)| cost.total())
        .unwrap();
    assert!(aos > 1.9 * soa, "aos {aos} vs soa {soa}");
}

#[test]
fn golden_narrow_int_trace_plans_bitpack() {
    let plan = Planner::new().recommend(&narrow_int_trace());
    assert_eq!(
        plan.chosen,
        Candidate::BitpackInt { bits: 10 },
        "capacity-bound narrow ints must bitpack:\n{}",
        plan.render_table()
    );
    // The win is capacity, not traffic.
    let bp = &plan.scored[0].1;
    let soa = plan
        .scored
        .iter()
        .find(|(c, _)| *c == Candidate::SoaMb)
        .map(|(_, cost)| *cost)
        .unwrap();
    assert!(bp.capacity < soa.capacity / 2.0);
    assert!(bp.traffic > soa.traffic);
}

#[test]
fn origin_breaks_ties_toward_staying_put() {
    // Same uniform trace, but recorded *on* SoA-MB: every other
    // candidate now pays amortized migration, so the winner must not
    // change, and is not flagged as a migration.
    let t = uniform_trace().with_origin("soa-mb");
    let plan = Planner::new().recommend(&t);
    assert_eq!(plan.chosen, Candidate::SoaMb);
    assert!(!plan.is_migration());
    // And an AoS-origin trace of the same workload *is* a migration.
    let t2 = uniform_trace().with_origin("aos");
    let plan2 = Planner::new().recommend(&t2);
    assert_eq!(plan2.chosen, Candidate::SoaMb);
    assert!(plan2.is_migration());
}

/// Fill any mapping of `R` with a deterministic pseudo-random pattern.
fn fill<M: MemoryAccess<R>>(v: &mut llama::view::View<R, M, llama::blob::HeapStorage>, n: usize, seed: u64) {
    let mut rng = Rng::new(seed);
    for i in 0..n {
        v.set(&[i], r::a, rng.f64_range(-1e6, 1e6));
        v.set(&[i], r::b, rng.f64_range(-1e3, 1e3) as f32);
        v.set(&[i], r::c, rng.next_u64() as u32);
        v.set(&[i], r::d, rng.range_i64(-30000, 30000) as i16);
    }
}

#[test]
fn prop_migrate_live_bit_identical_all_mappings() {
    // `migrate_live` itself asserts per-cell bit-identity (through both
    // mappings' own read paths) and panics on any mismatch — the
    // property is that it *returns* for every source mapping, into both
    // planner-recommended targets, at every thread count, with the full
    // cell count verified.
    use llama::mapping::aos::{AoS, MinPad, Packed};
    use llama::mapping::aosoa::AoSoA;
    use llama::mapping::bytesplit::Bytesplit;
    use llama::mapping::changetype::ChangeType;
    use llama::mapping::field_access_count::FieldAccessCount;
    use llama::mapping::heatmap::Heatmap;
    use llama::mapping::null::NullMapping;
    use llama::mapping::one::One;
    use llama::mapping::soa::{MultiBlob, SingleBlob, SoA};
    use llama::mapping::split::Split;

    // The two targets the golden traces recommend: plain SoA-MB
    // (uniform) and Split at the hot prefix {a, b} (hot/cold).
    assert_eq!(Planner::new().recommend(&uniform_trace()).chosen, Candidate::SoaMb);
    assert_eq!(
        Planner::new().recommend(&hotcold_trace()).chosen,
        Candidate::Split { hot: Selection::new(0, 2) }
    );

    const FIRST: u64 = 0b0011;
    const REST: u64 = 0b1100;
    type MHot = SoA<R, (Dyn<u32>,), MultiBlob, llama::extents::RowMajor, FIRST>;
    type MCold = SoA<R, (Dyn<u32>,), MultiBlob, llama::extents::RowMajor, REST>;

    fn migrates<M>(m: M, n: usize, seed: u64, threads: usize) -> bool
    where
        M: MemoryAccess<R> + Clone,
        M::Extents: llama::extents::Extents<ArrayIndex = [usize; 1]>,
    {
        let e = (Dyn(n as u32),);
        let mut src = alloc_view(m, &HeapAlloc);
        fill(&mut src, n, seed);
        // Uniform recommendation: SoA multi-blob.
        let (_dst, rep) =
            migrate_live(&src, SoA::<R, _, MultiBlob>::new(e), &HeapAlloc, threads);
        if rep.verified != n * 4 || rep.records != n || rep.threads != threads {
            return false;
        }
        // Hot/cold recommendation: Split at Selection::new(0, 2).
        let sel = Selection::new(0, 2);
        let (_dst, rep) = migrate_live(
            &src,
            Split::new(MHot::new(e), MCold::new(e), sel),
            &HeapAlloc,
            threads,
        );
        rep.verified == n * 4 && rep.records == n
    }

    forall("migrate-all-mappings", 4, |g| (g.range(1, 48), g.next_u64()), |&(n, seed)| {
        let e = (Dyn(n as u32),);
        let sel = Selection::new(0, 2);
        [1usize, 2, 4].iter().all(|&threads| {
            migrates(AoS::<R, _>::new(e), n, seed, threads)
                && migrates(AoS::<R, _, Packed>::new(e), n, seed, threads)
                && migrates(AoS::<R, _, MinPad>::new(e), n, seed, threads)
                && migrates(SoA::<R, _, MultiBlob>::new(e), n, seed, threads)
                && migrates(SoA::<R, _, SingleBlob>::new(e), n, seed, threads)
                && migrates(AoSoA::<R, _, 8>::new(e), n, seed, threads)
                && migrates(Bytesplit::<R, _>::new(e), n, seed, threads)
                && migrates(ChangeType::<R, R, _>::new(SoA::<R, _>::new(e)), n, seed, threads)
                && migrates(Heatmap::<R, _, 8>::new(SoA::<R, _>::new(e)), n, seed, threads)
                && migrates(FieldAccessCount::new(AoS::<R, _>::new(e)), n, seed, threads)
                && migrates(NullMapping::<R, _>::new(e), n, seed, threads)
                && migrates(One::<R, _>::new(e), n, seed, threads)
                && migrates(Split::new(MHot::new(e), MCold::new(e), sel), n, seed, threads)
        })
    });
}

#[test]
fn recorded_nbody_trace_recommends_a_column_layout() {
    // End-to-end: instrument the real n-body workload on AoS, record,
    // and check the planner sends it to a column layout — the same
    // decision the coordinator's autotune mode makes.
    use llama::blob::{alloc_view as av, AlignedAlloc};
    use llama::mapping::field_access_count::FieldAccessCount;
    use llama::nbody::{init_particles, views, Particle};

    let n = 64usize;
    let fac: FieldAccessCount<Particle, _> =
        FieldAccessCount::new(views::AosMap::new((Dyn(n as u32),)));
    let mut v = av(fac, &AlignedAlloc::<64>);
    views::fill_view(&mut v, &init_particles(n, 1));
    v.mapping().reset();
    views::update_scalar(&mut v);
    views::move_scalar(&mut v);
    let trace = AccessTrace::record(&v).with_origin("aos");
    assert!(trace.stable);
    assert!(trace.total_accesses() > 0);
    let plan = Planner::new().recommend_among(
        &trace,
        &[Candidate::Aos, Candidate::SoaMb, Candidate::Aosoa { lanes: 8 }],
    );
    assert_eq!(plan.chosen, Candidate::SoaMb, "{}", plan.render_table());
    assert!(plan.is_migration());
}
