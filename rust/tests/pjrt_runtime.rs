//! Integration: load the AOT artifacts through PJRT and validate numerics
//! against the native Rust n-body implementation (experiment E9).
//!
//! The whole file is gated on the `pjrt` feature (the `xla` crate is not
//! vendored in the offline image); with the feature on, tests still skip
//! (pass trivially with a note) when `make artifacts` has not run, so
//! `cargo test` works on a fresh checkout.
#![cfg(feature = "pjrt")]

use llama::mapping::bitpack_int::{read_bits, write_bits};
use llama::nbody::{init_particles, manual::SoaSim, ParticleData};
use llama::runtime::{default_artifacts_dir, Engine, TensorF32};

const N: usize = 1024; // must match `make artifacts` N

fn engine_or_skip(names: &[&str]) -> Option<Engine> {
    let engine = Engine::cpu(default_artifacts_dir()).expect("PJRT CPU client");
    for name in names {
        if !engine.artifact_available(name) {
            eprintln!("skipping: artifact '{name}' missing (run `make artifacts`)");
            return None;
        }
        engine.load(name).expect("artifact compiles");
    }
    Some(engine)
}

fn soa_inputs(ps: &[ParticleData]) -> Vec<TensorF32> {
    let sim = SoaSim::new(ps);
    [&sim.px, &sim.py, &sim.pz, &sim.vx, &sim.vy, &sim.vz, &sim.mass]
        .into_iter()
        .map(|v| TensorF32::vec(v.clone()))
        .collect()
}

#[test]
fn soa_artifact_matches_native_step() {
    let Some(engine) = engine_or_skip(&["nbody_soa"]) else { return };
    let init = init_particles(N, 99);

    let out = engine.execute_f32("nbody_soa", &soa_inputs(&init)).expect("execute");
    assert_eq!(out.len(), 6);
    assert_eq!(out[0].dims, vec![N]);

    let mut sim = SoaSim::new(&init);
    sim.update_scalar();
    sim.move_scalar();

    let max_dx =
        sim.px.iter().zip(&out[0].data).map(|(a, b)| (a - b).abs()).fold(0.0f32, f32::max);
    assert!(max_dx < 1e-5, "PJRT vs native px delta {max_dx}");
    let max_dv =
        sim.vx.iter().zip(&out[3].data).map(|(a, b)| (a - b).abs()).fold(0.0f32, f32::max);
    assert!(max_dv < 1e-5, "PJRT vs native vx delta {max_dv}");
}

#[test]
fn aos_and_soa_artifacts_agree() {
    let Some(engine) = engine_or_skip(&["nbody_soa", "nbody_aos"]) else { return };
    let init = init_particles(N, 7);

    let soa_out = engine.execute_f32("nbody_soa", &soa_inputs(&init)).unwrap();

    let mut aos = Vec::with_capacity(N * 7);
    for p in &init {
        aos.extend_from_slice(&[p.pos.x, p.pos.y, p.pos.z, p.vel.x, p.vel.y, p.vel.z, p.mass]);
    }
    let aos_out = engine.execute_f32("nbody_aos", &[TensorF32::new(aos, vec![N, 7])]).unwrap();
    assert_eq!(aos_out.len(), 1);
    assert_eq!(aos_out[0].dims, vec![N, 7]);

    let mut max_d = 0.0f32;
    for i in 0..N {
        for f in 0..6 {
            max_d = max_d.max((aos_out[0].data[i * 7 + f] - soa_out[f].data[i]).abs());
        }
    }
    assert!(max_d < 1e-5, "AoS vs SoA artifact delta {max_d}");
}

#[test]
fn aosoa_artifact_agrees() {
    let Some(engine) = engine_or_skip(&["nbody_soa", "nbody_aosoa"]) else { return };
    let init = init_particles(N, 13);
    const L: usize = 8;

    let soa_out = engine.execute_f32("nbody_soa", &soa_inputs(&init)).unwrap();

    let nb = N / L;
    let mut blocks = vec![0.0f32; N * 7];
    for (i, p) in init.iter().enumerate() {
        let (b, k) = (i / L, i % L);
        let fields = [p.pos.x, p.pos.y, p.pos.z, p.vel.x, p.vel.y, p.vel.z, p.mass];
        for (f, v) in fields.iter().enumerate() {
            blocks[b * 7 * L + f * L + k] = *v;
        }
    }
    let out = engine.execute_f32("nbody_aosoa", &[TensorF32::new(blocks, vec![nb, 7, L])]).unwrap();

    let mut max_d = 0.0f32;
    for i in 0..N {
        let (b, k) = (i / L, i % L);
        for f in 0..6 {
            max_d = max_d.max((out[0].data[b * 7 * L + f * L + k] - soa_out[f].data[i]).abs());
        }
    }
    assert!(max_d < 1e-5, "AoSoA vs SoA artifact delta {max_d}");
}

#[test]
fn bf16_artifact_is_coarser_but_close() {
    let Some(engine) = engine_or_skip(&["nbody_soa", "nbody_bf16"]) else { return };
    let init = init_particles(N, 21);
    let exact = engine.execute_f32("nbody_soa", &soa_inputs(&init)).unwrap();
    let coarse = engine.execute_f32("nbody_bf16", &soa_inputs(&init)).unwrap();

    let mut max_d = 0.0f32;
    for f in 0..6 {
        for (a, b) in exact[f].data.iter().zip(&coarse[f].data) {
            max_d = max_d.max((a - b).abs());
        }
    }
    // bf16 has ~3 decimal digits: must differ from f32 but stay close.
    assert!(max_d > 1e-7, "bf16 path should differ from f32");
    assert!(max_d < 2e-2, "bf16 drift too large: {max_d}");
}

#[test]
fn bitpack_artifact_increments_packed_values() {
    let Some(engine) = engine_or_skip(&["bitpack_roundtrip"]) else { return };
    const BITS: u32 = 12;
    let n = N;
    let vals: Vec<u32> = (0..n as u32).map(|i| (i * 37) % 4096).collect();
    let nwords = n * BITS as usize / 32;

    // Pack with the Rust bit helpers (shared convention with python ref).
    let mut bytes = vec![0u8; nwords * 4 + 8];
    for (i, &v) in vals.iter().enumerate() {
        write_bits(&mut bytes, i * BITS as usize, BITS, v as u64);
    }
    let words: Vec<u32> = bytes[..nwords * 4]
        .chunks_exact(4)
        .map(|c| u32::from_le_bytes(c.try_into().unwrap()))
        .collect();

    let out = engine.execute_u32("bitpack_roundtrip", &[(words, vec![nwords])]).unwrap();
    assert_eq!(out.len(), 1);
    assert_eq!(out[0].1, vec![nwords]);

    let mut out_bytes = vec![0u8; nwords * 4 + 8];
    for (i, w) in out[0].0.iter().enumerate() {
        out_bytes[i * 4..i * 4 + 4].copy_from_slice(&w.to_le_bytes());
    }
    for (i, &v) in vals.iter().enumerate() {
        let got = read_bits(&out_bytes, i * BITS as usize, BITS) as u32;
        assert_eq!(got, (v + 1) % 4096, "value {i}");
    }
}

#[test]
fn multi_step_energy_drift_via_pjrt() {
    let Some(engine) = engine_or_skip(&["nbody_soa"]) else { return };
    let init = init_particles(N, 3);
    let e0 = llama::nbody::total_energy(&init);

    let mut state = soa_inputs(&init);
    for _ in 0..10 {
        let out = engine.execute_f32("nbody_soa", &state).unwrap();
        let mass = state[6].clone();
        state = out;
        state.push(mass);
    }

    let final_ps: Vec<ParticleData> = (0..N)
        .map(|i| ParticleData {
            pos: llama::nbody::PVec {
                x: state[0].data[i],
                y: state[1].data[i],
                z: state[2].data[i],
            },
            vel: llama::nbody::PVec {
                x: state[3].data[i],
                y: state[4].data[i],
                z: state[5].data[i],
            },
            mass: state[6].data[i],
        })
        .collect();
    let e1 = llama::nbody::total_energy(&final_ps);
    let drift = ((e1 - e0) / e0).abs();
    assert!(drift < 1e-2, "energy drift over 10 PJRT steps: {drift}");
}
