//! Fault-injection tests for the coordinator's panic isolation and
//! retry path (`docs/SERVING.md` §5).
//!
//! Checks the module-doc invariants: a panicking job never takes its
//! worker thread down (subsequent jobs on the same worker complete), a
//! panic surfaces as a typed `error` on the job's own [`JobResult`]
//! (never as a coordinator crash), the retry policy re-dispatches up to
//! `max_attempts` with the attempt count reported, and the fault
//! counters in [`Metrics`] account for every injected event.

use std::time::Duration;

use llama::coordinator::{Backend, Config, Coordinator, JobSpec, Layout, RetryPolicy};
use llama::fault::{FaultConfig, FaultPlan};

/// Smallest useful job — fault-handling overhead dominates, which is
/// the point.
fn tiny_spec() -> JobSpec {
    JobSpec {
        id: 0,
        layout: Layout::Aos,
        backend: Backend::NativeScalar,
        n: 4,
        steps: 1,
        seed: 1,
        threads: 1,
    }
}

/// A retry policy with backoffs measured in microseconds, so tests stay
/// fast while still exercising the sleep path.
fn fast_retry(max_attempts: u32) -> RetryPolicy {
    RetryPolicy {
        max_attempts,
        base: Duration::from_micros(50),
        cap: Duration::from_micros(200),
    }
}

/// Every attempt of every job panics: each job must fail with a typed
/// "job panicked" error after exactly `max_attempts` attempts, and the
/// single worker must survive all of them (3 jobs, 1 worker — results
/// arriving at all proves the thread outlived each panic).
#[test]
fn panicking_jobs_fail_typed_and_the_worker_survives() {
    let cfg = FaultConfig { panic_first_attempts: u32::MAX, ..FaultConfig::default() };
    let mut c = Coordinator::start(Config {
        workers: 1,
        retry: fast_retry(2),
        faults: Some(FaultPlan::new(7, cfg)),
        ..Config::default()
    });
    let ing = c.ingest(); // keep a metrics handle past `finish`
    for _ in 0..3 {
        c.submit(tiny_spec());
    }
    let results = c.finish();

    assert_eq!(results.len(), 3, "every admitted job must report a result");
    for r in &results {
        let err = r.error.as_deref().expect("a panicking job must carry an error");
        assert!(
            err.contains("job panicked") && err.contains("injected fault"),
            "error must be the typed panic message, got: {err}"
        );
        assert_eq!(r.attempts, 2, "both attempts must have been used");
    }
    assert_eq!(ing.metrics().panics(), 6, "2 attempts x 3 jobs all panicked");
    assert_eq!(ing.metrics().retries(), 3, "one re-dispatch per job");
}

/// A scripted first-attempt panic followed by clean attempts: the retry
/// path must recover every job, reporting `attempts == 2` and a `None`
/// error, with the panic still counted.
#[test]
fn retry_recovers_jobs_that_panic_once() {
    let cfg = FaultConfig { panic_first_attempts: 1, ..FaultConfig::default() };
    let mut c = Coordinator::start(Config {
        workers: 2,
        retry: fast_retry(3),
        faults: Some(FaultPlan::new(11, cfg)),
        ..Config::default()
    });
    let ing = c.ingest();
    for _ in 0..4 {
        c.submit(tiny_spec());
    }
    let results = c.finish();

    assert_eq!(results.len(), 4);
    for r in &results {
        assert_eq!(r.error, None, "the retry must have recovered the job");
        assert_eq!(r.attempts, 2, "first attempt panicked, second succeeded");
        assert!(r.threads >= 1, "a successful job reports its granted budget");
    }
    assert_eq!(ing.metrics().panics(), 4, "exactly the scripted first attempts");
    assert_eq!(ing.metrics().retries(), 4);
    assert_eq!(ing.metrics().corrupt_frames(), 0);
}

/// Injected delays slow jobs down but never fail them: no retries, no
/// panics, first-attempt success across the board.
#[test]
fn injected_delays_do_not_fail_jobs() {
    let cfg = FaultConfig {
        p_job_delay: 1024, // every job
        delay: Duration::from_millis(1),
        ..FaultConfig::default()
    };
    let mut c = Coordinator::start(Config {
        workers: 2,
        retry: fast_retry(2),
        faults: Some(FaultPlan::new(13, cfg)),
        ..Config::default()
    });
    let ing = c.ingest();
    for _ in 0..4 {
        c.submit(tiny_spec());
    }
    let results = c.finish();

    assert_eq!(results.len(), 4);
    for r in &results {
        assert_eq!(r.error, None);
        assert_eq!(r.attempts, 1, "a delay is not a failure");
        assert!(r.exec_time >= Duration::from_millis(1), "the delay is part of exec time");
    }
    assert_eq!(ing.metrics().panics(), 0);
    assert_eq!(ing.metrics().retries(), 0);
}

/// With no fault plan armed, the retry machinery is inert: single
/// attempts, zero fault counters — the pre-fault-layer behavior.
#[test]
fn unarmed_plan_changes_nothing() {
    let mut c = Coordinator::start(Config {
        workers: 2,
        retry: fast_retry(3), // retries available, never needed
        ..Config::default()
    });
    let ing = c.ingest();
    for _ in 0..4 {
        c.submit(tiny_spec());
    }
    let results = c.finish();

    assert_eq!(results.len(), 4);
    for r in &results {
        assert_eq!(r.error, None);
        assert_eq!(r.attempts, 1);
    }
    assert_eq!(ing.metrics().panics(), 0);
    assert_eq!(ing.metrics().retries(), 0);
    assert_eq!(ing.metrics().corrupt_frames(), 0);
}
