//! Integration tests for the supervised TCP front-end (`llama::serve`,
//! `docs/SERVING.md` §6) — real sockets, real threads, real deadlines.
//!
//! Covered here, against a live [`Server`] on `127.0.0.1:0`:
//! - slow-loris (half-open mid-frame) clients get a typed
//!   `TimedOut { MidFrame }` and the listener keeps serving others;
//! - idle connections are evicted with `TimedOut { Idle }`;
//! - connections over `max_connections` are shed with a retry hint;
//! - `QueueFull` rejections carry the ingest retry-after estimate in
//!   milliseconds across the wire;
//! - per-client quota violations come back as a typed
//!   `QuotaExceeded { client }`;
//! - graceful drain finishes in-flight jobs, answers late submits with
//!   `Draining`, and reports `DrainOutcome::Completed`;
//! - the drain deadline hard-aborts stragglers
//!   (`DrainOutcome::TimedOut`, aborted connections counted);
//! - coordinator retries surface in the `Result` frame's `attempts`;
//! - corrupt and malformed frames get typed `Corrupt` replies;
//! - a chaos soak (N clients under seeded stream faults) conserves
//!   every submission and keeps results bit-identical to a serial
//!   local run, under a global no-hang watchdog.

use std::collections::HashMap;
use std::io::{Read, Write};
use std::net::TcpStream;
use std::sync::mpsc;
use std::thread;
use std::time::Duration;

use llama::coordinator::{Backend, Config, Coordinator, JobSpec, Layout, RetryPolicy};
use llama::fault::{FaultConfig, FaultPlan};
use llama::serve::{submit_frame, Client, ClientConfig, DrainOutcome, ServeConfig, Server};
use llama::transport::{CtrlFrame, TimeoutPhase, CTRL_MAGIC};

// ---------------------------------------------------------------------------
// Helpers
// ---------------------------------------------------------------------------

/// A small deterministic native job (serial scalar — bit-reproducible).
fn spec(seed: u64) -> JobSpec {
    JobSpec {
        id: 0,
        layout: Layout::Aos,
        backend: Backend::NativeScalar,
        n: 8,
        steps: 1,
        seed,
        threads: 1,
    }
}

/// Coordinator config whose every job sleeps `delay` before running —
/// the deterministic way to hold the dispatch pipeline busy.
fn delayed_coord(workers: usize, queue: usize, delay: Duration) -> Config {
    let faults = FaultConfig { p_job_delay: 1024, delay, ..FaultConfig::default() };
    Config {
        workers,
        max_batch: 1,
        queue_capacity: queue,
        faults: Some(FaultPlan::new(11, faults)),
        ..Config::default()
    }
}

/// Front-end config with everything generous except what a test pins.
fn lenient_serve() -> ServeConfig {
    ServeConfig {
        idle_timeout: Duration::from_secs(10),
        frame_timeout: Duration::from_secs(5),
        io_timeout: Duration::from_secs(5),
        drain_timeout: Duration::from_secs(10),
        result_poll: Duration::from_millis(5),
        ..ServeConfig::default()
    }
}

/// Write one submit, read one reply, on a raw socket.
fn exchange(stream: &mut TcpStream, client: u64, s: &JobSpec) -> std::io::Result<CtrlFrame> {
    submit_frame(client, s).write_to(stream)?;
    CtrlFrame::read_from(stream)
}

fn connect(server: &Server) -> TcpStream {
    let s = TcpStream::connect(server.local_addr()).expect("connect");
    s.set_nodelay(true).ok();
    s
}

/// Spin until a front-end counter reaches `want` (the kernel accepts a
/// TCP handshake into the backlog before the accept loop runs, so
/// "connected" does not yet mean "served" — tests that race a
/// shutdown against fresh connections must wait for the server side).
fn wait_for(server: &Server, want: u64, read: impl Fn(&llama::serve::ServeMetrics) -> u64) {
    let t0 = std::time::Instant::now();
    while read(&server.metrics()) < want {
        assert!(t0.elapsed() < Duration::from_secs(5), "server never caught up");
        thread::sleep(Duration::from_millis(2));
    }
}

// ---------------------------------------------------------------------------
// Deadlines
// ---------------------------------------------------------------------------

/// A client that opens a frame and stalls must be cut off with a typed
/// mid-frame timeout — and the listener must keep serving everyone
/// else (slow-loris containment).
#[test]
fn slow_loris_gets_a_typed_timeout_and_the_listener_survives() {
    let cfg = ServeConfig { frame_timeout: Duration::from_millis(120), ..lenient_serve() };
    let server = Server::bind("127.0.0.1:0", Config::default(), cfg).expect("bind");

    // Half a magic, then silence: the frame clock is now mid-frame.
    let mut loris = connect(&server);
    loris.write_all(&CTRL_MAGIC[..3]).expect("partial frame");
    loris.set_read_timeout(Some(Duration::from_secs(5))).unwrap();
    match CtrlFrame::read_from(&mut loris).expect("typed reply before close") {
        CtrlFrame::TimedOut { phase } => assert_eq!(phase, TimeoutPhase::MidFrame),
        other => panic!("expected TimedOut {{ MidFrame }}, got {other:?}"),
    }
    // After the reply the server closes the stream.
    let mut rest = Vec::new();
    assert_eq!(loris.read_to_end(&mut rest).unwrap_or(0), 0, "stream must be closed");

    // The listener is still alive: a well-behaved client round-trips.
    let mut ok = connect(&server);
    ok.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
    match exchange(&mut ok, 1, &spec(3)).expect("full round-trip") {
        CtrlFrame::Result { error, .. } => assert!(error.is_empty(), "job failed: {error}"),
        other => panic!("expected Result, got {other:?}"),
    }

    assert_eq!(server.metrics().slow_frames(), 1);
    let report = server.shutdown();
    assert_eq!(report.outcome, DrainOutcome::Completed);
}

/// A connection that never sends anything is evicted at the idle
/// deadline with `TimedOut { Idle }`.
#[test]
fn idle_connections_are_evicted_with_a_typed_timeout() {
    let cfg = ServeConfig { idle_timeout: Duration::from_millis(80), ..lenient_serve() };
    let server = Server::bind("127.0.0.1:0", Config::default(), cfg).expect("bind");

    let mut idle = connect(&server);
    idle.set_read_timeout(Some(Duration::from_secs(5))).unwrap();
    match CtrlFrame::read_from(&mut idle).expect("typed eviction notice") {
        CtrlFrame::TimedOut { phase } => assert_eq!(phase, TimeoutPhase::Idle),
        other => panic!("expected TimedOut {{ Idle }}, got {other:?}"),
    }
    assert_eq!(server.metrics().idle_evicted(), 1);
    server.shutdown();
}

// ---------------------------------------------------------------------------
// Admission
// ---------------------------------------------------------------------------

/// With the connection cap reached, a new connection is shed at accept
/// time with the configured reconnect hint, and the served connection
/// is undisturbed.
#[test]
fn connections_over_the_cap_are_shed_with_a_retry_hint() {
    let cfg = ServeConfig {
        max_connections: 1,
        shed_retry: Duration::from_millis(40),
        ..lenient_serve()
    };
    let server = Server::bind("127.0.0.1:0", Config::default(), cfg).expect("bind");

    // Occupy the single slot and prove it is live.
    let mut held = connect(&server);
    held.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
    match exchange(&mut held, 1, &spec(1)).expect("held connection round-trip") {
        CtrlFrame::Result { error, .. } => assert!(error.is_empty()),
        other => panic!("expected Result, got {other:?}"),
    }

    let mut extra = connect(&server);
    extra.set_read_timeout(Some(Duration::from_secs(5))).unwrap();
    match CtrlFrame::read_from(&mut extra).expect("typed shed notice") {
        CtrlFrame::Shed { retry_after_ms } => assert_eq!(retry_after_ms, 40),
        other => panic!("expected Shed, got {other:?}"),
    }
    assert_eq!(server.metrics().shed(), 1);

    // The held connection still works after the shed.
    match exchange(&mut held, 1, &spec(2)).expect("second round-trip") {
        CtrlFrame::Result { error, .. } => assert!(error.is_empty()),
        other => panic!("expected Result, got {other:?}"),
    }
    drop(held);
    server.shutdown();
}

/// When the ingest queue is full the rejection crosses the wire as
/// `QueueFull { retry_after_ms ≥ 1 }`, the connection stays open, and
/// every *admitted* job still completes.
#[test]
fn queue_full_replies_carry_the_retry_hint_over_the_wire() {
    // workers=1, batch=1, queue=1, every job sleeps 400ms: the pipeline
    // holds a bounded handful of jobs, so a burst must overflow.
    let server = Server::bind(
        "127.0.0.1:0",
        delayed_coord(1, 1, Duration::from_millis(400)),
        lenient_serve(),
    )
    .expect("bind");

    let mut admitted: Vec<TcpStream> = Vec::new();
    let mut rejected = 0u64;
    for i in 0..8u64 {
        let mut c = connect(&server);
        submit_frame(100 + i, &spec(i)).write_to(&mut c).expect("submit");
        // A rejection is written immediately; an admitted job holds the
        // connection until the (slow) result. Probe with a short read.
        c.set_read_timeout(Some(Duration::from_millis(50))).unwrap();
        match CtrlFrame::read_from(&mut c) {
            Ok(CtrlFrame::QueueFull { retry_after_ms }) => {
                assert!(retry_after_ms >= 1, "hint must be a usable backoff");
                rejected += 1;
                break;
            }
            Ok(other) => panic!("expected QueueFull or slow Result, got {other:?}"),
            Err(e) => {
                let k = e.kind();
                assert!(
                    k == std::io::ErrorKind::WouldBlock || k == std::io::ErrorKind::TimedOut,
                    "unexpected read failure while probing: {e}"
                );
                admitted.push(c);
            }
        }
    }
    assert_eq!(rejected, 1, "a bounded pipeline must overflow within 8 submits");
    assert!(!admitted.is_empty());

    // Conservation: every admitted job completes and reports back.
    for mut c in admitted {
        c.set_read_timeout(Some(Duration::from_secs(30))).unwrap();
        match CtrlFrame::read_from(&mut c).expect("admitted job result") {
            CtrlFrame::Result { error, .. } => assert!(error.is_empty(), "job failed: {error}"),
            other => panic!("expected Result, got {other:?}"),
        }
    }
    assert!(server.metrics().rejects_queue_full() >= 1);
    let report = server.shutdown();
    assert_eq!(report.outcome, DrainOutcome::Completed);
    assert_eq!(report.metrics.in_flight(), 0);
}

/// A client whose quota slot is already occupied by a *queued* job gets
/// a typed `QuotaExceeded { client }`; its queued job is unaffected.
#[test]
fn a_client_over_its_quota_gets_a_typed_rejection() {
    let coord = Config {
        client_quota: 1,
        ..delayed_coord(1, 8, Duration::from_millis(300))
    };
    let server = Server::bind("127.0.0.1:0", coord, lenient_serve()).expect("bind");

    // Quota is held while a job is *queued* (released at dispatch), so
    // first saturate the dispatch pipeline with filler clients...
    let mut fillers: Vec<TcpStream> = Vec::new();
    for i in 0..3u64 {
        let mut c = connect(&server);
        submit_frame(101 + i, &spec(i)).write_to(&mut c).expect("filler submit");
        fillers.push(c);
    }
    thread::sleep(Duration::from_millis(120));

    // ...then park one client-7 job in the queue behind them...
    let mut first = connect(&server);
    submit_frame(7, &spec(70)).write_to(&mut first).expect("first client-7 submit");
    thread::sleep(Duration::from_millis(60));

    // ...so a second client-7 submit finds the quota slot taken.
    let mut second = connect(&server);
    second.set_read_timeout(Some(Duration::from_secs(5))).unwrap();
    match exchange(&mut second, 7, &spec(71)).expect("typed rejection") {
        CtrlFrame::QuotaExceeded { client } => assert_eq!(client, 7),
        other => panic!("expected QuotaExceeded, got {other:?}"),
    }
    assert_eq!(server.metrics().rejects_quota(), 1);

    // The queued job and the fillers all still complete.
    for mut c in fillers.into_iter().chain(std::iter::once(first)) {
        c.set_read_timeout(Some(Duration::from_secs(30))).unwrap();
        match CtrlFrame::read_from(&mut c).expect("result") {
            CtrlFrame::Result { error, .. } => assert!(error.is_empty(), "job failed: {error}"),
            other => panic!("expected Result, got {other:?}"),
        }
    }
    let report = server.shutdown();
    assert_eq!(report.outcome, DrainOutcome::Completed);
}

// ---------------------------------------------------------------------------
// Drain
// ---------------------------------------------------------------------------

/// Graceful drain: the in-flight job finishes and its result is
/// delivered; a submit arriving mid-drain is answered `Draining`; the
/// report says `Completed` with nothing aborted.
#[test]
fn shutdown_drains_in_flight_jobs_and_refuses_new_work() {
    let server = Server::bind(
        "127.0.0.1:0",
        delayed_coord(1, 8, Duration::from_millis(400)),
        lenient_serve(),
    )
    .expect("bind");
    let addr = server.local_addr();

    // In-flight job: submitted before the drain starts, slow enough to
    // still be running when it does.
    let in_flight = thread::spawn(move || {
        let mut c = TcpStream::connect(addr).expect("connect");
        c.set_read_timeout(Some(Duration::from_secs(30))).unwrap();
        submit_frame(1, &spec(9)).write_to(&mut c).expect("submit");
        CtrlFrame::read_from(&mut c).expect("result survives the drain")
    });
    wait_for(&server, 1, |m| m.in_flight());

    // Accepted before the drain, submits during it.
    let mut late = connect(&server);
    late.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
    wait_for(&server, 2, |m| m.accepted());
    let late = thread::spawn(move || {
        thread::sleep(Duration::from_millis(100));
        submit_frame(2, &spec(10)).write_to(&mut late).ok();
        CtrlFrame::read_from(&mut late).expect("typed draining notice")
    });

    let report = server.shutdown();
    assert_eq!(report.outcome, DrainOutcome::Completed);
    assert_eq!(report.metrics.in_flight(), 0, "the drain must have flushed the job");

    match in_flight.join().expect("in-flight thread") {
        CtrlFrame::Result { error, .. } => assert!(error.is_empty(), "job failed: {error}"),
        other => panic!("expected Result, got {other:?}"),
    }
    match late.join().expect("late thread") {
        CtrlFrame::Draining => {}
        other => panic!("expected Draining, got {other:?}"),
    }
    assert!(report.metrics.draining_replies() >= 1);
}

/// A drain that cannot finish inside its deadline hard-aborts the
/// remaining connections and says so in the report.
#[test]
fn drain_deadline_hard_aborts_stragglers() {
    let cfg = ServeConfig { drain_timeout: Duration::from_millis(120), ..lenient_serve() };
    let server = Server::bind(
        "127.0.0.1:0",
        delayed_coord(1, 8, Duration::from_millis(1500)),
        cfg,
    )
    .expect("bind");
    let addr = server.local_addr();

    let straggler = thread::spawn(move || {
        let mut c = TcpStream::connect(addr).expect("connect");
        c.set_read_timeout(Some(Duration::from_secs(30))).unwrap();
        submit_frame(1, &spec(5)).write_to(&mut c).expect("submit");
        CtrlFrame::read_from(&mut c)
    });
    wait_for(&server, 1, |m| m.in_flight());

    let report = server.shutdown();
    assert_eq!(report.outcome, DrainOutcome::TimedOut);
    assert!(report.aborted_connections >= 1, "the straggler must be counted");
    assert!(
        report.elapsed >= Duration::from_millis(120),
        "the drain must have waited out its deadline"
    );

    // The aborted client never sees a result — only the socket closing
    // (possibly preceded by a best-effort Draining notice, depending on
    // whether its waiter or the socket shutdown wins the race).
    match straggler.join().expect("straggler thread") {
        Err(_) | Ok(CtrlFrame::Draining) => {}
        Ok(other) => panic!("aborted connection must not get a result, got {other:?}"),
    }
}

// ---------------------------------------------------------------------------
// Retries and corruption
// ---------------------------------------------------------------------------

/// A job whose first attempt panics is retried by the coordinator; the
/// attempt count crosses the wire in the `Result` frame.
#[test]
fn coordinator_retries_surface_in_the_result_attempts() {
    let coord = Config {
        workers: 1,
        retry: RetryPolicy {
            max_attempts: 2,
            base: Duration::from_micros(50),
            cap: Duration::from_micros(200),
        },
        faults: Some(FaultPlan::new(
            5,
            FaultConfig { panic_first_attempts: 1, ..FaultConfig::default() },
        )),
        ..Config::default()
    };
    let server = Server::bind("127.0.0.1:0", coord, lenient_serve()).expect("bind");

    let mut client = Client::new(server.local_addr(), ClientConfig::default()).expect("client");
    let r = client.submit(&spec(4)).expect("retried job must succeed");
    assert_eq!(r.attempts, 2, "first attempt panicked, second succeeded");
    assert!(r.error.is_none(), "retry must have recovered the job");
    server.shutdown();
}

/// A frame that fails its CRC gets a `Corrupt` reply echoing both
/// checksums; framing-level garbage gets `Corrupt { 0, 0 }`. Both
/// close the connection (the stream may be desynchronized).
#[test]
fn corrupt_and_malformed_frames_get_typed_replies() {
    let server =
        Server::bind("127.0.0.1:0", Config::default(), lenient_serve()).expect("bind");

    // Valid submit, one payload bit flipped: CRC mismatch.
    let mut frame = Vec::new();
    submit_frame(1, &spec(1)).write_to(&mut frame).expect("encode");
    frame[10] ^= 0x40; // inside the client-id field (after magic+ver+kind)
    let mut c = connect(&server);
    c.write_all(&frame).expect("send corrupted frame");
    c.set_read_timeout(Some(Duration::from_secs(5))).unwrap();
    match CtrlFrame::read_from(&mut c).expect("typed corruption notice") {
        CtrlFrame::Corrupt { expected, got } => {
            assert_ne!(expected, got, "a real CRC mismatch echoes both sums");
        }
        other => panic!("expected Corrupt, got {other:?}"),
    }
    let mut rest = Vec::new();
    assert_eq!(c.read_to_end(&mut rest).unwrap_or(0), 0, "connection must be closed");

    // Garbage where the magic should be: no checksums to echo.
    let mut g = connect(&server);
    g.write_all(b"GARBAGE!").expect("send garbage");
    g.set_read_timeout(Some(Duration::from_secs(5))).unwrap();
    match CtrlFrame::read_from(&mut g).expect("typed framing notice") {
        CtrlFrame::Corrupt { expected: 0, got: 0 } => {}
        other => panic!("expected Corrupt {{ 0, 0 }}, got {other:?}"),
    }

    assert_eq!(server.metrics().corrupt_frames(), 1);
    assert_eq!(server.metrics().malformed(), 1);
    server.shutdown();
}

// ---------------------------------------------------------------------------
// Chaos soak
// ---------------------------------------------------------------------------

const CLIENTS: u64 = 4;
const JOBS: u64 = 8;

/// The soak's job mix: every (client, index) pair gets a distinct seed
/// and cycles through three layouts, serial scalar so the result is a
/// deterministic function of the spec.
fn soak_spec(t: u64, i: u64) -> JobSpec {
    const LAYOUTS: [Layout; 3] = [Layout::Aos, Layout::SoaMb, Layout::Aosoa];
    JobSpec {
        id: 0,
        layout: LAYOUTS[((t + i) % 3) as usize],
        backend: Backend::NativeScalar,
        n: 32,
        steps: 2,
        seed: 1000 * t + i,
        threads: 1,
    }
}

/// One soak round: N clients hammer a server through seeded stream
/// chaos (short reads, torn writes, injected errors, bit flips on the
/// client side of every connection). Asserts, per seed:
/// - conservation: every submission is accounted for — a bit-exact
///   result or a typed client error, nothing lost, nothing hung;
/// - integrity: every delivered `energy_drift` is bit-identical to a
///   serial local run of the same spec (retries and reconnects never
///   corrupt a result);
/// - the server drains clean afterwards.
fn soak(seed: u64) {
    // Reference drifts from a serial, fault-free local coordinator.
    let mut reference: HashMap<u64, u64> = HashMap::new();
    {
        let mut local = Coordinator::start(Config { workers: 1, ..Config::default() });
        let mut by_id: HashMap<u64, u64> = HashMap::new();
        for t in 0..CLIENTS {
            for i in 0..JOBS {
                let s = soak_spec(t, i);
                by_id.insert(local.submit(s.clone()), s.seed);
            }
        }
        for r in local.finish() {
            assert!(r.error.is_none(), "reference job failed: {:?}", r.error);
            reference.insert(by_id[&r.id], r.energy_drift.to_bits());
        }
    }

    let server = Server::bind(
        "127.0.0.1:0",
        Config { workers: 2, queue_capacity: 16, ..Config::default() },
        lenient_serve(),
    )
    .expect("bind");
    let addr = server.local_addr();
    let plan = FaultPlan::new(seed, FaultConfig::stream_chaos());

    let mut handles = Vec::new();
    for t in 0..CLIENTS {
        let plan = plan.clone();
        handles.push(thread::spawn(move || {
            let cfg = ClientConfig {
                client_id: t,
                retry: RetryPolicy {
                    max_attempts: 7,
                    base: Duration::from_millis(2),
                    cap: Duration::from_millis(20),
                },
                faults: Some(plan),
                ..ClientConfig::default()
            };
            let mut client = Client::new(addr, cfg).expect("client");
            let mut completed: Vec<(u64, u64)> = Vec::new(); // (spec seed, drift bits)
            let mut failed: Vec<String> = Vec::new();
            for i in 0..JOBS {
                let s = soak_spec(t, i);
                match client.submit(&s) {
                    Ok(r) => {
                        assert!(r.error.is_none(), "remote job failed: {:?}", r.error);
                        completed.push((s.seed, r.energy_drift.to_bits()));
                    }
                    Err(e) => failed.push(e.to_string()),
                }
            }
            (completed, failed)
        }));
    }

    let mut completed = 0u64;
    let mut failed = 0u64;
    for h in handles {
        let (ok, errs) = h.join().expect("client thread");
        for (spec_seed, bits) in ok {
            assert_eq!(
                bits, reference[&spec_seed],
                "drift for spec seed {spec_seed} differs from the serial reference \
                 (chaos seed {seed})"
            );
            completed += 1;
        }
        failed += errs.len() as u64;
    }

    // Conservation: every submission resolved one way or the other.
    assert_eq!(
        completed + failed,
        CLIENTS * JOBS,
        "submissions lost under chaos seed {seed}"
    );
    assert!(
        completed >= CLIENTS * JOBS / 2,
        "stream chaos with retries should still complete most jobs \
         (seed {seed}: {completed} completed, {failed} failed)"
    );

    let report = server.shutdown();
    assert_eq!(report.outcome, DrainOutcome::Completed, "drain after soak (seed {seed})");
    assert_eq!(report.metrics.in_flight(), 0);
}

/// The chaos soak, under a global no-hang watchdog. Runs the seed from
/// `LLAMA_FAULT_SEED` when set (CI runs both canonical seeds that
/// way), else both canonical seeds back to back.
#[test]
fn chaos_soak_conserves_jobs_and_results_stay_bit_identical() {
    let seeds: Vec<u64> = match FaultPlan::from_env() {
        Some(p) => vec![p.seed()],
        None => vec![1, 8],
    };
    let (tx, rx) = mpsc::channel();
    let soaker = thread::spawn(move || {
        for s in seeds {
            soak(s);
        }
        tx.send(()).ok();
    });
    // The whole point of the deadline/drain machinery is that nothing
    // ever wedges the listener — enforce it with a hard cap.
    rx.recv_timeout(Duration::from_secs(120))
        .expect("chaos soak exceeded its no-hang deadline");
    soaker.join().expect("soak thread");
}
