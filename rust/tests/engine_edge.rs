//! Edge cases of the bulk-traversal engine: empty extents, single-element
//! views, SIMD tails (`count % N != 0`) on every mapping, and rank>1
//! traversal — the pinned-down baseline under the serial engine that the
//! parallel sharded layer is tested against in `properties.rs`.

use llama::blob::{alloc_view, BlobStorage, HeapAlloc};
use llama::extents::{Dyn, Extents};
use llama::mapping::{Mapping, SimdAccess};
use llama::simd::Simd;
use llama::view::Chunk;

llama::record! {
    pub struct P, mod p {
        x: f32,
        y: f32,
    }
}

#[test]
fn empty_extents_traversals_do_nothing() {
    use llama::mapping::soa::SoA;

    let mut v = alloc_view(SoA::<P, _>::new((Dyn(0u32),)), &HeapAlloc);
    let mut calls = 0;
    v.for_each(|_r| calls += 1);
    v.transform_simd::<4>(|_c| calls += 1);
    v.par_for_each_with(4, |_r| {});
    // SAFETY: the kernel touches nothing at all.
    unsafe { v.par_transform_simd_with::<4, _>(4, |_c| {}) };
    assert_eq!(calls, 0);

    // Rank 2 with a zero outer / zero inner extent.
    for e in [(Dyn(0u32), Dyn(4u32)), (Dyn(4u32), Dyn(0u32))] {
        let mut v = alloc_view(SoA::<P, _>::new(e), &HeapAlloc);
        let mut calls = 0;
        v.for_each(|_r| calls += 1);
        v.transform_simd::<4>(|_c| calls += 1);
        assert_eq!(calls, 0, "extents {e:?}");
    }
}

#[test]
fn single_element_views_traverse_once() {
    use llama::mapping::aos::AoS;

    let mut v = alloc_view(AoS::<P, _>::new((Dyn(1u32),)), &HeapAlloc);
    v.set(&[0], p::x, 2.0f32);
    let mut visits = 0;
    v.for_each(|r| {
        visits += 1;
        let x: f32 = r.get(p::x);
        r.set(p::y, x + 1.0);
    });
    assert_eq!(visits, 1);
    assert_eq!(v.get::<f32, _>(&[0], p::y), 3.0);

    let mut chunks = Vec::new();
    v.transform_simd::<8>(|c| {
        chunks.push((c.base(), c.lanes()));
        let x: Simd<f32, 8> = c.load(p::x);
        assert_eq!(x.0[0], 2.0);
        assert_eq!(x.0[1], 0.0); // inactive lane reads default
        c.store(p::x, x + Simd::splat(1.0));
    });
    assert_eq!(chunks, vec![(0, 1)]);
    assert_eq!(v.get::<f32, _>(&[0], p::x), 3.0);

    // Parallel entry points fall back to serial for a 1-record view.
    v.par_for_each_with(4, |r| r.set(p::y, 9.0f32));
    assert_eq!(v.get::<f32, _>(&[0], p::y), 9.0);
}

/// Apply `x += 1` through `transform_simd::<4>` (tail of 3 at n=7) and
/// through a scalar `for_each` on twin views; the results must agree for
/// every mapping.
fn tail_matches_scalar<M: SimdAccess<P> + Clone>(m: M) {
    let n = m.extents().extent(0);
    let mut simd = alloc_view(m.clone(), &HeapAlloc);
    let mut scalar = alloc_view(m, &HeapAlloc);
    for i in 0..n {
        let val = (i as f32) * 0.75 - 1.0;
        simd.set(&[i], p::x, val);
        scalar.set(&[i], p::x, val);
    }
    let mut tail_chunks = 0;
    simd.transform_simd::<4>(|c| {
        if c.lanes() < 4 {
            tail_chunks += 1;
        }
        let x: Simd<f32, 4> = c.load(p::x);
        c.store(p::x, x + Simd::splat(1.0));
    });
    scalar.for_each(|r| {
        let x: f32 = r.get(p::x);
        r.set(p::x, x + 1.0);
    });
    assert_eq!(tail_chunks, if n % 4 == 0 { 0 } else { 1 });
    for i in 0..n {
        assert_eq!(
            simd.get::<f32, _>(&[i], p::x).to_bits(),
            scalar.get::<f32, _>(&[i], p::x).to_bits(),
            "record {i}"
        );
    }
}

#[test]
fn simd_tail_matches_scalar_on_every_mapping() {
    use llama::mapping::aos::{AoS, MinPad, Packed};
    use llama::mapping::aosoa::AoSoA;
    use llama::mapping::bitpack_float::BitpackFloatSoA;
    use llama::mapping::bytesplit::Bytesplit;
    use llama::mapping::changetype::ChangeType;
    use llama::mapping::field_access_count::FieldAccessCount;
    use llama::mapping::heatmap::Heatmap;
    use llama::mapping::null::NullMapping;
    use llama::mapping::soa::{MultiBlob, SingleBlob, SoA};
    use llama::mapping::split::Split;

    for n in [1usize, 2, 3, 5, 7, 9, 16] {
        let e = (Dyn(n as u32),);
        tail_matches_scalar(AoS::<P, _>::new(e));
        tail_matches_scalar(AoS::<P, _, Packed>::new(e));
        tail_matches_scalar(AoS::<P, _, MinPad>::new(e));
        tail_matches_scalar(SoA::<P, _, MultiBlob>::new(e));
        tail_matches_scalar(SoA::<P, _, SingleBlob>::new(e));
        tail_matches_scalar(AoSoA::<P, _, 8>::new(e));
        tail_matches_scalar(Bytesplit::<P, _>::new(e));
        tail_matches_scalar(BitpackFloatSoA::<P, _, 8, 23>::new(e));
        tail_matches_scalar(ChangeType::<P, P, _>::new(SoA::<P, _>::new(e)));
        tail_matches_scalar(Heatmap::<P, _, 8>::new(SoA::<P, _>::new(e)));
        tail_matches_scalar(FieldAccessCount::new(AoS::<P, _>::new(e)));
        tail_matches_scalar(NullMapping::<P, _>::new(e));
        {
            const FIRST: u64 = 0b01; // x
            const REST: u64 = 0b10; // y
            type M1 = SoA<P, (Dyn<u32>,), MultiBlob, llama::extents::RowMajor, FIRST>;
            type M2 = SoA<P, (Dyn<u32>,), MultiBlob, llama::extents::RowMajor, REST>;
            let sel = llama::record::Selection::new(0, 1);
            tail_matches_scalar(Split::new(M1::new(e), M2::new(e), sel));
        }
    }
    // `One` is deliberately absent: all indices alias one record, so a
    // 4-lane chunk collapses its 4 read-modify-writes into one while the
    // scalar loop applies 4 — the op-count difference is the mapping's
    // semantics, not an engine bug.
}

#[test]
fn bitpack_int_tail_matches_scalar() {
    use llama::mapping::bitpack_int::BitpackIntSoADyn;

    llama::record! { pub struct H, mod h { adc: u32 } }
    for bits in [5u32, 12, 13, 24, 32] {
        let n = 7usize;
        let m = BitpackIntSoADyn::<H, _>::new((Dyn(n as u32),), bits);
        let mut simd = alloc_view(m, &HeapAlloc);
        let mut scalar = alloc_view(m, &HeapAlloc);
        for i in 0..n {
            simd.set(&[i], h::adc, (i as u32) * 37 + 5);
            scalar.set(&[i], h::adc, (i as u32) * 37 + 5);
        }
        simd.transform_simd::<4>(|c| {
            let a: Simd<u32, 4> = c.load(h::adc);
            c.store(h::adc, a + Simd::splat(1));
        });
        scalar.for_each(|r| {
            let a: u32 = r.get(h::adc);
            r.set(h::adc, a.wrapping_add(1));
        });
        for i in 0..n {
            assert_eq!(
                simd.get::<u32, _>(&[i], h::adc),
                scalar.get::<u32, _>(&[i], h::adc),
                "bits={bits} record {i}"
            );
        }
    }
}

#[test]
fn rank3_traversals_cover_every_record_once() {
    use llama::mapping::soa::SoA;

    let e = (Dyn(2u32), Dyn(3u32), Dyn(5u32));
    let mut via_for_each = alloc_view(SoA::<P, _>::new(e), &HeapAlloc);
    via_for_each.for_each(|r| {
        let y: f32 = r.get(p::y);
        r.set(p::y, y + 1.0);
    });

    let mut via_chunks = alloc_view(SoA::<P, _>::new(e), &HeapAlloc);
    let mut tails = 0;
    via_chunks.transform_simd::<4>(|c| {
        if c.lanes() < 4 {
            tails += 1;
        }
        let y: Simd<f32, 4> = c.load(p::y);
        c.store(p::y, y + Simd::splat(1.0));
    });
    // Inner extent 5 with 4 lanes: one tail (of 1) per inner row, 6 rows.
    assert_eq!(tails, 6);

    for i in 0..2 {
        for j in 0..3 {
            for k in 0..5 {
                assert_eq!(via_for_each.get::<f32, _>(&[i, j, k], p::y), 1.0);
                assert_eq!(via_chunks.get::<f32, _>(&[i, j, k], p::y), 1.0);
            }
        }
    }
}

#[test]
fn rank2_parallel_shards_split_the_outer_dimension() {
    use llama::mapping::soa::SoA;
    use llama::shard::ViewShards;

    let e = (Dyn(7u32), Dyn(5u32));
    let mut v = alloc_view(SoA::<P, _>::new(e), &HeapAlloc);
    {
        let shards = ViewShards::split(&mut v, 3).unwrap();
        assert_eq!(shards.bounds(), &[0, 2, 4, 7]);
        let mut cursors = shards.cursors();
        for cur in &mut cursors {
            let (lo, hi) = cur.outer_range();
            cur.for_each(|r| {
                assert!(r.index()[0] >= lo && r.index()[0] < hi);
                let x: f32 = r.get(p::x);
                r.set(p::x, x + 1.0);
            });
        }
    }
    for i in 0..7 {
        for j in 0..5 {
            assert_eq!(v.get::<f32, _>(&[i, j], p::x), 1.0);
        }
    }

    // The parallel SIMD walk matches the serial chunking on rank 2.
    let mut serial = alloc_view(SoA::<P, _>::new(e), &HeapAlloc);
    let mut par = alloc_view(SoA::<P, _>::new(e), &HeapAlloc);
    // Storage-generic: the serial engine hands chunks over the view's
    // storage, the parallel engine over the shard-worker storage.
    fn op<M: SimdAccess<P>, S: BlobStorage>(c: &mut Chunk<'_, P, M, S, 4>) {
        let x: Simd<f32, 4> = c.load(p::x);
        let y: Simd<f32, 4> = c.load(p::y);
        c.store(p::y, x + y + Simd::splat(0.5));
    }
    serial.transform_simd::<4>(op::<_, _>);
    // SAFETY: the kernel touches only its own chunk's records.
    unsafe { par.par_transform_simd_with::<4, _>(3, op::<_, _>) };
    for i in 0..7 {
        for j in 0..5 {
            assert_eq!(
                serial.get::<f32, _>(&[i, j], p::y).to_bits(),
                par.get::<f32, _>(&[i, j], p::y).to_bits()
            );
        }
    }
}

#[test]
fn chunk_accessors_expose_index_lanes_and_base() {
    use llama::mapping::soa::SoA;

    let mut v = alloc_view(SoA::<P, _>::new((Dyn(6u32),)), &HeapAlloc);
    let mut seen = Vec::new();
    v.transform_simd::<4>(|c| {
        seen.push((c.index().to_vec(), c.base(), c.lanes(), c.count()));
    });
    assert_eq!(seen, vec![(vec![0], 0, 4, 6), (vec![4], 4, 2, 6)]);
}
