//! Property-based tests (mini-proptest in `llama::testing`): randomized
//! invariants over mappings, bit packing, float repacking, copy, and the
//! coordinator.

use llama::blob::{alloc_view, HeapAlloc};
use llama::extents::Dyn;
use llama::mapping::bitpack_float::{pack_float_bits, unpack_float_bits};
use llama::mapping::bitpack_int::{read_bits, sign_extend, write_bits};
use llama::mapping::MemoryAccess;
use llama::testing::{forall, Rng};

llama::record! {
    pub struct R, mod r {
        a: f64,
        b: f32,
        c: u32,
        d: i16,
    }
}

/// Write a deterministic pseudo-random pattern, read it back, for any
/// mapping — the fundamental store/load inverse property.
fn roundtrip_prop<M: MemoryAccess<R>>(m: M, n: usize, seed: u64) -> bool {
    let mut v = alloc_view(m, &HeapAlloc);
    let mut rng = Rng::new(seed);
    let vals: Vec<(f64, f32, u32, i16)> = (0..n)
        .map(|_| {
            (
                rng.f64_range(-1e6, 1e6),
                rng.f64_range(-1e3, 1e3) as f32,
                rng.next_u64() as u32,
                rng.range_i64(-30000, 30000) as i16,
            )
        })
        .collect();
    for (i, &(a, b, c, d)) in vals.iter().enumerate() {
        v.set(&[i], r::a, a);
        v.set(&[i], r::b, b);
        v.set(&[i], r::c, c);
        v.set(&[i], r::d, d);
    }
    vals.iter().enumerate().all(|(i, &(a, b, c, d))| {
        v.get::<f64, _>(&[i], r::a) == a
            && v.get::<f32, _>(&[i], r::b) == b
            && v.get::<u32, _>(&[i], r::c) == c
            && v.get::<i16, _>(&[i], r::d) == d
    })
}

#[test]
fn prop_all_layouts_roundtrip_random_data() {
    use llama::mapping::aos::{AoS, MinPad, Packed};
    use llama::mapping::aosoa::AoSoA;
    use llama::mapping::bytesplit::Bytesplit;
    use llama::mapping::soa::{MultiBlob, SingleBlob, SoA};

    forall("layout-roundtrip", 25, |g| (g.range(1, 200), g.next_u64()), |&(n, seed)| {
        let e = (Dyn(n as u32),);
        roundtrip_prop(AoS::<R, _>::new(e), n, seed)
            && roundtrip_prop(AoS::<R, _, Packed>::new(e), n, seed)
            && roundtrip_prop(AoS::<R, _, MinPad>::new(e), n, seed)
            && roundtrip_prop(SoA::<R, _, MultiBlob>::new(e), n, seed)
            && roundtrip_prop(SoA::<R, _, SingleBlob>::new(e), n, seed)
            && roundtrip_prop(AoSoA::<R, _, 8>::new(e), n, seed)
            && roundtrip_prop(Bytesplit::<R, _>::new(e), n, seed)
    });
}

#[test]
fn prop_bit_read_write_inverse() {
    // Writing any value at any bit offset then reading returns the masked
    // value; neighbours are untouched.
    forall(
        "bits-inverse",
        500,
        |g| {
            let nbits = g.range(1, 64) as u32;
            let bit = g.range(0, 800);
            let value = g.next_u64();
            (nbits, bit, value)
        },
        |&(nbits, bit, value)| {
            let mut buf = vec![0xA5u8; 128];
            let before = buf.clone();
            write_bits(&mut buf, bit, nbits, value);
            let mask = if nbits == 64 { u64::MAX } else { (1u64 << nbits) - 1 };
            if read_bits(&buf, bit, nbits) != value & mask {
                return false;
            }
            // bits strictly before `bit` and after `bit+nbits` unchanged
            for check_bit in bit.saturating_sub(17)..bit {
                if read_bits(&buf, check_bit, 1) != read_bits(&before, check_bit, 1) {
                    return false;
                }
            }
            for check_bit in bit + nbits as usize..(bit + nbits as usize + 17).min(1000) {
                if read_bits(&buf, check_bit, 1) != read_bits(&before, check_bit, 1) {
                    return false;
                }
            }
            true
        },
    );
}

#[test]
fn prop_sign_extend_matches_arithmetic() {
    forall(
        "sign-extend",
        300,
        |g| {
            let nbits = g.range(1, 63) as u32;
            let v = g.next_u64() & ((1u64 << nbits) - 1);
            (nbits, v)
        },
        |&(nbits, v)| {
            let got = sign_extend(v, nbits);
            // reference: shift into the top of i64 then arithmetic-shift back
            let shift = 64 - nbits;
            let want = (((v << shift) as i64) >> shift) as i128;
            got == want
        },
    );
}

#[test]
fn prop_float_pack_unpack_faithful() {
    // For every (exp, man) config: unpack(pack(x)) is the nearest
    // representable value — checked via the monotone bound |x - round(x)|
    // <= ulp, plus exactness when x is already representable.
    forall(
        "float-repack",
        400,
        |g| {
            let exp = g.range(2, 11) as u32;
            let man = g.range(1, 52) as u32;
            (exp, man, g.f64_edgy())
        },
        |&(exp, man, x)| {
            let packed = pack_float_bits(x, exp, man);
            let total = 1 + exp + man;
            if packed >> total != 0 {
                return false; // no stray bits above the format width
            }
            let y = unpack_float_bits(packed, exp, man);
            if x.is_nan() {
                return y.is_nan();
            }
            // Round-trip idempotence: repacking the unpacked value is exact.
            let repacked = pack_float_bits(y, exp, man);
            if y.is_infinite() {
                // overflow-to-inf stays inf
                return unpack_float_bits(repacked, exp, man) == y;
            }
            repacked == packed
        },
    );
}

#[test]
fn prop_f32_exact_through_e8m23() {
    forall("f32-exact", 300, |g| g.f64_edgy() as f32, |&x| {
        let p = pack_float_bits(x as f64, 8, 23);
        let y = unpack_float_bits(p, 8, 23) as f32;
        if x.is_nan() {
            y.is_nan()
        } else {
            x.to_bits() == y.to_bits()
        }
    });
}

#[test]
fn prop_bitpack_int_view_roundtrips_masked() {
    use llama::mapping::bitpack_int::BitpackIntSoADyn;
    llama::record! { pub struct I, mod ifld { v: u64 } }
    forall(
        "bitpack-view",
        40,
        |g| {
            let bits = g.range(1, 64) as u32;
            let n = g.range(1, 120);
            (bits, n, g.next_u64())
        },
        |&(bits, n, seed)| {
            let m = BitpackIntSoADyn::<I, _>::new((Dyn(n as u32),), bits);
            let mut v = alloc_view(m, &HeapAlloc);
            let mut rng = Rng::new(seed);
            let mask = if bits == 64 { u64::MAX } else { (1u64 << bits) - 1 };
            let vals: Vec<u64> = (0..n).map(|_| rng.next_u64()).collect();
            for (i, &val) in vals.iter().enumerate() {
                v.set(&[i], ifld::v, val);
            }
            vals.iter().enumerate().all(|(i, &val)| v.get::<u64, _>(&[i], ifld::v) == val & mask)
        },
    );
}

#[test]
fn prop_typed_api_bit_identical_to_legacy_across_mappings() {
    // The typed tag API (`set_t`/`get_t`, `field`/`set_field`,
    // `load_t`/`store_t`, `load_simd_t`/`store_simd_t`) must produce
    // exactly the bytes and values of the legacy usize-index API on every
    // mapping — the zero-cost claim of the access-API redesign, checked
    // as bit-identity over scalar writes, bulk traversals, and SIMD
    // chunk transforms. All 13 mappings are covered between the float
    // record here and the integer record below.
    use llama::mapping::aos::{AoS, MinPad, Packed};
    use llama::mapping::aosoa::AoSoA;
    use llama::mapping::bitpack_float::BitpackFloatSoA;
    use llama::mapping::bytesplit::Bytesplit;
    use llama::mapping::changetype::ChangeType;
    use llama::mapping::field_access_count::FieldAccessCount;
    use llama::mapping::heatmap::Heatmap;
    use llama::mapping::null::NullMapping;
    use llama::mapping::one::One;
    use llama::mapping::soa::{MultiBlob, SingleBlob, SoA};
    use llama::mapping::split::Split;
    use llama::mapping::SimdAccess;
    use llama::simd::Simd;

    llama::record! {
        pub struct T, mod tf {
            v: f32,
            w: f32,
        }
    }

    // Drive one view through the typed API, its twin through the legacy
    // usize API, and compare every value bit for bit. The typed calls fix
    // the index rank in the bound.
    fn agree<M>(m: M, n: usize, seed: u64) -> bool
    where
        M: SimdAccess<T> + Clone,
        M::Extents: llama::extents::Extents<ArrayIndex = [usize; 1]>,
    {
        let mut typed = alloc_view(m.clone(), &HeapAlloc);
        let mut legacy = alloc_view(m, &HeapAlloc);
        let mut rng_a = Rng::new(seed);
        let mut rng_b = Rng::new(seed);
        for i in 0..n {
            typed.set_t([i], tf::v, rng_a.f64_range(-1e3, 1e3) as f32);
            typed.set_t([i], tf::w, rng_a.f64_range(-1e3, 1e3) as f32);
            legacy.set::<f32, _>(&[i], tf::v.i(), rng_b.f64_range(-1e3, 1e3) as f32);
            legacy.set::<f32, _>(&[i], tf::w.i(), rng_b.f64_range(-1e3, 1e3) as f32);
        }
        // Scalar bulk traversal: typed navigation vs legacy get/set.
        typed.for_each(|r| {
            let v = r.field(tf::v);
            let w = r.field(tf::w);
            r.set_field(tf::v, v * w - 1.0);
        });
        legacy.for_each(|r| {
            let v: f32 = r.get(tf::v.i());
            let w: f32 = r.get(tf::w.i());
            r.set(tf::v.i(), v * w - 1.0);
        });
        // SIMD chunk transform: load_t/store_t vs load/store.
        typed.transform_simd::<4>(|c| {
            let a = c.load_t(tf::v);
            let b = c.load_t(tf::w);
            c.store_t(tf::w, a + b);
        });
        legacy.transform_simd::<4>(|c| {
            let a: Simd<f32, 4> = c.load(tf::v.i());
            let b: Simd<f32, 4> = c.load(tf::w.i());
            c.store(tf::w.i(), a + b);
        });
        // Direct SIMD entry points where a full vector fits.
        if n >= 4 {
            let a: Simd<f32, 4> = typed.load_simd_t([0], tf::v);
            typed.store_simd_t([0], tf::v, a);
            let b: Simd<f32, 4> = legacy.load_simd(&[0], tf::v.i());
            legacy.store_simd(&[0], tf::v.i(), b);
            if a.0.map(f32::to_bits) != b.0.map(f32::to_bits) {
                return false;
            }
        }
        (0..n).all(|i| {
            typed.get_t([i], tf::v).to_bits() == legacy.get::<f32, _>(&[i], tf::v.i()).to_bits()
                && typed.get_t([i], tf::w).to_bits()
                    == legacy.get::<f32, _>(&[i], tf::w.i()).to_bits()
        })
    }

    forall("typed-vs-legacy", 10, |g| (g.range(1, 80), g.next_u64()), |&(n, seed)| {
        let e = (Dyn(n as u32),);
        let ok = agree(AoS::<T, _>::new(e), n, seed)
            && agree(AoS::<T, _, Packed>::new(e), n, seed)
            && agree(AoS::<T, _, MinPad>::new(e), n, seed)
            && agree(SoA::<T, _, MultiBlob>::new(e), n, seed)
            && agree(SoA::<T, _, SingleBlob>::new(e), n, seed)
            && agree(AoSoA::<T, _, 8>::new(e), n, seed)
            && agree(Bytesplit::<T, _>::new(e), n, seed)
            && agree(BitpackFloatSoA::<T, _, 8, 23>::new(e), n, seed)
            && agree(ChangeType::<T, T, _>::new(SoA::<T, _>::new(e)), n, seed)
            && agree(Heatmap::<T, _, 8>::new(SoA::<T, _>::new(e)), n, seed)
            && agree(FieldAccessCount::new(AoS::<T, _>::new(e)), n, seed)
            && agree(NullMapping::<T, _>::new(e), n, seed)
            && agree(One::<T, _>::new(e), n, seed);
        let sel = llama::record::Selection::new(0, 1);
        const FIRST: u64 = 0b01;
        const REST: u64 = 0b10;
        type M1 = SoA<T, (Dyn<u32>,), MultiBlob, llama::extents::RowMajor, FIRST>;
        type M2 = SoA<T, (Dyn<u32>,), MultiBlob, llama::extents::RowMajor, REST>;
        ok && agree(Split::new(M1::new(e), M2::new(e), sel), n, seed)
    });

    // Bit-packed integers (the record above is float-typed): typed vs
    // legacy over BitpackIntSoA and BitpackIntSoADyn.
    use llama::mapping::bitpack_int::{BitpackIntSoA, BitpackIntSoADyn};
    llama::record! { pub struct IT, mod it { v: u32 } }
    forall("typed-vs-legacy-bitpack-int", 10, |g| (g.range(1, 60), g.next_u64()), |&(n, seed)| {
        let e = (Dyn(n as u32),);
        fn agree_int<M>(m: M, n: usize, seed: u64) -> bool
        where
            M: llama::mapping::MemoryAccess<IT> + Clone,
            M::Extents: llama::extents::Extents<ArrayIndex = [usize; 1]>,
        {
            let mut typed = alloc_view(m.clone(), &HeapAlloc);
            let mut legacy = alloc_view(m, &HeapAlloc);
            let mut rng_a = Rng::new(seed);
            let mut rng_b = Rng::new(seed);
            for i in 0..n {
                typed.set_t([i], it::v, rng_a.next_u64() as u32);
                legacy.set::<u32, _>(&[i], it::v.i(), rng_b.next_u64() as u32);
            }
            (0..n).all(|i| typed.get_t([i], it::v) == legacy.get::<u32, _>(&[i], it::v.i()))
        }
        agree_int(BitpackIntSoA::<IT, _, 12>::new(e), n, seed)
            && agree_int(BitpackIntSoADyn::<IT, _>::new(e, 17), n, seed)
    });
}

#[test]
fn prop_copy_preserves_all_fields() {
    use llama::copy::copy_view;
    use llama::mapping::aos::AoS;
    use llama::mapping::aosoa::AoSoA;
    use llama::mapping::soa::SoA;

    forall("copy-preserves", 20, |g| (g.range(1, 100), g.next_u64()), |&(n, seed)| {
        let e = (Dyn(n as u32),);
        let mut a = alloc_view(AoS::<R, _>::new(e), &HeapAlloc);
        let mut rng = Rng::new(seed);
        for i in 0..n {
            a.set(&[i], r::a, rng.f64_range(-1.0, 1.0));
            a.set(&[i], r::c, rng.next_u64() as u32);
        }
        let mut b = alloc_view(SoA::<R, _>::new(e), &HeapAlloc);
        let mut c = alloc_view(AoSoA::<R, _, 4>::new(e), &HeapAlloc);
        copy_view(&a, &mut b);
        copy_view(&b, &mut c);
        (0..n).all(|i| {
            a.get::<f64, _>(&[i], r::a) == c.get::<f64, _>(&[i], r::a)
                && a.get::<u32, _>(&[i], r::c) == c.get::<u32, _>(&[i], r::c)
        })
    });
}

#[test]
fn prop_bulk_traversal_bit_identical_across_mappings() {
    // The bulk-traversal engine (`View::transform_simd` / `View::for_each`)
    // must produce bit-identical results whatever the mapping: SoA takes
    // the contiguous vector path, AoSoA the in-block lane path, AoS and
    // bitpack the scalar fallback. f32 values through BitpackFloatSoA
    // e8m23 are stored exactly, so even the computed mapping must match
    // bit for bit.
    use llama::mapping::aos::AoS;
    use llama::mapping::aosoa::AoSoA;
    use llama::mapping::bitpack_float::BitpackFloatSoA;
    use llama::mapping::soa::SoA;
    use llama::mapping::SimdAccess;
    use llama::simd::Simd;

    llama::record! {
        pub struct B, mod bf {
            v: f32,
            w: f32,
        }
    }

    fn run<M: SimdAccess<B>>(m: M, n: usize, seed: u64) -> Vec<u32> {
        let mut view = alloc_view(m, &HeapAlloc);
        let mut rng = Rng::new(seed);
        for i in 0..n {
            view.set(&[i], bf::v, rng.f64_range(-1e3, 1e3) as f32);
            view.set(&[i], bf::w, rng.f64_range(-1e3, 1e3) as f32);
        }
        // SIMD chunk transform (4 lanes), then a scalar for_each pass.
        view.transform_simd::<4>(|c| {
            let a: Simd<f32, 4> = c.load(bf::v);
            let b: Simd<f32, 4> = c.load(bf::w);
            c.store(bf::v, a * b + a);
        });
        view.for_each(|r| {
            let w: f32 = r.get(bf::w);
            r.set(bf::w, w + 1.0);
        });
        (0..n)
            .flat_map(|i| {
                [view.get::<f32, _>(&[i], bf::v).to_bits(), view.get::<f32, _>(&[i], bf::w).to_bits()]
            })
            .collect()
    }

    forall("bulk-identical", 12, |g| (g.range(1, 16) * 8, g.next_u64()), |&(n, seed)| {
        let e = (Dyn(n as u32),);
        let reference = run(SoA::<B, _>::new(e), n, seed);
        reference == run(AoS::<B, _>::new(e), n, seed)
            && reference == run(AoSoA::<B, _, 8>::new(e), n, seed)
            && reference == run(BitpackFloatSoA::<B, _, 8, 23>::new(e), n, seed)
    });
}

#[test]
fn prop_run_copy_agrees_with_field_wise() {
    // Strategy 2 (contiguous field runs) must produce exactly the bytes
    // the scalar fallback would.
    use llama::copy::{copy_view, CopyStrategy};
    use llama::mapping::aosoa::AoSoA;
    use llama::mapping::soa::{SingleBlob, SoA};

    forall("run-copy", 15, |g| (g.range(1, 120), g.next_u64()), |&(n, seed)| {
        let e = (Dyn(n as u32),);
        let mut src = alloc_view(SoA::<R, _>::new(e), &HeapAlloc);
        let mut rng = Rng::new(seed);
        for i in 0..n {
            src.set(&[i], r::a, rng.f64_range(-1e6, 1e6));
            src.set(&[i], r::b, rng.f64_range(-1e3, 1e3) as f32);
            src.set(&[i], r::c, rng.next_u64() as u32);
            src.set(&[i], r::d, rng.range_i64(-30000, 30000) as i16);
        }
        let mut via_runs = alloc_view(AoSoA::<R, _, 8>::new(e), &HeapAlloc);
        let strategy = copy_view(&src, &mut via_runs);
        let mut via_scalar = alloc_view(SoA::<R, _, SingleBlob>::new(e), &HeapAlloc);
        llama::copy::field_wise_copy(&src, &mut via_scalar);
        strategy == CopyStrategy::FieldRuns
            && (0..n).all(|i| {
                via_runs.get::<f64, _>(&[i], r::a) == via_scalar.get::<f64, _>(&[i], r::a)
                    && via_runs.get::<f32, _>(&[i], r::b) == via_scalar.get::<f32, _>(&[i], r::b)
                    && via_runs.get::<u32, _>(&[i], r::c) == via_scalar.get::<u32, _>(&[i], r::c)
                    && via_runs.get::<i16, _>(&[i], r::d) == via_scalar.get::<i16, _>(&[i], r::d)
            })
    });
}

#[test]
fn prop_par_run_copy_bit_identical_to_field_wise() {
    // The parallel run copy (`copy_view_par`) must write exactly the
    // values the serial field-wise copy writes, across destination
    // mappings × threads {1, 2, 4, 7}, including ragged extents —
    // and mappings that refuse `shard_bounds` (One) or have no
    // byte-contiguity (AoS) must fall back and still agree.
    use llama::copy::{copy_view_par, field_wise_copy, CopyStrategy};
    use llama::mapping::aos::AoS;
    use llama::mapping::aosoa::AoSoA;
    use llama::mapping::one::One;
    use llama::mapping::soa::{SingleBlob, SoA};

    fn snapshot<M: MemoryAccess<R>, S: llama::blob::BlobStorage>(
        v: &llama::view::View<R, M, S>,
        n: usize,
    ) -> Vec<u64> {
        (0..n)
            .flat_map(|i| {
                [
                    v.get::<f64, _>(&[i], r::a).to_bits(),
                    v.get::<f32, _>(&[i], r::b).to_bits() as u64,
                    v.get::<u32, _>(&[i], r::c) as u64,
                    v.get::<i16, _>(&[i], r::d) as u16 as u64,
                ]
            })
            .collect()
    }

    forall("par-run-copy", 10, |g| (g.range(1, 150), g.next_u64()), |&(n, seed)| {
        let e = (Dyn(n as u32),);
        let mut src = alloc_view(SoA::<R, _>::new(e), &HeapAlloc);
        let mut rng = Rng::new(seed);
        for i in 0..n {
            src.set(&[i], r::a, rng.f64_range(-1e6, 1e6));
            src.set(&[i], r::b, rng.f64_range(-1e3, 1e3) as f32);
            src.set(&[i], r::c, rng.next_u64() as u32);
            src.set(&[i], r::d, rng.range_i64(-30000, 30000) as i16);
        }
        macro_rules! check_dst {
            ($mk:expr) => {{
                let mut reference = alloc_view($mk, &HeapAlloc);
                field_wise_copy(&src, &mut reference);
                let want = snapshot(&reference, n);
                for t in [1usize, 2, 4, 7] {
                    let mut dst = alloc_view($mk, &HeapAlloc);
                    let _ = copy_view_par(&src, &mut dst, t);
                    if snapshot(&dst, n) != want {
                        return false;
                    }
                }
            }};
        }
        check_dst!(AoSoA::<R, _, 8>::new(e));
        check_dst!(AoSoA::<R, _, 4>::new(e));
        check_dst!(SoA::<R, _, SingleBlob>::new(e));
        check_dst!(AoS::<R, _>::new(e)); // no runs: field-wise fallback
        // `One` refuses shard_bounds entirely; both paths collapse every
        // record into the single stored one and must still agree.
        {
            let mut reference = alloc_view(One::<R, _>::new(e), &HeapAlloc);
            field_wise_copy(&src, &mut reference);
            let want = snapshot(&reference, 1);
            for t in [2usize, 7] {
                let mut dst = alloc_view(One::<R, _>::new(e), &HeapAlloc);
                let s = copy_view_par(&src, &mut dst, t);
                if s != CopyStrategy::FieldWise || snapshot(&dst, 1) != want {
                    return false;
                }
            }
        }
        // Large-enough views at >= 2 threads must actually take the
        // parallel strategy (not silently fall back forever).
        if n >= 16 {
            let mut dst = alloc_view(SoA::<R, _, SingleBlob>::new(e), &HeapAlloc);
            if copy_view_par(&src, &mut dst, 4) != CopyStrategy::FieldRunsPar {
                return false;
            }
        }
        true
    });
}

#[test]
fn prop_par_for_each_bit_identical_to_serial_across_mappings() {
    // The parallel sharded traversal must produce the bytes the serial
    // engine produces, for every mapping (shardable ones split, the rest
    // fall back), at thread counts that do and don't divide the extent.
    use llama::blob::BlobStorage;
    use llama::mapping::aos::{AoS, Packed};
    use llama::mapping::aosoa::AoSoA;
    use llama::mapping::bytesplit::Bytesplit;
    use llama::mapping::changetype::ChangeType;
    use llama::mapping::field_access_count::FieldAccessCount;
    use llama::mapping::heatmap::Heatmap;
    use llama::mapping::null::NullMapping;
    use llama::mapping::one::One;
    use llama::mapping::soa::{MultiBlob, SingleBlob, SoA};
    use llama::mapping::split::Split;
    use llama::view::RecordRefMut;

    // Per-record op touching only the record's own fields (the contract
    // under which parallel results are bit-identical). Generic over the
    // storage: the serial engine hands cursors over the view's own
    // storage, the parallel engine over the shard-worker storage
    // (`llama::blob::ShardBlobs`).
    fn op<M: MemoryAccess<R>, S: BlobStorage>(rec: &mut RecordRefMut<'_, R, M, S>) {
        let a: f64 = rec.get(r::a);
        let b: f32 = rec.get(r::b);
        let c: u32 = rec.get(r::c);
        let d: i16 = rec.get(r::d);
        rec.set(r::a, a * 0.5 + 1.0);
        rec.set(r::b, b * b - 2.0);
        rec.set(r::c, c.rotate_left(7) ^ 0xA5A5_A5A5);
        rec.set(r::d, d.wrapping_add(3));
    }

    fn run<M: MemoryAccess<R>>(m: M, n: usize, seed: u64, threads: Option<usize>) -> Vec<u64> {
        let mut v = alloc_view(m, &HeapAlloc);
        let mut rng = Rng::new(seed);
        for i in 0..n {
            v.set(&[i], r::a, rng.f64_range(-1e6, 1e6));
            v.set(&[i], r::b, rng.f64_range(-1e3, 1e3) as f32);
            v.set(&[i], r::c, rng.next_u64() as u32);
            v.set(&[i], r::d, rng.range_i64(-20000, 20000) as i16);
        }
        match threads {
            Some(t) => v.par_for_each_with(t, op::<M, _>),
            None => v.for_each(op::<M, _>),
        }
        (0..n)
            .flat_map(|i| {
                [
                    v.get::<f64, _>(&[i], r::a).to_bits(),
                    v.get::<f32, _>(&[i], r::b).to_bits() as u64,
                    v.get::<u32, _>(&[i], r::c) as u64,
                    v.get::<i16, _>(&[i], r::d) as u16 as u64,
                ]
            })
            .collect()
    }

    forall("par-for-each", 8, |g| (g.range(1, 150), g.next_u64()), |&(n, seed)| {
        let e = (Dyn(n as u32),);
        macro_rules! check {
            ($m:expr) => {{
                let serial = run($m, n, seed, None);
                for t in [1usize, 2, 4, 7] {
                    if run($m, n, seed, Some(t)) != serial {
                        return false;
                    }
                }
            }};
        }
        check!(AoS::<R, _>::new(e));
        check!(AoS::<R, _, Packed>::new(e));
        check!(SoA::<R, _, MultiBlob>::new(e));
        check!(SoA::<R, _, SingleBlob>::new(e));
        check!(AoSoA::<R, _, 8>::new(e));
        check!(Bytesplit::<R, _>::new(e));
        check!(ChangeType::<R, R, _>::new(SoA::<R, _>::new(e)));
        check!(Heatmap::<R, _, 64>::new(SoA::<R, _>::new(e)));
        check!(FieldAccessCount::new(AoS::<R, _>::new(e)));
        check!(NullMapping::<R, _>::new(e));
        check!(One::<R, _>::new(e)); // unshardable: exercises the fallback
        {
            const FIRST: u64 = 0b0001; // a
            const REST: u64 = 0b1110; // b, c, d
            type M1 = SoA<R, (Dyn<u32>,), MultiBlob, llama::extents::RowMajor, FIRST>;
            type M2 = SoA<R, (Dyn<u32>,), MultiBlob, llama::extents::RowMajor, REST>;
            let sel = llama::record::Selection::new(0, 1);
            check!(Split::new(M1::new(e), M2::new(e), sel));
        }

        // Instrumented wrappers must also land the same counter totals
        // (atomic increments commute across shards).
        let fac = FieldAccessCount::new(SoA::<R, _>::new(e));
        let mut v = alloc_view(fac, &HeapAlloc);
        v.par_for_each_with(4, op);
        let (reads, writes) = v.mapping().field_counts(r::a);
        reads == n as u64 && writes == n as u64
    });
}

#[test]
fn prop_par_transform_simd_bit_identical_to_serial_across_mappings() {
    // SIMD chunk traversal: parallel shards (rank-1 boundaries aligned to
    // the lane count) must reproduce the serial chunk pattern exactly,
    // including the tail when the lane count does not divide the extent.
    use llama::blob::{BlobStorage, HeapStorage};
    use llama::mapping::aos::AoS;
    use llama::mapping::aosoa::AoSoA;
    use llama::mapping::bitpack_float::BitpackFloatSoA;
    use llama::mapping::bytesplit::Bytesplit;
    use llama::mapping::field_access_count::FieldAccessCount;
    use llama::mapping::heatmap::Heatmap;
    use llama::mapping::soa::{MultiBlob, SingleBlob, SoA};
    use llama::mapping::SimdAccess;
    use llama::simd::Simd;
    use llama::view::Chunk;

    llama::record! {
        pub struct B2, mod bf2 {
            v: f32,
            w: f32,
        }
    }

    // Storage-generic: serial chunks run over the view's storage,
    // parallel chunks over the shard-worker storage.
    fn chunk_op<M: SimdAccess<B2>, S: BlobStorage>(c: &mut Chunk<'_, B2, M, S, 4>) {
        let a: Simd<f32, 4> = c.load(bf2::v);
        let b: Simd<f32, 4> = c.load(bf2::w);
        c.store(bf2::v, a * b + a);
        c.store(bf2::w, b - a);
    }

    fn run<M: SimdAccess<B2>>(m: M, n: usize, seed: u64, threads: Option<usize>) -> Vec<u32> {
        let mut v = alloc_view(m, &HeapAlloc);
        let mut rng = Rng::new(seed);
        for i in 0..n {
            v.set(&[i], bf2::v, rng.f64_range(-1e3, 1e3) as f32);
            v.set(&[i], bf2::w, rng.f64_range(-1e3, 1e3) as f32);
        }
        match threads {
            // SAFETY: chunk_op touches only its own chunk's records.
            Some(t) => unsafe { v.par_transform_simd_with::<4, _>(t, chunk_op::<M, _>) },
            None => v.transform_simd::<4>(chunk_op::<M, _>),
        }
        (0..n).flat_map(|i| [view_bits(&v, i, bf2::v), view_bits(&v, i, bf2::w)]).collect()
    }

    fn view_bits<M: MemoryAccess<B2>>(
        v: &llama::view::View<B2, M, HeapStorage>,
        i: usize,
        field: impl llama::record::FieldIndex,
    ) -> u32 {
        v.get::<f32, _>(&[i], field).to_bits()
    }

    forall("par-transform-simd", 8, |g| (g.range(1, 130), g.next_u64()), |&(n, seed)| {
        let e = (Dyn(n as u32),);
        macro_rules! check {
            ($m:expr) => {{
                let serial = run($m, n, seed, None);
                for t in [1usize, 2, 4, 7] {
                    if run($m, n, seed, Some(t)) != serial {
                        return false;
                    }
                }
            }};
        }
        check!(SoA::<B2, _, MultiBlob>::new(e));
        check!(SoA::<B2, _, SingleBlob>::new(e));
        check!(AoS::<B2, _>::new(e));
        check!(AoSoA::<B2, _, 8>::new(e));
        check!(Bytesplit::<B2, _>::new(e));
        check!(BitpackFloatSoA::<B2, _, 8, 23>::new(e));
        check!(Heatmap::<B2, _, 1>::new(SoA::<B2, _>::new(e)));
        check!(FieldAccessCount::new(AoS::<B2, _>::new(e)));
        true
    });
}

#[test]
fn prop_par_bitpack_int_matches_serial_at_byte_misaligned_sizes() {
    // Bit-packed integers share bytes between neighbours: the shard
    // splitter must only cut at byte-aligned value boundaries (or fall
    // back to serial), for every bit count and extent.
    use llama::blob::BlobStorage;
    use llama::mapping::bitpack_int::BitpackIntSoADyn;
    use llama::view::RecordRefMut;

    llama::record! { pub struct I2, mod i2 { v: u64 } }
    type M2 = BitpackIntSoADyn<I2, (Dyn<u32>,)>;

    fn op<S: BlobStorage>(rec: &mut RecordRefMut<'_, I2, M2, S>) {
        let x: u64 = rec.get(i2::v);
        rec.set(i2::v, x.wrapping_mul(0x9E37_79B9_7F4A_7C15).rotate_left(13));
    }

    forall(
        "par-bitpack-int",
        25,
        |g| {
            let bits = g.range(1, 64) as u32;
            let n = g.range(1, 120);
            (bits, n, g.next_u64())
        },
        |&(bits, n, seed)| {
            let run = |threads: Option<usize>| -> Vec<u64> {
                let mut v = alloc_view(M2::new((Dyn(n as u32),), bits), &HeapAlloc);
                let mut rng = Rng::new(seed);
                for i in 0..n {
                    v.set(&[i], i2::v, rng.next_u64());
                }
                match threads {
                    Some(t) => v.par_for_each_with(t, op),
                    None => v.for_each(op),
                }
                (0..n).map(|i| v.get::<u64, _>(&[i], i2::v)).collect()
            };
            let serial = run(None);
            [1usize, 2, 4, 7].iter().all(|&t| run(Some(t)) == serial)
        },
    );
}

#[test]
fn prop_pooled_dispatch_bit_identical_with_worker_reuse() {
    // The implicit parallel entry points (`par_for_each_with`,
    // `par_transform_simd_with`, `copy_view_par`) route through the
    // persistent global pool: across many calls they must keep
    // producing serial-identical bytes while the pool never respawns a
    // worker — the whole point of amortized dispatch. (Skipped when
    // pooling is off: `LLAMA_POOL=off` or Miri, where the entry points
    // use the per-call scoped spawn that other properties cover.)
    use llama::blob::BlobStorage;
    use llama::copy::{copy_view_par, field_wise_copy};
    use llama::mapping::aosoa::AoSoA;
    use llama::mapping::soa::SoA;
    use llama::simd::Simd;
    use llama::view::{Chunk, RecordRefMut};

    if !llama::pool::pooled_dispatch() {
        return;
    }

    fn rec_op<M: MemoryAccess<R>, S: BlobStorage>(rec: &mut RecordRefMut<'_, R, M, S>) {
        let a: f64 = rec.get(r::a);
        let c: u32 = rec.get(r::c);
        rec.set(r::a, a * 1.5 - 2.0);
        rec.set(r::c, c ^ 0x5A5A_5A5A);
    }

    fn chunk_op<M: llama::mapping::SimdAccess<R>, S: BlobStorage>(
        c: &mut Chunk<'_, R, M, S, 4>,
    ) {
        let b: Simd<f32, 4> = c.load(r::b);
        c.store(r::b, b * b - b);
    }

    fn run(n: usize, seed: u64, threads: Option<usize>) -> Vec<u64> {
        let mut v = alloc_view(SoA::<R, _>::new((Dyn(n as u32),)), &HeapAlloc);
        let mut rng = Rng::new(seed);
        for i in 0..n {
            v.set(&[i], r::a, rng.f64_range(-1e6, 1e6));
            v.set(&[i], r::b, rng.f64_range(-1e3, 1e3) as f32);
            v.set(&[i], r::c, rng.next_u64() as u32);
            v.set(&[i], r::d, rng.range_i64(-20000, 20000) as i16);
        }
        match threads {
            Some(t) => {
                v.par_for_each_with(t, rec_op);
                // SAFETY: chunk_op touches only its own chunk's records.
                unsafe { v.par_transform_simd_with::<4, _>(t, chunk_op) };
            }
            None => {
                v.for_each(rec_op);
                v.transform_simd::<4>(chunk_op);
            }
        }
        // Route the result through the pooled parallel copy as well.
        let mut copied = alloc_view(AoSoA::<R, _, 8>::new((Dyn(n as u32),)), &HeapAlloc);
        match threads {
            Some(t) => {
                let _ = copy_view_par(&v, &mut copied, t);
            }
            None => field_wise_copy(&v, &mut copied),
        }
        (0..n)
            .flat_map(|i| {
                [
                    copied.get::<f64, _>(&[i], r::a).to_bits(),
                    copied.get::<f32, _>(&[i], r::b).to_bits() as u64,
                    copied.get::<u32, _>(&[i], r::c) as u64,
                    copied.get::<i16, _>(&[i], r::d) as u16 as u64,
                ]
            })
            .collect()
    }

    // Force the pool into existence before snapshotting its stats, so
    // lazy construction is not mistaken for churn.
    let _ = run(16, 1, Some(2));
    let pool = llama::pool::global();
    let workers0 = pool.worker_count();
    let spawned0 = pool.spawned_total();
    let dispatches0 = pool.dispatch_count();
    assert_eq!(spawned0, workers0);

    forall("pooled-reuse", 8, |g| (g.range(2, 140), g.next_u64()), |&(n, seed)| {
        let serial = run(n, seed, None);
        [1usize, 2, 4, 7].iter().all(|&t| run(n, seed, Some(t)) == serial)
    });

    // The load-bearing half: many dispatches later, the original
    // workers are still the only ones that ever existed.
    assert_eq!(pool.spawned_total(), spawned0, "pool respawned workers");
    assert_eq!(pool.worker_count(), workers0);
    assert!(pool.dispatch_count() > dispatches0, "parallel calls bypassed the pool");
}

#[test]
fn prop_coordinator_completes_every_job_exactly_once() {
    // Exactly-once and FIFO-per-batch-key must survive the pooled
    // kernel routing: jobs now lease thread budgets from a shared
    // worker pool (including budgets > 1 on large jobs), and none of
    // that may change completion or dispatch-order semantics.
    use llama::coordinator::{Backend, Config, Coordinator, JobSpec, Layout};
    use llama::pool::WorkerPool;
    use std::sync::Arc;
    forall(
        "coordinator-complete",
        6,
        |g| {
            let workers = g.range(1, 4);
            let max_batch = g.range(1, 6);
            let jobs = g.range(1, 12);
            (workers, max_batch, jobs, g.next_u64())
        },
        |&(workers, max_batch, jobs, seed)| {
            let mut rng = Rng::new(seed);
            let pool = Arc::new(WorkerPool::with_pinning(3, false));
            let mut c = Coordinator::start(Config {
                workers,
                max_batch,
                pool: Some(pool),
                ..Config::default()
            });
            let mut specs = Vec::new();
            for _ in 0..jobs {
                let layout = [Layout::Aos, Layout::SoaMb, Layout::Aosoa][rng.range(0, 2)];
                let backend =
                    [Backend::NativeScalar, Backend::NativeSimd][rng.range(0, 1)];
                // Mix serial, capped, and "whole pool" budget requests.
                let threads = [1usize, 2, 0][rng.range(0, 2)];
                let mut s =
                    JobSpec { id: 0, layout, backend, n: 32, steps: 1, seed: 1, threads };
                s.id = c.submit(s.clone());
                specs.push(s);
            }
            let results = c.finish();
            // exactly once, ids 0..jobs, all succeeded, budgets >= 1
            let mut ids: Vec<u64> = results.iter().map(|x| x.id).collect();
            ids.sort_unstable();
            if ids != (0..jobs as u64).collect::<Vec<_>>()
                || !results.iter().all(|x| x.error.is_none() && x.threads >= 1)
            {
                return false;
            }
            // FIFO per batch key: results are sorted by id, so for jobs
            // sharing a key the dispatcher's batch ids must be
            // non-decreasing in submission order.
            for key in specs.iter().map(|s| s.batch_key()) {
                let batches: Vec<u64> = results
                    .iter()
                    .filter(|r| {
                        specs.iter().any(|s| s.id == r.id && s.batch_key() == key)
                    })
                    .map(|r| r.batch_id)
                    .collect();
                if batches.windows(2).any(|w| w[0] > w[1]) {
                    return false;
                }
            }
            true
        },
    );
}

#[test]
fn prop_heatmap_total_counts_equal_accesses_times_bytes() {
    use llama::mapping::heatmap::Heatmap;
    use llama::mapping::soa::SoA;
    llama::record! { pub struct Q, mod q { v: u32 } }
    forall("heatmap-conservation", 30, |g| (g.range(1, 64), g.range(1, 50)), |&(n, accesses)| {
        let hm = Heatmap::<Q, _, 1>::new(SoA::<Q, _>::new((Dyn(n as u32),)));
        let mut v = alloc_view(hm, &HeapAlloc);
        let mut rng = Rng::new(n as u64);
        for _ in 0..accesses {
            let i = rng.range(0, n - 1);
            let _: u32 = v.get(&[i], q::v);
        }
        // byte-granularity: each u32 access increments exactly 4 counters
        let total: u64 = v.mapping().blob_counts(0).iter().sum();
        total == accesses as u64 * 4
    });
}

#[test]
fn prop_wire_roundtrip_all_mappings_bit_identical() {
    // Transport property: for every mapping and both extent kinds,
    // encode → frame → parse → decode into the same mapping reproduces
    // every field bit for bit, and wherever the mapping reports
    // byte-contiguous runs for all fields the run engine (not the
    // field-wise fallback) carries the transfer.
    use llama::blob::HeapStorage;
    use llama::copy::CopyStrategy;
    use llama::extents::Fix;
    use llama::mapping::aos::{AoS, MinPad, Packed};
    use llama::mapping::aosoa::AoSoA;
    use llama::mapping::bitpack_float::BitpackFloatSoA;
    use llama::mapping::bitpack_int::{BitpackIntSoA, BitpackIntSoADyn};
    use llama::mapping::bytesplit::Bytesplit;
    use llama::mapping::changetype::ChangeType;
    use llama::mapping::field_access_count::FieldAccessCount;
    use llama::mapping::heatmap::Heatmap;
    use llama::mapping::null::NullMapping;
    use llama::mapping::one::One;
    use llama::mapping::soa::{MultiBlob, SingleBlob, SoA};
    use llama::mapping::split::Split;
    use llama::mapping::Mapping;
    use llama::record::RecordDim;
    use llama::transport::{decode_into, encode, WireMsg};
    use llama::view::View;

    // Does `m` report a byte-contiguous run for every (record, field)?
    // If so, falling back to the scalar field-wise copy on either wire
    // direction would be a fast-path regression.
    fn runs_everywhere<Rec: RecordDim, M: Mapping<Rec>>(m: &M, n: usize) -> bool {
        (0..n).all(|lin| (0..Rec::FIELDS.len()).all(|f| m.contiguous_run(lin, f).is_some()))
    }

    // encode → write_to → read_from → decode_into, with the strategy
    // guards on both directions. Value comparison is the caller's.
    fn wire_trip<Rec, M>(
        src: &View<Rec, M, HeapStorage>,
        dst: &mut View<Rec, M, HeapStorage>,
        n: usize,
    ) -> bool
    where
        Rec: RecordDim,
        M: MemoryAccess<Rec>,
    {
        let msg = encode(src);
        if runs_everywhere::<Rec, M>(src.mapping(), n) && msg.strategy == CopyStrategy::FieldWise {
            return false;
        }
        let mut buf = Vec::new();
        msg.write_to(&mut buf).unwrap();
        let parsed = WireMsg::read_from(&mut buf.as_slice()).unwrap();
        if parsed != msg {
            return false;
        }
        let needs_runs = runs_everywhere::<Rec, M>(dst.mapping(), n);
        match decode_into(parsed, dst) {
            Ok(s) => !(needs_runs && s == CopyStrategy::FieldWise),
            Err(e) => panic!("decode_into rejected its own encode: {e}"),
        }
    }

    // The mixed-type record R: fill, round-trip, compare bitwise.
    fn roundtrip<M>(m: M, n: usize, seed: u64) -> bool
    where
        M: MemoryAccess<R> + Clone,
        M::Extents: llama::extents::Extents<ArrayIndex = [usize; 1]>,
    {
        let mut src = alloc_view(m.clone(), &HeapAlloc);
        let mut rng = Rng::new(seed);
        for i in 0..n {
            src.set(&[i], r::a, rng.f64_range(-1e6, 1e6));
            src.set(&[i], r::b, rng.f64_range(-1e3, 1e3) as f32);
            src.set(&[i], r::c, rng.next_u64() as u32);
            src.set(&[i], r::d, rng.range_i64(-30000, 30000) as i16);
        }
        let mut dst = alloc_view(m, &HeapAlloc);
        wire_trip(&src, &mut dst, n)
            && (0..n).all(|i| {
                src.get::<f64, _>(&[i], r::a).to_bits() == dst.get::<f64, _>(&[i], r::a).to_bits()
                    && src.get::<f32, _>(&[i], r::b).to_bits()
                        == dst.get::<f32, _>(&[i], r::b).to_bits()
                    && src.get::<u32, _>(&[i], r::c) == dst.get::<u32, _>(&[i], r::c)
                    && src.get::<i16, _>(&[i], r::d) == dst.get::<i16, _>(&[i], r::d)
            })
    }

    const FIRST: u64 = 0b0001;
    const REST: u64 = 0b1110;

    // Runtime extents: every structural mapping at random sizes.
    forall("wire-roundtrip-dyn", 10, |g| (g.range(1, 64), g.next_u64()), |&(n, seed)| {
        let e = (Dyn(n as u32),);
        let sel = llama::record::Selection::new(0, 1);
        type M1 = SoA<R, (Dyn<u32>,), MultiBlob, llama::extents::RowMajor, FIRST>;
        type M2 = SoA<R, (Dyn<u32>,), MultiBlob, llama::extents::RowMajor, REST>;
        roundtrip(AoS::<R, _>::new(e), n, seed)
            && roundtrip(AoS::<R, _, Packed>::new(e), n, seed)
            && roundtrip(AoS::<R, _, MinPad>::new(e), n, seed)
            && roundtrip(SoA::<R, _, MultiBlob>::new(e), n, seed)
            && roundtrip(SoA::<R, _, SingleBlob>::new(e), n, seed)
            && roundtrip(AoSoA::<R, _, 8>::new(e), n, seed)
            && roundtrip(Bytesplit::<R, _>::new(e), n, seed)
            && roundtrip(ChangeType::<R, R, _>::new(SoA::<R, _>::new(e)), n, seed)
            && roundtrip(Heatmap::<R, _, 8>::new(SoA::<R, _>::new(e)), n, seed)
            && roundtrip(FieldAccessCount::new(AoS::<R, _>::new(e)), n, seed)
            && roundtrip(NullMapping::<R, _>::new(e), n, seed)
            && roundtrip(One::<R, _>::new(e), n, seed)
            && roundtrip(Split::new(M1::new(e), M2::new(e), sel), n, seed)
    });

    // Compile-time extents: the same mappings over `Fix` — the wire
    // header carries extent *values*, so fixed and dynamic views of the
    // same size interoperate.
    forall("wire-roundtrip-fix", 6, |g| g.next_u64(), |&seed| {
        const N: usize = 16;
        let e = (Fix::<u32, N>::new(),);
        let sel = llama::record::Selection::new(0, 1);
        type EF = (Fix<u32, 16>,);
        type M1 = SoA<R, EF, MultiBlob, llama::extents::RowMajor, FIRST>;
        type M2 = SoA<R, EF, MultiBlob, llama::extents::RowMajor, REST>;
        roundtrip(AoS::<R, _>::new(e), N, seed)
            && roundtrip(AoS::<R, _, Packed>::new(e), N, seed)
            && roundtrip(AoS::<R, _, MinPad>::new(e), N, seed)
            && roundtrip(SoA::<R, _, MultiBlob>::new(e), N, seed)
            && roundtrip(SoA::<R, _, SingleBlob>::new(e), N, seed)
            && roundtrip(AoSoA::<R, _, 8>::new(e), N, seed)
            && roundtrip(Bytesplit::<R, _>::new(e), N, seed)
            && roundtrip(ChangeType::<R, R, _>::new(SoA::<R, _>::new(e)), N, seed)
            && roundtrip(Heatmap::<R, _, 8>::new(SoA::<R, _>::new(e)), N, seed)
            && roundtrip(FieldAccessCount::new(AoS::<R, _>::new(e)), N, seed)
            && roundtrip(NullMapping::<R, _>::new(e), N, seed)
            && roundtrip(One::<R, _>::new(e), N, seed)
            && roundtrip(Split::new(M1::new(e), M2::new(e), sel), N, seed)
    });

    // The bit-packed mappings, on their type-suitable records, over both
    // extent kinds. Packed storage is idempotent over its own read-back
    // values, so src-read vs dst-read stays an exact comparison.
    llama::record! { pub struct WF, mod wff { v: f32, w: f32 } }
    llama::record! { pub struct WI, mod wfi { v: u32 } }

    fn roundtrip_f32<M>(m: M, n: usize, seed: u64) -> bool
    where
        M: MemoryAccess<WF> + Clone,
        M::Extents: llama::extents::Extents<ArrayIndex = [usize; 1]>,
    {
        let mut src = alloc_view(m.clone(), &HeapAlloc);
        let mut rng = Rng::new(seed);
        for i in 0..n {
            src.set(&[i], wff::v, rng.f64_range(-1e3, 1e3) as f32);
            src.set(&[i], wff::w, rng.f64_range(-1e3, 1e3) as f32);
        }
        let mut dst = alloc_view(m, &HeapAlloc);
        wire_trip(&src, &mut dst, n)
            && (0..n).all(|i| {
                src.get::<f32, _>(&[i], wff::v).to_bits()
                    == dst.get::<f32, _>(&[i], wff::v).to_bits()
                    && src.get::<f32, _>(&[i], wff::w).to_bits()
                        == dst.get::<f32, _>(&[i], wff::w).to_bits()
            })
    }

    fn roundtrip_u32<M>(m: M, n: usize, seed: u64) -> bool
    where
        M: MemoryAccess<WI> + Clone,
        M::Extents: llama::extents::Extents<ArrayIndex = [usize; 1]>,
    {
        let mut src = alloc_view(m.clone(), &HeapAlloc);
        let mut rng = Rng::new(seed);
        for i in 0..n {
            src.set(&[i], wfi::v, rng.next_u64() as u32);
        }
        let mut dst = alloc_view(m, &HeapAlloc);
        wire_trip(&src, &mut dst, n)
            && (0..n).all(|i| src.get::<u32, _>(&[i], wfi::v) == dst.get::<u32, _>(&[i], wfi::v))
    }

    forall("wire-roundtrip-packed", 8, |g| (g.range(1, 40), g.next_u64()), |&(n, seed)| {
        let ed = (Dyn(n as u32),);
        let ef = (Fix::<u32, 16>::new(),);
        roundtrip_f32(BitpackFloatSoA::<WF, _, 8, 23>::new(ed), n, seed)
            && roundtrip_f32(BitpackFloatSoA::<WF, _, 8, 23>::new(ef), 16, seed)
            && roundtrip_u32(BitpackIntSoA::<WI, _, 12>::new(ed), n, seed)
            && roundtrip_u32(BitpackIntSoA::<WI, _, 12>::new(ef), 16, seed)
            && roundtrip_u32(BitpackIntSoADyn::<WI, _>::new(ed, 17), n, seed)
            && roundtrip_u32(BitpackIntSoADyn::<WI, _>::new(ef, 17), 16, seed)
    });
}

#[test]
fn prop_wire_frames_reject_truncation_and_corruption() {
    // Hardening property for the checksummed v2 frames: a hostile or
    // fault-injected byte stream must never panic the parser, never
    // make it allocate past its documented cap, and never decode
    // silently wrong data — truncations and garbage are typed
    // `io::Error`s, and any bit flip that leaves the framing intact is
    // caught by the CRC (`WireError::Corrupt`) *before* decode.
    use llama::mapping::soa::SoA;
    use llama::transport::{encode, wire_error_in, WireError, WireMsg};

    // A valid frame to mutilate.
    let n = 8usize;
    let mut src = alloc_view(SoA::<R, _>::new((Dyn(n as u32),)), &HeapAlloc);
    let mut rng = Rng::new(0xFEED_FACE);
    for i in 0..n {
        src.set(&[i], r::a, rng.f64_range(-1e6, 1e6));
        src.set(&[i], r::b, rng.f64_range(-1e3, 1e3) as f32);
        src.set(&[i], r::c, rng.next_u64() as u32);
        src.set(&[i], r::d, rng.range_i64(-30000, 30000) as i16);
    }
    let msg = encode(&src);
    let mut frame = Vec::new();
    msg.write_to(&mut frame).unwrap();

    // Every proper prefix is a clean error — a peer dying mid-frame at
    // any byte boundary must surface as a parse failure, not a panic,
    // a hang, or a half-decoded message.
    for k in 0..frame.len() {
        assert!(
            WireMsg::read_from(&mut &frame[..k]).is_err(),
            "a {k}-byte prefix of a {}-byte frame parsed",
            frame.len()
        );
    }
    // ...while the untouched frame still parses to the same message.
    assert_eq!(WireMsg::read_from(&mut frame.as_slice()).unwrap(), msg);

    // Every single-bit flip anywhere in the frame is rejected, and
    // flips that leave the framing intact (the payload bytes — exactly
    // what a faulty link corrupts without changing lengths) are caught
    // by the checksum specifically.
    let payload_region = (frame.len() - 4 - msg.payload.len())..(frame.len() - 4);
    for pos in 0..frame.len() {
        for bit in 0..8 {
            let mut bad = frame.clone();
            bad[pos] ^= 1 << bit;
            let err = match WireMsg::read_from(&mut bad.as_slice()) {
                Err(e) => e,
                Ok(_) => panic!("bit {bit} of byte {pos} flipped, frame still parsed"),
            };
            if payload_region.contains(&pos) {
                assert!(
                    matches!(wire_error_in(&err), Some(WireError::Corrupt { .. })),
                    "payload flip at byte {pos} bit {bit} not caught by crc: {err}"
                );
            }
        }
    }

    // Seeded heavier corruptions: overwrite a random byte with a random
    // value — identity overwrites must still parse, real changes must
    // not (any one-byte change breaks either the framing or the crc).
    forall(
        "wire-corrupt-byte",
        200,
        |g| (g.range(0, frame.len() - 1), g.next_u64() as u8),
        |&(pos, val)| {
            let mut bad = frame.clone();
            bad[pos] = val;
            let parsed = WireMsg::read_from(&mut bad.as_slice());
            if val == frame[pos] { parsed.is_ok() } else { parsed.is_err() }
        },
    );

    // Pure garbage (no valid magic, random lengths): always a clean
    // error. The parser's allocation is bounded by its 1 MiB header cap
    // no matter what the length fields claim, so a short hostile buffer
    // can't balloon memory either — checked directly in
    // `transport::tests::garbage_blob_len_fails_without_huge_allocation`.
    forall(
        "wire-garbage",
        64,
        |g| {
            let len = g.range(0, 96);
            (0..len).map(|_| g.next_u64() as u8).collect::<Vec<u8>>()
        },
        |garbage| WireMsg::read_from(&mut garbage.as_slice()).is_err(),
    );
}
