//! False-sharing regression tests (E13): pin the `CachePadded` layout
//! guarantees and the "padded is never slower than contended" property
//! so the audit's fixes (pool lease word, `FieldAccessCount` per-field
//! counters) cannot silently regress.
//!
//! The timing half is deliberately tolerant — CI machines are noisy,
//! so it asserts `padded <= contended * 1.5` on the min-of-5 (a real
//! regression, i.e. padding *removed*, shows up as 2–10× at 4 threads),
//! not a tight ratio. The layout half is exact and runs everywhere
//! including Miri.

use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::time::{Duration, Instant};

use llama::pool::WorkerPool;
use llama::util::{CachePadded, CACHE_LINE};

#[test]
fn padded_layout_guarantees_hold() {
    // The regression the test guards: someone "simplifying" the padding
    // away. align/size must both be at least a full line.
    assert!(std::mem::align_of::<CachePadded<AtomicU64>>() >= CACHE_LINE);
    assert!(std::mem::size_of::<CachePadded<AtomicU64>>() >= CACHE_LINE);
    assert!(std::mem::align_of::<CachePadded<AtomicUsize>>() >= CACHE_LINE);
    assert!(std::mem::size_of::<CachePadded<AtomicUsize>>() >= CACHE_LINE);
    assert_eq!(CACHE_LINE, 64);

    // Adjacent padded counters in a Vec land on distinct lines — the
    // exact property the pool/instrumentation fixes rely on.
    let v: Vec<CachePadded<AtomicU64>> =
        (0..8).map(|_| CachePadded::new(AtomicU64::new(0))).collect();
    for pair in v.windows(2) {
        let a = &*pair[0] as *const AtomicU64 as usize;
        let b = &*pair[1] as *const AtomicU64 as usize;
        assert_ne!(a / CACHE_LINE, b / CACHE_LINE, "padded neighbors share a cache line");
    }
}

#[test]
fn padded_counters_count_correctly_under_contention() {
    // Correctness before speed: padding must not change the tallies.
    let threads = 4;
    let iters = 10_000u64;
    let pool = WorkerPool::with_pinning(threads, false);
    let slots: Vec<CachePadded<AtomicU64>> =
        (0..threads).map(|_| CachePadded::new(AtomicU64::new(0))).collect();
    pool.run_scoped(
        (0..threads)
            .map(|k| {
                let slot = &slots[k];
                move || {
                    for _ in 0..iters {
                        slot.fetch_add(1, Ordering::Relaxed);
                    }
                }
            })
            .collect::<Vec<_>>(),
    );
    assert!(slots.iter().all(|s| s.load(Ordering::Relaxed) == iters));
}

/// Time `threads` workers doing `iters` increments on their own slot,
/// with `stride`-spaced counters; min of `reps` runs.
fn time_increments(
    pool: &WorkerPool,
    threads: usize,
    iters: u64,
    reps: usize,
    padded: bool,
) -> Duration {
    let contended: Vec<AtomicU64> = (0..threads).map(|_| AtomicU64::new(0)).collect();
    let spaced: Vec<CachePadded<AtomicU64>> =
        (0..threads).map(|_| CachePadded::new(AtomicU64::new(0))).collect();
    let mut best = Duration::MAX;
    for _ in 0..reps {
        let start = Instant::now();
        pool.run_scoped(
            (0..threads)
                .map(|k| {
                    let contended = &contended[k];
                    let spaced = &spaced[k];
                    move || {
                        // One branch outside the hot loop, same loop body
                        // either way: the *only* difference is placement.
                        if padded {
                            for _ in 0..iters {
                                spaced.fetch_add(1, Ordering::Relaxed);
                            }
                        } else {
                            for _ in 0..iters {
                                contended.fetch_add(1, Ordering::Relaxed);
                            }
                        }
                    }
                })
                .collect::<Vec<_>>(),
        );
        best = best.min(start.elapsed());
    }
    best
}

#[test]
#[cfg_attr(miri, ignore)] // timing under the interpreter means nothing
fn padded_never_slower_than_contended() {
    let threads = 4;
    let iters = 200_000u64;
    let reps = 5;
    let pool = WorkerPool::with_pinning(threads, false);

    let contended = time_increments(&pool, threads, iters, reps, false);
    let padded = time_increments(&pool, threads, iters, reps, true);

    println!(
        "contended min {contended:?} vs padded min {padded:?} \
         ({threads} threads x {iters} increments)"
    );
    // Headroom of 1.5x for runner noise and single-core machines (where
    // the two variants legitimately tie): a padding regression at >= 2
    // real cores costs 2-10x, far outside this band.
    assert!(
        padded <= contended.mul_f64(1.5),
        "padded counters slower than contended: {padded:?} vs {contended:?}"
    );
}
