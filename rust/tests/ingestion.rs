//! Ingestion stress tests: the coordinator's bounded, quota-aware
//! admission path under many concurrent producers.
//!
//! Checks the invariants stated in the `coordinator` module docs:
//! every admitted job completes exactly once, the queue never exceeds
//! its configured capacity (bounded memory), same-key jobs dispatch in
//! FIFO order, and nothing deadlocks with the job count far above the
//! queue capacity.

use std::collections::HashSet;
use std::time::Duration;

use llama::coordinator::{Admission, Backend, Config, Coordinator, JobSpec, Layout, SubmitError};

/// The smallest useful job: 4 particles, 1 step, scalar backend, single
/// thread — admission overhead dominates, which is the point.
fn tiny_spec() -> JobSpec {
    JobSpec {
        id: 0,
        layout: Layout::Aos,
        backend: Backend::NativeScalar,
        n: 4,
        steps: 1,
        seed: 1,
        threads: 1,
    }
}

#[test]
fn stress_thousand_concurrent_jobs_bounded_queue() {
    const SUBMITTERS: usize = 4;
    const PER: usize = 256; // 1024 jobs total
    const CAPACITY: usize = 8; // ≪ job count: admission must recycle slots
    let c = Coordinator::start(Config {
        workers: 2,
        max_batch: 8,
        queue_capacity: CAPACITY,
        ..Config::default()
    });

    let handles: Vec<_> = (0..SUBMITTERS)
        .map(|_| {
            let ing = c.ingest();
            std::thread::spawn(move || {
                let mut ids = Vec::with_capacity(PER);
                for k in 0..PER {
                    let id = if k % 2 == 0 {
                        // Blocking admission: waits out full-queue phases.
                        ing.submit_with(tiny_spec(), Admission::Block { deadline: None })
                            .expect("queue closed under a live coordinator")
                    } else {
                        // Fail-fast admission: honor the retry-after hint
                        // (capped so the stress run stays fast).
                        loop {
                            match ing.submit_with(tiny_spec(), Admission::Reject) {
                                Ok(id) => break id,
                                Err(SubmitError::QueueFull { retry_after }) => {
                                    std::thread::sleep(
                                        retry_after.min(Duration::from_millis(1)),
                                    );
                                }
                                Err(e) => panic!("unexpected admission failure: {e:?}"),
                            }
                        }
                    };
                    ids.push(id);
                }
                ids
            })
        })
        .collect();
    let per_thread: Vec<Vec<u64>> = handles.into_iter().map(|h| h.join().unwrap()).collect();

    // Bounded memory: the exact high-water mark never exceeded capacity.
    let ing = c.ingest();
    assert!(
        ing.max_queue_depth() <= CAPACITY,
        "queue depth peaked at {} > capacity {CAPACITY}",
        ing.max_queue_depth()
    );

    // Ids are handed out in admission order, so each producer thread saw
    // a strictly increasing sequence (FIFO admission per producer).
    for ids in &per_thread {
        assert!(ids.windows(2).all(|w| w[0] < w[1]), "ids not monotone per producer");
    }

    let total = SUBMITTERS * PER;
    assert_eq!(c.metrics().job_counts().0, total as u64);

    // Exactly-once: every admitted job yields exactly one result.
    let results = c.finish();
    assert_eq!(results.len(), total);
    let unique: HashSet<u64> = results.iter().map(|r| r.id).collect();
    assert_eq!(unique.len(), total, "duplicate job ids in results");
    for r in &results {
        assert!(r.error.is_none(), "job {} failed: {:?}", r.id, r.error);
    }

    // FIFO-per-key: every job shares one batch key here, and `finish`
    // sorts by id (= admission order), so batch ids must be
    // non-decreasing — a later-admitted job can never land in an
    // earlier batch.
    assert!(
        results.windows(2).all(|w| w[0].batch_id <= w[1].batch_id),
        "same-key jobs dispatched out of FIFO order"
    );
}

#[test]
fn submits_after_finish_fail_closed() {
    let mut c = Coordinator::start(Config {
        workers: 1,
        max_batch: 2,
        queue_capacity: 2,
        ..Config::default()
    });
    let ing = c.ingest();
    c.submit(tiny_spec());
    let results = c.finish();
    assert_eq!(results.len(), 1);

    // Every admission flavor reports the closed queue, including a
    // blocking submit with a deadline (it must not wait it out).
    assert!(matches!(ing.submit(tiny_spec()), Err(SubmitError::Closed)));
    assert!(matches!(ing.submit_with(tiny_spec(), Admission::Reject), Err(SubmitError::Closed)));
    assert!(matches!(
        ing.submit_with(
            tiny_spec(),
            Admission::Block { deadline: Some(Duration::from_millis(5)) }
        ),
        Err(SubmitError::Closed)
    ));
}

#[test]
fn reject_and_quota_accounting_is_conserved() {
    const ATTEMPTS: usize = 200;
    const CLIENTS: usize = 2;
    let c = Coordinator::start(Config {
        workers: 1,
        max_batch: 4,
        queue_capacity: 2,
        client_quota: 1,
        ..Config::default()
    });

    // Two clients hammer a tiny queue with fail-fast submits under a
    // one-slot quota. Whether any given attempt is admitted is timing
    // dependent; the accounting identity is not: every attempt either
    // admits or lands in exactly one reject counter.
    let handles: Vec<_> = (0..CLIENTS)
        .map(|client| {
            let ing = c.ingest();
            std::thread::spawn(move || {
                let mut admitted = 0u64;
                for _ in 0..ATTEMPTS {
                    match ing.submit_from(client as u64, tiny_spec(), Admission::Reject) {
                        Ok(_) => admitted += 1,
                        Err(SubmitError::QueueFull { .. })
                        | Err(SubmitError::QuotaExceeded { .. }) => {}
                        Err(e) => panic!("unexpected admission failure: {e:?}"),
                    }
                }
                admitted
            })
        })
        .collect();
    let admitted: u64 = handles.into_iter().map(|h| h.join().unwrap()).sum();

    let attempts = (CLIENTS * ATTEMPTS) as u64;
    assert_eq!(c.metrics().job_counts().0, admitted);
    assert_eq!(admitted + c.metrics().rejected_total(), attempts);

    let ing = c.ingest();
    let results = c.finish();
    assert_eq!(results.len(), admitted as usize);
    assert_eq!(ing.queue_depth(), 0);
}
