//! Integration tests for the hardware-counter measurement mode
//! (`llama::counters` + its `llama::bench` wiring), exercising the
//! guarantees the E13/counter-mode work promises:
//!
//! - degradation is *graceful and typed*: `LLAMA_COUNTERS=off` and a
//!   simulated `Denied` both keep every bench working, and JSON rows
//!   **omit** the `counters` object rather than emitting zeros;
//! - when counters are live, two identical single-threaded runs of a
//!   fixed kernel agree on retired instructions within 1% — the
//!   determinism wall-clock sampling cannot offer.
//!
//! The live-path tests skip (with a printed reason) on machines where
//! `perf_event_open` is refused — CI asserts the *fallback*, not the
//! numbers.

use llama::bench::{black_box, emit_json_to, Bencher};
use llama::counters::{self, CounterError, CounterGroup, CounterMode, Counters};

/// The fixed-work kernel for determinism checks: branch-free integer
/// arithmetic, no allocation, no syscalls — its retired-instruction
/// count is a property of the code, not the machine's mood.
fn fixed_kernel(n: u64) -> u64 {
    let mut acc = 0x9e37_79b9_7f4a_7c15u64;
    for i in 0..n {
        acc = black_box(acc.rotate_left(7).wrapping_mul(i | 1));
    }
    acc
}

#[test]
fn forced_off_is_typed_and_total() {
    // The explicit-mode constructor bypasses the environment, so this
    // holds on every machine, every platform, and under Miri.
    match CounterGroup::open_with(CounterMode::Off) {
        Err(CounterError::Off) => {}
        other => panic!("forced-off open must yield CounterError::Off, got {other:?}"),
    }
}

#[test]
fn env_off_degrades_the_whole_process() {
    // `mode()` caches the env var process-wide, so flipping it needs a
    // child process, not setenv in this multithreaded harness: re-exec
    // this same test binary filtered to the child fn below.
    if std::env::var_os("LLAMA_COUNTERS_CHILD").is_some() {
        return; // we *are* the child; the child fn does the asserting
    }
    let exe = std::env::current_exe().expect("test binary path");
    let out = std::process::Command::new(exe)
        .args(["--exact", "child_env_off_body", "--nocapture"])
        .env("LLAMA_COUNTERS", "off")
        .env("LLAMA_COUNTERS_CHILD", "1")
        .output()
        .expect("spawning child test process");
    assert!(
        out.status.success(),
        "child with LLAMA_COUNTERS=off failed:\n{}\n{}",
        String::from_utf8_lossy(&out.stdout),
        String::from_utf8_lossy(&out.stderr),
    );
    let stdout = String::from_utf8_lossy(&out.stdout);
    assert!(
        stdout.contains("child saw counters off"),
        "child ran but never hit its assertions:\n{stdout}"
    );
}

/// Body of the `env_off` child: only meaningful with
/// `LLAMA_COUNTERS=off` in the environment (the parent sets it).
#[test]
fn child_env_off_body() {
    if std::env::var_os("LLAMA_COUNTERS_CHILD").is_none() {
        return; // running as part of the normal suite: nothing to do
    }
    assert_eq!(counters::mode(), CounterMode::Off);
    assert_eq!(CounterGroup::open().unwrap_err(), CounterError::Off);
    assert_eq!(counters::meta_tag(), "off");
    assert!(counters::status_line().contains("off"));
    // The bench harness keeps working and its rows carry no counters.
    let mut b = Bencher::new(0, 2);
    b.bench("row", 100, || {
        black_box(fixed_kernel(100));
    });
    assert!(b.results().iter().all(|m| m.counters.is_none()));
    println!("child saw counters off");
}

#[test]
fn denied_rows_omit_counters_in_json() {
    // Simulated kernel refusal: the Bencher is constructed as if
    // perf_event_open had returned EACCES. Rows must omit the object —
    // a consumer must never mistake "unmeasured" for "zero".
    let dir = std::env::temp_dir().join(format!("llama-cnt-denied-{}", std::process::id()));
    let mut b = Bencher::with_counter_error(0, 3, CounterError::Denied);
    b.bench("kernel", 500, || {
        black_box(fixed_kernel(500));
    });
    assert!(!b.counters_live());
    assert!(b.results().iter().all(|m| m.counters.is_none()));

    let path = emit_json_to(&dir, "cnt_denied", &[], &[("g", &b)]).expect("emit json");
    let text = std::fs::read_to_string(&path).unwrap();
    let _ = std::fs::remove_file(&path);
    let _ = std::fs::remove_dir(&dir);
    assert!(text.contains("\"schema\": 2"));
    assert!(text.contains("\"median_ns\""), "wall-clock fields still present");
    assert!(!text.contains("counters"), "denied run leaked a counters key:\n{text}");
}

/// Open a live group or skip the calling test with a printed reason.
fn live_group_or_skip(test: &str) -> Option<CounterGroup> {
    match CounterGroup::open_with(CounterMode::Auto) {
        Ok(g) => Some(g),
        Err(e) => {
            println!("{test}: skipping, counters unavailable here ({e})");
            None
        }
    }
}

#[test]
fn live_measure_yields_plausible_counts() {
    let Some(group) = live_group_or_skip("live_measure_yields_plausible_counts") else {
        return;
    };
    let (out, c) = match group.measure(|| fixed_kernel(50_000)) {
        Ok(r) => r,
        Err(e) => {
            println!("measure failed mid-flight ({e}); treating as unavailable");
            return;
        }
    };
    black_box(out);
    // 50k loop iterations retire at least one instruction each; an idle
    // single group should also actually get PMU time.
    assert!(c.instructions >= 50_000, "implausibly few instructions: {c:?}");
    assert!(c.cycles > 0, "zero cycles: {c:?}");
    assert!(c.time_enabled_ns > 0 && c.time_running_ns > 0, "no PMU time: {c:?}");
    assert!(c.time_running_ns <= c.time_enabled_ns, "running exceeds enabled: {c:?}");
    assert!(c.instructions_per_item(50_000) >= 1.0);
}

#[test]
fn live_instruction_counts_are_deterministic_within_1pct() {
    // The headline property (ISSUE acceptance): two identical
    // single-threaded runs of a fixed-seed kernel agree on retired
    // instructions within 1%. Wall clock on a noisy runner cannot do
    // this; instruction counts can, because the kernel executes the
    // same instruction stream both times.
    let Some(group) = live_group_or_skip("live_instruction_counts_are_deterministic_within_1pct")
    else {
        return;
    };
    let run = |g: &CounterGroup| -> Option<Counters> {
        match g.measure(|| fixed_kernel(200_000)) {
            Ok((out, c)) => {
                black_box(out);
                Some(c)
            }
            Err(e) => {
                println!("measure failed mid-flight ({e}); treating as unavailable");
                None
            }
        }
    };
    // Warm once (first-run effects: page faults on the code path).
    let _ = run(&group);
    let (Some(a), Some(b)) = (run(&group), run(&group)) else { return };
    if a.multiplexed || b.multiplexed {
        // Extrapolated counts are estimates; the determinism claim is
        // only made for unshared PMU time.
        println!("skipping: PMU multiplexed during the runs");
        return;
    }
    let (lo, hi) = (a.instructions.min(b.instructions), a.instructions.max(b.instructions));
    assert!(lo > 0);
    let rel = (hi - lo) as f64 / hi as f64;
    assert!(
        rel <= 0.01,
        "instruction counts diverged by {:.3}% ({} vs {})",
        rel * 100.0,
        a.instructions,
        b.instructions
    );
}

#[test]
fn live_rows_carry_counters_in_json() {
    // End-to-end through the bench harness: when this machine has live
    // counters, emitted rows carry the object with all five events.
    if let Err(e) = counters::available() {
        println!("live_rows_carry_counters_in_json: skipping ({e})");
        return;
    }
    let dir = std::env::temp_dir().join(format!("llama-cnt-live-{}", std::process::id()));
    let mut b = Bencher::new(1, 2);
    b.bench("kernel", 10_000, || {
        black_box(fixed_kernel(10_000));
    });
    let m = &b.results()[0];
    let Some(c) = &m.counters else {
        // Probe said live but the Bencher's own group failed (e.g. fd
        // limit): still a graceful path, with a typed reason.
        let err = b.counter_error().expect("counter-less row needs a reason");
        println!("live probe but bencher degraded ({err}); accepting fallback");
        return;
    };
    assert!(c.instructions > 0);
    let path = emit_json_to(&dir, "cnt_live", &[], &[("g", &b)]).expect("emit json");
    let text = std::fs::read_to_string(&path).unwrap();
    let _ = std::fs::remove_file(&path);
    let _ = std::fs::remove_dir(&dir);
    for key in counters::event_names() {
        assert!(text.contains(&format!("\"{key}\"")), "missing {key} in:\n{text}");
    }
    assert!(text.contains("\"multiplexed\""));
}
