//! Cross-module integration tests: views × mappings × copy ×
//! instrumentation × SIMD × coordinator, exercised together the way a
//! downstream user would.

use llama::blob::{alloc_view, array_view, AlignedAlloc, BlobStorage, HeapAlloc};
use llama::copy::{copy_view, CopyStrategy};
use llama::extents::{Dyn, Fix};
use llama::mapping::aos::{AoS, MinPad, Packed};
use llama::mapping::aosoa::AoSoA;
use llama::mapping::bitpack_float::BitpackFloatSoA;
use llama::mapping::bitpack_int::BitpackIntSoA;
use llama::mapping::bytesplit::Bytesplit;
use llama::mapping::changetype::ChangeType;
use llama::mapping::field_access_count::FieldAccessCount;
use llama::mapping::heatmap::Heatmap;
use llama::mapping::null::NullMapping;
use llama::mapping::soa::{MultiBlob, SingleBlob, SoA};
use llama::mapping::MemoryAccess;
use llama::mapping::split::Split;
use llama::record::{Bf16, RecordDim};
use llama::simd::Simd;
use llama::view::View;

llama::record! {
    /// HEP-ish event record with two nesting levels.
    pub struct Event, mod ev {
        hit: { pos: { x: f64, y: f64 }, adc: u32 },
        time: u64,
        good: bool,
    }
}

fn fill_event<M: MemoryAccess<Event>, S: BlobStorage>(v: &mut View<Event, M, S>, n: usize) {
    for i in 0..n {
        v.set(&[i], ev::hit::pos::x, i as f64 * 1.5);
        v.set(&[i], ev::hit::pos::y, -(i as f64));
        v.set(&[i], ev::hit::adc, (i * 3) as u32);
        v.set(&[i], ev::time, (i * 100) as u64);
        v.set(&[i], ev::good, i % 3 == 0);
    }
}

fn check_event<M: MemoryAccess<Event>, S: BlobStorage>(v: &View<Event, M, S>, n: usize) {
    for i in 0..n {
        assert_eq!(v.get::<f64, _>(&[i], ev::hit::pos::x), i as f64 * 1.5);
        assert_eq!(v.get::<f64, _>(&[i], ev::hit::pos::y), -(i as f64));
        assert_eq!(v.get::<u32, _>(&[i], ev::hit::adc), (i * 3) as u32);
        assert_eq!(v.get::<u64, _>(&[i], ev::time), (i * 100) as u64);
        assert_eq!(v.get::<bool, _>(&[i], ev::good), i % 3 == 0);
    }
}

#[test]
fn two_level_nesting_flattens_correctly() {
    assert_eq!(<Event as RecordDim>::FIELD_COUNT, 5);
    assert_eq!(ev::hit::pos::x.i(), 0);
    assert_eq!(ev::hit::adc.i(), 2);
    assert_eq!(ev::time.i(), 3);
    assert_eq!(ev::hit.start(), 0);
    assert_eq!(ev::hit.len(), 3);
}

#[test]
fn typed_tags_navigate_two_level_nesting() {
    use llama::record::FieldTag;
    let e = (Dyn(4u32),);
    let mut v = alloc_view(SoA::<Event, _>::new(e), &HeapAlloc);
    v.set_t([1], ev::hit::pos::y, -2.0);
    v.set_t([1], ev::good, true);
    // Element types are inferred from the tags at any nesting depth.
    let y: f64 = v.get_t([1], ev::hit::pos::y);
    assert_eq!(y, -2.0);
    assert!(v.get_t([1], ev::good));
    // Typed sub-record projection spans the nested group.
    let r = v.at_t([1]);
    let hit = r.sub(ev::hit);
    assert_eq!(hit.selection(), llama::record::Selection::new(0, 3));
    assert_eq!(hit.field(ev::hit::pos::y), -2.0);
    assert_eq!(hit.read_f64(), vec![0.0, -2.0, 0.0]);
    // Tag metadata is compile-time constant.
    fn index_of<F: FieldTag>(_: F) -> usize {
        F::INDEX
    }
    assert_eq!(index_of(ev::time), 3);
}

#[test]
fn every_physical_mapping_roundtrips() {
    const N: usize = 37; // deliberately not a multiple of any lane count
    let e = (Dyn(N as u32),);
    macro_rules! roundtrip {
        ($m:expr) => {{
            let mut v = alloc_view($m, &HeapAlloc);
            fill_event(&mut v, N);
            check_event(&v, N);
        }};
    }
    roundtrip!(AoS::<Event, _>::new(e));
    roundtrip!(AoS::<Event, _, Packed>::new(e));
    roundtrip!(AoS::<Event, _, MinPad>::new(e));
    roundtrip!(SoA::<Event, _, MultiBlob>::new(e));
    roundtrip!(SoA::<Event, _, SingleBlob>::new(e));
    roundtrip!(AoSoA::<Event, _, 4>::new(e));
    roundtrip!(AoSoA::<Event, _, 16>::new(e));
    roundtrip!(Bytesplit::<Event, _>::new(e));
}

#[test]
fn every_mapping_pair_copies() {
    const N: usize = 24;
    let e = (Dyn(N as u32),);

    let mut src = alloc_view(AoS::<Event, _>::new(e), &HeapAlloc);
    fill_event(&mut src, N);

    let mut soa = alloc_view(SoA::<Event, _>::new(e), &HeapAlloc);
    let mut aosoa = alloc_view(AoSoA::<Event, _, 8>::new(e), &HeapAlloc);
    let mut bsplit = alloc_view(Bytesplit::<Event, _>::new(e), &HeapAlloc);

    copy_view(&src, &mut soa);
    copy_view(&soa, &mut aosoa);
    copy_view(&aosoa, &mut bsplit);
    check_event(&bsplit, N);

    // identical-layout fast path
    let mut aos2 = alloc_view(AoS::<Event, _>::new(e), &HeapAlloc);
    assert_eq!(copy_view(&src, &mut aos2), CopyStrategy::BlobMemcpy);
    check_event(&aos2, N);
}

#[test]
fn instrumentation_wraps_any_inner_mapping() {
    const N: usize = 16;
    let e = (Dyn(N as u32),);

    // FieldAccessCount over a *computed* mapping (bitpack).
    llama::record! { pub struct Ints, mod ints { a: u32, b: i64 } }
    let fac = FieldAccessCount::new(BitpackIntSoA::<Ints, _, 20>::new(e));
    let mut v = alloc_view(fac, &HeapAlloc);
    v.set(&[3], ints::a, 12345u32);
    let _: u32 = v.get(&[3], ints::a);
    let (r, w) = v.mapping().field_counts(ints::a);
    assert_eq!((r, w), (1, 1));
    assert_eq!(v.get::<u32, _>(&[3], ints::a), 12345);

    // Heatmap over AoSoA (physical), cache-line granularity.
    let hm = Heatmap::<Event, _, 64>::new(AoSoA::<Event, _, 8>::new(e));
    let mut v = alloc_view(hm, &HeapAlloc);
    fill_event(&mut v, N);
    check_event(&v, N);
    let total: u64 = v.mapping().blob_counts(0).iter().sum();
    assert!(total > 0);
}

#[test]
fn changetype_over_bitpack_composes() {
    // f64 algorithm type -> f32 storage record -> 16-bit packed floats.
    llama::record! { pub struct Wide, mod wide { v: f64 } }
    llama::record! { pub struct Narrow, mod _narrow { v: f32 } }
    let inner = BitpackFloatSoA::<Narrow, _, 8, 7>::new((Dyn(32u32),));
    let ct = ChangeType::<Wide, Narrow, _>::new(inner);
    let mut v = alloc_view(ct, &HeapAlloc);
    v.set(&[5], wide::v, 1.5f64);
    assert_eq!(v.get::<f64, _>(&[5], wide::v), 1.5);
    // 16 bits per value + slack
    assert_eq!(v.storage().total_bytes(), 32 * 2 + 8);
}

#[test]
fn split_null_cache_pattern() {
    // §3: cache only hit.pos physically, discard the rest.
    const SEL: u64 = 0b00011; // pos.x, pos.y
    let e = (Dyn(8u32),);
    type Hot = SoA<Event, (Dyn<u32>,), MultiBlob, llama::extents::RowMajor, SEL>;
    let split = Split::new(
        Hot::new(e),
        NullMapping::<Event, _>::new(e),
        llama::record::Selection::new(0, 2),
    );
    let mut v = alloc_view(split, &HeapAlloc);
    v.set(&[1], ev::hit::pos::x, 9.0f64);
    v.set(&[1], ev::time, 7u64);
    assert_eq!(v.get::<f64, _>(&[1], ev::hit::pos::x), 9.0);
    assert_eq!(v.get::<u64, _>(&[1], ev::time), 0); // discarded
    assert_eq!(v.storage().total_bytes(), 2 * 8 * 8);
}

#[test]
fn zero_overhead_static_view_is_trivially_copyable() {
    llama::record! { pub struct V3, mod v3 { x: f32, y: f32, z: f32 } }
    type E = (Fix<u16, 16>,);
    type M = SoA<V3, E, SingleBlob>;
    assert_eq!(std::mem::size_of::<M>(), 0); // stateless mapping (§2)
    let view = array_view::<V3, M, { 16 * 12 }, 1>(M::new((Fix::new(),)));
    assert_eq!(std::mem::size_of_val(&view), 16 * 12);

    // memcpy-ability: plain bitwise copy carries the data.
    let mut a = view;
    a.set(&[3], v3::y, 8.5f32);
    let b = a; // Copy
    assert_eq!(b.get::<f32, _>(&[3], v3::y), 8.5);
}

#[test]
fn simd_roundtrip_through_all_simd_layouts() {
    llama::record! { pub struct P, mod p { a: f32, b: f64 } }
    const N: usize = 32;
    let e = (Dyn(N as u32),);

    macro_rules! simd_check {
        ($m:expr) => {{
            let mut v = alloc_view($m, &AlignedAlloc::<64>);
            for i in 0..N {
                v.set(&[i], p::a, i as f32);
            }
            let s: Simd<f32, 8> = v.load_simd(&[8], p::a);
            assert_eq!(s.0, [8., 9., 10., 11., 12., 13., 14., 15.]);
            v.store_simd(&[16], p::a, s + Simd::splat(100.0));
            assert_eq!(v.get::<f32, _>(&[17], p::a), 109.0);
        }};
    }
    simd_check!(AoS::<P, _>::new(e));
    simd_check!(SoA::<P, _>::new(e));
    simd_check!(AoSoA::<P, _, 8>::new(e));
}

#[test]
fn coordinator_runs_mixed_native_jobs() {
    use llama::coordinator::{Backend, Config, Coordinator, JobSpec, Layout};
    let mut c =
        Coordinator::start(Config { workers: 3, max_batch: 4, ..Config::default() });
    let mut expected = 0;
    for layout in [Layout::Aos, Layout::SoaMb, Layout::Aosoa] {
        for backend in [Backend::NativeScalar, Backend::NativeSimd] {
            c.submit(JobSpec { id: 0, layout, backend, n: 128, steps: 2, seed: 5, threads: 0 });
            expected += 1;
        }
    }
    let results = c.finish();
    assert_eq!(results.len(), expected);
    for r in &results {
        assert!(r.error.is_none());
        assert!(r.energy_drift.is_finite() && r.energy_drift < 1e-2);
    }
}

#[test]
fn morton_layout_roundtrips_2d() {
    use llama::extents::Morton;
    llama::record! { pub struct Cell, mod cell { v: f32 } }
    let e = (Dyn(16u32), Dyn(16u32));
    let m = SoA::<Cell, _, MultiBlob, Morton>::new(e);
    let mut v = alloc_view(m, &HeapAlloc);
    for i in 0..16usize {
        for j in 0..16usize {
            v.set(&[i, j], cell::v, (i * 16 + j) as f32);
        }
    }
    for i in 0..16usize {
        for j in 0..16usize {
            assert_eq!(v.get::<f32, _>(&[i, j], cell::v), (i * 16 + j) as f32);
        }
    }
}

#[test]
fn one_mapping_broadcast_with_nbody_record() {
    use llama::mapping::one::One;
    use llama::nbody::{particle, Particle};
    let mut v = alloc_view(One::<Particle, _>::new((Dyn(64u32),)), &HeapAlloc);
    v.set(&[0], particle::mass, 2.5f32);
    assert_eq!(v.get::<f32, _>(&[63], particle::mass), 2.5);
    assert_eq!(v.storage().total_bytes(), <Particle as RecordDim>::PACKED_SIZE);
}

#[test]
fn bf16_scalars_in_records() {
    llama::record! { pub struct Half, mod half { v: Bf16 } }
    let mut v = alloc_view(SoA::<Half, _>::new((Dyn(4u32),)), &HeapAlloc);
    v.set(&[0], half::v, Bf16::from_f32(1.5));
    assert_eq!(v.get::<Bf16, _>(&[0], half::v).to_f32(), 1.5);
}

#[test]
fn instrumented_nbody_matches_uninstrumented() {
    use llama::nbody::{init_particles, views, Particle};
    let init = init_particles(64, 3);
    let mut plain = views::make_soa_view(&init);
    let fac = FieldAccessCount::new(views::SoaMbMap::new((Dyn(64u32),)));
    let mut traced = alloc_view(fac, &HeapAlloc);
    views::fill_view(&mut traced, &init);
    for _ in 0..2 {
        views::update_scalar(&mut plain);
        views::move_scalar(&mut plain);
        views::update_scalar(&mut traced);
        views::move_scalar(&mut traced);
    }
    let a = views::snapshot_view(&plain);
    let b = views::snapshot_view(&traced);
    assert_eq!(llama::nbody::max_pos_delta(&a, &b), 0.0);
    // and the counts line up with the algorithm's structure: n reads of
    // pos per i-iteration x n iterations x 2 steps + n loads in move
    let rep = traced.mapping().report();
    let n = 64u64;
    // 2 steps x (update: n² j-loads + n i-loads; move: n loads) plus the
    // snapshot_view above (n loads of every field).
    assert_eq!(rep[0].reads, 2 * (n * n + n + n) + n);
    let _ = Particle::default();
}
