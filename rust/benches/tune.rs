//! Experiment E12 — the autotuner end to end: record an access trace
//! from the n-body workload on its starting layout (AoS), let the
//! planner pick, and measure the recommended layout against the
//! starting one — plus the one-time live-migration cost the plan
//! amortizes and the per-retrace record+plan overhead.
//!
//! Expected shape: the n-body trace sends AoS to multi-blob SoA
//! (asserted in every mode — the planner's headline decision), and at
//! full size the tuned row beats the starting row on wall clock
//! (asserted in full mode only; smoke sizes are noise). The migration
//! row is a single relayout+verify of the whole view: its cost is paid
//! once and amortized over every subsequent step, which is the
//! autotuner's bet.
//!
//! Run: `cargo bench --bench tune [-- N]`  (default N=16384;
//! LLAMA_BENCH_SMOKE=1 shrinks to a smoke run; LLAMA_THREADS overrides
//! the migration row's worker count, default 4; LLAMA_BENCH_JSON=<dir>
//! writes BENCH_tune.json)

use llama::bench::{black_box, smoke, Bencher};
use llama::blob::{alloc_view, AlignedAlloc, BlobStorage};
use llama::extents::Dyn;
use llama::mapping::field_access_count::FieldAccessCount;
use llama::nbody::{init_particles, views, Particle};
use llama::tune::{migrate_live, AccessTrace, Candidate, Planner};

fn main() {
    let arg_n: Option<usize> =
        std::env::args().skip(1).find(|a| !a.starts_with('-')).and_then(|a| a.parse().ok());
    let fast = smoke();
    let n = arg_n.unwrap_or(if fast { 2048 } else { 16384 });
    let threads = llama::shard::thread_count_or(4);
    let mut b = if fast { Bencher::new(1, 3) } else { Bencher::new(2, 7) };
    let e = (Dyn(n as u32),);
    let init = init_particles(n, 1);

    println!("autotune (E12): n={n}, starting layout aos, {threads}-thread migration\n");

    // Record: one instrumented SIMD step on the starting layout.
    let fac: FieldAccessCount<Particle, _> = FieldAccessCount::new(views::AosMap::new(e));
    let mut traced = alloc_view(fac, &AlignedAlloc::<64>);
    views::fill_view(&mut traced, &init);
    traced.mapping().reset(); // the trace covers the workload, not the fill
    views::update_simd::<8, _, _>(&mut traced);
    views::move_simd::<8, _, _>(&mut traced);
    let trace = AccessTrace::record(&traced).with_origin("aos");
    assert!(trace.stable && trace.total_accesses() > 0);

    // Plan over the layouts this bench instantiates, and pin the
    // decision: the n-body pattern must send AoS to multi-blob SoA in
    // every mode (guards the cost model, not the machine).
    let planner = Planner::new();
    let native = [Candidate::Aos, Candidate::SoaMb, Candidate::Aosoa { lanes: 8 }];
    let plan = planner.recommend_among(&trace, &native);
    println!("{}", plan.render_table());
    assert_eq!(plan.chosen, Candidate::SoaMb, "n-body trace must recommend SoA-MB");
    assert!(plan.is_migration());

    // The workload rows: one SIMD n-body step per iteration, identical
    // kernel code, only the mapping differs.
    let mut v_aos = views::make_aos_view(&init);
    b.bench("nbody step  aos (start)", n as u64, || {
        views::update_simd::<8, _, _>(&mut v_aos);
        views::move_simd::<8, _, _>(&mut v_aos);
        black_box(v_aos.storage().blob_len(0));
    });
    let mut v_soa = views::make_soa_view(&init);
    b.bench("nbody step  soa-mb (tuned)", n as u64, || {
        views::update_simd::<8, _, _>(&mut v_soa);
        views::move_simd::<8, _, _>(&mut v_soa);
        black_box(v_soa.storage().blob_len(0));
    });

    // The one-time migration cost (alloc + parallel copy + bit-identity
    // verify of every cell) the plan amortizes over future steps.
    let v_start = views::make_aos_view(&init);
    b.bench(&format!("migrate aos -> soa-mb {threads}T"), n as u64, || {
        let (dst, rep) =
            migrate_live(&v_start, views::SoaMbMap::new(e), &AlignedAlloc::<64>, threads);
        black_box((dst.count(), rep.bytes_moved));
    });

    // The per-retrace overhead the coordinator pays: freeze the
    // counters coherently, build the trace, score the candidates.
    b.bench("trace record + plan", 1, || {
        let t = AccessTrace::record(&traced).with_origin("aos");
        let p = planner.recommend_among(&t, &native);
        black_box(p.chosen);
    });

    println!("{}", b.render_table("autotune (per record)", Some("nbody step  aos (start)")));

    // The headline claim, asserted where it is signal: at full size the
    // recommended layout beats the starting one.
    if !fast {
        let med = |name: &str| {
            b.results().iter().find(|m| m.name == name).expect("row exists").median
        };
        assert!(
            med("nbody step  soa-mb (tuned)") < med("nbody step  aos (start)"),
            "recommended layout must beat the starting layout at n={n}"
        );
    }

    // Schema guard (smoke mode, i.e. CI): the measurement-key set of
    // BENCH_tune.json must stay diffable across commits.
    if fast {
        let mut want: Vec<String> = vec![
            "nbody step  aos (start)".into(),
            "nbody step  soa-mb (tuned)".into(),
            format!("migrate aos -> soa-mb {threads}T"),
            "trace record + plan".into(),
        ];
        want.sort();
        let mut got: Vec<String> = b.results().iter().map(|m| m.name.clone()).collect();
        got.sort();
        assert_eq!(got, want, "tune-table measurement keys drifted");
        println!("smoke schema guard OK: {} tune keys", got.len());
    }

    println!("counters: {}", llama::counters::status_line());

    let written = llama::bench::emit_json(
        "tune",
        &[
            ("n", n.to_string()),
            ("threads", threads.to_string()),
            ("smoke", (fast as u8).to_string()),
            ("counters", llama::counters::meta_tag().to_string()),
        ],
        &[("tune", &b)],
    )
    .expect("writing LLAMA_BENCH_JSON output");
    if let Some(path) = written {
        println!("perf trajectory written to {}", path.display());
    }
}
