//! Experiment E9 — layout-aware copy: the cost ladder of the four copy
//! strategies between the Figure-3 layouts, serial vs parallel.
//!
//! The paper's original layout-aware copy result: exchanging data between
//! views of different mappings can run as whole-blob `memcpy` (identical
//! layouts), per-field `memcpy` runs (both sides byte-contiguous —
//! SoA↔SoA across blob policies, SoA↔AoSoA), or a per-(record, field)
//! scalar loop (everything else). This bench records all three plus the
//! run-based *parallel* copy (`copy_view_par`): field runs intersected
//! with the destination mapping's `shard_bounds` boundaries and fanned
//! over the persistent worker pool — disjoint byte ranges per thread
//! for free.
//!
//! Expected shape: blob-memcpy ≲ runs ≤ runs-NT « field-wise. The
//! parallel rows profit only once the copy is large enough to beat the
//! thread fan-out cost; recording where that crossover sits is the point
//! of keeping serial and parallel rows side by side in the trajectory.
//!
//! Run: `cargo bench --bench copy [-- N]`  (default N=524288;
//! LLAMA_BENCH_SMOKE=1 shrinks to a smoke run; LLAMA_THREADS overrides
//! the parallel rows' worker count, default 4; LLAMA_BENCH_JSON=<dir>
//! writes BENCH_copy.json)

use llama::bench::{black_box, smoke, Bencher};
use llama::blob::{alloc_view, BlobStorage, HeapAlloc};
use llama::copy::{copy_view, copy_view_par, CopyStrategy};
use llama::extents::Dyn;
use llama::mapping::aos::AoS;
use llama::mapping::aosoa::AoSoA;
use llama::mapping::soa::{SingleBlob, SoA};

llama::record! {
    pub struct Particle, mod particle {
        pos: { x: f32, y: f32, z: f32 },
        vel: { x: f32, y: f32, z: f32 },
        mass: f32,
    }
}

fn main() {
    let arg_n: Option<usize> =
        std::env::args().skip(1).find(|a| !a.starts_with('-')).and_then(|a| a.parse().ok());
    let fast = smoke();
    let n = arg_n.unwrap_or(if fast { 4096 } else { 1 << 19 });
    let threads = llama::shard::thread_count_or(4);
    let mut b = if fast { Bencher::new(1, 3) } else { Bencher::new(2, 7) };
    let e = (Dyn(n as u32),);

    println!("layout-aware copy: n={n} records ({} B payload), {threads}-thread rows\n", n * 28);

    let mut src = alloc_view(SoA::<Particle, _>::new(e), &HeapAlloc);
    for i in 0..n {
        src.set_t([i], particle::pos::x, i as f32);
        src.set_t([i], particle::pos::y, -(i as f32));
        src.set_t([i], particle::pos::z, 0.5 * i as f32);
        src.set_t([i], particle::vel::x, 1.0);
        src.set_t([i], particle::vel::y, -1.0);
        src.set_t([i], particle::vel::z, 0.0);
        src.set_t([i], particle::mass, 1.0 + (i % 7) as f32);
    }

    // Strategy guards: each row must actually exercise the strategy its
    // name claims, so a silent fallback fails the bench (CI smoke) rather
    // than corrupting the trajectory.
    {
        let mut dst = alloc_view(SoA::<Particle, _>::new(e), &HeapAlloc);
        assert_eq!(copy_view(&src, &mut dst), CopyStrategy::BlobMemcpy);
        b.bench("copy SoA-MB -> SoA-MB  blob-memcpy", n as u64, || {
            copy_view(&src, &mut dst);
            black_box(dst.storage().blob_len(0));
        });
    }
    {
        let mut dst = alloc_view(AoSoA::<Particle, _, 8>::new(e), &HeapAlloc);
        assert_eq!(copy_view(&src, &mut dst), CopyStrategy::FieldRuns);
        b.bench("copy SoA-MB -> AoSoA8  runs serial", n as u64, || {
            copy_view(&src, &mut dst);
            black_box(dst.storage().blob_len(0));
        });
    }
    {
        let mut dst = alloc_view(AoSoA::<Particle, _, 8>::new(e), &HeapAlloc);
        let strat = copy_view_par(&src, &mut dst, threads);
        if threads >= 2 && n >= threads {
            assert_eq!(strat, CopyStrategy::FieldRunsPar);
        }
        b.bench(&format!("copy SoA-MB -> AoSoA8  runs {threads}T"), n as u64, || {
            copy_view_par(&src, &mut dst, threads);
            black_box(dst.storage().blob_len(0));
        });
    }
    {
        let mut dst = alloc_view(SoA::<Particle, _, SingleBlob>::new(e), &HeapAlloc);
        assert_eq!(copy_view(&src, &mut dst), CopyStrategy::FieldRuns);
        b.bench("copy SoA-MB -> SoA-SB  runs serial", n as u64, || {
            copy_view(&src, &mut dst);
            black_box(dst.storage().blob_len(0));
        });
    }
    {
        let mut dst = alloc_view(SoA::<Particle, _, SingleBlob>::new(e), &HeapAlloc);
        let strat = copy_view_par(&src, &mut dst, threads);
        if threads >= 2 && n >= threads {
            assert_eq!(strat, CopyStrategy::FieldRunsPar);
        }
        b.bench(&format!("copy SoA-MB -> SoA-SB  runs {threads}T"), n as u64, || {
            copy_view_par(&src, &mut dst, threads);
            black_box(dst.storage().blob_len(0));
        });
    }
    {
        let mut dst = alloc_view(AoS::<Particle, _>::new(e), &HeapAlloc);
        assert_eq!(copy_view(&src, &mut dst), CopyStrategy::FieldWise);
        b.bench("copy SoA-MB -> AoS     field-wise", n as u64, || {
            copy_view(&src, &mut dst);
            black_box(dst.storage().blob_len(0));
        });
    }

    println!(
        "{}",
        b.render_table("layout-aware copy (per record)", Some("copy SoA-MB -> AoS     field-wise"))
    );

    // Schema guard (smoke mode, i.e. CI): the measurement-key set of
    // BENCH_copy.json must stay diffable across commits.
    if fast {
        let mut want: Vec<String> = vec![
            "copy SoA-MB -> SoA-MB  blob-memcpy".into(),
            "copy SoA-MB -> AoSoA8  runs serial".into(),
            format!("copy SoA-MB -> AoSoA8  runs {threads}T"),
            "copy SoA-MB -> SoA-SB  runs serial".into(),
            format!("copy SoA-MB -> SoA-SB  runs {threads}T"),
            "copy SoA-MB -> AoS     field-wise".into(),
        ];
        want.sort();
        let mut got: Vec<String> = b.results().iter().map(|m| m.name.clone()).collect();
        got.sort();
        assert_eq!(got, want, "copy-table measurement keys drifted");
        println!("smoke schema guard OK: {} copy keys", got.len());
    }

    println!("counters: {}", llama::counters::status_line());

    let written = llama::bench::emit_json(
        "copy",
        &[
            ("n", n.to_string()),
            ("threads", threads.to_string()),
            ("smoke", (fast as u8).to_string()),
            ("counters", llama::counters::meta_tag().to_string()),
        ],
        &[("copy", &b)],
    )
    .expect("writing LLAMA_BENCH_JSON output");
    if let Some(path) = written {
        println!("perf trajectory written to {}", path.display());
    }
}
