//! Experiment E1 — Figure 3: n-body runtime across {AoS, SoA multi-blob,
//! AoSoA8} × {manually written, LLAMA} × {scalar, SIMD-8}, update and
//! move steps separately, plus serial-vs-multithreaded LLAMA rows per
//! layout through the sharded parallel engine.
//!
//! The paper's claim under test: LLAMA matches the manually written code
//! (zero overhead), SoA/AoSoA SIMD are fastest for update, SoA wins move,
//! and AoSoA has a known penalty in the single-loop LLAMA traversal
//! (footnote 13). Absolute numbers differ from the paper's Ryzen 5950X;
//! the *ordering and ratios* are what reproduce. The `<T>T` rows fan the
//! same kernel over `ViewShards` workers (bit-identical results); on the
//! compute-bound update step the parallel SoA row should beat serial SoA
//! on the full-size run.
//!
//! The LLAMA rows run through the bulk-traversal engine
//! (`view::transform_simd` / `view::for_each`): the acceptance bar is the
//! "LLAMA" SoA rows matching the "manual" SoA rows.
//!
//! Run: `cargo bench --bench fig3_nbody [-- N]`  (default N=16384 like the
//! paper's CPU plot; LLAMA_BENCH_SMOKE=1 shrinks to a smoke run;
//! LLAMA_THREADS overrides the parallel rows' worker count, default 4;
//! LLAMA_BENCH_JSON=<dir> writes BENCH_fig3.json)

use llama::bench::{black_box, smoke, Bencher};
use llama::nbody::{init_particles, manual, views};

fn main() {
    let arg_n: Option<usize> =
        std::env::args().skip(1).find(|a| !a.starts_with('-')).and_then(|a| a.parse().ok());
    let fast = smoke();
    let n = arg_n.unwrap_or(if fast { 2048 } else { 16384 });
    let par_threads = llama::shard::thread_count_or(4);
    let init = init_particles(n, 42);
    let mut b = if fast { Bencher::new(1, 3) } else { Bencher::new(2, 7) };

    println!("Figure 3 reproduction: n-body, n={n}, serial + {par_threads}-thread rows\n");

    // ---------------- update step (compute-bound) ----------------
    {
        let mut s = manual::AosSim::new(&init);
        b.bench("update AoS    manual scalar", n as u64, || {
            s.update_scalar();
            black_box(&s.ps);
        });
    }
    {
        let mut v = views::make_aos_view(&init);
        b.bench("update AoS    LLAMA  scalar", n as u64, || {
            views::update_scalar(&mut v);
        });
    }
    {
        let mut s = manual::AosSim::new(&init);
        b.bench("update AoS    manual SIMD8", n as u64, || {
            s.update_simd::<8>();
            black_box(&s.ps);
        });
    }
    {
        let mut v = views::make_aos_view(&init);
        b.bench("update AoS    LLAMA  SIMD8", n as u64, || {
            views::update_simd::<8, _, _>(&mut v);
        });
    }
    {
        let mut s = manual::SoaSim::new(&init);
        b.bench("update SoA-MB manual scalar", n as u64, || {
            s.update_scalar();
            black_box(&s.px);
        });
    }
    {
        let mut v = views::make_soa_view(&init);
        b.bench("update SoA-MB LLAMA  scalar", n as u64, || {
            views::update_scalar(&mut v);
        });
    }
    {
        let mut s = manual::SoaSim::new(&init);
        b.bench("update SoA-MB manual SIMD8", n as u64, || {
            s.update_simd::<8>();
            black_box(&s.px);
        });
    }
    {
        let mut v = views::make_soa_view(&init);
        b.bench("update SoA-MB LLAMA  SIMD8", n as u64, || {
            views::update_simd::<8, _, _>(&mut v);
        });
    }
    {
        // Same kernel, legacy usize-index access path: demonstrates the
        // typed-tag path is zero-cost (rows must agree within noise).
        let mut v = views::make_soa_view(&init);
        b.bench("update SoA-MB LLAMA  SIMD8 legacy-idx", n as u64, || {
            views::update_simd_idx::<8, _, _>(&mut v);
        });
    }
    {
        let mut s = manual::AosoaSim::<8>::new(&init);
        b.bench("update AoSoA8 manual scalar", n as u64, || {
            s.update_scalar();
            black_box(&s.blocks);
        });
    }
    {
        let mut v = views::make_aosoa_view(&init);
        b.bench("update AoSoA8 LLAMA  scalar", n as u64, || {
            views::update_scalar(&mut v);
        });
    }
    {
        let mut s = manual::AosoaSim::<8>::new(&init);
        b.bench("update AoSoA8 manual SIMD8", n as u64, || {
            s.update_simd();
            black_box(&s.blocks);
        });
    }
    {
        let mut v = views::make_aosoa_view(&init);
        b.bench("update AoSoA8 LLAMA  SIMD8", n as u64, || {
            views::update_simd::<8, _, _>(&mut v);
        });
    }

    // Sharded parallel rows: the same SIMD-8 kernel fanned out over
    // `par_threads` workers (bit-identical to the serial rows above).
    // The plain `<T>T` rows dispatch on the persistent worker pool (the
    // default since the pool landed); the `<T>T scoped` rows force the
    // old per-call thread spawn/join, so the pool's amortized-dispatch
    // win is visible on the headline workload.
    {
        let mut v = views::make_aos_view(&init);
        b.bench(&format!("update AoS    LLAMA  SIMD8 {par_threads}T"), n as u64, || {
            views::update_simd_par::<8, _, _>(&mut v, par_threads);
        });
    }
    {
        let mut v = views::make_soa_view(&init);
        b.bench(&format!("update SoA-MB LLAMA  SIMD8 {par_threads}T"), n as u64, || {
            views::update_simd_par::<8, _, _>(&mut v, par_threads);
        });
    }
    {
        let mut v = views::make_aosoa_view(&init);
        b.bench(&format!("update AoSoA8 LLAMA  SIMD8 {par_threads}T"), n as u64, || {
            views::update_simd_par::<8, _, _>(&mut v, par_threads);
        });
    }
    {
        let mut v = views::make_aos_view(&init);
        b.bench(&format!("update AoS    LLAMA  SIMD8 {par_threads}T scoped"), n as u64, || {
            views::update_simd_par_scoped::<8, _, _>(&mut v, par_threads);
        });
    }
    {
        let mut v = views::make_soa_view(&init);
        b.bench(&format!("update SoA-MB LLAMA  SIMD8 {par_threads}T scoped"), n as u64, || {
            views::update_simd_par_scoped::<8, _, _>(&mut v, par_threads);
        });
    }
    {
        let mut v = views::make_aosoa_view(&init);
        b.bench(&format!("update AoSoA8 LLAMA  SIMD8 {par_threads}T scoped"), n as u64, || {
            views::update_simd_par_scoped::<8, _, _>(&mut v, par_threads);
        });
    }

    println!(
        "{}",
        b.render_table("update step (runtime per particle)", Some("update AoS    manual scalar"))
    );
    let b_update = b;

    // ---------------- move step (memory-bound) ----------------
    // More reps per sample: a single move pass is microseconds.
    let move_reps = if fast { 50u64 } else { 200 };
    let mut b = if fast { Bencher::new(1, 3) } else { Bencher::new(2, 7) };
    macro_rules! bench_move {
        ($name:expr, $init:expr, $body:expr) => {{
            let mut s = $init;
            b.bench($name, n as u64 * move_reps, || {
                for _ in 0..move_reps {
                    #[allow(clippy::redundant_closure_call)]
                    ($body)(&mut s);
                }
                black_box(&s);
            });
        }};
    }
    type Aos = manual::AosSim;
    type Soa = manual::SoaSim;
    type Aosoa = manual::AosoaSim<8>;
    bench_move!("move AoS    manual scalar", Aos::new(&init), |s: &mut Aos| s.move_scalar());
    bench_move!("move AoS    LLAMA  scalar", views::make_aos_view(&init), |v: &mut _| {
        views::move_scalar(v)
    });
    bench_move!("move AoS    manual SIMD8", Aos::new(&init), |s: &mut Aos| s.move_simd::<8>());
    bench_move!("move AoS    LLAMA  SIMD8", views::make_aos_view(&init), |v: &mut _| {
        views::move_simd::<8, _, _>(v)
    });
    bench_move!("move SoA-MB manual scalar", Soa::new(&init), |s: &mut Soa| s.move_scalar());
    bench_move!("move SoA-MB LLAMA  scalar", views::make_soa_view(&init), |v: &mut _| {
        views::move_scalar(v)
    });
    bench_move!("move SoA-MB manual SIMD8", Soa::new(&init), |s: &mut Soa| s.move_simd::<8>());
    bench_move!("move SoA-MB LLAMA  SIMD8", views::make_soa_view(&init), |v: &mut _| {
        views::move_simd::<8, _, _>(v)
    });
    bench_move!("move SoA-MB LLAMA  SIMD8 legacy-idx", views::make_soa_view(&init), |v: &mut _| {
        views::move_simd_idx::<8, _, _>(v)
    });
    bench_move!("move AoSoA8 manual scalar", Aosoa::new(&init), |s: &mut Aosoa| s.move_scalar());
    bench_move!("move AoSoA8 LLAMA  scalar", views::make_aosoa_view(&init), |v: &mut _| {
        views::move_scalar(v)
    });
    bench_move!("move AoSoA8 manual SIMD8", Aosoa::new(&init), |s: &mut Aosoa| s.move_simd());
    bench_move!("move AoSoA8 LLAMA  SIMD8", views::make_aosoa_view(&init), |v: &mut _| {
        views::move_simd::<8, _, _>(v)
    });

    // Parallel move rows: the memory-bound step rarely profits as much as
    // update, which is itself a finding worth recording in the trajectory.
    // Pooled (default) vs `scoped` (per-call spawn) matters *most* here:
    // a move pass is microseconds, so the spawn fee dominates the scoped
    // rows outright.
    bench_move!(
        &format!("move AoS    LLAMA  SIMD8 {par_threads}T"),
        views::make_aos_view(&init),
        |v: &mut _| views::move_simd_par::<8, _, _>(v, par_threads)
    );
    bench_move!(
        &format!("move SoA-MB LLAMA  SIMD8 {par_threads}T"),
        views::make_soa_view(&init),
        |v: &mut _| views::move_simd_par::<8, _, _>(v, par_threads)
    );
    bench_move!(
        &format!("move AoSoA8 LLAMA  SIMD8 {par_threads}T"),
        views::make_aosoa_view(&init),
        |v: &mut _| views::move_simd_par::<8, _, _>(v, par_threads)
    );
    bench_move!(
        &format!("move AoS    LLAMA  SIMD8 {par_threads}T scoped"),
        views::make_aos_view(&init),
        |v: &mut _| views::move_simd_par_scoped::<8, _, _>(v, par_threads)
    );
    bench_move!(
        &format!("move SoA-MB LLAMA  SIMD8 {par_threads}T scoped"),
        views::make_soa_view(&init),
        |v: &mut _| views::move_simd_par_scoped::<8, _, _>(v, par_threads)
    );
    bench_move!(
        &format!("move AoSoA8 LLAMA  SIMD8 {par_threads}T scoped"),
        views::make_aosoa_view(&init),
        |v: &mut _| views::move_simd_par_scoped::<8, _, _>(v, par_threads)
    );

    println!(
        "{}",
        b.render_table("move step (runtime per particle)", Some("move AoS    manual scalar"))
    );

    // Schema guard (smoke mode, i.e. CI): the typed-tag n-body path must
    // emit exactly the expected measurement keys, so the BENCH_fig3.json
    // perf-trajectory artifact stays diffable across commits and a
    // typed-path row silently disappearing (or being renamed) fails the
    // build instead of corrupting the trajectory.
    if fast {
        let expect = |step: &str| -> Vec<String> {
            let mut keys: Vec<String> = [
                "AoS    manual scalar",
                "AoS    LLAMA  scalar",
                "AoS    manual SIMD8",
                "AoS    LLAMA  SIMD8",
                "SoA-MB manual scalar",
                "SoA-MB LLAMA  scalar",
                "SoA-MB manual SIMD8",
                "SoA-MB LLAMA  SIMD8",
                "SoA-MB LLAMA  SIMD8 legacy-idx",
                "AoSoA8 manual scalar",
                "AoSoA8 LLAMA  scalar",
                "AoSoA8 manual SIMD8",
                "AoSoA8 LLAMA  SIMD8",
            ]
            .iter()
            .map(|k| format!("{step} {k}"))
            .collect();
            for layout in ["AoS   ", "SoA-MB", "AoSoA8"] {
                keys.push(format!("{step} {layout} LLAMA  SIMD8 {par_threads}T"));
                keys.push(format!("{step} {layout} LLAMA  SIMD8 {par_threads}T scoped"));
            }
            keys
        };
        // Row order differs slightly between the two tables (the
        // legacy-idx row sits before the AoSoA block in update, after the
        // SoA SIMD8 row in move): compare as sorted sets.
        let mut want_update = expect("update");
        let mut want_move = expect("move");
        want_update.sort();
        want_move.sort();
        let mut got_update: Vec<String> =
            b_update.results().iter().map(|m| m.name.clone()).collect();
        let mut got_move: Vec<String> = b.results().iter().map(|m| m.name.clone()).collect();
        got_update.sort();
        got_move.sort();
        assert_eq!(got_update, want_update, "update-table measurement keys drifted");
        assert_eq!(got_move, want_move, "move-table measurement keys drifted");
        println!(
            "smoke schema guard OK: {} update + {} move keys",
            got_update.len(),
            got_move.len()
        );
    }

    println!("counters: {}", llama::counters::status_line());

    // Machine-readable perf trajectory (uploaded as a CI artifact).
    let written = llama::bench::emit_json(
        "fig3",
        &[
            ("n", n.to_string()),
            ("threads", par_threads.to_string()),
            ("smoke", (fast as u8).to_string()),
            ("counters", llama::counters::meta_tag().to_string()),
        ],
        &[("update", &b_update), ("move", &b)],
    )
    .expect("writing LLAMA_BENCH_JSON output");
    if let Some(path) = written {
        println!("perf trajectory written to {}", path.display());
    }
}
