//! Experiment E7 — §3 BitpackIntSoA / BitpackFloatSoA / Changetype:
//! storage saved vs access cost.
//!
//! Paper claims: bit-packing trades pack/unpack arithmetic for storage;
//! "a mere change of the storage data type is computationally more
//! efficient, because the hardware may have appropriate conversion
//! instructions" (Changetype vs Bitpack). We sweep integer bit counts and
//! float (exp, man) configs, reporting bytes + ns/access, with plain SoA
//! and ChangeType rows as baselines.
//!
//! Run: `cargo bench --bench bitpack`

use llama::bench::{black_box, Bencher};
use llama::blob::{alloc_view, BlobStorage, HeapAlloc};
use llama::extents::Dyn;
use llama::mapping::bitpack_float::BitpackFloatSoA;
use llama::mapping::bitpack_int::BitpackIntSoA;
use llama::mapping::changetype::ChangeType;
use llama::mapping::soa::SoA;
use llama::record::F16;
use llama::testing::Rng;

llama::record! {
    pub struct Hits, mod hits {
        adc: u32,
    }
}

llama::record! {
    pub struct Vals, mod vals {
        v: f64,
    }
}

llama::record! {
    pub struct ValsF32, mod _vals32 {
        v: f32,
    }
}

llama::record! {
    pub struct ValsF16, mod _vals16 {
        v: F16,
    }
}

fn main() {
    let fast = llama::bench::smoke();
    let n: usize = if fast { 1 << 13 } else { 1 << 16 };
    let mut rng = Rng::new(5);
    let ints: Vec<u32> = (0..n).map(|_| rng.range_u64(0, (1 << 12) - 1) as u32).collect();
    let floats: Vec<f64> = (0..n).map(|_| rng.f64_range(-100.0, 100.0)).collect();
    let mut b = if fast { Bencher::new(1, 3) } else { Bencher::new(2, 9) };

    println!("E7: bitpack/changetype storage-vs-speed, n={n}\n");
    println!("-- integers (12-bit ADC values stored in u32 fields) --");
    println!("{:>22} {:>12}", "mapping", "bytes");

    // Storage table.
    macro_rules! int_row {
        ($name:expr, $m:expr) => {{
            let v = alloc_view($m, &HeapAlloc);
            println!("{:>22} {:>12}", $name, v.storage().total_bytes());
        }};
    }
    let e = (Dyn(n as u32),);
    int_row!("SoA u32", SoA::<Hits, _>::new(e));
    int_row!("BitpackIntSoA<26>", BitpackIntSoA::<Hits, _, 26>::new(e));
    int_row!("BitpackIntSoA<17>", BitpackIntSoA::<Hits, _, 17>::new(e));
    int_row!("BitpackIntSoA<12>", BitpackIntSoA::<Hits, _, 12>::new(e));
    int_row!("BitpackIntSoA<7>", BitpackIntSoA::<Hits, _, 7>::new(e));
    println!();

    // Speed: sum all values through each mapping.
    {
        let mut v = alloc_view(SoA::<Hits, _>::new(e), &HeapAlloc);
        for (i, &x) in ints.iter().enumerate() {
            v.set_t([i], hits::adc, x);
        }
        b.bench("load u32 SoA", n as u64, || {
            let mut acc = 0u64;
            for i in 0..n {
                acc += v.get_t([i], hits::adc) as u64;
            }
            black_box(acc);
        });
    }
    macro_rules! int_speed {
        ($name:expr, $bits:literal) => {{
            let mut v = alloc_view(BitpackIntSoA::<Hits, _, $bits>::new(e), &HeapAlloc);
            for (i, &x) in ints.iter().enumerate() {
                v.set_t([i], hits::adc, x);
            }
            b.bench($name, n as u64, || {
                let mut acc = 0u64;
                for i in 0..n {
                    acc += v.get_t([i], hits::adc) as u64;
                }
                black_box(acc);
            });
        }};
    }
    int_speed!("load bitpack 26b", 26);
    int_speed!("load bitpack 17b", 17);
    int_speed!("load bitpack 12b", 12);
    int_speed!("load bitpack 7b", 7);
    println!("{}", b.render_table("integer load cost", Some("load u32 SoA")));
    let b_int = b;

    // -- floats --
    println!("-- floats (f64 algorithm type) --");
    println!("{:>26} {:>12}", "mapping", "bytes");
    macro_rules! float_row {
        ($name:expr, $m:expr) => {{
            let v = alloc_view($m, &HeapAlloc);
            println!("{:>26} {:>12}", $name, v.storage().total_bytes());
        }};
    }
    float_row!("SoA f64", SoA::<Vals, _>::new(e));
    float_row!("BitpackFloatSoA e11m52", BitpackFloatSoA::<Vals, _, 11, 52>::new(e));
    float_row!("BitpackFloatSoA e8m23", BitpackFloatSoA::<Vals, _, 8, 23>::new(e));
    float_row!("BitpackFloatSoA e8m7", BitpackFloatSoA::<Vals, _, 8, 7>::new(e));
    float_row!("BitpackFloatSoA e5m10", BitpackFloatSoA::<Vals, _, 5, 10>::new(e));
    float_row!(
        "ChangeType f64->f32",
        ChangeType::<Vals, ValsF32, _>::new(SoA::<ValsF32, _>::new(e))
    );
    float_row!(
        "ChangeType f64->f16",
        ChangeType::<Vals, ValsF16, _>::new(SoA::<ValsF16, _>::new(e))
    );
    println!();

    let mut b = if fast { Bencher::new(1, 3) } else { Bencher::new(2, 9) };
    {
        let mut v = alloc_view(SoA::<Vals, _>::new(e), &HeapAlloc);
        for (i, &x) in floats.iter().enumerate() {
            v.set_t([i], vals::v, x);
        }
        b.bench("load f64 SoA", n as u64, || {
            let mut acc = 0.0f64;
            for i in 0..n {
                acc += v.get_t([i], vals::v);
            }
            black_box(acc);
        });
    }
    macro_rules! float_speed {
        ($name:expr, $m:expr) => {{
            let mut v = alloc_view($m, &HeapAlloc);
            for (i, &x) in floats.iter().enumerate() {
                v.set_t([i], vals::v, x);
            }
            b.bench($name, n as u64, || {
                let mut acc = 0.0f64;
                for i in 0..n {
                    acc += v.get_t([i], vals::v);
                }
                black_box(acc);
            });
        }};
    }
    float_speed!("load bitpack e8m23", BitpackFloatSoA::<Vals, _, 8, 23>::new(e));
    float_speed!("load bitpack e5m10", BitpackFloatSoA::<Vals, _, 5, 10>::new(e));
    float_speed!(
        "load changetype f32",
        ChangeType::<Vals, ValsF32, _>::new(SoA::<ValsF32, _>::new(e))
    );
    float_speed!(
        "load changetype f16",
        ChangeType::<Vals, ValsF16, _>::new(SoA::<ValsF16, _>::new(e))
    );
    println!("{}", b.render_table("float load cost", Some("load f64 SoA")));
    println!(
        "expected shape (paper §3): changetype-f32 ≈ plain load (hardware cvt);\n\
         bitpack pays shift/mask on every access; both save the same storage at 32 bits."
    );

    println!("counters: {}", llama::counters::status_line());

    llama::bench::emit_json(
        "bitpack",
        &[("n", n.to_string()), ("counters", llama::counters::meta_tag().to_string())],
        &[("int", &b_int), ("float", &b)],
    )
    .expect("writing LLAMA_BENCH_JSON output");
}
