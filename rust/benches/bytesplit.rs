//! Experiment E6 — §3 Bytesplit: compression-ratio study.
//!
//! Paper claim: splitting values into byte planes colocates zero bytes and
//! improves compression of small-valued data. We sweep value magnitude
//! (bits of entropy per u32/u64 field) × layout (AoS, SoA, Bytesplit) ×
//! codec (RLE, DEFLATE, zstd) and also measure the access-time cost
//! Bytesplit pays for its scattered bytes.
//!
//! Run: `cargo bench --bench bytesplit`

use llama::bench::{black_box, Bencher};
use llama::blob::{alloc_view, BlobStorage, HeapAlloc};
use llama::compress::{measure_blobs, Codec};
use llama::extents::Dyn;
use llama::mapping::aos::AoS;
use llama::mapping::bytesplit::Bytesplit;
use llama::mapping::soa::SoA;
use llama::mapping::MemoryAccess;
use llama::testing::Rng;
use llama::view::View;

llama::record! {
    pub struct Event, mod ev {
        adc: u32,
        channel: u16,
        time: u64,
        energy: f32,
    }
}

fn fill<M, S: BlobStorage>(v: &mut View<Event, M, S>, n: usize, value_bits: u32)
where
    M: MemoryAccess<Event>,
    M::Extents: llama::extents::Extents<ArrayIndex = [usize; 1]>,
{
    let mut rng = Rng::new(17);
    for i in 0..n {
        v.set_t([i], ev::adc, rng.range_u64(0, (1u64 << value_bits) - 1) as u32);
        v.set_t([i], ev::channel, rng.range_u64(0, 1023) as u16);
        v.set_t([i], ev::time, i as u64 * 40 + rng.range_u64(0, 39));
        v.set_t([i], ev::energy, rng.f64_range(0.0, 100.0) as f32);
    }
}

fn blobs_of<S: BlobStorage>(s: &S) -> Vec<&[u8]> {
    (0..s.blob_count()).map(|b| s.blob(b)).collect()
}

fn main() {
    let fast = llama::bench::smoke();
    let n: usize = if fast { 1 << 13 } else { 1 << 17 };
    println!("E6: Bytesplit compression, {n} events\n");

    println!(
        "{:>10} {:>9} {:>11} {:>12} {:>8}",
        "adc bits", "codec", "layout", "bytes", "ratio"
    );
    for value_bits in [8u32, 12, 16, 24] {
        let e = (Dyn(n as u32),);
        let mut aos = alloc_view(AoS::<Event, _>::new(e), &HeapAlloc);
        let mut soa = alloc_view(SoA::<Event, _>::new(e), &HeapAlloc);
        let mut bs = alloc_view(Bytesplit::<Event, _>::new(e), &HeapAlloc);
        fill(&mut aos, n, value_bits);
        fill(&mut soa, n, value_bits);
        fill(&mut bs, n, value_bits);
        for codec in Codec::enabled() {
            for (label, blobs) in [
                ("AoS", blobs_of(aos.storage())),
                ("SoA", blobs_of(soa.storage())),
                ("Bytesplit", blobs_of(bs.storage())),
            ] {
                let stat = measure_blobs(&blobs, codec).expect("compress");
                println!(
                    "{:>10} {:>9} {:>11} {:>12} {:>8.2}",
                    value_bits,
                    codec.name(),
                    label,
                    stat.compressed,
                    stat.ratio()
                );
            }
        }
        println!();
    }
    println!("expected shape: ratio(Bytesplit) >= ratio(SoA) > ratio(AoS), growing as adc bits shrink.\n");

    // ---- access cost of the bytesplit layout ----
    let mut b = if fast { Bencher::new(1, 3) } else { Bencher::new(2, 7) };
    let e = (Dyn(n as u32),);
    {
        let mut v = alloc_view(SoA::<Event, _>::new(e), &HeapAlloc);
        fill(&mut v, n, 12);
        b.bench("sum adc via SoA", n as u64, || {
            let mut acc = 0u64;
            for i in 0..n {
                acc += v.get_t([i], ev::adc) as u64;
            }
            black_box(acc);
        });
    }
    {
        let mut v = alloc_view(Bytesplit::<Event, _>::new(e), &HeapAlloc);
        fill(&mut v, n, 12);
        b.bench("sum adc via Bytesplit", n as u64, || {
            let mut acc = 0u64;
            for i in 0..n {
                acc += v.get_t([i], ev::adc) as u64;
            }
            black_box(acc);
        });
    }
    let table = b.render_table("Bytesplit access cost (scattered bytes)", Some("sum adc via SoA"));
    println!("{table}");

    println!("counters: {}", llama::counters::status_line());

    llama::bench::emit_json(
        "bytesplit",
        &[("n", n.to_string()), ("counters", llama::counters::meta_tag().to_string())],
        &[("access", &b)],
    )
    .expect("writing LLAMA_BENCH_JSON output");
}
