//! Experiments E4 + E5 — §4 instrumentation costs.
//!
//! E4: runtime overhead of FieldAccessCount (paper: ~3× in a CUDA particle
//! simulation — the cost driver, one atomic RMW per access, is identical
//! here) and of Heatmap (heavier: address computation + 1-RMW per touched
//! granule).
//!
//! E5: counter-memory overhead of Heatmap (paper: 8× at byte granularity
//! with 64-bit counters) across granularities.
//!
//! Run: `cargo bench --bench instrumentation`

use llama::bench::Bencher;
use llama::blob::{alloc_view, HeapAlloc};
use llama::extents::Dyn;
use llama::mapping::field_access_count::FieldAccessCount;
use llama::mapping::heatmap::Heatmap;
use llama::mapping::Mapping;
use llama::nbody::{init_particles, views, Particle};

fn main() {
    let fast = llama::bench::smoke();
    let n: usize = if fast { 512 } else { 2048 };
    let init = init_particles(n, 42);
    let mut b = if fast { Bencher::new(1, 3) } else { Bencher::new(2, 7) };

    println!("§4 instrumentation overhead: n-body step, n={n}, SoA-MB\n");

    // Baseline: plain mapping.
    {
        let mut v = views::make_soa_view(&init);
        b.bench("plain SoA (update+move)", n as u64, || {
            views::update_scalar(&mut v);
            views::move_scalar(&mut v);
        });
    }
    // FieldAccessCount (Trace).
    {
        let fac = FieldAccessCount::new(views::SoaMbMap::new((Dyn(n as u32),)));
        let mut v = alloc_view(fac, &HeapAlloc);
        views::fill_view(&mut v, &init);
        b.bench("FieldAccessCount (Trace)", n as u64, || {
            views::update_scalar(&mut v);
            views::move_scalar(&mut v);
        });
    }
    // Heatmap at cache-line and byte granularity.
    {
        let hm = Heatmap::<Particle, _, 64>::new(views::SoaMbMap::new((Dyn(n as u32),)));
        let mut v = alloc_view(hm, &HeapAlloc);
        views::fill_view(&mut v, &init);
        b.bench("Heatmap gran=64B", n as u64, || {
            views::update_scalar(&mut v);
            views::move_scalar(&mut v);
        });
    }
    {
        let hm = Heatmap::<Particle, _, 1>::new(views::SoaMbMap::new((Dyn(n as u32),)));
        let mut v = alloc_view(hm, &HeapAlloc);
        views::fill_view(&mut v, &init);
        b.bench("Heatmap gran=1B", n as u64, || {
            views::update_scalar(&mut v);
            views::move_scalar(&mut v);
        });
    }

    println!("{}", b.render_table("E4: instrumentation runtime", Some("plain SoA (update+move)")));
    println!("paper reference: Trace cost ≈ 3x on the AdePT CUDA workload;\nexpect the same order here (one relaxed atomic RMW per scalar access).\n");

    // ---- E5: memory overhead table ----
    println!("E5: Heatmap counter memory (payload = n-body SoA blobs)");
    println!("{:>12} {:>12} {:>14} {:>10}", "granularity", "payload B", "counters B", "overhead");
    let payload: usize = {
        let m = views::SoaMbMap::new((Dyn(n as u32),));
        (0..7).map(|i| m.blob_size(i)).sum()
    };
    macro_rules! row {
        ($g:literal) => {{
            let hm = Heatmap::<Particle, _, $g>::new(views::SoaMbMap::new((Dyn(n as u32),)));
            println!(
                "{:>10} B {:>12} {:>14} {:>9.2}x",
                $g,
                payload,
                hm.counter_bytes(),
                hm.counter_bytes() as f64 / payload as f64
            );
        }};
    }
    row!(1);
    row!(8);
    row!(64);
    row!(4096);
    println!("\npaper reference: 8x at granularity 1 B with 64-bit counters.");

    // FieldAccessCount memory: 2 cache-line-padded counters per field
    // (64 B each since the E13 false-sharing fix), independent of n.
    println!(
        "\nFieldAccessCount memory: {} B for {} fields (payload {} B) -> negligible, as in §4",
        7 * 2 * llama::util::CACHE_LINE,
        7,
        payload
    );

    println!("counters: {}", llama::counters::status_line());

    llama::bench::emit_json(
        "instrumentation",
        &[("n", n.to_string()), ("counters", llama::counters::meta_tag().to_string())],
        &[("runtime", &b)],
    )
    .expect("writing LLAMA_BENCH_JSON output");
}
