//! Experiment E10 — parallel dispatch latency: per-call scoped spawn vs
//! the persistent worker pool vs the pool with NUMA pinning, across
//! small / medium / large extents.
//!
//! What the tentpole claims: spawning and joining fresh OS threads on
//! every `par_for_each` costs a roughly fixed fee per call, which
//! dominates small and medium extents (where the actual traversal is
//! microseconds) and still taxes large ones; waking parked pool workers
//! amortizes that fee to a condvar signal. Expected shape per extent
//! row: `pooled ≤ pooled+pinned ≪ scoped` on small, `pooled < scoped`
//! on medium, `pooled ≈ scoped` (no regression) on large where the
//! traversal itself dominates. The pinned rows only differ from pooled
//! on multi-node machines (single-node pinning is a no-op) — recording
//! them anyway keeps the trajectory comparable when CI moves hardware.
//!
//! The kernel is deliberately thin (one multiply-add per record): these
//! rows measure *dispatch*, not compute. `fig3_nbody` carries the
//! compute-bound counterpart (pooled vs scoped on the n-body update).
//!
//! Run: `cargo bench --bench pool`  (LLAMA_BENCH_SMOKE=1 shrinks to a
//! smoke run; LLAMA_THREADS overrides the worker count, default 4;
//! LLAMA_BENCH_JSON=<dir> writes BENCH_pool.json)

use llama::bench::{black_box, smoke, Bencher};
use llama::blob::{alloc_view, HeapAlloc};
use llama::extents::Dyn;
use llama::mapping::soa::SoA;
use llama::pool::WorkerPool;

llama::record! {
    pub struct P, mod p {
        x: f64,
    }
}

fn main() {
    let fast = smoke();
    let threads = llama::shard::thread_count_or(4);
    let sizes: [(&str, usize); 3] =
        if fast { [("small", 512), ("medium", 4096), ("large", 32768)] } else {
            [("small", 4096), ("medium", 262_144), ("large", 4_194_304)]
        };
    let mut b = if fast { Bencher::new(1, 3) } else { Bencher::new(3, 15) };

    // Explicit pools so the rows are self-contained: an unpinned pool
    // and a pinned one (identical on single-node machines).
    let pooled = WorkerPool::with_pinning(threads, false);
    let pinned = WorkerPool::with_pinning(threads, true);

    println!(
        "dispatch latency: {threads}-way par_for_each, scoped spawn vs pooled vs pinned\n\
         (pinned pool NUMA-pinned: {}, one multiply-add per record)",
        pinned.is_pinned()
    );
    println!("counters: {}\n", llama::counters::status_line());

    for (label, n) in sizes {
        let e = (Dyn(n as u32),);
        {
            let mut v = alloc_view(SoA::<P, _>::new(e), &HeapAlloc);
            b.bench(&format!("par_for_each {label:<6} {threads}T scoped"), n as u64, || {
                v.par_for_each_scoped_with(threads, |r| {
                    let x = r.field(p::x);
                    r.set_field(p::x, x * 1.000001 + 1.0);
                });
                black_box(&v);
            });
        }
        {
            let mut v = alloc_view(SoA::<P, _>::new(e), &HeapAlloc);
            b.bench(&format!("par_for_each {label:<6} {threads}T pooled"), n as u64, || {
                v.par_for_each_on(&pooled, threads, |r| {
                    let x = r.field(p::x);
                    r.set_field(p::x, x * 1.000001 + 1.0);
                });
                black_box(&v);
            });
        }
        {
            // Pinned pool + first-touch storage: the full NUMA story.
            // Pages are placed by the SAME pool that traverses
            // (`first_touch_on(&pinned, ..)`) so slot k's byte range is
            // resident on the node of the worker that owns shard k.
            let mut v = alloc_view(SoA::<P, _>::new(e), &llama::blob::AlignedAlloc::<4096>);
            llama::pool::first_touch_on(&pinned, v.storage_mut());
            b.bench(&format!("par_for_each {label:<6} {threads}T pooled+pinned"), n as u64, || {
                v.par_for_each_on(&pinned, threads, |r| {
                    let x = r.field(p::x);
                    r.set_field(p::x, x * 1.000001 + 1.0);
                });
                black_box(&v);
            });
        }
    }

    println!("{}", b.render_table("parallel dispatch (per record)", None));

    // Schema guard (smoke mode, i.e. CI): the measurement-key set of
    // BENCH_pool.json must stay diffable across commits.
    if fast {
        let mut want: Vec<String> = Vec::new();
        for (label, _) in sizes {
            for mode in ["scoped", "pooled", "pooled+pinned"] {
                want.push(format!("par_for_each {label:<6} {threads}T {mode}"));
            }
        }
        want.sort();
        let mut got: Vec<String> = b.results().iter().map(|m| m.name.clone()).collect();
        got.sort();
        assert_eq!(got, want, "pool-table measurement keys drifted");
        println!("smoke schema guard OK: {} dispatch keys", got.len());
    }

    let written = llama::bench::emit_json(
        "pool",
        &[
            ("n_small", sizes[0].1.to_string()),
            ("n_medium", sizes[1].1.to_string()),
            ("n_large", sizes[2].1.to_string()),
            ("threads", threads.to_string()),
            ("pinned_effective", (pinned.is_pinned() as u8).to_string()),
            ("smoke", (fast as u8).to_string()),
            ("counters", llama::counters::meta_tag().to_string()),
        ],
        &[("dispatch", &b)],
    )
    .expect("writing LLAMA_BENCH_JSON output");
    if let Some(path) = written {
        println!("perf trajectory written to {}", path.display());
    }
}
