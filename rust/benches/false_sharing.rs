//! Experiment E13 — false sharing on per-worker counters: adjacent
//! `AtomicU64`s on one cache line vs `CachePadded<AtomicU64>` one line
//! apart vs thread-local accumulation with a single final store.
//!
//! This is the conviction instrument behind the E13 audit: the pool's
//! lease word and the `FieldAccessCount` per-field counters follow the
//! same "one hot word per worker/field, words adjacent in a Vec" shape
//! as the `contended` row here. Expected shape: `local-merge ≤ padded ≪
//! contended` at ≥ 2 threads (contended pays a line ping-pong per
//! increment), and `padded ≈ contended` at 1 thread (padding only
//! changes *placement*, not the increment). With counters live
//! (`llama::counters`), the contended row also shows the cache-miss
//! rate the data volume cannot explain — the false-sharing signature
//! wall clock alone can't attribute.
//!
//! Run: `cargo bench --bench false_sharing`  (LLAMA_BENCH_SMOKE=1
//! shrinks to a smoke run; LLAMA_THREADS overrides the worker count,
//! default 4; LLAMA_BENCH_JSON=<dir> writes BENCH_false_sharing.json)

use std::sync::atomic::{AtomicU64, Ordering};

use llama::bench::{black_box, smoke, Bencher};
use llama::pool::WorkerPool;
use llama::util::CachePadded;

fn main() {
    let fast = smoke();
    let threads = llama::shard::thread_count_or(4);
    let iters: u64 = if fast { 20_000 } else { 2_000_000 };
    let mut b = if fast { Bencher::new(1, 3) } else { Bencher::new(3, 15) };

    let pool = WorkerPool::with_pinning(threads, false);
    let items = threads as u64 * iters;

    println!(
        "false sharing: {threads} workers x {iters} increments, \
         each worker on its own counter"
    );
    println!("counters: {}\n", llama::counters::status_line());

    // Row 1: counters adjacent in one Vec — consecutive AtomicU64s,
    // eight to a cache line, so distinct workers' increments contend.
    {
        let slots: Vec<AtomicU64> = (0..threads).map(|_| AtomicU64::new(0)).collect();
        b.bench(&format!("increment contended   {threads}T"), items, || {
            pool.run_scoped(
                (0..threads)
                    .map(|k| {
                        let slot = &slots[k];
                        move || {
                            for _ in 0..iters {
                                slot.fetch_add(1, Ordering::Relaxed);
                            }
                        }
                    })
                    .collect::<Vec<_>>(),
            );
            black_box(&slots);
        });
    }

    // Row 2: the E13 fix — one counter per cache line. Same atomic
    // traffic per worker, no cross-worker line bouncing.
    {
        let slots: Vec<CachePadded<AtomicU64>> =
            (0..threads).map(|_| CachePadded::new(AtomicU64::new(0))).collect();
        b.bench(&format!("increment padded      {threads}T"), items, || {
            pool.run_scoped(
                (0..threads)
                    .map(|k| {
                        let slot = &slots[k];
                        move || {
                            for _ in 0..iters {
                                slot.fetch_add(1, Ordering::Relaxed);
                            }
                        }
                    })
                    .collect::<Vec<_>>(),
            );
            black_box(&slots);
        });
    }

    // Row 3: the no-sharing floor — accumulate thread-locally, publish
    // once. What the padded row would cost if the atomic RMW itself
    // were free of coherence traffic.
    {
        let slots: Vec<CachePadded<AtomicU64>> =
            (0..threads).map(|_| CachePadded::new(AtomicU64::new(0))).collect();
        b.bench(&format!("increment local-merge {threads}T"), items, || {
            pool.run_scoped(
                (0..threads)
                    .map(|k| {
                        let slot = &slots[k];
                        move || {
                            let mut local = 0u64;
                            for _ in 0..iters {
                                local = black_box(local + 1);
                            }
                            slot.store(local, Ordering::Relaxed);
                        }
                    })
                    .collect::<Vec<_>>(),
            );
            black_box(&slots);
        });
    }

    println!(
        "{}",
        b.render_table(
            "per-worker counter increment (per increment)",
            Some(&format!("increment contended   {threads}T")),
        )
    );
    println!(
        "expected shape: local-merge <= padded << contended at >=2 threads;\n\
         the pool lease word and FieldAccessCount counters are padded\n\
         (llama::util::CachePadded) on the strength of this row pair —\n\
         rust/tests/false_sharing.rs pins padded <= contended."
    );

    // Schema guard (smoke mode, i.e. CI): the measurement-key set of
    // BENCH_false_sharing.json must stay diffable across commits.
    if fast {
        let mut want: Vec<String> = vec![
            format!("increment contended   {threads}T"),
            format!("increment padded      {threads}T"),
            format!("increment local-merge {threads}T"),
        ];
        want.sort();
        let mut got: Vec<String> = b.results().iter().map(|m| m.name.clone()).collect();
        got.sort();
        assert_eq!(got, want, "false-sharing-table measurement keys drifted");
        println!("smoke schema guard OK: {} false-sharing keys", got.len());
    }

    let written = llama::bench::emit_json(
        "false_sharing",
        &[
            ("iters", iters.to_string()),
            ("threads", threads.to_string()),
            ("smoke", (fast as u8).to_string()),
            ("counters", llama::counters::meta_tag().to_string()),
        ],
        &[("false_sharing", &b)],
    )
    .expect("writing LLAMA_BENCH_JSON output");
    if let Some(path) = written {
        println!("perf trajectory written to {}", path.display());
    }
}
