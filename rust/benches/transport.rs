//! Experiment E11 — view transport: the wire encode/decode cost ladder.
//!
//! The serving tier ships views between processes as a versioned wire
//! message (`llama::transport`): a header describing record layout,
//! extents, and mapping identity plus one packed field-major payload
//! blob. Encoding is a layout-aware copy into the wire mapping, decoding
//! either adopts the payload bytes directly (zero relayout) or streams
//! them into the receiver's mapping through the run-based copy engine.
//!
//! Rows record that ladder: run-based encode vs the field-wise fallback,
//! zero-copy adopt vs run-based relayout (serial and parallel) vs the
//! scalar fallback, and the raw header+payload framing. Every decode row
//! pays one payload clone per iteration (messages are consumed); the
//! cost is identical across rows, so the ladder's shape is unaffected.
//!
//! Run: `cargo bench --bench transport [-- N]`  (default N=524288;
//! LLAMA_BENCH_SMOKE=1 shrinks to a smoke run; LLAMA_THREADS overrides
//! the parallel rows' worker count, default 4; LLAMA_BENCH_JSON=<dir>
//! writes BENCH_transport.json)

use llama::bench::{black_box, smoke, Bencher};
use llama::blob::{alloc_view, HeapAlloc};
use llama::copy::CopyStrategy;
use llama::extents::Dyn;
use llama::mapping::aos::AoS;
use llama::mapping::aosoa::AoSoA;
use llama::mapping::soa::{MultiBlob, SoA};
use llama::transport::{decode_adopt, decode_into, decode_into_par, encode, encode_par, WireMsg};

llama::record! {
    pub struct Particle, mod particle {
        pos: { x: f32, y: f32, z: f32 },
        vel: { x: f32, y: f32, z: f32 },
        mass: f32,
    }
}

fn main() {
    let arg_n: Option<usize> =
        std::env::args().skip(1).find(|a| !a.starts_with('-')).and_then(|a| a.parse().ok());
    let fast = smoke();
    let n = arg_n.unwrap_or(if fast { 4096 } else { 1 << 19 });
    let threads = llama::shard::thread_count_or(4);
    let mut b = if fast { Bencher::new(1, 3) } else { Bencher::new(2, 7) };
    let e = (Dyn(n as u32),);

    println!("view transport: n={n} records ({} B payload), {threads}-thread rows\n", n * 28);

    let mut soa = alloc_view(SoA::<Particle, _, MultiBlob>::new(e), &HeapAlloc);
    let mut aos = alloc_view(AoS::<Particle, _>::new(e), &HeapAlloc);
    for i in 0..n {
        soa.set_t([i], particle::pos::x, i as f32);
        soa.set_t([i], particle::pos::y, -(i as f32));
        soa.set_t([i], particle::pos::z, 0.5 * i as f32);
        soa.set_t([i], particle::vel::x, 1.0);
        soa.set_t([i], particle::vel::y, -1.0);
        soa.set_t([i], particle::vel::z, 0.0);
        soa.set_t([i], particle::mass, 1.0 + (i % 7) as f32);
        aos.set_t([i], particle::mass, 1.0 + (i % 7) as f32);
    }

    // Strategy guards, as in the copy bench: every row must exercise the
    // path its name claims — a silent fallback fails CI smoke instead of
    // corrupting the trajectory.
    assert_eq!(encode(&soa).strategy, CopyStrategy::FieldRuns);
    b.bench("encode SoA-MB -> wire  runs serial", n as u64, || {
        black_box(encode(&soa).payload.len());
    });
    {
        let strat = encode_par(&soa, threads).strategy;
        if threads >= 2 && n >= threads {
            assert_eq!(strat, CopyStrategy::FieldRunsPar);
        }
        b.bench(&format!("encode SoA-MB -> wire  runs {threads}T"), n as u64, || {
            black_box(encode_par(&soa, threads).payload.len());
        });
    }
    assert_eq!(encode(&aos).strategy, CopyStrategy::FieldWise);
    b.bench("encode AoS    -> wire  field-wise", n as u64, || {
        black_box(encode(&aos).payload.len());
    });

    let msg = encode(&soa);

    // Zero-copy adopt: header validation + taking ownership of the
    // payload bytes. The per-iteration msg clone IS the row's memcpy —
    // adopt itself moves no payload bytes.
    b.bench("decode wire -> wire    adopt", n as u64, || {
        let v = decode_adopt::<Particle, _>(msg.clone(), e).expect("adopt");
        black_box(v.get_t([n - 1], particle::mass));
    });
    {
        let mut dst = alloc_view(SoA::<Particle, _, MultiBlob>::new(e), &HeapAlloc);
        assert_eq!(decode_into(msg.clone(), &mut dst).expect("decode"), CopyStrategy::FieldRuns);
        b.bench("decode wire -> SoA-MB  runs serial", n as u64, || {
            black_box(decode_into(msg.clone(), &mut dst).expect("decode"));
        });
    }
    {
        let mut dst = alloc_view(AoSoA::<Particle, _, 8>::new(e), &HeapAlloc);
        assert_eq!(decode_into(msg.clone(), &mut dst).expect("decode"), CopyStrategy::FieldRuns);
        b.bench("decode wire -> AoSoA8  runs serial", n as u64, || {
            black_box(decode_into(msg.clone(), &mut dst).expect("decode"));
        });
    }
    {
        let mut dst = alloc_view(AoSoA::<Particle, _, 8>::new(e), &HeapAlloc);
        let strat = decode_into_par(msg.clone(), &mut dst, threads).expect("decode");
        if threads >= 2 && n >= threads {
            assert_eq!(strat, CopyStrategy::FieldRunsPar);
        }
        b.bench(&format!("decode wire -> AoSoA8  runs {threads}T"), n as u64, || {
            black_box(decode_into_par(msg.clone(), &mut dst, threads).expect("decode"));
        });
    }
    {
        let mut dst = alloc_view(AoS::<Particle, _>::new(e), &HeapAlloc);
        assert_eq!(decode_into(msg.clone(), &mut dst).expect("decode"), CopyStrategy::FieldWise);
        b.bench("decode wire -> AoS     field-wise", n as u64, || {
            black_box(decode_into(msg.clone(), &mut dst).expect("decode"));
        });
    }

    // Raw framing: serialize header + payload into a reused buffer and
    // parse it back (the cost a socket adds on top of encode/decode).
    // Since wire v2 both directions run every byte through the frame
    // checksum, so this row includes two CRC passes.
    {
        let mut buf = Vec::with_capacity(msg.frame_len());
        b.bench("frame  write + parse   header+payload", n as u64, || {
            buf.clear();
            msg.write_to(&mut buf).expect("write frame");
            let parsed = WireMsg::read_from(&mut buf.as_slice()).expect("parse frame");
            black_box(parsed.payload.len());
        });
    }

    // The checksum alone, over the payload bytes — the incremental cost
    // v2 integrity added to each frame direction, isolated from the
    // header serialization around it.
    b.bench("frame  crc32           payload", n as u64, || {
        black_box(llama::transport::crc32(&msg.payload));
    });

    println!(
        "{}",
        b.render_table("view transport (per record)", Some("decode wire -> AoS     field-wise"))
    );

    // Schema guard (smoke mode, i.e. CI): the measurement-key set of
    // BENCH_transport.json must stay diffable across commits.
    if fast {
        let mut want: Vec<String> = vec![
            "encode SoA-MB -> wire  runs serial".into(),
            format!("encode SoA-MB -> wire  runs {threads}T"),
            "encode AoS    -> wire  field-wise".into(),
            "decode wire -> wire    adopt".into(),
            "decode wire -> SoA-MB  runs serial".into(),
            "decode wire -> AoSoA8  runs serial".into(),
            format!("decode wire -> AoSoA8  runs {threads}T"),
            "decode wire -> AoS     field-wise".into(),
            "frame  write + parse   header+payload".into(),
            "frame  crc32           payload".into(),
        ];
        want.sort();
        let mut got: Vec<String> = b.results().iter().map(|m| m.name.clone()).collect();
        got.sort();
        assert_eq!(got, want, "transport-table measurement keys drifted");
        println!("smoke schema guard OK: {} transport keys", got.len());
    }

    println!("counters: {}", llama::counters::status_line());

    let written = llama::bench::emit_json(
        "transport",
        &[
            ("n", n.to_string()),
            ("threads", threads.to_string()),
            ("smoke", (fast as u8).to_string()),
            ("counters", llama::counters::meta_tag().to_string()),
        ],
        &[("transport", &b)],
    )
    .expect("writing LLAMA_BENCH_JSON output");
    if let Some(path) = written {
        println!("perf trajectory written to {}", path.display());
    }
}
