//! Experiment E8 — §2 compile-time extents & index types.
//!
//! Two claims: (a) fully static extents make views zero-memory-overhead
//! trivial value types (a size table, asserted); (b) the index type used
//! for address arithmetic matters — narrower types help on hardware with
//! slow 64-bit integer ops (on x86-64 the effect is small; the *knob* is
//! what is reproduced, plus static-extent strength reduction, which lets
//! LLVM fold the linearization entirely).
//!
//! Run: `cargo bench --bench extents`

use llama::bench::{black_box, Bencher};
use llama::blob::{alloc_view, array_view, HeapAlloc};
use llama::extents::{Dyn, Extents, Fix};
use llama::mapping::soa::{SingleBlob, SoA};
use llama::mapping::Mapping;

llama::record! {
    pub struct Cell, mod cell {
        v: f32,
        w: f32,
    }
}

const SIDE: usize = 256; // 256x256 grid

fn main() {
    let fast = llama::bench::smoke();
    let mut b = if fast { Bencher::new(1, 3) } else { Bencher::new(2, 9) };
    let reps: usize = if fast { 2 } else { 8 };
    let items = (SIDE * SIDE * reps) as u64;

    println!("E8: §2 extents — index width & static extents, {SIDE}x{SIDE} stencil\n");

    // ---- size table: the zero-overhead claim ----
    println!("view size table (mapping state + blob handles):");
    type Edyn64 = (Dyn<u64>, Dyn<u64>);
    type Edyn32 = (Dyn<u32>, Dyn<u32>);
    type Edyn16 = (Dyn<u16>, Dyn<u16>);
    type Estat = (Fix<u32, SIDE>, Fix<u32, SIDE>);
    println!("  extents (u64,u64) dynamic : {:>3} B state", std::mem::size_of::<Edyn64>());
    println!("  extents (u32,u32) dynamic : {:>3} B state", std::mem::size_of::<Edyn32>());
    println!("  extents (u16,u16) dynamic : {:>3} B state", std::mem::size_of::<Edyn16>());
    println!("  extents static            : {:>3} B state (zero, §2)", std::mem::size_of::<Estat>());
    type Mstat = SoA<Cell, Estat, SingleBlob>;
    assert_eq!(std::mem::size_of::<Mstat>(), 0);
    let v = array_view::<Cell, Mstat, { SIDE * SIDE * 8 }, 1>(Mstat::new((Fix::new(), Fix::new())));
    println!(
        "  static view               : {} B == mapped data {} B\n",
        std::mem::size_of_val(&v),
        Mstat::new((Fix::new(), Fix::new())).blob_size(0)
    );

    // ---- index-arithmetic sweep: 2D gather sum with wrap ----
    // The wrapping neighbour access defeats trivial strength reduction, so
    // per-access linearization (in the chosen index type) stays live.
    // The typed access API fixes the index rank in the type: rank-2 is a
    // *bound* here, and `[i, j]` literals need no per-access rank checks.
    fn stencil<E: Extents<ArrayIndex = [usize; 2]>>(
        b: &mut Bencher,
        name: &str,
        e: E,
        items: u64,
        reps: usize,
    ) {
        let m = SoA::<Cell, E, SingleBlob>::new(e);
        let mut view = alloc_view(m, &HeapAlloc);
        for i in 0..SIDE {
            for j in 0..SIDE {
                view.set_t([i, j], cell::v, (i * j) as f32);
            }
        }
        b.bench(name, items, || {
            let mut acc = 0.0f32;
            for _ in 0..reps {
                for i in 0..SIDE {
                    let iu = (i + SIDE - 1) % SIDE;
                    let id = (i + 1) % SIDE;
                    for j in 0..SIDE {
                        let jl = (j + SIDE - 1) % SIDE;
                        let jr = (j + 1) % SIDE;
                        acc += view.get_t([iu, j], cell::v)
                            + view.get_t([id, j], cell::v)
                            + view.get_t([i, jl], cell::v)
                            + view.get_t([i, jr], cell::v);
                    }
                }
            }
            black_box(acc);
        });
    }

    stencil(&mut b, "stencil u64 dynamic", (Dyn(SIDE as u64), Dyn(SIDE as u64)), items, reps);
    stencil(&mut b, "stencil u32 dynamic", (Dyn(SIDE as u32), Dyn(SIDE as u32)), items, reps);
    stencil(&mut b, "stencil u16 dynamic", (Dyn(SIDE as u16), Dyn(SIDE as u16)), items, reps);
    stencil(
        &mut b,
        "stencil u32 static",
        (Fix::<u32, SIDE>::new(), Fix::<u32, SIDE>::new()),
        items,
        reps,
    );

    let table = b.render_table("index-type / static-extent stencil", Some("stencil u64 dynamic"));
    println!("{table}");
    println!(
        "paper context: 64-bit integer mul is slow on GPUs (absent on Hopper);\n\
         on this x86-64 CPU expect small deltas, with static extents enabling\n\
         constant-folded linearization (the shared-memory-view use case)."
    );

    println!("counters: {}", llama::counters::status_line());

    llama::bench::emit_json(
        "extents",
        &[
            ("side", SIDE.to_string()),
            ("reps", reps.to_string()),
            ("counters", llama::counters::meta_tag().to_string()),
        ],
        &[("stencil", &b)],
    )
    .expect("writing LLAMA_BENCH_JSON output");
}
