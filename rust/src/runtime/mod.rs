//! PJRT runtime: load and execute the AOT-compiled JAX/Pallas artifacts.
//!
//! The build-time Python layer (`python/compile/aot.py`) lowers each model
//! variant to HLO *text* under `artifacts/` (text, not serialized proto —
//! the image's xla_extension 0.5.1 rejects jax≥0.5's 64-bit-id protos).
//! [`Engine`] compiles each artifact once on the PJRT CPU client and
//! caches the loaded executable; the L3 coordinator then executes
//! simulation steps with zero Python on the request path.
//!
//! The PJRT path needs the external `xla` crate, which the offline build
//! image cannot fetch; it is gated behind the **`pjrt`** cargo feature
//! (enabling it requires adding the `xla` dependency yourself). Without
//! the feature, [`Engine::cpu`] and [`PjrtService::spawn`] return an
//! error and every caller degrades gracefully — the coordinator reports
//! PJRT jobs as failed, tests skip, the CLI prints a warning.

use std::path::PathBuf;

/// Default artifacts directory (relative to the repo root).
pub const ARTIFACTS_DIR: &str = "artifacts";

/// Names of the n-body step artifacts produced by `make artifacts`.
pub const NBODY_ARTIFACTS: &[&str] =
    &["nbody_soa", "nbody_aos", "nbody_aosoa", "nbody_bf16", "bitpack_roundtrip"];

/// A typed f32 tensor input for execution.
#[derive(Clone, Debug)]
pub struct TensorF32 {
    /// Row-major data.
    pub data: Vec<f32>,
    /// Shape.
    pub dims: Vec<usize>,
}

impl TensorF32 {
    /// 1-D tensor.
    pub fn vec(data: Vec<f32>) -> Self {
        let n = data.len();
        TensorF32 { data, dims: vec![n] }
    }

    /// n-D tensor (row-major `data`, `dims` product must match length).
    pub fn new(data: Vec<f32>, dims: Vec<usize>) -> Self {
        assert_eq!(data.len(), dims.iter().product::<usize>());
        TensorF32 { data, dims }
    }
}

/// Locate the repo's artifacts directory from the current/executable dir.
pub fn default_artifacts_dir() -> PathBuf {
    // Prefer $LLAMA_ARTIFACTS, then ./artifacts relative to cwd, then the
    // crate directory (useful under `cargo test`).
    if let Ok(p) = std::env::var("LLAMA_ARTIFACTS") {
        return PathBuf::from(p);
    }
    let cwd = PathBuf::from(ARTIFACTS_DIR);
    if cwd.exists() {
        return cwd;
    }
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join(ARTIFACTS_DIR)
}

#[cfg(feature = "pjrt")]
mod engine_impl {
    use std::collections::HashMap;
    use std::path::{Path, PathBuf};
    use std::sync::{mpsc, Mutex};

    use anyhow::{anyhow, Context, Result};

    use super::TensorF32;

    /// PJRT execution engine with an executable cache.
    ///
    /// Compilation happens once per artifact (at [`Engine::load`] or first
    /// use); execution is thread-safe through an internal mutex — PJRT CPU
    /// executions are short and the coordinator batches around this.
    pub struct Engine {
        client: xla::PjRtClient,
        cache: Mutex<HashMap<String, xla::PjRtLoadedExecutable>>,
        artifacts_dir: PathBuf,
    }

    impl Engine {
        /// Engine on the PJRT CPU client, loading from `artifacts_dir`.
        pub fn cpu(artifacts_dir: impl Into<PathBuf>) -> Result<Self> {
            let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
            Ok(Engine {
                client,
                cache: Mutex::new(HashMap::new()),
                artifacts_dir: artifacts_dir.into(),
            })
        }

        /// Platform name of the underlying client (e.g. "cpu", "Host").
        pub fn platform(&self) -> String {
            self.client.platform_name()
        }

        /// Path of artifact `name`.
        pub fn artifact_path(&self, name: &str) -> PathBuf {
            self.artifacts_dir.join(format!("{name}.hlo.txt"))
        }

        /// Whether the artifact file exists (used by tests/CLI to skip
        /// gracefully before `make artifacts` has run).
        pub fn artifact_available(&self, name: &str) -> bool {
            self.artifact_path(name).exists()
        }

        /// Compile and cache the artifact `name` from disk.
        pub fn load(&self, name: &str) -> Result<()> {
            let path = self.artifact_path(name);
            self.load_path(name, &path)
        }

        /// Compile and cache an explicit HLO-text file under `name`.
        pub fn load_path(&self, name: &str, path: &Path) -> Result<()> {
            let mut cache = self.cache.lock().unwrap();
            if cache.contains_key(name) {
                return Ok(());
            }
            let proto = xla::HloModuleProto::from_text_file(
                path.to_str().ok_or_else(|| anyhow!("non-utf8 path"))?,
            )
            .with_context(|| format!("parsing HLO text {path:?}"))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = self.client.compile(&comp).with_context(|| format!("compiling {name}"))?;
            cache.insert(name.to_string(), exe);
            Ok(())
        }

        /// Names currently cached.
        pub fn loaded(&self) -> Vec<String> {
            self.cache.lock().unwrap().keys().cloned().collect()
        }

        /// Execute cached executable `name` on f32 inputs, returning all f32
        /// outputs (the artifacts are lowered with `return_tuple=True`).
        pub fn execute_f32(&self, name: &str, inputs: &[TensorF32]) -> Result<Vec<TensorF32>> {
            let literals: Vec<xla::Literal> = inputs
                .iter()
                .map(|t| {
                    let dims: Vec<i64> = t.dims.iter().map(|&d| d as i64).collect();
                    xla::Literal::vec1(&t.data).reshape(&dims).context("reshaping input")
                })
                .collect::<Result<_>>()?;
            let parts = self.execute_literals(name, &literals)?;
            parts
                .into_iter()
                .map(|lit| {
                    let shape = lit.array_shape()?;
                    let dims: Vec<usize> = shape.dims().iter().map(|&d| d as usize).collect();
                    let data = lit.to_vec::<f32>()?;
                    Ok(TensorF32 { data, dims })
                })
                .collect()
        }

        /// Execute on u32 inputs (the bitpack artifacts), returning u32
        /// outputs as `(data, dims)` pairs.
        pub fn execute_u32(
            &self,
            name: &str,
            inputs: &[(Vec<u32>, Vec<usize>)],
        ) -> Result<Vec<(Vec<u32>, Vec<usize>)>> {
            let literals: Vec<xla::Literal> = inputs
                .iter()
                .map(|(data, dims)| {
                    let d: Vec<i64> = dims.iter().map(|&x| x as i64).collect();
                    xla::Literal::vec1(data.as_slice()).reshape(&d).context("reshaping input")
                })
                .collect::<Result<_>>()?;
            let parts = self.execute_literals(name, &literals)?;
            parts
                .into_iter()
                .map(|lit| {
                    let shape = lit.array_shape()?;
                    let dims: Vec<usize> = shape.dims().iter().map(|&d| d as usize).collect();
                    let data = lit.to_vec::<u32>()?;
                    Ok((data, dims))
                })
                .collect()
        }

        /// Shared execute path: run `name` on prepared literals, untuple.
        fn execute_literals(
            &self,
            name: &str,
            literals: &[xla::Literal],
        ) -> Result<Vec<xla::Literal>> {
            let cache = self.cache.lock().unwrap();
            let exe = cache.get(name).ok_or_else(|| anyhow!("artifact '{name}' not loaded"))?;
            let result = exe.execute::<xla::Literal>(literals).context("executing")?;
            let out = result[0][0].to_literal_sync().context("fetching result")?;
            out.to_tuple().context("untupling result")
        }
    }

    impl std::fmt::Debug for Engine {
        fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
            f.debug_struct("Engine")
                .field("artifacts_dir", &self.artifacts_dir)
                .field("loaded", &self.loaded())
                .finish()
        }
    }

    /// Requests served by the PJRT executor thread.
    enum Request {
        Load(String, mpsc::Sender<Result<()>>),
        Available(String, mpsc::Sender<bool>),
        Platform(mpsc::Sender<String>),
        ExecF32(String, Vec<TensorF32>, mpsc::Sender<Result<Vec<TensorF32>>>),
        ExecU32(
            String,
            Vec<(Vec<u32>, Vec<usize>)>,
            mpsc::Sender<Result<Vec<(Vec<u32>, Vec<usize>)>>>,
        ),
    }

    /// Thread-safe handle to a PJRT [`Engine`] running on a dedicated
    /// executor thread.
    ///
    /// The `xla` crate's PJRT client is not `Send` (internal `Rc`s), so the
    /// engine lives on one thread and the coordinator's workers talk to it
    /// via channels — which is also where cross-job batching naturally
    /// serializes. Handles are cheaply cloneable.
    #[derive(Clone)]
    pub struct PjrtService {
        tx: mpsc::Sender<Request>,
    }

    impl PjrtService {
        /// Spawn the executor thread with an engine over `artifacts_dir`.
        pub fn spawn(artifacts_dir: impl Into<PathBuf>) -> Result<Self> {
            let dir = artifacts_dir.into();
            let (tx, rx) = mpsc::channel::<Request>();
            let (ready_tx, ready_rx) = mpsc::channel::<Result<()>>();
            std::thread::Builder::new()
                .name("pjrt-executor".into())
                .spawn(move || {
                    let engine = match Engine::cpu(dir) {
                        Ok(e) => {
                            let _ = ready_tx.send(Ok(()));
                            e
                        }
                        Err(e) => {
                            let _ = ready_tx.send(Err(e));
                            return;
                        }
                    };
                    while let Ok(req) = rx.recv() {
                        match req {
                            Request::Load(name, reply) => {
                                let _ = reply.send(engine.load(&name));
                            }
                            Request::Available(name, reply) => {
                                let _ = reply.send(engine.artifact_available(&name));
                            }
                            Request::Platform(reply) => {
                                let _ = reply.send(engine.platform());
                            }
                            Request::ExecF32(name, inputs, reply) => {
                                let _ = reply.send(engine.execute_f32(&name, &inputs));
                            }
                            Request::ExecU32(name, inputs, reply) => {
                                let _ = reply.send(engine.execute_u32(&name, &inputs));
                            }
                        }
                    }
                })
                .context("spawning pjrt-executor")?;
            ready_rx.recv().context("pjrt-executor died")??;
            Ok(PjrtService { tx })
        }

        /// See [`Engine::load`].
        pub fn load(&self, name: &str) -> Result<()> {
            let (tx, rx) = mpsc::channel();
            self.tx
                .send(Request::Load(name.to_string(), tx))
                .map_err(|_| anyhow!("executor gone"))?;
            rx.recv().context("executor gone")?
        }

        /// See [`Engine::artifact_available`].
        pub fn artifact_available(&self, name: &str) -> bool {
            let (tx, rx) = mpsc::channel();
            if self.tx.send(Request::Available(name.to_string(), tx)).is_err() {
                return false;
            }
            rx.recv().unwrap_or(false)
        }

        /// See [`Engine::platform`].
        pub fn platform(&self) -> String {
            let (tx, rx) = mpsc::channel();
            if self.tx.send(Request::Platform(tx)).is_err() {
                return "unavailable".into();
            }
            rx.recv().unwrap_or_else(|_| "unavailable".into())
        }

        /// See [`Engine::execute_f32`].
        pub fn execute_f32(&self, name: &str, inputs: &[TensorF32]) -> Result<Vec<TensorF32>> {
            let (tx, rx) = mpsc::channel();
            self.tx
                .send(Request::ExecF32(name.to_string(), inputs.to_vec(), tx))
                .map_err(|_| anyhow!("executor gone"))?;
            rx.recv().context("executor gone")?
        }

        /// See [`Engine::execute_u32`].
        pub fn execute_u32(
            &self,
            name: &str,
            inputs: &[(Vec<u32>, Vec<usize>)],
        ) -> Result<Vec<(Vec<u32>, Vec<usize>)>> {
            let (tx, rx) = mpsc::channel();
            self.tx
                .send(Request::ExecU32(name.to_string(), inputs.to_vec(), tx))
                .map_err(|_| anyhow!("executor gone"))?;
            rx.recv().context("executor gone")?
        }
    }

    impl std::fmt::Debug for PjrtService {
        fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
            f.debug_struct("PjrtService").finish()
        }
    }
}

#[cfg(not(feature = "pjrt"))]
mod engine_impl {
    use std::path::{Path, PathBuf};

    use anyhow::{anyhow, Result};

    use super::TensorF32;

    const DISABLED: &str =
        "PJRT runtime requires the `pjrt` feature (the `xla` crate is not vendored offline)";

    /// Stub engine: the build carries no PJRT client. [`Engine::cpu`]
    /// always errors; the type exists so callers compile unchanged.
    #[derive(Debug)]
    pub struct Engine {
        artifacts_dir: PathBuf,
    }

    impl Engine {
        /// Always fails: this build has no PJRT client.
        pub fn cpu(artifacts_dir: impl Into<PathBuf>) -> Result<Self> {
            let _ = artifacts_dir.into();
            Err(anyhow!(DISABLED))
        }

        /// Platform name ("unavailable" in the stub).
        pub fn platform(&self) -> String {
            "unavailable".into()
        }

        /// Path of artifact `name`.
        pub fn artifact_path(&self, name: &str) -> PathBuf {
            self.artifacts_dir.join(format!("{name}.hlo.txt"))
        }

        /// Whether the artifact file exists on disk.
        pub fn artifact_available(&self, name: &str) -> bool {
            self.artifact_path(name).exists()
        }

        /// Always fails in the stub.
        pub fn load(&self, _name: &str) -> Result<()> {
            Err(anyhow!(DISABLED))
        }

        /// Always fails in the stub.
        pub fn load_path(&self, _name: &str, _path: &Path) -> Result<()> {
            Err(anyhow!(DISABLED))
        }

        /// Names currently cached (always empty in the stub).
        pub fn loaded(&self) -> Vec<String> {
            Vec::new()
        }

        /// Always fails in the stub.
        pub fn execute_f32(&self, _name: &str, _inputs: &[TensorF32]) -> Result<Vec<TensorF32>> {
            Err(anyhow!(DISABLED))
        }

        /// Always fails in the stub.
        pub fn execute_u32(
            &self,
            _name: &str,
            _inputs: &[(Vec<u32>, Vec<usize>)],
        ) -> Result<Vec<(Vec<u32>, Vec<usize>)>> {
            Err(anyhow!(DISABLED))
        }
    }

    /// Stub service handle; [`PjrtService::spawn`] always errors.
    #[derive(Clone, Debug)]
    pub struct PjrtService {
        _priv: (),
    }

    impl PjrtService {
        /// Always fails: this build has no PJRT client.
        pub fn spawn(artifacts_dir: impl Into<PathBuf>) -> Result<Self> {
            let _ = artifacts_dir.into();
            Err(anyhow!(DISABLED))
        }

        /// Always fails in the stub.
        pub fn load(&self, _name: &str) -> Result<()> {
            Err(anyhow!(DISABLED))
        }

        /// Always `false` in the stub.
        pub fn artifact_available(&self, _name: &str) -> bool {
            false
        }

        /// Platform name ("unavailable" in the stub).
        pub fn platform(&self) -> String {
            "unavailable".into()
        }

        /// Always fails in the stub.
        pub fn execute_f32(&self, _name: &str, _inputs: &[TensorF32]) -> Result<Vec<TensorF32>> {
            Err(anyhow!(DISABLED))
        }

        /// Always fails in the stub.
        pub fn execute_u32(
            &self,
            _name: &str,
            _inputs: &[(Vec<u32>, Vec<usize>)],
        ) -> Result<Vec<(Vec<u32>, Vec<usize>)>> {
            Err(anyhow!(DISABLED))
        }
    }
}

pub use engine_impl::{Engine, PjrtService};

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tensor_constructors_validate_shape() {
        let t = TensorF32::vec(vec![1.0, 2.0, 3.0]);
        assert_eq!(t.dims, vec![3]);
        let t = TensorF32::new(vec![0.0; 12], vec![3, 4]);
        assert_eq!(t.dims, vec![3, 4]);
    }

    #[test]
    #[should_panic]
    fn tensor_shape_mismatch_panics() {
        let _ = TensorF32::new(vec![0.0; 5], vec![3, 4]);
    }

    #[cfg(not(feature = "pjrt"))]
    #[test]
    fn stub_engine_fails_loudly_but_gracefully() {
        assert!(Engine::cpu("artifacts").is_err());
        let e = PjrtService::spawn("artifacts").unwrap_err();
        assert!(format!("{e:#}").contains("pjrt"));
    }

    #[test]
    fn artifacts_dir_env_override() {
        // Don't mutate the process env (tests run in parallel); just check
        // the fallback is a sensible path.
        let d = default_artifacts_dir();
        assert!(d.to_string_lossy().contains("artifacts"));
    }
}
