//! Explicit SIMD support (paper §5).
//!
//! Rust stable has no `std::simd`, so [`Simd<T, N>`] is a fixed-width value
//! type over `[T; N]` whose element-wise operations are written as
//! fixed-trip-count loops — the pattern LLVM reliably auto-vectorizes at
//! `opt-level=3` into packed SIMD instructions (the same contract
//! `std::experimental::simd` discharges via intrinsics in C++). The
//! explicit-width programming model of the paper is preserved:
//! algorithms are written against a flexible `N` fixed at compile time.
//!
//! Table 1 of the paper (`SimdN<T, N>`) is reproduced by [`SimdN`] +
//! [`Simdize`]: for a scalar `T`, `SimdN<T, N>` is `Simd<T, N>`; for
//! `N == 1` the scalar itself is used (here: `Simd<T, 1>`, which is
//! layout- and codegen-identical to `T`, asserted in tests). Records are
//! simdized via the `record!`-generated `Rec<W>` structs over a [`Wrap`]
//! policy — `Rec<SimdW<N>>` is the simdized record, `Rec<ScalarW>` the
//! scalar one.
//!
//! Layout-aware `loadSimd`/`storeSimd` live on the mappings
//! ([`crate::mapping::SimdAccess`]) and on [`crate::view::View`]: SoA and
//! in-block AoSoA lower to contiguous vector moves; AoS keeps per-lane
//! scalar loads (the paper found these *faster* than hardware gathers on
//! the tested CPU). The typed entry points
//! ([`crate::view::View::load_simd_t`], [`crate::view::Chunk::load_t`])
//! infer the lane element type from the field tag, so a lane-type
//! mismatch is a compile error; the legacy `T`-explicit methods remain
//! for index-driven code.

use crate::record::Scalar;

/// Element types eligible for [`Simd`] arithmetic.
pub trait SimdElem: Scalar {
    /// Element addition.
    fn el_add(self, rhs: Self) -> Self;
    /// Element subtraction.
    fn el_sub(self, rhs: Self) -> Self;
    /// Element multiplication.
    fn el_mul(self, rhs: Self) -> Self;
    /// Element division.
    fn el_div(self, rhs: Self) -> Self;
    /// Element fused (or contracted) multiply-add `self * a + b`.
    fn el_mul_add(self, a: Self, b: Self) -> Self;
    /// Element square root (integer types: via `f64`).
    fn el_sqrt(self) -> Self;
    /// Element minimum.
    fn el_min(self, rhs: Self) -> Self;
    /// Element maximum.
    fn el_max(self, rhs: Self) -> Self;
}

macro_rules! impl_simd_elem_float {
    ($($t:ty),*) => {$(
        impl SimdElem for $t {
            #[inline(always)] fn el_add(self, r: Self) -> Self { self + r }
            #[inline(always)] fn el_sub(self, r: Self) -> Self { self - r }
            #[inline(always)] fn el_mul(self, r: Self) -> Self { self * r }
            #[inline(always)] fn el_div(self, r: Self) -> Self { self / r }
            #[inline(always)] fn el_mul_add(self, a: Self, b: Self) -> Self {
                // Plain multiply-add: lets LLVM contract to FMA under the
                // target features without forcing a libm call per lane.
                self * a + b
            }
            #[inline(always)] fn el_sqrt(self) -> Self { self.sqrt() }
            #[inline(always)] fn el_min(self, r: Self) -> Self { if self < r { self } else { r } }
            #[inline(always)] fn el_max(self, r: Self) -> Self { if self > r { self } else { r } }
        }
    )*};
}

impl_simd_elem_float!(f32, f64);

macro_rules! impl_simd_elem_int {
    ($($t:ty),*) => {$(
        impl SimdElem for $t {
            #[inline(always)] fn el_add(self, r: Self) -> Self { self.wrapping_add(r) }
            #[inline(always)] fn el_sub(self, r: Self) -> Self { self.wrapping_sub(r) }
            #[inline(always)] fn el_mul(self, r: Self) -> Self { self.wrapping_mul(r) }
            #[inline(always)] fn el_div(self, r: Self) -> Self {
                if r == 0 { 0 } else { self.wrapping_div(r) }
            }
            #[inline(always)] fn el_mul_add(self, a: Self, b: Self) -> Self {
                self.wrapping_mul(a).wrapping_add(b)
            }
            #[inline(always)] fn el_sqrt(self) -> Self { (self as f64).sqrt() as $t }
            #[inline(always)] fn el_min(self, r: Self) -> Self { if self < r { self } else { r } }
            #[inline(always)] fn el_max(self, r: Self) -> Self { if self > r { self } else { r } }
        }
    )*};
}

impl_simd_elem_int!(i8, i16, i32, i64, u8, u16, u32, u64);

/// A fixed-width SIMD value: `N` lanes of `T`.
///
/// `Simd<T, 1>` is the scalar case of Table 1: one lane, no vector
/// constructs in the generated code.
#[derive(Clone, Copy, Debug, PartialEq)]
#[repr(transparent)]
pub struct Simd<T, const N: usize>(pub [T; N]);

impl<T: SimdElem, const N: usize> Default for Simd<T, N> {
    #[inline(always)]
    fn default() -> Self {
        Simd([T::default(); N])
    }
}

impl<T: SimdElem, const N: usize> Simd<T, N> {
    /// Number of lanes.
    pub const LANES: usize = N;

    /// All lanes set to `v`.
    #[inline(always)]
    pub fn splat(v: T) -> Self {
        Simd([v; N])
    }

    /// Load from a slice of at least `N` elements.
    #[inline(always)]
    pub fn from_slice(s: &[T]) -> Self {
        let mut a = [T::default(); N];
        a.copy_from_slice(&s[..N]);
        Simd(a)
    }

    /// Write the lanes into a slice of at least `N` elements.
    #[inline(always)]
    pub fn write_to_slice(self, s: &mut [T]) {
        s[..N].copy_from_slice(&self.0);
    }

    /// Load `N` little-endian elements from `bytes`
    /// (`bytes.len() == N * T::SIZE`); compiles to a vector move.
    #[inline(always)]
    pub fn from_le_bytes(bytes: &[u8]) -> Self {
        debug_assert_eq!(bytes.len(), N * T::SIZE);
        let mut a = [T::default(); N];
        for (k, lane) in a.iter_mut().enumerate() {
            *lane = T::read_le(&bytes[k * T::SIZE..(k + 1) * T::SIZE]);
        }
        Simd(a)
    }

    /// Store `N` little-endian elements into `bytes`.
    #[inline(always)]
    pub fn write_le_bytes(self, bytes: &mut [u8]) {
        debug_assert_eq!(bytes.len(), N * T::SIZE);
        for k in 0..N {
            self.0[k].write_le(&mut bytes[k * T::SIZE..(k + 1) * T::SIZE]);
        }
    }

    /// Lane-wise fused multiply-add: `self * a + b`.
    #[inline(always)]
    pub fn mul_add(self, a: Self, b: Self) -> Self {
        let mut o = self.0;
        for k in 0..N {
            o[k] = o[k].el_mul_add(a.0[k], b.0[k]);
        }
        Simd(o)
    }

    /// Lane-wise square root.
    #[inline(always)]
    pub fn sqrt(self) -> Self {
        let mut o = self.0;
        for lane in &mut o {
            *lane = lane.el_sqrt();
        }
        Simd(o)
    }

    /// Lane-wise minimum.
    #[inline(always)]
    pub fn min(self, r: Self) -> Self {
        let mut o = self.0;
        for k in 0..N {
            o[k] = o[k].el_min(r.0[k]);
        }
        Simd(o)
    }

    /// Lane-wise maximum.
    #[inline(always)]
    pub fn max(self, r: Self) -> Self {
        let mut o = self.0;
        for k in 0..N {
            o[k] = o[k].el_max(r.0[k]);
        }
        Simd(o)
    }

    /// Horizontal sum of all lanes.
    #[inline(always)]
    pub fn reduce_add(self) -> T {
        let mut acc = self.0[0];
        for k in 1..N {
            acc = acc.el_add(self.0[k]);
        }
        acc
    }

    /// Horizontal minimum.
    #[inline(always)]
    pub fn reduce_min(self) -> T {
        let mut acc = self.0[0];
        for k in 1..N {
            acc = acc.el_min(self.0[k]);
        }
        acc
    }

    /// First lane (the scalar value for `N == 1`).
    #[inline(always)]
    pub fn scalar(self) -> T {
        self.0[0]
    }
}

macro_rules! impl_simd_binop {
    ($trait:ident, $m:ident, $el:ident) => {
        impl<T: SimdElem, const N: usize> std::ops::$trait for Simd<T, N> {
            type Output = Self;
            #[inline(always)]
            fn $m(self, rhs: Self) -> Self {
                let mut o = self.0;
                for k in 0..N {
                    o[k] = o[k].$el(rhs.0[k]);
                }
                Simd(o)
            }
        }
    };
}

impl_simd_binop!(Add, add, el_add);
impl_simd_binop!(Sub, sub, el_sub);
impl_simd_binop!(Mul, mul, el_mul);
impl_simd_binop!(Div, div, el_div);

impl<T: SimdElem, const N: usize> std::ops::AddAssign for Simd<T, N> {
    #[inline(always)]
    fn add_assign(&mut self, rhs: Self) {
        *self = *self + rhs;
    }
}

impl<T: SimdElem, const N: usize> std::ops::SubAssign for Simd<T, N> {
    #[inline(always)]
    fn sub_assign(&mut self, rhs: Self) {
        *self = *self - rhs;
    }
}

// ---------------------------------------------------------------------------
// Table 1: SimdN / simdize
// ---------------------------------------------------------------------------

/// Table 1's `SimdizeN`: maps a scalar type to its `N`-wide SIMD version.
pub trait Simdize<const N: usize> {
    /// The simdized type.
    type Out;
}

impl<T: SimdElem, const N: usize> Simdize<N> for T {
    type Out = Simd<T, N>;
}

/// Table 1's `SimdN<T, N>` for scalar `T`: `Simd<T, N>`; `SimdN<T, 1>` is
/// the one-lane vector, which this library guarantees to be layout- and
/// codegen-equivalent to the plain scalar (see `simd::tests::table1`).
pub type SimdN<T, const N: usize> = <T as Simdize<N>>::Out;

/// Field-wrapping policy for `record!`-generated value structs (`Rec<W>`):
/// `Rec<ScalarW>` holds plain scalars, `Rec<SimdW<N>>` holds `Simd<T, N>`
/// per field — the record row of Table 1.
pub trait Wrap: 'static {
    /// The wrapped type of a scalar field `T`.
    type Of<T: SimdElem>: Copy + Default + std::fmt::Debug;
}

/// Identity wrap: fields are plain scalars (Table 1: `N == 1`, record → `One<T>`).
#[derive(Clone, Copy, Debug, Default)]
pub struct ScalarW;

impl Wrap for ScalarW {
    type Of<T: SimdElem> = T;
}

/// SIMD wrap: fields are `Simd<T, N>` (Table 1: `N > 1`, record →
/// `One<SimdizeN<T, N>>`).
#[derive(Clone, Copy, Debug, Default)]
pub struct SimdW<const N: usize>;

impl<const N: usize> Wrap for SimdW<N> {
    type Of<T: SimdElem> = Simd<T, N>;
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn arithmetic() {
        let a = Simd::<f32, 4>::from_slice(&[1.0, 2.0, 3.0, 4.0]);
        let b = Simd::<f32, 4>::splat(2.0);
        assert_eq!((a + b).0, [3.0, 4.0, 5.0, 6.0]);
        assert_eq!((a * b).0, [2.0, 4.0, 6.0, 8.0]);
        assert_eq!((a - b).0, [-1.0, 0.0, 1.0, 2.0]);
        assert_eq!((a / b).0, [0.5, 1.0, 1.5, 2.0]);
        assert_eq!(a.mul_add(b, b).0, [4.0, 6.0, 8.0, 10.0]);
        assert_eq!(a.reduce_add(), 10.0);
    }

    #[test]
    fn sqrt_min_max() {
        let a = Simd::<f64, 2>::from_slice(&[4.0, 9.0]);
        assert_eq!(a.sqrt().0, [2.0, 3.0]);
        let b = Simd::<f64, 2>::from_slice(&[5.0, 1.0]);
        assert_eq!(a.min(b).0, [4.0, 1.0]);
        assert_eq!(a.max(b).0, [5.0, 9.0]);
        assert_eq!(b.reduce_min(), 1.0);
    }

    #[test]
    fn byte_roundtrip() {
        let a = Simd::<u32, 4>::from_slice(&[1, 2, 3, 0xdeadbeef]);
        let mut buf = [0u8; 16];
        a.write_le_bytes(&mut buf);
        let b = Simd::<u32, 4>::from_le_bytes(&buf);
        assert_eq!(a, b);
    }

    #[test]
    fn table1() {
        // Scalar T, N > 1 -> Simd<T, N>
        let v: SimdN<f32, 8> = Simd::splat(1.0f32);
        assert_eq!(v.0.len(), 8);
        // Scalar T, N == 1 -> layout-identical to T
        assert_eq!(std::mem::size_of::<SimdN<f32, 1>>(), std::mem::size_of::<f32>());
        assert_eq!(std::mem::align_of::<SimdN<f64, 1>>(), std::mem::align_of::<f64>());
        let s: SimdN<f64, 1> = Simd::splat(2.5);
        assert_eq!(s.scalar(), 2.5);
        // Wrap policies (record row of Table 1)
        fn wrapped<W: Wrap>() -> W::Of<f32> {
            W::Of::<f32>::default()
        }
        let _scalar: f32 = wrapped::<ScalarW>();
        let _simd: Simd<f32, 4> = wrapped::<SimdW<4>>();
    }

    #[test]
    fn integer_lanes() {
        let a = Simd::<i32, 4>::from_slice(&[-4, 9, 16, 0]);
        assert_eq!(a.sqrt().0, [0, 3, 4, 0]); // sqrt(-4) -> NaN -> saturating cast 0
        let b = Simd::<i32, 4>::splat(0);
        assert_eq!((a / b).0, [0, 0, 0, 0]); // div-by-zero -> 0 (no trap)
    }
}
