//! Layout-aware copy between views (LLAMA's `llama::copy`).
//!
//! Three strategies, picked automatically by [`copy_view`]:
//!
//! 1. **Blob memcpy** — when both views' mappings have identical layout
//!    fingerprints, every blob is bytewise identical: copy blobs directly.
//! 2. **Field runs** — when both mappings expose byte-contiguous runs
//!    through the bulk-traversal engine's
//!    [`crate::mapping::Mapping::contiguous_run`] hook (SoA↔SoA with
//!    different blob policies, SoA↔AoSoA, AoSoA↔AoSoA with different lane
//!    counts), each field copies as `memcpy` runs clipped to the shorter
//!    side's block length — the layout-aware copy of the original LLAMA
//!    paper, generalized.
//! 3. **Field-wise fallback** — per (record, field) scalar load/store
//!    through both mappings; works for any mapping pair including
//!    computed ones (and converts precision when types differ, via f64).
//!
//! [`copy_view_par`] adds the **parallel run copy**: the linear record
//! space is partitioned at boundaries the *destination* mapping proves
//! byte-disjoint ([`crate::mapping::Mapping::shard_bounds`] — the same
//! proof the sharded traversal uses), and each worker memcpys its ranges'
//! field runs through a raw [`crate::blob::ShardBlobs`] handle. Source
//! reads are plain shared reads (nobody writes the source), destination
//! writes are byte-disjoint across workers, and every materialized
//! reference covers exactly one run — the copy engine is checker-clean
//! like the traversal engine (see `docs/PARALLELISM.md`). Workers run the
//! *same* run walker as the serial strategy 2, so the written bytes are
//! identical by construction (property-tested in
//! `tests/properties.rs::prop_par_run_copy_bit_identical_to_field_wise`).

use std::sync::atomic::{AtomicBool, Ordering};

use crate::blob::{blob_spans, BlobStorage, ShardBlobs};
use crate::extents::Extents;
use crate::mapping::{Mapping, MemoryAccess};
use crate::record::RecordDim;
use crate::view::{load_as_f64, store_from_f64, View};

/// Which strategy [`copy_view`] / [`copy_view_par`] used (exposed for
/// tests/benches).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum CopyStrategy {
    /// Whole-blob memcpy.
    BlobMemcpy,
    /// Per-field memcpy of contiguous runs (bulk-traversal engine).
    FieldRuns,
    /// Per-field memcpy runs fanned over worker threads at
    /// `shard_bounds`-proven boundaries ([`copy_view_par`] only).
    FieldRunsPar,
    /// Per-field scalar loop.
    FieldWise,
}

/// Copy every record of `src` into `dst`.
///
/// Panics if extents differ. Field scalar types may differ (values are
/// converted through `f64`, like [`crate::mapping::changetype`]).
pub fn copy_view<R, MS, SS, MD, SD>(
    src: &View<R, MS, SS>,
    dst: &mut View<R, MD, SD>,
) -> CopyStrategy
where
    R: RecordDim,
    MS: MemoryAccess<R>,
    SS: BlobStorage,
    MD: MemoryAccess<R>,
    SD: BlobStorage,
{
    let n = src.count();
    assert_eq!(n, dst.count(), "copy_view: extents differ");

    // Strategy 1: identical layouts -> blob memcpy.
    if src.mapping().fingerprint() == dst.mapping().fingerprint()
        && MS::BLOB_COUNT == MD::BLOB_COUNT
    {
        blob_memcpy(src, dst);
        return CopyStrategy::BlobMemcpy;
    }

    // Strategy 2: both layouts expose contiguous field runs -> memcpy runs.
    if try_run_copy(src, dst) {
        return CopyStrategy::FieldRuns;
    }

    // Strategy 3: generic field-wise copy over the index space.
    field_wise_copy(src, dst);
    CopyStrategy::FieldWise
}

/// [`copy_view`] with the run strategy fanned out over up to `threads`
/// workers of the persistent pool ([`crate::pool`]; per-call scoped
/// threads under `LLAMA_POOL=off`) — the ROADMAP's run-based parallel
/// copy.
///
/// The record space is partitioned at boundaries the destination
/// mapping's [`shard_bounds`](crate::mapping::Mapping::shard_bounds)
/// proves byte-disjoint; each worker then copies its ranges' field runs —
/// the [`contiguous_run`](crate::mapping::Mapping::contiguous_run) ×
/// `shard_bounds` intersection gives per-thread disjoint byte ranges for
/// free. Falls back to the serial strategies when the partition or the
/// runs are unavailable (`threads < 2`, tiny views, mappings that refuse
/// `shard_bounds` like [`crate::mapping::one::One`], or mappings without
/// byte-contiguity like the bit-packed ones). Written bytes are identical
/// to [`copy_view`]'s for every strategy.
pub fn copy_view_par<R, MS, SS, MD, SD>(
    src: &View<R, MS, SS>,
    dst: &mut View<R, MD, SD>,
    threads: usize,
) -> CopyStrategy
where
    R: RecordDim,
    MS: MemoryAccess<R>,
    SS: BlobStorage + Sync,
    MD: MemoryAccess<R>,
    SD: BlobStorage + Send + Sync,
{
    let n = src.count();
    assert_eq!(n, dst.count(), "copy_view_par: extents differ");

    if src.mapping().fingerprint() == dst.mapping().fingerprint()
        && MS::BLOB_COUNT == MD::BLOB_COUNT
    {
        blob_memcpy(src, dst);
        return CopyStrategy::BlobMemcpy;
    }

    let dm = dst.mapping().clone();
    // Probe run availability up front (both sides, every field) so the
    // common no-runs case skips straight to the serial fallback without
    // spawning workers. Mid-stream gaps are still caught below.
    let runs_available = n > 0
        && (0..R::FIELDS.len()).all(|f| {
            src.mapping().contiguous_run(0, f).is_some() && dm.contiguous_run(0, f).is_some()
        });
    if runs_available {
        if let Some(bounds) = run_copy_bounds::<R, MD>(&dm, n, threads) {
            let gap = AtomicBool::new(false);
            let spans = blob_spans(dst.storage_mut());
            {
                let (gap, dm, spans) = (&gap, &dm, &spans);
                // One job per worker range, dispatched on the persistent
                // pool (or per-call scoped threads when `LLAMA_POOL=off`);
                // `run_jobs` returns only when every job has finished, so
                // the borrows of `gap`/`dm`/`spans`/`src` stay valid.
                let jobs: Vec<_> = (0..bounds.len() - 1)
                    .map(|w| {
                        let (r0, r1) = (bounds[w], bounds[w + 1]);
                        move || {
                            // SAFETY (`ShardBlobs::new`): (1) the spans'
                            // buffers outlive the dispatch — `dst` stays
                            // mutably borrowed and untouched until it
                            // returns; (2) this worker writes only the
                            // field runs of records [r0, r1),
                            // byte-disjoint from every other worker's
                            // ranges by the `shard_bounds`-validated
                            // partition, and nothing reads dst
                            // concurrently.
                            let mut out = unsafe { ShardBlobs::new(spans.to_vec()) };
                            if !run_copy_range(src, dm, &mut out, r0, r1) {
                                gap.store(true, Ordering::Relaxed);
                            }
                        }
                    })
                    .collect();
                crate::pool::run_jobs(jobs);
            }
            if !gap.load(Ordering::Relaxed) {
                return CopyStrategy::FieldRunsPar;
            }
            // A mapping reported a mid-stream run gap: the field-wise
            // rewrite below overwrites every (record, field), so the
            // partially-written runs are harmless.
            field_wise_copy(src, dst);
            return CopyStrategy::FieldWise;
        }
    }

    // No runs or no usable partition: serial strategies 2/3.
    if try_run_copy(src, dst) {
        return CopyStrategy::FieldRuns;
    }
    field_wise_copy(src, dst);
    CopyStrategy::FieldWise
}

/// Strategy 1: bytewise-identical layouts, copy whole blobs.
fn blob_memcpy<R, MS, SS, MD, SD>(src: &View<R, MS, SS>, dst: &mut View<R, MD, SD>)
where
    R: RecordDim,
    MS: MemoryAccess<R>,
    SS: BlobStorage,
    MD: MemoryAccess<R>,
    SD: BlobStorage,
{
    let blob_sizes: Vec<usize> = (0..MS::BLOB_COUNT).map(|b| src.mapping().blob_size(b)).collect();
    for (b, size) in blob_sizes.into_iter().enumerate() {
        dst.storage_mut().bytes_mut(b, 0, size).copy_from_slice(src.storage().bytes(b, 0, size));
    }
}

/// Partition `[0, n)` into up to `threads` ranges whose boundaries the
/// destination mapping proves byte-disjoint, for the parallel run copy.
/// `None` when fewer than two non-empty ranges survive the rounding.
///
/// The validate-and-round fixpoint mirrors the traversal splitter's
/// (`shard::ViewShards::split_aligned`), but in plain linear-record
/// units — the splitter additionally rounds in aligned outer-row units.
/// A change to either loop's rounding semantics should be mirrored in
/// the other.
fn run_copy_bounds<R, M>(m: &M, n: usize, threads: usize) -> Option<Vec<usize>>
where
    R: RecordDim,
    M: Mapping<R>,
{
    let want = threads.min(n);
    if want < 2 {
        return None;
    }
    let mut bounds = Vec::with_capacity(want + 1);
    bounds.push(0usize);
    for k in 1..want {
        let mut b = (n as u128 * k as u128 / want as u128) as usize;
        let b = loop {
            if b == 0 {
                break 0;
            }
            // SAFETY: `shard_bounds` has no caller preconditions; its
            // `unsafe` marks the implementor's obligation, consumed here
            // as the write-disjointness proof of the parallel copy.
            let safe = unsafe { m.shard_bounds(b) }?;
            if safe == b {
                break b;
            }
            b = safe;
        };
        if b > *bounds.last().unwrap() {
            bounds.push(b);
        }
    }
    bounds.push(n);
    if bounds.len() < 3 {
        None
    } else {
        Some(bounds)
    }
}

/// Copy the byte runs of records `[r0, r1)` for every field from `src`
/// into `out` (the destination's storage, or a worker's [`ShardBlobs`]
/// handle over it — the shared walker of the serial and parallel run
/// strategies, so both write identical bytes by construction). Returns
/// `false` — leaving `out` partially written; callers must then run the
/// field-wise fallback — as soon as either side reports a gap.
fn run_copy_range<R, MS, SS, MD, SO>(
    src: &View<R, MS, SS>,
    dst_mapping: &MD,
    out: &mut SO,
    r0: usize,
    r1: usize,
) -> bool
where
    R: RecordDim,
    MS: MemoryAccess<R>,
    SS: BlobStorage,
    MD: MemoryAccess<R>,
    SO: BlobStorage,
{
    for (f, field) in R::FIELDS.iter().enumerate() {
        let size = field.size();
        let mut lin = r0;
        while lin < r1 {
            let (Some(s), Some(d)) =
                (src.mapping().contiguous_run(lin, f), dst_mapping.contiguous_run(lin, f))
            else {
                return false;
            };
            let len = s.len.min(d.len).min(r1 - lin);
            let bytes = len * size;
            out.bytes_mut(d.blob, d.offset, bytes)
                .copy_from_slice(src.storage().bytes(s.blob, s.offset, bytes));
            lin += len;
        }
    }
    true
}

/// Copy every field as byte runs where both mappings report contiguity
/// ([`crate::mapping::Mapping::contiguous_run`]). Returns `false` — and
/// leaves `dst` partially written, callers must then run the field-wise
/// fallback — as soon as either side reports a gap.
fn try_run_copy<R, MS, SS, MD, SD>(src: &View<R, MS, SS>, dst: &mut View<R, MD, SD>) -> bool
where
    R: RecordDim,
    MS: MemoryAccess<R>,
    SS: BlobStorage,
    MD: MemoryAccess<R>,
    SD: BlobStorage,
{
    let n = src.count();
    let dm = dst.mapping().clone();
    run_copy_range(src, &dm, dst.storage_mut(), 0, n)
}

/// Per-(record, field) copy through both mappings.
pub fn field_wise_copy<R, MS, SS, MD, SD>(src: &View<R, MS, SS>, dst: &mut View<R, MD, SD>)
where
    R: RecordDim,
    MS: MemoryAccess<R>,
    SS: BlobStorage,
    MD: MemoryAccess<R>,
    SD: BlobStorage,
{
    let e = *src.extents();
    let rank = <MS::Extents as Extents>::RANK;
    let mut idx = [0usize; crate::view::MAX_RANK];
    loop {
        for f in 0..R::FIELDS.len() {
            let v = load_as_f64(src, &idx[..rank], f);
            store_from_f64(dst, &idx[..rank], f, v);
        }
        if !crate::extents::advance_index(&e, &mut idx[..rank]) {
            return;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::blob::{alloc_view, HeapAlloc};
    use crate::extents::Dyn;
    use crate::mapping::aos::AoS;
    use crate::mapping::aosoa::AoSoA;
    use crate::mapping::soa::{SingleBlob, SoA};

    crate::record! {
        pub struct P, mod p {
            pos: { x: f64, y: f64 },
            m: f32,
        }
    }

    fn fill<M: crate::mapping::MemoryAccess<P>, S: crate::blob::BlobStorage>(
        v: &mut crate::view::View<P, M, S>,
        n: usize,
    ) {
        for i in 0..n {
            v.set(&[i], p::pos::x, i as f64);
            v.set(&[i], p::pos::y, -(i as f64));
            v.set(&[i], p::m, (i * 2) as f32);
        }
    }

    fn check<M: crate::mapping::MemoryAccess<P>, S: crate::blob::BlobStorage>(
        v: &crate::view::View<P, M, S>,
        n: usize,
    ) {
        for i in 0..n {
            assert_eq!(v.get::<f64, _>(&[i], p::pos::x), i as f64);
            assert_eq!(v.get::<f64, _>(&[i], p::pos::y), -(i as f64));
            assert_eq!(v.get::<f32, _>(&[i], p::m), (i * 2) as f32);
        }
    }

    #[test]
    fn same_layout_uses_memcpy() {
        let mut a = alloc_view(AoS::<P, _>::new((Dyn(32u32),)), &HeapAlloc);
        let mut b = alloc_view(AoS::<P, _>::new((Dyn(32u32),)), &HeapAlloc);
        fill(&mut a, 32);
        assert_eq!(copy_view(&a, &mut b), CopyStrategy::BlobMemcpy);
        check(&b, 32);
    }

    #[test]
    fn aos_to_soa_field_wise() {
        let mut a = alloc_view(AoS::<P, _>::new((Dyn(16u32),)), &HeapAlloc);
        let mut b = alloc_view(SoA::<P, _>::new((Dyn(16u32),)), &HeapAlloc);
        fill(&mut a, 16);
        assert_eq!(copy_view(&a, &mut b), CopyStrategy::FieldWise);
        check(&b, 16);
    }

    #[test]
    fn soa_to_aosoa_uses_field_runs() {
        let mut a = alloc_view(SoA::<P, _, SingleBlob>::new((Dyn(20u32),)), &HeapAlloc);
        let mut b = alloc_view(AoSoA::<P, _, 8>::new((Dyn(20u32),)), &HeapAlloc);
        fill(&mut a, 20);
        assert_eq!(copy_view(&a, &mut b), CopyStrategy::FieldRuns);
        check(&b, 20);
    }

    #[test]
    fn run_copy_between_blob_policies_and_lane_counts() {
        // SoA multi-blob -> SoA single-blob: one run per field.
        let mut a = alloc_view(SoA::<P, _>::new((Dyn(33u32),)), &HeapAlloc);
        let mut b = alloc_view(SoA::<P, _, SingleBlob>::new((Dyn(33u32),)), &HeapAlloc);
        fill(&mut a, 33);
        assert_eq!(copy_view(&a, &mut b), CopyStrategy::FieldRuns);
        check(&b, 33);

        // AoSoA4 -> AoSoA16: runs clip to the shorter block, including the
        // ragged tail (33 % 4 == 1).
        let mut c = alloc_view(AoSoA::<P, _, 4>::new((Dyn(33u32),)), &HeapAlloc);
        let mut d = alloc_view(AoSoA::<P, _, 16>::new((Dyn(33u32),)), &HeapAlloc);
        assert_eq!(copy_view(&b, &mut c), CopyStrategy::FieldRuns);
        assert_eq!(copy_view(&c, &mut d), CopyStrategy::FieldRuns);
        check(&d, 33);
    }

    #[test]
    fn parallel_run_copy_matches_serial_and_reports_strategy() {
        let n = 41usize; // deliberately ragged for AoSoA blocks + threads
        let mut src = alloc_view(SoA::<P, _>::new((Dyn(n as u32),)), &HeapAlloc);
        fill(&mut src, n);
        let mut serial = alloc_view(AoSoA::<P, _, 8>::new((Dyn(n as u32),)), &HeapAlloc);
        assert_eq!(copy_view(&src, &mut serial), CopyStrategy::FieldRuns);
        let mut par = alloc_view(AoSoA::<P, _, 8>::new((Dyn(n as u32),)), &HeapAlloc);
        assert_eq!(copy_view_par(&src, &mut par, 4), CopyStrategy::FieldRunsPar);
        check(&par, n);
        // Bytes, not just values: the parallel walker is the serial one.
        assert_eq!(serial.storage().blob(0), par.storage().blob(0));
    }

    #[test]
    fn parallel_copy_falls_back_without_partition_or_runs() {
        let mut src = alloc_view(SoA::<P, _>::new((Dyn(24u32),)), &HeapAlloc);
        fill(&mut src, 24);
        // threads < 2: serial run strategy.
        let mut b = alloc_view(AoSoA::<P, _, 8>::new((Dyn(24u32),)), &HeapAlloc);
        assert_eq!(copy_view_par(&src, &mut b, 1), CopyStrategy::FieldRuns);
        check(&b, 24);
        // Destination without byte-contiguity (AoS): field-wise.
        let mut c = alloc_view(AoS::<P, _>::new((Dyn(24u32),)), &HeapAlloc);
        assert_eq!(copy_view_par(&src, &mut c, 4), CopyStrategy::FieldWise);
        check(&c, 24);
        // Identical layout keeps the memcpy fast path.
        let mut d = alloc_view(SoA::<P, _>::new((Dyn(24u32),)), &HeapAlloc);
        assert_eq!(copy_view_par(&src, &mut d, 4), CopyStrategy::BlobMemcpy);
        check(&d, 24);
        // Unshardable destination (One): every index aliases one record —
        // no partition, no runs, field-wise fallback.
        let mut e = alloc_view(crate::mapping::one::One::<P, _>::new((Dyn(24u32),)), &HeapAlloc);
        assert_eq!(copy_view_par(&src, &mut e, 4), CopyStrategy::FieldWise);
        assert_eq!(e.get::<f32, _>(&[0], p::m), 46.0); // last record wins
    }

    #[test]
    fn copy_2d() {
        let mut a = alloc_view(SoA::<P, _>::new((Dyn(3u32), Dyn(4u32))), &HeapAlloc);
        let mut b = alloc_view(AoS::<P, _>::new((Dyn(3u32), Dyn(4u32))), &HeapAlloc);
        for i in 0..3usize {
            for j in 0..4usize {
                a.set(&[i, j], p::pos::x, (i * 10 + j) as f64);
            }
        }
        copy_view(&a, &mut b);
        for i in 0..3usize {
            for j in 0..4usize {
                assert_eq!(b.get::<f64, _>(&[i, j], p::pos::x), (i * 10 + j) as f64);
            }
        }
    }

    #[test]
    fn copy_into_computed_mapping() {
        use crate::mapping::bitpack_float::BitpackFloatSoA;
        crate::record! { pub struct Q, mod q { a: f64 } }
        let mut a = alloc_view(AoS::<Q, _>::new((Dyn(8u32),)), &HeapAlloc);
        let mut b = alloc_view(BitpackFloatSoA::<Q, _, 8, 23>::new((Dyn(8u32),)), &HeapAlloc);
        for i in 0..8usize {
            a.set(&[i], q::a, i as f64 + 0.5);
        }
        copy_view(&a, &mut b);
        for i in 0..8usize {
            assert_eq!(b.get::<f64, _>(&[i], q::a), i as f64 + 0.5);
        }
    }
}
