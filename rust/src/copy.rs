//! Layout-aware copy between views (LLAMA's `llama::copy`).
//!
//! Three strategies, picked automatically by [`copy_view`]:
//!
//! 1. **Blob memcpy** — when both views' mappings have identical layout
//!    fingerprints, every blob is bytewise identical: copy blobs directly.
//! 2. **Specialized SoA↔AoSoA** — both layouts keep each field's values
//!    at a regular stride, so fields copy as runs of contiguous lane
//!    blocks instead of per-scalar loads (the layout-aware copy of the
//!    original LLAMA paper).
//! 3. **Field-wise fallback** — per (record, field) scalar load/store
//!    through both mappings; works for any mapping pair including
//!    computed ones (and converts precision when types differ, via f64).

use crate::blob::BlobStorage;
use crate::extents::Extents;
use crate::mapping::MemoryAccess;
use crate::record::RecordDim;
use crate::view::{load_as_f64, store_from_f64, View};

/// Which strategy [`copy_view`] used (exposed for tests/benches).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum CopyStrategy {
    /// Whole-blob memcpy.
    BlobMemcpy,
    /// Per-field scalar loop.
    FieldWise,
}

/// Copy every record of `src` into `dst`.
///
/// Panics if extents differ. Field scalar types may differ (values are
/// converted through `f64`, like [`crate::mapping::changetype`]).
pub fn copy_view<R, MS, SS, MD, SD>(
    src: &View<R, MS, SS>,
    dst: &mut View<R, MD, SD>,
) -> CopyStrategy
where
    R: RecordDim,
    MS: MemoryAccess<R>,
    SS: BlobStorage,
    MD: MemoryAccess<R>,
    SD: BlobStorage,
{
    let n = src.count();
    assert_eq!(n, dst.count(), "copy_view: extents differ");

    // Strategy 1: identical layouts -> blob memcpy.
    if src.mapping().fingerprint() == dst.mapping().fingerprint() && MS::BLOB_COUNT == MD::BLOB_COUNT
    {
        let blob_sizes: Vec<usize> = (0..MS::BLOB_COUNT).map(|b| src.mapping().blob_size(b)).collect();
        for (b, size) in blob_sizes.into_iter().enumerate() {
            let s = src.storage().blob(b);
            let d = dst.storage_mut().blob_mut(b);
            d[..size].copy_from_slice(&s[..size]);
        }
        return CopyStrategy::BlobMemcpy;
    }

    // Strategy 3: generic field-wise copy over the linear index space.
    // (The SoA<->AoSoA block specialization lives in copy_soa_aosoa below
    // and is dispatched explicitly by callers that know their layouts.)
    field_wise_copy(src, dst);
    CopyStrategy::FieldWise
}

/// Per-(record, field) copy through both mappings.
pub fn field_wise_copy<R, MS, SS, MD, SD>(src: &View<R, MS, SS>, dst: &mut View<R, MD, SD>)
where
    R: RecordDim,
    MS: MemoryAccess<R>,
    SS: BlobStorage,
    MD: MemoryAccess<R>,
    SD: BlobStorage,
{
    let e = *src.extents();
    let rank = <MS::Extents as Extents>::RANK;
    let mut idx = [0usize; crate::view::MAX_RANK];
    loop {
        for f in 0..R::FIELDS.len() {
            let v = load_as_f64(src, &idx[..rank], f);
            store_from_f64(dst, &idx[..rank], f, v);
        }
        // Odometer increment over the array dimensions.
        let mut d = rank;
        loop {
            if d == 0 {
                return;
            }
            d -= 1;
            idx[d] += 1;
            if idx[d] < e.extent(d) {
                break;
            }
            idx[d] = 0;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::blob::{alloc_view, HeapAlloc};
    use crate::extents::Dyn;
    use crate::mapping::aos::AoS;
    use crate::mapping::aosoa::AoSoA;
    use crate::mapping::soa::{SingleBlob, SoA};

    crate::record! {
        pub struct P, mod p {
            pos: { x: f64, y: f64 },
            m: f32,
        }
    }

    fn fill<M: crate::mapping::MemoryAccess<P>, S: crate::blob::BlobStorage>(
        v: &mut crate::view::View<P, M, S>,
        n: usize,
    ) {
        for i in 0..n {
            v.set(&[i], p::pos::x, i as f64);
            v.set(&[i], p::pos::y, -(i as f64));
            v.set(&[i], p::m, (i * 2) as f32);
        }
    }

    fn check<M: crate::mapping::MemoryAccess<P>, S: crate::blob::BlobStorage>(
        v: &crate::view::View<P, M, S>,
        n: usize,
    ) {
        for i in 0..n {
            assert_eq!(v.get::<f64>(&[i], p::pos::x), i as f64);
            assert_eq!(v.get::<f64>(&[i], p::pos::y), -(i as f64));
            assert_eq!(v.get::<f32>(&[i], p::m), (i * 2) as f32);
        }
    }

    #[test]
    fn same_layout_uses_memcpy() {
        let mut a = alloc_view(AoS::<P, _>::new((Dyn(32u32),)), &HeapAlloc);
        let mut b = alloc_view(AoS::<P, _>::new((Dyn(32u32),)), &HeapAlloc);
        fill(&mut a, 32);
        assert_eq!(copy_view(&a, &mut b), CopyStrategy::BlobMemcpy);
        check(&b, 32);
    }

    #[test]
    fn aos_to_soa_field_wise() {
        let mut a = alloc_view(AoS::<P, _>::new((Dyn(16u32),)), &HeapAlloc);
        let mut b = alloc_view(SoA::<P, _>::new((Dyn(16u32),)), &HeapAlloc);
        fill(&mut a, 16);
        assert_eq!(copy_view(&a, &mut b), CopyStrategy::FieldWise);
        check(&b, 16);
    }

    #[test]
    fn soa_to_aosoa() {
        let mut a = alloc_view(SoA::<P, _, SingleBlob>::new((Dyn(20u32),)), &HeapAlloc);
        let mut b = alloc_view(AoSoA::<P, _, 8>::new((Dyn(20u32),)), &HeapAlloc);
        fill(&mut a, 20);
        copy_view(&a, &mut b);
        check(&b, 20);
    }

    #[test]
    fn copy_2d() {
        let mut a = alloc_view(SoA::<P, _>::new((Dyn(3u32), Dyn(4u32))), &HeapAlloc);
        let mut b = alloc_view(AoS::<P, _>::new((Dyn(3u32), Dyn(4u32))), &HeapAlloc);
        for i in 0..3usize {
            for j in 0..4usize {
                a.set(&[i, j], p::pos::x, (i * 10 + j) as f64);
            }
        }
        copy_view(&a, &mut b);
        for i in 0..3usize {
            for j in 0..4usize {
                assert_eq!(b.get::<f64>(&[i, j], p::pos::x), (i * 10 + j) as f64);
            }
        }
    }

    #[test]
    fn copy_into_computed_mapping() {
        use crate::mapping::bitpack_float::BitpackFloatSoA;
        crate::record! { pub struct Q, mod q { a: f64 } }
        let mut a = alloc_view(AoS::<Q, _>::new((Dyn(8u32),)), &HeapAlloc);
        let mut b = alloc_view(BitpackFloatSoA::<Q, _, 8, 23>::new((Dyn(8u32),)), &HeapAlloc);
        for i in 0..8usize {
            a.set(&[i], q::a, i as f64 + 0.5);
        }
        copy_view(&a, &mut b);
        for i in 0..8usize {
            assert_eq!(b.get::<f64>(&[i], q::a), i as f64 + 0.5);
        }
    }
}
