//! Layout-aware copy between views (LLAMA's `llama::copy`).
//!
//! Three strategies, picked automatically by [`copy_view`]:
//!
//! 1. **Blob memcpy** — when both views' mappings have identical layout
//!    fingerprints, every blob is bytewise identical: copy blobs directly.
//! 2. **Field runs** — when both mappings expose byte-contiguous runs
//!    through the bulk-traversal engine's
//!    [`crate::mapping::Mapping::contiguous_run`] hook (SoA↔SoA with
//!    different blob policies, SoA↔AoSoA, AoSoA↔AoSoA with different lane
//!    counts), each field copies as `memcpy` runs clipped to the shorter
//!    side's block length — the layout-aware copy of the original LLAMA
//!    paper, generalized.
//! 3. **Field-wise fallback** — per (record, field) scalar load/store
//!    through both mappings; works for any mapping pair including
//!    computed ones (and converts precision when types differ, via f64).

use crate::blob::BlobStorage;
use crate::extents::Extents;
use crate::mapping::MemoryAccess;
use crate::record::RecordDim;
use crate::view::{load_as_f64, store_from_f64, View};

/// Which strategy [`copy_view`] used (exposed for tests/benches).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum CopyStrategy {
    /// Whole-blob memcpy.
    BlobMemcpy,
    /// Per-field memcpy of contiguous runs (bulk-traversal engine).
    FieldRuns,
    /// Per-field scalar loop.
    FieldWise,
}

/// Copy every record of `src` into `dst`.
///
/// Panics if extents differ. Field scalar types may differ (values are
/// converted through `f64`, like [`crate::mapping::changetype`]).
pub fn copy_view<R, MS, SS, MD, SD>(
    src: &View<R, MS, SS>,
    dst: &mut View<R, MD, SD>,
) -> CopyStrategy
where
    R: RecordDim,
    MS: MemoryAccess<R>,
    SS: BlobStorage,
    MD: MemoryAccess<R>,
    SD: BlobStorage,
{
    let n = src.count();
    assert_eq!(n, dst.count(), "copy_view: extents differ");

    // Strategy 1: identical layouts -> blob memcpy.
    if src.mapping().fingerprint() == dst.mapping().fingerprint()
        && MS::BLOB_COUNT == MD::BLOB_COUNT
    {
        let blob_sizes: Vec<usize> =
            (0..MS::BLOB_COUNT).map(|b| src.mapping().blob_size(b)).collect();
        for (b, size) in blob_sizes.into_iter().enumerate() {
            let s = src.storage().blob(b);
            let d = dst.storage_mut().blob_mut(b);
            d[..size].copy_from_slice(&s[..size]);
        }
        return CopyStrategy::BlobMemcpy;
    }

    // Strategy 2: both layouts expose contiguous field runs -> memcpy runs.
    if try_run_copy(src, dst) {
        return CopyStrategy::FieldRuns;
    }

    // Strategy 3: generic field-wise copy over the index space.
    field_wise_copy(src, dst);
    CopyStrategy::FieldWise
}

/// Copy every field as byte runs where both mappings report contiguity
/// ([`crate::mapping::Mapping::contiguous_run`]). Returns `false` — and
/// leaves `dst` partially written, callers must then run the field-wise
/// fallback — as soon as either side reports a gap.
fn try_run_copy<R, MS, SS, MD, SD>(src: &View<R, MS, SS>, dst: &mut View<R, MD, SD>) -> bool
where
    R: RecordDim,
    MS: MemoryAccess<R>,
    SS: BlobStorage,
    MD: MemoryAccess<R>,
    SD: BlobStorage,
{
    let n = src.count();
    for (f, field) in R::FIELDS.iter().enumerate() {
        let size = field.size();
        let mut lin = 0;
        while lin < n {
            let (Some(s), Some(d)) =
                (src.mapping().contiguous_run(lin, f), dst.mapping().contiguous_run(lin, f))
            else {
                return false;
            };
            let len = s.len.min(d.len).min(n - lin);
            let bytes = len * size;
            let src_blob = src.storage().blob(s.blob);
            let dst_blob = dst.storage_mut().blob_mut(d.blob);
            dst_blob[d.offset..d.offset + bytes]
                .copy_from_slice(&src_blob[s.offset..s.offset + bytes]);
            lin += len;
        }
    }
    true
}

/// Per-(record, field) copy through both mappings.
pub fn field_wise_copy<R, MS, SS, MD, SD>(src: &View<R, MS, SS>, dst: &mut View<R, MD, SD>)
where
    R: RecordDim,
    MS: MemoryAccess<R>,
    SS: BlobStorage,
    MD: MemoryAccess<R>,
    SD: BlobStorage,
{
    let e = *src.extents();
    let rank = <MS::Extents as Extents>::RANK;
    let mut idx = [0usize; crate::view::MAX_RANK];
    loop {
        for f in 0..R::FIELDS.len() {
            let v = load_as_f64(src, &idx[..rank], f);
            store_from_f64(dst, &idx[..rank], f, v);
        }
        if !crate::extents::advance_index(&e, &mut idx[..rank]) {
            return;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::blob::{alloc_view, HeapAlloc};
    use crate::extents::Dyn;
    use crate::mapping::aos::AoS;
    use crate::mapping::aosoa::AoSoA;
    use crate::mapping::soa::{SingleBlob, SoA};

    crate::record! {
        pub struct P, mod p {
            pos: { x: f64, y: f64 },
            m: f32,
        }
    }

    fn fill<M: crate::mapping::MemoryAccess<P>, S: crate::blob::BlobStorage>(
        v: &mut crate::view::View<P, M, S>,
        n: usize,
    ) {
        for i in 0..n {
            v.set(&[i], p::pos::x, i as f64);
            v.set(&[i], p::pos::y, -(i as f64));
            v.set(&[i], p::m, (i * 2) as f32);
        }
    }

    fn check<M: crate::mapping::MemoryAccess<P>, S: crate::blob::BlobStorage>(
        v: &crate::view::View<P, M, S>,
        n: usize,
    ) {
        for i in 0..n {
            assert_eq!(v.get::<f64, _>(&[i], p::pos::x), i as f64);
            assert_eq!(v.get::<f64, _>(&[i], p::pos::y), -(i as f64));
            assert_eq!(v.get::<f32, _>(&[i], p::m), (i * 2) as f32);
        }
    }

    #[test]
    fn same_layout_uses_memcpy() {
        let mut a = alloc_view(AoS::<P, _>::new((Dyn(32u32),)), &HeapAlloc);
        let mut b = alloc_view(AoS::<P, _>::new((Dyn(32u32),)), &HeapAlloc);
        fill(&mut a, 32);
        assert_eq!(copy_view(&a, &mut b), CopyStrategy::BlobMemcpy);
        check(&b, 32);
    }

    #[test]
    fn aos_to_soa_field_wise() {
        let mut a = alloc_view(AoS::<P, _>::new((Dyn(16u32),)), &HeapAlloc);
        let mut b = alloc_view(SoA::<P, _>::new((Dyn(16u32),)), &HeapAlloc);
        fill(&mut a, 16);
        assert_eq!(copy_view(&a, &mut b), CopyStrategy::FieldWise);
        check(&b, 16);
    }

    #[test]
    fn soa_to_aosoa_uses_field_runs() {
        let mut a = alloc_view(SoA::<P, _, SingleBlob>::new((Dyn(20u32),)), &HeapAlloc);
        let mut b = alloc_view(AoSoA::<P, _, 8>::new((Dyn(20u32),)), &HeapAlloc);
        fill(&mut a, 20);
        assert_eq!(copy_view(&a, &mut b), CopyStrategy::FieldRuns);
        check(&b, 20);
    }

    #[test]
    fn run_copy_between_blob_policies_and_lane_counts() {
        // SoA multi-blob -> SoA single-blob: one run per field.
        let mut a = alloc_view(SoA::<P, _>::new((Dyn(33u32),)), &HeapAlloc);
        let mut b = alloc_view(SoA::<P, _, SingleBlob>::new((Dyn(33u32),)), &HeapAlloc);
        fill(&mut a, 33);
        assert_eq!(copy_view(&a, &mut b), CopyStrategy::FieldRuns);
        check(&b, 33);

        // AoSoA4 -> AoSoA16: runs clip to the shorter block, including the
        // ragged tail (33 % 4 == 1).
        let mut c = alloc_view(AoSoA::<P, _, 4>::new((Dyn(33u32),)), &HeapAlloc);
        let mut d = alloc_view(AoSoA::<P, _, 16>::new((Dyn(33u32),)), &HeapAlloc);
        assert_eq!(copy_view(&b, &mut c), CopyStrategy::FieldRuns);
        assert_eq!(copy_view(&c, &mut d), CopyStrategy::FieldRuns);
        check(&d, 33);
    }

    #[test]
    fn copy_2d() {
        let mut a = alloc_view(SoA::<P, _>::new((Dyn(3u32), Dyn(4u32))), &HeapAlloc);
        let mut b = alloc_view(AoS::<P, _>::new((Dyn(3u32), Dyn(4u32))), &HeapAlloc);
        for i in 0..3usize {
            for j in 0..4usize {
                a.set(&[i, j], p::pos::x, (i * 10 + j) as f64);
            }
        }
        copy_view(&a, &mut b);
        for i in 0..3usize {
            for j in 0..4usize {
                assert_eq!(b.get::<f64, _>(&[i, j], p::pos::x), (i * 10 + j) as f64);
            }
        }
    }

    #[test]
    fn copy_into_computed_mapping() {
        use crate::mapping::bitpack_float::BitpackFloatSoA;
        crate::record! { pub struct Q, mod q { a: f64 } }
        let mut a = alloc_view(AoS::<Q, _>::new((Dyn(8u32),)), &HeapAlloc);
        let mut b = alloc_view(BitpackFloatSoA::<Q, _, 8, 23>::new((Dyn(8u32),)), &HeapAlloc);
        for i in 0..8usize {
            a.set(&[i], q::a, i as f64 + 0.5);
        }
        copy_view(&a, &mut b);
        for i in 0..8usize {
            assert_eq!(b.get::<f64, _>(&[i], q::a), i as f64 + 0.5);
        }
    }
}
