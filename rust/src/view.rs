//! Views and record references: the program-facing access layer.
//!
//! A [`View`] binds a mapping to blob storage and spans the data space.
//! Programs address records with array indices, obtaining a [`RecordRef`]
//! (or [`RecordRefMut`]) — the analogue of LLAMA's `RecordRef` — and
//! finally scalars via the tags from [`crate::record!`]. Loads/stores
//! through *computed* mappings (bitpack, changetype, ...) transparently
//! run the mapping's pack/unpack logic — the Rust rendering of C++
//! LLAMA's proxy references.
//!
//! # Access API
//!
//! Two parallel method families address scalars:
//!
//! - **Typed (preferred)** — `*_t` methods plus the [`RecordRef`]
//!   navigation take a [`crate::record::FieldTag`] value and a const-rank
//!   [`crate::extents::ArrayIndex`] (`[usize; RANK]`). The field's scalar
//!   type is *inferred from the tag* and the rank from the extents, so a
//!   wrong-type, wrong-record, or wrong-rank access **does not compile**,
//!   and the monomorphized access carries no slice-length checks:
//!   [`View::get_t`]/[`View::set_t`], [`View::at_t`]/[`View::at_mut_t`],
//!   [`View::load_simd_t`]/[`View::store_simd_t`],
//!   [`RecordRef::field`]/[`RecordRefMut::field_mut`]/[`RecordRef::sub`],
//!   [`Chunk::load_t`]/[`Chunk::store_t`].
//! - **Legacy (compatibility)** — the original `usize`-index/`&[usize]`
//!   methods ([`View::get`]/[`View::set`], [`View::at`], [`Chunk::load`],
//!   ...). Their field parameter is now generic over [`FieldIndex`]
//!   (declared *after* the scalar type, so explicitly-typed call sites
//!   write `get::<f32, _>(...)`), accepting both raw `usize` values and
//!   typed tags, which convert to their index. Scalar type and index
//!   rank are checked only by debug asserts on the scalar path
//!   ([`View::at`]/[`View::at_mut`] do assert the rank at runtime, since
//!   they persist the index into a cursor). Kept for metadata-driven
//!   code ([`load_as_f64`], [`crate::copy`]); new code should use the
//!   typed family.
//!
//! Both families monomorphize to identical machine code when given the
//! same constant field — the typed path is zero-cost, verified by the
//! typed-vs-legacy property tests and the `fig3_nbody` bench rows.

use crate::blob::BlobStorage;
use crate::extents::{Extents, RankIndex};
use crate::mapping::{Mapping, MemoryAccess, SimdAccess};
use crate::record::{FieldIndex, FieldTag, GroupTag, RecordDim, Scalar, Selection};
use crate::simd::{Simd, SimdElem};
use std::marker::PhantomData;

/// Maximum supported array rank (extents tuples go up to 4).
pub const MAX_RANK: usize = 4;

/// The const-rank array index type of a view with mapping `M`:
/// `[usize; RANK]` with the rank taken from the mapping's extents.
pub type IndexOf<R, M> = <<M as Mapping<R>>::Extents as Extents>::ArrayIndex;

/// Convert a legacy `&[usize]` index to the const-rank array index,
/// asserting the rank matches (the one runtime check the compatibility
/// layer keeps; the typed API needs none).
#[inline(always)]
fn rank_checked<E: Extents>(idx: &[usize]) -> E::ArrayIndex {
    assert_eq!(
        idx.len(),
        E::RANK,
        "index rank {} does not match view rank {}",
        idx.len(),
        E::RANK
    );
    let mut a = <E::ArrayIndex as RankIndex>::zeroed();
    a.as_mut_slice().copy_from_slice(idx);
    a
}

/// A view over a data space: mapping + blob storage.
///
/// Construct with [`crate::blob::alloc_view`] or
/// [`crate::blob::array_view`]; see the crate root for a walkthrough.
#[derive(Clone, Copy, Debug, Default)]
pub struct View<R, M, S> {
    mapping: M,
    storage: S,
    _pd: PhantomData<R>,
}

impl<R, M, S> View<R, M, S>
where
    R: RecordDim,
    M: MemoryAccess<R>,
    S: BlobStorage,
{
    /// Assemble a view from an existing mapping and storage.
    ///
    /// The storage must provide at least `M::BLOB_COUNT` blobs of at least
    /// the mapping's `blob_size` each (checked).
    pub fn from_parts(mapping: M, storage: S) -> Self {
        assert!(
            storage.blob_count() >= M::BLOB_COUNT,
            "storage has {} blobs, mapping needs {}",
            storage.blob_count(),
            M::BLOB_COUNT
        );
        for i in 0..M::BLOB_COUNT {
            // `blob_len`, not `blob()`: validation must also work on the
            // shard-worker storage, which refuses whole-blob references.
            assert!(
                storage.blob_len(i) >= mapping.blob_size(i),
                "blob {i}: {} bytes provided, mapping needs {}",
                storage.blob_len(i),
                mapping.blob_size(i)
            );
        }
        View { mapping, storage, _pd: PhantomData }
    }

    /// Disassemble the view into mapping and storage (the inverse of
    /// [`from_parts`](View::from_parts); used by [`crate::transport`] to
    /// take the encoded payload buffer out without copying).
    pub fn into_parts(self) -> (M, S) {
        (self.mapping, self.storage)
    }

    /// The mapping.
    #[inline(always)]
    pub fn mapping(&self) -> &M {
        &self.mapping
    }

    /// The blob storage.
    #[inline(always)]
    pub fn storage(&self) -> &S {
        &self.storage
    }

    /// The blob storage, mutably (e.g. to memcpy a whole blob in).
    #[inline(always)]
    pub fn storage_mut(&mut self) -> &mut S {
        &mut self.storage
    }

    /// The array extents.
    #[inline(always)]
    pub fn extents(&self) -> &M::Extents {
        self.mapping.extents()
    }

    /// Records spanned by the view.
    #[inline(always)]
    pub fn count(&self) -> usize {
        self.mapping.extents().count()
    }

    // ---- typed access (compile-time-checked) ----

    /// Typed scalar load at `(idx, tag)` — the element type is inferred
    /// from the tag and the index rank from the extents, both checked at
    /// compile time.
    ///
    /// ```
    /// use llama::prelude::*;
    /// llama::record! { pub struct Px, mod px { r: f32, alpha: u8 } }
    /// let mut v = alloc_view(SoA::<Px, _>::new((Dyn(4u32),)), &HeapAlloc);
    /// v.set_t([2], px::alpha, 200u8);
    /// let a = v.get_t([2], px::alpha); // a: u8, inferred
    /// assert_eq!(a, 200);
    /// ```
    ///
    /// A wrong-type access does not compile (the legacy `usize` API only
    /// debug-asserts this):
    /// ```compile_fail,E0308
    /// use llama::prelude::*;
    /// llama::record! { pub struct Px, mod px { r: f32, alpha: u8 } }
    /// let v = alloc_view(SoA::<Px, _>::new((Dyn(4u32),)), &HeapAlloc);
    /// let _: f32 = v.get_t([0], px::alpha); // ERROR: alpha is u8, not f32
    /// ```
    ///
    /// Neither does a wrong-rank index ...
    /// ```compile_fail,E0308
    /// use llama::prelude::*;
    /// llama::record! { pub struct Px, mod px { r: f32, alpha: u8 } }
    /// let v = alloc_view(SoA::<Px, _>::new((Dyn(4u32), Dyn(4u32))), &HeapAlloc);
    /// let _ = v.get_t([0, 0, 0], px::r); // ERROR: rank-3 index, rank-2 view
    /// ```
    ///
    /// ... or a tag of a *different record dimension*:
    /// ```compile_fail,E0271
    /// use llama::prelude::*;
    /// llama::record! { pub struct Px, mod px { r: f32 } }
    /// llama::record! { pub struct Particle, mod particle { mass: f32 } }
    /// let v = alloc_view(SoA::<Px, _>::new((Dyn(4u32),)), &HeapAlloc);
    /// let _ = v.get_t([0], particle::mass); // ERROR: Particle tag, Px view
    /// ```
    #[inline(always)]
    pub fn get_t<F: FieldTag<Record = R>>(&self, idx: IndexOf<R, M>, tag: F) -> F::Elem {
        let _ = tag;
        self.mapping.load(&self.storage, idx.as_slice(), F::INDEX)
    }

    /// Typed scalar store at `(idx, tag)`; see [`get_t`](View::get_t).
    ///
    /// Storing a mistyped value does not compile:
    /// ```compile_fail,E0308
    /// use llama::prelude::*;
    /// llama::record! { pub struct Px, mod px { r: f32, alpha: u8 } }
    /// let mut v = alloc_view(SoA::<Px, _>::new((Dyn(4u32),)), &HeapAlloc);
    /// v.set_t([0], px::r, 1.0f64); // ERROR: r is f32
    /// ```
    #[inline(always)]
    pub fn set_t<F: FieldTag<Record = R>>(&mut self, idx: IndexOf<R, M>, tag: F, v: F::Elem) {
        let _ = tag;
        self.mapping.store(&mut self.storage, idx.as_slice(), F::INDEX, v)
    }

    /// Borrow the record at the const-rank index `idx`.
    #[inline(always)]
    pub fn at_t(&self, idx: IndexOf<R, M>) -> RecordRef<'_, R, M, S> {
        RecordRef { view: self, idx }
    }

    /// Mutably borrow the record at the const-rank index `idx`.
    #[inline(always)]
    pub fn at_mut_t(&mut self, idx: IndexOf<R, M>) -> RecordRefMut<'_, R, M, S> {
        RecordRefMut { view: self, idx }
    }

    // ---- legacy access (compatibility layer) ----

    /// Typed scalar load at `(idx, field)` — legacy entry point: `T` must
    /// be named explicitly (debug-asserted against the metadata) and the
    /// index rank is only checked by the mapping's debug asserts. Prefer
    /// [`get_t`](View::get_t).
    #[inline(always)]
    pub fn get<T: Scalar, F: FieldIndex>(&self, idx: &[usize], field: F) -> T {
        self.mapping.load(&self.storage, idx, field.field_index())
    }

    /// Typed scalar store at `(idx, field)` — legacy entry point; prefer
    /// [`set_t`](View::set_t).
    #[inline(always)]
    pub fn set<T: Scalar, F: FieldIndex>(&mut self, idx: &[usize], field: F, v: T) {
        self.mapping.store(&mut self.storage, idx, field.field_index(), v)
    }

    /// Borrow the record at `idx` (legacy entry point: rank checked at
    /// runtime; prefer [`at_t`](View::at_t)).
    #[inline(always)]
    pub fn at<'v>(&'v self, idx: &[usize]) -> RecordRef<'v, R, M, S> {
        RecordRef { view: self, idx: rank_checked::<M::Extents>(idx) }
    }

    /// Mutably borrow the record at `idx` (legacy entry point; prefer
    /// [`at_mut_t`](View::at_mut_t)).
    #[inline(always)]
    pub fn at_mut<'v>(&'v mut self, idx: &[usize]) -> RecordRefMut<'v, R, M, S> {
        RecordRefMut { view: self, idx: rank_checked::<M::Extents>(idx) }
    }

    /// Destructure into mapping and storage.
    pub fn into_parts(self) -> (M, S) {
        (self.mapping, self.storage)
    }
}

impl<R, M, S> View<R, M, S>
where
    R: RecordDim,
    M: SimdAccess<R>,
    S: BlobStorage,
{
    /// Typed `loadSimd`: `N` lanes of the tagged field starting at `idx`
    /// along the last array dimension, vectorized where the mapping
    /// allows (§5). Element type and index rank are compile-checked; see
    /// [`get_t`](View::get_t).
    #[inline(always)]
    pub fn load_simd_t<F, const N: usize>(&self, idx: IndexOf<R, M>, tag: F) -> Simd<F::Elem, N>
    where
        F: FieldTag<Record = R>,
        F::Elem: SimdElem,
    {
        let _ = tag;
        self.mapping.load_simd(&self.storage, idx.as_slice(), F::INDEX)
    }

    /// Typed `storeSimd`: write `N` lanes of the tagged field starting at
    /// `idx`.
    #[inline(always)]
    pub fn store_simd_t<F, const N: usize>(
        &mut self,
        idx: IndexOf<R, M>,
        tag: F,
        v: Simd<F::Elem, N>,
    ) where
        F: FieldTag<Record = R>,
        F::Elem: SimdElem,
    {
        let _ = tag;
        self.mapping.store_simd(&mut self.storage, idx.as_slice(), F::INDEX, v)
    }

    /// `loadSimd`: `N` lanes of `field` starting at `idx` along the last
    /// array dimension (legacy entry point; prefer
    /// [`load_simd_t`](View::load_simd_t)).
    #[inline(always)]
    pub fn load_simd<T: Scalar + SimdElem, const N: usize, F: FieldIndex>(
        &self,
        idx: &[usize],
        field: F,
    ) -> Simd<T, N> {
        self.mapping.load_simd(&self.storage, idx, field.field_index())
    }

    /// `storeSimd`: write `N` lanes of `field` starting at `idx` (legacy
    /// entry point; prefer [`store_simd_t`](View::store_simd_t)).
    #[inline(always)]
    pub fn store_simd<T: Scalar + SimdElem, const N: usize, F: FieldIndex>(
        &mut self,
        idx: &[usize],
        field: F,
        v: Simd<T, N>,
    ) {
        self.mapping.store_simd(&mut self.storage, idx, field.field_index(), v)
    }
}

// ---------------------------------------------------------------------------
// Bulk traversal engine
// ---------------------------------------------------------------------------

impl<R, M, S> View<R, M, S>
where
    R: RecordDim,
    M: MemoryAccess<R>,
    S: BlobStorage,
{
    /// Visit every record of the view once, in row-major index order,
    /// handing the closure a mutable record cursor.
    ///
    /// This is the scalar entry point of the bulk-traversal engine: it
    /// works for every mapping (physical, computed, instrumented) at any
    /// rank. Rank-1 views skip the odometer entirely; the per-record
    /// access cost is whatever the mapping's `load`/`store` costs — for
    /// SoA that monomorphizes to contiguous slice iteration, for
    /// computed mappings to their pack/unpack logic. The cursor's index
    /// is a const-rank array (no `MAX_RANK` padding, no per-access rank
    /// checks).
    ///
    /// The multithreaded counterpart is
    /// [`par_for_each`](crate::shard#parallel-traversal).
    pub fn for_each(&mut self, mut f: impl FnMut(&mut RecordRefMut<'_, R, M, S>)) {
        let outer = self.extents().extent(0);
        for_each_outer(self, 0, outer, &mut f);
    }
}

/// Visit every record whose outermost array index lies in
/// `[outer_begin, outer_end)`, in row-major order — the shared walker of
/// the serial [`View::for_each`] (full range) and of each parallel shard
/// ([`crate::shard::ShardCursor`], a sub-range).
pub(crate) fn for_each_outer<R, M, S>(
    view: &mut View<R, M, S>,
    outer_begin: usize,
    outer_end: usize,
    f: &mut impl FnMut(&mut RecordRefMut<'_, R, M, S>),
) where
    R: RecordDim,
    M: MemoryAccess<R>,
    S: BlobStorage,
{
    let rank = <M::Extents as Extents>::RANK;
    if outer_begin >= outer_end {
        return;
    }
    if rank == 1 {
        // Linear fast path: no index odometer in the loop.
        for i in outer_begin..outer_end {
            let mut idx = <IndexOf<R, M> as RankIndex>::zeroed();
            idx.as_mut_slice()[0] = i;
            f(&mut RecordRefMut { view: &mut *view, idx });
        }
        return;
    }
    let e = *view.extents();
    for d in 1..rank {
        if e.extent(d) == 0 {
            return;
        }
    }
    let mut idx = <IndexOf<R, M> as RankIndex>::zeroed();
    idx.as_mut_slice()[0] = outer_begin;
    loop {
        f(&mut RecordRefMut { view: &mut *view, idx });
        if !advance_bounded(&e, &mut idx, rank, outer_end) {
            return;
        }
    }
}

/// Advance the first `dims` dimensions of `idx` one step in row-major
/// order, with dimension 0 bounded by `outer_end` instead of its extent.
/// Returns `false` once `[.., outer_end)` is exhausted.
#[inline(always)]
fn advance_bounded<E: Extents>(
    e: &E,
    idx: &mut E::ArrayIndex,
    dims: usize,
    outer_end: usize,
) -> bool {
    let idx = idx.as_mut_slice();
    let mut d = dims;
    while d > 0 {
        d -= 1;
        idx[d] += 1;
        let limit = if d == 0 { outer_end } else { e.extent(d) };
        if idx[d] < limit {
            return true;
        }
        if d == 0 {
            return false;
        }
        idx[d] = 0;
    }
    false
}

impl<R, M, S> View<R, M, S>
where
    R: RecordDim,
    M: SimdAccess<R>,
    S: BlobStorage,
{
    /// Traverse the view in chunks of up to `N` records consecutive along
    /// the innermost array dimension, handing the closure a [`Chunk`]
    /// cursor whose `load`/`store` move whole lane vectors through the
    /// fastest path the mapping allows:
    ///
    /// - **SoA** lowers to contiguous slice moves over the field array,
    /// - **AoSoA** to in-block lane-vector moves (via [`SimdAccess`]),
    /// - **AoS** and the computed mappings (bitpack, bytesplit,
    ///   changetype) to a per-lane scalar walk — correct for every
    ///   mapping, and for AoS deliberately so (the paper found scalar
    ///   loads beat `gather` on the tested CPU).
    ///
    /// Works at any rank: the outer dimensions are walked by a row-major
    /// odometer while the innermost extent is vectorized — so the SoA /
    /// AoSoA fast paths fire on multidimensional views too. When `N` does
    /// not divide the innermost extent, the final chunk of each row is a
    /// *tail* with [`Chunk::lanes`]` < N`: its `load`/`store` fall back to
    /// a per-lane scalar walk (correct for every mapping) and the unused
    /// lanes read as `T::default()` / are never written.
    ///
    /// `N = 1` is the scalar traversal of Table 1 — identical operations
    /// to a hand-written scalar loop, so results are bit-identical.
    /// The chunk also exposes whole-view scalar access ([`Chunk::get_t`])
    /// for algorithms that combine streaming with random access (the
    /// n-body j-loop).
    ///
    /// The multithreaded counterpart is
    /// [`par_transform_simd`](crate::shard#parallel-traversal).
    pub fn transform_simd<const N: usize>(
        &mut self,
        mut f: impl FnMut(&mut Chunk<'_, R, M, S, N>),
    ) {
        assert!(N > 0, "lane count must be positive");
        let outer = self.extents().extent(0);
        walk_chunks(self, 0, outer, &mut f);
    }
}

/// Chunk-walk the records whose outermost array index lies in
/// `[outer_begin, outer_end)` — the shared walker of the serial
/// [`View::transform_simd`] (full range) and of each parallel shard.
///
/// Rank-1 views vectorize the outermost (= only) dimension directly;
/// higher ranks walk the outer dimensions with a row-major odometer and
/// vectorize the innermost extent, emitting a tail chunk per row when `N`
/// does not divide it.
pub(crate) fn walk_chunks<R, M, S, const N: usize>(
    view: &mut View<R, M, S>,
    outer_begin: usize,
    outer_end: usize,
    f: &mut impl FnMut(&mut Chunk<'_, R, M, S, N>),
) where
    R: RecordDim,
    M: SimdAccess<R>,
    S: BlobStorage,
{
    let rank = <M::Extents as Extents>::RANK;
    if outer_begin >= outer_end {
        return;
    }
    if rank == 1 {
        let mut b = outer_begin;
        while b < outer_end {
            let len = N.min(outer_end - b);
            let mut idx = <IndexOf<R, M> as RankIndex>::zeroed();
            idx.as_mut_slice()[0] = b;
            f(&mut Chunk { view: &mut *view, idx, len });
            b += N;
        }
        return;
    }
    let e = *view.extents();
    let last = rank - 1;
    let inner = e.extent(last);
    if inner == 0 {
        return;
    }
    for d in 1..last {
        if e.extent(d) == 0 {
            return;
        }
    }
    let mut idx = <IndexOf<R, M> as RankIndex>::zeroed();
    idx.as_mut_slice()[0] = outer_begin;
    loop {
        let mut b = 0;
        while b < inner {
            let len = N.min(inner - b);
            idx.as_mut_slice()[last] = b;
            f(&mut Chunk { view: &mut *view, idx, len });
            b += N;
        }
        idx.as_mut_slice()[last] = 0;
        if !advance_bounded(&e, &mut idx, last, outer_end) {
            return;
        }
    }
}

/// Cursor over up to `N` records consecutive along the innermost array
/// dimension during a bulk traversal ([`View::transform_simd`]).
/// `load_t`/`store_t` move whole lane vectors; `get_t`/`set_t` reach any
/// record of a rank-1 view scalar-wise. The index is a const-rank array
/// ([`crate::extents::ArrayIndex`]) — no padding, no per-access rank
/// checks.
pub struct Chunk<'v, R, M, S, const N: usize>
where
    R: RecordDim,
    M: Mapping<R>,
{
    view: &'v mut View<R, M, S>,
    idx: <M::Extents as Extents>::ArrayIndex,
    /// Active lanes: `N` except for the tail chunk of a row.
    len: usize,
}

impl<'v, R, M, S, const N: usize> Chunk<'v, R, M, S, N>
where
    R: RecordDim,
    M: SimdAccess<R>,
    S: BlobStorage,
{
    /// Array index of the chunk's first record.
    #[inline(always)]
    pub fn index(&self) -> &[usize] {
        self.idx.as_slice()
    }

    /// Row-major traversal position of the chunk's first record (for
    /// rank-1 views: its linear index).
    #[inline(always)]
    pub fn base(&self) -> usize {
        let rank = <M::Extents as Extents>::RANK;
        if rank == 1 {
            return self.idx.as_slice()[0];
        }
        let e = self.view.extents();
        let mut lin = 0usize;
        for d in 0..rank {
            lin = lin * e.extent(d) + self.idx.as_slice()[d];
        }
        lin
    }

    /// Active lanes of this chunk: `N`, except for the tail chunk of a
    /// row when `N` does not divide the innermost extent.
    #[inline(always)]
    pub fn lanes(&self) -> usize {
        self.len
    }

    /// Records in the whole view (for whole-view sweeps inside a chunk).
    #[inline(always)]
    pub fn count(&self) -> usize {
        self.view.count()
    }

    // ---- typed access (compile-time-checked) ----

    /// Typed load of the chunk's lanes of the tagged field — the lane
    /// element type is inferred from the tag. Tail chunks
    /// ([`lanes`](Chunk::lanes)` < N`) load lane-wise; their unused lanes
    /// are `Default::default()`.
    #[inline(always)]
    pub fn load_t<F>(&self, tag: F) -> Simd<F::Elem, N>
    where
        F: FieldTag<Record = R>,
        F::Elem: SimdElem,
    {
        let _ = tag;
        self.load::<F::Elem, _>(F::INDEX)
    }

    /// Typed store of the chunk's lanes of the tagged field. Tail chunks
    /// store lane-wise; lanes past [`lanes`](Chunk::lanes) are never
    /// written.
    #[inline(always)]
    pub fn store_t<F>(&mut self, tag: F, v: Simd<F::Elem, N>)
    where
        F: FieldTag<Record = R>,
        F::Elem: SimdElem,
    {
        let _ = tag;
        self.store::<F::Elem, _>(F::INDEX, v)
    }

    /// Typed scalar load of the tagged field at any record `i` of a
    /// rank-1 view (compile error on higher ranks).
    #[inline(always)]
    pub fn get_t<F: FieldTag<Record = R>>(&self, i: usize, tag: F) -> F::Elem {
        const {
            assert!(
                <M::Extents as Extents>::RANK == 1,
                "Chunk::get_t addresses records by rank-1 index"
            )
        };
        let _ = tag;
        self.view.get(&[i], F::INDEX)
    }

    /// Typed scalar store of the tagged field at any record `i` of a
    /// rank-1 view (compile error on higher ranks).
    #[inline(always)]
    pub fn set_t<F: FieldTag<Record = R>>(&mut self, i: usize, tag: F, v: F::Elem) {
        const {
            assert!(
                <M::Extents as Extents>::RANK == 1,
                "Chunk::set_t addresses records by rank-1 index"
            )
        };
        let _ = tag;
        self.view.set(&[i], F::INDEX, v)
    }

    // ---- legacy access (compatibility layer) ----

    /// Load the chunk's lanes of `field` (legacy entry point; prefer
    /// [`load_t`](Chunk::load_t)). Tail chunks load lane-wise; their
    /// unused lanes are `T::default()`.
    #[inline(always)]
    pub fn load<T: Scalar + SimdElem, F: FieldIndex>(&self, field: F) -> Simd<T, N> {
        let field = field.field_index();
        if self.len == N {
            return self.view.load_simd(self.idx.as_slice(), field);
        }
        let mut out = Simd::<T, N>::default();
        let last = <M::Extents as Extents>::RANK - 1;
        let mut idx = self.idx;
        for k in 0..self.len {
            idx.as_mut_slice()[last] = self.idx.as_slice()[last] + k;
            out.0[k] = self.view.get(idx.as_slice(), field);
        }
        out
    }

    /// Store the chunk's lanes of `field` (legacy entry point; prefer
    /// [`store_t`](Chunk::store_t)). Tail chunks store lane-wise; lanes
    /// past [`lanes`](Chunk::lanes) are never written.
    #[inline(always)]
    pub fn store<T: Scalar + SimdElem, F: FieldIndex>(&mut self, field: F, v: Simd<T, N>) {
        let field = field.field_index();
        if self.len == N {
            self.view.store_simd(self.idx.as_slice(), field, v);
            return;
        }
        let last = <M::Extents as Extents>::RANK - 1;
        let mut idx = self.idx;
        for k in 0..self.len {
            idx.as_mut_slice()[last] = self.idx.as_slice()[last] + k;
            self.view.set(idx.as_slice(), field, v.0[k]);
        }
    }

    /// Scalar load of `field` at any record `i` of a rank-1 view (legacy
    /// entry point; prefer [`get_t`](Chunk::get_t)).
    #[inline(always)]
    pub fn get<T: Scalar, F: FieldIndex>(&self, i: usize, field: F) -> T {
        debug_assert_eq!(
            <M::Extents as Extents>::RANK,
            1,
            "Chunk::get addresses records by rank-1 index"
        );
        self.view.get(&[i], field.field_index())
    }

    /// Scalar store of `field` at any record `i` of a rank-1 view (legacy
    /// entry point; prefer [`set_t`](Chunk::set_t)).
    #[inline(always)]
    pub fn set<T: Scalar, F: FieldIndex>(&mut self, i: usize, field: F, v: T) {
        debug_assert_eq!(
            <M::Extents as Extents>::RANK,
            1,
            "Chunk::set addresses records by rank-1 index"
        );
        self.view.set(&[i], field.field_index(), v)
    }
}

/// Immutable reference to one record of a view (LLAMA `RecordRef`).
pub struct RecordRef<'v, R, M, S>
where
    R: RecordDim,
    M: Mapping<R>,
{
    view: &'v View<R, M, S>,
    idx: <M::Extents as Extents>::ArrayIndex,
}

impl<'v, R, M, S> Clone for RecordRef<'v, R, M, S>
where
    R: RecordDim,
    M: Mapping<R>,
{
    #[inline(always)]
    fn clone(&self) -> Self {
        *self
    }
}

impl<'v, R, M, S> Copy for RecordRef<'v, R, M, S>
where
    R: RecordDim,
    M: Mapping<R>,
{
}

impl<'v, R, M, S> RecordRef<'v, R, M, S>
where
    R: RecordDim,
    M: MemoryAccess<R>,
    S: BlobStorage,
{
    /// The array index of this record.
    #[inline(always)]
    pub fn index(&self) -> &[usize] {
        self.idx.as_slice()
    }

    /// Typed scalar load of the tagged field — the element type is
    /// inferred from the tag (compile-time checked).
    #[inline(always)]
    pub fn field<F: FieldTag<Record = R>>(&self, tag: F) -> F::Elem {
        let _ = tag;
        self.view.get(self.idx.as_slice(), F::INDEX)
    }

    /// Project onto the sub-record named by the selection tag — the typed
    /// way to read a whole selection (e.g. widened to `f64` via
    /// [`SubRecordRef::read_f64`]).
    ///
    /// ```
    /// use llama::prelude::*;
    /// llama::record! { pub struct P, mod p { pos: { x: f64, y: f64 }, q: i32 } }
    /// let mut v = alloc_view(SoA::<P, _>::new((Dyn(4u32),)), &HeapAlloc);
    /// v.set_t([1], p::pos::x, 1.5);
    /// let r = v.at_t([1]);
    /// let pos = r.sub(p::pos);
    /// assert_eq!(pos.field(p::pos::x), 1.5); // typed leaf within the span
    /// assert_eq!(pos.read_f64(), vec![1.5, 0.0]);
    /// ```
    #[inline(always)]
    pub fn sub<G: GroupTag<Record = R>>(&self, group: G) -> SubRecordRef<'v, R, M, S, G> {
        let _ = group;
        SubRecordRef { view: self.view, idx: self.idx, _pd: PhantomData }
    }

    /// Typed scalar load of `field` (legacy entry point; prefer
    /// [`field`](RecordRef::field)).
    #[inline(always)]
    pub fn get<T: Scalar, F: FieldIndex>(&self, field: F) -> T {
        self.view.get(self.idx.as_slice(), field.field_index())
    }
}

/// Typed projection of one record onto a sub-record span, produced by
/// [`RecordRef::sub`] / [`RecordRefMut::sub`]. The selection (start, len,
/// record dimension) lives in the type, so cross-record selections are
/// compile errors and leaf access within the span is compile-checked.
pub struct SubRecordRef<'v, R, M, S, G>
where
    R: RecordDim,
    M: Mapping<R>,
{
    view: &'v View<R, M, S>,
    idx: <M::Extents as Extents>::ArrayIndex,
    _pd: PhantomData<G>,
}

impl<'v, R, M, S, G> SubRecordRef<'v, R, M, S, G>
where
    R: RecordDim,
    M: MemoryAccess<R>,
    S: BlobStorage,
    G: GroupTag<Record = R>,
{
    /// The span as a runtime [`Selection`].
    #[inline(always)]
    pub fn selection(&self) -> Selection {
        G::SELECTION
    }

    /// Number of leaves in the span.
    #[inline(always)]
    pub fn len(&self) -> usize {
        G::LEN
    }

    /// Whether the span is empty.
    #[inline(always)]
    pub fn is_empty(&self) -> bool {
        G::LEN == 0
    }

    /// Typed scalar load of a leaf *within the span* — membership is
    /// checked at compile time (a tag outside the sub-record fails the
    /// build during monomorphization).
    #[inline(always)]
    pub fn field<F: FieldTag<Record = R>>(&self, tag: F) -> F::Elem {
        const {
            assert!(
                F::INDEX >= G::START && F::INDEX < G::START + G::LEN,
                "field tag is not part of this sub-record selection"
            )
        };
        let _ = tag;
        self.view.get(self.idx.as_slice(), F::INDEX)
    }

    /// Load every leaf of the span widened to `f64`, in span order (the
    /// typed successor of the removed `RecordRef::get_selection_f64`).
    pub fn read_f64(&self) -> Vec<f64> {
        G::SELECTION.indices().map(|f| load_as_f64(self.view, self.idx.as_slice(), f)).collect()
    }
}

/// Mutable reference to one record of a view.
pub struct RecordRefMut<'v, R, M, S>
where
    R: RecordDim,
    M: Mapping<R>,
{
    view: &'v mut View<R, M, S>,
    idx: <M::Extents as Extents>::ArrayIndex,
}

impl<'v, R, M, S> RecordRefMut<'v, R, M, S>
where
    R: RecordDim,
    M: MemoryAccess<R>,
    S: BlobStorage,
{
    /// The array index of this record.
    #[inline(always)]
    pub fn index(&self) -> &[usize] {
        self.idx.as_slice()
    }

    /// Typed scalar load of the tagged field (compile-time checked).
    #[inline(always)]
    pub fn field<F: FieldTag<Record = R>>(&self, tag: F) -> F::Elem {
        let _ = tag;
        let idx = self.idx;
        self.view.get(idx.as_slice(), F::INDEX)
    }

    /// Typed scalar store of the tagged field (compile-time checked).
    #[inline(always)]
    pub fn set_field<F: FieldTag<Record = R>>(&mut self, tag: F, v: F::Elem) {
        let _ = tag;
        let idx = self.idx;
        self.view.set(idx.as_slice(), F::INDEX, v)
    }

    /// Navigate to the tagged field, yielding a read/write proxy — the
    /// Rust rendering of LLAMA's proxy references, usable through
    /// computed mappings (which have no address to hand out).
    #[inline(always)]
    pub fn field_mut<F: FieldTag<Record = R>>(&mut self, tag: F) -> FieldRefMut<'_, R, M, S, F> {
        let _ = tag;
        FieldRefMut { view: &mut *self.view, idx: self.idx, _pd: PhantomData }
    }

    /// Project onto the sub-record named by the selection tag (read-only;
    /// see [`RecordRef::sub`]).
    #[inline(always)]
    pub fn sub<G: GroupTag<Record = R>>(&self, group: G) -> SubRecordRef<'_, R, M, S, G> {
        let _ = group;
        SubRecordRef { view: &*self.view, idx: self.idx, _pd: PhantomData }
    }

    /// Typed scalar load of `field` (legacy entry point; prefer
    /// [`field`](RecordRefMut::field)).
    #[inline(always)]
    pub fn get<T: Scalar, F: FieldIndex>(&self, field: F) -> T {
        let idx = self.idx;
        self.view.get(idx.as_slice(), field.field_index())
    }

    /// Typed scalar store of `field` (legacy entry point; prefer
    /// [`set_field`](RecordRefMut::set_field)).
    #[inline(always)]
    pub fn set<T: Scalar, F: FieldIndex>(&mut self, field: F, v: T) {
        let idx = self.idx;
        self.view.set(idx.as_slice(), field.field_index(), v)
    }
}

/// Read/write proxy to one tagged field of one record, produced by
/// [`RecordRefMut::field_mut`]. Works through computed mappings: `get`
/// runs the mapping's unpack logic, `set` its pack logic.
pub struct FieldRefMut<'v, R, M, S, F>
where
    R: RecordDim,
    M: Mapping<R>,
{
    view: &'v mut View<R, M, S>,
    idx: <M::Extents as Extents>::ArrayIndex,
    _pd: PhantomData<F>,
}

impl<'v, R, M, S, F> FieldRefMut<'v, R, M, S, F>
where
    R: RecordDim,
    M: MemoryAccess<R>,
    S: BlobStorage,
    F: FieldTag<Record = R>,
{
    /// Load the field's value.
    #[inline(always)]
    pub fn get(&self) -> F::Elem {
        let idx = self.idx;
        self.view.get(idx.as_slice(), F::INDEX)
    }

    /// Store a value into the field.
    #[inline(always)]
    pub fn set(&mut self, v: F::Elem) {
        let idx = self.idx;
        self.view.set(idx.as_slice(), F::INDEX, v)
    }

    /// Read-modify-write the field through the mapping.
    #[inline(always)]
    pub fn update(&mut self, f: impl FnOnce(F::Elem) -> F::Elem) {
        let v = self.get();
        self.set(f(v));
    }
}

/// Load `(idx, field)` as `f64` regardless of the field's scalar type
/// (dispatches on the record metadata; used by copy/report paths).
pub fn load_as_f64<R, M, S>(view: &View<R, M, S>, idx: &[usize], field: usize) -> f64
where
    R: RecordDim,
    M: MemoryAccess<R>,
    S: BlobStorage,
{
    use crate::record::ScalarType as St;
    match R::FIELDS[field].ty {
        St::F32 => view.get::<f32, _>(idx, field) as f64,
        St::F64 => view.get::<f64, _>(idx, field),
        St::I8 => view.get::<i8, _>(idx, field) as f64,
        St::I16 => view.get::<i16, _>(idx, field) as f64,
        St::I32 => view.get::<i32, _>(idx, field) as f64,
        St::I64 => view.get::<i64, _>(idx, field) as f64,
        St::U8 => view.get::<u8, _>(idx, field) as f64,
        St::U16 => view.get::<u16, _>(idx, field) as f64,
        St::U32 => view.get::<u32, _>(idx, field) as f64,
        St::U64 => view.get::<u64, _>(idx, field) as f64,
        St::Bool => view.get::<bool, _>(idx, field) as u8 as f64,
        St::F16 => view.get::<crate::record::F16, _>(idx, field).as_f64(),
        St::Bf16 => view.get::<crate::record::Bf16, _>(idx, field).as_f64(),
    }
}

/// Store `v` (given as `f64`) into `(idx, field)` with the field's scalar
/// type (dispatches on the record metadata; used by copy/report paths).
pub fn store_from_f64<R, M, S>(view: &mut View<R, M, S>, idx: &[usize], field: usize, v: f64)
where
    R: RecordDim,
    M: MemoryAccess<R>,
    S: BlobStorage,
{
    use crate::record::ScalarType as St;
    match R::FIELDS[field].ty {
        St::F32 => view.set(idx, field, v as f32),
        St::F64 => view.set(idx, field, v),
        St::I8 => view.set(idx, field, v as i8),
        St::I16 => view.set(idx, field, v as i16),
        St::I32 => view.set(idx, field, v as i32),
        St::I64 => view.set(idx, field, v as i64),
        St::U8 => view.set(idx, field, v as u8),
        St::U16 => view.set(idx, field, v as u16),
        St::U32 => view.set(idx, field, v as u32),
        St::U64 => view.set(idx, field, v as u64),
        St::Bool => view.set(idx, field, v != 0.0),
        St::F16 => view.set(idx, field, crate::record::F16::from_f64(v)),
        St::Bf16 => view.set(idx, field, crate::record::Bf16::from_f64(v)),
    }
}

#[cfg(test)]
mod tests {
    use crate::blob::{alloc_view, array_view, HeapAlloc};
    use crate::extents::{Dyn, Fix};
    use crate::mapping::aos::AoS;
    use crate::mapping::soa::SoA;

    crate::record! {
        pub struct P, mod p {
            pos: { x: f64, y: f64 },
            q: i32,
        }
    }

    #[test]
    fn record_ref_access() {
        let mut v = alloc_view(SoA::<P, _>::new((Dyn(8u32),)), &HeapAlloc);
        {
            let mut r = v.at_mut_t([5]);
            r.set_field(p::pos::x, 1.5f64);
            r.set_field(p::q, -3i32);
            assert_eq!(r.field(p::pos::x), 1.5);
        }
        let r = v.at_t([5]);
        assert_eq!(r.field(p::q), -3);
        assert_eq!(r.sub(p::pos).read_f64(), vec![1.5, 0.0]);
        assert_eq!(r.index(), &[5]);
    }

    #[test]
    fn legacy_api_agrees_with_typed() {
        let mut v = alloc_view(SoA::<P, _>::new((Dyn(8u32),)), &HeapAlloc);
        // Legacy entry points accept both raw usize indices and tags.
        v.set(&[2], p::pos::y, 2.5f64);
        v.set(&[2], 2usize, 9i32); // p::q by raw index
        assert_eq!(v.get::<f64, _>(&[2], p::pos::y), 2.5);
        assert_eq!(v.get_t([2], p::q), 9);
        let r = v.at(&[2]);
        assert_eq!(r.get::<f64, _>(p::pos::y), 2.5);
        assert_eq!(r.sub(p::pos).read_f64(), vec![0.0, 2.5]);
    }

    #[test]
    fn field_mut_proxy_reads_and_writes() {
        let mut v = alloc_view(AoS::<P, _>::new((Dyn(4u32),)), &HeapAlloc);
        let mut r = v.at_mut_t([1]);
        let mut fx = r.field_mut(p::pos::x);
        assert_eq!(fx.get(), 0.0);
        fx.set(4.0);
        fx.update(|x| x * 2.0);
        assert_eq!(r.field(p::pos::x), 8.0);
    }

    #[test]
    #[should_panic(expected = "does not match view rank")]
    fn legacy_at_checks_rank() {
        let v = alloc_view(SoA::<P, _>::new((Dyn(4u32), Dyn(4u32))), &HeapAlloc);
        let _ = v.at(&[1]); // rank-1 index on a rank-2 view
    }

    #[test]
    fn zero_overhead_view() {
        use crate::mapping::Mapping;
        // §2: fully static extents + stateless mapping + inline storage
        // => size_of(view) == size of the mapped data exactly.
        type E = (Fix<u32, 16>,);
        type M = AoS<P, E>;
        let m = M::new((Fix::new(),));
        let record_size = 24; // x(8) y(8) q(4) pad(4)
        assert_eq!(m.blob_size(0), 16 * record_size);
        let v = array_view::<P, M, { 16 * 24 }, 1>(m);
        assert_eq!(std::mem::size_of_val(&v), 16 * record_size);
        // trivially copyable (Copy): move a *copy* around
        let v2 = v;
        let _ = v2;
    }

    #[test]
    fn load_store_as_f64() {
        use super::{load_as_f64, store_from_f64};
        let mut v = alloc_view(SoA::<P, _>::new((Dyn(4u32),)), &HeapAlloc);
        store_from_f64(&mut v, &[1], p::q.i(), 42.0);
        assert_eq!(v.get_t([1], p::q), 42);
        assert_eq!(load_as_f64(&v, &[1], p::q.i()), 42.0);
    }

    #[test]
    fn for_each_visits_every_record_once_any_rank() {
        let mut v = alloc_view(SoA::<P, _>::new((Dyn(6u32),)), &HeapAlloc);
        v.for_each(|r| {
            let i = r.index()[0];
            r.set_field(p::q, i as i32 + 1);
        });
        for i in 0..6 {
            assert_eq!(v.get_t([i], p::q), i as i32 + 1);
        }

        let mut v2 = alloc_view(AoS::<P, _>::new((Dyn(3u32), Dyn(4u32))), &HeapAlloc);
        let mut seen = Vec::new();
        v2.for_each(|r| {
            seen.push((r.index()[0], r.index()[1]));
            let (i, j) = (r.index()[0], r.index()[1]);
            r.set_field(p::pos::x, (i * 10 + j) as f64);
        });
        assert_eq!(seen.len(), 12);
        // row-major order, each index exactly once
        assert_eq!(seen[0], (0, 0));
        assert_eq!(seen[1], (0, 1));
        assert_eq!(seen[11], (2, 3));
        assert_eq!(v2.get_t([2, 3], p::pos::x), 23.0);
    }

    #[test]
    fn transform_simd_chunks_cover_the_view() {
        let mut v = alloc_view(SoA::<P, _>::new((Dyn(16u32),)), &HeapAlloc);
        for i in 0..16 {
            v.set_t([i], p::pos::x, i as f64);
        }
        let mut bases = Vec::new();
        v.transform_simd::<4>(|c| {
            bases.push(c.base());
            let x = c.load_t(p::pos::x);
            c.store_t(p::pos::x, x + crate::simd::Simd::splat(100.0));
        });
        assert_eq!(bases, vec![0, 4, 8, 12]);
        for i in 0..16 {
            assert_eq!(v.get_t([i], p::pos::x), i as f64 + 100.0);
        }
    }

    #[test]
    fn chunk_exposes_whole_view_scalar_access() {
        let mut v = alloc_view(SoA::<P, _>::new((Dyn(8u32),)), &HeapAlloc);
        for i in 0..8 {
            v.set_t([i], p::pos::x, i as f64);
        }
        // Each chunk sums the whole view (the n-body j-loop shape).
        v.transform_simd::<2>(|c| {
            let mut sum = 0.0;
            for j in 0..c.count() {
                sum += c.get_t(j, p::pos::x);
            }
            c.set_t(c.base(), p::pos::y, sum);
        });
        for base in [0usize, 2, 4, 6] {
            assert_eq!(v.get_t([base], p::pos::y), 28.0);
        }
    }

    #[test]
    fn transform_simd_handles_ragged_extents_with_a_tail_chunk() {
        let mut v = alloc_view(SoA::<P, _>::new((Dyn(10u32),)), &HeapAlloc);
        for i in 0..10 {
            v.set_t([i], p::pos::x, i as f64);
        }
        let mut seen = Vec::new();
        v.transform_simd::<4>(|c| {
            seen.push((c.base(), c.lanes()));
            let x = c.load_t(p::pos::x);
            if c.lanes() < 4 {
                // Inactive lanes load as default.
                assert_eq!(x.0[2], 0.0);
                assert_eq!(x.0[3], 0.0);
            }
            c.store_t(p::pos::x, x + crate::simd::Simd::splat(100.0));
        });
        assert_eq!(seen, vec![(0, 4), (4, 4), (8, 2)]);
        for i in 0..10 {
            assert_eq!(v.get_t([i], p::pos::x), i as f64 + 100.0);
        }
    }

    #[test]
    fn transform_simd_rank2_vectorizes_the_innermost_extent() {
        // 3 rows of 10: per row, chunks at inner 0, 4, 8 (tail of 2).
        let mut v = alloc_view(SoA::<P, _>::new((Dyn(3u32), Dyn(10u32))), &HeapAlloc);
        let mut chunks = Vec::new();
        v.transform_simd::<4>(|c| {
            chunks.push((c.index().to_vec(), c.lanes()));
            let x = c.load_t(p::pos::x);
            c.store_t(p::pos::x, x + crate::simd::Simd::splat(1.0));
        });
        assert_eq!(chunks.len(), 9);
        assert_eq!(chunks[0], (vec![0, 0], 4));
        assert_eq!(chunks[2], (vec![0, 8], 2));
        assert_eq!(chunks[8], (vec![2, 8], 2));
        // Every record incremented exactly once.
        for i in 0..3 {
            for j in 0..10 {
                assert_eq!(v.get_t([i, j], p::pos::x), 1.0);
            }
        }
    }

    #[test]
    #[should_panic(expected = "blob 0")]
    fn from_parts_validates_sizes() {
        use crate::blob::{BlobAlloc, HeapAlloc};
        let m = SoA::<P, _>::new((Dyn(1000u32),));
        let storage = HeapAlloc.alloc(&[8, 8, 8]); // far too small
        let _ = crate::view::View::<P, _, _>::from_parts(m, storage);
    }
}
