//! Views and record references: the program-facing access layer.
//!
//! A [`View`] binds a mapping to blob storage and spans the data space.
//! Programs address records with array indices, obtaining a [`RecordRef`]
//! (or [`RecordRefMut`]) — the analogue of LLAMA's `RecordRef` — and
//! finally scalars via typed `get`/`set` with tag constants from
//! [`crate::record!`]. Loads/stores through *computed* mappings (bitpack,
//! changetype, ...) transparently run the mapping's pack/unpack logic —
//! the Rust rendering of C++ LLAMA's proxy references.

use crate::blob::BlobStorage;
use crate::extents::Extents;
use crate::mapping::{MemoryAccess, SimdAccess};
use crate::record::{RecordDim, Scalar, Selection};
use crate::simd::{Simd, SimdElem};
use std::marker::PhantomData;

/// Maximum supported array rank (extents tuples go up to 4).
pub const MAX_RANK: usize = 4;

/// A view over a data space: mapping + blob storage.
///
/// Construct with [`crate::blob::alloc_view`] or
/// [`crate::blob::array_view`]; see the crate root for a walkthrough.
#[derive(Clone, Copy, Debug, Default)]
pub struct View<R, M, S> {
    mapping: M,
    storage: S,
    _pd: PhantomData<R>,
}

impl<R, M, S> View<R, M, S>
where
    R: RecordDim,
    M: MemoryAccess<R>,
    S: BlobStorage,
{
    /// Assemble a view from an existing mapping and storage.
    ///
    /// The storage must provide at least `M::BLOB_COUNT` blobs of at least
    /// the mapping's `blob_size` each (checked).
    pub fn from_parts(mapping: M, storage: S) -> Self {
        assert!(
            storage.blob_count() >= M::BLOB_COUNT,
            "storage has {} blobs, mapping needs {}",
            storage.blob_count(),
            M::BLOB_COUNT
        );
        for i in 0..M::BLOB_COUNT {
            assert!(
                storage.blob(i).len() >= mapping.blob_size(i),
                "blob {i}: {} bytes provided, mapping needs {}",
                storage.blob(i).len(),
                mapping.blob_size(i)
            );
        }
        View { mapping, storage, _pd: PhantomData }
    }

    /// The mapping.
    #[inline(always)]
    pub fn mapping(&self) -> &M {
        &self.mapping
    }

    /// The blob storage.
    #[inline(always)]
    pub fn storage(&self) -> &S {
        &self.storage
    }

    /// The blob storage, mutably (e.g. to memcpy a whole blob in).
    #[inline(always)]
    pub fn storage_mut(&mut self) -> &mut S {
        &mut self.storage
    }

    /// The array extents.
    #[inline(always)]
    pub fn extents(&self) -> &M::Extents {
        self.mapping.extents()
    }

    /// Records spanned by the view.
    #[inline(always)]
    pub fn count(&self) -> usize {
        self.mapping.extents().count()
    }

    /// Typed scalar load at `(idx, field)`.
    #[inline(always)]
    pub fn get<T: Scalar>(&self, idx: &[usize], field: usize) -> T {
        self.mapping.load(&self.storage, idx, field)
    }

    /// Typed scalar store at `(idx, field)`.
    #[inline(always)]
    pub fn set<T: Scalar>(&mut self, idx: &[usize], field: usize, v: T) {
        self.mapping.store(&mut self.storage, idx, field, v)
    }

    /// Borrow the record at `idx`.
    #[inline(always)]
    pub fn at<'v>(&'v self, idx: &[usize]) -> RecordRef<'v, R, M, S> {
        RecordRef { view: self, idx: pad_idx(idx), rank: idx.len() }
    }

    /// Mutably borrow the record at `idx`.
    #[inline(always)]
    pub fn at_mut<'v>(&'v mut self, idx: &[usize]) -> RecordRefMut<'v, R, M, S> {
        RecordRefMut { view: self, idx: pad_idx(idx), rank: idx.len() }
    }

    /// Destructure into mapping and storage.
    pub fn into_parts(self) -> (M, S) {
        (self.mapping, self.storage)
    }
}

impl<R, M, S> View<R, M, S>
where
    R: RecordDim,
    M: SimdAccess<R>,
    S: BlobStorage,
{
    /// `loadSimd`: `N` lanes of `field` starting at `idx` along the last
    /// array dimension, vectorized where the mapping allows (§5).
    #[inline(always)]
    pub fn load_simd<T: Scalar + SimdElem, const N: usize>(
        &self,
        idx: &[usize],
        field: usize,
    ) -> Simd<T, N> {
        self.mapping.load_simd(&self.storage, idx, field)
    }

    /// `storeSimd`: write `N` lanes of `field` starting at `idx`.
    #[inline(always)]
    pub fn store_simd<T: Scalar + SimdElem, const N: usize>(
        &mut self,
        idx: &[usize],
        field: usize,
        v: Simd<T, N>,
    ) {
        self.mapping.store_simd(&mut self.storage, idx, field, v)
    }
}

// ---------------------------------------------------------------------------
// Bulk traversal engine
// ---------------------------------------------------------------------------

impl<R, M, S> View<R, M, S>
where
    R: RecordDim,
    M: MemoryAccess<R>,
    S: BlobStorage,
{
    /// Visit every record of the view once, in row-major index order,
    /// handing the closure a mutable record cursor.
    ///
    /// This is the scalar entry point of the bulk-traversal engine: it
    /// works for every mapping (physical, computed, instrumented) at any
    /// rank. Rank-1 views skip the odometer entirely; the per-record
    /// access cost is whatever the mapping's `load`/`store` costs — for
    /// SoA that monomorphizes to contiguous slice iteration, for
    /// computed mappings to their pack/unpack logic.
    ///
    /// The multithreaded counterpart is
    /// [`par_for_each`](crate::shard#parallel-traversal).
    pub fn for_each(&mut self, mut f: impl FnMut(&mut RecordRefMut<'_, R, M, S>)) {
        let outer = self.extents().extent(0);
        for_each_outer(self, 0, outer, &mut f);
    }
}

/// Visit every record whose outermost array index lies in
/// `[outer_begin, outer_end)`, in row-major order — the shared walker of
/// the serial [`View::for_each`] (full range) and of each parallel shard
/// ([`crate::shard::ShardCursor`], a sub-range).
pub(crate) fn for_each_outer<R, M, S>(
    view: &mut View<R, M, S>,
    outer_begin: usize,
    outer_end: usize,
    f: &mut impl FnMut(&mut RecordRefMut<'_, R, M, S>),
) where
    R: RecordDim,
    M: MemoryAccess<R>,
    S: BlobStorage,
{
    let rank = <M::Extents as Extents>::RANK;
    if outer_begin >= outer_end {
        return;
    }
    if rank == 1 {
        // Linear fast path: no index odometer in the loop.
        for i in outer_begin..outer_end {
            f(&mut view.at_mut(&[i]));
        }
        return;
    }
    let e = *view.extents();
    for d in 1..rank {
        if e.extent(d) == 0 {
            return;
        }
    }
    let mut idx = [0usize; MAX_RANK];
    idx[0] = outer_begin;
    loop {
        f(&mut view.at_mut(&idx[..rank]));
        if !advance_bounded(&e, &mut idx, rank, outer_end) {
            return;
        }
    }
}

/// Advance the first `dims` dimensions of `idx` one step in row-major
/// order, with dimension 0 bounded by `outer_end` instead of its extent.
/// Returns `false` once `[.., outer_end)` is exhausted.
#[inline(always)]
fn advance_bounded<E: Extents>(
    e: &E,
    idx: &mut [usize; MAX_RANK],
    dims: usize,
    outer_end: usize,
) -> bool {
    let mut d = dims;
    while d > 0 {
        d -= 1;
        idx[d] += 1;
        let limit = if d == 0 { outer_end } else { e.extent(d) };
        if idx[d] < limit {
            return true;
        }
        if d == 0 {
            return false;
        }
        idx[d] = 0;
    }
    false
}

impl<R, M, S> View<R, M, S>
where
    R: RecordDim,
    M: SimdAccess<R>,
    S: BlobStorage,
{
    /// Traverse the view in chunks of up to `N` records consecutive along
    /// the innermost array dimension, handing the closure a [`Chunk`]
    /// cursor whose `load`/`store` move whole lane vectors through the
    /// fastest path the mapping allows:
    ///
    /// - **SoA** lowers to contiguous slice moves over the field array,
    /// - **AoSoA** to in-block lane-vector moves (via [`SimdAccess`]),
    /// - **AoS** and the computed mappings (bitpack, bytesplit,
    ///   changetype) to a per-lane scalar walk — correct for every
    ///   mapping, and for AoS deliberately so (the paper found scalar
    ///   loads beat `gather` on the tested CPU).
    ///
    /// Works at any rank: the outer dimensions are walked by a row-major
    /// odometer while the innermost extent is vectorized — so the SoA /
    /// AoSoA fast paths fire on multidimensional views too. When `N` does
    /// not divide the innermost extent, the final chunk of each row is a
    /// *tail* with [`Chunk::lanes`]` < N`: its `load`/`store` fall back to
    /// a per-lane scalar walk (correct for every mapping) and the unused
    /// lanes read as `T::default()` / are never written.
    ///
    /// `N = 1` is the scalar traversal of Table 1 — identical operations
    /// to a hand-written scalar loop, so results are bit-identical.
    /// The chunk also exposes whole-view scalar access ([`Chunk::get`])
    /// for algorithms that combine streaming with random access (the
    /// n-body j-loop).
    ///
    /// The multithreaded counterpart is
    /// [`par_transform_simd`](crate::shard#parallel-traversal).
    pub fn transform_simd<const N: usize>(
        &mut self,
        mut f: impl FnMut(&mut Chunk<'_, R, M, S, N>),
    ) {
        assert!(N > 0, "lane count must be positive");
        let outer = self.extents().extent(0);
        walk_chunks(self, 0, outer, &mut f);
    }
}

/// Chunk-walk the records whose outermost array index lies in
/// `[outer_begin, outer_end)` — the shared walker of the serial
/// [`View::transform_simd`] (full range) and of each parallel shard.
///
/// Rank-1 views vectorize the outermost (= only) dimension directly;
/// higher ranks walk the outer dimensions with a row-major odometer and
/// vectorize the innermost extent, emitting a tail chunk per row when `N`
/// does not divide it.
pub(crate) fn walk_chunks<R, M, S, const N: usize>(
    view: &mut View<R, M, S>,
    outer_begin: usize,
    outer_end: usize,
    f: &mut impl FnMut(&mut Chunk<'_, R, M, S, N>),
) where
    R: RecordDim,
    M: SimdAccess<R>,
    S: BlobStorage,
{
    let rank = <M::Extents as Extents>::RANK;
    if outer_begin >= outer_end {
        return;
    }
    if rank == 1 {
        let mut b = outer_begin;
        while b < outer_end {
            let len = N.min(outer_end - b);
            let mut idx = [0usize; MAX_RANK];
            idx[0] = b;
            f(&mut Chunk { view: &mut *view, idx, rank, len });
            b += N;
        }
        return;
    }
    let e = *view.extents();
    let last = rank - 1;
    let inner = e.extent(last);
    if inner == 0 {
        return;
    }
    for d in 1..last {
        if e.extent(d) == 0 {
            return;
        }
    }
    let mut idx = [0usize; MAX_RANK];
    idx[0] = outer_begin;
    loop {
        let mut b = 0;
        while b < inner {
            let len = N.min(inner - b);
            idx[last] = b;
            f(&mut Chunk { view: &mut *view, idx, rank, len });
            b += N;
        }
        idx[last] = 0;
        if !advance_bounded(&e, &mut idx, last, outer_end) {
            return;
        }
    }
}

/// Cursor over up to `N` records consecutive along the innermost array
/// dimension during a bulk traversal ([`View::transform_simd`]).
/// `load`/`store` move whole lane vectors; `get`/`set` reach any record
/// of a rank-1 view scalar-wise.
pub struct Chunk<'v, R, M, S, const N: usize> {
    view: &'v mut View<R, M, S>,
    idx: [usize; MAX_RANK],
    rank: usize,
    /// Active lanes: `N` except for the tail chunk of a row.
    len: usize,
}

impl<'v, R, M, S, const N: usize> Chunk<'v, R, M, S, N>
where
    R: RecordDim,
    M: SimdAccess<R>,
    S: BlobStorage,
{
    /// Array index of the chunk's first record.
    #[inline(always)]
    pub fn index(&self) -> &[usize] {
        &self.idx[..self.rank]
    }

    /// Row-major traversal position of the chunk's first record (for
    /// rank-1 views: its linear index).
    #[inline(always)]
    pub fn base(&self) -> usize {
        if self.rank == 1 {
            return self.idx[0];
        }
        let e = self.view.extents();
        let mut lin = 0usize;
        for d in 0..self.rank {
            lin = lin * e.extent(d) + self.idx[d];
        }
        lin
    }

    /// Active lanes of this chunk: `N`, except for the tail chunk of a
    /// row when `N` does not divide the innermost extent.
    #[inline(always)]
    pub fn lanes(&self) -> usize {
        self.len
    }

    /// Records in the whole view (for whole-view sweeps inside a chunk).
    #[inline(always)]
    pub fn count(&self) -> usize {
        self.view.count()
    }

    /// Load the chunk's lanes of `field`. Tail chunks
    /// ([`lanes`](Chunk::lanes)` < N`) load lane-wise; their unused lanes
    /// are `T::default()`.
    #[inline(always)]
    pub fn load<T: Scalar + SimdElem>(&self, field: usize) -> Simd<T, N> {
        if self.len == N {
            return self.view.load_simd(&self.idx[..self.rank], field);
        }
        let mut out = Simd::<T, N>::default();
        let last = self.rank - 1;
        let mut idx = self.idx;
        for k in 0..self.len {
            idx[last] = self.idx[last] + k;
            out.0[k] = self.view.get(&idx[..self.rank], field);
        }
        out
    }

    /// Store the chunk's lanes of `field`. Tail chunks store lane-wise;
    /// lanes past [`lanes`](Chunk::lanes) are never written.
    #[inline(always)]
    pub fn store<T: Scalar + SimdElem>(&mut self, field: usize, v: Simd<T, N>) {
        if self.len == N {
            self.view.store_simd(&self.idx[..self.rank], field, v);
            return;
        }
        let last = self.rank - 1;
        let mut idx = self.idx;
        for k in 0..self.len {
            idx[last] = self.idx[last] + k;
            self.view.set(&idx[..self.rank], field, v.0[k]);
        }
    }

    /// Scalar load of `field` at any record `i` of a rank-1 view.
    #[inline(always)]
    pub fn get<T: Scalar>(&self, i: usize, field: usize) -> T {
        debug_assert_eq!(self.rank, 1, "Chunk::get addresses records by rank-1 index");
        self.view.get(&[i], field)
    }

    /// Scalar store of `field` at any record `i` of a rank-1 view.
    #[inline(always)]
    pub fn set<T: Scalar>(&mut self, i: usize, field: usize, v: T) {
        debug_assert_eq!(self.rank, 1, "Chunk::set addresses records by rank-1 index");
        self.view.set(&[i], field, v)
    }
}

#[inline(always)]
fn pad_idx(idx: &[usize]) -> [usize; MAX_RANK] {
    debug_assert!(idx.len() <= MAX_RANK);
    let mut a = [0usize; MAX_RANK];
    a[..idx.len()].copy_from_slice(idx);
    a
}

/// Immutable reference to one record of a view (LLAMA `RecordRef`).
#[derive(Clone, Copy)]
pub struct RecordRef<'v, R, M, S> {
    view: &'v View<R, M, S>,
    idx: [usize; MAX_RANK],
    rank: usize,
}

impl<'v, R, M, S> RecordRef<'v, R, M, S>
where
    R: RecordDim,
    M: MemoryAccess<R>,
    S: BlobStorage,
{
    /// The array index of this record.
    #[inline(always)]
    pub fn index(&self) -> &[usize] {
        &self.idx[..self.rank]
    }

    /// Typed scalar load of `field`.
    #[inline(always)]
    pub fn get<T: Scalar>(&self, field: usize) -> T {
        self.view.get(self.index_slice(), field)
    }

    /// Load every field of `sel` widened to `f64` (order of `sel`).
    pub fn get_selection_f64(&self, sel: Selection) -> Vec<f64> {
        sel.indices().map(|f| load_as_f64(self.view, self.index_slice(), f)).collect()
    }

    #[inline(always)]
    fn index_slice(&self) -> &[usize] {
        &self.idx[..self.rank]
    }
}

/// Mutable reference to one record of a view.
pub struct RecordRefMut<'v, R, M, S> {
    view: &'v mut View<R, M, S>,
    idx: [usize; MAX_RANK],
    rank: usize,
}

impl<'v, R, M, S> RecordRefMut<'v, R, M, S>
where
    R: RecordDim,
    M: MemoryAccess<R>,
    S: BlobStorage,
{
    /// The array index of this record.
    #[inline(always)]
    pub fn index(&self) -> &[usize] {
        &self.idx[..self.rank]
    }

    /// Typed scalar load of `field`.
    #[inline(always)]
    pub fn get<T: Scalar>(&self, field: usize) -> T {
        let idx = self.idx;
        self.view.get(&idx[..self.rank], field)
    }

    /// Typed scalar store of `field`.
    #[inline(always)]
    pub fn set<T: Scalar>(&mut self, field: usize, v: T) {
        let idx = self.idx;
        let rank = self.rank;
        self.view.set(&idx[..rank], field, v)
    }
}

/// Load `(idx, field)` as `f64` regardless of the field's scalar type
/// (dispatches on the record metadata; used by copy/report paths).
pub fn load_as_f64<R, M, S>(view: &View<R, M, S>, idx: &[usize], field: usize) -> f64
where
    R: RecordDim,
    M: MemoryAccess<R>,
    S: BlobStorage,
{
    use crate::record::ScalarType as St;
    match R::FIELDS[field].ty {
        St::F32 => view.get::<f32>(idx, field) as f64,
        St::F64 => view.get::<f64>(idx, field),
        St::I8 => view.get::<i8>(idx, field) as f64,
        St::I16 => view.get::<i16>(idx, field) as f64,
        St::I32 => view.get::<i32>(idx, field) as f64,
        St::I64 => view.get::<i64>(idx, field) as f64,
        St::U8 => view.get::<u8>(idx, field) as f64,
        St::U16 => view.get::<u16>(idx, field) as f64,
        St::U32 => view.get::<u32>(idx, field) as f64,
        St::U64 => view.get::<u64>(idx, field) as f64,
        St::Bool => view.get::<bool>(idx, field) as u8 as f64,
        St::F16 => view.get::<crate::record::F16>(idx, field).as_f64(),
        St::Bf16 => view.get::<crate::record::Bf16>(idx, field).as_f64(),
    }
}

/// Store `v` (given as `f64`) into `(idx, field)` with the field's scalar
/// type (dispatches on the record metadata; used by copy/report paths).
pub fn store_from_f64<R, M, S>(view: &mut View<R, M, S>, idx: &[usize], field: usize, v: f64)
where
    R: RecordDim,
    M: MemoryAccess<R>,
    S: BlobStorage,
{
    use crate::record::ScalarType as St;
    match R::FIELDS[field].ty {
        St::F32 => view.set(idx, field, v as f32),
        St::F64 => view.set(idx, field, v),
        St::I8 => view.set(idx, field, v as i8),
        St::I16 => view.set(idx, field, v as i16),
        St::I32 => view.set(idx, field, v as i32),
        St::I64 => view.set(idx, field, v as i64),
        St::U8 => view.set(idx, field, v as u8),
        St::U16 => view.set(idx, field, v as u16),
        St::U32 => view.set(idx, field, v as u32),
        St::U64 => view.set(idx, field, v as u64),
        St::Bool => view.set(idx, field, v != 0.0),
        St::F16 => view.set(idx, field, crate::record::F16::from_f64(v)),
        St::Bf16 => view.set(idx, field, crate::record::Bf16::from_f64(v)),
    }
}

#[cfg(test)]
mod tests {
    use crate::blob::{alloc_view, array_view, HeapAlloc};
    use crate::extents::{Dyn, Fix};
    use crate::mapping::aos::AoS;
    use crate::mapping::soa::SoA;

    crate::record! {
        pub struct P, mod p {
            pos: { x: f64, y: f64 },
            q: i32,
        }
    }

    #[test]
    fn record_ref_access() {
        let mut v = alloc_view(SoA::<P, _>::new((Dyn(8u32),)), &HeapAlloc);
        {
            let mut r = v.at_mut(&[5]);
            r.set(p::pos::x, 1.5f64);
            r.set(p::q, -3i32);
            assert_eq!(r.get::<f64>(p::pos::x), 1.5);
        }
        let r = v.at(&[5]);
        assert_eq!(r.get::<i32>(p::q), -3);
        assert_eq!(r.get_selection_f64(p::pos), vec![1.5, 0.0]);
        assert_eq!(r.index(), &[5]);
    }

    #[test]
    fn zero_overhead_view() {
        use crate::mapping::Mapping;
        // §2: fully static extents + stateless mapping + inline storage
        // => size_of(view) == size of the mapped data exactly.
        type E = (Fix<u32, 16>,);
        type M = AoS<P, E>;
        let m = M::new((Fix::new(),));
        let record_size = 24; // x(8) y(8) q(4) pad(4)
        assert_eq!(m.blob_size(0), 16 * record_size);
        let v = array_view::<P, M, { 16 * 24 }, 1>(m);
        assert_eq!(std::mem::size_of_val(&v), 16 * record_size);
        // trivially copyable (Copy): move a *copy* around
        let v2 = v;
        let _ = v2;
    }

    #[test]
    fn load_store_as_f64() {
        use super::{load_as_f64, store_from_f64};
        let mut v = alloc_view(SoA::<P, _>::new((Dyn(4u32),)), &HeapAlloc);
        store_from_f64(&mut v, &[1], p::q, 42.0);
        assert_eq!(v.get::<i32>(&[1], p::q), 42);
        assert_eq!(load_as_f64(&v, &[1], p::q), 42.0);
    }

    #[test]
    fn for_each_visits_every_record_once_any_rank() {
        let mut v = alloc_view(SoA::<P, _>::new((Dyn(6u32),)), &HeapAlloc);
        v.for_each(|r| {
            let i = r.index()[0];
            r.set(p::q, i as i32 + 1);
        });
        for i in 0..6 {
            assert_eq!(v.get::<i32>(&[i], p::q), i as i32 + 1);
        }

        let mut v2 = alloc_view(AoS::<P, _>::new((Dyn(3u32), Dyn(4u32))), &HeapAlloc);
        let mut seen = Vec::new();
        v2.for_each(|r| {
            seen.push((r.index()[0], r.index()[1]));
            let (i, j) = (r.index()[0], r.index()[1]);
            r.set(p::pos::x, (i * 10 + j) as f64);
        });
        assert_eq!(seen.len(), 12);
        // row-major order, each index exactly once
        assert_eq!(seen[0], (0, 0));
        assert_eq!(seen[1], (0, 1));
        assert_eq!(seen[11], (2, 3));
        assert_eq!(v2.get::<f64>(&[2, 3], p::pos::x), 23.0);
    }

    #[test]
    fn transform_simd_chunks_cover_the_view() {
        let mut v = alloc_view(SoA::<P, _>::new((Dyn(16u32),)), &HeapAlloc);
        for i in 0..16 {
            v.set(&[i], p::pos::x, i as f64);
        }
        let mut bases = Vec::new();
        v.transform_simd::<4>(|c| {
            bases.push(c.base());
            let x: crate::simd::Simd<f64, 4> = c.load(p::pos::x);
            c.store(p::pos::x, x + crate::simd::Simd::splat(100.0));
        });
        assert_eq!(bases, vec![0, 4, 8, 12]);
        for i in 0..16 {
            assert_eq!(v.get::<f64>(&[i], p::pos::x), i as f64 + 100.0);
        }
    }

    #[test]
    fn chunk_exposes_whole_view_scalar_access() {
        let mut v = alloc_view(SoA::<P, _>::new((Dyn(8u32),)), &HeapAlloc);
        for i in 0..8 {
            v.set(&[i], p::pos::x, i as f64);
        }
        // Each chunk sums the whole view (the n-body j-loop shape).
        v.transform_simd::<2>(|c| {
            let mut sum = 0.0;
            for j in 0..c.count() {
                sum += c.get::<f64>(j, p::pos::x);
            }
            c.set(c.base(), p::pos::y, sum);
        });
        for base in [0usize, 2, 4, 6] {
            assert_eq!(v.get::<f64>(&[base], p::pos::y), 28.0);
        }
    }

    #[test]
    fn transform_simd_handles_ragged_extents_with_a_tail_chunk() {
        let mut v = alloc_view(SoA::<P, _>::new((Dyn(10u32),)), &HeapAlloc);
        for i in 0..10 {
            v.set(&[i], p::pos::x, i as f64);
        }
        let mut seen = Vec::new();
        v.transform_simd::<4>(|c| {
            seen.push((c.base(), c.lanes()));
            let x: crate::simd::Simd<f64, 4> = c.load(p::pos::x);
            if c.lanes() < 4 {
                // Inactive lanes load as default.
                assert_eq!(x.0[2], 0.0);
                assert_eq!(x.0[3], 0.0);
            }
            c.store(p::pos::x, x + crate::simd::Simd::splat(100.0));
        });
        assert_eq!(seen, vec![(0, 4), (4, 4), (8, 2)]);
        for i in 0..10 {
            assert_eq!(v.get::<f64>(&[i], p::pos::x), i as f64 + 100.0);
        }
    }

    #[test]
    fn transform_simd_rank2_vectorizes_the_innermost_extent() {
        // 3 rows of 10: per row, chunks at inner 0, 4, 8 (tail of 2).
        let mut v = alloc_view(SoA::<P, _>::new((Dyn(3u32), Dyn(10u32))), &HeapAlloc);
        let mut chunks = Vec::new();
        v.transform_simd::<4>(|c| {
            chunks.push((c.index().to_vec(), c.lanes()));
            let x: crate::simd::Simd<f64, 4> = c.load(p::pos::x);
            c.store(p::pos::x, x + crate::simd::Simd::splat(1.0));
        });
        assert_eq!(chunks.len(), 9);
        assert_eq!(chunks[0], (vec![0, 0], 4));
        assert_eq!(chunks[2], (vec![0, 8], 2));
        assert_eq!(chunks[8], (vec![2, 8], 2));
        // Every record incremented exactly once.
        for i in 0..3 {
            for j in 0..10 {
                assert_eq!(v.get::<f64>(&[i, j], p::pos::x), 1.0);
            }
        }
    }

    #[test]
    #[should_panic(expected = "blob 0")]
    fn from_parts_validates_sizes() {
        use crate::blob::{BlobAlloc, HeapAlloc};
        let m = SoA::<P, _>::new((Dyn(1000u32),));
        let storage = HeapAlloc.alloc(&[8, 8, 8]); // far too small
        let _ = crate::view::View::<P, _, _>::from_parts(m, storage);
    }
}
