//! Multithreaded sharded traversal: the parallel layer of the bulk engine.
//!
//! # Parallel traversal
//!
//! [`ViewShards`] partitions a `&mut View` along the **outermost** array
//! extent into disjoint [`ShardCursor`]s; [`View::par_for_each`] and
//! [`View::par_transform_simd`] fan those cursors out over the
//! persistent worker pool ([`crate::pool`] — parked workers woken per
//! dispatch; `LLAMA_POOL=off` falls back to per-call
//! `std::thread::scope` spawns, and the `*_scoped_with` / `*_on` entry
//! points pick the dispatch target explicitly). This drives the
//! hardware the way the paper's evaluation does (and "LLAMA: The
//! Low-Level Abstraction For Memory Access" benchmarks as the layout ×
//! parallelism matrix): vector units on the innermost dimension, cores
//! across the outer one.
//!
//! The worker count comes from the `LLAMA_THREADS` environment variable
//! (a positive integer), defaulting to `available_parallelism`
//! ([`thread_count`]; the value is parsed once per process and cached).
//!
//! ## Why this is safe — the `shard_bounds` proof
//!
//! Handing several threads mutable access to one view is only sound if
//! their accesses touch disjoint storage bytes. That is a property of the
//! *mapping*, not the view: AoS/SoA/AoSoA/Bytesplit give every record
//! private byte slots (any partition works), the bit-packed mappings share
//! bytes between adjacent values (boundaries must be byte-aligned in the
//! packed stream), `One` aliases every index to the same record (no
//! partition works), and the instrumented wrappers count through atomics
//! (sharing counters is fine, the payload rule is the inner mapping's).
//! Each mapping encodes this in [`Mapping::shard_bounds`] — the sharding
//! analogue of `Mapping::contiguous_run` — and the splitter queries and
//! re-validates every proposed boundary, falling back to the serial
//! engine (`None` from [`ViewShards::split`]) when no safe multi-shard
//! partition exists.
//!
//! Traversal order within a shard is exactly the serial engine's
//! row-major order, and `par_transform_simd` additionally aligns rank-1
//! shard boundaries to the lane count so every worker sees the same chunk
//! pattern as the serial walk. A kernel whose per-record result depends
//! only on the pre-pass state (the n-body update/move kernels) therefore
//! produces **bit-identical** results at any thread count. The shard
//! walkers reuse the serial engine's const-rank index cursors
//! ([`crate::extents::ArrayIndex`]), so the parallel path carries no
//! per-access rank checks either.
//!
//! ## Storage soundness — no worker ever holds an aliasing `&mut`
//!
//! Each [`ShardCursor`] owns its own worker-side view: the mapping is
//! cloned (cheap — mappings are extents plus `Arc`-shared
//! instrumentation counters, so clones keep counting into the same
//! tallies) and the storage is a [`crate::blob::ShardBlobs`] handle of
//! raw [`crate::blob::BlobBytes`] spans extracted once, under the
//! original `&mut View` borrow, at split time. All loads and stores then
//! materialize references over **exactly the bytes of one access**
//! (see [`crate::blob::BlobStorage::bytes`]); the `shard_bounds` proof
//! makes those windows disjoint across workers. No `&mut View`, no
//! whole-blob `&mut [u8]`, and no other overlapping reference is ever
//! created by two workers — the engine is expressible under Stacked/Tree
//! Borrows and is exercised under Miri in CI. The original view stays
//! mutably borrowed (`PhantomData<&'v mut View>`) until every cursor is
//! gone, so no third party can touch the blobs mid-flight. The full
//! model is documented in `docs/PARALLELISM.md`.
//!
//! When the mapping refuses to split (or the view is too small), the
//! parallel entry points traverse serially through a single whole-range
//! cursor — same walkers, same order, bit-identical results.
//!
//! ## Safety split: `par_for_each` is safe, `par_transform_simd` is not
//!
//! `par_for_each` hands the kernel a `RecordRefMut` that can only touch
//! its own record — within a shard by construction — so no safe closure
//! can express a cross-shard access and the entry point is a safe fn.
//! `par_transform_simd` hands out a [`Chunk`], whose [`Chunk::get`] /
//! [`Chunk::set`] reach *any* record of the view (the n-body j-loop
//! depends on this); a closure could therefore race with another shard's
//! stores. The parallel chunk entry points are `unsafe fn` with exactly
//! that contract: bytes stored by one shard must not be concurrently
//! read or written through another shard's whole-view accessors —
//! restrict cross-shard access to fields the pass never stores (the
//! n-body j-loop reads `pos`/`mass` while storing only `vel`).

use std::marker::PhantomData;
use std::sync::OnceLock;

use crate::blob::{blob_spans, BlobBytes, BlobStorage, ShardBlobs};
use crate::extents::Extents;
use crate::mapping::{Mapping, MemoryAccess, SimdAccess};
use crate::pool::WorkerPool;
use crate::record::RecordDim;
use crate::view::{Chunk, RecordRefMut, View};

/// Worker threads for the parallel traversals: `LLAMA_THREADS` (a
/// positive integer) if set and valid, otherwise
/// `std::thread::available_parallelism()` (1 if that is unavailable).
pub fn thread_count() -> usize {
    thread_count_or(std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1))
}

/// Like [`thread_count`], but with an explicit fallback instead of
/// `available_parallelism` when `LLAMA_THREADS` is unset or invalid
/// (used by the benches, which default their parallel rows to 4).
pub fn thread_count_or(default: usize) -> usize {
    cached_thread_env().unwrap_or(default)
}

/// `LLAMA_THREADS`, parsed **once per process** (`OnceLock`): the
/// parallel entry points consult the thread count on every hot
/// dispatch, and a `getenv` + parse per call is measurable noise there.
/// A malformed value logs one warning (instead of silently falling
/// back) and then behaves as unset.
fn cached_thread_env() -> Option<usize> {
    static CACHE: OnceLock<Option<usize>> = OnceLock::new();
    *CACHE.get_or_init(|| {
        let raw = std::env::var("LLAMA_THREADS").ok();
        let parsed = parse_thread_count(raw.as_deref());
        if let (Some(raw), None) = (&raw, parsed) {
            eprintln!(
                "llama: ignoring malformed LLAMA_THREADS={raw:?} (want a positive \
                 integer); using the default thread count"
            );
        }
        parsed
    })
}

/// Parse an `LLAMA_THREADS` value: a positive integer, anything else is
/// rejected (kept separate from the environment so it is testable
/// without process-global `setenv`, which is not thread-safe).
fn parse_thread_count(s: Option<&str>) -> Option<usize> {
    s.and_then(|s| s.trim().parse::<usize>().ok()).filter(|&n| n > 0)
}

/// A partition of a `&mut View` into disjoint shards along the outermost
/// array extent, each accessible through a [`ShardCursor`].
///
/// Construction ([`split`](ViewShards::split)) carries the safety proof:
/// every boundary is validated by the mapping's
/// [`shard_bounds`](Mapping::shard_bounds) hook, and the blob spans the
/// cursors will access are captured under the exclusive `&mut View`
/// borrow, which stays alive (`'v`) until the last cursor is dropped.
/// `None` means "traverse serially" — the mapping refused (e.g.
/// [`crate::mapping::one::One`]), the view is empty, or fewer than two
/// shards fit.
pub struct ViewShards<'v, R, M, S> {
    /// Worker-side mapping template (clones share instrumentation state).
    mapping: M,
    /// Raw spans of the view's blobs, shared by all cursors.
    spans: Vec<BlobBytes>,
    /// Outermost-dimension boundaries: shard `k` spans
    /// `bounds[k]..bounds[k + 1]`; strictly increasing, first 0, last the
    /// outer extent.
    bounds: Vec<usize>,
    _pd: PhantomData<&'v mut View<R, M, S>>,
}

impl<'v, R, M, S> ViewShards<'v, R, M, S>
where
    R: RecordDim,
    M: MemoryAccess<R>,
    S: BlobStorage,
{
    /// Split `view` into (at most) `shards` disjoint shards.
    pub fn split(view: &'v mut View<R, M, S>, shards: usize) -> Option<Self> {
        Self::split_aligned(view, shards, 1)
    }

    /// Like [`split`](ViewShards::split), but keep every boundary a
    /// multiple of `align` outer rows (used by `par_transform_simd` on
    /// rank-1 views to preserve the serial chunk pattern).
    pub fn split_aligned(view: &'v mut View<R, M, S>, shards: usize, align: usize) -> Option<Self> {
        let align = align.max(1);
        let rank = <M::Extents as Extents>::RANK;
        let e = *view.extents();
        let outer = e.extent(0);
        let mut inner = 1usize;
        for d in 1..rank {
            inner *= e.extent(d);
        }
        if shards <= 1 || outer == 0 || inner == 0 {
            return None;
        }
        let want = shards.min(outer.div_ceil(align));
        if want <= 1 {
            return None;
        }
        let mapping = view.mapping().clone();
        let mut bounds = Vec::with_capacity(want + 1);
        bounds.push(0usize);
        for k in 1..want {
            // Even split, rounded to the alignment, then clamped down to
            // the nearest boundary the mapping proves safe (0 always is).
            // The parallel copy's `copy::run_copy_bounds` mirrors this
            // fixpoint in linear-record units; keep the two in sync.
            let mut o = (outer as u128 * k as u128 / want as u128) as usize / align * align;
            let b = loop {
                if o == 0 {
                    break 0;
                }
                let lin = o * inner;
                // SAFETY: `shard_bounds` has no caller preconditions; its
                // `unsafe` marks the implementor's obligation, which the
                // splitter consumes as the disjointness proof.
                let safe = unsafe { mapping.shard_bounds(lin) }?;
                if safe == lin {
                    break o;
                }
                o = safe / inner / align * align;
            };
            if b > *bounds.last().unwrap() {
                bounds.push(b);
            }
        }
        bounds.push(outer);
        if bounds.len() < 3 {
            return None;
        }
        // Capture the raw blob spans last: after this, the view is not
        // touched again until every cursor (and the `'v` borrow) is gone.
        let spans = blob_spans(view.storage_mut());
        Some(ViewShards { mapping, spans, bounds, _pd: PhantomData })
    }

    /// Number of shards.
    pub fn len(&self) -> usize {
        self.bounds.len() - 1
    }

    /// A split always produces at least two shards.
    pub fn is_empty(&self) -> bool {
        false
    }

    /// The outermost-dimension shard boundaries (see [`ViewShards`]).
    pub fn bounds(&self) -> &[usize] {
        &self.bounds
    }

    /// Consume the splitter into one cursor per shard. Each cursor owns a
    /// worker-side view (cloned mapping + raw-span storage) restricted to
    /// its record range; the cursors access disjoint bytes and may be
    /// moved to different threads.
    pub fn cursors(self) -> Vec<ShardCursor<'v, R, M, S>> {
        let ViewShards { mapping, spans, bounds, .. } = self;
        (0..bounds.len() - 1)
            .map(|k| ShardCursor {
                // SAFETY (`ShardBlobs::new`): (1) the spans' buffers stay
                // live and unreachable elsewhere for `'v` — the source
                // view is mutably borrowed for as long as any cursor
                // exists; (2) a cursor's own traversal touches only its
                // record range's bytes, disjoint across cursors by the
                // `shard_bounds`-validated boundaries; whole-view chunk
                // accessors forward the obligation to
                // `par_transform_simd`'s contract.
                view: View::from_parts(mapping.clone(), unsafe {
                    ShardBlobs::new(spans.clone())
                }),
                begin: bounds[k],
                end: bounds[k + 1],
                _pd: PhantomData,
            })
            .collect()
    }

    /// Run `f` once per shard — shard 0 on the calling thread, the rest
    /// on the crate-global worker pool (or per-call scoped threads when
    /// `LLAMA_POOL=off`; see [`crate::pool::run_jobs`]). Returns when
    /// every shard is done.
    pub fn dispatch<F>(self, f: F)
    where
        F: Fn(ShardCursor<'v, R, M, S>) + Sync,
        S: Send + Sync,
    {
        self.dispatch_to(Target::Policy, f);
    }

    /// [`dispatch`](ViewShards::dispatch) pinned to the pre-pool
    /// per-call scoped-spawn path. Semantically identical; kept for
    /// `LLAMA_POOL=off` parity tests and as the baseline the `pool`
    /// bench measures amortized dispatch against.
    pub fn dispatch_scoped<F>(self, f: F)
    where
        F: Fn(ShardCursor<'v, R, M, S>) + Sync,
        S: Send + Sync,
    {
        self.dispatch_to(Target::Scoped, f);
    }

    /// [`dispatch`](ViewShards::dispatch) on an explicit pool (the
    /// coordinator's leased-budget kernels and the benches use this).
    pub fn dispatch_on<F>(self, pool: &WorkerPool, f: F)
    where
        F: Fn(ShardCursor<'v, R, M, S>) + Sync,
        S: Send + Sync,
    {
        self.dispatch_to(Target::On(pool), f);
    }

    /// The one dispatch body behind the three public variants: build
    /// one job per shard (job 0 always executes on the submitting
    /// thread) and hand the batch to the target.
    fn dispatch_to<F>(self, target: Target<'_>, f: F)
    where
        F: Fn(ShardCursor<'v, R, M, S>) + Sync,
        S: Send + Sync,
    {
        let f = &f;
        let jobs: Vec<_> = self.cursors().into_iter().map(|cur| move || f(cur)).collect();
        match target {
            Target::Policy => crate::pool::run_jobs(jobs),
            Target::Scoped => crate::pool::run_scoped_spawn(jobs),
            Target::On(pool) => pool.run_scoped(jobs),
        }
    }
}

/// Where a parallel entry point sends its shard jobs — the single
/// point of divergence between the `_with` / `_scoped_with` / `_on`
/// variants (everything else — splitting, alignment, serial fallback —
/// is shared).
#[derive(Clone, Copy)]
enum Target<'p> {
    /// The policy default: global pool, or scoped spawn under
    /// `LLAMA_POOL=off`/Miri ([`crate::pool::run_jobs`]).
    Policy,
    /// Per-call scoped spawn, unconditionally.
    Scoped,
    /// An explicit pool.
    On(&'p WorkerPool),
}

/// A single whole-range cursor over `view` — the serial fallback of the
/// parallel entry points (mapping refused to split, or the view is too
/// small). Same walkers, same order, one handle: trivially exclusive.
fn whole_cursor<'v, R, M, S>(view: &'v mut View<R, M, S>) -> ShardCursor<'v, R, M, S>
where
    R: RecordDim,
    M: MemoryAccess<R>,
    S: BlobStorage,
{
    let mapping = view.mapping().clone();
    let outer = view.extents().extent(0);
    let spans = blob_spans(view.storage_mut());
    // SAFETY (`ShardBlobs::new`): exactly one handle over the spans
    // exists and the source view stays mutably borrowed for `'v`, so all
    // access is exclusive — both contract clauses hold trivially.
    let storage = unsafe { ShardBlobs::new(spans) };
    ShardCursor {
        view: View::from_parts(mapping, storage),
        begin: 0,
        end: outer,
        _pd: PhantomData,
    }
}

/// Mutable access to the records of one shard: outermost array indices
/// `[begin, end)` of a shared view, through an owned worker-side view
/// over the shared blobs (see [`crate::shard`] module docs). Created by
/// [`ViewShards`]; sendable to a worker thread.
pub struct ShardCursor<'v, R, M, S> {
    view: View<R, M, ShardBlobs>,
    begin: usize,
    end: usize,
    /// Keeps the source view mutably borrowed while any cursor lives —
    /// the liveness half of the `ShardBlobs::new` contract.
    _pd: PhantomData<&'v mut View<R, M, S>>,
}

impl<'v, R, M, S> ShardCursor<'v, R, M, S>
where
    R: RecordDim,
    M: MemoryAccess<R>,
    S: BlobStorage,
{
    /// The shard's `[begin, end)` range of the outermost array dimension.
    pub fn outer_range(&self) -> (usize, usize) {
        (self.begin, self.end)
    }

    /// Visit every record of the shard in row-major order — the shard's
    /// slice of [`View::for_each`].
    pub fn for_each(&mut self, mut f: impl FnMut(&mut RecordRefMut<'_, R, M, ShardBlobs>)) {
        crate::view::for_each_outer(&mut self.view, self.begin, self.end, &mut f);
    }

    /// Chunk-walk the shard — the shard's slice of
    /// [`View::transform_simd`], with identical chunking and tail
    /// handling.
    ///
    /// # Safety
    ///
    /// [`Chunk::get`]/[`Chunk::set`] reach any record of the view. When
    /// other cursors of the same split run concurrently, `f` must not
    /// read or write bytes that another shard's traversal stores (see
    /// the [module docs](crate::shard)); chunk-local `load`/`store` and
    /// cross-shard reads of fields no shard writes are always fine.
    pub unsafe fn transform_simd<const N: usize, F>(&mut self, mut f: F)
    where
        F: FnMut(&mut Chunk<'_, R, M, ShardBlobs, N>),
        M: SimdAccess<R>,
    {
        assert!(N > 0, "lane count must be positive");
        crate::view::walk_chunks(&mut self.view, self.begin, self.end, &mut f);
    }
}

impl<R, M, S> View<R, M, S>
where
    R: RecordDim,
    M: MemoryAccess<R>,
    S: BlobStorage + Send + Sync,
{
    /// [`for_each`](View::for_each) fanned out over [`thread_count`]
    /// workers. Falls back to the serial traversal when the mapping
    /// cannot prove sharding safe (see [`crate::shard`]). Per-record
    /// kernels observe the same pre-pass state as the serial engine, so
    /// results are bit-identical.
    ///
    /// The kernel's record cursor is backed by the worker-side storage
    /// ([`crate::blob::ShardBlobs`]) and can only touch its own record:
    /// the entry point is a safe fn.
    ///
    /// ```
    /// use llama::prelude::*;
    /// llama::record! { pub struct P, mod p { x: f64, q: i32 } }
    /// let mut v = alloc_view(SoA::<P, _>::new((Dyn(100u32),)), &HeapAlloc);
    /// v.par_for_each(|r| {
    ///     let i = r.index()[0];
    ///     r.set_field(p::q, i as i32 * 3);
    /// });
    /// assert_eq!(v.get_t([42], p::q), 126);
    /// ```
    pub fn par_for_each<F>(&mut self, f: F)
    where
        F: Fn(&mut RecordRefMut<'_, R, M, ShardBlobs>) + Sync,
    {
        self.par_for_each_with(thread_count(), f)
    }

    /// [`par_for_each`](View::par_for_each) with an explicit worker count.
    pub fn par_for_each_with<F>(&mut self, threads: usize, f: F)
    where
        F: Fn(&mut RecordRefMut<'_, R, M, ShardBlobs>) + Sync,
    {
        self.par_for_each_to(Target::Policy, threads, f);
    }

    /// [`par_for_each_with`](View::par_for_each_with) forced onto the
    /// per-call scoped-spawn dispatch (no worker pool) — the baseline
    /// the `pool` bench compares amortized dispatch against.
    pub fn par_for_each_scoped_with<F>(&mut self, threads: usize, f: F)
    where
        F: Fn(&mut RecordRefMut<'_, R, M, ShardBlobs>) + Sync,
    {
        self.par_for_each_to(Target::Scoped, threads, f);
    }

    /// [`par_for_each_with`](View::par_for_each_with) dispatched on an
    /// explicit [`WorkerPool`] (e.g. one sized by a coordinator thread
    /// lease) instead of the crate-global pool.
    pub fn par_for_each_on<F>(&mut self, pool: &WorkerPool, threads: usize, f: F)
    where
        F: Fn(&mut RecordRefMut<'_, R, M, ShardBlobs>) + Sync,
    {
        self.par_for_each_to(Target::On(pool), threads, f);
    }

    /// The one split-or-fallback body behind the three variants above.
    fn par_for_each_to<F>(&mut self, target: Target<'_>, threads: usize, f: F)
    where
        F: Fn(&mut RecordRefMut<'_, R, M, ShardBlobs>) + Sync,
    {
        if let Some(shards) = ViewShards::split(self, threads) {
            shards.dispatch_to(target, |mut cur| cur.for_each(&f));
            return;
        }
        whole_cursor(self).for_each(f);
    }
}

impl<R, M, S> View<R, M, S>
where
    R: RecordDim,
    M: SimdAccess<R>,
    S: BlobStorage + Send + Sync,
{
    /// [`transform_simd`](View::transform_simd) fanned out over
    /// [`thread_count`] workers: SIMD along the innermost dimension,
    /// threads across the outermost — the full layout × parallelism
    /// matrix from one kernel. Falls back to the serial traversal when
    /// the mapping cannot prove sharding safe. Rank-1 shard boundaries
    /// are aligned to `N`, so the chunk pattern (including the tail)
    /// matches the serial walk exactly.
    ///
    /// # Safety
    ///
    /// `f` runs concurrently on disjoint shards but [`Chunk::get`] /
    /// [`Chunk::set`] reach any record of the view: the closure must not
    /// read or write bytes that the pass stores in *another* shard's
    /// chunks (see [`crate::shard`]). Kernels that only use the chunk's
    /// own `load`/`store` plus cross-shard reads of fields the pass
    /// never stores (the n-body pattern) satisfy this.
    pub unsafe fn par_transform_simd<const N: usize, F>(&mut self, f: F)
    where
        F: Fn(&mut Chunk<'_, R, M, ShardBlobs, N>) + Sync,
    {
        // SAFETY: forwarded contract.
        unsafe { self.par_transform_simd_with::<N, F>(thread_count(), f) }
    }

    /// [`par_transform_simd`](View::par_transform_simd) with an explicit
    /// worker count.
    ///
    /// # Safety
    ///
    /// As for [`par_transform_simd`](View::par_transform_simd).
    pub unsafe fn par_transform_simd_with<const N: usize, F>(&mut self, threads: usize, f: F)
    where
        F: Fn(&mut Chunk<'_, R, M, ShardBlobs, N>) + Sync,
    {
        // SAFETY: forwarded contract.
        unsafe { self.par_transform_simd_to::<N, F>(Target::Policy, threads, f) }
    }

    /// [`par_transform_simd_with`](View::par_transform_simd_with) forced
    /// onto the per-call scoped-spawn dispatch (no worker pool) — the
    /// baseline the benches compare amortized dispatch against.
    ///
    /// # Safety
    ///
    /// As for [`par_transform_simd`](View::par_transform_simd).
    pub unsafe fn par_transform_simd_scoped_with<const N: usize, F>(&mut self, threads: usize, f: F)
    where
        F: Fn(&mut Chunk<'_, R, M, ShardBlobs, N>) + Sync,
    {
        // SAFETY: forwarded contract.
        unsafe { self.par_transform_simd_to::<N, F>(Target::Scoped, threads, f) }
    }

    /// [`par_transform_simd_with`](View::par_transform_simd_with)
    /// dispatched on an explicit [`WorkerPool`].
    ///
    /// # Safety
    ///
    /// As for [`par_transform_simd`](View::par_transform_simd).
    pub unsafe fn par_transform_simd_on<const N: usize, F>(
        &mut self,
        pool: &WorkerPool,
        threads: usize,
        f: F,
    ) where
        F: Fn(&mut Chunk<'_, R, M, ShardBlobs, N>) + Sync,
    {
        // SAFETY: forwarded contract.
        unsafe { self.par_transform_simd_to::<N, F>(Target::On(pool), threads, f) }
    }

    /// The one split-align-or-fallback body behind the three variants
    /// above.
    ///
    /// # Safety
    ///
    /// As for [`par_transform_simd`](View::par_transform_simd).
    unsafe fn par_transform_simd_to<const N: usize, F>(
        &mut self,
        target: Target<'_>,
        threads: usize,
        f: F,
    ) where
        F: Fn(&mut Chunk<'_, R, M, ShardBlobs, N>) + Sync,
    {
        assert!(N > 0, "lane count must be positive");
        let align = if <M::Extents as Extents>::RANK == 1 { N } else { 1 };
        if let Some(shards) = ViewShards::split_aligned(self, threads, align) {
            // SAFETY: forwarded contract — the shards themselves are
            // disjoint by the `shard_bounds` proof.
            shards.dispatch_to(target, |mut cur| unsafe { cur.transform_simd::<N, _>(&f) });
            return;
        }
        // SAFETY: single whole-range cursor, no concurrency — every
        // access the closure can express goes through this one handle.
        unsafe { whole_cursor(self).transform_simd::<N, _>(f) };
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::blob::{alloc_view, HeapAlloc};
    use crate::extents::Dyn;
    use crate::mapping::bitpack_int::BitpackIntSoA;
    use crate::mapping::field_access_count::FieldAccessCount;
    use crate::mapping::one::One;
    use crate::mapping::soa::SoA;

    crate::record! {
        pub struct P, mod p {
            x: f64,
            q: i32,
        }
    }

    crate::record! {
        pub struct H, mod h {
            adc: u32,
        }
    }

    #[test]
    fn split_partitions_evenly() {
        let mut v = alloc_view(SoA::<P, _>::new((Dyn(10u32),)), &HeapAlloc);
        let shards = ViewShards::split(&mut v, 4).unwrap();
        assert_eq!(shards.len(), 4);
        assert_eq!(shards.bounds(), &[0, 2, 5, 7, 10]);
        let cursors = shards.cursors();
        assert_eq!(cursors[0].outer_range(), (0, 2));
        assert_eq!(cursors[3].outer_range(), (7, 10));
    }

    #[test]
    fn split_clamps_shard_count_and_refuses_trivial_splits() {
        let mut v = alloc_view(SoA::<P, _>::new((Dyn(3u32),)), &HeapAlloc);
        assert_eq!(ViewShards::split(&mut v, 8).map(|s| s.len()), Some(3));
        assert!(ViewShards::split(&mut v, 1).is_none());
        let mut empty = alloc_view(SoA::<P, _>::new((Dyn(0u32),)), &HeapAlloc);
        assert!(ViewShards::split(&mut empty, 4).is_none());
    }

    #[test]
    fn split_respects_bitpack_byte_alignment() {
        // 12-bit values: boundaries must be even (2 values = 3 bytes).
        let mut v = alloc_view(BitpackIntSoA::<H, _, 12>::new((Dyn(10u32),)), &HeapAlloc);
        let shards = ViewShards::split(&mut v, 4).unwrap();
        assert_eq!(shards.bounds(), &[0, 2, 4, 6, 10]);
        // 3-bit values: boundaries must be multiples of 8. n=24 shards at
        // the byte-aligned points below the even split...
        let mut v3 = alloc_view(BitpackIntSoA::<H, _, 3>::new((Dyn(24u32),)), &HeapAlloc);
        let shards = ViewShards::split(&mut v3, 4).unwrap();
        assert_eq!(shards.bounds(), &[0, 8, 16, 24]);
        // ...while n=10 admits no aligned boundary at all: serial fallback.
        let mut tiny = alloc_view(BitpackIntSoA::<H, _, 3>::new((Dyn(10u32),)), &HeapAlloc);
        assert!(ViewShards::split(&mut tiny, 4).is_none());
    }

    #[test]
    fn one_mapping_refuses_to_shard() {
        let mut v = alloc_view(One::<P, _>::new((Dyn(64u32),)), &HeapAlloc);
        assert!(ViewShards::split(&mut v, 4).is_none());
        // ...but the parallel entry points still work via the fallback.
        v.par_for_each_with(4, |r| r.set(p::q, 7i32));
        assert_eq!(v.get::<i32, _>(&[63], p::q), 7);
    }

    #[test]
    fn cursor_writes_land_in_the_source_view() {
        // The worker-side views write through raw spans into the same
        // blobs the source view owns.
        let mut v = alloc_view(SoA::<P, _>::new((Dyn(9u32),)), &HeapAlloc);
        {
            let shards = ViewShards::split(&mut v, 3).unwrap();
            for mut cur in shards.cursors() {
                let (lo, hi) = cur.outer_range();
                cur.for_each(|r| {
                    let i = r.index()[0];
                    assert!(i >= lo && i < hi);
                    r.set(p::q, i as i32 * 11);
                });
            }
        }
        for i in 0..9 {
            assert_eq!(v.get::<i32, _>(&[i], p::q), i as i32 * 11);
        }
    }

    #[test]
    fn cloned_mappings_share_instrumentation_counters() {
        // Worker-side views clone the mapping; the counters are behind an
        // `Arc`, so parallel counts land in the view's own tallies.
        let fac = FieldAccessCount::new(SoA::<P, _>::new((Dyn(50u32),)));
        let mut v = alloc_view(fac, &HeapAlloc);
        v.par_for_each_with(4, |r| {
            let x = r.field(p::x);
            r.set_field(p::x, x + 1.0);
        });
        let (reads, writes) = v.mapping().field_counts(p::x);
        assert_eq!((reads, writes), (50, 50));
    }

    #[test]
    fn par_for_each_visits_every_record_once() {
        let mut v = alloc_view(SoA::<P, _>::new((Dyn(103u32),)), &HeapAlloc);
        v.par_for_each_with(4, |r| {
            let i = r.index()[0];
            r.set(p::q, i as i32 + 1);
        });
        for i in 0..103 {
            assert_eq!(v.get::<i32, _>(&[i], p::q), i as i32 + 1);
        }
    }

    #[test]
    fn par_transform_simd_matches_serial() {
        let mut serial = alloc_view(SoA::<P, _>::new((Dyn(103u32),)), &HeapAlloc);
        let mut par = alloc_view(SoA::<P, _>::new((Dyn(103u32),)), &HeapAlloc);
        for i in 0..103 {
            serial.set(&[i], p::x, i as f64 * 0.25);
            par.set(&[i], p::x, i as f64 * 0.25);
        }
        serial.transform_simd::<4>(|c| {
            let x: crate::simd::Simd<f64, 4> = c.load(p::x);
            c.store(p::x, x * x + x);
        });
        // SAFETY: the kernel touches only its own chunk's records.
        unsafe {
            par.par_transform_simd_with::<4, _>(3, |c| {
                let x: crate::simd::Simd<f64, 4> = c.load(p::x);
                c.store(p::x, x * x + x);
            });
        }
        for i in 0..103 {
            assert_eq!(
                serial.get::<f64, _>(&[i], p::x).to_bits(),
                par.get::<f64, _>(&[i], p::x).to_bits()
            );
        }
    }

    #[test]
    fn scoped_explicit_pool_and_policy_dispatch_agree() {
        // The three dispatch targets (policy = global pool by default,
        // forced scoped spawn, explicit pool) are pure plumbing: same
        // shards, same walkers, same values.
        let pool = crate::pool::WorkerPool::with_pinning(3, false);
        let mut a = alloc_view(SoA::<P, _>::new((Dyn(41u32),)), &HeapAlloc);
        let mut b = alloc_view(SoA::<P, _>::new((Dyn(41u32),)), &HeapAlloc);
        let mut c = alloc_view(SoA::<P, _>::new((Dyn(41u32),)), &HeapAlloc);
        a.par_for_each_with(4, |r| {
            let i = r.index()[0];
            r.set(p::q, i as i32 * 5);
        });
        b.par_for_each_scoped_with(4, |r| {
            let i = r.index()[0];
            r.set(p::q, i as i32 * 5);
        });
        c.par_for_each_on(&pool, 4, |r| {
            let i = r.index()[0];
            r.set(p::q, i as i32 * 5);
        });
        // SAFETY: the kernel touches only its own chunk's records.
        unsafe {
            c.par_transform_simd_on::<4, _>(&pool, 3, |ch| {
                let q: crate::simd::Simd<i32, 4> = ch.load(p::q);
                ch.store(p::q, q + q);
            });
        }
        for i in 0..41 {
            let want = i as i32 * 5;
            assert_eq!(a.get::<i32, _>(&[i], p::q), want);
            assert_eq!(b.get::<i32, _>(&[i], p::q), want);
            assert_eq!(c.get::<i32, _>(&[i], p::q), want * 2);
        }
    }

    #[test]
    fn thread_count_parsing() {
        // The env-value parser is tested directly — mutating the process
        // environment from a multithreaded test harness is not safe.
        assert_eq!(parse_thread_count(Some("3")), Some(3));
        assert_eq!(parse_thread_count(Some(" 8 ")), Some(8));
        assert_eq!(parse_thread_count(Some("0")), None);
        assert_eq!(parse_thread_count(Some("not-a-number")), None);
        assert_eq!(parse_thread_count(None), None);
        assert!(thread_count() >= 1);
        assert!(thread_count_or(4) >= 1);
    }
}
