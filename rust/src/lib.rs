//! # llama-rs — Low-Level Abstraction of Memory Access, in Rust
//!
//! Reproduction of *Updates on the Low-Level Abstraction of Memory Access*
//! (Gruber, 2023) — the LLAMA C++ library — as a Rust library with a
//! JAX/Pallas AOT compute path executed through PJRT.
//!
//! LLAMA separates the *algorithmic view* of data (multidimensional arrays
//! of nested, structured records) from its *mapping* to memory. Programs
//! interact with a [`view::View`] spanning a record dimension
//! ([`record::RecordDim`]) and array dimensions ([`extents`]); the view's
//! [`mapping`] decides where each scalar lives (AoS, SoA, AoSoA, bit-packed,
//! byte-split, type-changed, instrumented, ...) and can be exchanged without
//! touching the algorithm.
//!
//! ```
//! use llama::prelude::*;
//!
//! llama::record! {
//!     /// A 3D particle: nested position/velocity records plus a mass.
//!     pub struct Particle, mod particle {
//!         pos: { x: f64, y: f64, z: f64 },
//!         vel: { x: f64, y: f64, z: f64 },
//!         mass: f32,
//!     }
//! }
//!
//! // One array dimension with a runtime extent, mapped struct-of-arrays.
//! let extents = (Dyn(128u32),);
//! let mapping = SoA::<Particle, _>::new(extents);
//! let mut view = alloc_view(mapping, &HeapAlloc);
//!
//! // Typed access: the tag carries the field's scalar type and record,
//! // the index is a const-rank array — wrong type, wrong record, or
//! // wrong rank would not compile.
//! view.set_t([3], particle::mass, 1.5f32);
//! let m = view.get_t([3], particle::mass); // m: f32, inferred
//! assert_eq!(m, 1.5);
//!
//! // Record navigation: typed field and sub-record projection.
//! let r = view.at_t([3]);
//! assert_eq!(r.field(particle::mass), 1.5);
//! assert_eq!(r.sub(particle::pos).read_f64(), vec![0.0, 0.0, 0.0]);
//!
//! // Bulk traversal engine: visit every record scalar-wise...
//! view.for_each(|r| {
//!     let i = r.index()[0] as f32;
//!     r.set_field(particle::mass, i);
//! });
//! assert_eq!(view.get_t([7], particle::mass), 7.0);
//!
//! // ...or stream SIMD chunks; the mapping picks the fastest path
//! // (SoA here: contiguous vector moves — swap in AoS/AoSoA and this
//! // code does not change).
//! view.transform_simd::<4>(|c| {
//!     let m = c.load_t(particle::mass); // Simd<f32, 4>, inferred
//!     c.store_t(particle::mass, m + m);
//! });
//! assert_eq!(view.get_t([7], particle::mass), 14.0);
//!
//! // ...and fan either traversal out over threads (`LLAMA_THREADS`, or
//! // all cores — parked workers of the persistent crate pool, not
//! // per-call spawns): the mapping's `shard_bounds` proof splits the
//! // view into disjoint shards, falling back to the serial engine when
//! // it can't.
//! view.par_for_each(|r| {
//!     let m = r.field(particle::mass);
//!     r.set_field(particle::mass, m + 1.0);
//! });
//! // The chunk variant is `unsafe`: `Chunk::get_t`/`set_t` can reach
//! // other shards' records, so the kernel must not touch bytes another
//! // shard stores (this one only uses its own chunk — see `shard`).
//! // SAFETY: the kernel touches only its own chunk's records.
//! unsafe {
//!     view.par_transform_simd::<4, _>(|c| {
//!         let m = c.load_t(particle::mass);
//!         c.store_t(particle::mass, m - Simd::splat(1.0));
//!     });
//! }
//! assert_eq!(view.get_t([7], particle::mass), 14.0);
//! ```
//!
//! # Access API
//!
//! The access layer has two parallel method families (see [`view`] for
//! the full list):
//!
//! - **Typed tags (preferred).** [`crate::record!`] emits a zero-sized
//!   [`record::FieldTag`] value per leaf (`particle::mass`) and a
//!   [`record::GroupTag`] per sub-record (`particle::pos`, `::all`). The
//!   `*_t` methods and the [`view::RecordRef`] navigation infer the
//!   scalar type from the tag, tie the tag to its record dimension, and
//!   take const-rank [`extents::ArrayIndex`] indices (`[usize; RANK]`) —
//!   so wrong-type, wrong-record, and wrong-rank accesses are *compile
//!   errors* and the monomorphized access path carries no slice-rank
//!   checks. Tags fold to constant field indices: the typed path is
//!   zero-cost (property-tested bit-identical to the legacy path, and
//!   benchmarked against it in `fig3_nbody`).
//! - **Legacy indices (compatibility).** The original `usize`-index /
//!   `&[usize]` methods remain, their field parameter generic over
//!   [`record::FieldIndex`] (raw indices or tags; explicitly-typed call
//!   sites write `get::<f32, _>(...)`). Type and rank agreement are only
//!   debug-asserted on the scalar path (`at`/`at_mut` assert the rank at
//!   runtime). Metadata-driven code
//!   ([`view::load_as_f64`], [`copy`]) legitimately lives here; for
//!   selection-wide reads use the typed sub-record projection
//!   [`view::RecordRef::sub`] (the deprecated `get_selection_f64` escape
//!   hatch was removed in 0.2).
//!
//! The crate layers (paper section → module):
//! - §2 compile-time array extents → [`extents`]
//! - §3 new memory mappings → [`mapping`]
//! - §4 access instrumentation → [`mapping::field_access_count`], [`mapping::heatmap`]
//! - §5 explicit SIMD → [`simd`], and the layout-aware bulk-traversal
//!   engine → [`view::View::for_each`], [`view::View::transform_simd`],
//!   [`mapping::Mapping::contiguous_run`] (which also powers the
//!   run-based [`copy`] strategy, serial and parallel), with the
//!   multithreaded sharded layer → [`shard`]
//!   ([`mapping::Mapping::shard_bounds`], `View::par_for_each`,
//!   `View::par_transform_simd`) built on the interior-mutable
//!   byte-exact storage path → [`blob::BlobBytes`], [`blob::ShardBlobs`],
//!   dispatched on the persistent worker pool → [`pool`]
//!   ([`pool::WorkerPool`]; `LLAMA_POOL`) with NUMA-aware placement →
//!   [`numa`] (`LLAMA_NUMA`, [`blob::FirstTouchAlloc`])
//! - §4 closing the loop: access-pattern-driven adaptive relayout →
//!   [`tune`] ([`tune::AccessTrace`] recorded via the instrumentation
//!   `snapshot()` APIs, the deterministic cost model and
//!   [`tune::Planner`], live double-buffered migration through the
//!   parallel copy engine → [`tune::migrate_live`], and the
//!   coordinator's per-job-key adaptation via
//!   [`coordinator::Config::autotune`])
//! - evaluation workload (Fig. 3) → [`nbody`], `benches/fig3_nbody.rs`,
//!   measured in wall clock *and* hardware counters → [`counters`]
//!   (`perf_event_open`; `LLAMA_COUNTERS`) via [`bench`], with
//!   false-sharing hardening → [`util::CachePadded`]
//! - AOT/PJRT execution of the Pallas/JAX lowering → [`runtime`], [`coordinator`]
//!   (PJRT behind the `pjrt` cargo feature), with bounded, quota-aware job
//!   ingestion → [`coordinator::Ingest`], layout-aware view transport
//!   across processes → [`transport`] (checksummed v2 frames;
//!   `examples/distributed_nbody.rs`), a supervised TCP front-end with
//!   connection deadlines, typed error/reply frames, and graceful drain
//!   → [`serve`] ([`serve::Server`] / [`serve::Client`];
//!   `llama-lab serve --listen`), and deterministic fault injection
//!   for chaos-testing the whole serving path → [`fault`]
//!   (`LLAMA_FAULT_SEED`, [`coordinator::RetryPolicy`])
//!
//! # Reference documentation
//!
//! - `docs/MAPPINGS.md` — the mapping reference manual: layout diagram,
//!   blob inventory, `contiguous_run` / `shard_bounds` / SIMD support
//!   matrix, and selection guidance for all 13 mappings.
//! - `docs/PARALLELISM.md` — the parallel storage soundness model (how
//!   shard workers share one view's blobs without overlapping `&mut`,
//!   checked under Miri in CI), the `par_for_each` /
//!   `par_transform_simd` / `copy_view_par` safety contracts, and the
//!   `LLAMA_THREADS` policy.
//! - `docs/SERVING.md` — the serving tier: the [`transport`] wire format
//!   specification, the coordinator's admission control / backpressure
//!   semantics ([`coordinator::Admission`]), the per-client quota
//!   model, and the failure model (frame CRC coverage, retry/backoff,
//!   chaos-test matrix).
//! - `docs/TUNING.md` — the autotuner: the trace JSON schema, every
//!   cost-model term and its default weight, candidate gating rules, and
//!   the migration safety argument.

pub mod bench;
pub mod blob;
pub mod compress;
pub mod coordinator;
pub mod copy;
pub mod counters;
pub mod extents;
pub mod fault;
pub mod mapping;
pub mod nbody;
pub mod numa;
pub mod pool;
pub mod record;
pub mod runtime;
pub mod serve;
pub mod shard;
pub mod simd;
pub mod testing;
pub mod transport;
pub mod tune;
pub mod util;
pub mod view;

/// Convenience re-exports covering the common 90% of the API.
pub mod prelude {
    pub use crate::blob::{
        alloc_view, AlignedAlloc, ArrayStorage, BlobAlloc, BlobBytes, BlobStorage,
        FirstTouchAlloc, HeapAlloc, ShardBlobs,
    };
    pub use crate::extents::{
        ArrayIndex, ColMajor, Dyn, Extent, Extents, Fix, Linearizer, Morton, RankIndex, RowMajor,
    };
    pub use crate::mapping::aos::{AoS, FieldOrder, Packed};
    pub use crate::mapping::aosoa::AoSoA;
    pub use crate::mapping::bitpack_float::BitpackFloatSoA;
    pub use crate::mapping::bitpack_int::{BitpackIntSoA, BitpackIntSoADyn};
    pub use crate::mapping::bytesplit::Bytesplit;
    pub use crate::mapping::changetype::ChangeType;
    pub use crate::mapping::field_access_count::FieldAccessCount;
    pub use crate::mapping::heatmap::Heatmap;
    pub use crate::mapping::null::NullMapping;
    pub use crate::mapping::one::One;
    pub use crate::mapping::soa::{MultiBlob, SingleBlob, SoA};
    pub use crate::mapping::split::Split;
    pub use crate::mapping::{
        FieldMask, FieldRun, Mapping, MemoryAccess, PhysicalMapping, SimdAccess, StaticMask,
    };
    pub use crate::record::{
        Bf16, Field, FieldIndex, FieldTag, GroupTag, Leaf, RecordDim, Scalar, ScalarType, Sel,
        Selection, F16,
    };
    pub use crate::counters::{CounterError, CounterGroup, Counters};
    pub use crate::numa::{NumaPolicy, Topology};
    pub use crate::pool::{Lease, WorkerPool};
    pub use crate::util::CachePadded;
    pub use crate::shard::{thread_count, thread_count_or, ShardCursor, ViewShards};
    pub use crate::simd::{Simd, SimdElem};
    pub use crate::fault::{FaultConfig, FaultPlan, FaultyStream, JobFault};
    pub use crate::serve::{
        Client, ClientConfig, ClientError, DrainOutcome, RemoteResult, ServeConfig, ServeMetrics,
        ServeReport, Server,
    };
    pub use crate::transport::{
        crc32, decode_adopt, decode_into, decode_into_par, encode, encode_par, wire_error_in,
        Crc32, CtrlFrame, TimeoutPhase, WireError, WireMapping, WireMsg, CTRL_MAGIC, MAX_PAYLOAD,
        WIRE_VERSION,
    };
    pub use crate::tune::{
        migrate_live, AccessTrace, Candidate, CostParams, LayoutPlan, MigrationReport, Planner,
    };
    pub use crate::view::{
        Chunk, FieldRefMut, IndexOf, RecordRef, RecordRefMut, SubRecordRef, View,
    };
}
