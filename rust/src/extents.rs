//! Array extents: the array dimensions of the data space (paper §2).
//!
//! The 2023 paper adds two things over original LLAMA:
//!
//! 1. **A user-chosen index type.** All indexing arithmetic runs in a
//!    configurable integral type `I` ([`IndexType`]) instead of a hardwired
//!    `usize`/`std::size_t` — GPUs (and, on TPU, scalar-core address
//!    arithmetic) pay extra for 64-bit integer math. Benchmarked in E8
//!    (`benches/extents.rs`).
//!
//! 2. **Mixed compile-time/runtime extents.** Each dimension is either
//!    [`Fix`]`<I, E>` (a zero-sized type carrying the extent in the type) or
//!    [`Dyn`]`<I>` (stores the extent). Extents are tuples of these, so a
//!    fully static extent tuple is itself zero-sized: combined with inline
//!    blob storage ([`crate::blob::ArrayStorage`]) the view becomes a
//!    trivial value type, storage-wise identical to the mapped data —
//!    `memcpy`-able and placeable in GPU shared memory / TPU VMEM. Verified
//!    by `size_of` tests below. *Only runtime extents are stored*, exactly
//!    as in the paper.
//!
//! The paper's examples translate as:
//!
//! ```
//! use llama::extents::{Dyn, Fix, Extents};
//! // auto ae1 = llama::ArrayExtentsDynamic<int, 2>{size1, size2};
//! let ae1 = (Dyn(100i32), Dyn(200i32));
//! // auto ae2 = llama::ArrayExtents<std::size_t, 3, llama::dyn, 4, 4>{size};
//! let ae2 = (Fix::<usize, 3>::new(), Dyn(7usize), Fix::<usize, 4>::new(), Fix::<usize, 4>::new());
//! // auto ae3 = llama::ArrayExtents<short, 32, 4, 4>{};
//! let ae3 = (Fix::<i16, 32>::new(), Fix::<i16, 4>::new(), Fix::<i16, 4>::new());
//! assert_eq!(ae1.count(), 100 * 200);
//! assert_eq!(ae2.count(), 3 * 7 * 4 * 4);
//! assert_eq!(std::mem::size_of_val(&ae3), 0); // fully static => stateless
//! ```

use std::fmt::Debug;

/// An integral type usable for index arithmetic (paper §2: "LLAMA now
/// allows to specify the data type which should be used in all indexing
/// computations").
pub trait IndexType:
    Copy + Default + PartialEq + Eq + PartialOrd + Ord + Debug + Send + Sync + 'static
{
    /// Human-readable name for reports.
    const NAME: &'static str;
    /// Widen to `usize` (always lossless for valid indices).
    fn to_usize(self) -> usize;
    /// Narrow from `usize`; debug-asserts the value fits.
    fn from_usize(v: usize) -> Self;
    /// Multiply in the index domain (the point of §2: this is the width
    /// the hardware executes).
    fn mul(self, rhs: Self) -> Self;
    /// Add in the index domain.
    fn add(self, rhs: Self) -> Self;
}

macro_rules! impl_index_type {
    ($($t:ty),*) => {$(
        impl IndexType for $t {
            const NAME: &'static str = stringify!($t);
            #[inline(always)]
            fn to_usize(self) -> usize { self as usize }
            #[inline(always)]
            fn from_usize(v: usize) -> Self {
                debug_assert!(v <= <$t>::MAX as usize, "index {v} overflows {}", stringify!($t));
                v as $t
            }
            #[inline(always)]
            fn mul(self, rhs: Self) -> Self { self.wrapping_mul(rhs) }
            #[inline(always)]
            fn add(self, rhs: Self) -> Self { self.wrapping_add(rhs) }
        }
    )*};
}

impl_index_type!(u8, u16, u32, u64, usize, i16, i32, i64);

/// One array dimension: either a compile-time extent ([`Fix`]) or a
/// runtime extent ([`Dyn`]).
pub trait Extent: Copy + Debug + Send + Sync + 'static {
    /// The index arithmetic type.
    type Index: IndexType;
    /// The compile-time extent, or [`DYN`] if decided at runtime.
    const STATIC: usize;
    /// The extent value.
    fn get(self) -> usize;
}

/// Marker for a runtime extent in `STATIC` position.
pub const DYN: usize = usize::MAX;

/// A compile-time array extent: zero-sized, the value lives in the type.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct Fix<I: IndexType, const E: usize>(std::marker::PhantomData<I>);

impl<I: IndexType, const E: usize> Fix<I, E> {
    /// Construct (zero-sized).
    pub const fn new() -> Self {
        Fix(std::marker::PhantomData)
    }
}

impl<I: IndexType, const E: usize> Extent for Fix<I, E> {
    type Index = I;
    const STATIC: usize = E;
    #[inline(always)]
    fn get(self) -> usize {
        E
    }
}

/// A runtime array extent: stores one value of the index type.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct Dyn<I: IndexType>(pub I);

impl<I: IndexType> Extent for Dyn<I> {
    type Index = I;
    const STATIC: usize = DYN;
    #[inline(always)]
    fn get(self) -> usize {
        self.0.to_usize()
    }
}

/// The const-rank array index type: `[usize; RANK]`.
///
/// The typed access API ([`crate::view::View::get_t`] and friends) takes
/// indices as `ArrayIndex<RANK>` with the rank fixed by the view's
/// [`Extents::RANK`], so a wrong-rank access is a *compile error* (a
/// `[usize; 3]` is not a `[usize; 2]`) and the access path carries no
/// slice-length checks. The legacy `&[usize]` API remains as a thin
/// compatibility layer that converts (with one runtime rank assert).
pub type ArrayIndex<const RANK: usize> = [usize; RANK];

/// Abstraction over `[usize; N]` for any rank `N` — the bound carried by
/// [`Extents::ArrayIndex`], letting rank-generic code (the bulk-traversal
/// odometers in [`crate::view`]) hold exact-size index arrays instead of
/// `MAX_RANK`-padded buffers plus a runtime rank.
pub trait RankIndex:
    Copy + Clone + Debug + PartialEq + Eq + Send + Sync + 'static
{
    /// The array rank (number of dimensions).
    const RANK: usize;
    /// The all-zeros index.
    fn zeroed() -> Self;
    /// View as a slice of length [`RANK`](RankIndex::RANK).
    fn as_slice(&self) -> &[usize];
    /// View as a mutable slice of length [`RANK`](RankIndex::RANK).
    fn as_mut_slice(&mut self) -> &mut [usize];
}

impl<const N: usize> RankIndex for [usize; N] {
    const RANK: usize = N;
    #[inline(always)]
    fn zeroed() -> Self {
        [0; N]
    }
    #[inline(always)]
    fn as_slice(&self) -> &[usize] {
        self
    }
    #[inline(always)]
    fn as_mut_slice(&mut self) -> &mut [usize] {
        self
    }
}

/// A full set of array extents: a tuple of per-dimension [`Extent`]s
/// (rank 1–4) sharing one index type.
pub trait Extents: Copy + Debug + Send + Sync + 'static {
    /// The shared index arithmetic type.
    type Index: IndexType;
    /// The const-rank array index type, `[usize; RANK]` — see
    /// [`ArrayIndex`].
    type ArrayIndex: RankIndex;
    /// Number of array dimensions.
    const RANK: usize;
    /// Per-dimension compile-time extents ([`DYN`] where runtime).
    const STATIC_EXTENTS: &'static [usize];
    /// Extent of dimension `dim`.
    fn extent(&self, dim: usize) -> usize;

    /// Total number of records spanned.
    #[inline]
    fn count(&self) -> usize {
        let mut c = 1;
        for d in 0..Self::RANK {
            c *= self.extent(d);
        }
        c
    }

    /// Whether every dimension is compile-time (the zero-storage case).
    fn fully_static() -> bool {
        Self::STATIC_EXTENTS.iter().all(|&e| e != DYN)
    }
}

macro_rules! impl_extents_tuple {
    ($rank:literal; $($T:ident . $idx:tt),+) => {
        impl<I: IndexType, $($T: Extent<Index = I>),+> Extents for ($($T,)+) {
            type Index = I;
            type ArrayIndex = [usize; $rank];
            const RANK: usize = $rank;
            const STATIC_EXTENTS: &'static [usize] = &[$($T::STATIC),+];
            #[inline(always)]
            fn extent(&self, dim: usize) -> usize {
                let dims = [$(self.$idx.get()),+];
                dims[dim]
            }
        }
    };
}

impl_extents_tuple!(1; A.0);
impl_extents_tuple!(2; A.0, B.1);
impl_extents_tuple!(3; A.0, B.1, C.2);
impl_extents_tuple!(4; A.0, B.1, C.2, D.3);

/// Shorthand: rank-1 dynamic extents over `I`.
pub type Dyn1<I> = (Dyn<I>,);
/// Shorthand: rank-2 dynamic extents over `I`.
pub type Dyn2<I> = (Dyn<I>, Dyn<I>);
/// Shorthand: rank-3 dynamic extents over `I`.
pub type Dyn3<I> = (Dyn<I>, Dyn<I>, Dyn<I>);

/// Rank-1 dynamic extents with the default (`usize`) index type.
pub fn dyn1(n: usize) -> Dyn1<usize> {
    (Dyn(n),)
}

/// Rank-2 dynamic extents with the default (`usize`) index type.
pub fn dyn2(n0: usize, n1: usize) -> Dyn2<usize> {
    (Dyn(n0), Dyn(n1))
}

/// Advance `idx` (length `E::RANK`) one step in row-major order over `e`.
/// Returns `false` — with `idx` wrapped back to all zeros — once the
/// index space is exhausted. The shared odometer of the bulk-traversal
/// engine ([`crate::view::View::for_each`]) and [`crate::copy`].
#[inline(always)]
pub fn advance_index<E: Extents>(e: &E, idx: &mut [usize]) -> bool {
    debug_assert_eq!(idx.len(), E::RANK);
    let mut d = E::RANK;
    loop {
        if d == 0 {
            return false;
        }
        d -= 1;
        idx[d] += 1;
        if idx[d] < e.extent(d) {
            return true;
        }
        idx[d] = 0;
    }
}

// ---------------------------------------------------------------------------
// Linearizers
// ---------------------------------------------------------------------------

/// Maps a multidimensional array index to a flat record index.
///
/// LLAMA's `LinearizeArrayIndexRight`/`Left`/`Morton`: mappings are
/// parameterized on the linearizer, so the traversal order of the array
/// dimensions is itself part of the memory layout.
///
/// The arithmetic runs in `E::Index` (§2): with `u32` extents the generated
/// code uses 32-bit multiplies.
pub trait Linearizer: Copy + Default + Send + Sync + 'static {
    /// Name for reports.
    const NAME: &'static str;
    /// Whether incrementing the *last* array index increments the linear
    /// index by one — enables contiguous (vector-move) SIMD fast paths in
    /// SoA/AoSoA mappings.
    const LAST_DIM_CONTIGUOUS: bool;
    /// Flatten `idx` (length `E::RANK`) under extents `e`.
    fn linearize<E: Extents>(e: &E, idx: &[usize]) -> usize;
}

/// Row-major / C order: the rightmost index is fastest (LLAMA's
/// `LinearizeArrayIndexRight`, the default).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct RowMajor;

impl Linearizer for RowMajor {
    const NAME: &'static str = "RowMajor";
    const LAST_DIM_CONTIGUOUS: bool = true;
    #[inline(always)]
    fn linearize<E: Extents>(e: &E, idx: &[usize]) -> usize {
        debug_assert_eq!(idx.len(), E::RANK);
        let mut lin = E::Index::from_usize(0);
        for d in 0..E::RANK {
            debug_assert!(idx[d] < e.extent(d), "index {} out of bounds {}", idx[d], e.extent(d));
            lin = lin
                .mul(E::Index::from_usize(e.extent(d)))
                .add(E::Index::from_usize(idx[d]));
        }
        lin.to_usize()
    }
}

/// Column-major / Fortran order: the leftmost index is fastest (LLAMA's
/// `LinearizeArrayIndexLeft`).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct ColMajor;

impl Linearizer for ColMajor {
    const NAME: &'static str = "ColMajor";
    const LAST_DIM_CONTIGUOUS: bool = false;
    #[inline(always)]
    fn linearize<E: Extents>(e: &E, idx: &[usize]) -> usize {
        debug_assert_eq!(idx.len(), E::RANK);
        let mut lin = E::Index::from_usize(0);
        for d in (0..E::RANK).rev() {
            debug_assert!(idx[d] < e.extent(d));
            lin = lin
                .mul(E::Index::from_usize(e.extent(d)))
                .add(E::Index::from_usize(idx[d]));
        }
        lin.to_usize()
    }
}

/// Morton / Z-order curve: interleaves the bits of the (up to 2D) index,
/// improving locality for stencil-like access (LLAMA's
/// `LinearizeArrayIndexMorton`). Falls back to row-major beyond rank 2.
/// Requires power-of-two extents for a bijective mapping; callers should
/// size views accordingly (debug-asserted).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct Morton;

#[inline(always)]
fn spread_bits(mut v: usize) -> usize {
    // Insert a zero bit between each of the low 32 bits of v.
    let mut out = 0usize;
    let mut bit = 0;
    while v != 0 {
        out |= (v & 1) << (2 * bit);
        v >>= 1;
        bit += 1;
    }
    out
}

impl Linearizer for Morton {
    const NAME: &'static str = "Morton";
    const LAST_DIM_CONTIGUOUS: bool = false;
    #[inline(always)]
    fn linearize<E: Extents>(e: &E, idx: &[usize]) -> usize {
        match E::RANK {
            1 => idx[0],
            2 => {
                debug_assert!(e.extent(0).is_power_of_two() && e.extent(1).is_power_of_two());
                debug_assert!(idx[0] < e.extent(0) && idx[1] < e.extent(1));
                (spread_bits(idx[0]) << 1) | spread_bits(idx[1])
            }
            _ => RowMajor::linearize(e, idx),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn array_index_rank_is_in_the_type() {
        fn idx_of<E: Extents>() -> E::ArrayIndex {
            <E::ArrayIndex as RankIndex>::zeroed()
        }
        let mut i2 = idx_of::<(Dyn<u32>, Dyn<u32>)>();
        assert_eq!(i2, [0usize, 0]);
        assert_eq!(<[usize; 2] as RankIndex>::RANK, 2);
        i2.as_mut_slice()[1] = 7;
        assert_eq!(i2.as_slice(), &[0, 7]);
        // The alias is the same type.
        let _: ArrayIndex<2> = i2;
    }

    #[test]
    fn static_extents_are_zero_sized() {
        type E3 = (Fix<u16, 32>, Fix<u16, 4>, Fix<u16, 4>);
        assert_eq!(std::mem::size_of::<E3>(), 0);
        assert!(E3::fully_static());
        let e = (Fix::<u16, 32>::new(), Fix::<u16, 4>::new(), Fix::<u16, 4>::new());
        assert_eq!(e.count(), 512);
    }

    #[test]
    fn mixed_extents_store_only_runtime_parts() {
        // paper ae2: <size_t, 3, dyn, 4, 4> stores exactly one size_t
        type E = (Fix<usize, 3>, Dyn<usize>, Fix<usize, 4>, Fix<usize, 4>);
        assert_eq!(std::mem::size_of::<E>(), std::mem::size_of::<usize>());
        let e: E = (Fix::new(), Dyn(7), Fix::new(), Fix::new());
        assert_eq!(e.extent(0), 3);
        assert_eq!(e.extent(1), 7);
        assert_eq!(e.count(), 3 * 7 * 4 * 4);
        assert_eq!(E::STATIC_EXTENTS, &[3, DYN, 4, 4]);
    }

    #[test]
    fn dynamic_extents_with_narrow_index() {
        let e = (Dyn(100u16), Dyn(200u16));
        assert_eq!(std::mem::size_of_val(&e), 4); // two u16
        assert_eq!(e.count(), 20000);
    }

    #[test]
    fn advance_index_walks_row_major_and_terminates() {
        let e = (Dyn(2usize), Dyn(3usize));
        let mut idx = [0usize; 2];
        let mut seen = vec![idx];
        while advance_index(&e, &mut idx) {
            seen.push(idx);
        }
        assert_eq!(
            seen,
            vec![[0, 0], [0, 1], [0, 2], [1, 0], [1, 1], [1, 2]]
        );
        assert_eq!(idx, [0, 0]); // wrapped back after exhaustion
    }

    #[test]
    fn row_major_linearize() {
        let e = (Dyn(4usize), Dyn(5usize));
        assert_eq!(RowMajor::linearize(&e, &[0, 0]), 0);
        assert_eq!(RowMajor::linearize(&e, &[0, 1]), 1);
        assert_eq!(RowMajor::linearize(&e, &[1, 0]), 5);
        assert_eq!(RowMajor::linearize(&e, &[3, 4]), 19);
    }

    #[test]
    fn col_major_linearize() {
        let e = (Dyn(4usize), Dyn(5usize));
        assert_eq!(ColMajor::linearize(&e, &[0, 0]), 0);
        assert_eq!(ColMajor::linearize(&e, &[1, 0]), 1);
        assert_eq!(ColMajor::linearize(&e, &[0, 1]), 4);
        assert_eq!(ColMajor::linearize(&e, &[3, 4]), 19);
    }

    #[test]
    fn morton_linearize() {
        let e = (Dyn(4usize), Dyn(4usize));
        // Z-order for 2x2 blocks: (0,0)=0 (0,1)=1 (1,0)=2 (1,1)=3
        assert_eq!(Morton::linearize(&e, &[0, 0]), 0);
        assert_eq!(Morton::linearize(&e, &[0, 1]), 1);
        assert_eq!(Morton::linearize(&e, &[1, 0]), 2);
        assert_eq!(Morton::linearize(&e, &[1, 1]), 3);
        assert_eq!(Morton::linearize(&e, &[2, 2]), 12);
        // bijective over the whole extent
        let mut seen = vec![false; 16];
        for i in 0..4 {
            for j in 0..4 {
                let l = Morton::linearize(&e, &[i, j]);
                assert!(!seen[l]);
                seen[l] = true;
            }
        }
        assert!(seen.iter().all(|&b| b));
    }

    #[test]
    fn index_arithmetic_in_narrow_type() {
        // u16 arithmetic wraps at 65536 — documents that the index type is
        // genuinely used for computation (the paper's 32-bit-on-GPU point).
        let e = (Dyn(300u16), Dyn(300u16));
        // 299*300+299 = 89999 > u16::MAX would wrap; extents this large with
        // u16 are a user error, mirroring C++ narrowing semantics.
        assert_eq!(e.count(), 90000); // count() itself runs in usize
    }
}
