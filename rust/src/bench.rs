//! Micro-benchmark harness (criterion replacement, offline image has no
//! criterion crate).
//!
//! Methodology: warmup runs, then `samples` timed runs; report
//! median and median-absolute-deviation. Benches are `harness = false`
//! binaries under `rust/benches/` using [`Bencher`] and printing aligned
//! tables that mirror the paper's figures (see EXPERIMENTS.md).
//!
//! Besides the human tables, every bench emits a machine-readable perf
//! trajectory when `LLAMA_BENCH_JSON=<dir>` is set ([`emit_json`]): one
//! `BENCH_<tag>.json` per bench binary, uploaded as a CI artifact so
//! regressions are diffable across commits.
//!
//! # Counter mode
//!
//! Where the platform allows it ([`crate::counters`]), every measured
//! row additionally gets one hardware-counter run: after the timed
//! samples, `f` runs once more under a `perf_event_open` group and the
//! row records multiplex-scaled instructions / cycles / cache
//! references / cache misses / branch misses
//! ([`Measurement::counters`]). Counter-grade numbers are deterministic
//! where wall clock is noisy — two identical single-threaded runs agree
//! on instructions within 1% — which is what makes layout wins and
//! regressions provable across CI runs. When counters are unavailable
//! (`LLAMA_COUNTERS=off`, `perf_event_paranoid`, seccomp, Miri,
//! non-Linux) the harness degrades silently: rows keep their wall-clock
//! fields and simply omit the `counters` JSON object — never zeros.

use std::path::{Path, PathBuf};
use std::time::{Duration, Instant};

use crate::counters::{CounterError, CounterGroup, Counters};

/// Prevent the optimizer from discarding a computed value.
#[inline(always)]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

/// CI smoke mode: `LLAMA_BENCH_SMOKE=1` (or the older `LLAMA_BENCH_FAST=1`)
/// shrinks every bench to a tiny problem size and sample count, so bench
/// bitrot fails the build in seconds instead of burning minutes on full
/// runs. Every bench binary consults this.
pub fn smoke() -> bool {
    let on = |k| std::env::var(k).as_deref() == Ok("1");
    on("LLAMA_BENCH_SMOKE") || on("LLAMA_BENCH_FAST")
}

/// One benchmark measurement.
#[derive(Clone, Debug)]
pub struct Measurement {
    /// Label (e.g. "update AoS LLAMA SIMD").
    pub name: String,
    /// Median wall time per iteration.
    pub median: Duration,
    /// Median absolute deviation.
    pub mad: Duration,
    /// Number of samples.
    pub samples: usize,
    /// Work items per iteration (for per-item rates), 0 if unset.
    pub items: u64,
    /// Hardware counters for one extra run of the workload, when the
    /// platform delivers them (`None` = wall-clock-only row).
    pub counters: Option<Counters>,
}

impl Measurement {
    /// Nanoseconds per work item (`median / items`).
    pub fn ns_per_item(&self) -> f64 {
        if self.items == 0 {
            return self.median.as_nanos() as f64;
        }
        self.median.as_nanos() as f64 / self.items as f64
    }
}

/// Hardware-counter state of one [`Bencher`]: probed lazily on the
/// first `bench` call, demoted to `Down` on the first failure so one
/// flaky counter read can't abort a bench run.
enum CounterState {
    /// No `bench` call yet — nothing opened.
    Unprobed,
    /// Open group; every subsequent measurement gets a counter run.
    Live(CounterGroup),
    /// Counters are off/unavailable; rows stay wall-clock-only. The
    /// typed reason is kept for [`Bencher::counter_error`].
    Down(CounterError),
}

/// Benchmark runner with fixed warmup/sample counts.
pub struct Bencher {
    warmup: usize,
    samples: usize,
    results: Vec<Measurement>,
    counters: CounterState,
}

impl Default for Bencher {
    fn default() -> Self {
        Bencher::new(3, 10)
    }
}

impl Bencher {
    /// Runner with `warmup` discarded runs and `samples` timed runs.
    /// Counter mode follows `LLAMA_COUNTERS` (probed on first use).
    pub fn new(warmup: usize, samples: usize) -> Self {
        Bencher { warmup, samples, results: Vec::new(), counters: CounterState::Unprobed }
    }

    /// Runner whose counter path is pre-failed with `err` — tests use
    /// this to assert the degradation behavior (rows must *omit* the
    /// counters object, not emit zeros) without depending on what the
    /// host machine permits.
    pub fn with_counter_error(warmup: usize, samples: usize, err: CounterError) -> Self {
        Bencher { warmup, samples, results: Vec::new(), counters: CounterState::Down(err) }
    }

    /// Honor smoke mode (see [`smoke`]): fewer samples for CI.
    pub fn from_env() -> Self {
        if smoke() {
            Bencher::new(1, 3)
        } else {
            Bencher::default()
        }
    }

    /// Time `f`, which performs `items` units of work per call.
    ///
    /// After the timed samples, when counters are live, `f` runs once
    /// more under the hardware-counter group (outside any timing, so
    /// the wall-clock fields are undisturbed) and the row carries the
    /// scaled counts.
    pub fn bench<F: FnMut()>(&mut self, name: &str, items: u64, mut f: F) -> &Measurement {
        for _ in 0..self.warmup {
            f();
        }
        let mut times: Vec<Duration> = (0..self.samples)
            .map(|_| {
                let t0 = Instant::now();
                f();
                t0.elapsed()
            })
            .collect();
        times.sort();
        let median = times[times.len() / 2];
        let mut devs: Vec<Duration> =
            times.iter().map(|t| if *t > median { *t - median } else { median - *t }).collect();
        devs.sort();
        let mad = devs[devs.len() / 2];
        let counters = self.count_one_run(&mut f);
        self.results.push(Measurement {
            name: name.to_string(),
            median,
            mad,
            samples: self.samples,
            items,
            counters,
        });
        self.results.last().unwrap()
    }

    /// One counter-measured run of `f`, if counters are (still) live.
    /// The first failure demotes the Bencher to wall-clock-only — a
    /// mid-run error must not abort the bench or fake zeros.
    fn count_one_run<F: FnMut()>(&mut self, f: &mut F) -> Option<Counters> {
        if matches!(self.counters, CounterState::Unprobed) {
            self.counters = match CounterGroup::open() {
                Ok(group) => CounterState::Live(group),
                Err(err) => CounterState::Down(err),
            };
        }
        let CounterState::Live(group) = &self.counters else {
            return None;
        };
        match group.measure(f) {
            Ok(((), counters)) => Some(counters),
            Err(err) => {
                self.counters = CounterState::Down(err);
                None
            }
        }
    }

    /// Whether this Bencher's rows are getting hardware counters (false
    /// before the first `bench` call and after any counter failure).
    pub fn counters_live(&self) -> bool {
        matches!(self.counters, CounterState::Live(_))
    }

    /// Why counters are down, if they are (`None` while live or before
    /// the first `bench` call probes them).
    pub fn counter_error(&self) -> Option<&CounterError> {
        match &self.counters {
            CounterState::Down(err) => Some(err),
            _ => None,
        }
    }

    /// All measurements so far.
    pub fn results(&self) -> &[Measurement] {
        &self.results
    }

    /// Render an aligned results table; `baseline` (if given) adds a
    /// relative-speed column against the named measurement. Rows that
    /// carried hardware counters additionally get `instr/item` and
    /// `cmiss/item` columns (the whole table gains them when any row
    /// has counters; counter-less rows show `-`).
    pub fn render_table(&self, title: &str, baseline: Option<&str>) -> String {
        let base = baseline
            .and_then(|b| self.results.iter().find(|m| m.name == b))
            .map(|m| m.median.as_nanos() as f64);
        let counted = self.results.iter().any(|m| m.counters.is_some());
        let w = self.results.iter().map(|m| m.name.len()).max().unwrap_or(4).max(4);
        let mut out = format!("== {title} ==\n");
        out.push_str(&format!(
            "{:w$}  {:>12}  {:>10}  {:>12}{}{}\n",
            "name",
            "median",
            "mad",
            "ns/item",
            if counted { "  instr/item  cmiss/item" } else { "" },
            if base.is_some() { "  rel" } else { "" },
            w = w
        ));
        for m in &self.results {
            let rel = base
                .map(|b| format!("  {:>5.2}x", b / m.median.as_nanos() as f64))
                .unwrap_or_default();
            let counts = if counted {
                match &m.counters {
                    Some(c) => format!(
                        "  {:>10.2}  {:>10.4}",
                        c.instructions_per_item(m.items),
                        c.cache_misses_per_item(m.items)
                    ),
                    None => format!("  {:>10}  {:>10}", "-", "-"),
                }
            } else {
                String::new()
            };
            out.push_str(&format!(
                "{:w$}  {:>12}  {:>10}  {:>12.2}{}{}\n",
                m.name,
                format_duration(m.median),
                format_duration(m.mad),
                m.ns_per_item(),
                counts,
                rel,
                w = w
            ));
        }
        out
    }
}

/// Write the measurements of one bench binary as `BENCH_<tag>.json` under
/// the directory named by `LLAMA_BENCH_JSON` (created if missing).
///
/// Returns `Ok(None)` when the variable is unset — the benches call this
/// unconditionally and only CI (or a curious user) pays the I/O. `meta`
/// carries run parameters (problem size, thread count); `groups` one
/// entry per [`Bencher`] (e.g. the update and move tables of Figure 3).
///
/// Schema (`"schema": 2`):
/// `{bench, schema, meta: {k: v}, groups: [{name, measurements: [{name,
/// median_ns, mad_ns, samples, items, ns_per_item, counters?}]}]}`.
///
/// The optional `counters` object (schema 2, only on rows measured with
/// live hardware counters — degraded rows *omit* the key rather than
/// emitting zeros) is `{instructions, cycles, cache_references,
/// cache_misses, branch_misses, time_enabled_ns, time_running_ns,
/// multiplexed}`, counts multiplex-scaled (see [`crate::counters`]).
/// Schema 1 files (pre-counter history) differ only in lacking the key,
/// so the trajectory renderer accepts both.
pub fn emit_json(
    tag: &str,
    meta: &[(&str, String)],
    groups: &[(&str, &Bencher)],
) -> std::io::Result<Option<PathBuf>> {
    let Some(dir) = std::env::var_os("LLAMA_BENCH_JSON") else {
        return Ok(None);
    };
    emit_json_to(&PathBuf::from(dir), tag, meta, groups).map(Some)
}

/// The engine behind [`emit_json`]: write `BENCH_<tag>.json` into `dir`
/// (created if missing), regardless of the environment.
pub fn emit_json_to(
    dir: &Path,
    tag: &str,
    meta: &[(&str, String)],
    groups: &[(&str, &Bencher)],
) -> std::io::Result<PathBuf> {
    std::fs::create_dir_all(dir)?;
    let path = dir.join(format!("BENCH_{tag}.json"));

    let mut out = String::from("{\n");
    out.push_str(&format!("  \"bench\": {},\n", json_str(tag)));
    out.push_str("  \"schema\": 2,\n");
    out.push_str("  \"meta\": {");
    for (i, (k, v)) in meta.iter().enumerate() {
        if i > 0 {
            out.push_str(", ");
        }
        out.push_str(&format!("{}: {}", json_str(k), json_str(v)));
    }
    out.push_str("},\n");
    out.push_str("  \"groups\": [\n");
    for (gi, (name, bencher)) in groups.iter().enumerate() {
        out.push_str(&format!("    {{\"name\": {}, \"measurements\": [\n", json_str(name)));
        let ms = bencher.results();
        for (mi, m) in ms.iter().enumerate() {
            // Rows without live counters omit the object entirely — a
            // consumer must never mistake "unmeasured" for "zero".
            let counters = m.counters.as_ref().map_or_else(String::new, |c| {
                format!(
                    ", \"counters\": {{\"instructions\": {}, \"cycles\": {}, \
                     \"cache_references\": {}, \"cache_misses\": {}, \
                     \"branch_misses\": {}, \"time_enabled_ns\": {}, \
                     \"time_running_ns\": {}, \"multiplexed\": {}}}",
                    c.instructions,
                    c.cycles,
                    c.cache_references,
                    c.cache_misses,
                    c.branch_misses,
                    c.time_enabled_ns,
                    c.time_running_ns,
                    c.multiplexed,
                )
            });
            out.push_str(&format!(
                "      {{\"name\": {}, \"median_ns\": {}, \"mad_ns\": {}, \
                 \"samples\": {}, \"items\": {}, \"ns_per_item\": {:.4}{}}}{}\n",
                json_str(&m.name),
                m.median.as_nanos(),
                m.mad.as_nanos(),
                m.samples,
                m.items,
                m.ns_per_item(),
                counters,
                if mi + 1 < ms.len() { "," } else { "" },
            ));
        }
        out.push_str(&format!("    ]}}{}\n", if gi + 1 < groups.len() { "," } else { "" }));
    }
    out.push_str("  ]\n}\n");
    std::fs::write(&path, out)?;
    Ok(path)
}

/// Minimal JSON string encoding (quotes, backslashes, control chars).
fn json_str(s: &str) -> String {
    let mut out = String::with_capacity(s.len() + 2);
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
    out
}

/// Human-readable duration (ns/µs/ms/s).
pub fn format_duration(d: Duration) -> String {
    let ns = d.as_nanos();
    if ns < 1_000 {
        format!("{ns}ns")
    } else if ns < 1_000_000 {
        format!("{:.1}µs", ns as f64 / 1e3)
    } else if ns < 1_000_000_000 {
        format!("{:.2}ms", ns as f64 / 1e6)
    } else {
        format!("{:.3}s", ns as f64 / 1e9)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_measures_something() {
        let mut b = Bencher::new(1, 5);
        let mut acc = 0u64;
        let m = b.bench("spin", 1000, || {
            for i in 0..1000u64 {
                acc = black_box(acc.wrapping_add(i));
            }
        });
        assert!(m.median.as_nanos() > 0);
        assert_eq!(m.items, 1000);
        let table = b.render_table("test", None);
        assert!(table.contains("spin"));
    }

    #[test]
    fn relative_column() {
        let mut b = Bencher::new(0, 3);
        b.bench("fast", 1, || std::thread::sleep(Duration::from_micros(50)));
        b.bench("slow", 1, || std::thread::sleep(Duration::from_micros(200)));
        let t = b.render_table("t", Some("slow"));
        assert!(t.contains("rel"));
    }

    #[test]
    fn duration_formatting() {
        assert_eq!(format_duration(Duration::from_nanos(500)), "500ns");
        assert_eq!(format_duration(Duration::from_micros(1500)), "1.50ms");
        assert!(format_duration(Duration::from_secs(2)).ends_with('s'));
    }

    #[test]
    fn json_escaping() {
        assert_eq!(super::json_str("plain"), "\"plain\"");
        assert_eq!(super::json_str("a\"b\\c"), "\"a\\\"b\\\\c\"");
        assert_eq!(super::json_str("x\ny"), "\"x\\ny\"");
    }

    #[test]
    fn emit_json_writes_all_measurements() {
        // emit_json_to is exercised directly: mutating the process
        // environment from a multithreaded test harness is not safe.
        let dir = std::env::temp_dir().join(format!("llama-bench-json-{}", std::process::id()));
        let mut b = Bencher::new(0, 3);
        b.bench("fast op", 10, || {});
        b.bench("slow \"op\"", 20, || std::thread::sleep(Duration::from_micros(5)));
        let path = emit_json_to(&dir, "selftest", &[("n", "10".to_string())], &[("g1", &b)])
            .expect("write");
        let text = std::fs::read_to_string(&path).unwrap();
        let _ = std::fs::remove_file(&path);
        let _ = std::fs::remove_dir(&dir);
        assert!(path.file_name().unwrap().to_str().unwrap() == "BENCH_selftest.json");
        assert!(text.contains("\"bench\": \"selftest\""));
        assert!(text.contains("\"schema\": 2"));
        assert!(text.contains("\"n\": \"10\""));
        assert!(text.contains("\"fast op\""));
        assert!(text.contains("\"slow \\\"op\\\"\""));
        assert!(text.contains("\"items\": 20"));
        // Balanced braces/brackets — a cheap well-formedness check given
        // the offline image has no JSON parser crate.
        let bal = |open: char, close: char| {
            text.chars().filter(|&c| c == open).count()
                == text.chars().filter(|&c| c == close).count()
        };
        assert!(bal('{', '}') && bal('[', ']'));
    }

    #[test]
    fn degraded_counters_omit_the_json_key_not_zeros() {
        // A Bencher whose counter path failed (here: simulated Denied,
        // the perf_event_paranoid case) must emit schema-2 rows WITHOUT
        // a counters object — zeros would poison the trajectory.
        let dir = std::env::temp_dir().join(format!("llama-bench-nocnt-{}", std::process::id()));
        let mut b =
            Bencher::with_counter_error(0, 3, crate::counters::CounterError::Denied);
        b.bench("row", 10, || {});
        assert!(!b.counters_live());
        assert_eq!(b.counter_error(), Some(&crate::counters::CounterError::Denied));
        assert!(b.results()[0].counters.is_none());
        let path = emit_json_to(&dir, "nocnt", &[], &[("g", &b)]).expect("write");
        let text = std::fs::read_to_string(&path).unwrap();
        let _ = std::fs::remove_file(&path);
        let _ = std::fs::remove_dir(&dir);
        assert!(text.contains("\"schema\": 2"));
        assert!(!text.contains("counters"));
        // The table renders without the counter columns.
        let table = b.render_table("t", None);
        assert!(!table.contains("instr/item"));
    }

    #[test]
    fn live_counters_attach_to_rows_and_json() {
        // Environment-dependent by nature: on machines where the PMU is
        // reachable this exercises the full attach path; elsewhere it
        // asserts the graceful degradation (typed error, no counters).
        let mut b = Bencher::new(0, 2);
        let mut acc = 0u64;
        b.bench("spin", 1_000, || {
            for i in 0..1_000u64 {
                acc = black_box(acc.wrapping_add(i));
            }
        });
        let m = &b.results()[0];
        match &m.counters {
            Some(c) => {
                assert!(b.counters_live());
                assert!(c.instructions > 0, "a 1000-iteration spin retires instructions");
                let table = b.render_table("t", None);
                assert!(table.contains("instr/item"));
            }
            None => {
                let err = b.counter_error().expect("no counters must come with a reason");
                assert!(!err.to_string().is_empty());
            }
        }
    }
}
