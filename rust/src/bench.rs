//! Micro-benchmark harness (criterion replacement, offline image has no
//! criterion crate).
//!
//! Methodology: warmup runs, then `samples` timed runs; report
//! median and median-absolute-deviation. Benches are `harness = false`
//! binaries under `rust/benches/` using [`Bencher`] and printing aligned
//! tables that mirror the paper's figures (see EXPERIMENTS.md).

use std::time::{Duration, Instant};

/// Prevent the optimizer from discarding a computed value.
#[inline(always)]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

/// CI smoke mode: `LLAMA_BENCH_SMOKE=1` (or the older `LLAMA_BENCH_FAST=1`)
/// shrinks every bench to a tiny problem size and sample count, so bench
/// bitrot fails the build in seconds instead of burning minutes on full
/// runs. Every bench binary consults this.
pub fn smoke() -> bool {
    let on = |k| std::env::var(k).as_deref() == Ok("1");
    on("LLAMA_BENCH_SMOKE") || on("LLAMA_BENCH_FAST")
}

/// One benchmark measurement.
#[derive(Clone, Debug)]
pub struct Measurement {
    /// Label (e.g. "update AoS LLAMA SIMD").
    pub name: String,
    /// Median wall time per iteration.
    pub median: Duration,
    /// Median absolute deviation.
    pub mad: Duration,
    /// Number of samples.
    pub samples: usize,
    /// Work items per iteration (for per-item rates), 0 if unset.
    pub items: u64,
}

impl Measurement {
    /// Nanoseconds per work item (`median / items`).
    pub fn ns_per_item(&self) -> f64 {
        if self.items == 0 {
            return self.median.as_nanos() as f64;
        }
        self.median.as_nanos() as f64 / self.items as f64
    }
}

/// Benchmark runner with fixed warmup/sample counts.
pub struct Bencher {
    warmup: usize,
    samples: usize,
    results: Vec<Measurement>,
}

impl Default for Bencher {
    fn default() -> Self {
        Bencher::new(3, 10)
    }
}

impl Bencher {
    /// Runner with `warmup` discarded runs and `samples` timed runs.
    pub fn new(warmup: usize, samples: usize) -> Self {
        Bencher { warmup, samples, results: Vec::new() }
    }

    /// Honor smoke mode (see [`smoke`]): fewer samples for CI.
    pub fn from_env() -> Self {
        if smoke() {
            Bencher::new(1, 3)
        } else {
            Bencher::default()
        }
    }

    /// Time `f`, which performs `items` units of work per call.
    pub fn bench<F: FnMut()>(&mut self, name: &str, items: u64, mut f: F) -> &Measurement {
        for _ in 0..self.warmup {
            f();
        }
        let mut times: Vec<Duration> = (0..self.samples)
            .map(|_| {
                let t0 = Instant::now();
                f();
                t0.elapsed()
            })
            .collect();
        times.sort();
        let median = times[times.len() / 2];
        let mut devs: Vec<Duration> =
            times.iter().map(|t| if *t > median { *t - median } else { median - *t }).collect();
        devs.sort();
        let mad = devs[devs.len() / 2];
        self.results.push(Measurement {
            name: name.to_string(),
            median,
            mad,
            samples: self.samples,
            items,
        });
        self.results.last().unwrap()
    }

    /// All measurements so far.
    pub fn results(&self) -> &[Measurement] {
        &self.results
    }

    /// Render an aligned results table; `baseline` (if given) adds a
    /// relative-speed column against the named measurement.
    pub fn render_table(&self, title: &str, baseline: Option<&str>) -> String {
        let base = baseline
            .and_then(|b| self.results.iter().find(|m| m.name == b))
            .map(|m| m.median.as_nanos() as f64);
        let w = self.results.iter().map(|m| m.name.len()).max().unwrap_or(4).max(4);
        let mut out = format!("== {title} ==\n");
        out.push_str(&format!(
            "{:w$}  {:>12}  {:>10}  {:>12}{}\n",
            "name",
            "median",
            "mad",
            "ns/item",
            if base.is_some() { "  rel" } else { "" },
            w = w
        ));
        for m in &self.results {
            let rel = base
                .map(|b| format!("  {:>5.2}x", b / m.median.as_nanos() as f64))
                .unwrap_or_default();
            out.push_str(&format!(
                "{:w$}  {:>12}  {:>10}  {:>12.2}{}\n",
                m.name,
                format_duration(m.median),
                format_duration(m.mad),
                m.ns_per_item(),
                rel,
                w = w
            ));
        }
        out
    }
}

/// Human-readable duration (ns/µs/ms/s).
pub fn format_duration(d: Duration) -> String {
    let ns = d.as_nanos();
    if ns < 1_000 {
        format!("{ns}ns")
    } else if ns < 1_000_000 {
        format!("{:.1}µs", ns as f64 / 1e3)
    } else if ns < 1_000_000_000 {
        format!("{:.2}ms", ns as f64 / 1e6)
    } else {
        format!("{:.3}s", ns as f64 / 1e9)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_measures_something() {
        let mut b = Bencher::new(1, 5);
        let mut acc = 0u64;
        let m = b.bench("spin", 1000, || {
            for i in 0..1000u64 {
                acc = black_box(acc.wrapping_add(i));
            }
        });
        assert!(m.median.as_nanos() > 0);
        assert_eq!(m.items, 1000);
        let table = b.render_table("test", None);
        assert!(table.contains("spin"));
    }

    #[test]
    fn relative_column() {
        let mut b = Bencher::new(0, 3);
        b.bench("fast", 1, || std::thread::sleep(Duration::from_micros(50)));
        b.bench("slow", 1, || std::thread::sleep(Duration::from_micros(200)));
        let t = b.render_table("t", Some("slow"));
        assert!(t.contains("rel"));
    }

    #[test]
    fn duration_formatting() {
        assert_eq!(format_duration(Duration::from_nanos(500)), "500ns");
        assert_eq!(format_duration(Duration::from_micros(1500)), "1.50ms");
        assert!(format_duration(Duration::from_secs(2)).ends_with('s'));
    }
}
