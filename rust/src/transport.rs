//! Layout-aware view transport: ship views across process boundaries.
//!
//! The paper's core claim — access is decoupled from layout — holds
//! across a wire as well as across a function call. This module defines a
//! versioned wire format for views: a header describing the record
//! dimension, the array extents, the payload mapping's identity
//! (fingerprint) and the blob geometry, followed by the raw payload
//! bytes. The payload always uses the **canonical wire layout**
//! [`WireMapping`] (packed field-major single blob: SoA single-blob,
//! row-major, full mask), so any two endpoints agree on the byte meaning
//! without exchanging mapping *types* — only the header's identity
//! strings are compared.
//!
//! - **Encode** ([`encode`] / [`encode_par`]) relayouts the source view
//!   into the canonical payload with the layout-aware copy engine
//!   ([`crate::copy::copy_view`]): memcpy-grade
//!   [`contiguous_run`](crate::mapping::Mapping::contiguous_run) field
//!   runs where the source layout permits (SoA, AoSoA), whole-blob
//!   memcpy when the source *is* the canonical layout, and the
//!   field-wise fallback for computed/bit-packed mappings. The strategy
//!   used is recorded in the message for observability.
//! - **Decode** either **adopts** the payload bytes directly as view
//!   storage ([`decode_adopt`]: same mapping ⇒ zero relayout, zero
//!   copy), or **streams** them into the receiver's preferred mapping
//!   ([`decode_into`] / [`decode_into_par`]) via the same copy engine —
//!   the receiver's layout may differ arbitrarily from the sender's.
//!
//! [`WireMsg::write_to`] / [`WireMsg::read_from`] frame messages over any
//! `Write`/`Read` transport (the distributed n-body example uses a Unix
//! socket; see `examples/distributed_nbody.rs` and `docs/SERVING.md` for
//! the byte-level format specification).
//!
//! **Integrity (version 2):** every frame ends in a CRC-32 ([`crc32`],
//! IEEE polynomial, hand-rolled — no crates) over all preceding frame
//! bytes, header included. [`WireMsg::read_from`] verifies the checksum
//! before any decode touches the payload; a mismatch surfaces as a typed
//! [`WireError::Corrupt`] (retrievable from the `io::Error` via
//! [`wire_error_in`]), so a flipped bit in transit becomes a clean retry
//! instead of silently wrong physics. Truncated or garbage frames fail
//! with bounded allocation — see `docs/SERVING.md` §5 "Failure model".

use std::io::{self, Read, Write};

use crate::blob::{alloc_view, BlobStorage, HeapAlloc, HeapStorage};
use crate::copy::{copy_view, copy_view_par, CopyStrategy};
use crate::extents::{Extents, RowMajor};
use crate::mapping::soa::{SingleBlob, SoA};
use crate::mapping::{Mapping, MemoryAccess};
use crate::record::RecordDim;
use crate::view::View;

/// Wire format version this build speaks; [`WireMsg::read_from`] rejects
/// others. Version 2 appended the trailing frame CRC-32 — v1 frames are
/// refused outright rather than trusted unchecked.
pub const WIRE_VERSION: u16 = 2;

/// Frame magic ("LLAMA Wire") guarding against misaligned streams.
pub const WIRE_MAGIC: [u8; 4] = *b"LLWv";

// ---------------------------------------------------------------------------
// CRC-32 (IEEE), hand-rolled — same zero-dependency pattern as `numa.rs`
// ---------------------------------------------------------------------------

/// Table for the reflected IEEE CRC-32 (polynomial `0xEDB88320`), built
/// at compile time.
const CRC_TABLE: [u32; 256] = {
    let mut table = [0u32; 256];
    let mut i = 0;
    while i < 256 {
        let mut c = i as u32;
        let mut k = 0;
        while k < 8 {
            c = if c & 1 != 0 { 0xEDB8_8320 ^ (c >> 1) } else { c >> 1 };
            k += 1;
        }
        table[i] = c;
        i += 1;
    }
    table
};

/// Incremental CRC-32 (IEEE / zlib variant: init `0xFFFFFFFF`, reflected,
/// final xor). Known answer: `crc32(b"123456789") == 0xCBF43926`.
#[derive(Clone, Copy, Debug)]
pub struct Crc32 {
    state: u32,
}

impl Crc32 {
    /// Fresh checksum state.
    pub fn new() -> Crc32 {
        Crc32 { state: 0xFFFF_FFFF }
    }

    /// Fold `bytes` into the checksum.
    #[inline]
    pub fn update(&mut self, bytes: &[u8]) {
        let mut s = self.state;
        for &b in bytes {
            s = CRC_TABLE[((s ^ u32::from(b)) & 0xFF) as usize] ^ (s >> 8);
        }
        self.state = s;
    }

    /// The checksum of everything folded in so far.
    pub fn finish(self) -> u32 {
        self.state ^ 0xFFFF_FFFF
    }
}

impl Default for Crc32 {
    fn default() -> Self {
        Crc32::new()
    }
}

/// One-shot CRC-32 of `bytes`.
pub fn crc32(bytes: &[u8]) -> u32 {
    let mut c = Crc32::new();
    c.update(bytes);
    c.finish()
}

/// The canonical wire payload layout: every field's values packed
/// contiguously, field regions concatenated in record order into one
/// blob, row-major linearization, all fields present.
///
/// Chosen because it is (a) unambiguous given only the record dimension
/// and the extents — no padding, no interleaving parameters — and (b)
/// run-friendly on both ends: every mapping with byte-contiguity copies
/// to/from it as whole-field memcpy runs.
pub type WireMapping<R, E> = SoA<R, E, SingleBlob, RowMajor>;

/// A decoded-header + payload wire message.
///
/// Produced by [`encode`]/[`encode_par`] or [`WireMsg::read_from`];
/// consumed by [`decode_adopt`]/[`decode_into`] or
/// [`WireMsg::write_to`].
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct WireMsg {
    /// Wire format version ([`WIRE_VERSION`]).
    pub version: u16,
    /// Record-dimension descriptor ([`record_descriptor`]): name plus
    /// every flattened field as `path:type`. Both ends must agree.
    pub record: String,
    /// Layout fingerprint of the payload mapping
    /// ([`crate::mapping::Mapping::fingerprint`]); receivers adopt only
    /// on an exact match.
    pub fingerprint: String,
    /// Runtime extent of each array dimension, outermost first.
    pub extents: Vec<u64>,
    /// Copy strategy the encoder used (observability: asserts in tests
    /// and benches that the memcpy-grade path fired where expected).
    pub strategy: CopyStrategy,
    /// The payload: the canonical wire blob's bytes.
    pub payload: Vec<u8>,
}

/// Decode-side validation failure: the message header does not match
/// what the receiver asked the payload to be.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum WireError {
    /// Message version differs from [`WIRE_VERSION`].
    Version(u16),
    /// Record-dimension descriptors differ (incompatible field sets).
    Record {
        /// Descriptor the receiver expects.
        expected: String,
        /// Descriptor the message carries.
        got: String,
    },
    /// Extents differ (per-dimension values or rank).
    Extents {
        /// Extents the receiver expects.
        expected: Vec<u64>,
        /// Extents the message carries.
        got: Vec<u64>,
    },
    /// Mapping fingerprints differ — the payload is not the layout the
    /// receiver tried to adopt.
    Fingerprint {
        /// Fingerprint the receiver expects.
        expected: String,
        /// Fingerprint the message carries.
        got: String,
    },
    /// Payload byte count does not match the blob geometry the mapping
    /// requires for the stated extents.
    Geometry {
        /// Bytes the mapping requires.
        expected: usize,
        /// Bytes the message carries.
        got: usize,
    },
    /// Frame checksum mismatch: the bytes were corrupted in transit.
    /// Raised by [`WireMsg::read_from`] **before** any decode touches
    /// the payload; retrieve it from the `io::Error` with
    /// [`wire_error_in`].
    Corrupt {
        /// CRC-32 the receiver computed over the frame bytes.
        expected: u32,
        /// CRC-32 the frame carried.
        got: u32,
    },
}

impl std::fmt::Display for WireError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            WireError::Version(v) => {
                write!(f, "wire version {v} (this build speaks {WIRE_VERSION})")
            }
            WireError::Record { expected, got } => {
                write!(f, "record mismatch: expected {expected:?}, got {got:?}")
            }
            WireError::Extents { expected, got } => {
                write!(f, "extents mismatch: expected {expected:?}, got {got:?}")
            }
            WireError::Fingerprint { expected, got } => {
                write!(f, "layout mismatch: expected {expected:?}, got {got:?}")
            }
            WireError::Geometry { expected, got } => {
                write!(f, "payload geometry: mapping needs {expected} bytes, message has {got}")
            }
            WireError::Corrupt { expected, got } => {
                write!(
                    f,
                    "frame corrupt: computed crc32 {expected:#010x}, frame carries {got:#010x}"
                )
            }
        }
    }
}

impl std::error::Error for WireError {}

/// The typed [`WireError`] inside an `io::Error`, if it carries one.
///
/// [`WireMsg::read_from`] reports checksum failures as
/// `io::ErrorKind::InvalidData` wrapping a [`WireError::Corrupt`]; use
/// this to tell in-transit corruption (worth a retry against a live
/// peer) apart from protocol violations and plain transport failures:
///
/// ```
/// # use llama::transport::{wire_error_in, WireError};
/// # let err = std::io::Error::new(
/// #     std::io::ErrorKind::InvalidData,
/// #     WireError::Corrupt { expected: 1, got: 2 },
/// # );
/// if let Some(WireError::Corrupt { .. }) = wire_error_in(&err) {
///     // count it, drop the peer, re-dispatch the work
/// }
/// ```
pub fn wire_error_in(e: &io::Error) -> Option<&WireError> {
    e.get_ref()?.downcast_ref::<WireError>()
}

/// The record-dimension descriptor shipped in every message header:
/// record name plus each flattened field as `dotted.path:type`, e.g.
/// `Particle{pos.x:f32,pos.y:f32,...,mass:f32}`. Two record dimensions
/// with equal descriptors have identical flattened field sets, so their
/// canonical wire payloads are interchangeable.
pub fn record_descriptor<R: RecordDim>() -> String {
    let fields: Vec<String> =
        R::FIELDS.iter().map(|f| format!("{}:{}", f.dotted(), f.ty.name())).collect();
    format!("{}{{{}}}", R::NAME, fields.join(","))
}

fn extent_values<E: Extents>(e: &E) -> Vec<u64> {
    (0..E::RANK).map(|d| e.extent(d) as u64).collect()
}

/// Encode `src` into a wire message, relayouting into the canonical
/// [`WireMapping`] payload via the layout-aware copy engine.
///
/// The strategy the engine picked is recorded in the message:
/// `BlobMemcpy` when `src` already is the canonical layout, `FieldRuns`
/// when every field has [`contiguous_run`] byte-contiguity (SoA, AoSoA),
/// `FieldWise` otherwise (AoS interleaving, computed mappings).
///
/// [`contiguous_run`]: crate::mapping::Mapping::contiguous_run
pub fn encode<R, M, S>(src: &View<R, M, S>) -> WireMsg
where
    R: RecordDim,
    M: MemoryAccess<R>,
    S: BlobStorage,
{
    let e = *src.extents();
    let mut wire = alloc_view(WireMapping::<R, M::Extents>::new(e), &HeapAlloc);
    let strategy = copy_view(src, &mut wire);
    finish_encode(wire, &e, strategy)
}

/// [`encode`] with the relayout fanned over up to `threads` workers
/// ([`crate::copy::copy_view_par`]) — for large views whose source
/// layout has contiguous runs.
pub fn encode_par<R, M, S>(src: &View<R, M, S>, threads: usize) -> WireMsg
where
    R: RecordDim,
    M: MemoryAccess<R>,
    S: BlobStorage + Sync,
{
    let e = *src.extents();
    let mut wire = alloc_view(WireMapping::<R, M::Extents>::new(e), &HeapAlloc);
    let strategy = copy_view_par(src, &mut wire, threads);
    finish_encode(wire, &e, strategy)
}

fn finish_encode<R, E>(
    wire: View<R, WireMapping<R, E>, HeapStorage>,
    e: &E,
    strategy: CopyStrategy,
) -> WireMsg
where
    R: RecordDim,
    E: Extents,
{
    let fingerprint = wire.mapping().fingerprint();
    let extents = extent_values(e);
    let (_, storage) = wire.into_parts();
    let mut blobs = storage.into_blobs();
    let payload = if blobs.is_empty() { Vec::new() } else { blobs.swap_remove(0) };
    WireMsg { version: WIRE_VERSION, record: record_descriptor::<R>(), fingerprint, extents, strategy, payload }
}

/// Adopt the payload bytes directly as the storage of a
/// [`WireMapping`]-mapped view — **zero relayout, zero copy** (the
/// `Vec<u8>` moves into the view).
///
/// `extents` is the receiver's extents value (any extents type with the
/// same runtime values works: the canonical layout depends only on the
/// values, and [`fingerprint`](crate::mapping::Mapping::fingerprint)s
/// agree across `Fix`/`Dyn` dimensions of equal extent). Fails if the
/// header's record descriptor, extents, layout fingerprint, or payload
/// geometry don't match.
pub fn decode_adopt<R, E>(
    msg: WireMsg,
    extents: E,
) -> Result<View<R, WireMapping<R, E>, HeapStorage>, WireError>
where
    R: RecordDim,
    E: Extents,
{
    let mapping = WireMapping::<R, E>::new(extents);
    validate::<R, _>(&msg, &mapping)?;
    let need = mapping.blob_size(0);
    if msg.payload.len() < need {
        return Err(WireError::Geometry { expected: need, got: msg.payload.len() });
    }
    Ok(View::from_parts(mapping, HeapStorage::from_blobs(vec![msg.payload])))
}

/// Stream the payload into `dst`, whatever its mapping — the relayout
/// path of the receive side. Returns the copy strategy used (memcpy
/// field runs into SoA/AoSoA destinations, field-wise into
/// computed/interleaved ones).
///
/// The wire-side view is built over the moved payload bytes (no copy
/// before the relayout itself). Fails on any header mismatch against
/// `dst`'s record/extents.
pub fn decode_into<R, MD, SD>(
    msg: WireMsg,
    dst: &mut View<R, MD, SD>,
) -> Result<CopyStrategy, WireError>
where
    R: RecordDim,
    MD: MemoryAccess<R>,
    SD: BlobStorage,
{
    let wire = decode_adopt::<R, MD::Extents>(msg, *dst.extents())?;
    Ok(copy_view(&wire, dst))
}

/// [`decode_into`] with the relayout fanned over up to `threads` workers
/// ([`crate::copy::copy_view_par`]).
pub fn decode_into_par<R, MD, SD>(
    msg: WireMsg,
    dst: &mut View<R, MD, SD>,
    threads: usize,
) -> Result<CopyStrategy, WireError>
where
    R: RecordDim,
    MD: MemoryAccess<R>,
    SD: BlobStorage + Send + Sync,
{
    let wire = decode_adopt::<R, MD::Extents>(msg, *dst.extents())?;
    Ok(copy_view_par(&wire, dst, threads))
}

/// Validate the header against a receiver-side canonical mapping.
fn validate<R, E>(msg: &WireMsg, mapping: &WireMapping<R, E>) -> Result<(), WireError>
where
    R: RecordDim,
    E: Extents,
{
    if msg.version != WIRE_VERSION {
        return Err(WireError::Version(msg.version));
    }
    let expected = record_descriptor::<R>();
    if msg.record != expected {
        return Err(WireError::Record { expected, got: msg.record.clone() });
    }
    let extents = extent_values(mapping.extents());
    if msg.extents != extents {
        return Err(WireError::Extents { expected: extents, got: msg.extents.clone() });
    }
    let fp = mapping.fingerprint();
    if msg.fingerprint != fp {
        return Err(WireError::Fingerprint { expected: fp, got: msg.fingerprint.clone() });
    }
    Ok(())
}

// ---------------------------------------------------------------------------
// Framing
// ---------------------------------------------------------------------------

/// Cap on header strings accepted by [`WireMsg::read_from`], so a
/// corrupt length prefix cannot drive an unbounded allocation.
const MAX_HEADER_STRING: usize = 1 << 20;
const MAX_RANK: usize = crate::view::MAX_RANK;

impl WireMsg {
    /// Number of records the extents span (saturating — a garbage
    /// header with overflowing extents must not wrap into a small,
    /// plausible-looking count).
    pub fn record_count(&self) -> usize {
        let n = self.extents.iter().fold(1u64, |acc, &e| acc.saturating_mul(e));
        usize::try_from(n).unwrap_or(usize::MAX)
    }

    /// Serialized frame size in bytes (header + payload + trailing
    /// CRC-32).
    pub fn frame_len(&self) -> usize {
        4 + 2 + 1 + 1
            + self.extents.len() * 8
            + 4
            + self.record.len()
            + 4
            + self.fingerprint.len()
            + 4
            + 8
            + self.payload.len()
            + 4
    }

    /// Write one framed message.
    ///
    /// Frame layout (all integers little-endian):
    ///
    /// ```text
    /// magic            4 bytes  "LLWv"
    /// version          u16
    /// strategy         u8       CopyStrategy the encoder used
    /// rank             u8
    /// extents          rank × u64
    /// record_len       u32      then that many UTF-8 bytes
    /// fingerprint_len  u32      then that many UTF-8 bytes
    /// blob_count       u32      payload blob geometry (always 1)
    /// blob_len         u64      per blob
    /// payload          blob_len bytes
    /// crc32            u32      CRC-32 of every preceding frame byte
    /// ```
    pub fn write_to<W: Write>(&self, w: &mut W) -> io::Result<()> {
        let crc = {
            let mut cw = CrcWriter { inner: &mut *w, crc: Crc32::new() };
            cw.write_all(&WIRE_MAGIC)?;
            cw.write_all(&self.version.to_le_bytes())?;
            cw.write_all(&[strategy_code(self.strategy), self.extents.len() as u8])?;
            for &e in &self.extents {
                cw.write_all(&e.to_le_bytes())?;
            }
            cw.write_all(&(self.record.len() as u32).to_le_bytes())?;
            cw.write_all(self.record.as_bytes())?;
            cw.write_all(&(self.fingerprint.len() as u32).to_le_bytes())?;
            cw.write_all(self.fingerprint.as_bytes())?;
            cw.write_all(&1u32.to_le_bytes())?;
            cw.write_all(&(self.payload.len() as u64).to_le_bytes())?;
            cw.write_all(&self.payload)?;
            cw.crc.finish()
        };
        w.write_all(&crc.to_le_bytes())
    }

    /// Read one framed message (see [`write_to`](WireMsg::write_to) for
    /// the layout), verifying the trailing CRC-32 **before returning**
    /// — corrupted frames never reach a decoder. Malformed frames — bad
    /// magic, unknown version or strategy, oversized header fields,
    /// unsupported blob geometry — fail with
    /// [`io::ErrorKind::InvalidData`]; checksum mismatches additionally
    /// carry a typed [`WireError::Corrupt`] (see [`wire_error_in`]).
    /// Truncations fail with `UnexpectedEof`. Allocation stays bounded
    /// on garbage: header strings are capped at 1 MiB up front, and the
    /// payload buffer grows with bytes actually read, so a corrupt
    /// `blob_len` cannot drive an unbounded upfront allocation.
    pub fn read_from<Rd: Read>(r: &mut Rd) -> io::Result<WireMsg> {
        let mut cr = CrcReader { inner: &mut *r, crc: Crc32::new() };
        let mut magic = [0u8; 4];
        cr.read_exact(&mut magic)?;
        if magic != WIRE_MAGIC {
            return Err(bad_frame("bad magic"));
        }
        let version = u16::from_le_bytes(read_array(&mut cr)?);
        if version != WIRE_VERSION {
            return Err(bad_frame("unsupported wire version"));
        }
        let [strategy, rank] = read_array(&mut cr)?;
        let strategy = strategy_from_code(strategy).ok_or_else(|| bad_frame("bad strategy"))?;
        let rank = rank as usize;
        if rank == 0 || rank > MAX_RANK {
            return Err(bad_frame("bad rank"));
        }
        let mut extents = Vec::with_capacity(rank);
        for _ in 0..rank {
            extents.push(u64::from_le_bytes(read_array(&mut cr)?));
        }
        let record = read_string(&mut cr)?;
        let fingerprint = read_string(&mut cr)?;
        let blob_count = u32::from_le_bytes(read_array(&mut cr)?);
        if blob_count != 1 {
            return Err(bad_frame("unsupported blob geometry"));
        }
        let blob_len = u64::from_le_bytes(read_array(&mut cr)?);
        let blob_len = usize::try_from(blob_len).map_err(|_| bad_frame("payload too large"))?;
        // Pre-reserve at most the header-string cap; beyond that the
        // buffer grows only as bytes actually arrive, so a garbage
        // length cannot allocate terabytes before the EOF shows up.
        let mut payload = Vec::with_capacity(blob_len.min(MAX_HEADER_STRING));
        let got = (&mut cr).take(blob_len as u64).read_to_end(&mut payload)?;
        if got < blob_len {
            return Err(io::Error::new(
                io::ErrorKind::UnexpectedEof,
                "wire frame: payload truncated",
            ));
        }
        let computed = cr.crc.finish();
        let stored = u32::from_le_bytes(read_array(r)?);
        if computed != stored {
            return Err(io::Error::new(
                io::ErrorKind::InvalidData,
                WireError::Corrupt { expected: computed, got: stored },
            ));
        }
        Ok(WireMsg { version, record, fingerprint, extents, strategy, payload })
    }
}

/// `Read` adapter folding everything it reads into a [`Crc32`].
struct CrcReader<'a, R> {
    inner: &'a mut R,
    crc: Crc32,
}

impl<R: Read> Read for CrcReader<'_, R> {
    fn read(&mut self, buf: &mut [u8]) -> io::Result<usize> {
        let n = self.inner.read(buf)?;
        self.crc.update(&buf[..n]);
        Ok(n)
    }
}

/// `Write` adapter folding everything it writes into a [`Crc32`].
struct CrcWriter<'a, W> {
    inner: &'a mut W,
    crc: Crc32,
}

impl<W: Write> Write for CrcWriter<'_, W> {
    fn write(&mut self, buf: &[u8]) -> io::Result<usize> {
        let n = self.inner.write(buf)?;
        self.crc.update(&buf[..n]);
        Ok(n)
    }

    fn flush(&mut self) -> io::Result<()> {
        self.inner.flush()
    }
}

fn bad_frame(what: &str) -> io::Error {
    io::Error::new(io::ErrorKind::InvalidData, format!("wire frame: {what}"))
}

fn read_array<const N: usize, Rd: Read>(r: &mut Rd) -> io::Result<[u8; N]> {
    let mut buf = [0u8; N];
    r.read_exact(&mut buf)?;
    Ok(buf)
}

fn read_string<Rd: Read>(r: &mut Rd) -> io::Result<String> {
    let len = u32::from_le_bytes(read_array(r)?) as usize;
    if len > MAX_HEADER_STRING {
        return Err(bad_frame("header string too long"));
    }
    let mut buf = vec![0u8; len];
    r.read_exact(&mut buf)?;
    String::from_utf8(buf).map_err(|_| bad_frame("header string not UTF-8"))
}

fn strategy_code(s: CopyStrategy) -> u8 {
    match s {
        CopyStrategy::BlobMemcpy => 0,
        CopyStrategy::FieldRuns => 1,
        CopyStrategy::FieldRunsPar => 2,
        CopyStrategy::FieldWise => 3,
    }
}

fn strategy_from_code(c: u8) -> Option<CopyStrategy> {
    match c {
        0 => Some(CopyStrategy::BlobMemcpy),
        1 => Some(CopyStrategy::FieldRuns),
        2 => Some(CopyStrategy::FieldRunsPar),
        3 => Some(CopyStrategy::FieldWise),
        _ => None,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::extents::{Dyn, Fix};
    use crate::mapping::aos::AoS;
    use crate::mapping::aosoa::AoSoA;
    use crate::mapping::soa::MultiBlob;

    crate::record! {
        pub struct P, mod p {
            pos: { x: f64, y: f64 },
            m: f32,
        }
    }

    fn fill<M: MemoryAccess<P>, S: BlobStorage>(v: &mut View<P, M, S>, n: usize) {
        for i in 0..n {
            v.set(&[i], p::pos::x, i as f64);
            v.set(&[i], p::pos::y, -(i as f64));
            v.set(&[i], p::m, (i * 2) as f32);
        }
    }

    fn check<M: MemoryAccess<P>, S: BlobStorage>(v: &View<P, M, S>, n: usize) {
        for i in 0..n {
            assert_eq!(v.get::<f64, _>(&[i], p::pos::x), i as f64);
            assert_eq!(v.get::<f64, _>(&[i], p::pos::y), -(i as f64));
            assert_eq!(v.get::<f32, _>(&[i], p::m), (i * 2) as f32);
        }
    }

    #[test]
    fn encode_strategy_tracks_source_layout() {
        let n = 24usize;
        // Canonical layout already: whole-blob memcpy.
        let mut a =
            alloc_view(SoA::<P, _, SingleBlob>::new((Dyn(n as u32),)), &HeapAlloc);
        fill(&mut a, n);
        assert_eq!(encode(&a).strategy, CopyStrategy::BlobMemcpy);
        // Contiguous runs: per-field memcpy.
        let mut b = alloc_view(SoA::<P, _, MultiBlob>::new((Dyn(n as u32),)), &HeapAlloc);
        fill(&mut b, n);
        assert_eq!(encode(&b).strategy, CopyStrategy::FieldRuns);
        let mut c = alloc_view(AoSoA::<P, _, 8>::new((Dyn(n as u32),)), &HeapAlloc);
        fill(&mut c, n);
        assert_eq!(encode(&c).strategy, CopyStrategy::FieldRuns);
        // Interleaved AoS: field-wise fallback.
        let mut d = alloc_view(AoS::<P, _>::new((Dyn(n as u32),)), &HeapAlloc);
        fill(&mut d, n);
        assert_eq!(encode(&d).strategy, CopyStrategy::FieldWise);
    }

    #[test]
    fn adopt_is_zero_relayout() {
        let n = 16usize;
        let mut src = alloc_view(SoA::<P, _>::new((Dyn(n as u32),)), &HeapAlloc);
        fill(&mut src, n);
        let msg = encode(&src);
        let payload = msg.payload.clone();
        let v = decode_adopt::<P, _>(msg, (Dyn(n as u32),)).unwrap();
        check(&v, n);
        // The adopted storage is the payload buffer, bytes untouched.
        assert_eq!(v.storage().blob(0), &payload[..]);
    }

    #[test]
    fn adopt_accepts_equal_static_extents() {
        // Fix and Dyn extents of equal value produce the same canonical
        // layout (fingerprints embed runtime values only).
        let mut src = alloc_view(SoA::<P, _>::new((Dyn(12u32),)), &HeapAlloc);
        fill(&mut src, 12);
        let v = decode_adopt::<P, _>(encode(&src), (Fix::<u32, 12>::new(),)).unwrap();
        check(&v, 12);
    }

    #[test]
    fn decode_streams_into_other_mappings() {
        let n = 20usize;
        let mut src = alloc_view(AoS::<P, _>::new((Dyn(n as u32),)), &HeapAlloc);
        fill(&mut src, n);
        let msg = encode(&src);

        let mut soa = alloc_view(SoA::<P, _>::new((Dyn(n as u32),)), &HeapAlloc);
        assert_eq!(decode_into(msg.clone(), &mut soa).unwrap(), CopyStrategy::FieldRuns);
        check(&soa, n);

        let mut aosoa = alloc_view(AoSoA::<P, _, 4>::new((Dyn(n as u32),)), &HeapAlloc);
        assert_eq!(decode_into(msg.clone(), &mut aosoa).unwrap(), CopyStrategy::FieldRuns);
        check(&aosoa, n);

        let mut aos = alloc_view(AoS::<P, _>::new((Dyn(n as u32),)), &HeapAlloc);
        assert_eq!(decode_into(msg, &mut aos).unwrap(), CopyStrategy::FieldWise);
        check(&aos, n);
    }

    #[test]
    fn parallel_decode_matches_serial() {
        let n = 512usize;
        let mut src = alloc_view(SoA::<P, _>::new((Dyn(n as u32),)), &HeapAlloc);
        fill(&mut src, n);
        let msg = encode_par(&src, 4);
        let mut dst = alloc_view(AoSoA::<P, _, 8>::new((Dyn(n as u32),)), &HeapAlloc);
        let strategy = decode_into_par(msg, &mut dst, 4).unwrap();
        assert!(matches!(strategy, CopyStrategy::FieldRuns | CopyStrategy::FieldRunsPar));
        check(&dst, n);
    }

    crate::record! {
        pub struct Q, mod q { a: f64 }
    }

    #[test]
    fn header_mismatches_are_rejected() {
        let mut src = alloc_view(SoA::<P, _>::new((Dyn(8u32),)), &HeapAlloc);
        fill(&mut src, 8);
        let msg = encode(&src);

        // Wrong extents.
        let mut dst = alloc_view(SoA::<P, _>::new((Dyn(9u32),)), &HeapAlloc);
        assert!(matches!(
            decode_into(msg.clone(), &mut dst),
            Err(WireError::Extents { .. })
        ));

        // Wrong record dimension.
        let mut other = alloc_view(SoA::<Q, _>::new((Dyn(8u32),)), &HeapAlloc);
        other.set(&[0], q::a, 1.0f64);
        assert!(matches!(
            decode_into(msg.clone(), &mut other),
            Err(WireError::Record { .. })
        ));

        // Corrupted fingerprint.
        let mut bad = msg.clone();
        bad.fingerprint = "AoS<lies>".into();
        assert!(matches!(
            decode_adopt::<P, _>(bad, (Dyn(8u32),)),
            Err(WireError::Fingerprint { .. })
        ));

        // Unknown version.
        let mut v3 = msg;
        v3.version = 3;
        assert!(matches!(decode_adopt::<P, _>(v3, (Dyn(8u32),)), Err(WireError::Version(3))));
    }

    #[test]
    fn framing_round_trips() {
        let mut src = alloc_view(SoA::<P, _>::new((Dyn(2u32), Dyn(3u32))), &HeapAlloc);
        for i in 0..2usize {
            for j in 0..3usize {
                src.set(&[i, j], p::pos::x, (i * 10 + j) as f64);
            }
        }
        let msg = encode(&src);
        let mut frame = Vec::new();
        msg.write_to(&mut frame).unwrap();
        assert_eq!(frame.len(), msg.frame_len());
        let back = WireMsg::read_from(&mut frame.as_slice()).unwrap();
        assert_eq!(back, msg);
        let v = decode_adopt::<P, _>(back, (Dyn(2u32), Dyn(3u32))).unwrap();
        for i in 0..2usize {
            for j in 0..3usize {
                assert_eq!(v.get::<f64, _>(&[i, j], p::pos::x), (i * 10 + j) as f64);
            }
        }
    }

    #[test]
    fn malformed_frames_are_invalid_data() {
        let mut src = alloc_view(SoA::<P, _>::new((Dyn(4u32),)), &HeapAlloc);
        fill(&mut src, 4);
        let mut frame = Vec::new();
        encode(&src).write_to(&mut frame).unwrap();

        // Truncation anywhere fails cleanly.
        for cut in [0, 3, 7, frame.len() - 1] {
            assert!(WireMsg::read_from(&mut &frame[..cut]).is_err());
        }
        // Bad magic.
        let mut bad = frame.clone();
        bad[0] = b'X';
        let err = WireMsg::read_from(&mut bad.as_slice()).unwrap_err();
        assert_eq!(err.kind(), io::ErrorKind::InvalidData);
        // Bad version.
        let mut bad = frame;
        bad[4] = 0xFF;
        assert!(WireMsg::read_from(&mut bad.as_slice()).is_err());
    }

    #[test]
    fn crc32_known_answers() {
        // IEEE check value plus the incremental-update identity.
        assert_eq!(crc32(b"123456789"), 0xCBF4_3926);
        assert_eq!(crc32(b""), 0);
        let mut c = Crc32::new();
        c.update(b"1234");
        c.update(b"56789");
        assert_eq!(c.finish(), 0xCBF4_3926);
    }

    #[test]
    fn payload_corruption_is_caught_before_decode() {
        let mut src = alloc_view(SoA::<P, _>::new((Dyn(6u32),)), &HeapAlloc);
        fill(&mut src, 6);
        let msg = encode(&src);
        let mut frame = Vec::new();
        msg.write_to(&mut frame).unwrap();

        // Flip one bit in every payload byte in turn: the CRC catches
        // each one with the typed Corrupt error, never a decode.
        let payload_start = frame.len() - 4 - msg.payload.len();
        for i in payload_start..frame.len() - 4 {
            let mut bad = frame.clone();
            bad[i] ^= 0x10;
            let err = WireMsg::read_from(&mut bad.as_slice()).unwrap_err();
            assert_eq!(err.kind(), io::ErrorKind::InvalidData);
            assert!(
                matches!(wire_error_in(&err), Some(WireError::Corrupt { .. })),
                "payload byte {i}: expected Corrupt, got {err:?}"
            );
        }
        // A corrupted stored checksum is equally fatal.
        let mut bad = frame.clone();
        let last = bad.len() - 1;
        bad[last] ^= 1;
        let err = WireMsg::read_from(&mut bad.as_slice()).unwrap_err();
        assert!(matches!(wire_error_in(&err), Some(WireError::Corrupt { .. })));
        // The pristine frame still parses.
        assert_eq!(WireMsg::read_from(&mut frame.as_slice()).unwrap(), msg);
    }

    #[test]
    fn garbage_blob_len_fails_without_huge_allocation() {
        // Hand-build a frame whose header claims an absurd payload
        // length and then ends: read_from must fail with EOF after
        // reading what's there — not allocate the claimed bytes.
        let mut frame = Vec::new();
        frame.extend_from_slice(&WIRE_MAGIC);
        frame.extend_from_slice(&WIRE_VERSION.to_le_bytes());
        frame.push(0); // strategy BlobMemcpy
        frame.push(1); // rank 1
        frame.extend_from_slice(&4u64.to_le_bytes());
        frame.extend_from_slice(&1u32.to_le_bytes());
        frame.push(b'R');
        frame.extend_from_slice(&1u32.to_le_bytes());
        frame.push(b'F');
        frame.extend_from_slice(&1u32.to_le_bytes()); // blob_count
        frame.extend_from_slice(&(u64::MAX).to_le_bytes()); // blob_len
        let err = WireMsg::read_from(&mut frame.as_slice()).unwrap_err();
        let ok = err.kind() == io::ErrorKind::UnexpectedEof
            || err.kind() == io::ErrorKind::InvalidData;
        assert!(ok, "unexpected error kind: {err:?}");
    }
}
