//! Layout-aware view transport: ship views across process boundaries.
//!
//! The paper's core claim — access is decoupled from layout — holds
//! across a wire as well as across a function call. This module defines a
//! versioned wire format for views: a header describing the record
//! dimension, the array extents, the payload mapping's identity
//! (fingerprint) and the blob geometry, followed by the raw payload
//! bytes. The payload always uses the **canonical wire layout**
//! [`WireMapping`] (packed field-major single blob: SoA single-blob,
//! row-major, full mask), so any two endpoints agree on the byte meaning
//! without exchanging mapping *types* — only the header's identity
//! strings are compared.
//!
//! - **Encode** ([`encode`] / [`encode_par`]) relayouts the source view
//!   into the canonical payload with the layout-aware copy engine
//!   ([`crate::copy::copy_view`]): memcpy-grade
//!   [`contiguous_run`](crate::mapping::Mapping::contiguous_run) field
//!   runs where the source layout permits (SoA, AoSoA), whole-blob
//!   memcpy when the source *is* the canonical layout, and the
//!   field-wise fallback for computed/bit-packed mappings. The strategy
//!   used is recorded in the message for observability.
//! - **Decode** either **adopts** the payload bytes directly as view
//!   storage ([`decode_adopt`]: same mapping ⇒ zero relayout, zero
//!   copy), or **streams** them into the receiver's preferred mapping
//!   ([`decode_into`] / [`decode_into_par`]) via the same copy engine —
//!   the receiver's layout may differ arbitrarily from the sender's.
//!
//! [`WireMsg::write_to`] / [`WireMsg::read_from`] frame messages over any
//! `Write`/`Read` transport (the distributed n-body example uses a Unix
//! socket; see `examples/distributed_nbody.rs` and `docs/SERVING.md` for
//! the byte-level format specification).
//!
//! **Integrity (version 2):** every frame ends in a CRC-32 ([`crc32`],
//! IEEE polynomial, hand-rolled — no crates) over all preceding frame
//! bytes, header included. [`WireMsg::read_from`] verifies the checksum
//! before any decode touches the payload; a mismatch surfaces as a typed
//! [`WireError::Corrupt`] (retrievable from the `io::Error` via
//! [`wire_error_in`]), so a flipped bit in transit becomes a clean retry
//! instead of silently wrong physics. Truncated or garbage frames fail
//! with bounded allocation — a declared payload beyond [`MAX_PAYLOAD`]
//! is refused *before any allocation* with a typed
//! [`WireError::TooLarge`] — see `docs/SERVING.md` §5 "Failure model".
//!
//! **Control frames:** alongside the view-payload frame, the serving
//! tier speaks a small fixed set of CRC-protected control/reply frames
//! ([`CtrlFrame`], magic `"LLWc"`): job submission and its typed
//! outcomes — results, backpressure (`QueueFull` carrying the ingest
//! `retry_after` hint in milliseconds), quota rejection, corruption
//! reports, drain notices, accept-time shedding, and deadline
//! disconnects. Byte spec in `docs/SERVING.md` §6.

use std::io::{self, Read, Write};

use crate::blob::{alloc_view, BlobStorage, HeapAlloc, HeapStorage};
use crate::copy::{copy_view, copy_view_par, CopyStrategy};
use crate::extents::{Extents, RowMajor};
use crate::mapping::soa::{SingleBlob, SoA};
use crate::mapping::{Mapping, MemoryAccess};
use crate::record::RecordDim;
use crate::view::View;

/// Wire format version this build speaks; [`WireMsg::read_from`] rejects
/// others. Version 2 appended the trailing frame CRC-32 — v1 frames are
/// refused outright rather than trusted unchecked.
pub const WIRE_VERSION: u16 = 2;

/// Frame magic ("LLAMA Wire") guarding against misaligned streams.
pub const WIRE_MAGIC: [u8; 4] = *b"LLWv";

/// Control-frame magic ("LLAMA Wire control") — distinguishes the
/// serving tier's [`CtrlFrame`]s from view-payload frames on the same
/// stream family.
pub const CTRL_MAGIC: [u8; 4] = *b"LLWc";

/// Cap on the *declared* payload length [`WireMsg::read_from`] accepts
/// (1 GiB). A header claiming more is rejected with a typed
/// [`WireError::TooLarge`] **before any payload allocation** — a
/// corrupt or hostile length prefix can neither reserve absurd memory
/// nor drag the reader through a gigabyte-scale drain.
pub const MAX_PAYLOAD: usize = 1 << 30;

// ---------------------------------------------------------------------------
// CRC-32 (IEEE), hand-rolled — same zero-dependency pattern as `numa.rs`
// ---------------------------------------------------------------------------

/// Table for the reflected IEEE CRC-32 (polynomial `0xEDB88320`), built
/// at compile time.
const CRC_TABLE: [u32; 256] = {
    let mut table = [0u32; 256];
    let mut i = 0;
    while i < 256 {
        let mut c = i as u32;
        let mut k = 0;
        while k < 8 {
            c = if c & 1 != 0 { 0xEDB8_8320 ^ (c >> 1) } else { c >> 1 };
            k += 1;
        }
        table[i] = c;
        i += 1;
    }
    table
};

/// Incremental CRC-32 (IEEE / zlib variant: init `0xFFFFFFFF`, reflected,
/// final xor). Known answer: `crc32(b"123456789") == 0xCBF43926`.
#[derive(Clone, Copy, Debug)]
pub struct Crc32 {
    state: u32,
}

impl Crc32 {
    /// Fresh checksum state.
    pub fn new() -> Crc32 {
        Crc32 { state: 0xFFFF_FFFF }
    }

    /// Fold `bytes` into the checksum.
    #[inline]
    pub fn update(&mut self, bytes: &[u8]) {
        let mut s = self.state;
        for &b in bytes {
            s = CRC_TABLE[((s ^ u32::from(b)) & 0xFF) as usize] ^ (s >> 8);
        }
        self.state = s;
    }

    /// The checksum of everything folded in so far.
    pub fn finish(self) -> u32 {
        self.state ^ 0xFFFF_FFFF
    }
}

impl Default for Crc32 {
    fn default() -> Self {
        Crc32::new()
    }
}

/// One-shot CRC-32 of `bytes`.
pub fn crc32(bytes: &[u8]) -> u32 {
    let mut c = Crc32::new();
    c.update(bytes);
    c.finish()
}

/// The canonical wire payload layout: every field's values packed
/// contiguously, field regions concatenated in record order into one
/// blob, row-major linearization, all fields present.
///
/// Chosen because it is (a) unambiguous given only the record dimension
/// and the extents — no padding, no interleaving parameters — and (b)
/// run-friendly on both ends: every mapping with byte-contiguity copies
/// to/from it as whole-field memcpy runs.
pub type WireMapping<R, E> = SoA<R, E, SingleBlob, RowMajor>;

/// A decoded-header + payload wire message.
///
/// Produced by [`encode`]/[`encode_par`] or [`WireMsg::read_from`];
/// consumed by [`decode_adopt`]/[`decode_into`] or
/// [`WireMsg::write_to`].
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct WireMsg {
    /// Wire format version ([`WIRE_VERSION`]).
    pub version: u16,
    /// Record-dimension descriptor ([`record_descriptor`]): name plus
    /// every flattened field as `path:type`. Both ends must agree.
    pub record: String,
    /// Layout fingerprint of the payload mapping
    /// ([`crate::mapping::Mapping::fingerprint`]); receivers adopt only
    /// on an exact match.
    pub fingerprint: String,
    /// Runtime extent of each array dimension, outermost first.
    pub extents: Vec<u64>,
    /// Copy strategy the encoder used (observability: asserts in tests
    /// and benches that the memcpy-grade path fired where expected).
    pub strategy: CopyStrategy,
    /// The payload: the canonical wire blob's bytes.
    pub payload: Vec<u8>,
}

/// Decode-side validation failure: the message header does not match
/// what the receiver asked the payload to be.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum WireError {
    /// Message version differs from [`WIRE_VERSION`].
    Version(u16),
    /// Record-dimension descriptors differ (incompatible field sets).
    Record {
        /// Descriptor the receiver expects.
        expected: String,
        /// Descriptor the message carries.
        got: String,
    },
    /// Extents differ (per-dimension values or rank).
    Extents {
        /// Extents the receiver expects.
        expected: Vec<u64>,
        /// Extents the message carries.
        got: Vec<u64>,
    },
    /// Mapping fingerprints differ — the payload is not the layout the
    /// receiver tried to adopt.
    Fingerprint {
        /// Fingerprint the receiver expects.
        expected: String,
        /// Fingerprint the message carries.
        got: String,
    },
    /// Payload byte count does not match the blob geometry the mapping
    /// requires for the stated extents.
    Geometry {
        /// Bytes the mapping requires.
        expected: usize,
        /// Bytes the message carries.
        got: usize,
    },
    /// Frame checksum mismatch: the bytes were corrupted in transit.
    /// Raised by [`WireMsg::read_from`] **before** any decode touches
    /// the payload; retrieve it from the `io::Error` with
    /// [`wire_error_in`].
    Corrupt {
        /// CRC-32 the receiver computed over the frame bytes.
        expected: u32,
        /// CRC-32 the frame carried.
        got: u32,
    },
    /// Header declares a payload longer than [`MAX_PAYLOAD`]. Raised by
    /// [`WireMsg::read_from`] before any payload allocation.
    TooLarge {
        /// Payload length the header declared.
        declared: u64,
        /// The cap ([`MAX_PAYLOAD`]).
        cap: u64,
    },
}

impl std::fmt::Display for WireError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            WireError::Version(v) => {
                write!(f, "wire version {v} (this build speaks {WIRE_VERSION})")
            }
            WireError::Record { expected, got } => {
                write!(f, "record mismatch: expected {expected:?}, got {got:?}")
            }
            WireError::Extents { expected, got } => {
                write!(f, "extents mismatch: expected {expected:?}, got {got:?}")
            }
            WireError::Fingerprint { expected, got } => {
                write!(f, "layout mismatch: expected {expected:?}, got {got:?}")
            }
            WireError::Geometry { expected, got } => {
                write!(f, "payload geometry: mapping needs {expected} bytes, message has {got}")
            }
            WireError::Corrupt { expected, got } => {
                write!(
                    f,
                    "frame corrupt: computed crc32 {expected:#010x}, frame carries {got:#010x}"
                )
            }
            WireError::TooLarge { declared, cap } => {
                write!(f, "declared payload length {declared} exceeds the {cap}-byte cap")
            }
        }
    }
}

impl std::error::Error for WireError {}

/// The typed [`WireError`] inside an `io::Error`, if it carries one.
///
/// [`WireMsg::read_from`] reports checksum failures as
/// `io::ErrorKind::InvalidData` wrapping a [`WireError::Corrupt`]; use
/// this to tell in-transit corruption (worth a retry against a live
/// peer) apart from protocol violations and plain transport failures:
///
/// ```
/// # use llama::transport::{wire_error_in, WireError};
/// # let err = std::io::Error::new(
/// #     std::io::ErrorKind::InvalidData,
/// #     WireError::Corrupt { expected: 1, got: 2 },
/// # );
/// if let Some(WireError::Corrupt { .. }) = wire_error_in(&err) {
///     // count it, drop the peer, re-dispatch the work
/// }
/// ```
pub fn wire_error_in(e: &io::Error) -> Option<&WireError> {
    e.get_ref()?.downcast_ref::<WireError>()
}

/// The record-dimension descriptor shipped in every message header:
/// record name plus each flattened field as `dotted.path:type`, e.g.
/// `Particle{pos.x:f32,pos.y:f32,...,mass:f32}`. Two record dimensions
/// with equal descriptors have identical flattened field sets, so their
/// canonical wire payloads are interchangeable.
pub fn record_descriptor<R: RecordDim>() -> String {
    let fields: Vec<String> =
        R::FIELDS.iter().map(|f| format!("{}:{}", f.dotted(), f.ty.name())).collect();
    format!("{}{{{}}}", R::NAME, fields.join(","))
}

fn extent_values<E: Extents>(e: &E) -> Vec<u64> {
    (0..E::RANK).map(|d| e.extent(d) as u64).collect()
}

/// Encode `src` into a wire message, relayouting into the canonical
/// [`WireMapping`] payload via the layout-aware copy engine.
///
/// The strategy the engine picked is recorded in the message:
/// `BlobMemcpy` when `src` already is the canonical layout, `FieldRuns`
/// when every field has [`contiguous_run`] byte-contiguity (SoA, AoSoA),
/// `FieldWise` otherwise (AoS interleaving, computed mappings).
///
/// [`contiguous_run`]: crate::mapping::Mapping::contiguous_run
pub fn encode<R, M, S>(src: &View<R, M, S>) -> WireMsg
where
    R: RecordDim,
    M: MemoryAccess<R>,
    S: BlobStorage,
{
    let e = *src.extents();
    let mut wire = alloc_view(WireMapping::<R, M::Extents>::new(e), &HeapAlloc);
    let strategy = copy_view(src, &mut wire);
    finish_encode(wire, &e, strategy)
}

/// [`encode`] with the relayout fanned over up to `threads` workers
/// ([`crate::copy::copy_view_par`]) — for large views whose source
/// layout has contiguous runs.
pub fn encode_par<R, M, S>(src: &View<R, M, S>, threads: usize) -> WireMsg
where
    R: RecordDim,
    M: MemoryAccess<R>,
    S: BlobStorage + Sync,
{
    let e = *src.extents();
    let mut wire = alloc_view(WireMapping::<R, M::Extents>::new(e), &HeapAlloc);
    let strategy = copy_view_par(src, &mut wire, threads);
    finish_encode(wire, &e, strategy)
}

fn finish_encode<R, E>(
    wire: View<R, WireMapping<R, E>, HeapStorage>,
    e: &E,
    strategy: CopyStrategy,
) -> WireMsg
where
    R: RecordDim,
    E: Extents,
{
    let fingerprint = wire.mapping().fingerprint();
    let extents = extent_values(e);
    let (_, storage) = wire.into_parts();
    let mut blobs = storage.into_blobs();
    let payload = if blobs.is_empty() { Vec::new() } else { blobs.swap_remove(0) };
    WireMsg { version: WIRE_VERSION, record: record_descriptor::<R>(), fingerprint, extents, strategy, payload }
}

/// Adopt the payload bytes directly as the storage of a
/// [`WireMapping`]-mapped view — **zero relayout, zero copy** (the
/// `Vec<u8>` moves into the view).
///
/// `extents` is the receiver's extents value (any extents type with the
/// same runtime values works: the canonical layout depends only on the
/// values, and [`fingerprint`](crate::mapping::Mapping::fingerprint)s
/// agree across `Fix`/`Dyn` dimensions of equal extent). Fails if the
/// header's record descriptor, extents, layout fingerprint, or payload
/// geometry don't match.
pub fn decode_adopt<R, E>(
    msg: WireMsg,
    extents: E,
) -> Result<View<R, WireMapping<R, E>, HeapStorage>, WireError>
where
    R: RecordDim,
    E: Extents,
{
    let mapping = WireMapping::<R, E>::new(extents);
    validate::<R, _>(&msg, &mapping)?;
    let need = mapping.blob_size(0);
    if msg.payload.len() < need {
        return Err(WireError::Geometry { expected: need, got: msg.payload.len() });
    }
    Ok(View::from_parts(mapping, HeapStorage::from_blobs(vec![msg.payload])))
}

/// Stream the payload into `dst`, whatever its mapping — the relayout
/// path of the receive side. Returns the copy strategy used (memcpy
/// field runs into SoA/AoSoA destinations, field-wise into
/// computed/interleaved ones).
///
/// The wire-side view is built over the moved payload bytes (no copy
/// before the relayout itself). Fails on any header mismatch against
/// `dst`'s record/extents.
pub fn decode_into<R, MD, SD>(
    msg: WireMsg,
    dst: &mut View<R, MD, SD>,
) -> Result<CopyStrategy, WireError>
where
    R: RecordDim,
    MD: MemoryAccess<R>,
    SD: BlobStorage,
{
    let wire = decode_adopt::<R, MD::Extents>(msg, *dst.extents())?;
    Ok(copy_view(&wire, dst))
}

/// [`decode_into`] with the relayout fanned over up to `threads` workers
/// ([`crate::copy::copy_view_par`]).
pub fn decode_into_par<R, MD, SD>(
    msg: WireMsg,
    dst: &mut View<R, MD, SD>,
    threads: usize,
) -> Result<CopyStrategy, WireError>
where
    R: RecordDim,
    MD: MemoryAccess<R>,
    SD: BlobStorage + Send + Sync,
{
    let wire = decode_adopt::<R, MD::Extents>(msg, *dst.extents())?;
    Ok(copy_view_par(&wire, dst, threads))
}

/// Validate the header against a receiver-side canonical mapping.
fn validate<R, E>(msg: &WireMsg, mapping: &WireMapping<R, E>) -> Result<(), WireError>
where
    R: RecordDim,
    E: Extents,
{
    if msg.version != WIRE_VERSION {
        return Err(WireError::Version(msg.version));
    }
    let expected = record_descriptor::<R>();
    if msg.record != expected {
        return Err(WireError::Record { expected, got: msg.record.clone() });
    }
    let extents = extent_values(mapping.extents());
    if msg.extents != extents {
        return Err(WireError::Extents { expected: extents, got: msg.extents.clone() });
    }
    let fp = mapping.fingerprint();
    if msg.fingerprint != fp {
        return Err(WireError::Fingerprint { expected: fp, got: msg.fingerprint.clone() });
    }
    Ok(())
}

// ---------------------------------------------------------------------------
// Framing
// ---------------------------------------------------------------------------

/// Cap on header strings accepted by [`WireMsg::read_from`], so a
/// corrupt length prefix cannot drive an unbounded allocation.
const MAX_HEADER_STRING: usize = 1 << 20;
const MAX_RANK: usize = crate::view::MAX_RANK;

impl WireMsg {
    /// Number of records the extents span (saturating — a garbage
    /// header with overflowing extents must not wrap into a small,
    /// plausible-looking count).
    pub fn record_count(&self) -> usize {
        let n = self.extents.iter().fold(1u64, |acc, &e| acc.saturating_mul(e));
        usize::try_from(n).unwrap_or(usize::MAX)
    }

    /// Serialized frame size in bytes (header + payload + trailing
    /// CRC-32).
    pub fn frame_len(&self) -> usize {
        4 + 2 + 1 + 1
            + self.extents.len() * 8
            + 4
            + self.record.len()
            + 4
            + self.fingerprint.len()
            + 4
            + 8
            + self.payload.len()
            + 4
    }

    /// Write one framed message.
    ///
    /// Frame layout (all integers little-endian):
    ///
    /// ```text
    /// magic            4 bytes  "LLWv"
    /// version          u16
    /// strategy         u8       CopyStrategy the encoder used
    /// rank             u8
    /// extents          rank × u64
    /// record_len       u32      then that many UTF-8 bytes
    /// fingerprint_len  u32      then that many UTF-8 bytes
    /// blob_count       u32      payload blob geometry (always 1)
    /// blob_len         u64      per blob
    /// payload          blob_len bytes
    /// crc32            u32      CRC-32 of every preceding frame byte
    /// ```
    pub fn write_to<W: Write>(&self, w: &mut W) -> io::Result<()> {
        let crc = {
            let mut cw = CrcWriter { inner: &mut *w, crc: Crc32::new() };
            cw.write_all(&WIRE_MAGIC)?;
            cw.write_all(&self.version.to_le_bytes())?;
            cw.write_all(&[strategy_code(self.strategy), self.extents.len() as u8])?;
            for &e in &self.extents {
                cw.write_all(&e.to_le_bytes())?;
            }
            cw.write_all(&(self.record.len() as u32).to_le_bytes())?;
            cw.write_all(self.record.as_bytes())?;
            cw.write_all(&(self.fingerprint.len() as u32).to_le_bytes())?;
            cw.write_all(self.fingerprint.as_bytes())?;
            cw.write_all(&1u32.to_le_bytes())?;
            cw.write_all(&(self.payload.len() as u64).to_le_bytes())?;
            cw.write_all(&self.payload)?;
            cw.crc.finish()
        };
        w.write_all(&crc.to_le_bytes())
    }

    /// Read one framed message (see [`write_to`](WireMsg::write_to) for
    /// the layout), verifying the trailing CRC-32 **before returning**
    /// — corrupted frames never reach a decoder. Malformed frames — bad
    /// magic, unknown version or strategy, oversized header fields,
    /// unsupported blob geometry — fail with
    /// [`io::ErrorKind::InvalidData`]; checksum mismatches additionally
    /// carry a typed [`WireError::Corrupt`] (see [`wire_error_in`]).
    /// Truncations fail with `UnexpectedEof`. Allocation stays bounded
    /// on garbage: header strings are capped at 1 MiB up front, a
    /// declared payload beyond [`MAX_PAYLOAD`] is refused with a typed
    /// [`WireError::TooLarge`] *before any allocation*, and within the
    /// cap the payload buffer grows with bytes actually read, so a
    /// corrupt `blob_len` cannot drive an unbounded upfront allocation.
    pub fn read_from<Rd: Read>(r: &mut Rd) -> io::Result<WireMsg> {
        let mut cr = CrcReader { inner: &mut *r, crc: Crc32::new() };
        let mut magic = [0u8; 4];
        cr.read_exact(&mut magic)?;
        if magic != WIRE_MAGIC {
            return Err(bad_frame("bad magic"));
        }
        let version = u16::from_le_bytes(read_array(&mut cr)?);
        if version != WIRE_VERSION {
            return Err(bad_frame("unsupported wire version"));
        }
        let [strategy, rank] = read_array(&mut cr)?;
        let strategy = strategy_from_code(strategy).ok_or_else(|| bad_frame("bad strategy"))?;
        let rank = rank as usize;
        if rank == 0 || rank > MAX_RANK {
            return Err(bad_frame("bad rank"));
        }
        let mut extents = Vec::with_capacity(rank);
        for _ in 0..rank {
            extents.push(u64::from_le_bytes(read_array(&mut cr)?));
        }
        let record = read_string(&mut cr)?;
        let fingerprint = read_string(&mut cr)?;
        let blob_count = u32::from_le_bytes(read_array(&mut cr)?);
        if blob_count != 1 {
            return Err(bad_frame("unsupported blob geometry"));
        }
        let declared = u64::from_le_bytes(read_array(&mut cr)?);
        if declared > MAX_PAYLOAD as u64 {
            // Typed refusal before any payload allocation: a corrupt or
            // hostile length prefix never reserves memory for itself.
            return Err(io::Error::new(
                io::ErrorKind::InvalidData,
                WireError::TooLarge { declared, cap: MAX_PAYLOAD as u64 },
            ));
        }
        let blob_len = declared as usize;
        // Pre-reserve at most the header-string cap; beyond that the
        // buffer grows only as bytes actually arrive, so a garbage
        // length cannot allocate terabytes before the EOF shows up.
        let mut payload = Vec::with_capacity(blob_len.min(MAX_HEADER_STRING));
        let got = (&mut cr).take(blob_len as u64).read_to_end(&mut payload)?;
        if got < blob_len {
            return Err(io::Error::new(
                io::ErrorKind::UnexpectedEof,
                "wire frame: payload truncated",
            ));
        }
        let computed = cr.crc.finish();
        let stored = u32::from_le_bytes(read_array(r)?);
        if computed != stored {
            return Err(io::Error::new(
                io::ErrorKind::InvalidData,
                WireError::Corrupt { expected: computed, got: stored },
            ));
        }
        Ok(WireMsg { version, record, fingerprint, extents, strategy, payload })
    }
}

/// `Read` adapter folding everything it reads into a [`Crc32`].
struct CrcReader<'a, R> {
    inner: &'a mut R,
    crc: Crc32,
}

impl<R: Read> Read for CrcReader<'_, R> {
    fn read(&mut self, buf: &mut [u8]) -> io::Result<usize> {
        let n = self.inner.read(buf)?;
        self.crc.update(&buf[..n]);
        Ok(n)
    }
}

/// `Write` adapter folding everything it writes into a [`Crc32`].
struct CrcWriter<'a, W> {
    inner: &'a mut W,
    crc: Crc32,
}

impl<W: Write> Write for CrcWriter<'_, W> {
    fn write(&mut self, buf: &[u8]) -> io::Result<usize> {
        let n = self.inner.write(buf)?;
        self.crc.update(&buf[..n]);
        Ok(n)
    }

    fn flush(&mut self) -> io::Result<()> {
        self.inner.flush()
    }
}

fn bad_frame(what: &str) -> io::Error {
    io::Error::new(io::ErrorKind::InvalidData, format!("wire frame: {what}"))
}

fn read_array<const N: usize, Rd: Read>(r: &mut Rd) -> io::Result<[u8; N]> {
    let mut buf = [0u8; N];
    r.read_exact(&mut buf)?;
    Ok(buf)
}

fn read_string<Rd: Read>(r: &mut Rd) -> io::Result<String> {
    let len = u32::from_le_bytes(read_array(r)?) as usize;
    if len > MAX_HEADER_STRING {
        return Err(bad_frame("header string too long"));
    }
    let mut buf = vec![0u8; len];
    r.read_exact(&mut buf)?;
    String::from_utf8(buf).map_err(|_| bad_frame("header string not UTF-8"))
}

fn strategy_code(s: CopyStrategy) -> u8 {
    match s {
        CopyStrategy::BlobMemcpy => 0,
        CopyStrategy::FieldRuns => 1,
        CopyStrategy::FieldRunsPar => 2,
        CopyStrategy::FieldWise => 3,
    }
}

fn strategy_from_code(c: u8) -> Option<CopyStrategy> {
    match c {
        0 => Some(CopyStrategy::BlobMemcpy),
        1 => Some(CopyStrategy::FieldRuns),
        2 => Some(CopyStrategy::FieldRunsPar),
        3 => Some(CopyStrategy::FieldWise),
        _ => None,
    }
}

// ---------------------------------------------------------------------------
// Control frames (serving tier)
// ---------------------------------------------------------------------------

/// Which deadline a [`CtrlFrame::TimedOut`] disconnect reports.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TimeoutPhase {
    /// No frame in progress: the connection sent nothing for the idle
    /// budget and was evicted.
    Idle,
    /// A frame was started but not finished within the partial-frame
    /// budget (slow-loris protection).
    MidFrame,
}

impl TimeoutPhase {
    fn code(self) -> u8 {
        match self {
            TimeoutPhase::Idle => 0,
            TimeoutPhase::MidFrame => 1,
        }
    }

    fn from_code(c: u8) -> Option<TimeoutPhase> {
        match c {
            0 => Some(TimeoutPhase::Idle),
            1 => Some(TimeoutPhase::MidFrame),
            _ => None,
        }
    }
}

impl std::fmt::Display for TimeoutPhase {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            TimeoutPhase::Idle => write!(f, "idle"),
            TimeoutPhase::MidFrame => write!(f, "mid-frame"),
        }
    }
}

/// One serving-tier control/reply frame (magic [`CTRL_MAGIC`]).
///
/// These carry the coordinator's job protocol — submission and every
/// typed outcome — across a process boundary, so failures that today
/// die at the edge (the ingest `retry_after` hint, quota rejections,
/// corruption detection, drain notices) reach the client as data
/// instead of a silent close. Fields are deliberately primitive
/// (layout/backend as `u8` codes, durations as integer ns/ms, floats as
/// IEEE-754 bit patterns) so the transport layer stays independent of
/// the coordinator's types; `llama::serve` owns the mapping.
///
/// Frame layout (all integers little-endian):
///
/// ```text
/// magic     4 bytes  "LLWc"
/// version   u16      WIRE_VERSION
/// kind      u8       variant discriminant (0..=7)
/// body      variant-specific fixed fields, in declaration order
/// crc32     u32      CRC-32 of every preceding frame byte
/// ```
///
/// Variable-length fields (the result's error string) are `u32` length
/// + bytes, capped like header strings. A CRC mismatch surfaces as a
/// typed [`WireError::Corrupt`] via [`wire_error_in`], exactly like
/// view frames. Byte-level spec: `docs/SERVING.md` §6.
#[derive(Clone, Debug, PartialEq)]
pub enum CtrlFrame {
    /// Client → server: run one job.
    Submit {
        /// Client identity for per-client quota accounting.
        client: u64,
        /// Layout code (`serve` maps to `coordinator::Layout`).
        layout: u8,
        /// Backend code (`serve` maps to `coordinator::Backend`).
        backend: u8,
        /// Particle count.
        n: u64,
        /// Simulation steps.
        steps: u64,
        /// Deterministic init seed.
        seed: u64,
        /// Worker thread budget (0 = serial).
        threads: u32,
    },
    /// Server → client: the job finished (successfully or not — a
    /// non-empty `error` is the job's typed failure after retries).
    Result {
        /// Job id the server assigned at admission.
        id: u64,
        /// Execution attempts the coordinator used (retries + 1).
        attempts: u32,
        /// Threads the job ran with.
        threads: u32,
        /// Execution wall-clock, nanoseconds.
        exec_ns: u64,
        /// Queue wait, nanoseconds.
        queue_ns: u64,
        /// Energy drift (bit-exact IEEE-754 round trip).
        energy_drift: f64,
        /// Throughput in steps/s (bit-exact IEEE-754 round trip).
        steps_per_sec: f64,
        /// Job error after all retries; empty = success.
        error: String,
    },
    /// Server → client: ingestion queue full; retry after the hinted
    /// backoff (the `ingest` retry-after estimate, milliseconds).
    QueueFull {
        /// Suggested client backoff before resubmitting, ms (≥ 1).
        retry_after_ms: u64,
    },
    /// Server → client: this client is at its per-client queue quota.
    QuotaExceeded {
        /// The client id that exceeded its quota.
        client: u64,
    },
    /// Server → client: your last frame failed CRC or was malformed;
    /// `expected`/`got` echo the checksums when known (`0, 0` for
    /// framing-level garbage such as a bad magic). The server closes
    /// the connection after sending this — the stream may be
    /// desynchronized.
    Corrupt {
        /// CRC-32 the server computed.
        expected: u32,
        /// CRC-32 the frame carried.
        got: u32,
    },
    /// Server → client: the server is draining (or closed) and accepts
    /// no new work. Terminal for this server instance.
    Draining,
    /// Server → client, at accept time: the connection cap is reached;
    /// the connection is being shed. Reconnect after the hint.
    Shed {
        /// Suggested client backoff before reconnecting, ms.
        retry_after_ms: u64,
    },
    /// Server → client: a connection deadline expired ([`TimeoutPhase`]).
    /// The server closes the connection after sending this.
    TimedOut {
        /// Which deadline fired.
        phase: TimeoutPhase,
    },
}

const K_SUBMIT: u8 = 0;
const K_RESULT: u8 = 1;
const K_QUEUE_FULL: u8 = 2;
const K_QUOTA_EXCEEDED: u8 = 3;
const K_CORRUPT: u8 = 4;
const K_DRAINING: u8 = 5;
const K_SHED: u8 = 6;
const K_TIMED_OUT: u8 = 7;

impl CtrlFrame {
    /// The frame's wire discriminant.
    pub fn kind_code(&self) -> u8 {
        match self {
            CtrlFrame::Submit { .. } => K_SUBMIT,
            CtrlFrame::Result { .. } => K_RESULT,
            CtrlFrame::QueueFull { .. } => K_QUEUE_FULL,
            CtrlFrame::QuotaExceeded { .. } => K_QUOTA_EXCEEDED,
            CtrlFrame::Corrupt { .. } => K_CORRUPT,
            CtrlFrame::Draining => K_DRAINING,
            CtrlFrame::Shed { .. } => K_SHED,
            CtrlFrame::TimedOut { .. } => K_TIMED_OUT,
        }
    }

    /// Write one framed control message (layout in the type docs).
    pub fn write_to<W: Write>(&self, w: &mut W) -> io::Result<()> {
        let crc = {
            let mut cw = CrcWriter { inner: &mut *w, crc: Crc32::new() };
            cw.write_all(&CTRL_MAGIC)?;
            cw.write_all(&WIRE_VERSION.to_le_bytes())?;
            cw.write_all(&[self.kind_code()])?;
            match self {
                CtrlFrame::Submit { client, layout, backend, n, steps, seed, threads } => {
                    cw.write_all(&client.to_le_bytes())?;
                    cw.write_all(&[*layout, *backend])?;
                    cw.write_all(&n.to_le_bytes())?;
                    cw.write_all(&steps.to_le_bytes())?;
                    cw.write_all(&seed.to_le_bytes())?;
                    cw.write_all(&threads.to_le_bytes())?;
                }
                CtrlFrame::Result {
                    id,
                    attempts,
                    threads,
                    exec_ns,
                    queue_ns,
                    energy_drift,
                    steps_per_sec,
                    error,
                } => {
                    cw.write_all(&id.to_le_bytes())?;
                    cw.write_all(&attempts.to_le_bytes())?;
                    cw.write_all(&threads.to_le_bytes())?;
                    cw.write_all(&exec_ns.to_le_bytes())?;
                    cw.write_all(&queue_ns.to_le_bytes())?;
                    cw.write_all(&energy_drift.to_bits().to_le_bytes())?;
                    cw.write_all(&steps_per_sec.to_bits().to_le_bytes())?;
                    cw.write_all(&(error.len() as u32).to_le_bytes())?;
                    cw.write_all(error.as_bytes())?;
                }
                CtrlFrame::QueueFull { retry_after_ms } | CtrlFrame::Shed { retry_after_ms } => {
                    cw.write_all(&retry_after_ms.to_le_bytes())?;
                }
                CtrlFrame::QuotaExceeded { client } => {
                    cw.write_all(&client.to_le_bytes())?;
                }
                CtrlFrame::Corrupt { expected, got } => {
                    cw.write_all(&expected.to_le_bytes())?;
                    cw.write_all(&got.to_le_bytes())?;
                }
                CtrlFrame::Draining => {}
                CtrlFrame::TimedOut { phase } => {
                    cw.write_all(&[phase.code()])?;
                }
            }
            cw.crc.finish()
        };
        w.write_all(&crc.to_le_bytes())
    }

    /// Read one framed control message, verifying the trailing CRC-32
    /// before returning. Error taxonomy matches
    /// [`WireMsg::read_from`]: malformed frames are
    /// [`io::ErrorKind::InvalidData`], checksum mismatches carry a
    /// typed [`WireError::Corrupt`], truncations are `UnexpectedEof`.
    pub fn read_from<Rd: Read>(r: &mut Rd) -> io::Result<CtrlFrame> {
        let mut cr = CrcReader { inner: &mut *r, crc: Crc32::new() };
        let mut magic = [0u8; 4];
        cr.read_exact(&mut magic)?;
        if magic != CTRL_MAGIC {
            return Err(bad_frame("bad control magic"));
        }
        let version = u16::from_le_bytes(read_array(&mut cr)?);
        if version != WIRE_VERSION {
            return Err(bad_frame("unsupported wire version"));
        }
        let [kind] = read_array(&mut cr)?;
        let frame = match kind {
            K_SUBMIT => {
                let client = u64::from_le_bytes(read_array(&mut cr)?);
                let [layout, backend] = read_array(&mut cr)?;
                let n = u64::from_le_bytes(read_array(&mut cr)?);
                let steps = u64::from_le_bytes(read_array(&mut cr)?);
                let seed = u64::from_le_bytes(read_array(&mut cr)?);
                let threads = u32::from_le_bytes(read_array(&mut cr)?);
                CtrlFrame::Submit { client, layout, backend, n, steps, seed, threads }
            }
            K_RESULT => {
                let id = u64::from_le_bytes(read_array(&mut cr)?);
                let attempts = u32::from_le_bytes(read_array(&mut cr)?);
                let threads = u32::from_le_bytes(read_array(&mut cr)?);
                let exec_ns = u64::from_le_bytes(read_array(&mut cr)?);
                let queue_ns = u64::from_le_bytes(read_array(&mut cr)?);
                let energy_drift = f64::from_bits(u64::from_le_bytes(read_array(&mut cr)?));
                let steps_per_sec = f64::from_bits(u64::from_le_bytes(read_array(&mut cr)?));
                let error = read_string(&mut cr)?;
                CtrlFrame::Result {
                    id,
                    attempts,
                    threads,
                    exec_ns,
                    queue_ns,
                    energy_drift,
                    steps_per_sec,
                    error,
                }
            }
            K_QUEUE_FULL => {
                CtrlFrame::QueueFull { retry_after_ms: u64::from_le_bytes(read_array(&mut cr)?) }
            }
            K_QUOTA_EXCEEDED => {
                CtrlFrame::QuotaExceeded { client: u64::from_le_bytes(read_array(&mut cr)?) }
            }
            K_CORRUPT => {
                let expected = u32::from_le_bytes(read_array(&mut cr)?);
                let got = u32::from_le_bytes(read_array(&mut cr)?);
                CtrlFrame::Corrupt { expected, got }
            }
            K_DRAINING => CtrlFrame::Draining,
            K_SHED => CtrlFrame::Shed { retry_after_ms: u64::from_le_bytes(read_array(&mut cr)?) },
            K_TIMED_OUT => {
                let [code] = read_array(&mut cr)?;
                let phase =
                    TimeoutPhase::from_code(code).ok_or_else(|| bad_frame("bad timeout phase"))?;
                CtrlFrame::TimedOut { phase }
            }
            _ => return Err(bad_frame("bad control kind")),
        };
        let computed = cr.crc.finish();
        let stored = u32::from_le_bytes(read_array(r)?);
        if computed != stored {
            return Err(io::Error::new(
                io::ErrorKind::InvalidData,
                WireError::Corrupt { expected: computed, got: stored },
            ));
        }
        Ok(frame)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::extents::{Dyn, Fix};
    use crate::mapping::aos::AoS;
    use crate::mapping::aosoa::AoSoA;
    use crate::mapping::soa::MultiBlob;

    crate::record! {
        pub struct P, mod p {
            pos: { x: f64, y: f64 },
            m: f32,
        }
    }

    fn fill<M: MemoryAccess<P>, S: BlobStorage>(v: &mut View<P, M, S>, n: usize) {
        for i in 0..n {
            v.set(&[i], p::pos::x, i as f64);
            v.set(&[i], p::pos::y, -(i as f64));
            v.set(&[i], p::m, (i * 2) as f32);
        }
    }

    fn check<M: MemoryAccess<P>, S: BlobStorage>(v: &View<P, M, S>, n: usize) {
        for i in 0..n {
            assert_eq!(v.get::<f64, _>(&[i], p::pos::x), i as f64);
            assert_eq!(v.get::<f64, _>(&[i], p::pos::y), -(i as f64));
            assert_eq!(v.get::<f32, _>(&[i], p::m), (i * 2) as f32);
        }
    }

    #[test]
    fn encode_strategy_tracks_source_layout() {
        let n = 24usize;
        // Canonical layout already: whole-blob memcpy.
        let mut a =
            alloc_view(SoA::<P, _, SingleBlob>::new((Dyn(n as u32),)), &HeapAlloc);
        fill(&mut a, n);
        assert_eq!(encode(&a).strategy, CopyStrategy::BlobMemcpy);
        // Contiguous runs: per-field memcpy.
        let mut b = alloc_view(SoA::<P, _, MultiBlob>::new((Dyn(n as u32),)), &HeapAlloc);
        fill(&mut b, n);
        assert_eq!(encode(&b).strategy, CopyStrategy::FieldRuns);
        let mut c = alloc_view(AoSoA::<P, _, 8>::new((Dyn(n as u32),)), &HeapAlloc);
        fill(&mut c, n);
        assert_eq!(encode(&c).strategy, CopyStrategy::FieldRuns);
        // Interleaved AoS: field-wise fallback.
        let mut d = alloc_view(AoS::<P, _>::new((Dyn(n as u32),)), &HeapAlloc);
        fill(&mut d, n);
        assert_eq!(encode(&d).strategy, CopyStrategy::FieldWise);
    }

    #[test]
    fn adopt_is_zero_relayout() {
        let n = 16usize;
        let mut src = alloc_view(SoA::<P, _>::new((Dyn(n as u32),)), &HeapAlloc);
        fill(&mut src, n);
        let msg = encode(&src);
        let payload = msg.payload.clone();
        let v = decode_adopt::<P, _>(msg, (Dyn(n as u32),)).unwrap();
        check(&v, n);
        // The adopted storage is the payload buffer, bytes untouched.
        assert_eq!(v.storage().blob(0), &payload[..]);
    }

    #[test]
    fn adopt_accepts_equal_static_extents() {
        // Fix and Dyn extents of equal value produce the same canonical
        // layout (fingerprints embed runtime values only).
        let mut src = alloc_view(SoA::<P, _>::new((Dyn(12u32),)), &HeapAlloc);
        fill(&mut src, 12);
        let v = decode_adopt::<P, _>(encode(&src), (Fix::<u32, 12>::new(),)).unwrap();
        check(&v, 12);
    }

    #[test]
    fn decode_streams_into_other_mappings() {
        let n = 20usize;
        let mut src = alloc_view(AoS::<P, _>::new((Dyn(n as u32),)), &HeapAlloc);
        fill(&mut src, n);
        let msg = encode(&src);

        let mut soa = alloc_view(SoA::<P, _>::new((Dyn(n as u32),)), &HeapAlloc);
        assert_eq!(decode_into(msg.clone(), &mut soa).unwrap(), CopyStrategy::FieldRuns);
        check(&soa, n);

        let mut aosoa = alloc_view(AoSoA::<P, _, 4>::new((Dyn(n as u32),)), &HeapAlloc);
        assert_eq!(decode_into(msg.clone(), &mut aosoa).unwrap(), CopyStrategy::FieldRuns);
        check(&aosoa, n);

        let mut aos = alloc_view(AoS::<P, _>::new((Dyn(n as u32),)), &HeapAlloc);
        assert_eq!(decode_into(msg, &mut aos).unwrap(), CopyStrategy::FieldWise);
        check(&aos, n);
    }

    #[test]
    fn parallel_decode_matches_serial() {
        let n = 512usize;
        let mut src = alloc_view(SoA::<P, _>::new((Dyn(n as u32),)), &HeapAlloc);
        fill(&mut src, n);
        let msg = encode_par(&src, 4);
        let mut dst = alloc_view(AoSoA::<P, _, 8>::new((Dyn(n as u32),)), &HeapAlloc);
        let strategy = decode_into_par(msg, &mut dst, 4).unwrap();
        assert!(matches!(strategy, CopyStrategy::FieldRuns | CopyStrategy::FieldRunsPar));
        check(&dst, n);
    }

    crate::record! {
        pub struct Q, mod q { a: f64 }
    }

    #[test]
    fn header_mismatches_are_rejected() {
        let mut src = alloc_view(SoA::<P, _>::new((Dyn(8u32),)), &HeapAlloc);
        fill(&mut src, 8);
        let msg = encode(&src);

        // Wrong extents.
        let mut dst = alloc_view(SoA::<P, _>::new((Dyn(9u32),)), &HeapAlloc);
        assert!(matches!(
            decode_into(msg.clone(), &mut dst),
            Err(WireError::Extents { .. })
        ));

        // Wrong record dimension.
        let mut other = alloc_view(SoA::<Q, _>::new((Dyn(8u32),)), &HeapAlloc);
        other.set(&[0], q::a, 1.0f64);
        assert!(matches!(
            decode_into(msg.clone(), &mut other),
            Err(WireError::Record { .. })
        ));

        // Corrupted fingerprint.
        let mut bad = msg.clone();
        bad.fingerprint = "AoS<lies>".into();
        assert!(matches!(
            decode_adopt::<P, _>(bad, (Dyn(8u32),)),
            Err(WireError::Fingerprint { .. })
        ));

        // Unknown version.
        let mut v3 = msg;
        v3.version = 3;
        assert!(matches!(decode_adopt::<P, _>(v3, (Dyn(8u32),)), Err(WireError::Version(3))));
    }

    #[test]
    fn framing_round_trips() {
        let mut src = alloc_view(SoA::<P, _>::new((Dyn(2u32), Dyn(3u32))), &HeapAlloc);
        for i in 0..2usize {
            for j in 0..3usize {
                src.set(&[i, j], p::pos::x, (i * 10 + j) as f64);
            }
        }
        let msg = encode(&src);
        let mut frame = Vec::new();
        msg.write_to(&mut frame).unwrap();
        assert_eq!(frame.len(), msg.frame_len());
        let back = WireMsg::read_from(&mut frame.as_slice()).unwrap();
        assert_eq!(back, msg);
        let v = decode_adopt::<P, _>(back, (Dyn(2u32), Dyn(3u32))).unwrap();
        for i in 0..2usize {
            for j in 0..3usize {
                assert_eq!(v.get::<f64, _>(&[i, j], p::pos::x), (i * 10 + j) as f64);
            }
        }
    }

    #[test]
    fn malformed_frames_are_invalid_data() {
        let mut src = alloc_view(SoA::<P, _>::new((Dyn(4u32),)), &HeapAlloc);
        fill(&mut src, 4);
        let mut frame = Vec::new();
        encode(&src).write_to(&mut frame).unwrap();

        // Truncation anywhere fails cleanly.
        for cut in [0, 3, 7, frame.len() - 1] {
            assert!(WireMsg::read_from(&mut &frame[..cut]).is_err());
        }
        // Bad magic.
        let mut bad = frame.clone();
        bad[0] = b'X';
        let err = WireMsg::read_from(&mut bad.as_slice()).unwrap_err();
        assert_eq!(err.kind(), io::ErrorKind::InvalidData);
        // Bad version.
        let mut bad = frame;
        bad[4] = 0xFF;
        assert!(WireMsg::read_from(&mut bad.as_slice()).is_err());
    }

    #[test]
    fn crc32_known_answers() {
        // IEEE check value plus the incremental-update identity.
        assert_eq!(crc32(b"123456789"), 0xCBF4_3926);
        assert_eq!(crc32(b""), 0);
        let mut c = Crc32::new();
        c.update(b"1234");
        c.update(b"56789");
        assert_eq!(c.finish(), 0xCBF4_3926);
    }

    #[test]
    fn payload_corruption_is_caught_before_decode() {
        let mut src = alloc_view(SoA::<P, _>::new((Dyn(6u32),)), &HeapAlloc);
        fill(&mut src, 6);
        let msg = encode(&src);
        let mut frame = Vec::new();
        msg.write_to(&mut frame).unwrap();

        // Flip one bit in every payload byte in turn: the CRC catches
        // each one with the typed Corrupt error, never a decode.
        let payload_start = frame.len() - 4 - msg.payload.len();
        for i in payload_start..frame.len() - 4 {
            let mut bad = frame.clone();
            bad[i] ^= 0x10;
            let err = WireMsg::read_from(&mut bad.as_slice()).unwrap_err();
            assert_eq!(err.kind(), io::ErrorKind::InvalidData);
            assert!(
                matches!(wire_error_in(&err), Some(WireError::Corrupt { .. })),
                "payload byte {i}: expected Corrupt, got {err:?}"
            );
        }
        // A corrupted stored checksum is equally fatal.
        let mut bad = frame.clone();
        let last = bad.len() - 1;
        bad[last] ^= 1;
        let err = WireMsg::read_from(&mut bad.as_slice()).unwrap_err();
        assert!(matches!(wire_error_in(&err), Some(WireError::Corrupt { .. })));
        // The pristine frame still parses.
        assert_eq!(WireMsg::read_from(&mut frame.as_slice()).unwrap(), msg);
    }

    #[test]
    fn garbage_blob_len_fails_without_huge_allocation() {
        // Hand-build a frame whose header claims an absurd payload
        // length and then ends: read_from must fail with EOF after
        // reading what's there — not allocate the claimed bytes.
        let mut frame = Vec::new();
        frame.extend_from_slice(&WIRE_MAGIC);
        frame.extend_from_slice(&WIRE_VERSION.to_le_bytes());
        frame.push(0); // strategy BlobMemcpy
        frame.push(1); // rank 1
        frame.extend_from_slice(&4u64.to_le_bytes());
        frame.extend_from_slice(&1u32.to_le_bytes());
        frame.push(b'R');
        frame.extend_from_slice(&1u32.to_le_bytes());
        frame.push(b'F');
        frame.extend_from_slice(&1u32.to_le_bytes()); // blob_count
        frame.extend_from_slice(&(u64::MAX).to_le_bytes()); // blob_len
        let err = WireMsg::read_from(&mut frame.as_slice()).unwrap_err();
        let ok = err.kind() == io::ErrorKind::UnexpectedEof
            || err.kind() == io::ErrorKind::InvalidData;
        assert!(ok, "unexpected error kind: {err:?}");
    }

    /// Build a syntactically valid view-frame header declaring
    /// `blob_len` payload bytes, then stop — no payload, no CRC.
    fn header_declaring(blob_len: u64) -> Vec<u8> {
        let mut frame = Vec::new();
        frame.extend_from_slice(&WIRE_MAGIC);
        frame.extend_from_slice(&WIRE_VERSION.to_le_bytes());
        frame.push(0); // strategy BlobMemcpy
        frame.push(1); // rank 1
        frame.extend_from_slice(&4u64.to_le_bytes());
        frame.extend_from_slice(&1u32.to_le_bytes());
        frame.push(b'R');
        frame.extend_from_slice(&1u32.to_le_bytes());
        frame.push(b'F');
        frame.extend_from_slice(&1u32.to_le_bytes()); // blob_count
        frame.extend_from_slice(&blob_len.to_le_bytes());
        frame
    }

    #[test]
    fn declared_payload_at_cap_is_not_rejected_upfront() {
        // Exactly MAX_PAYLOAD passes the cap check; the (absent) payload
        // then fails as a truncation, not as TooLarge.
        let frame = header_declaring(MAX_PAYLOAD as u64);
        let err = WireMsg::read_from(&mut frame.as_slice()).unwrap_err();
        assert_eq!(err.kind(), io::ErrorKind::UnexpectedEof, "got {err:?}");
        assert!(wire_error_in(&err).is_none());
    }

    #[test]
    fn declared_payload_over_cap_is_typed_before_allocation() {
        // One byte over the cap: typed TooLarge, before any allocation —
        // the frame ends right after the header, so if read_from had
        // tried to read (or reserve) the payload it would have surfaced
        // an EOF instead.
        let frame = header_declaring(MAX_PAYLOAD as u64 + 1);
        let err = WireMsg::read_from(&mut frame.as_slice()).unwrap_err();
        assert_eq!(err.kind(), io::ErrorKind::InvalidData);
        match wire_error_in(&err) {
            Some(WireError::TooLarge { declared, cap }) => {
                assert_eq!(*declared, MAX_PAYLOAD as u64 + 1);
                assert_eq!(*cap, MAX_PAYLOAD as u64);
            }
            other => panic!("expected TooLarge, got {other:?} ({err:?})"),
        }
        // And the absurd u64::MAX header from the legacy test is now
        // typed too.
        let err = WireMsg::read_from(&mut header_declaring(u64::MAX).as_slice()).unwrap_err();
        assert!(matches!(wire_error_in(&err), Some(WireError::TooLarge { .. })));
    }

    fn all_ctrl_frames() -> Vec<CtrlFrame> {
        vec![
            CtrlFrame::Submit {
                client: 7,
                layout: 1,
                backend: 0,
                n: 4096,
                steps: 12,
                seed: 42,
                threads: 3,
            },
            CtrlFrame::Result {
                id: 9,
                attempts: 2,
                threads: 4,
                exec_ns: 1_234_567,
                queue_ns: 89_000,
                energy_drift: 1.25e-9,
                steps_per_sec: 1234.5,
                error: String::new(),
            },
            CtrlFrame::Result {
                id: 10,
                attempts: 3,
                threads: 1,
                exec_ns: 0,
                queue_ns: 0,
                energy_drift: -0.0,
                steps_per_sec: 0.0,
                error: "job panicked: injected".into(),
            },
            CtrlFrame::QueueFull { retry_after_ms: 17 },
            CtrlFrame::QuotaExceeded { client: 7 },
            CtrlFrame::Corrupt { expected: 0xDEAD_BEEF, got: 0x0BAD_F00D },
            CtrlFrame::Draining,
            CtrlFrame::Shed { retry_after_ms: 100 },
            CtrlFrame::TimedOut { phase: TimeoutPhase::Idle },
            CtrlFrame::TimedOut { phase: TimeoutPhase::MidFrame },
        ]
    }

    #[test]
    fn ctrl_frames_round_trip() {
        for frame in all_ctrl_frames() {
            let mut buf = Vec::new();
            frame.write_to(&mut buf).unwrap();
            let back = CtrlFrame::read_from(&mut buf.as_slice()).unwrap();
            assert_eq!(back, frame);
        }
        // Several frames back-to-back on one stream parse in order.
        let mut buf = Vec::new();
        for frame in all_ctrl_frames() {
            frame.write_to(&mut buf).unwrap();
        }
        let mut r = buf.as_slice();
        for frame in all_ctrl_frames() {
            assert_eq!(CtrlFrame::read_from(&mut r).unwrap(), frame);
        }
        assert!(r.is_empty());
    }

    #[test]
    fn ctrl_frame_corruption_and_truncation_are_typed() {
        let frame = CtrlFrame::Submit {
            client: 1,
            layout: 0,
            backend: 1,
            n: 64,
            steps: 3,
            seed: 5,
            threads: 0,
        };
        let mut buf = Vec::new();
        frame.write_to(&mut buf).unwrap();
        // Every single-byte flip is rejected; flips past the fixed
        // header surface as the typed Corrupt (CRC) error.
        for i in 0..buf.len() {
            let mut bad = buf.clone();
            bad[i] ^= 0x20;
            let err = CtrlFrame::read_from(&mut bad.as_slice()).unwrap_err();
            assert_eq!(err.kind(), io::ErrorKind::InvalidData, "byte {i}: {err:?}");
            if i >= 7 {
                assert!(
                    matches!(wire_error_in(&err), Some(WireError::Corrupt { .. })),
                    "byte {i}: expected Corrupt, got {err:?}"
                );
            }
        }
        // Truncation anywhere is an error (EOF).
        for cut in 0..buf.len() {
            assert!(CtrlFrame::read_from(&mut &buf[..cut]).is_err(), "cut {cut}");
        }
        // A view frame on a control stream is refused at the magic.
        let mut src = alloc_view(SoA::<P, _>::new((Dyn(2u32),)), &HeapAlloc);
        fill(&mut src, 2);
        let mut vframe = Vec::new();
        encode(&src).write_to(&mut vframe).unwrap();
        let err = CtrlFrame::read_from(&mut vframe.as_slice()).unwrap_err();
        assert_eq!(err.kind(), io::ErrorKind::InvalidData);
    }
}
