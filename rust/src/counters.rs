//! Hardware performance counters via a hand-declared `perf_event_open`
//! (no crates — same pattern as [`crate::numa`]'s `sched_setaffinity`).
//!
//! Wall-clock medians are the wrong currency for layout work: they are
//! CI-noisy (frequency scaling, co-tenants, scheduler jitter), while the
//! paper's central claim is about *memory behavior*. What a mapping
//! change actually buys is visible in instruction and cache-event
//! counts, which are deterministic for a fixed single-threaded kernel
//! (morello's iai_callgrind benches make the same argument with
//! simulated cache geometry). This module reads the real thing:
//!
//! - One **counter group** ([`CounterGroup`]) per measured row: five
//!   `PERF_TYPE_HARDWARE` events — instructions (group leader), cycles,
//!   cache references, cache misses, branch misses — opened on the
//!   calling thread, kernel/hypervisor excluded so
//!   `perf_event_paranoid <= 2` suffices.
//! - Read with `PERF_FORMAT_GROUP | PERF_FORMAT_TOTAL_TIME_ENABLED |
//!   PERF_FORMAT_TOTAL_TIME_RUNNING`: one `read(2)` returns every
//!   event of the group from the same scheduling interval, plus the
//!   enabled/running times that let us **scale for multiplexing** (the
//!   PMU has finite slots; when the kernel time-shares them,
//!   `time_running < time_enabled` and the raw counts are extrapolated
//!   by `enabled/running` — flagged via [`Counters::multiplexed`]).
//! - A **typed fallback** ([`CounterError`]): forbidden environments —
//!   `LLAMA_COUNTERS=off`, non-Linux, Miri, seccomp,
//!   `perf_event_paranoid`, missing PMU (common on CI VMs) — yield a
//!   diagnosable error, never a panic and never fake zeros. The bench
//!   harness ([`crate::bench::Bencher`]) degrades to wall-clock-only
//!   rows, so every existing bench keeps working unchanged.
//!
//! The group-read **decoder** ([`decode_group_read`], [`GroupReading`])
//! is pure byte parsing, unit-tested against hand-built fixtures and
//! runs everywhere including Miri; only [`CounterGroup::open`] and the
//! read itself touch the kernel.
//!
//! Counts cover the **calling thread only** (`pid = 0`, no `inherit`):
//! a parallel bench row counts its submitting thread's share, which for
//! the pool's "shard 0 on the caller" dispatch is one shard's worth of
//! work plus the dispatch itself. Single-threaded rows are covered
//! exactly — those are the rows whose instruction counts two identical
//! runs reproduce within 1% (`rust/tests/counters.rs` asserts this).

use std::sync::OnceLock;

/// Why hardware counters are not being read. Every variant is a
/// *graceful* outcome: callers fall back to wall-clock measurement and
/// JSON rows simply omit the `counters` object.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum CounterError {
    /// Disabled by `LLAMA_COUNTERS=off` — the forced-fallback knob CI
    /// and tests use to exercise the degradation path deterministically.
    Off,
    /// The platform cannot deliver counters: non-Linux, Miri, a kernel
    /// without `perf_event_open`, or no PMU behind it (common on
    /// virtualized CI runners).
    Unsupported,
    /// The kernel refused access: `perf_event_paranoid` too strict, a
    /// seccomp filter, or missing capabilities in a container.
    Denied,
    /// A syscall failed for a reason the buckets above don't cover.
    Syscall {
        /// Which call failed (`"perf_event_open"`, `"ioctl"`, `"read"`).
        op: &'static str,
        /// The raw errno.
        errno: i32,
    },
    /// The group read returned fewer bytes than its header + values
    /// require.
    ShortRead {
        /// Bytes actually available.
        got: usize,
        /// Bytes the declared layout needs.
        want: usize,
    },
    /// The group read reported a different event count than the group
    /// was opened with.
    EventCount {
        /// `nr` from the read buffer.
        got: u64,
        /// Events the group holds.
        want: u64,
    },
    /// `time_running == 0`: the PMU never scheduled the group, so the
    /// raw values carry no information (and cannot be scaled).
    NeverRan,
}

impl std::fmt::Display for CounterError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            CounterError::Off => write!(f, "disabled by LLAMA_COUNTERS=off"),
            CounterError::Unsupported => {
                write!(f, "perf_event_open unsupported on this platform/kernel")
            }
            CounterError::Denied => {
                write!(f, "denied: perf_event_paranoid/seccomp forbids counters")
            }
            CounterError::Syscall { op, errno } => write!(f, "{op} failed (errno {errno})"),
            CounterError::ShortRead { got, want } => {
                write!(f, "short group read: {got} bytes, want {want}")
            }
            CounterError::EventCount { got, want } => {
                write!(f, "group read reported {got} events, want {want}")
            }
            CounterError::NeverRan => write!(f, "counter group was never scheduled"),
        }
    }
}

impl std::error::Error for CounterError {}

/// Counter measurement mode, from `LLAMA_COUNTERS`.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum CounterMode {
    /// Try to open counters; degrade to a typed [`CounterError`] when
    /// the platform refuses (the default).
    Auto,
    /// Never open counters ([`CounterGroup::open`] returns
    /// [`CounterError::Off`]) — the deterministic fallback for CI
    /// assertions and for opting out of the extra per-row run.
    Off,
}

/// `LLAMA_COUNTERS=on|off` (default `on` — unavailable platforms
/// degrade by themselves). Malformed values log once and keep the
/// default, mirroring `LLAMA_NUMA`/`LLAMA_POOL` handling. Parsed once
/// per process.
pub fn mode() -> CounterMode {
    static MODE: OnceLock<CounterMode> = OnceLock::new();
    *MODE.get_or_init(|| {
        let raw = std::env::var("LLAMA_COUNTERS").ok();
        match parse_counters_env(raw.as_deref()) {
            Some(m) => m,
            None => {
                eprintln!(
                    "llama: ignoring malformed LLAMA_COUNTERS={:?} (want on|off); \
                     counters stay on",
                    raw.unwrap_or_default()
                );
                CounterMode::Auto
            }
        }
    })
}

/// Parse an `LLAMA_COUNTERS` value (`None` result = malformed; unset is
/// the default, on). Kept separate from the environment so it is
/// testable without process-global `setenv`.
fn parse_counters_env(s: Option<&str>) -> Option<CounterMode> {
    match s.map(str::trim) {
        None | Some("") | Some("on") | Some("1") => Some(CounterMode::Auto),
        Some("off") | Some("0") => Some(CounterMode::Off),
        Some(_) => None,
    }
}

/// The five measured hardware events, in group order. Index 0 is the
/// group leader; [`decode_group_read`] values and [`Counters`] fields
/// follow this order.
const EVENTS: [(&str, u64); 5] = [
    ("instructions", PERF_COUNT_HW_INSTRUCTIONS),
    ("cycles", PERF_COUNT_HW_CPU_CYCLES),
    ("cache_references", PERF_COUNT_HW_CACHE_REFERENCES),
    ("cache_misses", PERF_COUNT_HW_CACHE_MISSES),
    ("branch_misses", PERF_COUNT_HW_BRANCH_MISSES),
];

// PERF_TYPE_HARDWARE event configs (uapi/linux/perf_event.h).
const PERF_COUNT_HW_CPU_CYCLES: u64 = 0;
const PERF_COUNT_HW_INSTRUCTIONS: u64 = 1;
const PERF_COUNT_HW_CACHE_REFERENCES: u64 = 2;
const PERF_COUNT_HW_CACHE_MISSES: u64 = 3;
const PERF_COUNT_HW_BRANCH_MISSES: u64 = 5;

/// Bytes of one full group read: `nr`, `time_enabled`, `time_running`,
/// then one `u64` per event.
const GROUP_READ_BYTES: usize = 24 + EVENTS.len() * 8;

/// One decoded `PERF_FORMAT_GROUP` read buffer, before scaling: the
/// scheduling times plus the raw (unscaled) per-event values in
/// [`EVENTS`] order. Produced by [`decode_group_read`].
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct GroupReading {
    /// Nanoseconds the group was enabled.
    pub time_enabled: u64,
    /// Nanoseconds the group was actually scheduled on the PMU.
    pub time_running: u64,
    /// Raw event values, one per opened event, in group order.
    pub values: Vec<u64>,
}

impl GroupReading {
    /// Whether the kernel time-shared the PMU under this reading (the
    /// raw values then cover only `time_running` of the `time_enabled`
    /// window and must be scaled).
    pub fn multiplexed(&self) -> bool {
        self.time_running < self.time_enabled
    }

    /// Extrapolate the raw values to the full enabled window:
    /// `value * time_enabled / time_running`, in 128-bit intermediate
    /// arithmetic so large counts cannot overflow. Identity when the
    /// group was never descheduled. `Err(NeverRan)` when
    /// `time_running == 0` — the values carry no information.
    pub fn scaled(&self) -> Result<Vec<u64>, CounterError> {
        if self.time_running == 0 {
            return Err(CounterError::NeverRan);
        }
        Ok(self
            .values
            .iter()
            .map(|&v| (v as u128 * self.time_enabled as u128 / self.time_running as u128) as u64)
            .collect())
    }
}

/// Decode one `read(2)` buffer of a counter group opened with
/// `PERF_FORMAT_GROUP | PERF_FORMAT_TOTAL_TIME_ENABLED |
/// PERF_FORMAT_TOTAL_TIME_RUNNING`:
///
/// ```text
/// u64 nr            events in the group (must equal `want_events`)
/// u64 time_enabled  ns the group was enabled
/// u64 time_running  ns the group was scheduled on the PMU
/// u64 value[nr]     raw counts, in group-open order
/// ```
///
/// Pure byte parsing (little-endian, the native order everywhere this
/// crate targets) — testable against hand-built fixtures with no
/// syscall, including under Miri.
pub fn decode_group_read(buf: &[u8], want_events: usize) -> Result<GroupReading, CounterError> {
    let u64_at = |off: usize| {
        let mut b = [0u8; 8];
        b.copy_from_slice(&buf[off..off + 8]);
        u64::from_le_bytes(b)
    };
    if buf.len() < 24 {
        return Err(CounterError::ShortRead { got: buf.len(), want: 24 });
    }
    let nr = u64_at(0);
    if nr != want_events as u64 {
        return Err(CounterError::EventCount { got: nr, want: want_events as u64 });
    }
    let want = 24 + want_events * 8;
    if buf.len() < want {
        return Err(CounterError::ShortRead { got: buf.len(), want });
    }
    Ok(GroupReading {
        time_enabled: u64_at(8),
        time_running: u64_at(16),
        values: (0..want_events).map(|i| u64_at(24 + i * 8)).collect(),
    })
}

/// One multiplex-scaled counter measurement of a code region on the
/// calling thread. All counts are extrapolated to the full enabled
/// window when the PMU was time-shared (see [`Counters::multiplexed`]).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Counters {
    /// Retired instructions.
    pub instructions: u64,
    /// CPU cycles.
    pub cycles: u64,
    /// Cache references (last-level, per the generalized HW event).
    pub cache_references: u64,
    /// Cache misses.
    pub cache_misses: u64,
    /// Mispredicted branches.
    pub branch_misses: u64,
    /// Nanoseconds the group was enabled.
    pub time_enabled_ns: u64,
    /// Nanoseconds the group was scheduled on the PMU.
    pub time_running_ns: u64,
    /// Whether the counts were extrapolated (`time_running <
    /// time_enabled`). Multiplexed counts are estimates; single-group
    /// readers on an idle PMU are exact.
    pub multiplexed: bool,
}

impl Counters {
    /// Scale and shape one decoded group reading.
    pub fn from_reading(r: &GroupReading) -> Result<Counters, CounterError> {
        if r.values.len() != EVENTS.len() {
            return Err(CounterError::EventCount {
                got: r.values.len() as u64,
                want: EVENTS.len() as u64,
            });
        }
        let v = r.scaled()?;
        Ok(Counters {
            instructions: v[0],
            cycles: v[1],
            cache_references: v[2],
            cache_misses: v[3],
            branch_misses: v[4],
            time_enabled_ns: r.time_enabled,
            time_running_ns: r.time_running,
            multiplexed: r.multiplexed(),
        })
    }

    /// Instructions per work item (`items == 0` returns the raw count).
    pub fn instructions_per_item(&self, items: u64) -> f64 {
        if items == 0 {
            return self.instructions as f64;
        }
        self.instructions as f64 / items as f64
    }

    /// Cache misses per work item (`items == 0` returns the raw count).
    pub fn cache_misses_per_item(&self, items: u64) -> f64 {
        if items == 0 {
            return self.cache_misses as f64;
        }
        self.cache_misses as f64 / items as f64
    }
}

/// An open hardware-counter group on the calling thread (see the module
/// docs for the event set and read format). Obtained via
/// [`CounterGroup::open`]; file descriptors are closed on drop.
///
/// The group must be read from the thread it was opened on — the bench
/// harness opens one per [`crate::bench::Bencher`] and measures on the
/// bench's calling thread.
#[derive(Debug)]
pub struct CounterGroup {
    /// Event fds in [`EVENTS`] order; `fds[0]` is the group leader.
    fds: Vec<i32>,
}

impl CounterGroup {
    /// Open the counter group under the process-wide [`mode`]
    /// (`LLAMA_COUNTERS`). The `Err` path is the *expected* outcome on
    /// locked-down machines — treat it as "measure wall-clock only".
    pub fn open() -> Result<CounterGroup, CounterError> {
        CounterGroup::open_with(mode())
    }

    /// Open under an explicit mode, bypassing the environment — tests
    /// use this to exercise both the forced-off and the live path
    /// without process-global `setenv`.
    pub fn open_with(mode: CounterMode) -> Result<CounterGroup, CounterError> {
        match mode {
            CounterMode::Off => Err(CounterError::Off),
            CounterMode::Auto => sys::open_group(),
        }
    }

    /// Number of events in the group.
    pub fn event_count(&self) -> usize {
        self.fds.len()
    }

    /// Measure `f`: reset the group, enable it, run `f`, disable, read
    /// and scale. Returns `f`'s output plus the [`Counters`]. An error
    /// mid-measurement still returns typed — callers demote to
    /// wall-clock-only and keep going.
    pub fn measure<T>(&self, f: impl FnOnce() -> T) -> Result<(T, Counters), CounterError> {
        sys::group_ioctl(&self.fds, sys::PERF_EVENT_IOC_RESET)?;
        sys::group_ioctl(&self.fds, sys::PERF_EVENT_IOC_ENABLE)?;
        let out = f();
        sys::group_ioctl(&self.fds, sys::PERF_EVENT_IOC_DISABLE)?;
        let reading = sys::read_group(&self.fds)?;
        Ok((out, Counters::from_reading(&reading)?))
    }
}

impl Drop for CounterGroup {
    fn drop(&mut self) {
        sys::close_all(&self.fds);
    }
}

/// Process-cached availability probe: open a group, measure a trivial
/// region, drop it. `Ok` means live counters; the `Err` is the typed
/// reason rows will lack a `counters` object. Benches put this in their
/// JSON meta and status line so a trajectory reader can tell "no
/// counters on that runner" from "bench predates counter mode".
pub fn available() -> &'static Result<(), CounterError> {
    static PROBE: OnceLock<Result<(), CounterError>> = OnceLock::new();
    PROBE.get_or_init(|| {
        let group = CounterGroup::open()?;
        let (_, counters) = group.measure(|| {
            let mut acc = 0u64;
            for i in 0..10_000u64 {
                acc = std::hint::black_box(acc.wrapping_add(i));
            }
            acc
        })?;
        // A PMU that schedules the group but counts nothing is as
        // useless as no PMU (seen on some paravirtualized runners).
        if counters.instructions == 0 {
            return Err(CounterError::Unsupported);
        }
        Ok(())
    })
}

/// Human status for bench output: `live` or `unavailable (<reason>)`.
/// CI greps for this line to assert the fallback path engaged rather
/// than crashed.
pub fn status_line() -> String {
    match available() {
        Ok(()) => "live".to_string(),
        Err(e) => format!("unavailable ({e})"),
    }
}

/// One-word availability tag for `BENCH_*.json` meta
/// (`live|off|denied|unsupported|error`).
pub fn meta_tag() -> &'static str {
    match available() {
        Ok(()) => "live",
        Err(CounterError::Off) => "off",
        Err(CounterError::Denied) => "denied",
        Err(CounterError::Unsupported) => "unsupported",
        Err(_) => "error",
    }
}

/// Names of the measured events, in group (and [`Counters`] field)
/// order — the `counters` JSON object uses exactly these keys.
pub fn event_names() -> [&'static str; 5] {
    [EVENTS[0].0, EVENTS[1].0, EVENTS[2].0, EVENTS[3].0, EVENTS[4].0]
}

// ---------------------------------------------------------------------------
// Kernel interface: hand-declared perf_event_open / ioctl / read / close
// ---------------------------------------------------------------------------

#[cfg(all(target_os = "linux", not(miri), any(target_arch = "x86_64", target_arch = "aarch64")))]
mod sys {
    use super::{CounterError, CounterGroup, GroupReading, EVENTS, GROUP_READ_BYTES};

    // perf_event_open has no glibc wrapper: go through syscall(2).
    #[cfg(target_arch = "x86_64")]
    const SYS_PERF_EVENT_OPEN: i64 = 298;
    #[cfg(target_arch = "aarch64")]
    const SYS_PERF_EVENT_OPEN: i64 = 241;

    const PERF_TYPE_HARDWARE: u32 = 0;
    /// `PERF_ATTR_SIZE_VER0`: the 64-byte original attr. Every field we
    /// set lives in those first 64 bytes, and older kernels accept this
    /// size unconditionally — maximum compatibility.
    const PERF_ATTR_SIZE_VER0: u32 = 64;

    // attr.flags bits (bitfield in the C header, plain u64 here).
    const FLAG_DISABLED: u64 = 1 << 0;
    const FLAG_EXCLUDE_KERNEL: u64 = 1 << 5;
    const FLAG_EXCLUDE_HV: u64 = 1 << 6;

    // read_format bits.
    const PERF_FORMAT_TOTAL_TIME_ENABLED: u64 = 1 << 0;
    const PERF_FORMAT_TOTAL_TIME_RUNNING: u64 = 1 << 1;
    const PERF_FORMAT_GROUP: u64 = 1 << 3;

    const PERF_FLAG_FD_CLOEXEC: u64 = 1 << 3;

    // Group-wide ioctls on the leader fd; arg = PERF_IOC_FLAG_GROUP.
    pub(super) const PERF_EVENT_IOC_ENABLE: u64 = 0x2400;
    pub(super) const PERF_EVENT_IOC_DISABLE: u64 = 0x2401;
    pub(super) const PERF_EVENT_IOC_RESET: u64 = 0x2403;
    const PERF_IOC_FLAG_GROUP: u64 = 1;

    // errno values we classify (asm-generic, valid on both arches).
    const EPERM: i32 = 1;
    const ENOENT: i32 = 2;
    const EACCES: i32 = 13;
    const ENODEV: i32 = 19;
    const ENOSYS: i32 = 38;
    const EOPNOTSUPP: i32 = 95;

    /// Mirrors the first 128 bytes of the kernel's `perf_event_attr`
    /// (through `sig_data`); we pass `size = 64` so only the VER0
    /// prefix is ever read. Unions of the C header are collapsed to
    /// their first member; the `flags` bitfield is a plain `u64`.
    #[repr(C)]
    #[derive(Default)]
    struct PerfEventAttr {
        type_: u32,
        size: u32,
        config: u64,
        sample_period_or_freq: u64,
        sample_type: u64,
        read_format: u64,
        flags: u64,
        wakeup_events_or_watermark: u32,
        bp_type: u32,
        config1: u64,
        config2: u64,
        branch_sample_type: u64,
        sample_regs_user: u64,
        sample_stack_user: u32,
        clockid: i32,
        sample_regs_intr: u64,
        aux_watermark: u32,
        sample_max_stack: u16,
        reserved_2: u16,
        aux_sample_size: u32,
        reserved_3: u32,
        sig_data: u64,
    }

    extern "C" {
        /// `syscall(2)` — the only way at `perf_event_open` without libc.
        fn syscall(num: i64, ...) -> i64;
        /// `ioctl(2)`; glibc/musl symbol, request is unsigned long.
        fn ioctl(fd: i32, request: u64, ...) -> i32;
        /// `read(2)`.
        fn read(fd: i32, buf: *mut u8, count: usize) -> isize;
        /// `close(2)`.
        fn close(fd: i32) -> i32;
        /// glibc's and musl's thread-local errno address.
        fn __errno_location() -> *mut i32;
    }

    fn errno() -> i32 {
        // SAFETY: __errno_location returns a valid thread-local address
        // for the life of the thread on every Linux libc we target.
        unsafe { *__errno_location() }
    }

    fn classify_open_errno(errno: i32) -> CounterError {
        match errno {
            EACCES | EPERM => CounterError::Denied,
            ENOENT | ENODEV | ENOSYS | EOPNOTSUPP => CounterError::Unsupported,
            e => CounterError::Syscall { op: "perf_event_open", errno: e },
        }
    }

    /// Open all [`EVENTS`] as one group on the calling thread, any CPU.
    pub(super) fn open_group() -> Result<CounterGroup, CounterError> {
        let mut fds: Vec<i32> = Vec::with_capacity(EVENTS.len());
        for (i, (_, config)) in EVENTS.iter().enumerate() {
            let attr = PerfEventAttr {
                type_: PERF_TYPE_HARDWARE,
                size: PERF_ATTR_SIZE_VER0,
                config: *config,
                read_format: PERF_FORMAT_GROUP
                    | PERF_FORMAT_TOTAL_TIME_ENABLED
                    | PERF_FORMAT_TOTAL_TIME_RUNNING,
                // Only the leader starts disabled: enabling the leader
                // with PERF_IOC_FLAG_GROUP flips the whole group, and
                // members created enabled simply follow the leader's
                // scheduling.
                flags: FLAG_EXCLUDE_KERNEL
                    | FLAG_EXCLUDE_HV
                    | if i == 0 { FLAG_DISABLED } else { 0 },
                ..PerfEventAttr::default()
            };
            let group_fd: i64 = if i == 0 { -1 } else { fds[0] as i64 };
            // SAFETY: `attr` is a valid, fully-initialized struct whose
            // declared `size` covers only bytes we initialize; the
            // kernel copies it during the call and does not retain the
            // pointer. pid=0 / cpu=-1 is "this thread, any CPU".
            let fd = unsafe {
                syscall(
                    SYS_PERF_EVENT_OPEN,
                    &attr as *const PerfEventAttr,
                    0i64,
                    -1i64,
                    group_fd,
                    PERF_FLAG_FD_CLOEXEC,
                )
            };
            if fd < 0 {
                let e = errno();
                close_all(&fds);
                return Err(classify_open_errno(e));
            }
            fds.push(fd as i32);
        }
        Ok(CounterGroup { fds })
    }

    /// Issue a group-wide ioctl (reset/enable/disable) on the leader.
    pub(super) fn group_ioctl(fds: &[i32], request: u64) -> Result<(), CounterError> {
        // SAFETY: fds[0] is a live perf event fd owned by the group;
        // these ioctls read only their integer argument.
        let rc = unsafe { ioctl(fds[0], request, PERF_IOC_FLAG_GROUP) };
        if rc < 0 {
            return Err(CounterError::Syscall { op: "ioctl", errno: errno() });
        }
        Ok(())
    }

    /// One `read(2)` of the whole group from the leader, decoded.
    pub(super) fn read_group(fds: &[i32]) -> Result<GroupReading, CounterError> {
        let mut buf = [0u8; GROUP_READ_BYTES];
        // SAFETY: `buf` is a valid writable buffer of the length passed.
        let n = unsafe { read(fds[0], buf.as_mut_ptr(), buf.len()) };
        if n < 0 {
            return Err(CounterError::Syscall { op: "read", errno: errno() });
        }
        super::decode_group_read(&buf[..n as usize], EVENTS.len())
    }

    pub(super) fn close_all(fds: &[i32]) {
        for &fd in fds {
            // SAFETY: each fd was returned by perf_event_open and is
            // closed exactly once (Vec dropped right after).
            unsafe {
                close(fd);
            }
        }
    }

    #[cfg(test)]
    mod tests {
        use super::PerfEventAttr;

        #[test]
        fn attr_layout_matches_the_kernel_header() {
            // The struct must mirror uapi perf_event_attr through
            // sig_data (128 bytes), with read_format/flags in the VER0
            // prefix at their kernel offsets.
            assert_eq!(std::mem::size_of::<PerfEventAttr>(), 128);
            assert_eq!(std::mem::align_of::<PerfEventAttr>(), 8);
            let a = PerfEventAttr::default();
            let base = &a as *const PerfEventAttr as usize;
            assert_eq!(&a.config as *const u64 as usize - base, 8);
            assert_eq!(&a.read_format as *const u64 as usize - base, 32);
            assert_eq!(&a.flags as *const u64 as usize - base, 40);
            assert_eq!(&a.config1 as *const u64 as usize - base, 56);
            assert_eq!(&a.sig_data as *const u64 as usize - base, 120);
        }
    }
}

/// Stub kernel interface for platforms that cannot deliver counters
/// (non-Linux, Miri, exotic arches): open always reports
/// [`CounterError::Unsupported`], so the group methods below are
/// unreachable but keep the one [`CounterGroup`] type compiling
/// everywhere.
#[cfg(not(all(target_os = "linux", not(miri), any(target_arch = "x86_64", target_arch = "aarch64"))))]
mod sys {
    use super::{CounterError, CounterGroup, GroupReading};

    pub(super) const PERF_EVENT_IOC_ENABLE: u64 = 0x2400;
    pub(super) const PERF_EVENT_IOC_DISABLE: u64 = 0x2401;
    pub(super) const PERF_EVENT_IOC_RESET: u64 = 0x2403;

    pub(super) fn open_group() -> Result<CounterGroup, CounterError> {
        Err(CounterError::Unsupported)
    }

    pub(super) fn group_ioctl(_fds: &[i32], _request: u64) -> Result<(), CounterError> {
        Err(CounterError::Unsupported)
    }

    pub(super) fn read_group(_fds: &[i32]) -> Result<GroupReading, CounterError> {
        Err(CounterError::Unsupported)
    }

    pub(super) fn close_all(_fds: &[i32]) {}
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Little-endian group-read fixture: `nr`, `time_enabled`,
    /// `time_running`, then `values`.
    fn fixture(nr: u64, te: u64, tr: u64, values: &[u64]) -> Vec<u8> {
        let mut buf = Vec::with_capacity(24 + values.len() * 8);
        buf.extend_from_slice(&nr.to_le_bytes());
        buf.extend_from_slice(&te.to_le_bytes());
        buf.extend_from_slice(&tr.to_le_bytes());
        for v in values {
            buf.extend_from_slice(&v.to_le_bytes());
        }
        buf
    }

    #[test]
    fn decodes_a_normal_unmultiplexed_read() {
        let buf = fixture(5, 1_000_000, 1_000_000, &[100, 200, 50, 10, 5]);
        let r = decode_group_read(&buf, 5).unwrap();
        assert_eq!(r.time_enabled, 1_000_000);
        assert_eq!(r.time_running, 1_000_000);
        assert_eq!(r.values, vec![100, 200, 50, 10, 5]);
        assert!(!r.multiplexed());
        // Identity scaling when the group was never descheduled.
        assert_eq!(r.scaled().unwrap(), vec![100, 200, 50, 10, 5]);
        let c = Counters::from_reading(&r).unwrap();
        assert_eq!(c.instructions, 100);
        assert_eq!(c.cycles, 200);
        assert_eq!(c.cache_references, 50);
        assert_eq!(c.cache_misses, 10);
        assert_eq!(c.branch_misses, 5);
        assert!(!c.multiplexed);
    }

    #[test]
    fn scales_a_multiplexed_read_by_enabled_over_running() {
        // Scheduled for a quarter of the window: counts extrapolate 4x.
        let buf = fixture(5, 1_000_000, 250_000, &[100, 200, 50, 10, 5]);
        let r = decode_group_read(&buf, 5).unwrap();
        assert!(r.multiplexed());
        assert_eq!(r.scaled().unwrap(), vec![400, 800, 200, 40, 20]);
        let c = Counters::from_reading(&r).unwrap();
        assert_eq!(c.instructions, 400);
        assert!(c.multiplexed);
        assert_eq!(c.time_enabled_ns, 1_000_000);
        assert_eq!(c.time_running_ns, 250_000);
    }

    #[test]
    fn scaling_truncates_and_survives_huge_counts() {
        // 3 * 3 / 2 = 4.5 -> truncates to 4 (integer extrapolation).
        let r = GroupReading { time_enabled: 3, time_running: 2, values: vec![3; 5] };
        assert_eq!(r.scaled().unwrap(), vec![4; 5]);
        // u64-scale counts with 2x scaling would overflow 64-bit
        // intermediate math; 128-bit keeps it exact.
        let big = u64::MAX / 2;
        let r = GroupReading { time_enabled: 2, time_running: 1, values: vec![big; 5] };
        assert_eq!(r.scaled().unwrap(), vec![big * 2; 5]);
    }

    #[test]
    fn zero_values_scale_to_zero_not_error() {
        // A group that ran but observed nothing is a valid reading —
        // "omit zeros" policy applies to *errors*, not measured zeros.
        let buf = fixture(5, 1_000, 500, &[0, 0, 0, 0, 0]);
        let r = decode_group_read(&buf, 5).unwrap();
        assert_eq!(r.scaled().unwrap(), vec![0; 5]);
    }

    #[test]
    fn never_scheduled_group_is_a_typed_error() {
        let buf = fixture(5, 1_000_000, 0, &[7, 7, 7, 7, 7]);
        let r = decode_group_read(&buf, 5).unwrap();
        assert_eq!(r.scaled(), Err(CounterError::NeverRan));
        assert_eq!(Counters::from_reading(&r), Err(CounterError::NeverRan));
    }

    #[test]
    fn wrong_event_count_is_rejected() {
        // nr = 0: a "zero-event" read — the kernel never produces this
        // for a 5-event group, so it must be a typed error, not zeros.
        let buf = fixture(0, 1_000, 1_000, &[]);
        assert_eq!(
            decode_group_read(&buf, 5),
            Err(CounterError::EventCount { got: 0, want: 5 })
        );
        let buf = fixture(3, 1_000, 1_000, &[1, 2, 3]);
        assert_eq!(
            decode_group_read(&buf, 5),
            Err(CounterError::EventCount { got: 3, want: 5 })
        );
    }

    #[test]
    fn short_reads_are_rejected_at_both_boundaries() {
        // Shorter than the 24-byte header...
        assert_eq!(
            decode_group_read(&[], 5),
            Err(CounterError::ShortRead { got: 0, want: 24 })
        );
        let buf = fixture(5, 1_000, 1_000, &[1, 2, 3, 4, 5]);
        assert_eq!(
            decode_group_read(&buf[..23], 5),
            Err(CounterError::ShortRead { got: 23, want: 24 })
        );
        // ...and a truncated value array.
        assert_eq!(
            decode_group_read(&buf[..40], 5),
            Err(CounterError::ShortRead { got: 40, want: 64 })
        );
        // The exact boundary decodes.
        assert!(decode_group_read(&buf[..64], 5).is_ok());
    }

    #[test]
    fn env_parsing() {
        assert_eq!(parse_counters_env(None), Some(CounterMode::Auto));
        assert_eq!(parse_counters_env(Some("")), Some(CounterMode::Auto));
        assert_eq!(parse_counters_env(Some("on")), Some(CounterMode::Auto));
        assert_eq!(parse_counters_env(Some("1")), Some(CounterMode::Auto));
        assert_eq!(parse_counters_env(Some(" off ")), Some(CounterMode::Off));
        assert_eq!(parse_counters_env(Some("0")), Some(CounterMode::Off));
        assert_eq!(parse_counters_env(Some("maybe")), None);
    }

    #[test]
    fn forced_off_mode_never_opens() {
        assert!(matches!(
            CounterGroup::open_with(CounterMode::Off),
            Err(CounterError::Off)
        ));
    }

    #[test]
    fn open_is_graceful_everywhere() {
        // Whatever this machine allows, open() must return a typed
        // result — never panic. (Under Miri and off Linux this is the
        // Unsupported stub; on locked-down runners, Denied.)
        match CounterGroup::open_with(CounterMode::Auto) {
            Ok(g) => {
                assert_eq!(g.event_count(), 5);
                // A live group must measure something for a real spin.
                let (sum, c) = g
                    .measure(|| {
                        let mut acc = 0u64;
                        for i in 0..10_000u64 {
                            acc = std::hint::black_box(acc.wrapping_add(i));
                        }
                        acc
                    })
                    .expect("open group must be readable");
                assert_eq!(sum, (0..10_000).sum::<u64>());
                assert!(c.instructions > 0);
            }
            Err(e) => {
                // Typed, displayable, and not the env-off variant (we
                // passed Auto explicitly).
                assert_ne!(e, CounterError::Off);
                assert!(!e.to_string().is_empty());
            }
        }
    }

    #[test]
    fn event_names_match_group_order() {
        assert_eq!(
            event_names(),
            ["instructions", "cycles", "cache_references", "cache_misses", "branch_misses"]
        );
    }
}
