//! Deterministic fault injection for the serving stack (no crates).
//!
//! A serving tier that only works on a clean machine is not a serving
//! tier. This module is the chaos layer underneath the transport and the
//! coordinator: a seeded plan ([`FaultPlan`]) that derives every fault
//! decision from a [splitmix64](https://prng.di.unimi.it/splitmix64.c)
//! stream, so a failing run reproduces **exactly** from its seed — no
//! wall clock, no global RNG, no flaky CI. LLAMA's own design argument
//! applies: cross-cutting concerns (instrumentation there, fault
//! injection here) belong in a composable layer under the access API,
//! not scattered through call sites.
//!
//! Three injection surfaces:
//!
//! 1. **Streams** ([`FaultyStream`]): wraps any `Read`/`Write` and
//!    injects short reads, torn (partial) writes, injected
//!    `io::Error`s, and single-bit payload flips at configured
//!    per-call rates. Bit flips are what the transport's CRC-32 frame
//!    checksum ([`crate::transport`]) exists to catch; short reads and
//!    torn writes exercise every `read_exact`/`write_all` loop.
//! 2. **Jobs** ([`FaultPlan::job_fault`]): the coordinator consults the
//!    plan before each job attempt and injects a panic or a delay
//!    ([`JobFault`]) — the test harness for panic isolation and
//!    retry/backoff ([`crate::coordinator::RetryPolicy`]).
//! 3. **Free draws** ([`FaultPlan::draw`]): a stable per-site hash for
//!    callers that need their own deterministic schedule (the chaos
//!    example derives worker crash points from it).
//!
//! The environment knob `LLAMA_FAULT_SEED` ([`FaultPlan::from_env`])
//! arms the chaos preset ([`FaultConfig::chaos`]) across any binary
//! that opts in — CI runs the distributed n-body example under two
//! fixed seeds and asserts bit-identity to the serial engine anyway
//! (see `docs/SERVING.md`, "Failure model").

use std::io::{self, Read, Write};
use std::time::Duration;

// ---------------------------------------------------------------------------
// Splitmix64
// ---------------------------------------------------------------------------

/// One splitmix64 scramble of `x`: a high-quality 64→64 bit mixer.
/// Stateless building block for [`SplitMix`] and for stable per-site
/// hashes ([`hash2`]).
#[inline]
pub fn splitmix64(x: u64) -> u64 {
    let mut z = x.wrapping_add(0x9E37_79B9_7F4A_7C15);
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// Stable hash of two words — deterministic jitter and per-site seed
/// derivation ("the same (job, attempt) always jitters the same way").
#[inline]
pub fn hash2(a: u64, b: u64) -> u64 {
    splitmix64(splitmix64(a) ^ b.rotate_left(32))
}

/// Splitmix64 PRNG: increment a Weyl sequence, scramble each point.
/// Unlike `testing::Rng` (xorshift, zero-state pitfalls) every seed is
/// valid and nearby seeds produce uncorrelated streams — exactly what a
/// per-site fault schedule needs.
#[derive(Clone, Debug)]
pub struct SplitMix {
    state: u64,
}

impl SplitMix {
    /// PRNG seeded at `seed` (any value, including 0).
    pub fn new(seed: u64) -> SplitMix {
        SplitMix { state: seed }
    }

    /// Next raw 64-bit value.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E37_79B9_7F4A_7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^ (z >> 31)
    }

    /// True with probability `p_1024`/1024. Draws **no** value when the
    /// probability is zero, so disabled knobs leave the stream
    /// untouched (an all-zero config is an exact passthrough).
    #[inline]
    pub fn chance(&mut self, p_1024: u16) -> bool {
        p_1024 > 0 && self.next_u64() % 1024 < u64::from(p_1024)
    }

    /// Uniform in `[0, n)` (`n` ≥ 1).
    #[inline]
    pub fn below(&mut self, n: u64) -> u64 {
        self.next_u64() % n.max(1)
    }
}

// ---------------------------------------------------------------------------
// Configuration
// ---------------------------------------------------------------------------

/// Fault rates and shapes. Stream probabilities are per I/O call in
/// parts per 1024; job knobs drive [`FaultPlan::job_fault`]. The
/// default is **all zero** — a plan with a default config injects
/// nothing and a [`FaultyStream`] under it is a pure passthrough.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct FaultConfig {
    /// Per-read probability (/1024) of an injected `io::Error`
    /// (`ConnectionReset`) instead of reading.
    pub p_read_error: u16,
    /// Per-read probability (/1024) of truncating the destination
    /// buffer to a random shorter length (≥ 1) before reading — no
    /// bytes are lost, `read_exact` loops must cope.
    pub p_short_read: u16,
    /// Per-read probability (/1024) of flipping one bit in the bytes
    /// just read — in-transit corruption the frame CRC must catch.
    pub p_read_bit_flip: u16,
    /// Per-write probability (/1024) of an injected `io::Error`.
    pub p_write_error: u16,
    /// Per-write probability (/1024) of accepting only a random prefix
    /// (≥ 1 byte) of the buffer — `write_all` loops must cope.
    pub p_torn_write: u16,
    /// Per-write probability (/1024) of flipping one bit in the bytes
    /// written out.
    pub p_write_bit_flip: u16,
    /// Inject a panic into the first this-many **attempts** of every
    /// job (0 = none; `u32::MAX` = every attempt). The deterministic
    /// counterpart to [`p_job_panic`](FaultConfig::p_job_panic) —
    /// tests use it to script "panics twice, then succeeds".
    pub panic_first_attempts: u32,
    /// Per-attempt probability (/1024) of an injected job panic,
    /// derived from (seed, job id, attempt) — reproducible across
    /// runs.
    pub p_job_panic: u16,
    /// Per-attempt probability (/1024) of an injected job delay.
    pub p_job_delay: u16,
    /// The delay injected when `p_job_delay` fires.
    pub delay: Duration,
}

impl Default for FaultConfig {
    fn default() -> Self {
        FaultConfig {
            p_read_error: 0,
            p_short_read: 0,
            p_read_bit_flip: 0,
            p_write_error: 0,
            p_torn_write: 0,
            p_write_bit_flip: 0,
            panic_first_attempts: 0,
            p_job_panic: 0,
            p_job_delay: 0,
            delay: Duration::from_millis(1),
        }
    }
}

impl FaultConfig {
    /// The chaos preset `LLAMA_FAULT_SEED` arms: frequent short
    /// reads/torn writes (they are harmless by contract), occasional
    /// bit flips and injected errors, rare job panics/delays. Rates
    /// are chosen so a tiny CI run still sees several of each.
    pub fn chaos() -> FaultConfig {
        FaultConfig {
            p_read_error: 6,
            p_short_read: 128,
            p_read_bit_flip: 10,
            p_write_error: 6,
            p_torn_write: 128,
            p_write_bit_flip: 10,
            panic_first_attempts: 0,
            p_job_panic: 48,
            p_job_delay: 48,
            delay: Duration::from_millis(2),
        }
    }

    /// The [`chaos`](FaultConfig::chaos) stream rates with every job
    /// knob zeroed: wire-level havoc (short reads, torn writes,
    /// injected errors, bit flips) without perturbing job execution.
    /// The TCP serving soak uses it so conservation and bit-identity
    /// assertions isolate the *connection* lifecycle — job-level chaos
    /// has its own tests.
    pub fn stream_chaos() -> FaultConfig {
        FaultConfig {
            panic_first_attempts: 0,
            p_job_panic: 0,
            p_job_delay: 0,
            ..FaultConfig::chaos()
        }
    }
}

// ---------------------------------------------------------------------------
// The plan
// ---------------------------------------------------------------------------

/// What [`FaultPlan::job_fault`] tells the coordinator to do to one job
/// attempt.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum JobFault {
    /// Run the attempt untouched.
    None,
    /// Panic before the kernel runs (the worker must survive it).
    Panic,
    /// Sleep [`FaultConfig::delay`] before the kernel runs.
    Delay(Duration),
}

/// A seeded, deterministic fault schedule. Every decision — per stream
/// site, per (job, attempt) — is a pure function of `(seed, site)`, so
/// two processes holding the same plan agree on the schedule without
/// communicating, and any run reproduces from its seed alone.
#[derive(Clone, Debug)]
pub struct FaultPlan {
    seed: u64,
    cfg: FaultConfig,
}

/// Domain-separation salts so stream, job, and free-draw schedules
/// derived from one seed stay uncorrelated.
const SALT_STREAM: u64 = 0x5354_5245_414D_0001; // "STREAM"
const SALT_JOB: u64 = 0x4A4F_4246_4C54_0002; // "JOBFLT"
const SALT_DRAW: u64 = 0x4452_4157_5342_0003; // "DRAWS"

impl FaultPlan {
    /// Plan with an explicit config.
    pub fn new(seed: u64, cfg: FaultConfig) -> FaultPlan {
        FaultPlan { seed, cfg }
    }

    /// Read `LLAMA_FAULT_SEED` (a u64); when set, arm the
    /// [`FaultConfig::chaos`] preset under that seed. Unset, empty, or
    /// unparsable values mean "no plan" — callers treat `None` as
    /// fault-free.
    pub fn from_env() -> Option<FaultPlan> {
        let raw = std::env::var("LLAMA_FAULT_SEED").ok()?;
        let seed: u64 = raw.trim().parse().ok()?;
        Some(FaultPlan::new(seed, FaultConfig::chaos()))
    }

    /// The plan's seed.
    pub fn seed(&self) -> u64 {
        self.seed
    }

    /// The plan's fault rates.
    pub fn config(&self) -> FaultConfig {
        self.cfg
    }

    /// Wrap `inner` in a [`FaultyStream`] whose schedule is derived
    /// from `(seed, site)` — give each peer/socket its own site id so
    /// their fault sequences are independent and reproducible.
    pub fn stream<S>(&self, site: u64, inner: S) -> FaultyStream<S> {
        FaultyStream::new(inner, hash2(self.seed ^ SALT_STREAM, site), self.cfg)
    }

    /// The fault (if any) to inject into attempt `attempt` (0-based) of
    /// job `job`. [`FaultConfig::panic_first_attempts`] wins over the
    /// probabilistic knobs; decisions are independent per (job,
    /// attempt) and reproducible.
    pub fn job_fault(&self, job: u64, attempt: u32) -> JobFault {
        if attempt < self.cfg.panic_first_attempts {
            return JobFault::Panic;
        }
        let mut rng =
            SplitMix::new(hash2(self.seed ^ SALT_JOB, hash2(job, u64::from(attempt))));
        if rng.chance(self.cfg.p_job_panic) {
            JobFault::Panic
        } else if rng.chance(self.cfg.p_job_delay) {
            JobFault::Delay(self.cfg.delay)
        } else {
            JobFault::None
        }
    }

    /// A stable 64-bit draw for `site` — for callers that derive their
    /// own schedules (e.g. "worker `w` crashes after `draw(w) % k`
    /// requests" in the chaos example).
    pub fn draw(&self, site: u64) -> u64 {
        hash2(self.seed ^ SALT_DRAW, site)
    }
}

// ---------------------------------------------------------------------------
// FaultyStream
// ---------------------------------------------------------------------------

/// A `Read`/`Write` adapter injecting faults per its [`FaultConfig`]:
/// short reads, torn writes, injected `io::Error`s, single-bit flips.
/// Decisions come from an embedded [`SplitMix`] stream, so an identical
/// call sequence replays an identical fault sequence.
///
/// Contract notes:
/// - Short reads and torn writes never lose bytes — they only return
///   less than asked, which correct `read_exact`/`write_all` users
///   already handle.
/// - Bit flips corrupt data **in transit** (the source buffer is never
///   modified on writes).
/// - Injected errors consume no bytes from the inner stream.
#[derive(Debug)]
pub struct FaultyStream<S> {
    inner: S,
    rng: SplitMix,
    cfg: FaultConfig,
    scratch: Vec<u8>,
}

impl<S> FaultyStream<S> {
    /// Wrap `inner` with a fault schedule seeded at `seed`. Prefer
    /// [`FaultPlan::stream`] so sites derive from one plan.
    pub fn new(inner: S, seed: u64, cfg: FaultConfig) -> FaultyStream<S> {
        FaultyStream { inner, rng: SplitMix::new(seed), cfg, scratch: Vec::new() }
    }

    /// The wrapped stream.
    pub fn get_ref(&self) -> &S {
        &self.inner
    }

    /// The wrapped stream, mutably (bypasses injection).
    pub fn get_mut(&mut self) -> &mut S {
        &mut self.inner
    }

    /// Unwrap.
    pub fn into_inner(self) -> S {
        self.inner
    }

    fn injected_error(what: &str) -> io::Error {
        io::Error::new(io::ErrorKind::ConnectionReset, format!("injected fault: {what}"))
    }
}

impl<S: Read> Read for FaultyStream<S> {
    fn read(&mut self, buf: &mut [u8]) -> io::Result<usize> {
        if buf.is_empty() {
            return self.inner.read(buf);
        }
        if self.rng.chance(self.cfg.p_read_error) {
            return Err(Self::injected_error("read error"));
        }
        let want = if buf.len() > 1 && self.rng.chance(self.cfg.p_short_read) {
            1 + self.rng.below(buf.len() as u64 - 1) as usize
        } else {
            buf.len()
        };
        let n = self.inner.read(&mut buf[..want])?;
        if n > 0 && self.rng.chance(self.cfg.p_read_bit_flip) {
            let byte = self.rng.below(n as u64) as usize;
            let bit = self.rng.below(8) as u32;
            buf[byte] ^= 1 << bit;
        }
        Ok(n)
    }
}

impl<S: Write> Write for FaultyStream<S> {
    fn write(&mut self, buf: &[u8]) -> io::Result<usize> {
        if buf.is_empty() {
            return self.inner.write(buf);
        }
        if self.rng.chance(self.cfg.p_write_error) {
            return Err(Self::injected_error("write error"));
        }
        let take = if buf.len() > 1 && self.rng.chance(self.cfg.p_torn_write) {
            1 + self.rng.below(buf.len() as u64 - 1) as usize
        } else {
            buf.len()
        };
        if self.rng.chance(self.cfg.p_write_bit_flip) {
            self.scratch.clear();
            self.scratch.extend_from_slice(&buf[..take]);
            let byte = self.rng.below(take as u64) as usize;
            let bit = self.rng.below(8) as u32;
            self.scratch[byte] ^= 1 << bit;
            self.inner.write(&self.scratch)
        } else {
            self.inner.write(&buf[..take])
        }
    }

    fn flush(&mut self) -> io::Result<()> {
        self.inner.flush()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Cursor;

    #[test]
    fn splitmix_matches_reference_vectors() {
        // Reference values from the canonical splitmix64.c with seed 0:
        // the Weyl increment then the three xor-multiply rounds.
        let mut rng = SplitMix::new(0);
        assert_eq!(rng.next_u64(), 0xE220A8397B1DCDAF);
        assert_eq!(rng.next_u64(), 0x6E789E6AA1B965F4);
        assert_eq!(rng.next_u64(), 0x06C45D188009454F);
    }

    #[test]
    fn streams_are_deterministic_per_seed() {
        let a: Vec<u64> = {
            let mut r = SplitMix::new(42);
            (0..32).map(|_| r.next_u64()).collect()
        };
        let b: Vec<u64> = {
            let mut r = SplitMix::new(42);
            (0..32).map(|_| r.next_u64()).collect()
        };
        let c: Vec<u64> = {
            let mut r = SplitMix::new(43);
            (0..32).map(|_| r.next_u64()).collect()
        };
        assert_eq!(a, b);
        assert_ne!(a, c);
    }

    #[test]
    fn zero_config_stream_is_passthrough() {
        let data: Vec<u8> = (0..=255u8).collect();
        let mut s = FaultyStream::new(Cursor::new(data.clone()), 7, FaultConfig::default());
        let mut out = Vec::new();
        s.read_to_end(&mut out).unwrap();
        assert_eq!(out, data);

        let mut w = FaultyStream::new(Vec::new(), 7, FaultConfig::default());
        w.write_all(&data).unwrap();
        assert_eq!(w.into_inner(), data);
    }

    #[test]
    fn short_reads_and_torn_writes_lose_nothing() {
        // Only the length-shaping faults armed: read_exact/write_all
        // loops must still move every byte, uncorrupted.
        let cfg = FaultConfig { p_short_read: 1024, p_torn_write: 1024, ..Default::default() };
        let data: Vec<u8> = (0..4096).map(|i| (i * 31 % 251) as u8).collect();

        let mut r = FaultyStream::new(Cursor::new(data.clone()), 11, cfg);
        let mut out = vec![0u8; data.len()];
        r.read_exact(&mut out).unwrap();
        assert_eq!(out, data);

        let mut w = FaultyStream::new(Vec::new(), 11, cfg);
        w.write_all(&data).unwrap();
        assert_eq!(w.into_inner(), data);
    }

    #[test]
    fn bit_flips_corrupt_in_transit_only() {
        let cfg = FaultConfig { p_read_bit_flip: 1024, ..Default::default() };
        let data = vec![0u8; 64];
        let mut r = FaultyStream::new(Cursor::new(data.clone()), 5, cfg);
        let mut out = vec![0u8; 64];
        r.read_exact(&mut out).unwrap();
        // Every read call flips exactly one bit in the bytes it
        // returned, so the output differs from the source...
        assert_ne!(out, data);
        // ...and replaying the same seed reproduces the exact flips.
        let mut r2 = FaultyStream::new(Cursor::new(data), 5, cfg);
        let mut out2 = vec![0u8; 64];
        r2.read_exact(&mut out2).unwrap();
        assert_eq!(out, out2);

        let cfg = FaultConfig { p_write_bit_flip: 1024, ..Default::default() };
        let src = vec![0xFFu8; 64];
        let mut w = FaultyStream::new(Vec::new(), 5, cfg);
        w.write_all(&src).unwrap();
        assert_ne!(w.get_ref()[..], src[..], "sink saw flipped bytes");
        assert_eq!(src, vec![0xFFu8; 64], "source buffer untouched");
    }

    #[test]
    fn injected_errors_are_typed_and_deterministic() {
        let cfg = FaultConfig { p_read_error: 1024, ..Default::default() };
        let mut r = FaultyStream::new(Cursor::new(vec![1u8, 2, 3]), 3, cfg);
        let err = r.read(&mut [0u8; 2]).unwrap_err();
        assert_eq!(err.kind(), io::ErrorKind::ConnectionReset);
        assert!(err.to_string().contains("injected fault"));

        let cfg = FaultConfig { p_write_error: 1024, ..Default::default() };
        let mut w = FaultyStream::new(Vec::new(), 3, cfg);
        assert!(w.write(&[1, 2, 3]).is_err());
    }

    #[test]
    fn job_fault_scripted_attempts_then_probabilistic() {
        let cfg = FaultConfig { panic_first_attempts: 2, ..Default::default() };
        let plan = FaultPlan::new(9, cfg);
        for job in 0..8u64 {
            assert_eq!(plan.job_fault(job, 0), JobFault::Panic);
            assert_eq!(plan.job_fault(job, 1), JobFault::Panic);
            // Probabilistic knobs are all zero: attempt 2 is clean.
            assert_eq!(plan.job_fault(job, 2), JobFault::None);
        }

        // Always-delay plan: every attempt sleeps, none panics.
        let cfg = FaultConfig {
            p_job_delay: 1024,
            delay: Duration::from_millis(3),
            ..Default::default()
        };
        let plan = FaultPlan::new(9, cfg);
        assert_eq!(plan.job_fault(4, 0), JobFault::Delay(Duration::from_millis(3)));
    }

    #[test]
    fn stream_chaos_leaves_jobs_alone() {
        let cfg = FaultConfig::stream_chaos();
        let full = FaultConfig::chaos();
        assert_eq!(cfg.p_read_bit_flip, full.p_read_bit_flip);
        assert_eq!(cfg.p_torn_write, full.p_torn_write);
        let plan = FaultPlan::new(77, cfg);
        for job in 0..64u64 {
            for attempt in 0..3u32 {
                assert_eq!(plan.job_fault(job, attempt), JobFault::None);
            }
        }
    }

    #[test]
    fn plans_agree_across_holders() {
        // Two plans with equal seed+config produce identical schedules
        // (the distributed example relies on this: parent and workers
        // derive the schedule independently from the env seed).
        let a = FaultPlan::new(1234, FaultConfig::chaos());
        let b = FaultPlan::new(1234, FaultConfig::chaos());
        for site in 0..16u64 {
            assert_eq!(a.draw(site), b.draw(site));
            assert_eq!(a.job_fault(site, 0), b.job_fault(site, 0));
        }
        // Sites are decorrelated: distinct draws.
        assert_ne!(a.draw(0), a.draw(1));
    }
}
