//! Persistent worker pool: amortized dispatch for the parallel engines.
//!
//! Before this module, every `View::par_for_each` /
//! `View::par_transform_simd` / `copy::copy_view_par` call spawned fresh
//! OS threads inside `std::thread::scope` and joined them before
//! returning — hundreds of microseconds of `clone(2)`/`futex` traffic
//! per *call*, which swamps the actual work on small and medium extents
//! and throttles any caller that dispatches in a loop (the coordinator,
//! the n-body step loop). A [`WorkerPool`] spawns its workers **once**:
//! parked workers sit in a condvar wait on a generation-counted job
//! queue, a dispatch pushes its jobs and bumps the generation, and the
//! submitter runs job 0 itself — the same "shard 0 on the calling
//! thread" shape the scoped path had, minus the per-call spawn/join.
//!
//! # Scoped-borrow-safe handoff
//!
//! The parallel engines hand workers closures that borrow stack data
//! (`&f`, shard cursors holding `PhantomData<&'v mut View>` borrows,
//! `&AtomicBool` gap flags). [`WorkerPool::run_scoped`] accepts exactly
//! such non-`'static` closures: it erases their lifetime to queue them
//! (the one `unsafe` in this module) and **does not return until every
//! queued job has finished** — on the success path, on the panic path
//! (a drop guard), and even when a job itself panics (workers catch the
//! unwind, record the payload, and the submitter re-raises it after the
//! batch drains). The borrows therefore strictly outlive every use, the
//! same guarantee `std::thread::scope` provides.
//!
//! While waiting, the submitter *helps*: it drains queued jobs instead
//! of parking. This keeps `run_scoped` deadlock-free even when jobs
//! themselves dispatch on the same pool (every batch has at least one
//! thread guaranteed to execute its jobs: its own submitter).
//!
//! # NUMA placement
//!
//! On a multi-node machine (and unless `LLAMA_NUMA=off`,
//! [`crate::numa::policy`]), pool workers are pinned round-robin across
//! nodes at spawn, queued jobs carry their slot's preferred node, and
//! parked workers prefer jobs tagged for their own node (stealing
//! others only when nothing local is queued). [`first_touch`] completes
//! the story: it faults the pages of each worker slot's byte range in
//! from that worker, so a subsequent sharded traversal whose shard `k`
//! lands on slot `k` reads node-local memory. Placement is best-effort
//! — single-node machines and refused `sched_setaffinity` degrade to
//! plain pooling with zero overhead.
//!
//! # Which pool runs my dispatch?
//!
//! - The parallel entry points without a pool argument use the lazy
//!   crate-global pool ([`global`], sized by
//!   [`crate::shard::thread_count`]) — unless `LLAMA_POOL=off`
//!   ([`pooled_dispatch`]) or under Miri (the global pool's threads
//!   would outlive the interpreted test binary), where they fall back
//!   to the per-call scoped spawn ([`run_scoped_spawn`]).
//! - The `*_on` entry points (`View::par_for_each_on`, …) take an
//!   explicit [`WorkerPool`] — the coordinator and the benches use
//!   these for deterministic sizing.
//!
//! # Thread budgets
//!
//! A pool hands out advisory thread budgets through [`WorkerPool::lease`]:
//! concurrent callers (coordinator workers) split the pool's capacity
//! instead of each assuming they own all of it, and a single caller on
//! an idle pool is granted the whole budget — one big job saturates the
//! workers that batching small jobs would otherwise leave parked.

use std::collections::VecDeque;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex, OnceLock};

use crate::blob::{blob_spans, BlobBytes, BlobStorage};
use crate::numa::{self, NumaPolicy};
use crate::util::CachePadded;

/// A queued, lifetime-erased job plus its batch bookkeeping.
struct Job {
    run: Box<dyn FnOnce() + Send + 'static>,
    batch: Arc<Batch>,
    /// Preferred NUMA node (pinned pools only); workers prefer matching
    /// jobs and steal others when nothing local is queued.
    node: Option<usize>,
}

/// Completion state of one `run_scoped` batch.
struct Batch {
    state: Mutex<BatchState>,
    done: Condvar,
}

struct BatchState {
    remaining: usize,
    /// First panic payload observed by a worker running this batch's
    /// jobs; re-raised on the submitting thread.
    panic: Option<Box<dyn std::any::Any + Send>>,
}

impl Batch {
    fn new(jobs: usize) -> Arc<Batch> {
        Arc::new(Batch {
            state: Mutex::new(BatchState { remaining: jobs, panic: None }),
            done: Condvar::new(),
        })
    }

    fn is_done(&self) -> bool {
        self.state.lock().unwrap().remaining == 0
    }

    /// Block until every job of the batch has run; returns the first
    /// panic payload, if any.
    fn wait(&self) -> Option<Box<dyn std::any::Any + Send>> {
        let mut st = self.state.lock().unwrap();
        while st.remaining > 0 {
            st = self.done.wait(st).unwrap();
        }
        st.panic.take()
    }
}

/// The generation-counted job cell workers park on.
struct JobCell {
    jobs: VecDeque<Job>,
    /// Bumped once per dispatch; lets stats distinguish "parked workers
    /// woken N times" from "N threads spawned".
    generation: u64,
    shutdown: bool,
}

/// State shared between the pool handle and its workers.
///
/// The mutex and the condvar are each padded to their own cache line
/// (E13 false-sharing audit): workers spin-lock the cell while parked
/// submitters hammer the condvar word, and co-locating the two made
/// every lock acquisition also bounce the condvar's line.
struct Shared {
    cell: CachePadded<Mutex<JobCell>>,
    work: CachePadded<Condvar>,
}

impl Shared {
    /// Pop a job, preferring ones tagged for `my_node`; `None` when the
    /// queue is empty.
    fn take_job(cell: &mut JobCell, my_node: Option<usize>) -> Option<Job> {
        if let Some(nd) = my_node {
            if let Some(pos) =
                cell.jobs.iter().position(|j| j.node.is_none() || j.node == Some(nd))
            {
                return cell.jobs.remove(pos);
            }
        }
        cell.jobs.pop_front()
    }

    /// Run one job to completion, recording panics into its batch.
    fn execute(job: Job) {
        let Job { run, batch, .. } = job;
        let result = catch_unwind(AssertUnwindSafe(run));
        let mut st = batch.state.lock().unwrap();
        if let Err(payload) = result {
            if st.panic.is_none() {
                st.panic = Some(payload);
            }
        }
        st.remaining -= 1;
        if st.remaining == 0 {
            batch.done.notify_all();
        }
    }
}

fn worker_loop(shared: Arc<Shared>, my_node: Option<usize>, cpus: Vec<usize>) {
    if !cpus.is_empty() {
        // Refusal (sandbox, shrunk cgroup mask) just means "unpinned".
        let _ = numa::pin_current_thread(&cpus);
    }
    loop {
        let job = {
            let mut cell = shared.cell.lock().unwrap();
            loop {
                if let Some(job) = Shared::take_job(&mut cell, my_node) {
                    break job;
                }
                if cell.shutdown {
                    return;
                }
                cell = shared.work.wait(cell).unwrap();
            }
        };
        Shared::execute(job);
    }
}

/// A persistent pool of parked worker threads (see the module docs).
///
/// Dropping the pool drains the queue, wakes the workers into shutdown,
/// and joins them — explicit pools (benches, coordinator tests) clean
/// up after themselves; the [`global`] pool lives for the process.
pub struct WorkerPool {
    shared: Arc<Shared>,
    workers: Vec<std::thread::JoinHandle<()>>,
    /// Preferred node per worker slot (empty when unpinned): slot `k`
    /// of a dispatch is tagged `node_ids[(k - 1) % len]`… see
    /// [`node_of_slot`](WorkerPool::node_of_slot).
    node_ids: Vec<usize>,
    /// Advisory thread budget not currently leased out. Padded: leases
    /// are taken/returned by CAS from concurrent coordinator workers,
    /// and unpadded this word shared a line with the read-mostly
    /// `node_ids`/`workers` Vec headers (E13 audit).
    available: CachePadded<AtomicUsize>,
    /// Worker threads ever spawned — stays equal to
    /// [`worker_count`](WorkerPool::worker_count) for the pool's whole
    /// life: workers are never respawned.
    spawned: AtomicUsize,
}

impl WorkerPool {
    /// Pool with `threads` workers, pinned across NUMA nodes when the
    /// process policy asks for it ([`crate::numa::policy`]) and the
    /// machine has more than one node.
    pub fn new(threads: usize) -> WorkerPool {
        WorkerPool::with_pinning(threads, numa::policy() == NumaPolicy::FirstTouch)
    }

    /// Pool with explicit control over worker pinning (the benches
    /// compare pinned and unpinned pools side by side). `pin` is only
    /// effective on multi-node machines; elsewhere it is a no-op.
    pub fn with_pinning(threads: usize, pin: bool) -> WorkerPool {
        let threads = threads.max(1);
        let shared = Arc::new(Shared {
            cell: CachePadded::new(Mutex::new(JobCell {
                jobs: VecDeque::new(),
                generation: 0,
                shutdown: false,
            })),
            work: CachePadded::new(Condvar::new()),
        });
        let topo = numa::probe();
        let pin = pin && topo.is_multi_node();
        let mut node_ids = Vec::new();
        let mut workers = Vec::with_capacity(threads);
        let spawned = AtomicUsize::new(0);
        for slot in 0..threads {
            let (node, cpus) = if pin {
                let nd = topo.node_of_slot(slot);
                node_ids.push(nd.id);
                (Some(nd.id), nd.cpus.clone())
            } else {
                (None, Vec::new())
            };
            let shared = shared.clone();
            spawned.fetch_add(1, Ordering::Relaxed);
            workers.push(
                std::thread::Builder::new()
                    .name(format!("llama-pool-{slot}"))
                    .spawn(move || worker_loop(shared, node, cpus))
                    .expect("spawning pool worker"),
            );
        }
        WorkerPool {
            shared,
            workers,
            node_ids,
            available: CachePadded::new(AtomicUsize::new(threads)),
            spawned,
        }
    }

    /// Number of worker threads (fixed at construction).
    pub fn worker_count(&self) -> usize {
        self.workers.len()
    }

    /// Worker threads ever spawned — equals
    /// [`worker_count`](WorkerPool::worker_count) because workers are
    /// never respawned; tests assert this stays flat across dispatches.
    pub fn spawned_total(&self) -> usize {
        self.spawned.load(Ordering::Relaxed)
    }

    /// Dispatches served so far — the job cell's generation counter
    /// (each `run_scoped` that queues jobs, i.e. has ≥ 2 of them, bumps
    /// it once).
    pub fn dispatch_count(&self) -> u64 {
        self.shared.cell.lock().unwrap().generation
    }

    /// Whether this pool's workers are NUMA-pinned.
    pub fn is_pinned(&self) -> bool {
        !self.node_ids.is_empty()
    }

    /// Preferred NUMA node for dispatch slot `slot` (slot 0 is the
    /// submitting thread — unpinned, so `None`; queued slots map
    /// round-robin onto the pinned workers).
    fn node_of_slot(&self, slot: usize) -> Option<usize> {
        if self.node_ids.is_empty() || slot == 0 {
            None
        } else {
            Some(self.node_ids[(slot - 1) % self.node_ids.len()])
        }
    }

    /// Run `jobs` to completion: job 0 on the calling thread, the rest
    /// on the pool's workers. Returns only when every job has finished
    /// (panics in any job are re-raised here after the batch drains) —
    /// which is what makes non-`'static` borrows in the jobs sound, the
    /// same guarantee `std::thread::scope` gives.
    ///
    /// ```
    /// use std::sync::atomic::{AtomicUsize, Ordering};
    /// let pool = llama::pool::WorkerPool::with_pinning(2, false);
    /// let sum = AtomicUsize::new(0); // borrowed, not 'static
    /// pool.run_scoped((1..=4).map(|k| {
    ///     let sum = &sum;
    ///     move || { sum.fetch_add(k, Ordering::Relaxed); }
    /// }).collect());
    /// assert_eq!(sum.load(Ordering::Relaxed), 10);
    /// ```
    pub fn run_scoped<'env, F>(&self, mut jobs: Vec<F>)
    where
        F: FnOnce() + Send + 'env,
    {
        if jobs.is_empty() {
            return;
        }
        let first = jobs.remove(0);
        if jobs.is_empty() {
            first();
            return;
        }
        let batch = Batch::new(jobs.len());
        {
            let mut cell = self.shared.cell.lock().unwrap();
            assert!(!cell.shutdown, "dispatch on a shut-down pool");
            for (i, f) in jobs.into_iter().enumerate() {
                // Queued job i is dispatch slot i + 1 (slot 0 = caller).
                let node = self.node_of_slot(i + 1);
                // SAFETY: the erased borrows stay live until this fn
                // returns, and it returns only after the batch fully
                // drains (wait below, plus the drop guard on the panic
                // path) — see `erase_lifetime`.
                let run = unsafe { erase_lifetime(f) };
                cell.jobs.push_back(Job { run, batch: batch.clone(), node });
            }
            cell.generation += 1;
        }
        self.shared.work.notify_all();

        // If `first` unwinds, the guard still drains the batch before
        // the erased borrows go out of scope (payloads from pool jobs
        // are dropped then — the caller's own panic wins).
        let guard = DrainGuard { pool: self, batch: &batch };
        first();
        std::mem::forget(guard);
        self.help_until_done(&batch);
        if let Some(payload) = batch.wait() {
            std::panic::resume_unwind(payload);
        }
    }

    /// Drain queued jobs (any batch's) while `batch` is unfinished —
    /// the submitter works instead of parking, which both finishes
    /// sooner on a loaded pool and guarantees progress when jobs
    /// themselves dispatch on this pool.
    fn help_until_done(&self, batch: &Batch) {
        while !batch.is_done() {
            let job = {
                let mut cell = self.shared.cell.lock().unwrap();
                Shared::take_job(&mut cell, None)
            };
            match job {
                Some(job) => Shared::execute(job),
                None => break, // nothing left to help with: park in wait()
            }
        }
    }

    /// Lease an advisory thread budget from the pool: up to `want`
    /// threads (`0` = "as many as possible"), granted from what other
    /// live leases have left, always at least 1. Dropping the lease
    /// returns the budget. Concurrent callers (coordinator workers)
    /// thereby split the pool instead of oversubscribing it, and a
    /// single caller on an idle pool gets the whole budget.
    pub fn lease(&self, want: usize) -> Lease<'_> {
        let want = if want == 0 { self.worker_count() } else { want };
        let mut avail = self.available.load(Ordering::Relaxed);
        loop {
            let take = avail.min(want);
            match self.available.compare_exchange_weak(
                avail,
                avail - take,
                Ordering::AcqRel,
                Ordering::Relaxed,
            ) {
                Ok(_) => return Lease { pool: self, granted: take.max(1), reserved: take },
                Err(now) => avail = now,
            }
        }
    }
}

impl Drop for WorkerPool {
    fn drop(&mut self) {
        {
            let mut cell = self.shared.cell.lock().unwrap();
            cell.shutdown = true;
        }
        self.shared.work.notify_all();
        for handle in self.workers.drain(..) {
            let _ = handle.join();
        }
    }
}

impl std::fmt::Debug for WorkerPool {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("WorkerPool")
            .field("workers", &self.worker_count())
            .field("pinned", &self.is_pinned())
            .field("dispatches", &self.dispatch_count())
            .finish()
    }
}

/// Drains the batch on unwind from the submitter's own job; forgotten
/// on the success path.
struct DrainGuard<'a> {
    pool: &'a WorkerPool,
    batch: &'a Batch,
}

impl Drop for DrainGuard<'_> {
    fn drop(&mut self) {
        self.pool.help_until_done(self.batch);
        let _ = self.batch.wait();
    }
}

/// Erase a job closure's borrow lifetime so it can sit in the queue.
///
/// # Safety
///
/// The caller must not return (or otherwise invalidate any borrow
/// captured by `f`) until the job has finished running. `run_scoped`
/// discharges this by draining the batch on every exit path.
unsafe fn erase_lifetime<'env, F>(f: F) -> Box<dyn FnOnce() + Send + 'static>
where
    F: FnOnce() + Send + 'env,
{
    let boxed: Box<dyn FnOnce() + Send + 'env> = Box::new(f);
    // SAFETY: only the lifetime bound changes; fat-pointer layout is
    // identical, and the caller upholds the liveness contract above.
    unsafe {
        std::mem::transmute::<Box<dyn FnOnce() + Send + 'env>, Box<dyn FnOnce() + Send + 'static>>(
            boxed,
        )
    }
}

/// An advisory thread budget held out of a pool; see
/// [`WorkerPool::lease`]. Returned to the pool on drop.
pub struct Lease<'p> {
    pool: &'p WorkerPool,
    granted: usize,
    reserved: usize,
}

impl Lease<'_> {
    /// The thread budget granted (≥ 1).
    pub fn threads(&self) -> usize {
        self.granted
    }
}

impl Drop for Lease<'_> {
    fn drop(&mut self) {
        self.pool.available.fetch_add(self.reserved, Ordering::AcqRel);
    }
}

// ---------------------------------------------------------------------------
// Process-global pool and the dispatch policy
// ---------------------------------------------------------------------------

/// The lazy crate-global pool: sized by [`crate::shard::thread_count`]
/// (`LLAMA_THREADS`), constructed on first parallel dispatch, alive for
/// the rest of the process.
pub fn global() -> &'static WorkerPool {
    static GLOBAL: OnceLock<WorkerPool> = OnceLock::new();
    GLOBAL.get_or_init(|| WorkerPool::new(crate::shard::thread_count()))
}

/// Whether the implicit parallel entry points dispatch on the global
/// pool (default) or fall back to per-call scoped spawn:
/// `LLAMA_POOL=off|0` opts out, and Miri always uses the scoped path
/// (a process-global pool's threads would still be running when the
/// interpreted test binary exits, which Miri treats as an error;
/// explicit pools are joined on drop and run under Miri fine).
/// Parsed once per process; malformed values log one warning and keep
/// the default (on) — same convention as `LLAMA_THREADS`/`LLAMA_NUMA`.
pub fn pooled_dispatch() -> bool {
    if cfg!(miri) {
        return false;
    }
    static ON: OnceLock<bool> = OnceLock::new();
    *ON.get_or_init(|| {
        let raw = std::env::var("LLAMA_POOL").ok();
        match parse_pool_env(raw.as_deref()) {
            Some(on) => on,
            None => {
                eprintln!(
                    "llama: ignoring malformed LLAMA_POOL={:?} (want off|on); \
                     pooled dispatch stays on",
                    raw.unwrap_or_default()
                );
                true
            }
        }
    })
}

/// Parse an `LLAMA_POOL` value (`None` result = malformed; unset is
/// the default, on). Kept separate from the environment so it is
/// testable without process-global `setenv`.
fn parse_pool_env(s: Option<&str>) -> Option<bool> {
    match s.map(str::trim) {
        None | Some("") | Some("on") | Some("1") => Some(true),
        Some("off") | Some("0") => Some(false),
        Some(_) => None,
    }
}

/// Run a batch of scoped jobs on the policy target: the [`global`] pool
/// when [`pooled_dispatch`] is on, otherwise a per-call
/// [`run_scoped_spawn`]. This is the single funnel the parallel engines
/// (`shard::ViewShards::dispatch`, `copy::copy_view_par`) go through.
pub fn run_jobs<'env, F>(jobs: Vec<F>)
where
    F: FnOnce() + Send + 'env,
{
    if pooled_dispatch() {
        global().run_scoped(jobs);
    } else {
        run_scoped_spawn(jobs);
    }
}

/// The pre-pool dispatch: job 0 on the calling thread, one fresh scoped
/// thread per remaining job. Kept as the `LLAMA_POOL=off` / Miri path
/// and as the baseline the `pool` bench measures the pool against.
pub fn run_scoped_spawn<'env, F>(mut jobs: Vec<F>)
where
    F: FnOnce() + Send + 'env,
{
    if jobs.is_empty() {
        return;
    }
    let first = jobs.remove(0);
    if jobs.is_empty() {
        first();
        return;
    }
    std::thread::scope(|scope| {
        for job in jobs {
            scope.spawn(job);
        }
        first();
    });
}

// ---------------------------------------------------------------------------
// First-touch page placement
// ---------------------------------------------------------------------------

/// [`first_touch_on`] against the crate-[`global`] pool — the pool the
/// implicit parallel entry points dispatch on, so pages land where
/// `par_for_each`/`par_transform_simd`/`copy_view_par` will read them.
/// Returns without ever *constructing* the global pool when placement
/// cannot happen — pooled dispatch off (`LLAMA_POOL=off`, Miri: those
/// runs traverse on per-call scoped threads with no stable worker↔node
/// identity), policy `off`, or a single-node machine — so a program
/// that merely allocates with [`crate::blob::FirstTouchAlloc`] never
/// spawns worker threads as a side effect.
///
/// Traversals that run on an *explicit* pool (`*_on` entry points)
/// should place with [`first_touch_on`] against that same pool instead
/// — the partition is per-pool, so touching with one pool and
/// traversing with another mislays the ranges.
pub fn first_touch<S: BlobStorage>(storage: &mut S) {
    if !pooled_dispatch()
        || numa::policy() != NumaPolicy::FirstTouch
        || !numa::probe().is_multi_node()
    {
        return;
    }
    first_touch_on(global(), storage);
}

/// Fault `storage`'s pages in from the workers of `pool` that will own
/// them: dispatch slot `k` touches byte range `[len·k/S, len·(k+1)/S)`
/// of every blob (one volatile same-value read-modify-write per 4 KiB
/// page — contents are **always** preserved, so calling this on
/// already-filled storage is safe), where `S` = the pool's worker
/// count. That matches the partition of a sharded traversal at the
/// pool's full width: `S` shards, shard 0 on the calling thread
/// (wherever it runs — slot 0 here is likewise the caller), shard `k`
/// preferring the node of worker `k - 1` — so on a first-touch kernel
/// each worker's shard lands on pages resident on that worker's node.
/// Traversals at other shard counts get best-effort placement (see the
/// ROADMAP follow-up). A no-op when the policy is `off` or when
/// placement cannot help (single worker, or an unpinned pool — its
/// workers have no node identity, so faulting pages in eagerly would
/// cost a pass over memory for zero locality benefit).
pub fn first_touch_on<S: BlobStorage>(pool: &WorkerPool, storage: &mut S) {
    if numa::policy() != NumaPolicy::FirstTouch || !pool.is_pinned() {
        return;
    }
    let slots = pool.worker_count();
    if slots < 2 {
        return;
    }
    let spans = blob_spans(storage);
    let spans: &[BlobBytes] = &spans;
    pool.run_scoped((0..slots).map(|k| move || touch_slot(spans, k, slots)).collect());
}

/// Touch one byte per page of slot `k`'s byte range of every span: a
/// volatile read of the byte followed by a volatile write of the same
/// value. Volatile so the (semantically no-op) store cannot be
/// optimized out — the store is what makes the kernel commit the page
/// on the toucher's node — and value-preserving so the touch is safe
/// on storage that already holds data.
fn touch_slot(spans: &[BlobBytes], k: usize, slots: usize) {
    const PAGE: usize = 4096;
    for span in spans {
        let len = span.len() as u128;
        let lo = (len * k as u128 / slots as u128) as usize;
        let hi = (len * (k + 1) as u128 / slots as u128) as usize;
        let mut off = lo;
        while off < hi {
            // SAFETY: slot byte ranges are disjoint by construction,
            // the storage is exclusively borrowed by `first_touch_on`,
            // and `run_scoped` keeps the spans alive until every slot
            // is done — the `BlobBytes::bytes_mut` contract holds.
            unsafe {
                let byte = span.bytes_mut(off, 1).as_mut_ptr();
                std::ptr::write_volatile(byte, std::ptr::read_volatile(byte));
            }
            off += PAGE;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicUsize;

    fn pool(n: usize) -> WorkerPool {
        // Unpinned in tests: deterministic across machines and Miri.
        WorkerPool::with_pinning(n, false)
    }

    #[test]
    fn runs_every_job_exactly_once() {
        let p = pool(3);
        let hits = AtomicUsize::new(0);
        p.run_scoped(
            (0..17)
                .map(|_| {
                    let hits = &hits;
                    move || {
                        hits.fetch_add(1, Ordering::Relaxed);
                    }
                })
                .collect::<Vec<_>>(),
        );
        assert_eq!(hits.load(Ordering::Relaxed), 17);
    }

    #[test]
    fn empty_and_single_job_batches() {
        let p = pool(2);
        p.run_scoped(Vec::<fn()>::new());
        let ran = AtomicUsize::new(0);
        let ran_ref = &ran;
        p.run_scoped(vec![move || {
            ran_ref.fetch_add(1, Ordering::Relaxed);
        }]);
        assert_eq!(ran.load(Ordering::Relaxed), 1);
        // Single-job batches run inline: no dispatch was needed.
        assert_eq!(p.dispatch_count(), 0);
    }

    #[test]
    fn reuses_workers_across_dispatches_without_respawn() {
        let p = pool(4);
        assert_eq!(p.spawned_total(), 4);
        let sum = AtomicUsize::new(0);
        for round in 0..25 {
            p.run_scoped(
                (0..6)
                    .map(|j| {
                        let sum = &sum;
                        move || {
                            sum.fetch_add(round * 6 + j, Ordering::Relaxed);
                        }
                    })
                    .collect::<Vec<_>>(),
            );
        }
        assert_eq!(sum.load(Ordering::Relaxed), (0..150).sum());
        assert_eq!(p.dispatch_count(), 25);
        // The load-bearing claim: 25 dispatches, still the original 4
        // threads — nothing respawned.
        assert_eq!(p.spawned_total(), 4);
        assert_eq!(p.worker_count(), 4);
    }

    #[test]
    fn jobs_borrow_stack_data() {
        let p = pool(2);
        let mut data = vec![0u64; 64];
        {
            // Disjoint &mut chunks into a stack-owned Vec — the borrow
            // pattern the sharded engine relies on.
            let chunks: Vec<&mut [u64]> = data.chunks_mut(16).collect();
            p.run_scoped(
                chunks
                    .into_iter()
                    .enumerate()
                    .map(|(k, chunk)| {
                        move || {
                            for (i, slot) in chunk.iter_mut().enumerate() {
                                *slot = (k * 100 + i) as u64;
                            }
                        }
                    })
                    .collect::<Vec<_>>(),
            );
        }
        assert_eq!(data[0], 0);
        assert_eq!(data[17], 101);
        assert_eq!(data[63], 315);
    }

    #[test]
    fn panicking_job_propagates_and_pool_survives() {
        let p = pool(2);
        let result = std::panic::catch_unwind(AssertUnwindSafe(|| {
            p.run_scoped(vec![
                Box::new(|| {}) as Box<dyn FnOnce() + Send>,
                Box::new(|| panic!("job exploded")),
                Box::new(|| {}),
            ]);
        }));
        let payload = result.expect_err("panic must propagate to the submitter");
        let msg = payload.downcast_ref::<&str>().copied().unwrap_or("");
        assert_eq!(msg, "job exploded");
        // The pool took the hit and keeps serving.
        let ok = AtomicUsize::new(0);
        p.run_scoped(
            (0..2)
                .map(|_| {
                    let ok = &ok;
                    move || {
                        ok.fetch_add(1, Ordering::Relaxed);
                    }
                })
                .collect::<Vec<_>>(),
        );
        assert_eq!(ok.load(Ordering::Relaxed), 2);
    }

    #[test]
    fn concurrent_submitters_share_one_pool() {
        let p = Arc::new(pool(3));
        let total = Arc::new(AtomicUsize::new(0));
        let mut handles = Vec::new();
        for _ in 0..4 {
            let p = p.clone();
            let total = total.clone();
            handles.push(std::thread::spawn(move || {
                for _ in 0..10 {
                    let local = AtomicUsize::new(0);
                    p.run_scoped(
                        (0..5)
                            .map(|_| {
                                let local = &local;
                                move || {
                                    local.fetch_add(1, Ordering::Relaxed);
                                }
                            })
                            .collect::<Vec<_>>(),
                    );
                    total.fetch_add(local.load(Ordering::Relaxed), Ordering::Relaxed);
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(total.load(Ordering::Relaxed), 4 * 10 * 5);
    }

    #[test]
    fn nested_dispatch_from_inside_a_job_completes() {
        // Jobs that themselves dispatch on the same pool must not
        // deadlock: the inner submitter helps drain the queue.
        let p = Arc::new(pool(2));
        let hits = AtomicUsize::new(0);
        let hits_ref = &hits;
        let inner_pool = p.clone();
        p.run_scoped(
            (0..3)
                .map(|_| {
                    let inner_pool = inner_pool.clone();
                    move || {
                        inner_pool.run_scoped(
                            (0..3)
                                .map(|_| {
                                    let hits_ref = &hits_ref;
                                    move || {
                                        hits_ref.fetch_add(1, Ordering::Relaxed);
                                    }
                                })
                                .collect::<Vec<_>>(),
                        );
                    }
                })
                .collect::<Vec<_>>(),
        );
        assert_eq!(hits.load(Ordering::Relaxed), 9);
    }

    #[test]
    fn lease_budget_splits_and_returns() {
        let p = pool(4);
        let a = p.lease(0);
        assert_eq!(a.threads(), 4);
        let b = p.lease(3);
        assert_eq!(b.threads(), 1); // nothing left, floor of 1
        drop(a);
        let c = p.lease(3);
        assert_eq!(c.threads(), 3);
        let d = p.lease(0);
        assert_eq!(d.threads(), 1);
        drop((b, c, d));
        assert_eq!(p.lease(0).threads(), 4); // everything returned
    }

    #[test]
    fn pool_env_parsing() {
        assert_eq!(parse_pool_env(None), Some(true));
        assert_eq!(parse_pool_env(Some("")), Some(true));
        assert_eq!(parse_pool_env(Some("on")), Some(true));
        assert_eq!(parse_pool_env(Some("1")), Some(true));
        assert_eq!(parse_pool_env(Some(" off ")), Some(false));
        assert_eq!(parse_pool_env(Some("0")), Some(false));
        assert_eq!(parse_pool_env(Some("OFF")), None); // malformed: warn + default
    }

    #[test]
    fn scoped_spawn_fallback_runs_jobs() {
        let hits = AtomicUsize::new(0);
        run_scoped_spawn(
            (0..5)
                .map(|_| {
                    let hits = &hits;
                    move || {
                        hits.fetch_add(1, Ordering::Relaxed);
                    }
                })
                .collect::<Vec<_>>(),
        );
        assert_eq!(hits.load(Ordering::Relaxed), 5);
    }

    #[test]
    fn first_touch_preserves_contents() {
        // Whatever the policy/topology resolves to (no-op on single
        // node, volatile RMW touch on NUMA machines), placement must
        // be invisible to contents — zeroed or already filled.
        use crate::blob::{BlobAlloc, HeapAlloc};
        let mut s = HeapAlloc.alloc(&[3 * 4096 + 17, 100]);
        first_touch(&mut s);
        assert!(s.blob(0).iter().all(|&b| b == 0));
        s.blob_mut(0).iter_mut().enumerate().for_each(|(i, b)| *b = i as u8);
        first_touch(&mut s);
        assert!(s.blob(0).iter().enumerate().all(|(i, &b)| b == i as u8));

        let p = pool(3); // unpinned: first_touch_on must be a no-op
        first_touch_on(&p, &mut s);
        assert!(s.blob(0).iter().enumerate().all(|(i, &b)| b == i as u8));
        assert_eq!(p.dispatch_count(), 0);
    }

    #[test]
    fn touch_slot_is_value_preserving() {
        // The touch itself (exercised directly — CI machines are
        // single-node, so the pinned path never runs there): every
        // slot's volatile RMW leaves a filled buffer bit-identical.
        use crate::blob::blob_spans;
        use crate::blob::{BlobAlloc, HeapAlloc};
        let mut s = HeapAlloc.alloc(&[2 * 4096 + 123]);
        s.blob_mut(0)
            .iter_mut()
            .enumerate()
            .for_each(|(i, b)| *b = (i * 7 % 251) as u8);
        let spans = blob_spans(&mut s);
        for k in 0..4 {
            touch_slot(&spans, k, 4);
        }
        drop(spans);
        assert!(s.blob(0).iter().enumerate().all(|(i, &b)| b == (i * 7 % 251) as u8));
    }

    #[test]
    fn touch_slot_ranges_cover_disjointly() {
        // Pure-arithmetic check of the slot partition: ranges tile
        // [0, len) without overlap for awkward lengths.
        for len in [0usize, 1, 4095, 4096, 4097, 3 * 4096 + 123] {
            for slots in [2usize, 3, 5] {
                let mut prev_hi = 0;
                for k in 0..slots {
                    let lo = (len as u128 * k as u128 / slots as u128) as usize;
                    let hi = (len as u128 * (k + 1) as u128 / slots as u128) as usize;
                    assert_eq!(lo, prev_hi);
                    prev_hi = hi;
                }
                assert_eq!(prev_hi, len);
            }
        }
    }
}
