//! Small shared utilities. Currently: [`CachePadded`], the fix the
//! false-sharing audit (experiment E13, `benches/false_sharing.rs`)
//! prescribes for convicted concurrent structures.
//!
//! # False sharing
//!
//! Two atomics that live on the same 64-byte cache line ping-pong that
//! line between cores even when each core only ever touches its *own*
//! atomic: every `fetch_add` takes the line exclusive, invalidating the
//! other core's copy. The counters are logically independent but
//! physically coupled — that coupling is "false" sharing, and it shows
//! up in hardware counters as a cache-miss rate far above what the data
//! volume justifies (see `llama::counters`).
//!
//! [`CachePadded<T>`] breaks the coupling by aligning `T` to the cache
//! line, so two consecutive `CachePadded<AtomicU64>`s can never share
//! one. The cost is memory: 64 bytes per counter instead of 8. Use it
//! for *per-worker / per-shard* hot counters with a bounded count
//! (pool lease words, shard access counters); do NOT use it for bulk
//! per-element state like `Heatmap`'s line counters, where an 8×
//! memory bloat would defeat the instrument (§4 of the paper keeps
//! that overhead at 8 B per granule deliberately).
//!
//! 64 bytes covers x86-64 and current aarch64 cores. Some Apple/ARM
//! designs prefetch line *pairs* (128 B); we stick with 64 like the
//! kernel's `____cacheline_aligned` default — the bench measures the
//! actual machine, so a pair-prefetch penalty would still be caught.

/// The alignment [`CachePadded`] enforces, in bytes.
pub const CACHE_LINE: usize = 64;

/// Pads and aligns `T` to a 64-byte cache line so that adjacent values
/// in a `Vec` or struct never share a line. Transparent to use:
/// `Deref`/`DerefMut` pass through to `T`, so wrapping an
/// `AtomicU64` leaves every `.load()` / `.fetch_add()` call site
/// unchanged.
#[derive(Clone, Copy, Default, PartialEq, Eq, Hash)]
#[repr(align(64))]
pub struct CachePadded<T> {
    value: T,
}

impl<T> CachePadded<T> {
    /// Wrap `value`, padding it to a full cache line.
    pub const fn new(value: T) -> Self {
        Self { value }
    }

    /// Unwrap, discarding the padding.
    pub fn into_inner(self) -> T {
        self.value
    }
}

impl<T> std::ops::Deref for CachePadded<T> {
    type Target = T;

    fn deref(&self) -> &T {
        &self.value
    }
}

impl<T> std::ops::DerefMut for CachePadded<T> {
    fn deref_mut(&mut self) -> &mut T {
        &mut self.value
    }
}

impl<T: std::fmt::Debug> std::fmt::Debug for CachePadded<T> {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_tuple("CachePadded").field(&self.value).finish()
    }
}

impl<T> From<T> for CachePadded<T> {
    fn from(value: T) -> Self {
        Self::new(value)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicU64, Ordering};

    #[test]
    fn layout_is_at_least_one_cache_line() {
        assert_eq!(std::mem::align_of::<CachePadded<AtomicU64>>(), CACHE_LINE);
        assert_eq!(std::mem::size_of::<CachePadded<AtomicU64>>(), CACHE_LINE);
        assert_eq!(std::mem::align_of::<CachePadded<u8>>(), CACHE_LINE);
        assert_eq!(std::mem::size_of::<CachePadded<u8>>(), CACHE_LINE);
        // Larger-than-a-line payloads round up to whole lines.
        assert_eq!(std::mem::size_of::<CachePadded<[u8; 65]>>(), 2 * CACHE_LINE);
    }

    #[test]
    fn adjacent_vec_elements_never_share_a_line() {
        let v: Vec<CachePadded<AtomicU64>> =
            (0..4).map(|i| CachePadded::new(AtomicU64::new(i))).collect();
        for pair in v.windows(2) {
            let a = &*pair[0] as *const AtomicU64 as usize;
            let b = &*pair[1] as *const AtomicU64 as usize;
            assert!(a / CACHE_LINE != b / CACHE_LINE, "elements share line");
        }
    }

    #[test]
    fn deref_passes_through() {
        let c = CachePadded::new(AtomicU64::new(7));
        c.fetch_add(3, Ordering::Relaxed);
        assert_eq!(c.load(Ordering::Relaxed), 10);
        assert_eq!(c.into_inner().into_inner(), 10);

        let mut m = CachePadded::new(5u32);
        *m += 1;
        assert_eq!(*m, 6);
        assert_eq!(CachePadded::from(6u32), m);
        assert_eq!(format!("{m:?}"), "CachePadded(6)");
    }

    #[test]
    fn default_and_clone() {
        let d: CachePadded<u64> = CachePadded::default();
        assert_eq!(*d, 0);
        let c = d;
        assert_eq!(*c, 0);
    }
}
