//! L3 coordinator: the layout-lab job service.
//!
//! The paper's system is a library, so the coordinator is the *lab* around
//! it: it accepts simulation jobs (layout × backend × size × steps),
//! batches compatible jobs (same executable / code path) for dispatch,
//! routes them across a worker pool, executes either through the native
//! LLAMA views (L3) or the AOT Pallas artifacts via PJRT (L1/L2), and
//! aggregates metrics. Python never appears on this path.
//!
//! ```text
//! Ingest::submit*() ─► bounded queue ─► dispatcher (batches by batch_key, FIFO)
//!  (admission control:      │
//!   reject-with-retry /     │
//!   block-with-deadline,    │
//!   per-client quotas)      │
//!              ┌────────────┼───────────┐
//!           worker 0     worker 1    worker W   (std threads)
//!              │             │           │
//!         native views    native     PJRT Engine (shared, compiled once)
//!              │             │
//!         parallel kernels on a leased thread budget
//!         (crate worker pool; one big job saturates idle workers)
//! ```
//!
//! Native jobs run the **parallel** n-body kernels
//! (`views::update_simd_par_on` / `update_scalar_par_on`) with a thread
//! budget leased from the coordinator's [`crate::pool::WorkerPool`]
//! ([`Config::pool`], default the crate-global pool): a single large
//! job on an idle pool is granted the whole budget instead of running
//! single-threaded next to parked workers, while concurrent jobs split
//! the budget between their leases. The parallel kernels are
//! bit-identical to the serial ones, so routing through them is a pure
//! wall-clock change.
//!
//! Submissions pass through the bounded **ingestion queue** ([`ingest`]):
//! callers obtain a clonable [`Ingest`] handle and choose the admission
//! behavior on a full queue — fail fast with a retry-after hint or block
//! up to a deadline ([`Admission`]) — with optional per-client quotas on
//! queue occupancy. Queue depth, rejects, and admission waits land in
//! [`Metrics`]. See `docs/SERVING.md` for the semantics.
//!
//! **Fault tolerance:** every job attempt runs under
//! `std::panic::catch_unwind`, so a panicking kernel becomes a typed
//! [`JobResult::error`] on a *surviving* worker, never a dead thread.
//! Failed attempts (panic or error) are re-dispatched in place per
//! [`Config::retry`] ([`RetryPolicy`]: exponential backoff with
//! deterministic per-(job, attempt) jitter); [`Config::faults`] accepts
//! a seeded [`crate::fault::FaultPlan`] that injects job panics and
//! delays for chaos testing (`LLAMA_FAULT_SEED`). Caught panics,
//! retries, and checksum-rejected wire frames all land in [`Metrics`].
//! See `docs/SERVING.md` §5 "Failure model".
//!
//! Invariants (checked by `rust/tests/properties.rs`,
//! `rust/tests/ingestion.rs`, and `rust/tests/faults.rs`):
//! - every *admitted* job completes exactly once (success or error);
//! - batches never exceed `max_batch` and never mix batch keys;
//! - jobs with the same batch key dispatch in FIFO order;
//! - queue depth never exceeds [`Config::queue_capacity`];
//! - a panicking job never kills its worker, and a job never runs more
//!   than [`RetryPolicy::max_attempts`] times.

pub mod ingest;
pub mod job;
pub mod metrics;

pub use ingest::{Admission, Ingest, SubmitError};
pub use job::{Backend, JobResult, JobSpec, Layout};
pub use metrics::Metrics;

use ingest::Queued;

use std::sync::mpsc;
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use crate::blob::BlobStorage;
use crate::fault::{FaultPlan, JobFault};
use crate::mapping::SimdAccess;
use crate::nbody::{init_particles, total_energy, views, Particle, ParticleData};
use crate::pool::WorkerPool;
use crate::runtime::{PjrtService, TensorF32};
use crate::view::View;

/// Coordinator configuration.
#[derive(Clone)]
pub struct Config {
    /// Worker thread count.
    pub workers: usize,
    /// Max jobs per dispatch batch.
    pub max_batch: usize,
    /// PJRT service handle (required for [`Backend::Pjrt`] jobs).
    pub engine: Option<PjrtService>,
    /// Worker pool the native parallel kernels dispatch on (`None` =
    /// the crate-global pool, [`crate::pool::global`]). Tests and
    /// benches pass an explicitly sized pool for determinism.
    pub pool: Option<Arc<WorkerPool>>,
    /// Default per-job thread-budget request for native jobs whose
    /// [`JobSpec::threads`] is 0 (`0` = lease as much of the pool as
    /// is uncommitted — one big job on an idle pool saturates it).
    pub native_threads: usize,
    /// Capacity of the bounded ingestion queue: jobs admitted but not
    /// yet dispatched. Full-queue behavior is per-submission
    /// ([`Admission`]).
    pub queue_capacity: usize,
    /// Max ingestion-queue slots any single client may occupy at once
    /// via [`Ingest::submit_from`] (0 = no per-client cap). Fairness
    /// between *running* jobs is separate: thread budgets are leased
    /// per job from the worker pool.
    pub client_quota: usize,
    /// Retry policy for failed/panicked job attempts. The default runs
    /// each job exactly once (no retries) — existing behavior.
    pub retry: RetryPolicy,
    /// Optional seeded fault plan injecting job panics/delays
    /// ([`crate::fault::FaultPlan::job_fault`]) — the chaos-testing
    /// hook. `None` (the default) injects nothing.
    pub faults: Option<FaultPlan>,
    /// Adaptive relayout (`false` by default, the existing behavior):
    /// when set, the first native job of each batch key — and every
    /// [`RETRACE_EVERY`]-th thereafter, so the choice follows traffic
    /// shifts — runs on a [`FieldAccessCount`]-instrumented view; the
    /// recorded [`crate::tune::AccessTrace`] is scored by the
    /// [`crate::tune::Planner`] over the layouts the native engine can
    /// run, and the winner overrides the key's layout for subsequent
    /// jobs. Traces and relayout decisions land in [`Metrics`]
    /// (`traces_recorded` / `relayouts_performed` /
    /// `relayouts_skipped`). Results stay exact: the instrumented run
    /// computes the same physics, only the storage layout of later
    /// jobs changes.
    ///
    /// [`FieldAccessCount`]: crate::mapping::field_access_count::FieldAccessCount
    pub autotune: bool,
}

impl Default for Config {
    fn default() -> Self {
        Config {
            workers: 2,
            max_batch: 8,
            engine: None,
            pool: None,
            native_threads: 0,
            queue_capacity: 1024,
            client_quota: 0,
            retry: RetryPolicy::default(),
            faults: None,
            autotune: false,
        }
    }
}

/// Native jobs per batch key between instrumented re-traces in
/// autotune mode ([`Config::autotune`]): a decision is reused this many
/// times, then the next job re-traces so the layout choice tracks
/// shifting traffic.
pub const RETRACE_EVERY: u32 = 8;

/// Per-coordinator autotune state: the planner's latest decision per
/// batch key, plus the metrics registry the decisions are counted in.
struct TuneShared {
    decisions: Mutex<std::collections::HashMap<(Layout, Backend, usize), TuneDecision>>,
    metrics: Arc<Metrics>,
}

/// The layout the planner chose for one batch key, and how many jobs
/// ran on it since the trace that chose it.
struct TuneDecision {
    layout: Layout,
    jobs_since_trace: u32,
}

/// The candidate the cost model scores for a native [`Layout`] (bf16 is
/// a PJRT artifact; natively it runs as f32 SoA, so it maps there).
fn layout_candidate(l: Layout) -> crate::tune::Candidate {
    match l {
        Layout::Aos => crate::tune::Candidate::Aos,
        Layout::SoaMb | Layout::Bf16 => crate::tune::Candidate::SoaMb,
        Layout::Aosoa => crate::tune::Candidate::Aosoa { lanes: 8 },
    }
}

/// The native [`Layout`] that realizes a planner candidate. Only called
/// on candidates from the coordinator's own restricted set, but total
/// anyway: column-ish exotics degrade to SoA-MB, the closest runnable
/// layout.
fn candidate_layout(c: crate::tune::Candidate) -> Layout {
    match c {
        crate::tune::Candidate::Aos => Layout::Aos,
        crate::tune::Candidate::Aosoa { .. } => Layout::Aosoa,
        _ => Layout::SoaMb,
    }
}

/// How failed job attempts are re-dispatched: up to `max_attempts`
/// total runs, sleeping an exponentially growing, deterministically
/// jittered backoff between them.
///
/// The backoff for the `k`-th failed attempt is
/// `min(cap, base × 2^(k−1))`, of which half is kept and half is
/// jittered by a stable hash of `(job id, k)` ("equal jitter") — so
/// simultaneous failures don't re-dispatch in lockstep, yet every run
/// with the same ids sleeps the same schedule (no wall-clock, no global
/// RNG; reproducibility is the point of the whole fault layer).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct RetryPolicy {
    /// Total attempts per job, ≥ 1 (1 = no retries, the default).
    pub max_attempts: u32,
    /// Backoff before the first retry.
    pub base: Duration,
    /// Upper bound on any single backoff sleep.
    pub cap: Duration,
}

impl Default for RetryPolicy {
    fn default() -> Self {
        RetryPolicy {
            max_attempts: 1,
            base: Duration::from_millis(5),
            cap: Duration::from_millis(250),
        }
    }
}

impl RetryPolicy {
    /// Policy allowing `n` retries (`n + 1` total attempts) with the
    /// default backoff shape.
    pub fn retries(n: u32) -> RetryPolicy {
        RetryPolicy { max_attempts: n.saturating_add(1), ..RetryPolicy::default() }
    }

    /// Sleep before re-dispatching after failed attempt number
    /// `failed_attempt` (1-based) of job `job`.
    pub fn backoff(&self, failed_attempt: u32, job: u64) -> Duration {
        let shift = failed_attempt.saturating_sub(1).min(20);
        let capped = self.base.saturating_mul(1u32 << shift).min(self.cap);
        let half = capped / 2;
        let jitter_ns = if half.is_zero() {
            0
        } else {
            crate::fault::hash2(job, u64::from(failed_attempt))
                % (half.as_nanos().max(1) as u64)
        };
        half + Duration::from_nanos(jitter_ns)
    }
}

/// The layout-lab coordinator. See module docs.
pub struct Coordinator {
    ingest: Ingest,
    /// `None` once [`Coordinator::take_results`] handed the stream to an
    /// external consumer (the serving tier's result router).
    results_rx: Option<mpsc::Receiver<JobResult>>,
    dispatcher: Option<std::thread::JoinHandle<()>>,
    workers: Vec<std::thread::JoinHandle<()>>,
    metrics: Arc<Metrics>,
}

impl Coordinator {
    /// Start the worker pool and dispatcher.
    pub fn start(config: Config) -> Self {
        let metrics = Arc::new(Metrics::default());
        let ingest = Ingest::new(
            config.queue_capacity,
            config.client_quota,
            config.workers.max(1),
            metrics.clone(),
        );
        // The dispatcher→worker hand-off is *bounded* (one in-flight
        // batch per worker beyond the ones being executed): with an
        // unbounded channel the dispatcher would drain the ingestion
        // queue into the channel as fast as it can pop, and
        // `queue_capacity` would bound nothing — admission control
        // (QueueFull, quotas, retry-after hints) only bites if admitted
        // work actually accumulates in the queue while workers are busy.
        let (batch_tx, batch_rx) =
            mpsc::sync_channel::<(u64, Vec<Queued>)>(config.workers.max(1));
        let batch_rx = Arc::new(Mutex::new(batch_rx));
        let (results_tx, results_rx) = mpsc::channel::<JobResult>();

        // Dispatcher: the queue's single consumer (preserving FIFO
        // dispatch order), grouping runs of equal batch_key up to
        // max_batch and handing batches to workers.
        let max_batch = config.max_batch.max(1);
        let dmetrics = metrics.clone();
        let dingest = ingest.clone();
        let dispatcher = std::thread::spawn(move || {
            let mut batch_id = 0u64;
            let mut pending: Option<Queued> = None;
            loop {
                // Block for the first job of the next batch.
                let first = match pending.take() {
                    Some(q) => q,
                    None => match dingest.next_job() {
                        Some(q) => q,
                        None => break, // queue closed: drain done
                    },
                };
                let key = first.spec.batch_key();
                let mut batch = vec![first];
                // Greedily take more of the same key without blocking.
                while batch.len() < max_batch {
                    match dingest.try_next_job() {
                        Some(q) if q.spec.batch_key() == key => batch.push(q),
                        Some(q) => {
                            pending = Some(q);
                            break;
                        }
                        None => break,
                    }
                }
                dmetrics.on_batch(batch.len());
                if batch_tx.send((batch_id, batch)).is_err() {
                    break;
                }
                batch_id += 1;
            }
        });

        // Workers.
        let tune: Option<Arc<TuneShared>> = config.autotune.then(|| {
            Arc::new(TuneShared {
                decisions: Mutex::new(std::collections::HashMap::new()),
                metrics: metrics.clone(),
            })
        });
        let mut workers = Vec::new();
        for widx in 0..config.workers.max(1) {
            let rx = batch_rx.clone();
            let results = results_tx.clone();
            let engine = config.engine.clone();
            let pool = config.pool.clone();
            let native_threads = config.native_threads;
            let retry = config.retry;
            let faults = config.faults.clone();
            let tune = tune.clone();
            let wmetrics = metrics.clone();
            workers.push(std::thread::spawn(move || loop {
                let next = { rx.lock().unwrap().recv() };
                let (batch_id, batch) = match next {
                    Ok(b) => b,
                    Err(_) => break,
                };
                // Native kernels dispatch on the configured pool (or
                // the crate-global one); budgets are leased per job.
                // With `LLAMA_POOL=off` and no explicit pool, honor the
                // opt-out: no persistent pool is ever constructed and
                // the kernels fall back to per-call scoped dispatch.
                let kernel_pool: Option<&WorkerPool> = pool
                    .as_deref()
                    .or_else(|| crate::pool::pooled_dispatch().then(crate::pool::global));
                for q in batch {
                    let queue_time = q.submitted_at.elapsed();
                    let t0 = Instant::now();
                    let max_attempts = retry.max_attempts.max(1);
                    let mut attempt: u32 = 1;
                    // Attempt loop: panics are caught (the worker
                    // survives any kernel), failed attempts back off
                    // and re-run in place up to the policy's budget.
                    // Pool kernel panics are safe to catch here: the
                    // pool resumes a shard panic on this (submitter)
                    // thread only after draining the batch, so the
                    // pool itself stays consistent.
                    let outcome = loop {
                        let injected = match &faults {
                            Some(p) => p.job_fault(q.spec.id, attempt - 1),
                            None => JobFault::None,
                        };
                        let caught =
                            std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                                match injected {
                                    JobFault::Panic => {
                                        panic!("injected fault: job panic (attempt {attempt})")
                                    }
                                    JobFault::Delay(d) => std::thread::sleep(d),
                                    JobFault::None => {}
                                }
                                run_job(
                                    &q.spec,
                                    engine.as_ref(),
                                    kernel_pool,
                                    native_threads,
                                    tune.as_deref(),
                                )
                            }));
                        let attempt_result = match caught {
                            Ok(r) => r,
                            Err(payload) => {
                                wmetrics.on_job_panic();
                                Err(anyhow::anyhow!(
                                    "job panicked: {}",
                                    panic_message(payload.as_ref())
                                ))
                            }
                        };
                        match attempt_result {
                            Ok(ok) => break Ok(ok),
                            Err(_) if attempt < max_attempts => {
                                wmetrics.on_job_retry();
                                std::thread::sleep(retry.backoff(attempt, q.spec.id));
                                attempt += 1;
                            }
                            Err(e) => break Err(e),
                        }
                    };
                    let exec_time = t0.elapsed();
                    let (drift, threads, error) = match outcome {
                        Ok((d, t)) => (d, t, None),
                        Err(e) => (f64::NAN, 0, Some(format!("{e:#}"))),
                    };
                    wmetrics.on_complete(queue_time, exec_time, error.is_some());
                    let _ = results.send(JobResult {
                        id: q.spec.id,
                        worker: widx,
                        batch_id,
                        exec_time,
                        queue_time,
                        energy_drift: drift,
                        steps_per_sec: q.spec.steps as f64 / exec_time.as_secs_f64().max(1e-12),
                        threads,
                        attempts: attempt,
                        error,
                    });
                }
            }));
        }
        drop(results_tx);

        Coordinator {
            ingest,
            results_rx: Some(results_rx),
            dispatcher: Some(dispatcher),
            workers,
            metrics,
        }
    }

    /// Take ownership of the result stream: every [`JobResult`] the
    /// workers produce, in completion order, ending when the
    /// coordinator drains after [`Ingest::close`].
    ///
    /// For streaming consumers (the TCP serving tier routes results to
    /// waiting connections as they complete) instead of the batch
    /// collection in [`Coordinator::finish`]. Can be taken once;
    /// afterwards `finish` only joins the threads and returns an empty
    /// vec — the stream owner has the results.
    pub fn take_results(&mut self) -> Option<mpsc::Receiver<JobResult>> {
        self.results_rx.take()
    }

    /// Submit a job, blocking without a deadline while the queue is
    /// full; returns its assigned id.
    ///
    /// Thin wrapper over [`Ingest::submit`]. For fail-fast admission,
    /// deadlines, or per-client accounting, take an [`Coordinator::ingest`]
    /// handle and pick an [`Admission`] policy explicitly.
    pub fn submit(&mut self, spec: JobSpec) -> u64 {
        self.ingest.submit(spec).expect("coordinator ingestion queue closed")
    }

    /// A clonable submission handle feeding this coordinator's bounded
    /// ingestion queue; safe to hand to concurrent producer threads.
    pub fn ingest(&self) -> Ingest {
        self.ingest.clone()
    }

    /// The metrics registry.
    pub fn metrics(&self) -> &Metrics {
        &self.metrics
    }

    /// An owning handle to the metrics registry, outliving
    /// [`Coordinator::finish`] (which consumes the coordinator) —
    /// the registry is shared, so counters keep reflecting the run.
    pub fn metrics_handle(&self) -> Arc<Metrics> {
        self.metrics.clone()
    }

    /// Close the queue, wait for all admitted jobs, return their results
    /// sorted by id.
    ///
    /// Outstanding [`Ingest`] handles fail with [`SubmitError::Closed`]
    /// from here on; quiesce producer threads first if every submission
    /// must be admitted. If [`Coordinator::take_results`] was called,
    /// the stream owner has the results: this joins the threads and
    /// returns an empty vec.
    pub fn finish(mut self) -> Vec<JobResult> {
        self.ingest.close(); // dispatcher drains the queue and exits
        let admitted = self.ingest.admitted() as usize; // exact after close
        let mut results = Vec::with_capacity(admitted);
        if let Some(rx) = &self.results_rx {
            for _ in 0..admitted {
                match rx.recv() {
                    Ok(r) => results.push(r),
                    Err(_) => break,
                }
            }
        }
        if let Some(d) = self.dispatcher.take() {
            let _ = d.join();
        }
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
        results.sort_by_key(|r| r.id);
        results
    }
}

impl Drop for Coordinator {
    fn drop(&mut self) {
        // Abandoning a coordinator without `finish` must not leave the
        // dispatcher parked on the queue forever.
        self.ingest.close();
    }
}

/// Best-effort text of a caught panic payload (`panic!` with a string
/// literal or a formatted message covers essentially all of std and
/// this crate).
fn panic_message(payload: &(dyn std::any::Any + Send)) -> &str {
    if let Some(s) = payload.downcast_ref::<&'static str>() {
        s
    } else if let Some(s) = payload.downcast_ref::<String>() {
        s.as_str()
    } else {
        "non-string panic payload"
    }
}

/// Execute one job, returning the relative energy drift and the thread
/// budget it ran with. `pool: None` means "pooling opted out"
/// (`LLAMA_POOL=off` with no explicit [`Config::pool`]): native
/// kernels then use per-call scoped dispatch at the requested budget.
fn run_job(
    spec: &JobSpec,
    engine: Option<&PjrtService>,
    pool: Option<&WorkerPool>,
    default_want: usize,
    tune: Option<&TuneShared>,
) -> anyhow::Result<(f64, usize)> {
    let init = init_particles(spec.n, spec.seed);
    let e0 = total_energy(&init);
    let (finals, threads): (Vec<ParticleData>, usize) = match (spec.backend, tune) {
        (Backend::Pjrt, _) => (run_pjrt(spec, engine, &init)?, 1),
        (Backend::NativeScalar | Backend::NativeSimd, Some(t)) => {
            run_native_tuned(spec, &init, pool, default_want, t)
        }
        (Backend::NativeScalar | Backend::NativeSimd, None) => {
            run_native(spec, &init, pool, default_want)
        }
    };
    let e1 = total_energy(&finals);
    Ok((((e1 - e0) / e0).abs(), threads))
}

/// Run `spec.steps` steps of one native job through the **parallel**
/// kernels, with a thread budget leased from `pool` for the job's
/// duration: one big job on an idle pool saturates the workers that
/// batching small jobs would leave parked, while concurrent jobs split
/// the pool instead of oversubscribing it. A granted budget of 1
/// degrades to the serial engine (the sharded entry points refuse
/// single-shard splits), and the parallel kernels are bit-identical to
/// the serial ones at any budget — routing through them changes
/// wall-clock time, never results.
fn run_native(
    spec: &JobSpec,
    init: &[ParticleData],
    pool: Option<&WorkerPool>,
    default_want: usize,
) -> (Vec<ParticleData>, usize) {
    let want = if spec.threads > 0 { spec.threads } else { default_want };
    // With a pool, the budget is leased (concurrent jobs split the
    // capacity; the lease returns on drop at the end of this job).
    // Without one (`LLAMA_POOL=off`), the requested budget is used
    // as-is on per-call scoped dispatch.
    let lease = pool.map(|p| p.lease(want));
    let threads = match &lease {
        Some(lease) => lease.threads(),
        None => if want > 0 { want } else { crate::shard::thread_count() },
    };
    let simd = spec.backend == Backend::NativeSimd;

    let finals = match spec.layout {
        Layout::Aos => {
            let mut v = views::make_aos_view(init);
            native_steps(&mut v, simd, spec.steps, pool, threads);
            views::snapshot_view(&v)
        }
        Layout::SoaMb | Layout::Bf16 => {
            // Native bf16 falls back to f32 SoA (bf16 is a PJRT artifact).
            let mut v = views::make_soa_view(init);
            native_steps(&mut v, simd, spec.steps, pool, threads);
            views::snapshot_view(&v)
        }
        Layout::Aosoa => {
            let mut v = views::make_aosoa_view(init);
            native_steps(&mut v, simd, spec.steps, pool, threads);
            views::snapshot_view(&v)
        }
    };
    (finals, threads)
}

/// The layout-generic native stepping loop (hoisted from [`run_native`]
/// so the instrumented autotune run reuses it unchanged on
/// `FieldAccessCount`-wrapped mappings).
fn native_steps<M, S>(
    v: &mut View<Particle, M, S>,
    simd: bool,
    n_steps: usize,
    pool: Option<&WorkerPool>,
    threads: usize,
) where
    M: SimdAccess<Particle>,
    S: BlobStorage + Send + Sync,
{
    for _ in 0..n_steps {
        match (pool, simd) {
            (Some(pool), true) => {
                views::update_simd_par_on::<8, _, _>(v, pool, threads);
                views::move_simd_par_on::<8, _, _>(v, pool, threads);
            }
            (Some(pool), false) => {
                views::update_scalar_par_on(v, pool, threads);
                views::move_scalar_par_on(v, pool, threads);
            }
            (None, true) => {
                views::update_simd_par_scoped::<8, _, _>(v, threads);
                views::move_simd_par_scoped::<8, _, _>(v, threads);
            }
            (None, false) => {
                views::update_scalar_par(v, threads);
                views::move_scalar_par(v, threads);
            }
        }
    }
}

/// Autotuned native execution ([`Config::autotune`]): reuse the batch
/// key's fresh planner decision if one exists, otherwise run this job
/// instrumented, record its [`crate::tune::AccessTrace`], and let the
/// planner pick the layout the key runs on next.
///
/// The decision map is locked only around lookup/update — the job
/// itself (trace run included) executes outside the lock, so workers
/// tracing different keys never serialize each other.
fn run_native_tuned(
    spec: &JobSpec,
    init: &[ParticleData],
    pool: Option<&WorkerPool>,
    default_want: usize,
    tune: &TuneShared,
) -> (Vec<ParticleData>, usize) {
    let key = spec.batch_key();
    // Decide under the lock: run on the decided layout, or re-trace.
    let mode: Result<Layout, Layout> = {
        let mut map = tune.decisions.lock().unwrap();
        match map.get_mut(&key) {
            Some(d) if d.jobs_since_trace < RETRACE_EVERY => {
                d.jobs_since_trace += 1;
                Ok(d.layout)
            }
            Some(d) => Err(d.layout), // decision went stale: re-trace
            None => Err(spec.layout), // first sighting of this key
        }
    };
    match mode {
        Ok(layout) => {
            let eff = JobSpec { layout, ..spec.clone() };
            run_native(&eff, init, pool, default_want)
        }
        Err(current) => {
            let (finals, threads, trace) =
                run_native_traced(spec, current, init, pool, default_want);
            tune.metrics.on_trace_recorded();
            // Restrict the planner to the layouts the native engine
            // runs; the trace's origin makes the cost model charge
            // migration only to actual layout changes.
            let plan = crate::tune::Planner::new().recommend_among(
                &trace,
                &[
                    crate::tune::Candidate::Aos,
                    crate::tune::Candidate::SoaMb,
                    crate::tune::Candidate::Aosoa { lanes: 8 },
                ],
            );
            let chosen = candidate_layout(plan.chosen);
            if chosen != current {
                tune.metrics.on_relayout_performed();
            } else {
                tune.metrics.on_relayout_skipped();
            }
            tune.decisions
                .lock()
                .unwrap()
                .insert(key, TuneDecision { layout: chosen, jobs_since_trace: 0 });
            (finals, threads)
        }
    }
}

/// Run one native job on a [`FieldAccessCount`]-instrumented view of
/// `layout`, returning the physics result plus the recorded trace.
/// Instrumentation counts with relaxed atomics on the hot path; the
/// physics is identical to [`run_native`] at the same layout.
///
/// [`FieldAccessCount`]: crate::mapping::field_access_count::FieldAccessCount
fn run_native_traced(
    spec: &JobSpec,
    layout: Layout,
    init: &[ParticleData],
    pool: Option<&WorkerPool>,
    default_want: usize,
) -> (Vec<ParticleData>, usize, crate::tune::AccessTrace) {
    use crate::blob::{alloc_view, AlignedAlloc};
    use crate::mapping::field_access_count::FieldAccessCount;

    let want = if spec.threads > 0 { spec.threads } else { default_want };
    let lease = pool.map(|p| p.lease(want));
    let threads = match &lease {
        Some(lease) => lease.threads(),
        None => if want > 0 { want } else { crate::shard::thread_count() },
    };
    let simd = spec.backend == Backend::NativeSimd;
    let ext = (crate::extents::Dyn(init.len() as u32),);
    let origin = layout_candidate(layout).name();

    macro_rules! traced {
        ($map:expr) => {{
            let mut v = alloc_view(FieldAccessCount::new($map), &AlignedAlloc::<64>);
            views::fill_view(&mut v, init);
            native_steps(&mut v, simd, spec.steps, pool, threads);
            let trace = crate::tune::AccessTrace::record(&v).with_origin(&origin);
            (views::snapshot_view(&v), trace)
        }};
    }
    let (finals, trace) = match layout {
        Layout::Aos => traced!(views::AosMap::new(ext)),
        Layout::SoaMb | Layout::Bf16 => traced!(views::SoaMbMap::new(ext)),
        Layout::Aosoa => traced!(views::AosoaMap::new(ext)),
    };
    (finals, threads, trace)
}

fn run_pjrt(
    spec: &JobSpec,
    engine: Option<&PjrtService>,
    init: &[ParticleData],
) -> anyhow::Result<Vec<ParticleData>> {
    let engine = engine.ok_or_else(|| anyhow::anyhow!("no PJRT engine configured"))?;
    let artifact = spec.layout.artifact();
    engine.load(artifact)?;

    match spec.layout {
        Layout::SoaMb | Layout::Bf16 => {
            let sim = crate::nbody::manual::SoaSim::new(init);
            let mut state: Vec<TensorF32> =
                [&sim.px, &sim.py, &sim.pz, &sim.vx, &sim.vy, &sim.vz, &sim.mass]
                    .into_iter()
                    .map(|v| TensorF32::vec(v.clone()))
                    .collect();
            for _ in 0..spec.steps {
                let out = engine.execute_f32(artifact, &state)?;
                let mass = state[6].clone();
                state = out;
                state.push(mass);
            }
            Ok((0..spec.n)
                .map(|i| ParticleData {
                    pos: crate::nbody::PVec {
                        x: state[0].data[i],
                        y: state[1].data[i],
                        z: state[2].data[i],
                    },
                    vel: crate::nbody::PVec {
                        x: state[3].data[i],
                        y: state[4].data[i],
                        z: state[5].data[i],
                    },
                    mass: state[6].data[i],
                })
                .collect())
        }
        Layout::Aos => {
            let mut data = Vec::with_capacity(spec.n * 7);
            for p in init {
                data.extend_from_slice(&[
                    p.pos.x, p.pos.y, p.pos.z, p.vel.x, p.vel.y, p.vel.z, p.mass,
                ]);
            }
            let mut state = TensorF32::new(data, vec![spec.n, 7]);
            for _ in 0..spec.steps {
                state = engine.execute_f32(artifact, &[state])?.remove(0);
            }
            Ok((0..spec.n)
                .map(|i| ParticleData {
                    pos: crate::nbody::PVec {
                        x: state.data[i * 7],
                        y: state.data[i * 7 + 1],
                        z: state.data[i * 7 + 2],
                    },
                    vel: crate::nbody::PVec {
                        x: state.data[i * 7 + 3],
                        y: state.data[i * 7 + 4],
                        z: state.data[i * 7 + 5],
                    },
                    mass: state.data[i * 7 + 6],
                })
                .collect())
        }
        Layout::Aosoa => {
            const L: usize = 8;
            let nb = spec.n / L;
            let mut data = vec![0.0f32; spec.n * 7];
            for (i, p) in init.iter().enumerate() {
                let (b, k) = (i / L, i % L);
                let fields = [p.pos.x, p.pos.y, p.pos.z, p.vel.x, p.vel.y, p.vel.z, p.mass];
                for (f, v) in fields.iter().enumerate() {
                    data[b * 7 * L + f * L + k] = *v;
                }
            }
            let mut state = TensorF32::new(data, vec![nb, 7, L]);
            for _ in 0..spec.steps {
                state = engine.execute_f32(artifact, &[state])?.remove(0);
            }
            Ok((0..spec.n)
                .map(|i| {
                    let (b, k) = (i / L, i % L);
                    let g = |f: usize| state.data[b * 7 * L + f * L + k];
                    ParticleData {
                        pos: crate::nbody::PVec { x: g(0), y: g(1), z: g(2) },
                        vel: crate::nbody::PVec { x: g(3), y: g(4), z: g(5) },
                        mass: g(6),
                    }
                })
                .collect())
        }
    }
}

/// Render job results as an aligned table.
pub fn render_results(specs: &[JobSpec], results: &[JobResult]) -> String {
    let mut out = format!(
        "{:>4}  {:>9}  {:>14}  {:>6}  {:>6}  {:>4}  {:>12}  {:>10}  {}\n",
        "id", "layout", "backend", "worker", "batch", "thr", "exec", "steps/s", "drift"
    );
    for r in results {
        let spec = specs.iter().find(|s| s.id == r.id);
        out.push_str(&format!(
            "{:>4}  {:>9}  {:>14}  {:>6}  {:>6}  {:>4}  {:>12}  {:>10.1}  {}\n",
            r.id,
            spec.map(|s| s.layout.name()).unwrap_or("?"),
            spec.map(|s| s.backend.name()).unwrap_or("?"),
            r.worker,
            r.batch_id,
            r.threads,
            format!("{:.2?}", r.exec_time),
            r.steps_per_sec,
            if let Some(e) = &r.error { e.clone() } else { format!("{:.1e}", r.energy_drift) },
        ));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn spec(layout: Layout, backend: Backend, n: usize, steps: usize) -> JobSpec {
        JobSpec { id: 0, layout, backend, n, steps, seed: 1, threads: 0 }
    }

    #[test]
    fn native_jobs_complete() {
        let mut c =
            Coordinator::start(Config { workers: 2, max_batch: 4, ..Config::default() });
        for layout in [Layout::Aos, Layout::SoaMb, Layout::Aosoa] {
            c.submit(spec(layout, Backend::NativeScalar, 64, 2));
            c.submit(spec(layout, Backend::NativeSimd, 64, 2));
        }
        let results = c.finish();
        assert_eq!(results.len(), 6);
        for r in &results {
            assert!(r.error.is_none(), "{:?}", r.error);
            assert!(r.energy_drift < 1e-2);
            assert!(r.steps_per_sec > 0.0);
            assert!(r.threads >= 1);
        }
        let ids: Vec<u64> = results.iter().map(|r| r.id).collect();
        assert_eq!(ids, (0..6).collect::<Vec<_>>());
    }

    #[test]
    fn pjrt_jobs_error_without_engine() {
        let mut c =
            Coordinator::start(Config { workers: 1, max_batch: 2, ..Config::default() });
        c.submit(spec(Layout::SoaMb, Backend::Pjrt, 64, 1));
        let results = c.finish();
        assert_eq!(results.len(), 1);
        assert!(results[0].error.as_deref().unwrap_or("").contains("no PJRT engine"));
    }

    #[test]
    fn single_large_native_job_saturates_the_pool() {
        // The headline of the routing change: one big job on a single
        // coordinator worker leases the whole (idle) pool instead of
        // running single-threaded next to parked workers — and the
        // result is still exactly-once and physically sane.
        let pool = Arc::new(WorkerPool::with_pinning(4, false));
        let mut c = Coordinator::start(Config {
            workers: 1,
            max_batch: 4,
            pool: Some(pool),
            ..Config::default()
        });
        c.submit(spec(Layout::SoaMb, Backend::NativeSimd, 256, 2));
        let results = c.finish();
        assert_eq!(results.len(), 1);
        assert!(results[0].error.is_none(), "{:?}", results[0].error);
        assert_eq!(results[0].threads, 4, "idle 4-thread pool fully leased");
        assert!(results[0].energy_drift < 1e-2);
    }

    #[test]
    fn per_job_thread_budget_request_is_honored() {
        let pool = Arc::new(WorkerPool::with_pinning(4, false));
        let mut c = Coordinator::start(Config {
            workers: 1,
            max_batch: 4,
            pool: Some(pool),
            ..Config::default()
        });
        let mut want2 = spec(Layout::Aosoa, Backend::NativeScalar, 128, 1);
        want2.threads = 2;
        c.submit(want2);
        let results = c.finish();
        assert_eq!(results[0].threads, 2, "JobSpec::threads caps the lease");
        assert!(results[0].error.is_none());
    }

    #[test]
    fn native_results_identical_across_thread_budgets() {
        // The parallel kernels are bit-identical to serial, so the
        // energy drift must not depend on the granted budget.
        let drift_at = |threads: usize| -> f64 {
            let pool = Arc::new(WorkerPool::with_pinning(4, false));
            let mut c = Coordinator::start(Config {
                workers: 1,
                max_batch: 2,
                pool: Some(pool),
                ..Config::default()
            });
            let mut s = spec(Layout::SoaMb, Backend::NativeSimd, 96, 3);
            s.threads = threads;
            c.submit(s);
            let results = c.finish();
            assert!(results[0].error.is_none());
            assert_eq!(results[0].threads, threads);
            results[0].energy_drift
        };
        let d1 = drift_at(1);
        assert_eq!(d1.to_bits(), drift_at(2).to_bits());
        assert_eq!(d1.to_bits(), drift_at(4).to_bits());
    }

    #[test]
    fn batching_respects_limits_and_completes() {
        let mut c =
            Coordinator::start(Config { workers: 1, max_batch: 8, ..Config::default() });
        for _ in 0..6 {
            c.submit(spec(Layout::SoaMb, Backend::NativeScalar, 64, 1));
        }
        assert_eq!(c.metrics().job_counts().0, 6);
        let results = c.finish();
        assert_eq!(results.len(), 6);
        let m_max = results.iter().map(|r| r.batch_id).max().unwrap();
        assert!(m_max < 6); // batched into <= 6 batches
    }

    #[test]
    fn autotune_relays_hot_keys_to_the_planner_choice() {
        let mut c = Coordinator::start(Config {
            workers: 2,
            max_batch: 4,
            autotune: true,
            ..Config::default()
        });
        let m = c.metrics_handle();
        // Two batch keys: an AoS key (the n-body pattern is
        // column-friendly, so the planner relayouts it to SoA) and a
        // SoA key (already optimal: the trace confirms it).
        for _ in 0..4 {
            c.submit(spec(Layout::Aos, Backend::NativeSimd, 64, 2));
            c.submit(spec(Layout::SoaMb, Backend::NativeScalar, 64, 2));
        }
        let results = c.finish();
        assert_eq!(results.len(), 8);
        for r in &results {
            assert!(r.error.is_none(), "{:?}", r.error);
            assert!(r.energy_drift < 1e-2);
            assert!(r.threads >= 1);
        }
        // At least one instrumented run per key; every trace produced
        // exactly one decision; the AoS key's decision changed layout
        // and the SoA key's confirmed it.
        assert!(m.traces_recorded() >= 2, "one trace per batch key");
        assert!(m.relayouts_performed() >= 1, "AoS key should relayout");
        assert!(m.relayouts_skipped() >= 1, "SoA key should be confirmed");
        assert_eq!(
            m.relayouts_performed() + m.relayouts_skipped(),
            m.traces_recorded(),
            "every trace ends in exactly one decision"
        );
        assert!(m.render().contains("tune:"));
    }

    #[test]
    fn autotune_off_records_nothing() {
        let mut c =
            Coordinator::start(Config { workers: 1, max_batch: 2, ..Config::default() });
        let m = c.metrics_handle();
        c.submit(spec(Layout::Aos, Backend::NativeScalar, 64, 1));
        let results = c.finish();
        assert!(results[0].error.is_none());
        assert_eq!(m.traces_recorded(), 0);
        assert_eq!(m.relayouts_performed() + m.relayouts_skipped(), 0);
    }

    #[test]
    fn layout_and_backend_parsing() {
        assert_eq!(Layout::parse("aos"), Some(Layout::Aos));
        assert_eq!(Layout::parse("soa"), Some(Layout::SoaMb));
        assert_eq!(Layout::parse("nope"), None);
        assert_eq!(Backend::parse("simd"), Some(Backend::NativeSimd));
        assert_eq!(Backend::parse("pjrt"), Some(Backend::Pjrt));
    }
}
