//! L3 coordinator: the layout-lab job service.
//!
//! The paper's system is a library, so the coordinator is the *lab* around
//! it: it accepts simulation jobs (layout × backend × size × steps),
//! batches compatible jobs (same executable / code path) for dispatch,
//! routes them across a worker pool, executes either through the native
//! LLAMA views (L3) or the AOT Pallas artifacts via PJRT (L1/L2), and
//! aggregates metrics. Python never appears on this path.
//!
//! ```text
//! submit() ─► queue ─► dispatcher (batches by batch_key, FIFO)
//!                          │
//!              ┌───────────┼───────────┐
//!           worker 0    worker 1    worker W   (std threads)
//!              │            │           │
//!         native views   native     PJRT Engine (shared, compiled once)
//! ```
//!
//! Invariants (checked by `rust/tests/properties.rs`):
//! - every submitted job completes exactly once (success or error);
//! - batches never exceed `max_batch` and never mix batch keys;
//! - jobs with the same batch key dispatch in FIFO order.

pub mod job;
pub mod metrics;

pub use job::{Backend, JobResult, JobSpec, Layout};
pub use metrics::Metrics;

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc;
use std::sync::{Arc, Mutex};
use std::time::Instant;

use crate::nbody::{init_particles, total_energy, views, ParticleData};
use crate::runtime::{PjrtService, TensorF32};

/// Coordinator configuration.
#[derive(Clone)]
pub struct Config {
    /// Worker thread count.
    pub workers: usize,
    /// Max jobs per dispatch batch.
    pub max_batch: usize,
    /// PJRT service handle (required for [`Backend::Pjrt`] jobs).
    pub engine: Option<PjrtService>,
}

impl Default for Config {
    fn default() -> Self {
        Config { workers: 2, max_batch: 8, engine: None }
    }
}

struct Queued {
    spec: JobSpec,
    submitted_at: Instant,
}

/// The layout-lab coordinator. See module docs.
pub struct Coordinator {
    submit_tx: Option<mpsc::Sender<Queued>>,
    results_rx: mpsc::Receiver<JobResult>,
    dispatcher: Option<std::thread::JoinHandle<()>>,
    workers: Vec<std::thread::JoinHandle<()>>,
    metrics: Arc<Metrics>,
    next_id: AtomicU64,
    submitted: usize,
}

impl Coordinator {
    /// Start the worker pool and dispatcher.
    pub fn start(config: Config) -> Self {
        let metrics = Arc::new(Metrics::default());
        let (submit_tx, submit_rx) = mpsc::channel::<Queued>();
        let (batch_tx, batch_rx) = mpsc::channel::<(u64, Vec<Queued>)>();
        let batch_rx = Arc::new(Mutex::new(batch_rx));
        let (results_tx, results_rx) = mpsc::channel::<JobResult>();

        // Dispatcher: drain the queue, group runs of equal batch_key (FIFO,
        // up to max_batch), hand batches to workers.
        let max_batch = config.max_batch.max(1);
        let dmetrics = metrics.clone();
        let dispatcher = std::thread::spawn(move || {
            let mut batch_id = 0u64;
            let mut pending: Option<Queued> = None;
            loop {
                // Block for the first job of the next batch.
                let first = match pending.take() {
                    Some(q) => q,
                    None => match submit_rx.recv() {
                        Ok(q) => q,
                        Err(_) => break, // channel closed: drain done
                    },
                };
                let key = first.spec.batch_key();
                let mut batch = vec![first];
                // Greedily take more of the same key without blocking.
                while batch.len() < max_batch {
                    match submit_rx.try_recv() {
                        Ok(q) if q.spec.batch_key() == key => batch.push(q),
                        Ok(q) => {
                            pending = Some(q);
                            break;
                        }
                        Err(_) => break,
                    }
                }
                dmetrics.on_batch(batch.len());
                if batch_tx.send((batch_id, batch)).is_err() {
                    break;
                }
                batch_id += 1;
            }
        });

        // Workers.
        let mut workers = Vec::new();
        for widx in 0..config.workers.max(1) {
            let rx = batch_rx.clone();
            let results = results_tx.clone();
            let engine = config.engine.clone();
            let wmetrics = metrics.clone();
            workers.push(std::thread::spawn(move || loop {
                let next = { rx.lock().unwrap().recv() };
                let (batch_id, batch) = match next {
                    Ok(b) => b,
                    Err(_) => break,
                };
                for q in batch {
                    let queue_time = q.submitted_at.elapsed();
                    let t0 = Instant::now();
                    let outcome = run_job(&q.spec, engine.as_ref());
                    let exec_time = t0.elapsed();
                    let (drift, error) = match outcome {
                        Ok(d) => (d, None),
                        Err(e) => (f64::NAN, Some(format!("{e:#}"))),
                    };
                    wmetrics.on_complete(queue_time, exec_time, error.is_some());
                    let _ = results.send(JobResult {
                        id: q.spec.id,
                        worker: widx,
                        batch_id,
                        exec_time,
                        queue_time,
                        energy_drift: drift,
                        steps_per_sec: q.spec.steps as f64 / exec_time.as_secs_f64().max(1e-12),
                        error,
                    });
                }
            }));
        }
        drop(results_tx);

        Coordinator {
            submit_tx: Some(submit_tx),
            results_rx,
            dispatcher: Some(dispatcher),
            workers,
            metrics,
            next_id: AtomicU64::new(0),
            submitted: 0,
        }
    }

    /// Submit a job; returns its assigned id.
    pub fn submit(&mut self, mut spec: JobSpec) -> u64 {
        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
        spec.id = id;
        self.metrics.on_submit();
        self.submitted += 1;
        self.submit_tx
            .as_ref()
            .expect("coordinator already shut down")
            .send(Queued { spec, submitted_at: Instant::now() })
            .expect("dispatcher alive");
        id
    }

    /// The metrics registry.
    pub fn metrics(&self) -> &Metrics {
        &self.metrics
    }

    /// Close the queue, wait for all submitted jobs, return their results
    /// sorted by id.
    pub fn finish(mut self) -> Vec<JobResult> {
        drop(self.submit_tx.take()); // close queue -> dispatcher drains
        let mut results = Vec::with_capacity(self.submitted);
        for _ in 0..self.submitted {
            match self.results_rx.recv() {
                Ok(r) => results.push(r),
                Err(_) => break,
            }
        }
        if let Some(d) = self.dispatcher.take() {
            let _ = d.join();
        }
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
        results.sort_by_key(|r| r.id);
        results
    }
}

/// Execute one job, returning the relative energy drift.
fn run_job(spec: &JobSpec, engine: Option<&PjrtService>) -> anyhow::Result<f64> {
    let init = init_particles(spec.n, spec.seed);
    let e0 = total_energy(&init);
    let finals: Vec<ParticleData> = match spec.backend {
        Backend::Pjrt => run_pjrt(spec, engine, &init)?,
        Backend::NativeScalar | Backend::NativeSimd => run_native(spec, &init),
    };
    let e1 = total_energy(&finals);
    Ok(((e1 - e0) / e0).abs())
}

fn run_native(spec: &JobSpec, init: &[ParticleData]) -> Vec<ParticleData> {
    let simd = spec.backend == Backend::NativeSimd;
    match spec.layout {
        Layout::Aos => {
            let mut v = views::make_aos_view(init);
            for _ in 0..spec.steps {
                if simd {
                    views::update_simd::<8, _, _>(&mut v);
                    views::move_simd::<8, _, _>(&mut v);
                } else {
                    views::update_scalar(&mut v);
                    views::move_scalar(&mut v);
                }
            }
            views::snapshot_view(&v)
        }
        Layout::SoaMb | Layout::Bf16 => {
            // Native bf16 falls back to f32 SoA (bf16 is a PJRT artifact).
            let mut v = views::make_soa_view(init);
            for _ in 0..spec.steps {
                if simd {
                    views::update_simd::<8, _, _>(&mut v);
                    views::move_simd::<8, _, _>(&mut v);
                } else {
                    views::update_scalar(&mut v);
                    views::move_scalar(&mut v);
                }
            }
            views::snapshot_view(&v)
        }
        Layout::Aosoa => {
            let mut v = views::make_aosoa_view(init);
            for _ in 0..spec.steps {
                if simd {
                    views::update_simd::<8, _, _>(&mut v);
                    views::move_simd::<8, _, _>(&mut v);
                } else {
                    views::update_scalar(&mut v);
                    views::move_scalar(&mut v);
                }
            }
            views::snapshot_view(&v)
        }
    }
}

fn run_pjrt(
    spec: &JobSpec,
    engine: Option<&PjrtService>,
    init: &[ParticleData],
) -> anyhow::Result<Vec<ParticleData>> {
    let engine = engine.ok_or_else(|| anyhow::anyhow!("no PJRT engine configured"))?;
    let artifact = spec.layout.artifact();
    engine.load(artifact)?;

    match spec.layout {
        Layout::SoaMb | Layout::Bf16 => {
            let sim = crate::nbody::manual::SoaSim::new(init);
            let mut state: Vec<TensorF32> =
                [&sim.px, &sim.py, &sim.pz, &sim.vx, &sim.vy, &sim.vz, &sim.mass]
                    .into_iter()
                    .map(|v| TensorF32::vec(v.clone()))
                    .collect();
            for _ in 0..spec.steps {
                let out = engine.execute_f32(artifact, &state)?;
                let mass = state[6].clone();
                state = out;
                state.push(mass);
            }
            Ok((0..spec.n)
                .map(|i| ParticleData {
                    pos: crate::nbody::PVec {
                        x: state[0].data[i],
                        y: state[1].data[i],
                        z: state[2].data[i],
                    },
                    vel: crate::nbody::PVec {
                        x: state[3].data[i],
                        y: state[4].data[i],
                        z: state[5].data[i],
                    },
                    mass: state[6].data[i],
                })
                .collect())
        }
        Layout::Aos => {
            let mut data = Vec::with_capacity(spec.n * 7);
            for p in init {
                data.extend_from_slice(&[
                    p.pos.x, p.pos.y, p.pos.z, p.vel.x, p.vel.y, p.vel.z, p.mass,
                ]);
            }
            let mut state = TensorF32::new(data, vec![spec.n, 7]);
            for _ in 0..spec.steps {
                state = engine.execute_f32(artifact, &[state])?.remove(0);
            }
            Ok((0..spec.n)
                .map(|i| ParticleData {
                    pos: crate::nbody::PVec {
                        x: state.data[i * 7],
                        y: state.data[i * 7 + 1],
                        z: state.data[i * 7 + 2],
                    },
                    vel: crate::nbody::PVec {
                        x: state.data[i * 7 + 3],
                        y: state.data[i * 7 + 4],
                        z: state.data[i * 7 + 5],
                    },
                    mass: state.data[i * 7 + 6],
                })
                .collect())
        }
        Layout::Aosoa => {
            const L: usize = 8;
            let nb = spec.n / L;
            let mut data = vec![0.0f32; spec.n * 7];
            for (i, p) in init.iter().enumerate() {
                let (b, k) = (i / L, i % L);
                let fields = [p.pos.x, p.pos.y, p.pos.z, p.vel.x, p.vel.y, p.vel.z, p.mass];
                for (f, v) in fields.iter().enumerate() {
                    data[b * 7 * L + f * L + k] = *v;
                }
            }
            let mut state = TensorF32::new(data, vec![nb, 7, L]);
            for _ in 0..spec.steps {
                state = engine.execute_f32(artifact, &[state])?.remove(0);
            }
            Ok((0..spec.n)
                .map(|i| {
                    let (b, k) = (i / L, i % L);
                    let g = |f: usize| state.data[b * 7 * L + f * L + k];
                    ParticleData {
                        pos: crate::nbody::PVec { x: g(0), y: g(1), z: g(2) },
                        vel: crate::nbody::PVec { x: g(3), y: g(4), z: g(5) },
                        mass: g(6),
                    }
                })
                .collect())
        }
    }
}

/// Render job results as an aligned table.
pub fn render_results(specs: &[JobSpec], results: &[JobResult]) -> String {
    let mut out = format!(
        "{:>4}  {:>9}  {:>14}  {:>6}  {:>6}  {:>12}  {:>10}  {}\n",
        "id", "layout", "backend", "worker", "batch", "exec", "steps/s", "drift"
    );
    for r in results {
        let spec = specs.iter().find(|s| s.id == r.id);
        out.push_str(&format!(
            "{:>4}  {:>9}  {:>14}  {:>6}  {:>6}  {:>12}  {:>10.1}  {}\n",
            r.id,
            spec.map(|s| s.layout.name()).unwrap_or("?"),
            spec.map(|s| s.backend.name()).unwrap_or("?"),
            r.worker,
            r.batch_id,
            format!("{:.2?}", r.exec_time),
            r.steps_per_sec,
            if let Some(e) = &r.error { e.clone() } else { format!("{:.1e}", r.energy_drift) },
        ));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn spec(layout: Layout, backend: Backend, n: usize, steps: usize) -> JobSpec {
        JobSpec { id: 0, layout, backend, n, steps, seed: 1 }
    }

    #[test]
    fn native_jobs_complete() {
        let mut c = Coordinator::start(Config { workers: 2, max_batch: 4, engine: None });
        for layout in [Layout::Aos, Layout::SoaMb, Layout::Aosoa] {
            c.submit(spec(layout, Backend::NativeScalar, 64, 2));
            c.submit(spec(layout, Backend::NativeSimd, 64, 2));
        }
        let results = c.finish();
        assert_eq!(results.len(), 6);
        for r in &results {
            assert!(r.error.is_none(), "{:?}", r.error);
            assert!(r.energy_drift < 1e-2);
            assert!(r.steps_per_sec > 0.0);
        }
        let ids: Vec<u64> = results.iter().map(|r| r.id).collect();
        assert_eq!(ids, (0..6).collect::<Vec<_>>());
    }

    #[test]
    fn pjrt_jobs_error_without_engine() {
        let mut c = Coordinator::start(Config { workers: 1, max_batch: 2, engine: None });
        c.submit(spec(Layout::SoaMb, Backend::Pjrt, 64, 1));
        let results = c.finish();
        assert_eq!(results.len(), 1);
        assert!(results[0].error.as_deref().unwrap_or("").contains("no PJRT engine"));
    }

    #[test]
    fn batching_respects_limits_and_completes() {
        let mut c = Coordinator::start(Config { workers: 1, max_batch: 8, engine: None });
        for _ in 0..6 {
            c.submit(spec(Layout::SoaMb, Backend::NativeScalar, 64, 1));
        }
        assert_eq!(c.metrics().job_counts().0, 6);
        let results = c.finish();
        assert_eq!(results.len(), 6);
        let m_max = results.iter().map(|r| r.batch_id).max().unwrap();
        assert!(m_max < 6); // batched into <= 6 batches
    }

    #[test]
    fn layout_and_backend_parsing() {
        assert_eq!(Layout::parse("aos"), Some(Layout::Aos));
        assert_eq!(Layout::parse("soa"), Some(Layout::SoaMb));
        assert_eq!(Layout::parse("nope"), None);
        assert_eq!(Backend::parse("simd"), Some(Backend::NativeSimd));
        assert_eq!(Backend::parse("pjrt"), Some(Backend::Pjrt));
    }
}
