//! Coordinator metrics registry: queue/exec timings, batch stats,
//! admission-control counters (queue depth, rejects, admission waits).

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;
use std::time::Duration;

/// Aggregated coordinator metrics (counters monotonically increase;
/// `queue_depth` is a gauge).
#[derive(Debug, Default)]
pub struct Metrics {
    jobs_submitted: AtomicU64,
    jobs_completed: AtomicU64,
    jobs_failed: AtomicU64,
    batches_dispatched: AtomicU64,
    queue_ns_total: AtomicU64,
    exec_ns_total: AtomicU64,
    batch_sizes: Mutex<Vec<usize>>,
    // Admission control (see `crate::coordinator::Ingest`).
    queue_depth: AtomicU64,
    queue_depth_max: AtomicU64,
    rejected_full: AtomicU64,
    rejected_deadline: AtomicU64,
    rejected_quota: AtomicU64,
    admission_waits: AtomicU64,
    admission_wait_ns: AtomicU64,
    // Fault tolerance (see `crate::coordinator::RetryPolicy` and
    // `crate::fault`).
    jobs_panicked: AtomicU64,
    job_retries: AtomicU64,
    corrupt_frames: AtomicU64,
    // Adaptive relayout (see `crate::tune` and `Config::autotune`).
    traces_recorded: AtomicU64,
    relayouts_performed: AtomicU64,
    relayouts_skipped: AtomicU64,
}

impl Metrics {
    /// Record a submission.
    pub fn on_submit(&self) {
        self.jobs_submitted.fetch_add(1, Ordering::Relaxed);
    }

    /// Record an admitted job with the queue depth after its enqueue.
    pub fn on_enqueue(&self, depth: usize) {
        self.queue_depth.store(depth as u64, Ordering::Relaxed);
        self.queue_depth_max.fetch_max(depth as u64, Ordering::Relaxed);
    }

    /// Record a dispatched (dequeued) job with the depth after removal.
    pub fn on_dequeue(&self, depth: usize) {
        self.queue_depth.store(depth as u64, Ordering::Relaxed);
    }

    /// Record a full-queue rejection ([`Admission::Reject`]).
    ///
    /// [`Admission::Reject`]: crate::coordinator::Admission::Reject
    pub fn on_reject_full(&self) {
        self.rejected_full.fetch_add(1, Ordering::Relaxed);
    }

    /// Record an admission deadline expiry ([`Admission::Block`]).
    ///
    /// [`Admission::Block`]: crate::coordinator::Admission::Block
    pub fn on_reject_deadline(&self) {
        self.rejected_deadline.fetch_add(1, Ordering::Relaxed);
    }

    /// Record a per-client quota rejection.
    pub fn on_reject_quota(&self) {
        self.rejected_quota.fetch_add(1, Ordering::Relaxed);
    }

    /// Record time a submitter spent blocked waiting for admission.
    pub fn on_admission_wait(&self, wait: Duration) {
        self.admission_waits.fetch_add(1, Ordering::Relaxed);
        self.admission_wait_ns.fetch_add(wait.as_nanos() as u64, Ordering::Relaxed);
    }

    /// Record a job attempt that panicked (the worker caught it and
    /// survived; counted once per panicking attempt, so a job that
    /// panics on every one of its `max_attempts` counts that many).
    pub fn on_job_panic(&self) {
        self.jobs_panicked.fetch_add(1, Ordering::Relaxed);
    }

    /// Record a failed attempt being re-dispatched under the retry
    /// policy (counted once per extra attempt, not per job).
    pub fn on_job_retry(&self) {
        self.job_retries.fetch_add(1, Ordering::Relaxed);
    }

    /// Record a wire frame rejected by its checksum
    /// ([`WireError::Corrupt`](crate::transport::WireError::Corrupt)).
    pub fn on_corrupt_frame(&self) {
        self.corrupt_frames.fetch_add(1, Ordering::Relaxed);
    }

    /// Record an access trace captured from an instrumented native job
    /// run (autotune mode).
    pub fn on_trace_recorded(&self) {
        self.traces_recorded.fetch_add(1, Ordering::Relaxed);
    }

    /// Record a planner decision that *changed* a job key's layout.
    pub fn on_relayout_performed(&self) {
        self.relayouts_performed.fetch_add(1, Ordering::Relaxed);
    }

    /// Record a planner decision that confirmed the layout in use.
    pub fn on_relayout_skipped(&self) {
        self.relayouts_skipped.fetch_add(1, Ordering::Relaxed);
    }

    /// Record a dispatched batch of `size` jobs.
    pub fn on_batch(&self, size: usize) {
        self.batches_dispatched.fetch_add(1, Ordering::Relaxed);
        self.batch_sizes.lock().unwrap().push(size);
    }

    /// Record a completed job.
    pub fn on_complete(&self, queue: Duration, exec: Duration, failed: bool) {
        self.jobs_completed.fetch_add(1, Ordering::Relaxed);
        if failed {
            self.jobs_failed.fetch_add(1, Ordering::Relaxed);
        }
        self.queue_ns_total.fetch_add(queue.as_nanos() as u64, Ordering::Relaxed);
        self.exec_ns_total.fetch_add(exec.as_nanos() as u64, Ordering::Relaxed);
    }

    /// (submitted, completed, failed).
    pub fn job_counts(&self) -> (u64, u64, u64) {
        (
            self.jobs_submitted.load(Ordering::Relaxed),
            self.jobs_completed.load(Ordering::Relaxed),
            self.jobs_failed.load(Ordering::Relaxed),
        )
    }

    /// Number of batches dispatched.
    pub fn batches(&self) -> u64 {
        self.batches_dispatched.load(Ordering::Relaxed)
    }

    /// Mean batch size.
    pub fn mean_batch_size(&self) -> f64 {
        let sizes = self.batch_sizes.lock().unwrap();
        if sizes.is_empty() {
            return 0.0;
        }
        sizes.iter().sum::<usize>() as f64 / sizes.len() as f64
    }

    /// Largest batch dispatched.
    pub fn max_batch_size(&self) -> usize {
        self.batch_sizes.lock().unwrap().iter().copied().max().unwrap_or(0)
    }

    /// Mean queue wait across completed jobs.
    pub fn mean_queue_time(&self) -> Duration {
        let done = self.jobs_completed.load(Ordering::Relaxed).max(1);
        Duration::from_nanos(self.queue_ns_total.load(Ordering::Relaxed) / done)
    }

    /// Mean execution time across completed jobs.
    pub fn mean_exec_time(&self) -> Duration {
        let done = self.jobs_completed.load(Ordering::Relaxed).max(1);
        Duration::from_nanos(self.exec_ns_total.load(Ordering::Relaxed) / done)
    }

    /// Current ingestion queue depth (gauge).
    pub fn queue_depth(&self) -> u64 {
        self.queue_depth.load(Ordering::Relaxed)
    }

    /// High-water mark of the ingestion queue depth.
    pub fn max_queue_depth(&self) -> u64 {
        self.queue_depth_max.load(Ordering::Relaxed)
    }

    /// Rejected submissions as `(queue_full, deadline, quota)`.
    pub fn rejected(&self) -> (u64, u64, u64) {
        (
            self.rejected_full.load(Ordering::Relaxed),
            self.rejected_deadline.load(Ordering::Relaxed),
            self.rejected_quota.load(Ordering::Relaxed),
        )
    }

    /// Total rejected submissions across all reasons.
    pub fn rejected_total(&self) -> u64 {
        let (f, d, q) = self.rejected();
        f + d + q
    }

    /// Mean time submitters spent blocked for admission (blocking
    /// submissions only; 0 when none blocked).
    pub fn mean_admission_wait(&self) -> Duration {
        let waits = self.admission_waits.load(Ordering::Relaxed).max(1);
        Duration::from_nanos(self.admission_wait_ns.load(Ordering::Relaxed) / waits)
    }

    /// Job attempts that panicked (and were caught).
    pub fn panics(&self) -> u64 {
        self.jobs_panicked.load(Ordering::Relaxed)
    }

    /// Extra attempts dispatched by the retry policy.
    pub fn retries(&self) -> u64 {
        self.job_retries.load(Ordering::Relaxed)
    }

    /// Wire frames rejected by checksum.
    pub fn corrupt_frames(&self) -> u64 {
        self.corrupt_frames.load(Ordering::Relaxed)
    }

    /// Access traces recorded by autotune's instrumented runs.
    pub fn traces_recorded(&self) -> u64 {
        self.traces_recorded.load(Ordering::Relaxed)
    }

    /// Planner decisions that changed a job key's layout.
    pub fn relayouts_performed(&self) -> u64 {
        self.relayouts_performed.load(Ordering::Relaxed)
    }

    /// Planner decisions that confirmed the layout in use.
    pub fn relayouts_skipped(&self) -> u64 {
        self.relayouts_skipped.load(Ordering::Relaxed)
    }

    /// Render a summary block.
    pub fn render(&self) -> String {
        let (s, c, f) = self.job_counts();
        let (rf, rd, rq) = self.rejected();
        format!(
            "jobs: {s} submitted, {c} completed, {f} failed\n\
             batches: {} (mean size {:.2}, max {})\n\
             queue: depth {} (max {}), rejected {} (full {rf}, deadline {rd}, quota {rq})\n\
             faults: {} panics caught, {} retries, {} corrupt frames\n\
             tune: {} traces, {} relayouts, {} confirmations\n\
             mean queue {:?}, mean exec {:?}, mean admission wait {:?}\n",
            self.batches(),
            self.mean_batch_size(),
            self.max_batch_size(),
            self.queue_depth(),
            self.max_queue_depth(),
            self.rejected_total(),
            self.panics(),
            self.retries(),
            self.corrupt_frames(),
            self.traces_recorded(),
            self.relayouts_performed(),
            self.relayouts_skipped(),
            self.mean_queue_time(),
            self.mean_exec_time(),
            self.mean_admission_wait(),
        )
    }
}
