//! Coordinator metrics registry: queue/exec timings, batch stats.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;
use std::time::Duration;

/// Aggregated coordinator metrics (all counters monotonically increase).
#[derive(Debug, Default)]
pub struct Metrics {
    jobs_submitted: AtomicU64,
    jobs_completed: AtomicU64,
    jobs_failed: AtomicU64,
    batches_dispatched: AtomicU64,
    queue_ns_total: AtomicU64,
    exec_ns_total: AtomicU64,
    batch_sizes: Mutex<Vec<usize>>,
}

impl Metrics {
    /// Record a submission.
    pub fn on_submit(&self) {
        self.jobs_submitted.fetch_add(1, Ordering::Relaxed);
    }

    /// Record a dispatched batch of `size` jobs.
    pub fn on_batch(&self, size: usize) {
        self.batches_dispatched.fetch_add(1, Ordering::Relaxed);
        self.batch_sizes.lock().unwrap().push(size);
    }

    /// Record a completed job.
    pub fn on_complete(&self, queue: Duration, exec: Duration, failed: bool) {
        self.jobs_completed.fetch_add(1, Ordering::Relaxed);
        if failed {
            self.jobs_failed.fetch_add(1, Ordering::Relaxed);
        }
        self.queue_ns_total.fetch_add(queue.as_nanos() as u64, Ordering::Relaxed);
        self.exec_ns_total.fetch_add(exec.as_nanos() as u64, Ordering::Relaxed);
    }

    /// (submitted, completed, failed).
    pub fn job_counts(&self) -> (u64, u64, u64) {
        (
            self.jobs_submitted.load(Ordering::Relaxed),
            self.jobs_completed.load(Ordering::Relaxed),
            self.jobs_failed.load(Ordering::Relaxed),
        )
    }

    /// Number of batches dispatched.
    pub fn batches(&self) -> u64 {
        self.batches_dispatched.load(Ordering::Relaxed)
    }

    /// Mean batch size.
    pub fn mean_batch_size(&self) -> f64 {
        let sizes = self.batch_sizes.lock().unwrap();
        if sizes.is_empty() {
            return 0.0;
        }
        sizes.iter().sum::<usize>() as f64 / sizes.len() as f64
    }

    /// Largest batch dispatched.
    pub fn max_batch_size(&self) -> usize {
        self.batch_sizes.lock().unwrap().iter().copied().max().unwrap_or(0)
    }

    /// Mean queue wait across completed jobs.
    pub fn mean_queue_time(&self) -> Duration {
        let done = self.jobs_completed.load(Ordering::Relaxed).max(1);
        Duration::from_nanos(self.queue_ns_total.load(Ordering::Relaxed) / done)
    }

    /// Mean execution time across completed jobs.
    pub fn mean_exec_time(&self) -> Duration {
        let done = self.jobs_completed.load(Ordering::Relaxed).max(1);
        Duration::from_nanos(self.exec_ns_total.load(Ordering::Relaxed) / done)
    }

    /// Render a summary block.
    pub fn render(&self) -> String {
        let (s, c, f) = self.job_counts();
        format!(
            "jobs: {s} submitted, {c} completed, {f} failed\n\
             batches: {} (mean size {:.2}, max {})\n\
             mean queue {:?}, mean exec {:?}\n",
            self.batches(),
            self.mean_batch_size(),
            self.max_batch_size(),
            self.mean_queue_time(),
            self.mean_exec_time(),
        )
    }
}
