//! Job specifications and results for the layout lab.

use std::time::Duration;

/// Memory layout under test (the Figure-3 axis).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Layout {
    /// Array of structs.
    Aos,
    /// Struct of arrays, multi-blob.
    SoaMb,
    /// Array of struct-of-arrays, 8 lanes.
    Aosoa,
    /// SoA with bf16 storage (Changetype; PJRT backend only).
    Bf16,
}

impl Layout {
    /// Parse a CLI name.
    pub fn parse(s: &str) -> Option<Layout> {
        match s {
            "aos" => Some(Layout::Aos),
            "soa" | "soa-mb" => Some(Layout::SoaMb),
            "aosoa" => Some(Layout::Aosoa),
            "bf16" => Some(Layout::Bf16),
            _ => None,
        }
    }

    /// Display name.
    pub fn name(self) -> &'static str {
        match self {
            Layout::Aos => "AoS",
            Layout::SoaMb => "SoA MB",
            Layout::Aosoa => "AoSoA",
            Layout::Bf16 => "SoA bf16",
        }
    }

    /// PJRT artifact name for this layout.
    pub fn artifact(self) -> &'static str {
        match self {
            Layout::Aos => "nbody_aos",
            Layout::SoaMb => "nbody_soa",
            Layout::Aosoa => "nbody_aosoa",
            Layout::Bf16 => "nbody_bf16",
        }
    }
}

/// Execution backend (the three-layer stack's entry points).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Backend {
    /// Rust LLAMA views, scalar loop.
    NativeScalar,
    /// Rust LLAMA views, SIMD-8 loop.
    NativeSimd,
    /// AOT JAX/Pallas artifact through PJRT.
    Pjrt,
}

impl Backend {
    /// Parse a CLI name.
    pub fn parse(s: &str) -> Option<Backend> {
        match s {
            "scalar" | "native-scalar" => Some(Backend::NativeScalar),
            "simd" | "native-simd" => Some(Backend::NativeSimd),
            "pjrt" => Some(Backend::Pjrt),
            _ => None,
        }
    }

    /// Display name.
    pub fn name(self) -> &'static str {
        match self {
            Backend::NativeScalar => "native/scalar",
            Backend::NativeSimd => "native/simd8",
            Backend::Pjrt => "pjrt",
        }
    }
}

/// A simulation job: run `steps` n-body steps over `n` particles.
#[derive(Clone, Debug)]
pub struct JobSpec {
    /// Unique id assigned at submission.
    pub id: u64,
    /// Memory layout.
    pub layout: Layout,
    /// Execution backend.
    pub backend: Backend,
    /// Particle count (PJRT jobs must match the artifact's baked n).
    pub n: usize,
    /// Number of simulation steps.
    pub steps: usize,
    /// Initial-conditions seed.
    pub seed: u64,
    /// Thread-budget request for the native parallel kernels: the job
    /// leases up to this many threads from the coordinator's worker
    /// pool (`0` = as many as the pool has uncommitted; PJRT jobs
    /// ignore it). The actually granted budget is reported in
    /// [`JobResult::threads`].
    pub threads: usize,
}

impl JobSpec {
    /// Jobs with equal keys may share a dispatch batch (same executable /
    /// same native code path).
    pub fn batch_key(&self) -> (Layout, Backend, usize) {
        (self.layout, self.backend, self.n)
    }
}

/// Outcome of one job.
#[derive(Clone, Debug)]
pub struct JobResult {
    /// Job id.
    pub id: u64,
    /// Worker thread index that executed it.
    pub worker: usize,
    /// Batch the dispatcher placed it in.
    pub batch_id: u64,
    /// Wall time spent executing.
    pub exec_time: Duration,
    /// Time from submit to dispatch.
    pub queue_time: Duration,
    /// Relative energy drift |E1-E0|/|E0| over the run.
    pub energy_drift: f64,
    /// Steps per second achieved.
    pub steps_per_sec: f64,
    /// Thread budget the job actually ran with (native backends: the
    /// granted pool lease, ≥ 1; PJRT: 1; 0 on error).
    pub threads: usize,
    /// Attempts the job took (1 = first try succeeded; > 1 means the
    /// retry policy re-dispatched it after failures/panics).
    pub attempts: u32,
    /// Error message of the **last** attempt if the job ultimately
    /// failed (earlier attempts' errors are superseded).
    pub error: Option<String>,
}
