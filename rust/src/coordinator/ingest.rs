//! Bounded job ingestion with explicit backpressure and per-client
//! quotas — the admission-control front of the coordinator.
//!
//! The coordinator used to accept submissions into an unbounded channel:
//! a fast client could queue arbitrary memory and starve everyone's
//! latency. Ingestion now goes through a bounded MPMC queue
//! ([`BoundedQueue`]) with caller-selectable admission behavior
//! ([`Admission`]):
//!
//! - **`Reject`** — fail fast when the queue is full, returning a
//!   `retry_after` hint derived from the observed service rate
//!   (mean exec time × queue depth / workers);
//! - **`Block`** — wait for a slot, optionally bounded by a deadline.
//!
//! On top of slot admission, an optional **per-client quota** caps how
//! many queue slots any one client may occupy at once
//! ([`crate::coordinator::Config::client_quota`]), so a flood from one
//! client cannot lock others out of the queue; thread-level fairness
//! between running jobs stays with the existing
//! [`crate::pool::WorkerPool::lease`] budgets.
//!
//! [`Ingest`] is the clonable submission handle — many threads submit
//! concurrently while the coordinator's single dispatcher pops, which
//! preserves the FIFO-per-batch-key dispatch invariant (verified in
//! `rust/tests/ingestion.rs` and `rust/tests/properties.rs`). Queue
//! depth, rejection counts, and admission-wait totals land in the
//! coordinator [`Metrics`]. Semantics are specified in
//! `docs/SERVING.md`.

use std::collections::HashMap;
use std::collections::VecDeque;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

use super::job::JobSpec;
use super::metrics::Metrics;

/// What to do when the ingestion queue has no free slot.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Admission {
    /// Fail immediately with [`SubmitError::QueueFull`] carrying a
    /// retry-after hint.
    Reject,
    /// Wait for a slot; `deadline: Some(d)` bounds the wait and fails
    /// with [`SubmitError::DeadlineExceeded`], `None` waits until a slot
    /// frees or the queue closes.
    Block {
        /// Maximum time to wait for admission.
        deadline: Option<Duration>,
    },
}

/// Why a submission was not admitted.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum SubmitError {
    /// Queue full under [`Admission::Reject`]; retry after the hint
    /// (derived from the observed service rate, floor 1 ms).
    QueueFull {
        /// Suggested wait before retrying.
        retry_after: Duration,
    },
    /// Queue stayed full past the [`Admission::Block`] deadline.
    DeadlineExceeded,
    /// The client already occupies its full quota of queue slots.
    QuotaExceeded {
        /// The client that exceeded its quota.
        client: u64,
    },
    /// The coordinator is shutting down; no further jobs are accepted.
    Closed,
}

impl std::fmt::Display for SubmitError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            SubmitError::QueueFull { retry_after } => {
                write!(f, "ingestion queue full; retry after {retry_after:?}")
            }
            SubmitError::DeadlineExceeded => write!(f, "admission deadline exceeded"),
            SubmitError::QuotaExceeded { client } => {
                write!(f, "client {client} exceeded its queue quota")
            }
            SubmitError::Closed => write!(f, "coordinator closed"),
        }
    }
}

impl std::error::Error for SubmitError {}

/// Assumed per-job execution time for the `retry_after` hint before any
/// job has completed (no observed service rate yet). Deliberately
/// pessimistic relative to the 1 ms floor: a cold coordinator facing an
/// already-full queue should not invite an immediate thundering herd.
pub const COLD_START_EXEC_ESTIMATE: Duration = Duration::from_millis(10);

/// A job admitted to the queue (dispatcher currency).
pub(crate) struct Queued {
    pub(crate) spec: JobSpec,
    pub(crate) submitted_at: Instant,
    client: Option<u64>,
}

// ---------------------------------------------------------------------------
// Bounded MPMC queue
// ---------------------------------------------------------------------------

enum PushErr<T> {
    Full(T),
    TimedOut,
    Closed,
}

struct QueueState<T> {
    items: VecDeque<T>,
    closed: bool,
    /// Total successful pushes, counted under the queue mutex so
    /// `close()` + `pushed()` observe an exact final value.
    pushed: u64,
    /// High-water mark of the depth (exact: updated under the mutex).
    max_depth: usize,
}

/// Bounded blocking MPMC queue: `Mutex<VecDeque>` + two condvars
/// (std-only; the container image has no crossbeam).
struct BoundedQueue<T> {
    state: Mutex<QueueState<T>>,
    not_full: Condvar,
    not_empty: Condvar,
    capacity: usize,
}

impl<T> BoundedQueue<T> {
    fn new(capacity: usize) -> Self {
        BoundedQueue {
            state: Mutex::new(QueueState {
                items: VecDeque::new(),
                closed: false,
                pushed: 0,
                max_depth: 0,
            }),
            not_full: Condvar::new(),
            not_empty: Condvar::new(),
            capacity: capacity.max(1),
        }
    }

    /// Push without waiting; `Err(Full)` hands the item back.
    fn try_push(&self, item: T) -> Result<usize, PushErr<T>> {
        let mut st = self.state.lock().unwrap();
        if st.closed {
            return Err(PushErr::Closed);
        }
        if st.items.len() >= self.capacity {
            return Err(PushErr::Full(item));
        }
        Ok(Self::admit(&mut st, item, &self.not_empty))
    }

    /// Push, waiting for a slot up to `deadline` (`None` = indefinitely).
    fn push_blocking(&self, item: T, deadline: Option<Duration>) -> Result<usize, PushErr<T>> {
        let start = Instant::now();
        let mut st = self.state.lock().unwrap();
        loop {
            if st.closed {
                return Err(PushErr::Closed);
            }
            if st.items.len() < self.capacity {
                return Ok(Self::admit(&mut st, item, &self.not_empty));
            }
            match deadline {
                None => st = self.not_full.wait(st).unwrap(),
                Some(d) => {
                    let elapsed = start.elapsed();
                    if elapsed >= d {
                        return Err(PushErr::TimedOut);
                    }
                    st = self.not_full.wait_timeout(st, d - elapsed).unwrap().0;
                }
            }
        }
    }

    fn admit(st: &mut QueueState<T>, item: T, not_empty: &Condvar) -> usize {
        st.items.push_back(item);
        st.pushed += 1;
        let depth = st.items.len();
        st.max_depth = st.max_depth.max(depth);
        not_empty.notify_one();
        depth
    }

    /// Pop, blocking until an item arrives; `None` once closed and
    /// drained.
    fn pop(&self) -> Option<(T, usize)> {
        let mut st = self.state.lock().unwrap();
        loop {
            if let Some(item) = st.items.pop_front() {
                self.not_full.notify_one();
                return Some((item, st.items.len()));
            }
            if st.closed {
                return None;
            }
            st = self.not_empty.wait(st).unwrap();
        }
    }

    /// Pop without waiting.
    fn try_pop(&self) -> Option<(T, usize)> {
        let mut st = self.state.lock().unwrap();
        let item = st.items.pop_front()?;
        self.not_full.notify_one();
        Some((item, st.items.len()))
    }

    /// Stop admitting; blocked pushers fail with `Closed`, poppers drain
    /// the remainder then get `None`. Idempotent.
    fn close(&self) {
        let mut st = self.state.lock().unwrap();
        st.closed = true;
        self.not_full.notify_all();
        self.not_empty.notify_all();
    }

    fn depth(&self) -> usize {
        self.state.lock().unwrap().items.len()
    }

    fn pushed(&self) -> u64 {
        self.state.lock().unwrap().pushed
    }

    fn max_depth(&self) -> usize {
        self.state.lock().unwrap().max_depth
    }
}

// ---------------------------------------------------------------------------
// The submission handle
// ---------------------------------------------------------------------------

struct IngestShared {
    queue: BoundedQueue<Queued>,
    metrics: Arc<Metrics>,
    next_id: AtomicU64,
    workers: usize,
    /// Max queue slots one client may occupy (0 = unlimited).
    client_quota: usize,
    /// Slots currently occupied per client (only clients with a quota
    /// and a nonzero count are present).
    client_slots: Mutex<HashMap<u64, usize>>,
}

/// Clonable, thread-safe submission handle to a running
/// [`crate::coordinator::Coordinator`] — obtain via
/// [`Coordinator::ingest`](crate::coordinator::Coordinator::ingest).
///
/// All clones feed the same bounded queue; drop order does not matter
/// (the queue closes when the coordinator finishes, after which every
/// submit fails with [`SubmitError::Closed`]).
#[derive(Clone)]
pub struct Ingest {
    shared: Arc<IngestShared>,
}

impl Ingest {
    pub(crate) fn new(
        capacity: usize,
        client_quota: usize,
        workers: usize,
        metrics: Arc<Metrics>,
    ) -> Ingest {
        Ingest {
            shared: Arc::new(IngestShared {
                queue: BoundedQueue::new(capacity),
                metrics,
                next_id: AtomicU64::new(0),
                workers: workers.max(1),
                client_quota,
                client_slots: Mutex::new(HashMap::new()),
            }),
        }
    }

    /// Submit a job, blocking without deadline until a queue slot frees
    /// (the pre-admission-control behavior). Fails only once the
    /// coordinator closed.
    pub fn submit(&self, spec: JobSpec) -> Result<u64, SubmitError> {
        self.submit_with(spec, Admission::Block { deadline: None })
    }

    /// Submit a job under an explicit [`Admission`] policy.
    pub fn submit_with(&self, spec: JobSpec, admission: Admission) -> Result<u64, SubmitError> {
        self.admit(spec, admission, None)
    }

    /// Submit on behalf of client `client`, under an explicit
    /// [`Admission`] policy and the per-client queue quota.
    pub fn submit_from(
        &self,
        client: u64,
        spec: JobSpec,
        admission: Admission,
    ) -> Result<u64, SubmitError> {
        self.admit(spec, admission, Some(client))
    }

    fn admit(
        &self,
        mut spec: JobSpec,
        admission: Admission,
        client: Option<u64>,
    ) -> Result<u64, SubmitError> {
        let sh = &*self.shared;

        // Reserve a quota slot first; released again on any failure.
        if let Some(c) = client {
            if sh.client_quota > 0 {
                let mut slots = sh.client_slots.lock().unwrap();
                let used = slots.entry(c).or_insert(0);
                if *used >= sh.client_quota {
                    sh.metrics.on_reject_quota();
                    return Err(SubmitError::QuotaExceeded { client: c });
                }
                *used += 1;
            }
        }

        let id = sh.next_id.fetch_add(1, Ordering::Relaxed);
        spec.id = id;
        let q = Queued { spec, submitted_at: Instant::now(), client };

        let pushed = match admission {
            Admission::Reject => sh.queue.try_push(q),
            Admission::Block { deadline } => {
                let t0 = Instant::now();
                let r = sh.queue.push_blocking(q, deadline);
                sh.metrics.on_admission_wait(t0.elapsed());
                r
            }
        };

        match pushed {
            Ok(depth) => {
                sh.metrics.on_submit();
                sh.metrics.on_enqueue(depth);
                Ok(id)
            }
            Err(e) => {
                self.release_quota(client);
                Err(match e {
                    PushErr::Full(_) => {
                        sh.metrics.on_reject_full();
                        SubmitError::QueueFull { retry_after: self.retry_after() }
                    }
                    PushErr::TimedOut => {
                        sh.metrics.on_reject_deadline();
                        SubmitError::DeadlineExceeded
                    }
                    PushErr::Closed => SubmitError::Closed,
                })
            }
        }
    }

    /// Retry hint under full-queue rejection: the time the backlog takes
    /// to drain at the observed service rate (mean exec time × depth /
    /// workers), clamped to `[1ms, 10s]`.
    ///
    /// Cold start: before any job has *completed* there is no observed
    /// service rate — `mean_exec_time()` would read 0 and every hint
    /// would collapse to the 1 ms floor even against a full queue,
    /// telling rejected clients to hammer a coordinator that has not
    /// proven it can drain at all. Until the first completion the hint
    /// substitutes [`COLD_START_EXEC_ESTIMATE`] as the per-job cost, so
    /// it still scales with the backlog.
    fn retry_after(&self) -> Duration {
        let sh = &*self.shared;
        let per_job = if sh.metrics.job_counts().1 == 0 {
            COLD_START_EXEC_ESTIMATE
        } else {
            sh.metrics.mean_exec_time()
        };
        let hint = per_job.mul_f64(sh.queue.depth() as f64 / sh.workers as f64);
        hint.clamp(Duration::from_millis(1), Duration::from_secs(10))
    }

    /// Jobs currently waiting for dispatch.
    pub fn queue_depth(&self) -> usize {
        self.shared.queue.depth()
    }

    /// Queue slot capacity.
    pub fn capacity(&self) -> usize {
        self.shared.queue.capacity
    }

    /// Exact high-water mark of the queue depth (bounded-memory proof:
    /// never exceeds [`capacity`](Ingest::capacity)).
    pub fn max_queue_depth(&self) -> usize {
        self.shared.queue.max_depth()
    }

    /// The coordinator's metrics registry. Unlike
    /// [`Coordinator::metrics`](crate::coordinator::Coordinator::metrics),
    /// this handle keeps the registry alive past
    /// [`Coordinator::finish`](crate::coordinator::Coordinator::finish),
    /// so final queue/rejection accounting can be read after the drain.
    pub fn metrics(&self) -> &Metrics {
        &self.shared.metrics
    }

    /// Stop admitting jobs: every subsequent or blocked submit fails
    /// with [`SubmitError::Closed`]; already-admitted jobs still run.
    pub fn close(&self) {
        self.shared.queue.close();
    }

    /// Total successfully admitted jobs (exact once closed).
    pub(crate) fn admitted(&self) -> u64 {
        self.shared.queue.pushed()
    }

    /// Dispatcher side: blocking pop; `None` once closed and drained.
    pub(crate) fn next_job(&self) -> Option<Queued> {
        let (q, depth) = self.shared.queue.pop()?;
        self.on_dequeued(&q, depth);
        Some(q)
    }

    /// Dispatcher side: non-blocking pop (greedy batch fill).
    pub(crate) fn try_next_job(&self) -> Option<Queued> {
        let (q, depth) = self.shared.queue.try_pop()?;
        self.on_dequeued(&q, depth);
        Some(q)
    }

    fn on_dequeued(&self, q: &Queued, depth: usize) {
        self.shared.metrics.on_dequeue(depth);
        self.release_quota(q.client);
    }

    fn release_quota(&self, client: Option<u64>) {
        let (Some(c), true) = (client, self.shared.client_quota > 0) else {
            return;
        };
        let mut slots = self.shared.client_slots.lock().unwrap();
        if let Some(used) = slots.get_mut(&c) {
            *used = used.saturating_sub(1);
            if *used == 0 {
                slots.remove(&c);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bounded_queue_push_pop_fifo() {
        let q: BoundedQueue<u32> = BoundedQueue::new(4);
        for i in 0..4 {
            assert!(q.try_push(i).is_ok());
        }
        assert!(matches!(q.try_push(9), Err(PushErr::Full(9))));
        assert_eq!(q.max_depth(), 4);
        let mut seen = Vec::new();
        while let Some((v, _)) = q.try_pop() {
            seen.push(v);
        }
        assert_eq!(seen, vec![0, 1, 2, 3]);
        assert_eq!(q.pushed(), 4);
    }

    #[test]
    fn blocked_push_wakes_on_pop() {
        let q = Arc::new(BoundedQueue::new(1));
        q.try_push(1u32).ok().unwrap();
        let q2 = q.clone();
        let pusher = std::thread::spawn(move || q2.push_blocking(2u32, None).is_ok());
        std::thread::sleep(Duration::from_millis(20));
        assert_eq!(q.pop().map(|(v, _)| v), Some(1));
        assert!(pusher.join().unwrap());
        assert_eq!(q.pop().map(|(v, _)| v), Some(2));
    }

    #[test]
    fn deadline_expires_when_full() {
        let q: BoundedQueue<u32> = BoundedQueue::new(1);
        q.try_push(1).ok().unwrap();
        let r = q.push_blocking(2, Some(Duration::from_millis(10)));
        assert!(matches!(r, Err(PushErr::TimedOut)));
    }

    #[test]
    fn cold_start_retry_after_uses_documented_default() {
        use super::super::job::{Backend, JobSpec, Layout};
        // Standalone ingest front: capacity 1, 2 workers, fresh metrics
        // — no dispatcher, so nothing ever completes and the service
        // rate stays unobserved.
        let ing = Ingest::new(1, 0, 2, Arc::new(Metrics::default()));
        let spec = || JobSpec {
            id: 0,
            layout: Layout::SoaMb,
            backend: Backend::NativeScalar,
            n: 8,
            steps: 1,
            seed: 1,
            threads: 0,
        };
        ing.submit_with(spec(), Admission::Reject).unwrap();
        match ing.submit_with(spec(), Admission::Reject) {
            Err(SubmitError::QueueFull { retry_after }) => {
                // depth 1 over 2 workers at the documented cold-start
                // estimate: exactly half of it — not the degenerate
                // 1 ms floor a zero mean-exec would have produced.
                assert_eq!(retry_after, COLD_START_EXEC_ESTIMATE.mul_f64(0.5));
            }
            other => panic!("expected QueueFull, got {other:?}"),
        }
    }

    #[test]
    fn close_wakes_everyone() {
        let q = Arc::new(BoundedQueue::<u32>::new(1));
        q.try_push(7).ok().unwrap();
        let (qa, qb) = (q.clone(), q.clone());
        let blocked_push = std::thread::spawn(move || qa.push_blocking(8, None));
        let popper = std::thread::spawn(move || {
            let mut got = Vec::new();
            while let Some((v, _)) = qb.pop() {
                got.push(v);
            }
            got
        });
        std::thread::sleep(Duration::from_millis(20));
        q.close();
        // The blocked pusher either got the slot freed by the popper
        // before the close landed, or fails Closed; the popper drains
        // whatever was admitted and then sees the close.
        let push_result = blocked_push.join().unwrap();
        let drained = popper.join().unwrap();
        match push_result {
            Ok(_) => assert_eq!(drained, vec![7, 8]),
            Err(_) => assert_eq!(drained, vec![7]),
        }
        assert!(matches!(q.try_push(9), Err(PushErr::Closed)));
    }
}
