//! Blob storage and allocators.
//!
//! A mapping describes the data space as a set of *blobs* (byte buffers)
//! plus a rule locating each scalar in them. Where those bytes live is the
//! blob allocator's choice: heap vectors ([`HeapAlloc`]), cache-line/SIMD
//! aligned heap buffers ([`AlignedAlloc`]), or inline arrays
//! ([`ArrayStorage`] via [`array_view`]) — the last one making the whole
//! view a trivial value type when the extents are compile-time (§2: views
//! placeable in GPU shared memory; here: `memcpy`-able, stack-residing,
//! reinterpretable).

use crate::mapping::{Mapping, MemoryAccess};
use crate::record::RecordDim;
use crate::view::View;

/// Byte storage for the blobs of a view.
///
/// # Safety-relevant contract
/// `blob(i)` / `blob_mut(i)` must return stable slices of the size the
/// mapping requested at allocation for all `i < blob_count()`.
pub trait BlobStorage {
    /// Number of blobs held.
    fn blob_count(&self) -> usize;
    /// Read access to blob `i`.
    fn blob(&self, i: usize) -> &[u8];
    /// Write access to blob `i`.
    fn blob_mut(&mut self, i: usize) -> &mut [u8];

    /// Total bytes across all blobs (reporting).
    fn total_bytes(&self) -> usize {
        (0..self.blob_count()).map(|i| self.blob(i).len()).sum()
    }
}

/// Allocates blob storage for a mapping's blob sizes.
pub trait BlobAlloc {
    /// The storage this allocator produces.
    type Storage: BlobStorage;
    /// Allocate zero-initialized blobs of the given sizes.
    fn alloc(&self, sizes: &[usize]) -> Self::Storage;
}

// ---------------------------------------------------------------------------
// Heap storage
// ---------------------------------------------------------------------------

/// Plain heap storage: one `Vec<u8>` per blob.
#[derive(Clone, Debug, Default)]
pub struct HeapStorage {
    blobs: Vec<Vec<u8>>,
}

impl BlobStorage for HeapStorage {
    #[inline]
    fn blob_count(&self) -> usize {
        self.blobs.len()
    }
    #[inline(always)]
    fn blob(&self, i: usize) -> &[u8] {
        &self.blobs[i]
    }
    #[inline(always)]
    fn blob_mut(&mut self, i: usize) -> &mut [u8] {
        &mut self.blobs[i]
    }
}

/// Allocator producing [`HeapStorage`] (LLAMA's `bloballoc::Vector`).
#[derive(Clone, Copy, Debug, Default)]
pub struct HeapAlloc;

impl BlobAlloc for HeapAlloc {
    type Storage = HeapStorage;
    fn alloc(&self, sizes: &[usize]) -> HeapStorage {
        HeapStorage { blobs: sizes.iter().map(|&s| vec![0u8; s]).collect() }
    }
}

// ---------------------------------------------------------------------------
// Aligned heap storage
// ---------------------------------------------------------------------------

/// A heap buffer with a guaranteed start alignment.
#[derive(Debug)]
pub struct AlignedBuf {
    ptr: *mut u8,
    len: usize,
    align: usize,
}

// SAFETY: AlignedBuf owns its allocation exclusively.
unsafe impl Send for AlignedBuf {}
unsafe impl Sync for AlignedBuf {}

impl AlignedBuf {
    /// Allocate `len` zeroed bytes aligned to `align` (a power of two).
    pub fn zeroed(len: usize, align: usize) -> Self {
        assert!(align.is_power_of_two());
        if len == 0 {
            return AlignedBuf { ptr: std::ptr::null_mut(), len: 0, align };
        }
        let layout = std::alloc::Layout::from_size_align(len, align).expect("bad layout");
        // SAFETY: len > 0, layout valid.
        let ptr = unsafe { std::alloc::alloc_zeroed(layout) };
        assert!(!ptr.is_null(), "allocation failure of {len} bytes");
        AlignedBuf { ptr, len, align }
    }

    /// The buffer contents.
    #[inline(always)]
    pub fn as_slice(&self) -> &[u8] {
        if self.len == 0 {
            return &[];
        }
        // SAFETY: ptr valid for len bytes, exclusive ownership.
        unsafe { std::slice::from_raw_parts(self.ptr, self.len) }
    }

    /// The buffer contents, mutably.
    #[inline(always)]
    pub fn as_mut_slice(&mut self) -> &mut [u8] {
        if self.len == 0 {
            return &mut [];
        }
        // SAFETY: ptr valid for len bytes, exclusive ownership.
        unsafe { std::slice::from_raw_parts_mut(self.ptr, self.len) }
    }
}

impl Drop for AlignedBuf {
    fn drop(&mut self) {
        if !self.ptr.is_null() {
            let layout = std::alloc::Layout::from_size_align(self.len, self.align).unwrap();
            // SAFETY: allocated with this exact layout.
            unsafe { std::alloc::dealloc(self.ptr, layout) };
        }
    }
}

impl Clone for AlignedBuf {
    fn clone(&self) -> Self {
        let mut new = AlignedBuf::zeroed(self.len, self.align);
        new.as_mut_slice().copy_from_slice(self.as_slice());
        new
    }
}

/// Aligned heap storage: one [`AlignedBuf`] per blob.
#[derive(Clone, Debug)]
pub struct AlignedStorage {
    blobs: Vec<AlignedBuf>,
}

impl BlobStorage for AlignedStorage {
    #[inline]
    fn blob_count(&self) -> usize {
        self.blobs.len()
    }
    #[inline(always)]
    fn blob(&self, i: usize) -> &[u8] {
        self.blobs[i].as_slice()
    }
    #[inline(always)]
    fn blob_mut(&mut self, i: usize) -> &mut [u8] {
        self.blobs[i].as_mut_slice()
    }
}

/// Allocator producing blob buffers aligned to `ALIGN` bytes (default 64:
/// cache line; use 4096 for page alignment). LLAMA's
/// `bloballoc::AlignedAllocator`.
#[derive(Clone, Copy, Debug, Default)]
pub struct AlignedAlloc<const ALIGN: usize = 64>;

impl<const ALIGN: usize> BlobAlloc for AlignedAlloc<ALIGN> {
    type Storage = AlignedStorage;
    fn alloc(&self, sizes: &[usize]) -> AlignedStorage {
        AlignedStorage { blobs: sizes.iter().map(|&s| AlignedBuf::zeroed(s, ALIGN)).collect() }
    }
}

// ---------------------------------------------------------------------------
// Inline array storage (the trivially-copyable view of §2)
// ---------------------------------------------------------------------------

/// Inline storage: `BLOBS` byte arrays of `SIZE` bytes each, held by value.
///
/// With fully static extents and a stateless mapping, a
/// `View<_, ArrayStorage<..>>` is a plain value containing only the mapped
/// bytes — the paper's "trivial value type ... storage-wise equivalent to
/// the mapped data" that can be memcpy-ed or placed in shared memory.
#[derive(Clone, Copy, Debug)]
pub struct ArrayStorage<const SIZE: usize, const BLOBS: usize> {
    blobs: [[u8; SIZE]; BLOBS],
}

impl<const SIZE: usize, const BLOBS: usize> Default for ArrayStorage<SIZE, BLOBS> {
    fn default() -> Self {
        ArrayStorage { blobs: [[0; SIZE]; BLOBS] }
    }
}

impl<const SIZE: usize, const BLOBS: usize> BlobStorage for ArrayStorage<SIZE, BLOBS> {
    #[inline(always)]
    fn blob_count(&self) -> usize {
        BLOBS
    }
    #[inline(always)]
    fn blob(&self, i: usize) -> &[u8] {
        &self.blobs[i]
    }
    #[inline(always)]
    fn blob_mut(&mut self, i: usize) -> &mut [u8] {
        &mut self.blobs[i]
    }
}

// ---------------------------------------------------------------------------
// View construction helpers
// ---------------------------------------------------------------------------

/// Allocate a [`View`] for `mapping` using `alloc`.
///
/// ```
/// use llama::prelude::*;
/// llama::record! { pub struct P, mod p { x: f32, y: f32 } }
/// let view = alloc_view(SoA::<P, _>::new((Dyn(16u32),)), &HeapAlloc);
/// assert_eq!(view.storage().total_bytes(), 16 * 8);
/// ```
pub fn alloc_view<R, M, A>(mapping: M, alloc: &A) -> View<R, M, A::Storage>
where
    R: RecordDim,
    M: Mapping<R> + MemoryAccess<R>,
    A: BlobAlloc,
{
    let sizes: Vec<usize> = (0..M::BLOB_COUNT).map(|i| mapping.blob_size(i)).collect();
    let storage = alloc.alloc(&sizes);
    View::from_parts(mapping, storage)
}

/// Build a view over inline array storage (compile-time sizes).
///
/// `SIZE` must be at least the largest blob size of the mapping and `BLOBS`
/// must equal the mapping's blob count — both checked at construction.
/// For a fully-static mapping this produces the §2 "trivial value type"
/// view; see `rust/tests/integration.rs::zero_overhead_view`.
pub fn array_view<R, M, const SIZE: usize, const BLOBS: usize>(
    mapping: M,
) -> View<R, M, ArrayStorage<SIZE, BLOBS>>
where
    R: RecordDim,
    M: Mapping<R> + MemoryAccess<R>,
{
    assert_eq!(M::BLOB_COUNT, BLOBS, "BLOBS must equal the mapping blob count");
    for i in 0..M::BLOB_COUNT {
        assert!(
            mapping.blob_size(i) <= SIZE,
            "blob {i} needs {} bytes, ArrayStorage provides {SIZE}",
            mapping.blob_size(i)
        );
    }
    View::from_parts(mapping, ArrayStorage::default())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn heap_alloc_zeroed() {
        let s = HeapAlloc.alloc(&[16, 32]);
        assert_eq!(s.blob_count(), 2);
        assert_eq!(s.blob(0).len(), 16);
        assert_eq!(s.blob(1).len(), 32);
        assert!(s.blob(1).iter().all(|&b| b == 0));
        assert_eq!(s.total_bytes(), 48);
    }

    #[test]
    fn aligned_alloc_alignment() {
        let s = AlignedAlloc::<64>.alloc(&[100, 7]);
        for i in 0..2 {
            assert_eq!(s.blob(i).as_ptr() as usize % 64, 0);
        }
        let s = AlignedAlloc::<4096>.alloc(&[10]);
        assert_eq!(s.blob(0).as_ptr() as usize % 4096, 0);
    }

    #[test]
    fn aligned_buf_clone_and_write() {
        let mut s = AlignedAlloc::<64>.alloc(&[8]);
        s.blob_mut(0)[3] = 0xab;
        let s2 = s.clone();
        assert_eq!(s2.blob(0)[3], 0xab);
    }

    #[test]
    fn array_storage_is_value_type() {
        let mut s = ArrayStorage::<64, 2>::default();
        s.blob_mut(1)[0] = 9;
        let copy = s; // Copy!
        assert_eq!(copy.blob(1)[0], 9);
        assert_eq!(std::mem::size_of::<ArrayStorage<64, 2>>(), 128);
    }

    #[test]
    fn zero_len_blobs() {
        let s = AlignedAlloc::<64>.alloc(&[0, 4]);
        assert_eq!(s.blob(0).len(), 0);
        assert_eq!(s.blob(1).len(), 4);
    }
}
