//! Blob storage and allocators.
//!
//! A mapping describes the data space as a set of *blobs* (byte buffers)
//! plus a rule locating each scalar in them. Where those bytes live is the
//! blob allocator's choice: heap vectors ([`HeapAlloc`]), cache-line/SIMD
//! aligned heap buffers ([`AlignedAlloc`]), or inline arrays
//! ([`ArrayStorage`] via [`array_view`]) — the last one making the whole
//! view a trivial value type when the extents are compile-time (§2: views
//! placeable in GPU shared memory; here: `memcpy`-able, stack-residing,
//! reinterpretable).
//!
//! # Byte-exact access and the parallel storage model
//!
//! The access layer reaches blob bytes through [`BlobStorage::bytes`] /
//! [`BlobStorage::bytes_mut`], which materialize a reference over
//! **exactly** the bytes one access touches — never a whole-blob slice.
//! For the exclusive storages in this module that is a plain sub-slice;
//! the distinction matters for the parallel engine: shard workers access
//! the *same* blobs concurrently through [`ShardBlobs`], a raw,
//! interior-mutable handle ([`BlobBytes`] spans). Because every
//! materialized reference covers only the bytes of one access, and the
//! sharding proof ([`crate::mapping::Mapping::shard_bounds`]) makes those
//! byte ranges disjoint across workers, the engine never creates
//! overlapping `&mut` — the whole parallel layer is expressible under
//! Stacked/Tree Borrows and runs under Miri (see `docs/PARALLELISM.md`).

use crate::mapping::{Mapping, MemoryAccess};
use crate::record::RecordDim;
use crate::view::View;

/// Byte storage for the blobs of a view.
///
/// # Safety-relevant contract
/// `blob(i)` / `blob_mut(i)` / `bytes(i, ..)` / `bytes_mut(i, ..)` must
/// address stable buffers of the size the mapping requested at allocation
/// for all `i < blob_count()`, and `blob_len(i)` must report that size.
///
/// # Byte-exact access
/// Mappings address storage through [`bytes`](BlobStorage::bytes) /
/// [`bytes_mut`](BlobStorage::bytes_mut) with the exact byte window of
/// one access. The provided implementations sub-slice
/// [`blob`](BlobStorage::blob) — correct for exclusively-owned storage.
/// [`ShardBlobs`] overrides them to materialize references over only the
/// requested window (its whole-blob methods panic instead), which is what
/// lets shard workers touch disjoint parts of one blob concurrently
/// without overlapping references.
pub trait BlobStorage {
    /// Number of blobs held.
    fn blob_count(&self) -> usize;
    /// Read access to blob `i`.
    fn blob(&self, i: usize) -> &[u8];
    /// Write access to blob `i`.
    fn blob_mut(&mut self, i: usize) -> &mut [u8];

    /// Byte length of blob `i` (without materializing a whole-blob
    /// reference — required wherever a [`ShardBlobs`] may be behind the
    /// trait).
    fn blob_len(&self, i: usize) -> usize {
        self.blob(i).len()
    }

    /// Shared access to exactly `len` bytes of blob `i` at offset `off`.
    #[inline(always)]
    fn bytes(&self, i: usize, off: usize, len: usize) -> &[u8] {
        &self.blob(i)[off..off + len]
    }

    /// Mutable access to exactly `len` bytes of blob `i` at offset `off`.
    #[inline(always)]
    fn bytes_mut(&mut self, i: usize, off: usize, len: usize) -> &mut [u8] {
        &mut self.blob_mut(i)[off..off + len]
    }

    /// Total bytes across all blobs (reporting).
    fn total_bytes(&self) -> usize {
        (0..self.blob_count()).map(|i| self.blob_len(i)).sum()
    }

    /// Extract one raw [`BlobBytes`] span per blob. The exclusive `&mut`
    /// receiver is the proof that no reference to the blob bytes is live
    /// at extraction time; see [`blob_spans`] for the lifetime contract.
    ///
    /// The default derives each span through a separate
    /// [`blob_mut`](BlobStorage::blob_mut) call — valid for storages
    /// whose blobs are separate allocations (heap vectors, aligned
    /// buffers: retagging the storage struct does not touch the heap
    /// data). Storages whose blobs live *inline in one allocation*
    /// ([`ArrayStorage`]) must override so all spans derive from a
    /// single exclusive reborrow — repeated whole-struct reborrows would
    /// invalidate the earlier spans under Stacked/Tree Borrows.
    fn spans(&mut self) -> Vec<BlobBytes> {
        (0..self.blob_count()).map(|i| BlobBytes::from_mut(self.blob_mut(i))).collect()
    }
}

/// Allocates blob storage for a mapping's blob sizes.
pub trait BlobAlloc {
    /// The storage this allocator produces.
    type Storage: BlobStorage;
    /// Allocate zero-initialized blobs of the given sizes.
    fn alloc(&self, sizes: &[usize]) -> Self::Storage;
}

// ---------------------------------------------------------------------------
// Heap storage
// ---------------------------------------------------------------------------

/// Plain heap storage: one `Vec<u8>` per blob.
#[derive(Clone, Debug, Default)]
pub struct HeapStorage {
    blobs: Vec<Vec<u8>>,
}

impl HeapStorage {
    /// Storage adopting existing buffers as blobs, without copying.
    ///
    /// The byte-adoption path of the view transport
    /// ([`crate::transport::decode_adopt`]): wire payload bytes become
    /// view storage directly. [`crate::view::View::from_parts`] validates
    /// the sizes against the mapping.
    pub fn from_blobs(blobs: Vec<Vec<u8>>) -> Self {
        HeapStorage { blobs }
    }

    /// Take the blob buffers back out, without copying (the encode-side
    /// counterpart of [`from_blobs`](HeapStorage::from_blobs)).
    pub fn into_blobs(self) -> Vec<Vec<u8>> {
        self.blobs
    }
}

impl BlobStorage for HeapStorage {
    #[inline]
    fn blob_count(&self) -> usize {
        self.blobs.len()
    }
    #[inline(always)]
    fn blob(&self, i: usize) -> &[u8] {
        &self.blobs[i]
    }
    #[inline(always)]
    fn blob_mut(&mut self, i: usize) -> &mut [u8] {
        &mut self.blobs[i]
    }
}

/// Allocator producing [`HeapStorage`] (LLAMA's `bloballoc::Vector`).
#[derive(Clone, Copy, Debug, Default)]
pub struct HeapAlloc;

impl BlobAlloc for HeapAlloc {
    type Storage = HeapStorage;
    fn alloc(&self, sizes: &[usize]) -> HeapStorage {
        HeapStorage { blobs: sizes.iter().map(|&s| vec![0u8; s]).collect() }
    }
}

// ---------------------------------------------------------------------------
// Aligned heap storage
// ---------------------------------------------------------------------------

/// A heap buffer with a guaranteed start alignment.
#[derive(Debug)]
pub struct AlignedBuf {
    ptr: *mut u8,
    len: usize,
    align: usize,
}

// SAFETY: AlignedBuf owns its allocation exclusively.
unsafe impl Send for AlignedBuf {}
unsafe impl Sync for AlignedBuf {}

impl AlignedBuf {
    /// Allocate `len` zeroed bytes aligned to `align` (a power of two).
    pub fn zeroed(len: usize, align: usize) -> Self {
        assert!(align.is_power_of_two());
        if len == 0 {
            return AlignedBuf { ptr: std::ptr::null_mut(), len: 0, align };
        }
        let layout = std::alloc::Layout::from_size_align(len, align).expect("bad layout");
        // SAFETY: len > 0, layout valid.
        let ptr = unsafe { std::alloc::alloc_zeroed(layout) };
        assert!(!ptr.is_null(), "allocation failure of {len} bytes");
        AlignedBuf { ptr, len, align }
    }

    /// The buffer contents.
    #[inline(always)]
    pub fn as_slice(&self) -> &[u8] {
        if self.len == 0 {
            return &[];
        }
        // SAFETY: ptr valid for len bytes, exclusive ownership.
        unsafe { std::slice::from_raw_parts(self.ptr, self.len) }
    }

    /// The buffer contents, mutably.
    #[inline(always)]
    pub fn as_mut_slice(&mut self) -> &mut [u8] {
        if self.len == 0 {
            return &mut [];
        }
        // SAFETY: ptr valid for len bytes, exclusive ownership.
        unsafe { std::slice::from_raw_parts_mut(self.ptr, self.len) }
    }
}

impl Drop for AlignedBuf {
    fn drop(&mut self) {
        if !self.ptr.is_null() {
            let layout = std::alloc::Layout::from_size_align(self.len, self.align).unwrap();
            // SAFETY: allocated with this exact layout.
            unsafe { std::alloc::dealloc(self.ptr, layout) };
        }
    }
}

impl Clone for AlignedBuf {
    fn clone(&self) -> Self {
        let mut new = AlignedBuf::zeroed(self.len, self.align);
        new.as_mut_slice().copy_from_slice(self.as_slice());
        new
    }
}

/// Aligned heap storage: one [`AlignedBuf`] per blob.
#[derive(Clone, Debug)]
pub struct AlignedStorage {
    blobs: Vec<AlignedBuf>,
}

impl BlobStorage for AlignedStorage {
    #[inline]
    fn blob_count(&self) -> usize {
        self.blobs.len()
    }
    #[inline(always)]
    fn blob(&self, i: usize) -> &[u8] {
        self.blobs[i].as_slice()
    }
    #[inline(always)]
    fn blob_mut(&mut self, i: usize) -> &mut [u8] {
        self.blobs[i].as_mut_slice()
    }
}

/// Allocator producing blob buffers aligned to `ALIGN` bytes (default 64:
/// cache line; use 4096 for page alignment). LLAMA's
/// `bloballoc::AlignedAllocator`.
#[derive(Clone, Copy, Debug, Default)]
pub struct AlignedAlloc<const ALIGN: usize = 64>;

impl<const ALIGN: usize> BlobAlloc for AlignedAlloc<ALIGN> {
    type Storage = AlignedStorage;
    fn alloc(&self, sizes: &[usize]) -> AlignedStorage {
        AlignedStorage { blobs: sizes.iter().map(|&s| AlignedBuf::zeroed(s, ALIGN)).collect() }
    }
}

// ---------------------------------------------------------------------------
// NUMA first-touch placement
// ---------------------------------------------------------------------------

/// Allocator adapter applying the NUMA **first-touch** placement policy:
/// after the inner allocator produces the (zeroed, lazily-mapped) blobs,
/// each worker of the **crate-global** pool faults in the pages of the
/// byte range its dispatch slot will own in a sharded traversal
/// ([`crate::pool::first_touch`]) — on a first-touch kernel those pages
/// become resident on that worker's NUMA node, so a later traversal
/// through the implicit parallel entry points reads node-local memory.
/// Views that will be traversed on an *explicit* pool (`*_on` entry
/// points) should instead allocate plainly and place with
/// [`crate::pool::first_touch_on`] against that same pool — the slot
/// partition is per-pool.
///
/// The default inner allocator is page-aligned ([`AlignedAlloc<4096>`]):
/// each blob's *start* then sits on a page boundary (interior slot
/// boundaries generally fall mid-page, so boundary pages land on
/// whichever neighbouring slot's worker faults them first — placement
/// is best-effort at page granularity). A no-op (beyond the inner
/// allocation) when `LLAMA_NUMA=off`/`LLAMA_POOL=off`, under Miri, or
/// whenever placement cannot help (single-node machines — the global
/// pool is then unpinned — or single-worker pools); the touch itself is
/// value-preserving, so contents equal the inner allocator's either
/// way.
#[derive(Clone, Copy, Debug, Default)]
pub struct FirstTouchAlloc<A = AlignedAlloc<4096>>(pub A);

impl<A: BlobAlloc> BlobAlloc for FirstTouchAlloc<A> {
    type Storage = A::Storage;
    fn alloc(&self, sizes: &[usize]) -> A::Storage {
        let mut storage = self.0.alloc(sizes);
        crate::pool::first_touch(&mut storage);
        storage
    }
}

// ---------------------------------------------------------------------------
// Inline array storage (the trivially-copyable view of §2)
// ---------------------------------------------------------------------------

/// Inline storage: `BLOBS` byte arrays of `SIZE` bytes each, held by value.
///
/// With fully static extents and a stateless mapping, a
/// `View<_, ArrayStorage<..>>` is a plain value containing only the mapped
/// bytes — the paper's "trivial value type ... storage-wise equivalent to
/// the mapped data" that can be memcpy-ed or placed in shared memory.
#[derive(Clone, Copy, Debug)]
pub struct ArrayStorage<const SIZE: usize, const BLOBS: usize> {
    blobs: [[u8; SIZE]; BLOBS],
}

impl<const SIZE: usize, const BLOBS: usize> Default for ArrayStorage<SIZE, BLOBS> {
    fn default() -> Self {
        ArrayStorage { blobs: [[0; SIZE]; BLOBS] }
    }
}

impl<const SIZE: usize, const BLOBS: usize> BlobStorage for ArrayStorage<SIZE, BLOBS> {
    #[inline(always)]
    fn blob_count(&self) -> usize {
        BLOBS
    }
    #[inline(always)]
    fn blob(&self, i: usize) -> &[u8] {
        &self.blobs[i]
    }
    #[inline(always)]
    fn blob_mut(&mut self, i: usize) -> &mut [u8] {
        &mut self.blobs[i]
    }
    fn spans(&mut self) -> Vec<BlobBytes> {
        // All blobs live inline in this one allocation: derive every
        // span from a single exclusive reborrow (`iter_mut` splits it
        // into disjoint `&mut`s), so no span invalidates another.
        self.blobs.iter_mut().map(|b| BlobBytes::from_mut(b)).collect()
    }
}

// ---------------------------------------------------------------------------
// Raw blob spans and the shard-worker storage (the Miri-clean parallel path)
// ---------------------------------------------------------------------------

/// A raw span over one blob's bytes: pointer + length, no borrow.
///
/// This is the `SyncUnsafeCell`-style escape hatch of the storage layer:
/// a span is extracted from a live `&mut [u8]` (capturing its provenance)
/// and can then be shared freely across threads — it is `Send + Sync`
/// because *holding* a span asserts nothing; only [`bytes`](BlobBytes::bytes)
/// / [`bytes_mut`](BlobBytes::bytes_mut) touch memory, and those are
/// `unsafe` with a disjointness contract. Every materialized reference
/// covers exactly the requested byte window, so two threads using spans
/// of the same blob on disjoint windows never create overlapping
/// references — the invariant the sharded engine is built on.
#[derive(Clone, Copy, Debug)]
pub struct BlobBytes {
    ptr: *mut u8,
    len: usize,
}

// SAFETY: a span is an address, not an access; all accesses go through the
// unsafe window methods whose contract covers cross-thread disjointness.
unsafe impl Send for BlobBytes {}
unsafe impl Sync for BlobBytes {}

impl BlobBytes {
    /// Capture a span over `slice` (provenance of the full buffer).
    ///
    /// The span does not borrow: it stays *valid* only for as long as the
    /// underlying buffer lives and is not accessed through any path that
    /// would invalidate `slice`'s provenance. The sharded engine ties
    /// that lifetime down with a `PhantomData<&mut View>` borrow.
    pub fn from_mut(slice: &mut [u8]) -> Self {
        BlobBytes { ptr: slice.as_mut_ptr(), len: slice.len() }
    }

    /// Length of the spanned buffer in bytes.
    #[inline(always)]
    pub fn len(&self) -> usize {
        self.len
    }

    /// Whether the spanned buffer is empty.
    #[inline(always)]
    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Shared view of exactly `len` bytes at `off` (bounds-checked).
    ///
    /// # Safety
    ///
    /// The underlying buffer must still be live (see
    /// [`from_mut`](BlobBytes::from_mut)), and for the returned
    /// reference's lifetime no other thread may *write* any byte of the
    /// window through another span of the same buffer.
    #[inline(always)]
    pub unsafe fn bytes(&self, off: usize, len: usize) -> &[u8] {
        // Overflow-proof form: `off + len` could wrap in release builds
        // and let a corrupt window through the check.
        assert!(len <= self.len && off <= self.len - len, "blob window out of bounds");
        // SAFETY: in bounds (just checked); validity and non-aliasing are
        // the caller's contract above.
        unsafe { std::slice::from_raw_parts(self.ptr.add(off), len) }
    }

    /// Mutable view of exactly `len` bytes at `off` (bounds-checked).
    ///
    /// # Safety
    ///
    /// As [`bytes`](BlobBytes::bytes), and additionally no other thread
    /// may *read or write* any byte of the window through another span
    /// for the returned reference's lifetime.
    #[inline(always)]
    #[allow(clippy::mut_from_ref)] // the whole point: interior mutability
    pub unsafe fn bytes_mut(&self, off: usize, len: usize) -> &mut [u8] {
        // Overflow-proof form; see `bytes`.
        assert!(len <= self.len && off <= self.len - len, "blob window out of bounds");
        // SAFETY: in bounds (just checked); validity and exclusivity of
        // the window are the caller's contract above.
        unsafe { std::slice::from_raw_parts_mut(self.ptr.add(off), len) }
    }
}

/// Extract one [`BlobBytes`] span per blob of `storage`
/// ([`BlobStorage::spans`]).
///
/// Takes `&mut` — the exclusive borrow is the proof that no reference to
/// the blob bytes is live when the spans are captured. Callers (the shard
/// engine, the parallel copy) must keep that exclusivity for as long as
/// the spans are used, e.g. by holding the `&mut` borrow in a
/// `PhantomData` for the span consumers' lifetime.
pub fn blob_spans<S: BlobStorage>(storage: &mut S) -> Vec<BlobBytes> {
    storage.spans()
}

/// Per-worker blob storage of the sharded parallel engine: one
/// [`BlobBytes`] span per blob of a shared view.
///
/// Implements [`BlobStorage`] with **byte-exact** windows: `bytes` /
/// `bytes_mut` materialize references over only the requested range, so
/// several workers holding `ShardBlobs` over the *same* blobs can access
/// disjoint byte ranges concurrently without ever creating overlapping
/// references (the property Miri's aliasing models check). The
/// whole-blob methods `blob` / `blob_mut` panic: a whole-blob reference
/// would overlap every other worker's windows by construction.
///
/// Constructed only by the parallel engine ([`crate::shard`]) and the
/// parallel copy ([`crate::copy`]); user kernels meet it as the storage
/// type of the record/chunk cursors inside `par_for_each` /
/// `par_transform_simd` closures.
#[derive(Clone, Debug)]
pub struct ShardBlobs {
    blobs: Vec<BlobBytes>,
}

impl ShardBlobs {
    /// Assemble a worker-side storage from blob spans.
    ///
    /// # Safety
    ///
    /// The caller must guarantee, for the lifetime of the returned value:
    ///
    /// 1. every span's underlying buffer stays live and is not accessed
    ///    through any other path than [`BlobBytes`] spans of the same
    ///    extraction (typically enforced by holding the `&mut View`
    ///    borrow the spans came from), and
    /// 2. byte ranges accessed through this storage are never accessed
    ///    concurrently through another handle to the same buffers,
    ///    except for concurrent *reads* of bytes nobody writes.
    ///
    /// The sharded traversal discharges (2) via the
    /// [`Mapping::shard_bounds`](crate::mapping::Mapping::shard_bounds)
    /// disjointness proof for everything a worker's own cursor touches;
    /// for whole-view chunk accessors that can reach other shards, the
    /// obligation is forwarded to `par_transform_simd`'s `unsafe`
    /// contract.
    pub unsafe fn new(blobs: Vec<BlobBytes>) -> Self {
        ShardBlobs { blobs }
    }
}

impl BlobStorage for ShardBlobs {
    #[inline]
    fn blob_count(&self) -> usize {
        self.blobs.len()
    }

    fn blob(&self, _i: usize) -> &[u8] {
        panic!("whole-blob access through ShardBlobs; use bytes(i, off, len)")
    }

    fn blob_mut(&mut self, _i: usize) -> &mut [u8] {
        panic!("whole-blob access through ShardBlobs; use bytes_mut(i, off, len)")
    }

    #[inline(always)]
    fn blob_len(&self, i: usize) -> usize {
        self.blobs[i].len()
    }

    #[inline(always)]
    fn bytes(&self, i: usize, off: usize, len: usize) -> &[u8] {
        // SAFETY: buffer liveness and window disjointness are the
        // `ShardBlobs::new` contract, discharged by the parallel engine.
        unsafe { self.blobs[i].bytes(off, len) }
    }

    #[inline(always)]
    fn bytes_mut(&mut self, i: usize, off: usize, len: usize) -> &mut [u8] {
        // SAFETY: as in `bytes`.
        unsafe { self.blobs[i].bytes_mut(off, len) }
    }

    fn spans(&mut self) -> Vec<BlobBytes> {
        // Spans are addresses: re-sharing them is exactly what this
        // handle exists for (the default would call the panicking
        // `blob_mut`).
        self.blobs.clone()
    }
}

// ---------------------------------------------------------------------------
// View construction helpers
// ---------------------------------------------------------------------------

/// Allocate a [`View`] for `mapping` using `alloc`.
///
/// ```
/// use llama::prelude::*;
/// llama::record! { pub struct P, mod p { x: f32, y: f32 } }
/// let view = alloc_view(SoA::<P, _>::new((Dyn(16u32),)), &HeapAlloc);
/// assert_eq!(view.storage().total_bytes(), 16 * 8);
/// ```
pub fn alloc_view<R, M, A>(mapping: M, alloc: &A) -> View<R, M, A::Storage>
where
    R: RecordDim,
    M: Mapping<R> + MemoryAccess<R>,
    A: BlobAlloc,
{
    let sizes: Vec<usize> = (0..M::BLOB_COUNT).map(|i| mapping.blob_size(i)).collect();
    let storage = alloc.alloc(&sizes);
    View::from_parts(mapping, storage)
}

/// Build a view over inline array storage (compile-time sizes).
///
/// `SIZE` must be at least the largest blob size of the mapping and `BLOBS`
/// must equal the mapping's blob count — both checked at construction.
/// For a fully-static mapping this produces the §2 "trivial value type"
/// view; see `rust/tests/integration.rs::zero_overhead_view`.
pub fn array_view<R, M, const SIZE: usize, const BLOBS: usize>(
    mapping: M,
) -> View<R, M, ArrayStorage<SIZE, BLOBS>>
where
    R: RecordDim,
    M: Mapping<R> + MemoryAccess<R>,
{
    assert_eq!(M::BLOB_COUNT, BLOBS, "BLOBS must equal the mapping blob count");
    for i in 0..M::BLOB_COUNT {
        assert!(
            mapping.blob_size(i) <= SIZE,
            "blob {i} needs {} bytes, ArrayStorage provides {SIZE}",
            mapping.blob_size(i)
        );
    }
    View::from_parts(mapping, ArrayStorage::default())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn heap_alloc_zeroed() {
        let s = HeapAlloc.alloc(&[16, 32]);
        assert_eq!(s.blob_count(), 2);
        assert_eq!(s.blob(0).len(), 16);
        assert_eq!(s.blob(1).len(), 32);
        assert!(s.blob(1).iter().all(|&b| b == 0));
        assert_eq!(s.total_bytes(), 48);
    }

    #[test]
    fn aligned_alloc_alignment() {
        let s = AlignedAlloc::<64>.alloc(&[100, 7]);
        for i in 0..2 {
            assert_eq!(s.blob(i).as_ptr() as usize % 64, 0);
        }
        let s = AlignedAlloc::<4096>.alloc(&[10]);
        assert_eq!(s.blob(0).as_ptr() as usize % 4096, 0);
    }

    #[test]
    fn aligned_buf_clone_and_write() {
        let mut s = AlignedAlloc::<64>.alloc(&[8]);
        s.blob_mut(0)[3] = 0xab;
        let s2 = s.clone();
        assert_eq!(s2.blob(0)[3], 0xab);
    }

    #[test]
    fn array_storage_is_value_type() {
        let mut s = ArrayStorage::<64, 2>::default();
        s.blob_mut(1)[0] = 9;
        let copy = s; // Copy!
        assert_eq!(copy.blob(1)[0], 9);
        assert_eq!(std::mem::size_of::<ArrayStorage<64, 2>>(), 128);
    }

    #[test]
    fn first_touch_alloc_is_zeroed_and_page_aligned() {
        // Placement is invisible to correctness: contents and alignment
        // must equal the inner allocator's.
        let s = FirstTouchAlloc::<AlignedAlloc<4096>>::default().alloc(&[2 * 4096 + 5, 64]);
        assert_eq!(s.blob_count(), 2);
        assert_eq!(s.blob(0).len(), 2 * 4096 + 5);
        assert!(s.blob(0).iter().all(|&b| b == 0));
        assert!(s.blob(1).iter().all(|&b| b == 0));
        assert_eq!(s.blob(0).as_ptr() as usize % 4096, 0);
    }

    #[test]
    fn zero_len_blobs() {
        let s = AlignedAlloc::<64>.alloc(&[0, 4]);
        assert_eq!(s.blob(0).len(), 0);
        assert_eq!(s.blob(1).len(), 4);
    }

    #[test]
    fn byte_exact_windows_default_to_subslices() {
        let mut s = HeapAlloc.alloc(&[16]);
        s.bytes_mut(0, 4, 2).copy_from_slice(&[0xab, 0xcd]);
        assert_eq!(s.bytes(0, 4, 2), &[0xab, 0xcd]);
        assert_eq!(s.blob(0)[4], 0xab);
        assert_eq!(s.blob_len(0), 16);
    }

    #[test]
    fn shard_blobs_window_access_roundtrips() {
        let mut s = HeapAlloc.alloc(&[8, 4]);
        // SAFETY: single handle, source borrow held for the whole test.
        let mut sh = unsafe { ShardBlobs::new(blob_spans(&mut s)) };
        assert_eq!(sh.blob_count(), 2);
        assert_eq!(sh.blob_len(0), 8);
        assert_eq!(sh.blob_len(1), 4);
        sh.bytes_mut(1, 1, 2).copy_from_slice(&[7, 9]);
        assert_eq!(sh.bytes(1, 0, 4), &[0, 7, 9, 0]);
        assert_eq!(sh.total_bytes(), 12);
        drop(sh);
        assert_eq!(s.blob(1), &[0, 7, 9, 0]);
    }

    #[test]
    #[should_panic(expected = "blob window out of bounds")]
    fn shard_blobs_windows_are_bounds_checked() {
        let mut s = HeapAlloc.alloc(&[8]);
        let sh = unsafe { ShardBlobs::new(blob_spans(&mut s)) };
        let _ = sh.bytes(0, 5, 4);
    }

    #[test]
    #[should_panic(expected = "whole-blob access through ShardBlobs")]
    fn shard_blobs_refuses_whole_blob_references() {
        let mut s = HeapAlloc.alloc(&[8]);
        let sh = unsafe { ShardBlobs::new(blob_spans(&mut s)) };
        let _ = sh.blob(0);
    }

    #[test]
    fn disjoint_windows_of_one_blob_from_two_handles() {
        // The invariant the sharded engine relies on, in miniature: two
        // handles over the same blob, touching disjoint halves.
        let mut s = HeapAlloc.alloc(&[8]);
        let spans = blob_spans(&mut s);
        // SAFETY: the two handles below only ever access disjoint byte
        // ranges ([0,4) vs [4,8)), and `s` stays mutably borrowed.
        let mut a = unsafe { ShardBlobs::new(spans.clone()) };
        let mut b = unsafe { ShardBlobs::new(spans) };
        a.bytes_mut(0, 0, 4).copy_from_slice(&[1, 2, 3, 4]);
        b.bytes_mut(0, 4, 4).copy_from_slice(&[5, 6, 7, 8]);
        drop((a, b));
        assert_eq!(s.blob(0), &[1, 2, 3, 4, 5, 6, 7, 8]);
    }
}
