//! Supervised TCP serving front-end for the coordinator.
//!
//! [`Server`] binds a `TcpListener` and speaks the [`CtrlFrame`]
//! protocol (magic `LLWc`, same `WIRE_VERSION`/CRC discipline as view
//! frames): clients send `Submit`, the server answers with exactly one
//! typed reply per submit — `Result`, `QueueFull{retry_after_ms}`,
//! `QuotaExceeded`, `Corrupt`, `Draining`, `Shed` or `TimedOut` — so
//! every failure that used to die at the process edge (the ingest
//! backpressure hint above all) crosses the wire as data.
//!
//! Connection lifecycle (full state machine in `docs/SERVING.md` §6):
//!
//! - **Accept-time shedding.** At most [`ServeConfig::max_connections`]
//!   connections are served; one over the cap gets a typed
//!   [`CtrlFrame::Shed`] with a reconnect hint instead of a silent
//!   close.
//! - **Idle timeout.** A connection with no frame in progress must send
//!   a byte within [`ServeConfig::idle_timeout`] or it is evicted with
//!   `TimedOut{phase: Idle}`.
//! - **Partial-frame deadline** (slow-loris protection). Once the first
//!   byte of a frame arrives, the whole frame must land within
//!   [`ServeConfig::frame_timeout`] or the client gets
//!   `TimedOut{phase: MidFrame}` and a disconnect. Both budgets are
//!   enforced with `set_read_timeout` windows that shrink as the
//!   deadline nears — a trickling client cannot reset them.
//! - **Graceful drain.** [`Server::shutdown`] stops accepting, replies
//!   `Draining` to new submits, flushes in-flight jobs under
//!   [`ServeConfig::drain_timeout`], then hard-aborts whatever is left
//!   (socket shutdown; running jobs are detached — Rust threads cannot
//!   be killed). The [`ServeReport`] renders the outcome plus exact
//!   connection/frame counters.
//!
//! [`Client`] is the matching caller: it reconnects, honors server
//! `retry_after` hints (sleeping the hinted backoff before
//! resubmitting), and falls back to [`RetryPolicy`] backoff for
//! transport-level failures. The whole lifecycle is chaos-tested by
//! threading [`crate::fault::FaultyStream`] over the client side of
//! real sockets (`rust/tests/serve.rs`).
//!
//! Everything here is std-only: `TcpListener`/`TcpStream`, one thread
//! per connection (the cap bounds them), `mpsc` for result routing.

use std::collections::{HashMap, HashSet};
use std::io::{self, Read};
use std::net::{Shutdown, SocketAddr, TcpListener, TcpStream, ToSocketAddrs};
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicU8, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::thread::{self, JoinHandle};
use std::time::{Duration, Instant};

use crate::coordinator::ingest::{Admission, Ingest, SubmitError};
use crate::coordinator::job::{Backend, JobResult, JobSpec, Layout};
use crate::coordinator::metrics::Metrics;
use crate::coordinator::{Config, Coordinator, RetryPolicy};
use crate::fault::{hash2, FaultConfig, FaultPlan, FaultyStream};
use crate::transport::{wire_error_in, CtrlFrame, TimeoutPhase, WireError};

/// How often the accept loop re-polls its nonblocking listener.
const ACCEPT_POLL: Duration = Duration::from_millis(5);

/// Floor for any `set_read_timeout` window: a zero duration would be
/// rejected by the OS, and sub-millisecond windows just spin.
const MIN_READ_WINDOW: Duration = Duration::from_millis(1);

// ---------------------------------------------------------------------------
// Configuration
// ---------------------------------------------------------------------------

/// Tunables for the TCP front-end (the coordinator itself is configured
/// separately via [`Config`]).
#[derive(Clone, Debug)]
pub struct ServeConfig {
    /// Served-connection cap; one over it is shed with a typed reply.
    pub max_connections: usize,
    /// Max quiet time between frames before eviction.
    pub idle_timeout: Duration,
    /// Max time from a frame's first byte to its last (slow-loris cap).
    pub frame_timeout: Duration,
    /// Write deadline for replies (and the shed notice).
    pub io_timeout: Duration,
    /// How long [`Server::shutdown`] waits for in-flight jobs before
    /// hard-aborting the remaining connections.
    pub drain_timeout: Duration,
    /// Reconnect hint carried by the [`CtrlFrame::Shed`] reply.
    pub shed_retry: Duration,
    /// Poll granularity for result waits and the drain loop.
    pub result_poll: Duration,
    /// Largest particle count a remote submit may request.
    pub max_job_records: u64,
    /// Largest step count a remote submit may request.
    pub max_job_steps: u64,
}

impl Default for ServeConfig {
    fn default() -> Self {
        ServeConfig {
            max_connections: 64,
            idle_timeout: Duration::from_secs(30),
            frame_timeout: Duration::from_secs(2),
            io_timeout: Duration::from_secs(2),
            drain_timeout: Duration::from_secs(5),
            shed_retry: Duration::from_millis(100),
            result_poll: Duration::from_millis(25),
            max_job_records: 1 << 20,
            max_job_steps: 1 << 20,
        }
    }
}

// ---------------------------------------------------------------------------
// Deadline bookkeeping (pure state machine — Miri-tested)
// ---------------------------------------------------------------------------

/// Which deadline currently governs a connection's next read, and how
/// much of it is left.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ReadBudget {
    /// Remaining time before the governing deadline fires.
    pub remaining: Duration,
    /// Which timeout fires when `remaining` hits zero.
    pub phase: TimeoutPhase,
}

/// Per-connection deadline state machine over *relative* time.
///
/// Deliberately clock-free: callers feed it `t0.elapsed()` offsets, so
/// the logic is deterministic under test (and runs under Miri, which
/// the socket plumbing cannot). Between frames the **idle** budget
/// counts from the last completed frame; from the first byte of a frame
/// until [`FrameClock::frame_done`] the **mid-frame** budget counts
/// from that first byte — progress inside a frame does *not* extend it,
/// which is the slow-loris defense.
#[derive(Clone, Copy, Debug)]
pub struct FrameClock {
    idle: Duration,
    frame: Duration,
    frame_open: bool,
    frame_start: Duration,
    last_done: Duration,
}

impl FrameClock {
    /// A fresh connection clock; `now` starts at zero.
    pub fn new(idle: Duration, frame: Duration) -> FrameClock {
        FrameClock {
            idle,
            frame,
            frame_open: false,
            frame_start: Duration::ZERO,
            last_done: Duration::ZERO,
        }
    }

    /// Record that at least one byte arrived at offset `now`. The first
    /// byte after a completed frame opens the next frame and starts the
    /// mid-frame budget; later bytes of the same frame change nothing.
    pub fn byte_read(&mut self, now: Duration) {
        if !self.frame_open {
            self.frame_open = true;
            self.frame_start = now;
        }
    }

    /// Record that a full frame was parsed at offset `now`; the idle
    /// budget restarts here.
    pub fn frame_done(&mut self, now: Duration) {
        self.frame_open = false;
        self.last_done = now;
    }

    /// Is a frame currently in progress (started but not done)?
    pub fn mid_frame(&self) -> bool {
        self.frame_open
    }

    /// The governing deadline at offset `now`.
    pub fn budget(&self, now: Duration) -> ReadBudget {
        if self.frame_open {
            ReadBudget {
                remaining: (self.frame_start + self.frame).saturating_sub(now),
                phase: TimeoutPhase::MidFrame,
            }
        } else {
            ReadBudget {
                remaining: (self.last_done + self.idle).saturating_sub(now),
                phase: TimeoutPhase::Idle,
            }
        }
    }
}

/// Typed payload carried by deadline-expiry `io::Error`s, so the
/// failure classifier can tell *which* phase fired without re-deriving
/// it from clock state.
#[derive(Clone, Copy, Debug)]
pub struct DeadlineExpired {
    /// Which budget ran out.
    pub phase: TimeoutPhase,
}

impl std::fmt::Display for DeadlineExpired {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "connection deadline expired ({})", self.phase)
    }
}

impl std::error::Error for DeadlineExpired {}

fn deadline_expired(phase: TimeoutPhase) -> io::Error {
    io::Error::new(io::ErrorKind::TimedOut, DeadlineExpired { phase })
}

/// A [`Read`] over a `TcpStream` that enforces a [`FrameClock`]: every
/// read gets a `set_read_timeout` window no longer than the remaining
/// budget (floor [`MIN_READ_WINDOW`]), so a client trickling one byte
/// per window still hits the frame deadline.
struct DeadlineReader<'a> {
    stream: &'a TcpStream,
    clock: &'a mut FrameClock,
    t0: Instant,
}

impl Read for DeadlineReader<'_> {
    fn read(&mut self, buf: &mut [u8]) -> io::Result<usize> {
        let budget = self.clock.budget(self.t0.elapsed());
        if budget.remaining.is_zero() {
            return Err(deadline_expired(budget.phase));
        }
        self.stream.set_read_timeout(Some(budget.remaining.max(MIN_READ_WINDOW)))?;
        let mut inner: &TcpStream = self.stream;
        match inner.read(buf) {
            Ok(0) => Ok(0),
            Ok(n) => {
                self.clock.byte_read(self.t0.elapsed());
                Ok(n)
            }
            Err(e)
                if matches!(e.kind(), io::ErrorKind::WouldBlock | io::ErrorKind::TimedOut) =>
            {
                Err(deadline_expired(budget.phase))
            }
            Err(e) => Err(e),
        }
    }
}

// ---------------------------------------------------------------------------
// Read-failure taxonomy
// ---------------------------------------------------------------------------

/// Why a frame read failed, reduced to the server's response policy.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
enum ReadFailure {
    /// A connection deadline fired; reply `TimedOut{phase}`, close.
    TimedOut(TimeoutPhase),
    /// CRC mismatch; reply `Corrupt{expected, got}`, close.
    Corrupt {
        expected: u32,
        got: u32,
    },
    /// Framed garbage (bad magic/version/kind/field); reply
    /// `Corrupt{0, 0}`, close.
    Malformed,
    /// Peer went away (EOF, reset, broken pipe); close silently.
    Disconnected,
    /// Anything else the OS produced; close silently.
    Io,
}

fn classify_read_failure(e: &io::Error, mid_frame: bool) -> ReadFailure {
    if let Some(d) = e.get_ref().and_then(|b| b.downcast_ref::<DeadlineExpired>()) {
        return ReadFailure::TimedOut(d.phase);
    }
    if matches!(e.kind(), io::ErrorKind::TimedOut | io::ErrorKind::WouldBlock) {
        let phase = if mid_frame { TimeoutPhase::MidFrame } else { TimeoutPhase::Idle };
        return ReadFailure::TimedOut(phase);
    }
    if let Some(WireError::Corrupt { expected, got }) = wire_error_in(e) {
        return ReadFailure::Corrupt { expected: *expected, got: *got };
    }
    if e.kind() == io::ErrorKind::InvalidData {
        return ReadFailure::Malformed;
    }
    match e.kind() {
        io::ErrorKind::UnexpectedEof
        | io::ErrorKind::ConnectionReset
        | io::ErrorKind::ConnectionAborted
        | io::ErrorKind::BrokenPipe => ReadFailure::Disconnected,
        _ => ReadFailure::Io,
    }
}

// ---------------------------------------------------------------------------
// Metrics
// ---------------------------------------------------------------------------

/// Front-end counters, separate from the coordinator's job
/// [`Metrics`] — these count *connections and frames*.
#[derive(Debug, Default)]
pub struct ServeMetrics {
    accepted: AtomicU64,
    active: AtomicU64,
    shed: AtomicU64,
    idle_evicted: AtomicU64,
    slow_frames: AtomicU64,
    disconnects: AtomicU64,
    corrupt_frames: AtomicU64,
    malformed: AtomicU64,
    submits: AtomicU64,
    results_sent: AtomicU64,
    rejects_queue_full: AtomicU64,
    rejects_quota: AtomicU64,
    draining_replies: AtomicU64,
    in_flight: AtomicU64,
    orphaned: AtomicU64,
}

impl ServeMetrics {
    /// Connections admitted past the cap check.
    pub fn accepted(&self) -> u64 {
        self.accepted.load(Ordering::Acquire)
    }

    /// Connections currently being served.
    pub fn active(&self) -> u64 {
        self.active.load(Ordering::Acquire)
    }

    /// Connections refused at accept time with a `Shed` reply.
    pub fn shed(&self) -> u64 {
        self.shed.load(Ordering::Acquire)
    }

    /// Connections evicted by the idle timeout.
    pub fn idle_evicted(&self) -> u64 {
        self.idle_evicted.load(Ordering::Acquire)
    }

    /// Connections evicted by the partial-frame (slow-loris) deadline.
    pub fn slow_frames(&self) -> u64 {
        self.slow_frames.load(Ordering::Acquire)
    }

    /// Connections that dropped without a clean protocol ending.
    pub fn disconnects(&self) -> u64 {
        self.disconnects.load(Ordering::Acquire)
    }

    /// Frames rejected for CRC mismatch.
    pub fn corrupt_frames(&self) -> u64 {
        self.corrupt_frames.load(Ordering::Acquire)
    }

    /// Frames rejected as framed garbage (bad magic/kind/field or an
    /// out-of-policy submit).
    pub fn malformed(&self) -> u64 {
        self.malformed.load(Ordering::Acquire)
    }

    /// Submit frames received.
    pub fn submits(&self) -> u64 {
        self.submits.load(Ordering::Acquire)
    }

    /// Result frames delivered.
    pub fn results_sent(&self) -> u64 {
        self.results_sent.load(Ordering::Acquire)
    }

    /// `QueueFull` replies sent (the retry-after hint crossing the wire).
    pub fn rejects_queue_full(&self) -> u64 {
        self.rejects_queue_full.load(Ordering::Acquire)
    }

    /// `QuotaExceeded` replies sent.
    pub fn rejects_quota(&self) -> u64 {
        self.rejects_quota.load(Ordering::Acquire)
    }

    /// `Draining` replies sent.
    pub fn draining_replies(&self) -> u64 {
        self.draining_replies.load(Ordering::Acquire)
    }

    /// Jobs admitted whose result has not yet been written back.
    pub fn in_flight(&self) -> u64 {
        self.in_flight.load(Ordering::Acquire)
    }

    /// Results that completed after their connection gave up (aborted
    /// drain or vanished client) — computed work with no recipient.
    pub fn orphaned(&self) -> u64 {
        self.orphaned.load(Ordering::Acquire)
    }

    /// Multi-line status block (the `llama-lab serve` epilogue; CI
    /// greps the `conns:` line).
    pub fn render(&self) -> String {
        let timed_out = self.idle_evicted() + self.slow_frames();
        let mut s = String::new();
        s.push_str(&format!(
            "conns: accepted {} · active {} · shed {} · timed out {} (idle {}, mid-frame {})\n",
            self.accepted(),
            self.active(),
            self.shed(),
            timed_out,
            self.idle_evicted(),
            self.slow_frames(),
        ));
        s.push_str(&format!(
            "frames: submits {} · results {} · queue-full {} · quota {} · draining {} · corrupt {} · malformed {} · disconnects {}\n",
            self.submits(),
            self.results_sent(),
            self.rejects_queue_full(),
            self.rejects_quota(),
            self.draining_replies(),
            self.corrupt_frames(),
            self.malformed(),
            self.disconnects(),
        ));
        s.push_str(&format!("jobs: in flight {} · orphaned {}\n", self.in_flight(), self.orphaned()));
        s
    }
}

// ---------------------------------------------------------------------------
// Result routing
// ---------------------------------------------------------------------------

/// Routes the coordinator's streaming [`JobResult`]s to the connection
/// threads waiting on them, by job id.
///
/// Three-way state per id: a **waiter** registered before the result
/// arrived (send it through), an **unclaimed** result that arrived
/// before its waiter (rare — the submit path registers immediately, but
/// the router thread races it), or an **abandoned** id whose waiter
/// gave up (drain abort, vanished client): its result, when it lands,
/// counts as orphaned and is dropped.
#[derive(Clone)]
struct ResultRouter {
    state: Arc<Mutex<RouterState>>,
    metrics: Arc<ServeMetrics>,
}

#[derive(Default)]
struct RouterState {
    waiting: HashMap<u64, mpsc::Sender<JobResult>>,
    unclaimed: HashMap<u64, JobResult>,
    abandoned: HashSet<u64>,
}

enum Claim {
    /// The result already arrived.
    Ready(Box<JobResult>),
    /// Registered; the result will arrive on this channel.
    Wait(mpsc::Receiver<JobResult>),
}

impl ResultRouter {
    fn new(metrics: Arc<ServeMetrics>) -> ResultRouter {
        ResultRouter { state: Arc::new(Mutex::new(RouterState::default())), metrics }
    }

    /// Deliver one result (router thread).
    fn route(&self, r: JobResult) {
        let mut st = self.state.lock().unwrap();
        if let Some(tx) = st.waiting.remove(&r.id) {
            if tx.send(r).is_err() {
                // Waiter hung up between registering and receiving.
                self.metrics.orphaned.fetch_add(1, Ordering::Relaxed);
            }
        } else if st.abandoned.remove(&r.id) {
            self.metrics.orphaned.fetch_add(1, Ordering::Relaxed);
        } else {
            st.unclaimed.insert(r.id, r);
        }
    }

    /// Register interest in job `id` (connection thread).
    fn claim(&self, id: u64) -> Claim {
        let mut st = self.state.lock().unwrap();
        if let Some(r) = st.unclaimed.remove(&id) {
            return Claim::Ready(Box::new(r));
        }
        let (tx, rx) = mpsc::channel();
        st.waiting.insert(id, tx);
        Claim::Wait(rx)
    }

    /// The waiter for `id` gives up; its result (if it ever lands) is
    /// orphaned.
    fn abandon(&self, id: u64) {
        let mut st = self.state.lock().unwrap();
        st.waiting.remove(&id);
        if st.unclaimed.remove(&id).is_some() {
            self.metrics.orphaned.fetch_add(1, Ordering::Relaxed);
        } else {
            st.abandoned.insert(id);
        }
    }
}

// ---------------------------------------------------------------------------
// Server
// ---------------------------------------------------------------------------

const STATE_RUNNING: u8 = 0;
const STATE_DRAINING: u8 = 1;
const STATE_ABORTED: u8 = 2;

struct Shared {
    cfg: ServeConfig,
    state: AtomicU8,
    router_done: AtomicBool,
    metrics: Arc<ServeMetrics>,
    coord_metrics: Arc<Metrics>,
    ingest: Ingest,
    router: ResultRouter,
    /// `try_clone`d handles of every served connection, for the
    /// hard-abort path: `Shutdown::Both` on the clone wakes the
    /// connection thread's blocked read with EOF.
    conns: Mutex<HashMap<u64, TcpStream>>,
}

impl Shared {
    fn state(&self) -> u8 {
        self.state.load(Ordering::Acquire)
    }
}

/// How a [`Server::shutdown`] drain ended.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DrainOutcome {
    /// Every in-flight job finished and its result was written back
    /// within the drain deadline.
    Completed,
    /// The deadline fired with jobs still in flight; the remaining
    /// connections were hard-aborted and the jobs detached.
    TimedOut,
}

/// Final accounting from [`Server::shutdown`].
pub struct ServeReport {
    /// Drain outcome.
    pub outcome: DrainOutcome,
    /// Wall time the drain took (deadline-capped when `TimedOut`).
    pub elapsed: Duration,
    /// Connections still open when the server force-closed them.
    pub aborted_connections: u64,
    /// Front-end counters (final values).
    pub metrics: Arc<ServeMetrics>,
    /// The coordinator's job metrics registry (outlives the drain).
    pub coordinator: Arc<Metrics>,
}

fn render_drain(outcome: DrainOutcome, elapsed: Duration, aborted: u64) -> String {
    match outcome {
        DrainOutcome::Completed => {
            format!("drain: completed in {elapsed:?} ({aborted} connections aborted)")
        }
        DrainOutcome::TimedOut => {
            format!("drain: timed out after {elapsed:?} ({aborted} connections aborted)")
        }
    }
}

impl ServeReport {
    /// The one-line drain verdict (CI greps for
    /// `^drain: (completed|timed out)`).
    pub fn drain_line(&self) -> String {
        render_drain(self.outcome, self.elapsed, self.aborted_connections)
    }

    /// The full `serve` status block: front-end counters plus the
    /// drain line.
    pub fn render(&self) -> String {
        format!("{}{}\n", self.metrics.render(), self.drain_line())
    }
}

/// A running TCP front-end. Construct with [`Server::bind`], stop with
/// [`Server::shutdown`] (graceful drain). Dropping without `shutdown`
/// hard-aborts.
pub struct Server {
    shared: Arc<Shared>,
    local_addr: SocketAddr,
    coordinator: Option<Coordinator>,
    accept_thread: Option<JoinHandle<()>>,
    router_thread: Option<JoinHandle<()>>,
    conn_threads: Arc<Mutex<Vec<JoinHandle<()>>>>,
}

impl Server {
    /// Start a coordinator under `coord` and serve it on `addr`
    /// (`"127.0.0.1:0"` picks a free port — read it back with
    /// [`Server::local_addr`]).
    pub fn bind<A: ToSocketAddrs>(addr: A, coord: Config, cfg: ServeConfig) -> io::Result<Server> {
        let listener = TcpListener::bind(addr)?;
        listener.set_nonblocking(true)?;
        let local_addr = listener.local_addr()?;

        let mut coordinator = Coordinator::start(coord);
        let results = coordinator
            .take_results()
            .expect("fresh coordinator owns its result stream");

        let metrics = Arc::new(ServeMetrics::default());
        let router = ResultRouter::new(metrics.clone());
        let shared = Arc::new(Shared {
            cfg,
            state: AtomicU8::new(STATE_RUNNING),
            router_done: AtomicBool::new(false),
            metrics,
            coord_metrics: coordinator.metrics_handle(),
            ingest: coordinator.ingest(),
            router: router.clone(),
            conns: Mutex::new(HashMap::new()),
        });

        let router_thread = {
            let shared = shared.clone();
            thread::spawn(move || {
                for r in results.iter() {
                    router.route(r);
                }
                shared.router_done.store(true, Ordering::Release);
            })
        };

        let conn_threads = Arc::new(Mutex::new(Vec::new()));
        let accept_thread = {
            let shared = shared.clone();
            let conn_threads = conn_threads.clone();
            thread::spawn(move || accept_loop(&listener, &shared, &conn_threads))
        };

        Ok(Server {
            shared,
            local_addr,
            coordinator: Some(coordinator),
            accept_thread: Some(accept_thread),
            router_thread: Some(router_thread),
            conn_threads,
        })
    }

    /// The bound address (resolved port when bound to port 0).
    pub fn local_addr(&self) -> SocketAddr {
        self.local_addr
    }

    /// Front-end counters (live).
    pub fn metrics(&self) -> Arc<ServeMetrics> {
        self.shared.metrics.clone()
    }

    /// The coordinator's job metrics registry (live).
    pub fn coordinator_metrics(&self) -> Arc<Metrics> {
        self.shared.coord_metrics.clone()
    }

    /// Graceful drain: stop accepting, answer new submits with
    /// `Draining`, wait for in-flight jobs under
    /// [`ServeConfig::drain_timeout`], then force-close whatever
    /// remains. See [`DrainOutcome`] for the two endings.
    pub fn shutdown(mut self) -> ServeReport {
        let shared = self.shared.clone();
        shared.state.store(STATE_DRAINING, Ordering::Release);
        let t0 = Instant::now();

        // The accept loop exits within one poll tick (and drops the
        // listener, freeing the port).
        if let Some(h) = self.accept_thread.take() {
            let _ = h.join();
        }

        // Flush in-flight jobs under the deadline. Connection threads
        // keep writing results back while we wait.
        let mut drained = true;
        while shared.metrics.in_flight.load(Ordering::Acquire) != 0 {
            if t0.elapsed() >= shared.cfg.drain_timeout {
                drained = false;
                break;
            }
            thread::sleep(shared.cfg.result_poll.min(ACCEPT_POLL));
        }

        let coordinator = self.coordinator.take().expect("shutdown consumes the server once");
        let aborted;
        if drained {
            // Nothing in flight: closing ingestion and joining the
            // coordinator threads is prompt by construction.
            let _ = coordinator.finish();
            // finish() dropped the result senders; the router sees EOF.
            if let Some(h) = self.router_thread.take() {
                let _ = h.join();
            }
            // Evict connections that are idle-parked in a read: a
            // best-effort Draining notice, then a socket shutdown wakes
            // them with EOF (no result writes are pending — in-flight
            // is zero).
            let conns: Vec<TcpStream> =
                shared.conns.lock().unwrap().drain().map(|(_, s)| s).collect();
            aborted = conns.len() as u64;
            for s in &conns {
                let _ = CtrlFrame::Draining.write_to(&mut &*s);
                let _ = s.shutdown(Shutdown::Both);
            }
            for h in self.conn_threads.lock().unwrap().drain(..) {
                let _ = h.join();
            }
        } else {
            // Hard abort: running jobs cannot be killed (Rust threads),
            // so detach them. Waiters observe ABORTED within one poll
            // tick and abandon their ids; socket shutdown wakes any
            // blocked reads.
            shared.state.store(STATE_ABORTED, Ordering::Release);
            let conns: Vec<TcpStream> =
                shared.conns.lock().unwrap().drain().map(|(_, s)| s).collect();
            aborted = conns.len() as u64;
            for s in &conns {
                let _ = s.shutdown(Shutdown::Both);
            }
            for h in self.conn_threads.lock().unwrap().drain(..) {
                let _ = h.join();
            }
            // Drop, not finish(): Drop only closes ingestion, so this
            // never blocks on the detached jobs; the router thread
            // (also detached) exits once the last worker does.
            drop(coordinator);
            drop(self.router_thread.take());
        }

        ServeReport {
            outcome: if drained { DrainOutcome::Completed } else { DrainOutcome::TimedOut },
            elapsed: t0.elapsed(),
            aborted_connections: aborted,
            metrics: shared.metrics.clone(),
            coordinator: shared.coord_metrics.clone(),
        }
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        // `shutdown` leaves `coordinator` empty; a raw drop hard-aborts.
        if self.coordinator.is_some() {
            self.shared.state.store(STATE_ABORTED, Ordering::Release);
            for (_, s) in self.shared.conns.lock().unwrap().drain() {
                let _ = s.shutdown(Shutdown::Both);
            }
            if let Some(h) = self.accept_thread.take() {
                let _ = h.join();
            }
        }
    }
}

// ---------------------------------------------------------------------------
// Accept loop + per-connection protocol
// ---------------------------------------------------------------------------

fn accept_loop(listener: &TcpListener, shared: &Arc<Shared>, threads: &Arc<Mutex<Vec<JoinHandle<()>>>>) {
    let mut next_conn = 0u64;
    loop {
        if shared.state() != STATE_RUNNING {
            return;
        }
        match listener.accept() {
            Ok((stream, _peer)) => {
                // The accepted socket may inherit the listener's
                // nonblocking flag; the protocol threads expect
                // timeout-based blocking reads.
                let _ = stream.set_nonblocking(false);
                let _ = stream.set_nodelay(true);
                let _ = stream.set_write_timeout(Some(shared.cfg.io_timeout));

                let m = &shared.metrics;
                if m.active.load(Ordering::Acquire) >= shared.cfg.max_connections as u64 {
                    m.shed.fetch_add(1, Ordering::Relaxed);
                    let mut s = &stream;
                    let _ = CtrlFrame::Shed { retry_after_ms: ms(shared.cfg.shed_retry) }
                        .write_to(&mut s);
                    let _ = stream.shutdown(Shutdown::Both);
                    continue;
                }
                m.accepted.fetch_add(1, Ordering::Relaxed);
                m.active.fetch_add(1, Ordering::AcqRel);

                let id = next_conn;
                next_conn += 1;
                if let Ok(clone) = stream.try_clone() {
                    shared.conns.lock().unwrap().insert(id, clone);
                }
                let sh = shared.clone();
                let handle = thread::spawn(move || {
                    serve_conn(&stream, &sh);
                    sh.conns.lock().unwrap().remove(&id);
                    sh.metrics.active.fetch_sub(1, Ordering::AcqRel);
                });
                let mut ts = threads.lock().unwrap();
                ts.retain(|h| !h.is_finished());
                ts.push(handle);
            }
            Err(e) if e.kind() == io::ErrorKind::WouldBlock => thread::sleep(ACCEPT_POLL),
            Err(_) => thread::sleep(ACCEPT_POLL),
        }
    }
}

/// Serve one connection until it ends: a loop of (read frame under the
/// deadline clock) → (handle submit / classify failure).
fn serve_conn(stream: &TcpStream, sh: &Shared) {
    let t0 = Instant::now();
    let mut clock = FrameClock::new(sh.cfg.idle_timeout, sh.cfg.frame_timeout);
    loop {
        let frame = {
            let mut dr = DeadlineReader { stream, clock: &mut clock, t0 };
            CtrlFrame::read_from(&mut dr)
        };
        match frame {
            Ok(CtrlFrame::Submit { client, layout, backend, n, steps, seed, threads }) => {
                clock.frame_done(t0.elapsed());
                let keep = handle_submit(
                    stream, sh, client, layout, backend, n, steps, seed, threads,
                );
                if !keep {
                    return;
                }
            }
            Ok(_) => {
                // Reply kinds are server → client only; a client
                // sending one is framed garbage.
                sh.metrics.malformed.fetch_add(1, Ordering::Relaxed);
                let _ = CtrlFrame::Corrupt { expected: 0, got: 0 }.write_to(&mut &*stream);
                let _ = stream.shutdown(Shutdown::Both);
                return;
            }
            Err(e) => {
                handle_read_failure(&e, &clock, stream, sh);
                return;
            }
        }
    }
}

fn handle_read_failure(e: &io::Error, clock: &FrameClock, stream: &TcpStream, sh: &Shared) {
    let m = &sh.metrics;
    match classify_read_failure(e, clock.mid_frame()) {
        ReadFailure::TimedOut(phase) => {
            match phase {
                TimeoutPhase::Idle => m.idle_evicted.fetch_add(1, Ordering::Relaxed),
                TimeoutPhase::MidFrame => m.slow_frames.fetch_add(1, Ordering::Relaxed),
            };
            let _ = CtrlFrame::TimedOut { phase }.write_to(&mut &*stream);
        }
        ReadFailure::Corrupt { expected, got } => {
            m.corrupt_frames.fetch_add(1, Ordering::Relaxed);
            sh.coord_metrics.on_corrupt_frame();
            let _ = CtrlFrame::Corrupt { expected, got }.write_to(&mut &*stream);
        }
        ReadFailure::Malformed => {
            m.malformed.fetch_add(1, Ordering::Relaxed);
            sh.coord_metrics.on_corrupt_frame();
            let _ = CtrlFrame::Corrupt { expected: 0, got: 0 }.write_to(&mut &*stream);
        }
        ReadFailure::Disconnected | ReadFailure::Io => {
            m.disconnects.fetch_add(1, Ordering::Relaxed);
        }
    }
    let _ = stream.shutdown(Shutdown::Both);
}

/// Handle one submit end-to-end; returns whether the connection stays
/// open.
#[allow(clippy::too_many_arguments)]
fn handle_submit(
    stream: &TcpStream,
    sh: &Shared,
    client: u64,
    layout: u8,
    backend: u8,
    n: u64,
    steps: u64,
    seed: u64,
    threads: u32,
) -> bool {
    let m = &sh.metrics;
    m.submits.fetch_add(1, Ordering::Relaxed);

    if sh.state() != STATE_RUNNING {
        m.draining_replies.fetch_add(1, Ordering::Relaxed);
        let _ = CtrlFrame::Draining.write_to(&mut &*stream);
        let _ = stream.shutdown(Shutdown::Both);
        return false;
    }

    let Some(spec) = decode_submit(&sh.cfg, layout, backend, n, steps, seed, threads) else {
        m.malformed.fetch_add(1, Ordering::Relaxed);
        let _ = CtrlFrame::Corrupt { expected: 0, got: 0 }.write_to(&mut &*stream);
        let _ = stream.shutdown(Shutdown::Both);
        return false;
    };

    // In-flight goes up *before* admission so the drain loop can never
    // observe "queue empty, nothing in flight" between the two.
    m.in_flight.fetch_add(1, Ordering::AcqRel);
    let id = match sh.ingest.submit_from(client, spec, Admission::Reject) {
        Ok(id) => id,
        Err(e) => {
            m.in_flight.fetch_sub(1, Ordering::AcqRel);
            return match e {
                SubmitError::QueueFull { retry_after } => {
                    m.rejects_queue_full.fetch_add(1, Ordering::Relaxed);
                    CtrlFrame::QueueFull { retry_after_ms: ms(retry_after) }
                        .write_to(&mut &*stream)
                        .is_ok()
                }
                SubmitError::QuotaExceeded { client } => {
                    m.rejects_quota.fetch_add(1, Ordering::Relaxed);
                    CtrlFrame::QuotaExceeded { client }.write_to(&mut &*stream).is_ok()
                }
                // Unreachable under Admission::Reject; answer like a
                // full queue with the floor hint.
                SubmitError::DeadlineExceeded => {
                    m.rejects_queue_full.fetch_add(1, Ordering::Relaxed);
                    CtrlFrame::QueueFull { retry_after_ms: 1 }.write_to(&mut &*stream).is_ok()
                }
                SubmitError::Closed => {
                    m.draining_replies.fetch_add(1, Ordering::Relaxed);
                    let _ = CtrlFrame::Draining.write_to(&mut &*stream);
                    let _ = stream.shutdown(Shutdown::Both);
                    false
                }
            };
        }
    };

    match wait_result(sh, id) {
        Some(r) => {
            // Write first, then count the job as flushed: the drain
            // loop must not abort this socket under us.
            let ok = result_frame(&r).write_to(&mut &*stream).is_ok();
            if ok {
                m.results_sent.fetch_add(1, Ordering::Relaxed);
            } else {
                m.disconnects.fetch_add(1, Ordering::Relaxed);
                let _ = stream.shutdown(Shutdown::Both);
            }
            m.in_flight.fetch_sub(1, Ordering::AcqRel);
            ok
        }
        None => {
            // Aborted drain (or the router died): the job is detached.
            m.in_flight.fetch_sub(1, Ordering::AcqRel);
            m.draining_replies.fetch_add(1, Ordering::Relaxed);
            let _ = CtrlFrame::Draining.write_to(&mut &*stream);
            let _ = stream.shutdown(Shutdown::Both);
            false
        }
    }
}

/// Block until job `id`'s result arrives, polling so an aborted drain
/// is noticed within one tick. `None` means the job was detached.
fn wait_result(sh: &Shared, id: u64) -> Option<JobResult> {
    let rx = match sh.router.claim(id) {
        Claim::Ready(r) => return Some(*r),
        Claim::Wait(rx) => rx,
    };
    loop {
        match rx.recv_timeout(sh.cfg.result_poll) {
            Ok(r) => return Some(r),
            Err(mpsc::RecvTimeoutError::Timeout) => {
                if sh.state() == STATE_ABORTED {
                    sh.router.abandon(id);
                    return None;
                }
                if sh.router_done.load(Ordering::Acquire) {
                    // Router exited; one last non-blocking look in case
                    // it routed to us on its way out.
                    return match rx.try_recv() {
                        Ok(r) => Some(r),
                        Err(_) => {
                            sh.router.abandon(id);
                            None
                        }
                    };
                }
            }
            Err(mpsc::RecvTimeoutError::Disconnected) => {
                sh.router.abandon(id);
                return None;
            }
        }
    }
}

// ---------------------------------------------------------------------------
// Wire ↔ coordinator type mapping
// ---------------------------------------------------------------------------

/// Wire code for a [`Layout`] (the `Submit` frame's `layout` byte).
pub fn layout_code(l: Layout) -> u8 {
    match l {
        Layout::Aos => 0,
        Layout::SoaMb => 1,
        Layout::Aosoa => 2,
        Layout::Bf16 => 3,
    }
}

/// Decode a `Submit` layout byte.
pub fn layout_from_code(c: u8) -> Option<Layout> {
    match c {
        0 => Some(Layout::Aos),
        1 => Some(Layout::SoaMb),
        2 => Some(Layout::Aosoa),
        3 => Some(Layout::Bf16),
        _ => None,
    }
}

/// Wire code for a [`Backend`] (the `Submit` frame's `backend` byte).
pub fn backend_code(b: Backend) -> u8 {
    match b {
        Backend::NativeScalar => 0,
        Backend::NativeSimd => 1,
        Backend::Pjrt => 2,
    }
}

/// Decode a `Submit` backend byte.
pub fn backend_from_code(c: u8) -> Option<Backend> {
    match c {
        0 => Some(Backend::NativeScalar),
        1 => Some(Backend::NativeSimd),
        2 => Some(Backend::Pjrt),
        _ => None,
    }
}

/// Duration → whole milliseconds for a wire hint, floored at 1 so a
/// sub-millisecond hint never round-trips to "retry immediately".
fn ms(d: Duration) -> u64 {
    u64::try_from(d.as_millis()).unwrap_or(u64::MAX).max(1)
}

/// Validate and map a `Submit` frame's fields onto a [`JobSpec`]
/// (id 0 — admission assigns the real one). `None` = out of policy.
fn decode_submit(
    cfg: &ServeConfig,
    layout: u8,
    backend: u8,
    n: u64,
    steps: u64,
    seed: u64,
    threads: u32,
) -> Option<JobSpec> {
    if n == 0 || n > cfg.max_job_records || steps > cfg.max_job_steps {
        return None;
    }
    Some(JobSpec {
        id: 0,
        layout: layout_from_code(layout)?,
        backend: backend_from_code(backend)?,
        n: n as usize,
        steps: steps as usize,
        seed,
        threads: threads as usize,
    })
}

fn result_frame(r: &JobResult) -> CtrlFrame {
    CtrlFrame::Result {
        id: r.id,
        attempts: r.attempts,
        threads: u32::try_from(r.threads).unwrap_or(u32::MAX),
        exec_ns: u64::try_from(r.exec_time.as_nanos()).unwrap_or(u64::MAX),
        queue_ns: u64::try_from(r.queue_time.as_nanos()).unwrap_or(u64::MAX),
        energy_drift: r.energy_drift,
        steps_per_sec: r.steps_per_sec,
        error: r.error.clone().unwrap_or_default(),
    }
}

// ---------------------------------------------------------------------------
// Client
// ---------------------------------------------------------------------------

/// Client-side knobs for [`Client`].
#[derive(Clone, Debug)]
pub struct ClientConfig {
    /// Identity sent with every submit (per-client quota accounting).
    pub client_id: u64,
    /// Attempt budget and transport-failure backoff shape. Server
    /// `retry_after` hints override the backoff sleep when present.
    pub retry: RetryPolicy,
    /// Connect/write deadline.
    pub io_timeout: Duration,
    /// Read deadline for a reply — generous, because the server holds
    /// the connection while the job runs.
    pub result_timeout: Duration,
    /// Chaos hook: wrap each connection's stream in a
    /// [`FaultyStream`] under this plan (site = hash of client id and
    /// a per-connection counter, so reconnects draw fresh schedules).
    pub faults: Option<FaultPlan>,
}

impl Default for ClientConfig {
    fn default() -> Self {
        ClientConfig {
            client_id: 0,
            retry: RetryPolicy::retries(4),
            io_timeout: Duration::from_secs(2),
            result_timeout: Duration::from_secs(60),
            faults: None,
        }
    }
}

/// A job outcome as seen across the wire.
#[derive(Clone, Debug)]
pub struct RemoteResult {
    /// Server-assigned job id.
    pub id: u64,
    /// Execution attempts the coordinator used.
    pub attempts: u32,
    /// Threads the job ran with.
    pub threads: u32,
    /// Execution wall-clock.
    pub exec_time: Duration,
    /// Queue wait.
    pub queue_time: Duration,
    /// Relative energy drift (bit-exact across the wire).
    pub energy_drift: f64,
    /// Steps per second achieved.
    pub steps_per_sec: f64,
    /// The job's typed failure after retries, if any.
    pub error: Option<String>,
}

/// Why a [`Client::submit`] gave up.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum ClientError {
    /// The server is draining/closed; resubmitting there is pointless.
    Draining,
    /// This client is at its per-client queue quota.
    QuotaExceeded {
        /// The client id the server reported.
        client: u64,
    },
    /// The server answered outside the protocol.
    Protocol(String),
    /// The attempt budget ran out on retryable failures.
    Exhausted {
        /// Attempts spent.
        attempts: u32,
        /// The last failure, human-readable.
        last: String,
    },
}

impl std::fmt::Display for ClientError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ClientError::Draining => write!(f, "server is draining"),
            ClientError::QuotaExceeded { client } => {
                write!(f, "client {client} exceeded its queue quota")
            }
            ClientError::Protocol(what) => write!(f, "protocol violation: {what}"),
            ClientError::Exhausted { attempts, last } => {
                write!(f, "gave up after {attempts} attempts (last: {last})")
            }
        }
    }
}

impl std::error::Error for ClientError {}

/// What one wire exchange concluded.
enum Step {
    Done(Box<RemoteResult>),
    Fatal(ClientError),
    /// Retry after the server's hint.
    RetryAfter(Duration, String),
    /// Retry after policy backoff.
    Backoff(String),
}

/// Reconnecting submit client for a [`Server`]. One outstanding job per
/// client (the protocol is strictly request/reply per connection); run
/// several clients for concurrency.
pub struct Client {
    addr: SocketAddr,
    cfg: ClientConfig,
    conn: Option<FaultyStream<TcpStream>>,
    conns_opened: u64,
    submitted: u64,
}

impl Client {
    /// A client for the server at `addr`. Connects lazily on the first
    /// submit (and re-connects after any transport failure).
    pub fn new<A: ToSocketAddrs>(addr: A, cfg: ClientConfig) -> io::Result<Client> {
        let addr = addr
            .to_socket_addrs()?
            .next()
            .ok_or_else(|| io::Error::new(io::ErrorKind::InvalidInput, "address resolved empty"))?;
        Ok(Client { addr, cfg, conn: None, conns_opened: 0, submitted: 0 })
    }

    /// Connections this client has opened (reconnects make it > 1).
    pub fn conns_opened(&self) -> u64 {
        self.conns_opened
    }

    /// Submit one job and wait for its result, retrying through
    /// transport failures, `QueueFull` (sleeping the server's
    /// `retry_after_ms` hint) and `Shed` (reconnecting after the hint)
    /// up to the [`RetryPolicy`] attempt budget. `QuotaExceeded` and
    /// `Draining` are terminal.
    pub fn submit(&mut self, spec: &JobSpec) -> Result<RemoteResult, ClientError> {
        self.submitted += 1;
        let frame = submit_frame(self.cfg.client_id, spec);
        let max = self.cfg.retry.max_attempts.max(1);
        let mut last = String::from("no attempt made");
        for attempt in 1..=max {
            match self.try_once(&frame) {
                Step::Done(r) => return Ok(*r),
                Step::Fatal(e) => return Err(e),
                Step::RetryAfter(hint, why) => {
                    last = why;
                    if attempt < max {
                        thread::sleep(hint);
                    }
                }
                Step::Backoff(why) => {
                    last = why;
                    if attempt < max {
                        thread::sleep(self.cfg.retry.backoff(attempt, self.submitted));
                    }
                }
            }
        }
        Err(ClientError::Exhausted { attempts: max, last })
    }

    fn disconnect(&mut self) {
        self.conn = None;
    }

    fn ensure_conn(&mut self) -> io::Result<&mut FaultyStream<TcpStream>> {
        if self.conn.is_none() {
            let stream = TcpStream::connect(self.addr)?;
            stream.set_nodelay(true)?;
            stream.set_write_timeout(Some(self.cfg.io_timeout))?;
            stream.set_read_timeout(Some(self.cfg.result_timeout))?;
            self.conns_opened += 1;
            let site = hash2(self.cfg.client_id, self.conns_opened);
            let wrapped = match &self.cfg.faults {
                Some(plan) => plan.stream(site, stream),
                // Default config injects nothing: pure passthrough.
                None => FaultyStream::new(stream, 0, FaultConfig::default()),
            };
            self.conn = Some(wrapped);
        }
        Ok(self.conn.as_mut().expect("connection just ensured"))
    }

    fn try_once(&mut self, frame: &CtrlFrame) -> Step {
        let wrote = match self.ensure_conn() {
            Ok(s) => frame.write_to(s),
            Err(e) => return Step::Backoff(format!("connect: {e}")),
        };
        if let Err(e) = wrote {
            self.disconnect();
            return Step::Backoff(format!("send: {e}"));
        }
        let reply = {
            let s = self.conn.as_mut().expect("connection present after write");
            CtrlFrame::read_from(s)
        };
        match reply {
            Ok(CtrlFrame::Result {
                id,
                attempts,
                threads,
                exec_ns,
                queue_ns,
                energy_drift,
                steps_per_sec,
                error,
            }) => Step::Done(Box::new(RemoteResult {
                id,
                attempts,
                threads,
                exec_time: Duration::from_nanos(exec_ns),
                queue_time: Duration::from_nanos(queue_ns),
                energy_drift,
                steps_per_sec,
                error: if error.is_empty() { None } else { Some(error) },
            })),
            // The connection stays usable after a queue-full reject.
            Ok(CtrlFrame::QueueFull { retry_after_ms }) => Step::RetryAfter(
                Duration::from_millis(retry_after_ms.max(1)),
                format!("queue full, retry after {retry_after_ms} ms"),
            ),
            Ok(CtrlFrame::Shed { retry_after_ms }) => {
                self.disconnect();
                Step::RetryAfter(
                    Duration::from_millis(retry_after_ms.max(1)),
                    "connection shed at accept".into(),
                )
            }
            Ok(CtrlFrame::QuotaExceeded { client }) => {
                Step::Fatal(ClientError::QuotaExceeded { client })
            }
            Ok(CtrlFrame::Draining) => {
                self.disconnect();
                Step::Fatal(ClientError::Draining)
            }
            Ok(CtrlFrame::Corrupt { .. }) => {
                // Our frame got mangled in transit; the server closed
                // the (possibly desynchronized) stream.
                self.disconnect();
                Step::Backoff("server rejected the frame as corrupt".into())
            }
            Ok(CtrlFrame::TimedOut { phase }) => {
                self.disconnect();
                Step::Backoff(format!("server timed the connection out ({phase})"))
            }
            Ok(CtrlFrame::Submit { .. }) => {
                self.disconnect();
                Step::Fatal(ClientError::Protocol("server sent a Submit frame".into()))
            }
            Err(e) => {
                self.disconnect();
                Step::Backoff(format!("recv: {e}"))
            }
        }
    }
}

/// Encode a [`JobSpec`] as the `Submit` frame `client` sends.
pub fn submit_frame(client: u64, spec: &JobSpec) -> CtrlFrame {
    CtrlFrame::Submit {
        client,
        layout: layout_code(spec.layout),
        backend: backend_code(spec.backend),
        n: spec.n as u64,
        steps: spec.steps as u64,
        seed: spec.seed,
        threads: u32::try_from(spec.threads).unwrap_or(u32::MAX),
    }
}

// ---------------------------------------------------------------------------
// Tests (stream-free state machines only — these run under Miri; the
// socket lifecycle is integration-tested in rust/tests/serve.rs)
// ---------------------------------------------------------------------------

#[cfg(test)]
mod tests {
    use super::*;

    const MS: Duration = Duration::from_millis(1);

    #[test]
    fn frame_clock_idle_budget_counts_from_last_frame() {
        let mut c = FrameClock::new(10 * MS, 4 * MS);
        let b = c.budget(Duration::ZERO);
        assert_eq!(b.phase, TimeoutPhase::Idle);
        assert_eq!(b.remaining, 10 * MS);

        // Finish a frame at t=6ms: idle restarts there.
        c.byte_read(5 * MS);
        c.frame_done(6 * MS);
        let b = c.budget(8 * MS);
        assert_eq!(b.phase, TimeoutPhase::Idle);
        assert_eq!(b.remaining, 8 * MS);

        // Budget saturates at zero past the deadline.
        let b = c.budget(20 * MS);
        assert_eq!(b.remaining, Duration::ZERO);
        assert_eq!(b.phase, TimeoutPhase::Idle);
    }

    #[test]
    fn frame_clock_mid_frame_budget_is_not_extended_by_progress() {
        let mut c = FrameClock::new(10 * MS, 4 * MS);
        c.byte_read(2 * MS); // frame opens at t=2ms → deadline t=6ms
        assert!(c.mid_frame());

        // Trickling bytes do not move the deadline (slow-loris).
        c.byte_read(3 * MS);
        c.byte_read(5 * MS);
        let b = c.budget(5 * MS);
        assert_eq!(b.phase, TimeoutPhase::MidFrame);
        assert_eq!(b.remaining, MS);

        let b = c.budget(7 * MS);
        assert_eq!(b.remaining, Duration::ZERO);
        assert_eq!(b.phase, TimeoutPhase::MidFrame);

        // Completing the frame closes it and restores the idle phase.
        c.frame_done(5 * MS);
        assert!(!c.mid_frame());
        assert_eq!(c.budget(5 * MS).phase, TimeoutPhase::Idle);
    }

    fn result(id: u64) -> JobResult {
        JobResult {
            id,
            worker: 0,
            batch_id: 0,
            exec_time: Duration::from_millis(3),
            queue_time: Duration::from_millis(1),
            energy_drift: 1e-9,
            steps_per_sec: 1000.0,
            threads: 1,
            attempts: 1,
            error: None,
        }
    }

    #[test]
    fn router_delivers_to_registered_waiter() {
        let m = Arc::new(ServeMetrics::default());
        let router = ResultRouter::new(m.clone());
        let rx = match router.claim(7) {
            Claim::Wait(rx) => rx,
            Claim::Ready(_) => panic!("no result routed yet"),
        };
        router.route(result(7));
        assert_eq!(rx.try_recv().expect("routed").id, 7);
        assert_eq!(m.orphaned(), 0);
    }

    #[test]
    fn router_hands_over_early_results() {
        let m = Arc::new(ServeMetrics::default());
        let router = ResultRouter::new(m.clone());
        router.route(result(3)); // result beats the waiter
        match router.claim(3) {
            Claim::Ready(r) => assert_eq!(r.id, 3),
            Claim::Wait(_) => panic!("result should be waiting"),
        }
        assert_eq!(m.orphaned(), 0);
    }

    #[test]
    fn router_counts_abandoned_results_as_orphaned() {
        let m = Arc::new(ServeMetrics::default());
        let router = ResultRouter::new(m.clone());

        // Abandon before the result lands.
        let _rx = match router.claim(1) {
            Claim::Wait(rx) => rx,
            Claim::Ready(_) => panic!("nothing routed"),
        };
        router.abandon(1);
        router.route(result(1));
        assert_eq!(m.orphaned(), 1);

        // Abandon after the result landed unclaimed.
        router.route(result(2));
        router.abandon(2);
        assert_eq!(m.orphaned(), 2);

        // A dropped receiver at delivery time orphans too.
        match router.claim(4) {
            Claim::Wait(rx) => drop(rx),
            Claim::Ready(_) => panic!("nothing routed"),
        }
        router.route(result(4));
        assert_eq!(m.orphaned(), 3);
    }

    #[test]
    fn layout_and_backend_codes_round_trip() {
        for l in [Layout::Aos, Layout::SoaMb, Layout::Aosoa, Layout::Bf16] {
            assert_eq!(layout_from_code(layout_code(l)), Some(l));
        }
        for b in [Backend::NativeScalar, Backend::NativeSimd, Backend::Pjrt] {
            assert_eq!(backend_from_code(backend_code(b)), Some(b));
        }
        assert_eq!(layout_from_code(200), None);
        assert_eq!(backend_from_code(200), None);
    }

    #[test]
    fn decode_submit_enforces_policy_caps() {
        let cfg = ServeConfig::default();
        assert!(decode_submit(&cfg, 0, 0, 64, 10, 1, 0).is_some());
        assert!(decode_submit(&cfg, 0, 0, 0, 10, 1, 0).is_none(), "n = 0");
        assert!(
            decode_submit(&cfg, 0, 0, cfg.max_job_records, 10, 1, 0).is_some(),
            "n at cap admits"
        );
        assert!(
            decode_submit(&cfg, 0, 0, cfg.max_job_records + 1, 10, 1, 0).is_none(),
            "n over cap rejects"
        );
        assert!(decode_submit(&cfg, 0, 0, 64, cfg.max_job_steps + 1, 1, 0).is_none());
        assert!(decode_submit(&cfg, 9, 0, 64, 10, 1, 0).is_none(), "bad layout code");
        assert!(decode_submit(&cfg, 0, 9, 64, 10, 1, 0).is_none(), "bad backend code");
    }

    #[test]
    fn ms_floors_at_one_and_saturates() {
        assert_eq!(ms(Duration::from_micros(10)), 1);
        assert_eq!(ms(Duration::from_millis(250)), 250);
        assert_eq!(ms(Duration::MAX), u64::MAX);
    }

    #[test]
    fn classify_read_failure_maps_the_taxonomy() {
        let timed = deadline_expired(TimeoutPhase::MidFrame);
        assert_eq!(
            classify_read_failure(&timed, false),
            ReadFailure::TimedOut(TimeoutPhase::MidFrame),
            "typed payload wins over the mid_frame flag"
        );

        let raw_timeout = io::Error::new(io::ErrorKind::TimedOut, "os timeout");
        assert_eq!(
            classify_read_failure(&raw_timeout, true),
            ReadFailure::TimedOut(TimeoutPhase::MidFrame)
        );
        assert_eq!(
            classify_read_failure(&raw_timeout, false),
            ReadFailure::TimedOut(TimeoutPhase::Idle)
        );

        let corrupt =
            io::Error::new(io::ErrorKind::InvalidData, WireError::Corrupt { expected: 7, got: 9 });
        assert_eq!(
            classify_read_failure(&corrupt, true),
            ReadFailure::Corrupt { expected: 7, got: 9 }
        );

        let malformed = io::Error::new(io::ErrorKind::InvalidData, "bad control magic");
        assert_eq!(classify_read_failure(&malformed, true), ReadFailure::Malformed);

        let eof = io::Error::new(io::ErrorKind::UnexpectedEof, "eof");
        assert_eq!(classify_read_failure(&eof, false), ReadFailure::Disconnected);
        let reset = io::Error::new(io::ErrorKind::ConnectionReset, "reset");
        assert_eq!(classify_read_failure(&reset, true), ReadFailure::Disconnected);
        let other = io::Error::new(io::ErrorKind::PermissionDenied, "no");
        assert_eq!(classify_read_failure(&other, false), ReadFailure::Io);
    }

    #[test]
    fn drain_lines_match_the_ci_grep() {
        let done = render_drain(DrainOutcome::Completed, Duration::from_millis(12), 0);
        assert!(done.starts_with("drain: completed in "), "{done}");
        assert!(done.ends_with("(0 connections aborted)"), "{done}");
        let timed = render_drain(DrainOutcome::TimedOut, Duration::from_secs(5), 3);
        assert!(timed.starts_with("drain: timed out after "), "{timed}");
        assert!(timed.ends_with("(3 connections aborted)"), "{timed}");
    }

    #[test]
    fn metrics_render_has_the_status_lines() {
        let m = ServeMetrics::default();
        m.accepted.store(4, Ordering::Relaxed);
        m.shed.store(1, Ordering::Relaxed);
        m.idle_evicted.store(2, Ordering::Relaxed);
        m.slow_frames.store(1, Ordering::Relaxed);
        let text = m.render();
        assert!(text.contains("conns: accepted 4 · active 0 · shed 1 · timed out 3 (idle 2, mid-frame 1)"), "{text}");
        assert!(text.lines().any(|l| l.starts_with("frames: ")), "{text}");
        assert!(text.lines().any(|l| l.starts_with("jobs: ")), "{text}");
    }

    #[test]
    fn result_frame_is_lossless_for_the_fields_that_cross() {
        let mut r = result(42);
        r.attempts = 3;
        r.threads = 8;
        r.error = Some("boom".into());
        let f = result_frame(&r);
        match f {
            CtrlFrame::Result { id, attempts, threads, energy_drift, error, .. } => {
                assert_eq!(id, 42);
                assert_eq!(attempts, 3);
                assert_eq!(threads, 8);
                assert_eq!(energy_drift.to_bits(), r.energy_drift.to_bits());
                assert_eq!(error, "boom");
            }
            other => panic!("expected a Result frame, got {other:?}"),
        }
    }
}
