//! NUMA topology probe and thread placement (no extra crates).
//!
//! Memory-bound traversals lose a large fraction of their bandwidth when
//! a worker chews memory resident on a *remote* NUMA node. The paper's
//! premise — match data movement to the hardware — therefore extends to
//! thread and page placement, not just byte layout. This module supplies
//! the two primitives the worker pool ([`crate::pool`]) builds its
//! placement policy on:
//!
//! 1. **Topology probe** ([`probe`] / [`probe_dir`]): parses the Linux
//!    sysfs tree `/sys/devices/system/node` (`node<k>/cpulist` files in
//!    the kernel's list format, e.g. `0-3,8-11`). Anything unexpected —
//!    the directory missing (non-Linux, sandboxes), zero nodes, an
//!    unreadable `cpulist` — degrades to a single-node fallback covering
//!    all CPUs, so callers never need a NUMA special case.
//! 2. **Thread pinning** ([`pin_current_thread`]): restricts the calling
//!    thread to a CPU set via a hand-declared `sched_setaffinity(2)`
//!    (the offline image has no libc crate). Compiled to a no-op off
//!    Linux and under Miri (no foreign calls in the interpreter).
//!
//! The placement *policy* — which worker goes to which node, who touches
//! which pages — lives in [`crate::pool`]; the `LLAMA_NUMA` environment
//! knob ([`policy`]) selects it:
//!
//! - `LLAMA_NUMA=firsttouch` (default): pin pool workers round-robin
//!   across nodes (only when there are ≥ 2 nodes) and let
//!   [`crate::pool::first_touch`] fault each worker's shard range into
//!   node-local pages.
//! - `LLAMA_NUMA=off`: no pinning, no touch pass.

use std::path::Path;
use std::sync::OnceLock;

/// One NUMA node: its sysfs id and the CPUs it owns.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Node {
    /// Kernel node id (the `<k>` of `node<k>`; ids may have holes).
    pub id: usize,
    /// CPU ids local to this node, ascending.
    pub cpus: Vec<usize>,
}

/// The machine's NUMA topology as probed from sysfs (or the single-node
/// fallback when sysfs is unavailable).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Topology {
    /// Nodes sorted by id. Never empty: the fallback is one node 0
    /// spanning all CPUs.
    pub nodes: Vec<Node>,
}

impl Topology {
    /// Single node spanning `cpus` CPUs — the fallback when the sysfs
    /// tree is missing or empty.
    pub fn single_node(cpus: usize) -> Topology {
        Topology { nodes: vec![Node { id: 0, cpus: (0..cpus.max(1)).collect() }] }
    }

    /// Whether placement can matter at all (more than one node).
    pub fn is_multi_node(&self) -> bool {
        self.nodes.len() > 1
    }

    /// Total CPUs across all nodes.
    pub fn cpu_count(&self) -> usize {
        self.nodes.iter().map(|n| n.cpus.len()).sum()
    }

    /// The node that worker slot `slot` of a pool is assigned to
    /// (round-robin across nodes — neighbouring shards land on
    /// neighbouring nodes, matching the round-robin job tagging in
    /// [`crate::pool`]).
    pub fn node_of_slot(&self, slot: usize) -> &Node {
        &self.nodes[slot % self.nodes.len()]
    }
}

/// Probe the live system: `/sys/devices/system/node`, with the
/// single-node fallback on any failure. The result is cached for the
/// process (the tree is immutable at runtime).
pub fn probe() -> &'static Topology {
    static TOPO: OnceLock<Topology> = OnceLock::new();
    TOPO.get_or_init(|| probe_dir(Path::new("/sys/devices/system/node")))
}

/// Probe a sysfs-shaped directory tree: every `node<k>` subdirectory
/// with a parseable `cpulist` becomes a [`Node`]. Missing directory,
/// zero parseable nodes, or any I/O error yields the single-node
/// fallback (sized by `available_parallelism`). Testable against
/// fixture directories — see the unit tests.
pub fn probe_dir(dir: &Path) -> Topology {
    let fallback =
        || Topology::single_node(std::thread::available_parallelism().map_or(1, |n| n.get()));
    let Ok(entries) = std::fs::read_dir(dir) else {
        return fallback();
    };
    let mut nodes = Vec::new();
    for entry in entries.flatten() {
        let name = entry.file_name();
        let Some(id) = name.to_str().and_then(parse_node_dir_name) else {
            continue;
        };
        let Ok(list) = std::fs::read_to_string(entry.path().join("cpulist")) else {
            continue;
        };
        let cpus = parse_cpu_list(&list);
        if !cpus.is_empty() {
            nodes.push(Node { id, cpus });
        }
    }
    if nodes.is_empty() {
        return fallback();
    }
    nodes.sort_by_key(|n| n.id);
    Topology { nodes }
}

/// `"node12"` → `Some(12)`; anything else (including `"node"` or
/// `"node1a"`) → `None`.
fn parse_node_dir_name(name: &str) -> Option<usize> {
    let digits = name.strip_prefix("node")?;
    if digits.is_empty() || !digits.bytes().all(|b| b.is_ascii_digit()) {
        return None;
    }
    digits.parse().ok()
}

/// Parse the kernel's CPU list format: comma-separated single ids and
/// inclusive ranges, e.g. `"0-3,8,10-11"` → `[0, 1, 2, 3, 8, 10, 11]`.
/// Malformed pieces are skipped; the result is sorted and deduplicated.
///
/// ```
/// assert_eq!(llama::numa::parse_cpu_list("0-2,5"), vec![0, 1, 2, 5]);
/// assert!(llama::numa::parse_cpu_list("").is_empty());
/// ```
pub fn parse_cpu_list(list: &str) -> Vec<usize> {
    let mut cpus = Vec::new();
    for piece in list.trim().split(',') {
        let piece = piece.trim();
        if piece.is_empty() {
            continue;
        }
        match piece.split_once('-') {
            Some((lo, hi)) => {
                if let (Ok(lo), Ok(hi)) = (lo.trim().parse::<usize>(), hi.trim().parse::<usize>())
                {
                    if lo <= hi && hi - lo < 4096 {
                        cpus.extend(lo..=hi);
                    }
                }
            }
            None => {
                if let Ok(id) = piece.parse::<usize>() {
                    cpus.push(id);
                }
            }
        }
    }
    cpus.sort_unstable();
    cpus.dedup();
    cpus
}

/// The NUMA placement policy, from `LLAMA_NUMA` (cached per process).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum NumaPolicy {
    /// No pinning, no touch pass.
    Off,
    /// Pin pool workers round-robin across nodes (when there are ≥ 2)
    /// and first-touch shard ranges from their owning workers.
    FirstTouch,
}

/// `LLAMA_NUMA=off|firsttouch` (default `firsttouch` — it is a no-op on
/// single-node machines). Malformed values log once and fall back to
/// the default, mirroring `shard::thread_count`'s env handling.
pub fn policy() -> NumaPolicy {
    static POLICY: OnceLock<NumaPolicy> = OnceLock::new();
    *POLICY.get_or_init(|| {
        let raw = std::env::var("LLAMA_NUMA").ok();
        match parse_policy(raw.as_deref()) {
            Some(p) => p,
            None => {
                eprintln!(
                    "llama: ignoring malformed LLAMA_NUMA={:?} (want off|firsttouch); \
                     defaulting to firsttouch",
                    raw.unwrap_or_default()
                );
                NumaPolicy::FirstTouch
            }
        }
    })
}

/// Parse an `LLAMA_NUMA` value (`None` result = malformed; unset is the
/// default). Kept separate from the environment so it is testable
/// without process-global `setenv`.
fn parse_policy(s: Option<&str>) -> Option<NumaPolicy> {
    match s.map(str::trim) {
        None | Some("") => Some(NumaPolicy::FirstTouch),
        Some("firsttouch") | Some("first-touch") | Some("on") => Some(NumaPolicy::FirstTouch),
        Some("off") | Some("0") => Some(NumaPolicy::Off),
        Some(_) => None,
    }
}

// ---------------------------------------------------------------------------
// Thread pinning: hand-declared sched_setaffinity (no libc crate)
// ---------------------------------------------------------------------------

/// Pin the calling thread to `cpus`. Returns `true` when the kernel
/// accepted the mask; `false` on failure, with an empty/oversized set,
/// off Linux, or under Miri (foreign calls are unsupported there) — the
/// caller treats a refusal as "run unpinned", never as an error.
pub fn pin_current_thread(cpus: &[usize]) -> bool {
    if cpus.is_empty() {
        return false;
    }
    pin_impl(cpus)
}

#[cfg(all(target_os = "linux", not(miri)))]
fn pin_impl(cpus: &[usize]) -> bool {
    /// Mirrors glibc's `cpu_set_t`: a 1024-bit mask.
    #[repr(C)]
    struct CpuSet {
        bits: [u64; 16],
    }
    extern "C" {
        /// `sched_setaffinity(2)`; `pid == 0` targets the calling thread.
        fn sched_setaffinity(pid: i32, cpusetsize: usize, mask: *const CpuSet) -> i32;
    }
    let mut set = CpuSet { bits: [0; 16] };
    let mut any = false;
    for &c in cpus {
        if c < 1024 {
            set.bits[c / 64] |= 1u64 << (c % 64);
            any = true;
        }
    }
    if !any {
        return false;
    }
    // SAFETY: `set` is a valid, fully-initialized mask of the size we
    // pass; the syscall does not retain the pointer past the call.
    unsafe { sched_setaffinity(0, std::mem::size_of::<CpuSet>(), &set) == 0 }
}

#[cfg(not(all(target_os = "linux", not(miri))))]
fn pin_impl(_cpus: &[usize]) -> bool {
    false
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cpu_list_parsing() {
        assert_eq!(parse_cpu_list("0-3,8,10-11"), vec![0, 1, 2, 3, 8, 10, 11]);
        assert_eq!(parse_cpu_list("0\n"), vec![0]);
        assert_eq!(parse_cpu_list(" 4 - 6 , 2 "), vec![2, 4, 5, 6]);
        assert_eq!(parse_cpu_list("7-5"), Vec::<usize>::new()); // inverted range
        assert_eq!(parse_cpu_list("1,1,1"), vec![1]); // deduped
        assert_eq!(parse_cpu_list("x,2,y-3"), vec![2]); // malformed pieces skipped
        assert!(parse_cpu_list("").is_empty());
    }

    #[test]
    fn node_dir_name_parsing() {
        assert_eq!(parse_node_dir_name("node0"), Some(0));
        assert_eq!(parse_node_dir_name("node17"), Some(17));
        assert_eq!(parse_node_dir_name("node"), None);
        assert_eq!(parse_node_dir_name("node1a"), None);
        assert_eq!(parse_node_dir_name("cpu0"), None);
        assert_eq!(parse_node_dir_name("has_cpu"), None);
    }

    #[test]
    fn policy_parsing() {
        assert_eq!(parse_policy(None), Some(NumaPolicy::FirstTouch));
        assert_eq!(parse_policy(Some("")), Some(NumaPolicy::FirstTouch));
        assert_eq!(parse_policy(Some("firsttouch")), Some(NumaPolicy::FirstTouch));
        assert_eq!(parse_policy(Some("off")), Some(NumaPolicy::Off));
        assert_eq!(parse_policy(Some("banana")), None);
    }

    /// Build a sysfs-shaped fixture tree: `dir/node<k>/cpulist`.
    fn fixture(name: &str, nodes: &[(usize, &str)]) -> std::path::PathBuf {
        let dir = std::env::temp_dir().join(format!("llama-numa-{}-{}", std::process::id(), name));
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        for (id, cpulist) in nodes {
            let nd = dir.join(format!("node{id}"));
            std::fs::create_dir_all(&nd).unwrap();
            std::fs::write(nd.join("cpulist"), cpulist).unwrap();
        }
        dir
    }

    #[test]
    fn probe_zero_nodes_falls_back_to_single_node() {
        let dir = fixture("zero", &[]);
        let topo = probe_dir(&dir);
        assert_eq!(topo.nodes.len(), 1);
        assert_eq!(topo.nodes[0].id, 0);
        assert!(!topo.is_multi_node());
        assert!(topo.cpu_count() >= 1);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn probe_missing_dir_falls_back_to_single_node() {
        let dir = std::env::temp_dir().join("llama-numa-definitely-missing");
        let topo = probe_dir(&dir);
        assert_eq!(topo.nodes.len(), 1);
        assert!(topo.cpu_count() >= 1);
    }

    #[test]
    fn probe_one_node() {
        let dir = fixture("one", &[(0, "0-7\n")]);
        let topo = probe_dir(&dir);
        assert_eq!(topo.nodes.len(), 1);
        assert_eq!(topo.nodes[0].cpus, (0..8).collect::<Vec<_>>());
        assert!(!topo.is_multi_node());
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn probe_two_nodes() {
        let dir = fixture("two", &[(0, "0-3\n"), (1, "4-7\n")]);
        let topo = probe_dir(&dir);
        assert_eq!(topo.nodes.len(), 2);
        assert!(topo.is_multi_node());
        assert_eq!(topo.cpu_count(), 8);
        assert_eq!(topo.nodes[0].cpus, vec![0, 1, 2, 3]);
        assert_eq!(topo.nodes[1].cpus, vec![4, 5, 6, 7]);
        // Round-robin slot assignment wraps.
        assert_eq!(topo.node_of_slot(0).id, 0);
        assert_eq!(topo.node_of_slot(1).id, 1);
        assert_eq!(topo.node_of_slot(2).id, 0);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn probe_nodes_with_id_holes_sorted_by_id() {
        // Real machines can expose e.g. node0 + node2 (offlined node 1).
        let dir = fixture("holes", &[(2, "8-15\n"), (0, "0-7\n")]);
        let topo = probe_dir(&dir);
        assert_eq!(topo.nodes.iter().map(|n| n.id).collect::<Vec<_>>(), vec![0, 2]);
        assert_eq!(topo.nodes[1].cpus, (8..16).collect::<Vec<_>>());
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn probe_skips_unparseable_nodes() {
        let dir = fixture("bad", &[(0, "0-3\n"), (1, "garbage\n")]);
        let topo = probe_dir(&dir);
        // node1's cpulist parses to nothing -> dropped; node0 survives.
        assert_eq!(topo.nodes.len(), 1);
        assert_eq!(topo.nodes[0].id, 0);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn pinning_is_safe_to_call() {
        // Outcome is platform-dependent (may be refused in sandboxes);
        // the contract is "never panics, false on refusal".
        let _ = pin_current_thread(&[0]);
        assert!(!pin_current_thread(&[]));
        assert!(!pin_current_thread(&[100_000])); // out of mask range
    }

    #[test]
    fn live_probe_is_consistent() {
        let topo = probe();
        assert!(!topo.nodes.is_empty());
        assert!(topo.cpu_count() >= 1);
        for n in &topo.nodes {
            assert!(!n.cpus.is_empty());
        }
    }
}
