//! Compression substrate for the Bytesplit evaluation (experiment E6).
//!
//! The paper motivates [`crate::mapping::bytesplit`] with compression:
//! regrouping bytes by significance colocates zero bytes and improves
//! ratios (cf. Apache Parquet's BYTE_STREAM_SPLIT). This module provides
//! the compressors the benchmark sweeps: run-length encoding (the
//! best-case proxy for "streams of zeros", always available), plus
//! DEFLATE and zstd behind the `deflate`/`zstd-codec` cargo features —
//! the offline build image carries no crates.io registry, so the real
//! `flate2`/`zstd` crates must be added by whoever enables the feature.
//! Callers sweep [`Codec::enabled`] (or check [`Codec::available`]) so
//! the default build degrades to the RLE column instead of erroring.

use anyhow::Result;

/// Available compression backends.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Codec {
    /// Byte-level run-length encoding (escape-free, worst case 2x).
    Rle,
    /// DEFLATE via flate2 (level 6); needs the `deflate` feature.
    Deflate,
    /// Zstandard (level 3); needs the `zstd-codec` feature.
    Zstd,
}

impl Codec {
    /// All codecs, for sweeps (including ones this build can't run; see
    /// [`Codec::available`] / [`Codec::enabled`]).
    pub const ALL: [Codec; 3] = [Codec::Rle, Codec::Deflate, Codec::Zstd];

    /// Whether this build can run the codec.
    pub fn available(self) -> bool {
        match self {
            Codec::Rle => true,
            Codec::Deflate => cfg!(feature = "deflate"),
            Codec::Zstd => cfg!(feature = "zstd-codec"),
        }
    }

    /// The codecs this build can run.
    pub fn enabled() -> impl Iterator<Item = Codec> {
        Codec::ALL.into_iter().filter(|c| c.available())
    }

    /// Short name for reports.
    pub fn name(self) -> &'static str {
        match self {
            Codec::Rle => "rle",
            Codec::Deflate => "deflate",
            Codec::Zstd => "zstd",
        }
    }

    /// Compress `data`.
    pub fn compress(self, data: &[u8]) -> Result<Vec<u8>> {
        match self {
            Codec::Rle => Ok(rle_encode(data)),
            Codec::Deflate => deflate_compress(data),
            Codec::Zstd => zstd_compress(data),
        }
    }

    /// Decompress `data` (RLE needs no size hint; zstd gets one).
    pub fn decompress(self, data: &[u8], size_hint: usize) -> Result<Vec<u8>> {
        match self {
            Codec::Rle => Ok(rle_decode(data)),
            Codec::Deflate => deflate_decompress(data, size_hint),
            Codec::Zstd => zstd_decompress(data, size_hint),
        }
    }
}

#[cfg(feature = "deflate")]
fn deflate_compress(data: &[u8]) -> Result<Vec<u8>> {
    use flate2::write::ZlibEncoder;
    use flate2::Compression;
    use std::io::Write;
    let mut enc = ZlibEncoder::new(Vec::new(), Compression::new(6));
    enc.write_all(data)?;
    Ok(enc.finish()?)
}

#[cfg(feature = "deflate")]
fn deflate_decompress(data: &[u8], size_hint: usize) -> Result<Vec<u8>> {
    use flate2::read::ZlibDecoder;
    use std::io::Read;
    let mut out = Vec::with_capacity(size_hint);
    ZlibDecoder::new(data).read_to_end(&mut out)?;
    Ok(out)
}

#[cfg(not(feature = "deflate"))]
fn deflate_compress(_data: &[u8]) -> Result<Vec<u8>> {
    Err(anyhow::anyhow!("DEFLATE codec requires the `deflate` feature (flate2 not vendored)"))
}

#[cfg(not(feature = "deflate"))]
fn deflate_decompress(_data: &[u8], _size_hint: usize) -> Result<Vec<u8>> {
    Err(anyhow::anyhow!("DEFLATE codec requires the `deflate` feature (flate2 not vendored)"))
}

#[cfg(feature = "zstd-codec")]
fn zstd_compress(data: &[u8]) -> Result<Vec<u8>> {
    Ok(zstd::bulk::compress(data, 3)?)
}

#[cfg(feature = "zstd-codec")]
fn zstd_decompress(data: &[u8], size_hint: usize) -> Result<Vec<u8>> {
    Ok(zstd::bulk::decompress(data, size_hint.max(1))?)
}

#[cfg(not(feature = "zstd-codec"))]
fn zstd_compress(_data: &[u8]) -> Result<Vec<u8>> {
    Err(anyhow::anyhow!("zstd codec requires the `zstd-codec` feature (zstd not vendored)"))
}

#[cfg(not(feature = "zstd-codec"))]
fn zstd_decompress(_data: &[u8], _size_hint: usize) -> Result<Vec<u8>> {
    Err(anyhow::anyhow!("zstd codec requires the `zstd-codec` feature (zstd not vendored)"))
}

/// Run-length encode: `(count-1, byte)` pairs, runs capped at 256.
pub fn rle_encode(data: &[u8]) -> Vec<u8> {
    let mut out = Vec::with_capacity(data.len() / 4 + 16);
    let mut i = 0;
    while i < data.len() {
        let b = data[i];
        let mut run = 1usize;
        while run < 256 && i + run < data.len() && data[i + run] == b {
            run += 1;
        }
        out.push((run - 1) as u8);
        out.push(b);
        i += run;
    }
    out
}

/// Decode [`rle_encode`] output.
pub fn rle_decode(data: &[u8]) -> Vec<u8> {
    let mut out = Vec::with_capacity(data.len() * 2);
    for pair in data.chunks_exact(2) {
        let run = pair[0] as usize + 1;
        out.extend(std::iter::repeat(pair[1]).take(run));
    }
    out
}

/// Result row of a compression measurement.
#[derive(Clone, Debug)]
pub struct CompressionStat {
    /// Codec used.
    pub codec: Codec,
    /// Input bytes.
    pub raw: usize,
    /// Output bytes.
    pub compressed: usize,
}

impl CompressionStat {
    /// raw/compressed (higher is better).
    pub fn ratio(&self) -> f64 {
        self.raw as f64 / self.compressed as f64
    }
}

/// Compress `blobs` concatenated per codec and report sizes.
pub fn measure_blobs(blobs: &[&[u8]], codec: Codec) -> Result<CompressionStat> {
    let mut compressed = 0usize;
    let mut raw = 0usize;
    for b in blobs {
        raw += b.len();
        compressed += codec.compress(b)?.len();
    }
    Ok(CompressionStat { codec, raw, compressed })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rle_roundtrip() {
        let cases: Vec<Vec<u8>> = vec![
            vec![],
            vec![1],
            vec![0; 1000],
            vec![1, 2, 3, 4, 5],
            (0..=255u8).cycle().take(700).collect(),
            vec![7; 300], // run > 256
        ];
        for c in cases {
            assert_eq!(rle_decode(&rle_encode(&c)), c);
        }
    }

    #[test]
    fn codecs_roundtrip() {
        let data: Vec<u8> = (0..4096u32).flat_map(|i| ((i * 7) as u16).to_le_bytes()).collect();
        for codec in Codec::enabled() {
            let c = codec.compress(&data).unwrap();
            let d = codec.decompress(&c, data.len()).unwrap();
            assert_eq!(d, data, "{}", codec.name());
        }
    }

    #[test]
    fn unavailable_codecs_error_instead_of_panicking() {
        for codec in Codec::ALL {
            if !codec.available() {
                assert!(codec.compress(&[1, 2, 3]).is_err());
                assert!(codec.decompress(&[1, 2, 3], 8).is_err());
            }
        }
        assert!(Codec::Rle.available());
        assert!(Codec::enabled().count() >= 1);
    }

    #[test]
    fn zeros_compress_better_than_noise() {
        let zeros = vec![0u8; 8192];
        let noise: Vec<u8> =
            (0..8192u32).map(|i| (i.wrapping_mul(2654435761) >> 13) as u8).collect();
        for codec in Codec::enabled() {
            let cz = codec.compress(&zeros).unwrap().len();
            let cn = codec.compress(&noise).unwrap().len();
            assert!(cz < cn / 4, "{}: zeros {} vs noise {}", codec.name(), cz, cn);
        }
    }

    #[test]
    fn measure_ratio() {
        let blob = vec![0u8; 1024];
        let stat = measure_blobs(&[&blob], Codec::Rle).unwrap();
        assert_eq!(stat.raw, 1024);
        assert!(stat.ratio() > 50.0);
    }
}
