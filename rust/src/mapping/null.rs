//! The `Null` mapping: writes are discarded, reads return default values.
//!
//! Paper §3: "The Null mapping discards any values written to it and
//! returns a default constructed value when reading from it. It is intended
//! to be used together with the Split mapping, to select which part of the
//! record dimension to not map to physical storage" — e.g. shared-memory
//! cache views that only need a field subset, or nulling a field out to
//! measure its access cost during profiling.

use std::marker::PhantomData;

use crate::blob::BlobStorage;
use crate::extents::Extents;
use crate::mapping::{Mapping, MemoryAccess, SimdAccess, StaticMask};
use crate::record::{RecordDim, Scalar};

/// Discards stores; loads yield `T::default()`. Occupies zero storage.
#[derive(Clone, Copy, Debug, Default)]
pub struct NullMapping<R, E> {
    extents: E,
    _pd: PhantomData<R>,
}

impl<R: RecordDim, E: Extents> NullMapping<R, E> {
    /// Mapping over `extents`.
    pub fn new(extents: E) -> Self {
        NullMapping { extents, _pd: PhantomData }
    }
}

// Null accepts (and discards) every field, so it covers any selection a
// `Split` routes to it.
impl<R, E> StaticMask for NullMapping<R, E> {
    const FIELD_MASK: u64 = u64::MAX;
}

impl<R: RecordDim, E: Extents> Mapping<R> for NullMapping<R, E> {
    type Extents = E;
    const BLOB_COUNT: usize = 0;

    #[inline(always)]
    fn extents(&self) -> &E {
        &self.extents
    }

    #[inline(always)]
    fn blob_size(&self, _i: usize) -> usize {
        0
    }

    fn fingerprint(&self) -> String {
        format!("Null<{}>", R::NAME)
    }

    #[inline(always)]
    unsafe fn shard_bounds(&self, lin: usize) -> Option<usize> {
        // No storage is touched at all: any split is trivially disjoint.
        Some(lin)
    }
}

impl<R: RecordDim, E: Extents> MemoryAccess<R> for NullMapping<R, E> {
    #[inline(always)]
    fn load<T: Scalar, S: BlobStorage>(&self, _storage: &S, _idx: &[usize], _field: usize) -> T {
        T::default()
    }

    #[inline(always)]
    fn store<T: Scalar, S: BlobStorage>(
        &self,
        _storage: &mut S,
        _idx: &[usize],
        _field: usize,
        _v: T,
    ) {
    }
}

impl<R: RecordDim, E: Extents> SimdAccess<R> for NullMapping<R, E> {}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::blob::{alloc_view, HeapAlloc};
    use crate::extents::Dyn;

    crate::record! { pub struct P, mod p { a: f32, b: u32 } }

    #[test]
    fn discards_and_defaults() {
        let mut v = alloc_view(NullMapping::<P, _>::new((Dyn(4u32),)), &HeapAlloc);
        assert_eq!(v.storage().total_bytes(), 0);
        v.set(&[1], p::a, 9.0f32);
        assert_eq!(v.get::<f32, _>(&[1], p::a), 0.0);
        assert_eq!(v.get::<u32, _>(&[3], p::b), 0);
    }
}
