//! Struct-of-Arrays mapping.
//!
//! Each field's values are stored contiguously. Two blob policies:
//! [`MultiBlob`] gives every field its own blob (the paper's "SoA MB",
//! used in Figure 3 — each field in a separate allocation), [`SingleBlob`]
//! packs all field arrays consecutively into one blob.

use std::marker::PhantomData;

use crate::blob::BlobStorage;
use crate::extents::{Extents, Linearizer, RowMajor};
use crate::mapping::{
    FieldMask, FieldRun, Mapping, MemoryAccess, PhysicalMapping, SimdAccess, StaticMask,
};
use crate::record::{RecordDim, Scalar};
use crate::simd::{Simd, SimdElem};

/// Blob policy for [`SoA`]: how field arrays are distributed over blobs.
pub trait BlobPolicy: Copy + Default + Send + Sync + 'static {
    /// Name for fingerprints/reports.
    const NAME: &'static str;
    /// `true` → one blob per field; `false` → one blob for all.
    const MULTI: bool;
}

/// One blob per field ("SoA MB" in the paper's Figure 3).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct MultiBlob;

impl BlobPolicy for MultiBlob {
    const NAME: &'static str = "MultiBlob";
    const MULTI: bool = true;
}

/// All field arrays consecutive in a single blob ("SoA SB").
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct SingleBlob;

impl BlobPolicy for SingleBlob {
    const NAME: &'static str = "SingleBlob";
    const MULTI: bool = false;
}

/// Struct-of-Arrays mapping.
///
/// ```
/// use llama::prelude::*;
/// llama::record! { pub struct P, mod p { x: f64, m: f32 } }
/// let mut v = alloc_view(SoA::<P, _>::new((Dyn(8u32),)), &HeapAlloc);
/// v.set(&[5], p::x, 1.0f64);
/// assert_eq!(v.get::<f64, _>(&[5], p::x), 1.0);
/// ```
#[derive(Clone, Copy, Debug, Default)]
pub struct SoA<R, E, B = MultiBlob, L = RowMajor, const MASK: u64 = { u64::MAX }> {
    extents: E,
    _pd: PhantomData<(R, B, L)>,
}

impl<R: RecordDim, E: Extents, B: BlobPolicy, L: Linearizer, const MASK: u64>
    SoA<R, E, B, L, MASK>
{
    /// Mapping over `extents`.
    pub fn new(extents: E) -> Self {
        SoA { extents, _pd: PhantomData }
    }

    /// The field mask as a value.
    pub const fn mask() -> FieldMask {
        FieldMask(MASK)
    }

    /// Blob index per field under [`MultiBlob`] (rank among masked fields;
    /// constant LUT — §Perf: no per-access scan of the field metadata).
    pub const FIELD_BLOB: [usize; crate::record::MAX_FIELDS] = {
        let mut lut = [0usize; crate::record::MAX_FIELDS];
        let mut b = 0;
        let mut i = 0;
        while i < R::FIELDS.len() {
            if FieldMask(MASK).contains(i) {
                lut[i] = b;
                b += 1;
            }
            i += 1;
        }
        lut
    };

    /// Sum of masked field sizes strictly before each field (constant LUT;
    /// multiplied by the record count for [`SingleBlob`] region starts).
    pub const PRE_SIZES: [usize; crate::record::MAX_FIELDS] = {
        let mut lut = [0usize; crate::record::MAX_FIELDS];
        let mut acc = 0;
        let mut i = 0;
        while i < R::FIELDS.len() {
            lut[i] = acc;
            if FieldMask(MASK).contains(i) {
                acc += R::FIELDS[i].size();
            }
            i += 1;
        }
        lut
    };

    /// Per-field scalar sizes (constant LUT).
    pub const SIZES: [usize; crate::record::MAX_FIELDS] = crate::record::size_lut(R::FIELDS);
}

impl<R, E, B, L, const MASK: u64> StaticMask for SoA<R, E, B, L, MASK> {
    const FIELD_MASK: u64 = MASK;
}

impl<R: RecordDim, E: Extents, B: BlobPolicy, L: Linearizer, const MASK: u64> Mapping<R>
    for SoA<R, E, B, L, MASK>
{
    type Extents = E;
    const BLOB_COUNT: usize = if B::MULTI { FieldMask(MASK).count(R::FIELDS.len()) } else { 1 };

    #[inline(always)]
    fn extents(&self) -> &E {
        &self.extents
    }

    #[inline(always)]
    fn blob_size(&self, i: usize) -> usize {
        let n = self.extents.count();
        if B::MULTI {
            // i-th masked field
            let mut rank = 0;
            for (f, fld) in R::FIELDS.iter().enumerate() {
                if FieldMask(MASK).contains(f) {
                    if rank == i {
                        return n * fld.size();
                    }
                    rank += 1;
                }
            }
            panic!("blob index {i} out of range");
        } else {
            let mut total = 0;
            for (f, fld) in R::FIELDS.iter().enumerate() {
                if FieldMask(MASK).contains(f) {
                    total += n * fld.size();
                }
            }
            total
        }
    }

    fn fingerprint(&self) -> String {
        format!(
            "SoA<{},{},{},mask={MASK:x}>@{:?}",
            R::NAME,
            B::NAME,
            L::NAME,
            (0..E::RANK).map(|d| self.extents.extent(d)).collect::<Vec<_>>()
        )
    }

    #[inline(always)]
    fn contiguous_run(&self, lin: usize, field: usize) -> Option<FieldRun> {
        // Each field's values sit at stride size(field) in linear order, so
        // the run extends to the end of the array (bulk engine fast path).
        if !L::LAST_DIM_CONTIGUOUS || !FieldMask(MASK).contains(field) {
            return None;
        }
        let n = self.extents.count();
        if lin >= n {
            return None;
        }
        let elem = lin * Self::SIZES[field];
        let (blob, offset) = if B::MULTI {
            (Self::FIELD_BLOB[field], elem)
        } else {
            (0, n * Self::PRE_SIZES[field] + elem)
        };
        Some(FieldRun { blob, offset, len: n - lin })
    }

    #[inline(always)]
    unsafe fn shard_bounds(&self, lin: usize) -> Option<usize> {
        // Field `f` of record `lin` owns the disjoint byte range
        // `[lin * size(f), (lin + 1) * size(f))` of its field array, so any
        // partition of the index space is byte-disjoint.
        Some(lin)
    }
}

impl<R: RecordDim, E: Extents, B: BlobPolicy, L: Linearizer, const MASK: u64> PhysicalMapping<R>
    for SoA<R, E, B, L, MASK>
{
    #[inline(always)]
    fn blob_nr_and_offset(&self, idx: &[usize], field: usize) -> (usize, usize) {
        debug_assert!(FieldMask(MASK).contains(field), "field {field} not mapped (masked out)");
        let lin = L::linearize(&self.extents, idx);
        let elem = lin * Self::SIZES[field];
        if B::MULTI {
            (Self::FIELD_BLOB[field], elem)
        } else {
            (0, self.extents.count() * Self::PRE_SIZES[field] + elem)
        }
    }
}

impl<R: RecordDim, E: Extents, B: BlobPolicy, L: Linearizer, const MASK: u64> MemoryAccess<R>
    for SoA<R, E, B, L, MASK>
{
    #[inline(always)]
    fn load<T: Scalar, S: BlobStorage>(&self, storage: &S, idx: &[usize], field: usize) -> T {
        crate::mapping::physical_load::<R, _, T, S>(self, storage, idx, field)
    }

    #[inline(always)]
    fn store<T: Scalar, S: BlobStorage>(&self, storage: &mut S, idx: &[usize], field: usize, v: T) {
        crate::mapping::physical_store::<R, _, T, S>(self, storage, idx, field, v)
    }
}

impl<R: RecordDim, E: Extents, B: BlobPolicy, L: Linearizer, const MASK: u64> SimdAccess<R>
    for SoA<R, E, B, L, MASK>
{
    #[inline(always)]
    fn load_simd<T: Scalar + SimdElem, S: BlobStorage, const N: usize>(
        &self,
        storage: &S,
        idx: &[usize],
        field: usize,
    ) -> Simd<T, N> {
        if L::LAST_DIM_CONTIGUOUS {
            // N consecutive records of one field are N consecutive T's
            // (byte-exact window: sound on the shard-worker storage).
            let (b, off) = self.blob_nr_and_offset(idx, field);
            return Simd::from_le_bytes(storage.bytes(b, off, N * T::SIZE));
        }
        // Fallback: per-lane scalar loads.
        default_load_simd(self, storage, idx, field)
    }

    #[inline(always)]
    fn store_simd<T: Scalar + SimdElem, S: BlobStorage, const N: usize>(
        &self,
        storage: &mut S,
        idx: &[usize],
        field: usize,
        v: Simd<T, N>,
    ) {
        if L::LAST_DIM_CONTIGUOUS {
            let (b, off) = self.blob_nr_and_offset(idx, field);
            v.write_le_bytes(storage.bytes_mut(b, off, N * T::SIZE));
            return;
        }
        default_store_simd(self, storage, idx, field, v)
    }
}

/// The trait-default per-lane SIMD load, callable from specialized impls'
/// fallback branches.
#[inline]
pub(crate) fn default_load_simd<R, M, T, S, const N: usize>(
    m: &M,
    storage: &S,
    idx: &[usize],
    field: usize,
) -> Simd<T, N>
where
    R: RecordDim,
    M: MemoryAccess<R>,
    T: Scalar + SimdElem,
    S: BlobStorage,
{
    let mut out = Simd::<T, N>::default();
    if idx.len() == 1 {
        // Rank-1 fast path (§Perf): no index-buffer shuffling per lane.
        for k in 0..N {
            out.0[k] = m.load(storage, &[idx[0] + k], field);
        }
        return out;
    }
    let mut idx_k = [0usize; crate::view::MAX_RANK];
    idx_k[..idx.len()].copy_from_slice(idx);
    let last = idx.len() - 1;
    for k in 0..N {
        idx_k[last] = idx[last] + k;
        out.0[k] = m.load(storage, &idx_k[..idx.len()], field);
    }
    out
}

/// The trait-default per-lane SIMD store (see [`default_load_simd`]).
#[inline]
pub(crate) fn default_store_simd<R, M, T, S, const N: usize>(
    m: &M,
    storage: &mut S,
    idx: &[usize],
    field: usize,
    v: Simd<T, N>,
) where
    R: RecordDim,
    M: MemoryAccess<R>,
    T: Scalar + SimdElem,
    S: BlobStorage,
{
    if idx.len() == 1 {
        for k in 0..N {
            m.store(storage, &[idx[0] + k], field, v.0[k]);
        }
        return;
    }
    let mut idx_k = [0usize; crate::view::MAX_RANK];
    idx_k[..idx.len()].copy_from_slice(idx);
    let last = idx.len() - 1;
    for k in 0..N {
        idx_k[last] = idx[last] + k;
        m.store(storage, &idx_k[..idx.len()], field, v.0[k]);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::blob::{alloc_view, HeapAlloc};
    use crate::extents::Dyn;

    crate::record! {
        pub struct P, mod p {
            pos: { x: f64, y: f64, z: f64 },
            mass: f32,
        }
    }

    #[test]
    fn multiblob_layout() {
        let m = SoA::<P, _>::new((Dyn(10u32),));
        assert_eq!(<SoA<P, (Dyn<u32>,)> as Mapping<P>>::BLOB_COUNT, 4);
        assert_eq!(m.blob_size(0), 80); // pos.x: 10 f64
        assert_eq!(m.blob_size(3), 40); // mass: 10 f32
        assert_eq!(m.blob_nr_and_offset_t(&[7], p::pos::y), (1, 56));
        assert_eq!(m.blob_nr_and_offset_t(&[7], p::mass), (3, 28));
    }

    #[test]
    fn singleblob_layout() {
        let m = SoA::<P, _, SingleBlob>::new((Dyn(10u32),));
        assert_eq!(<SoA<P, (Dyn<u32>,), SingleBlob> as Mapping<P>>::BLOB_COUNT, 1);
        assert_eq!(m.blob_size(0), 10 * (24 + 4));
        assert_eq!(m.blob_nr_and_offset_t(&[7], p::pos::x), (0, 56));
        assert_eq!(m.blob_nr_and_offset_t(&[7], p::pos::y), (0, 80 + 56));
        assert_eq!(m.blob_nr_and_offset_t(&[7], p::mass), (0, 240 + 28));
    }

    #[test]
    fn roundtrip_2d() {
        let mut v = alloc_view(SoA::<P, _>::new((Dyn(4u32), Dyn(5u32))), &HeapAlloc);
        v.set(&[2, 3], p::pos::z, 9.25f64);
        assert_eq!(v.get::<f64, _>(&[2, 3], p::pos::z), 9.25);
        assert_eq!(v.get::<f64, _>(&[3, 2], p::pos::z), 0.0);
    }

    #[test]
    fn simd_fast_path_roundtrip() {
        let mut v = alloc_view(SoA::<P, _>::new((Dyn(16u32),)), &HeapAlloc);
        for i in 0..16 {
            v.set(&[i], p::pos::x, i as f64);
        }
        let s: Simd<f64, 4> = v.load_simd(&[4], p::pos::x);
        assert_eq!(s.0, [4.0, 5.0, 6.0, 7.0]);
        v.store_simd(&[8], p::pos::x, Simd([100.0f64, 101.0, 102.0, 103.0]));
        assert_eq!(v.get::<f64, _>(&[9], p::pos::x), 101.0);
        assert_eq!(v.get::<f64, _>(&[12], p::pos::x), 12.0);
    }

    #[test]
    fn contiguous_runs_span_the_field_array() {
        use crate::mapping::FieldRun;
        let m = SoA::<P, _>::new((Dyn(10u32),));
        // MultiBlob: run covers the rest of the field's own blob.
        let run = m.contiguous_run_t(3, p::pos::y);
        assert_eq!(run, Some(FieldRun { blob: 1, offset: 24, len: 7 }));
        let run = m.contiguous_run_t(0, p::mass);
        assert_eq!(run, Some(FieldRun { blob: 3, offset: 0, len: 10 }));
        assert_eq!(m.contiguous_run_t(10, p::mass), None);
        // SingleBlob: run starts at the field's region within blob 0.
        let sb = SoA::<P, _, SingleBlob>::new((Dyn(10u32),));
        let run = sb.contiguous_run_t(3, p::pos::y);
        assert_eq!(run, Some(FieldRun { blob: 0, offset: 104, len: 7 }));
        // ColMajor linearization breaks contiguity.
        let cm = SoA::<P, (Dyn<u32>,), MultiBlob, crate::extents::ColMajor>::new((Dyn(10u32),));
        assert_eq!(cm.contiguous_run_t(0, p::mass), None);
    }

    #[test]
    fn masked_soa_multiblob() {
        const M: u64 = 0b1000; // only mass
        let m = SoA::<P, (Dyn<u32>,), MultiBlob, RowMajor, M>::new((Dyn(10u32),));
        assert_eq!(<SoA<P, (Dyn<u32>,), MultiBlob, RowMajor, M> as Mapping<P>>::BLOB_COUNT, 1);
        assert_eq!(m.blob_size(0), 40);
        assert_eq!(m.blob_nr_and_offset_t(&[3], p::mass), (0, 12));
    }
}
