//! `BitpackIntSoA`: integers stored with an arbitrary bit count (§3).
//!
//! HEP detectors produce values whose precision matches the hardware
//! (e.g. a 12-bit ADC), not a C++ fundamental type. Storing a 12-bit value
//! in a `u16` wastes 25% of the bits; bit-packing stores exactly `BITS`
//! bits per value, packed back-to-back per field (then organized SoA),
//! at the cost of shift/mask work on every access. The compile-time
//! [`BitpackIntSoA`] keeps the mapping stateless; [`BitpackIntSoADyn`]
//! chooses the bit count at runtime (the paper allows both).
//!
//! Signed values are stored as `BITS`-bit two's complement and
//! sign-extended on load. Values outside the representable range wrap
//! (truncation to the low `BITS` bits), matching C++ narrowing.

use std::marker::PhantomData;

use crate::blob::BlobStorage;
use crate::extents::{Extents, Linearizer, RowMajor};
use crate::mapping::{Mapping, MemoryAccess, SimdAccess};
use crate::record::{RecordDim, Scalar};

// ---------------------------------------------------------------------------
// Bit-level storage helpers (shared with bitpack_float)
// ---------------------------------------------------------------------------

/// Read `nbits` (1..=64) starting at absolute bit offset `bit` from a
/// little-endian byte buffer.
///
/// Touches exactly the bytes containing the value's bits (up to 9 when a
/// shifted 64-bit value spills) — the parallel sharded traversal relies on
/// this window staying inside a byte-aligned shard
/// ([`crate::mapping::Mapping::shard_bounds`]).
#[inline(always)]
pub fn read_bits(blob: &[u8], bit: usize, nbits: u32) -> u64 {
    debug_assert!(nbits >= 1 && nbits <= 64);
    let byte = bit / 8;
    let shift = (bit % 8) as u32;
    let covered = ((shift + nbits) as usize).div_ceil(8);
    let mut lo = [0u8; 8];
    let avail = blob.len() - byte;
    let n = avail.min(covered).min(8);
    lo[..n].copy_from_slice(&blob[byte..byte + n]);
    let lo = u64::from_le_bytes(lo);
    let mut v = lo >> shift;
    if shift + nbits > 64 && byte + 8 < blob.len() {
        let hi = blob[byte + 8] as u64;
        v |= hi << (64 - shift);
    }
    if nbits == 64 {
        v
    } else {
        v & ((1u64 << nbits) - 1)
    }
}

/// Write the low `nbits` of `value` at absolute bit offset `bit` into a
/// little-endian byte buffer (read-modify-write on exactly the bytes
/// containing the value's bits — see [`read_bits`] for why the window is
/// exact).
#[inline(always)]
pub fn write_bits(blob: &mut [u8], bit: usize, nbits: u32, value: u64) {
    debug_assert!(nbits >= 1 && nbits <= 64);
    let mask = if nbits == 64 { u64::MAX } else { (1u64 << nbits) - 1 };
    let value = value & mask;
    let byte = bit / 8;
    let shift = (bit % 8) as u32;
    let covered = ((shift + nbits) as usize).div_ceil(8);

    let mut lo = [0u8; 8];
    let avail = blob.len() - byte;
    let n = avail.min(covered).min(8);
    lo[..n].copy_from_slice(&blob[byte..byte + n]);
    let mut lo64 = u64::from_le_bytes(lo);
    lo64 = (lo64 & !(mask << shift)) | (value << shift);
    let lo = lo64.to_le_bytes();
    blob[byte..byte + n].copy_from_slice(&lo[..n]);

    // Spill into a ninth byte when shift pushes bits past 64.
    if shift != 0 && shift + nbits > 64 {
        let spill_bits = shift + nbits - 64;
        let spill_mask = ((1u16 << spill_bits) - 1) as u8;
        let spill_val = (value >> (64 - shift)) as u8;
        let b = &mut blob[byte + 8];
        *b = (*b & !spill_mask) | (spill_val & spill_mask);
    }
}

/// Byte window of the value stored at absolute bit offset `bit` (`nbits`
/// wide) within a blob of `len` bytes: `(first_byte, bit_in_window,
/// window_len)`. The window covers exactly the bytes containing the
/// value's bits (at most 9), clamped to the blob end.
///
/// This is the byte-exact currency the storage layer wants
/// ([`crate::blob::BlobStorage::bytes`]): passing the window (instead of
/// the whole blob) to [`read_bits`]/[`write_bits`] keeps every bit-packed
/// access inside its own byte range, which is what makes byte-aligned
/// shard boundaries ([`byte_aligned_shard_bound`]) a genuine disjointness
/// proof on the shard-worker storage.
#[inline(always)]
pub fn bit_window(len: usize, bit: usize, nbits: u32) -> (usize, usize, usize) {
    let byte = bit / 8;
    let shift = bit % 8;
    let covered = (shift + nbits as usize).div_ceil(8);
    (byte, shift, covered.min(len - byte))
}

/// Sign-extend the low `nbits` of `v` to i128.
#[inline(always)]
pub fn sign_extend(v: u64, nbits: u32) -> i128 {
    if nbits >= 64 {
        return v as i64 as i128;
    }
    let sign_bit = 1u64 << (nbits - 1);
    if v & sign_bit != 0 {
        (v as i128) - (1i128 << nbits)
    } else {
        v as i128
    }
}

/// Largest `b <= lin` such that a split at value index `b` falls on a byte
/// boundary of the packed stream (`b * bits % 8 == 0`) — the shard-safety
/// granularity of the bit-packed mappings
/// ([`crate::mapping::Mapping::shard_bounds`]).
#[inline]
pub fn byte_aligned_shard_bound(lin: usize, bits: u32) -> usize {
    // b * bits ≡ 0 (mod 8)  ⇔  b is a multiple of 8 / gcd(bits, 8).
    let g = match bits % 8 {
        0 => 1,
        4 => 2,
        2 | 6 => 4,
        _ => 8,
    };
    lin - lin % g
}

/// Bytes needed to bitpack `count` values of `bits` each, padded so any
/// access can read/write full 8-byte words plus a spill byte.
#[inline]
pub fn packed_blob_size(count: usize, bits: u32) -> usize {
    let payload = (count * bits as usize).div_ceil(8);
    // +8 slack: read_bits/write_bits touch up to 9 bytes from the value's
    // first byte.
    payload + 8
}

// ---------------------------------------------------------------------------
// Compile-time bit count
// ---------------------------------------------------------------------------

/// Bit-packed SoA with a compile-time per-value bit count.
///
/// All fields must be integral (checked at construction). Each field packs
/// into its own blob, `BITS` bits per value.
///
/// ```
/// use llama::prelude::*;
/// llama::record! { pub struct Hit, mod hit { adc: u16, ch: i32 } }
/// // 12-bit packing: 16 values fit in 24 payload bytes per field.
/// let mut v = alloc_view(BitpackIntSoA::<Hit, _, 12>::new((Dyn(16u32),)), &HeapAlloc);
/// v.set(&[3], hit::adc, 4095u16);
/// v.set(&[4], hit::ch, -17i32);
/// assert_eq!(v.get::<u16, _>(&[3], hit::adc), 4095);
/// assert_eq!(v.get::<i32, _>(&[4], hit::ch), -17);
/// ```
#[derive(Clone, Copy, Debug, Default)]
pub struct BitpackIntSoA<R, E, const BITS: u32, L = RowMajor> {
    extents: E,
    _pd: PhantomData<(R, L)>,
}

impl<R: RecordDim, E: Extents, const BITS: u32, L: Linearizer> BitpackIntSoA<R, E, BITS, L> {
    /// Mapping over `extents`. Panics if a field is non-integral or `BITS`
    /// is 0 or > 64.
    pub fn new(extents: E) -> Self {
        assert!(BITS >= 1 && BITS <= 64, "BITS must be in 1..=64");
        for f in R::FIELDS {
            assert!(
                f.ty.is_integral(),
                "BitpackIntSoA requires integral fields; {} is {:?}",
                f.path.join("."),
                f.ty
            );
        }
        BitpackIntSoA { extents, _pd: PhantomData }
    }
}

impl<R: RecordDim, E: Extents, const BITS: u32, L: Linearizer> Mapping<R>
    for BitpackIntSoA<R, E, BITS, L>
{
    type Extents = E;
    const BLOB_COUNT: usize = R::FIELDS.len();

    #[inline(always)]
    fn extents(&self) -> &E {
        &self.extents
    }

    #[inline(always)]
    fn blob_size(&self, _i: usize) -> usize {
        packed_blob_size(self.extents.count(), BITS)
    }

    fn fingerprint(&self) -> String {
        format!(
            "BitpackIntSoA<{},{BITS},{}>@{:?}",
            R::NAME,
            L::NAME,
            (0..E::RANK).map(|d| self.extents.extent(d)).collect::<Vec<_>>()
        )
    }

    #[inline(always)]
    unsafe fn shard_bounds(&self, lin: usize) -> Option<usize> {
        // Adjacent values share bytes; a byte-aligned split point makes
        // the two halves of the packed stream disjoint (the bit helpers
        // touch exactly the bytes containing a value's bits). Only the
        // row-major linearizer turns outermost-dimension shards into the
        // contiguous stream halves this argument needs.
        if !L::LAST_DIM_CONTIGUOUS {
            return None;
        }
        Some(byte_aligned_shard_bound(lin, BITS))
    }
}

impl<R: RecordDim, E: Extents, const BITS: u32, L: Linearizer> MemoryAccess<R>
    for BitpackIntSoA<R, E, BITS, L>
{
    #[inline(always)]
    fn load<T: Scalar, S: BlobStorage>(&self, storage: &S, idx: &[usize], field: usize) -> T {
        let lin = L::linearize(&self.extents, idx);
        let (byte, shift, win) = bit_window(storage.blob_len(field), lin * BITS as usize, BITS);
        let raw = read_bits(storage.bytes(field, byte, win), shift, BITS);
        if T::TYPE.is_signed_integral() {
            T::from_i128(sign_extend(raw, BITS))
        } else {
            T::from_i128(raw as i128)
        }
    }

    #[inline(always)]
    fn store<T: Scalar, S: BlobStorage>(&self, storage: &mut S, idx: &[usize], field: usize, v: T) {
        let lin = L::linearize(&self.extents, idx);
        // Two's-complement truncation to BITS bits.
        let raw = v.as_i128() as u64;
        let (byte, shift, win) = bit_window(storage.blob_len(field), lin * BITS as usize, BITS);
        write_bits(storage.bytes_mut(field, byte, win), shift, BITS, raw);
    }
}

impl<R: RecordDim, E: Extents, const BITS: u32, L: Linearizer> SimdAccess<R>
    for BitpackIntSoA<R, E, BITS, L>
{
}

// ---------------------------------------------------------------------------
// Runtime bit count
// ---------------------------------------------------------------------------

/// Bit-packed SoA with a runtime per-value bit count (one count for all
/// fields, as in the paper's runtime variant).
#[derive(Clone, Copy, Debug)]
pub struct BitpackIntSoADyn<R, E, L = RowMajor> {
    extents: E,
    bits: u32,
    _pd: PhantomData<(R, L)>,
}

impl<R: RecordDim, E: Extents, L: Linearizer> BitpackIntSoADyn<R, E, L> {
    /// Mapping over `extents` storing `bits` bits per value.
    pub fn new(extents: E, bits: u32) -> Self {
        assert!(bits >= 1 && bits <= 64);
        for f in R::FIELDS {
            assert!(f.ty.is_integral());
        }
        BitpackIntSoADyn { extents, bits, _pd: PhantomData }
    }

    /// The configured bit count.
    pub fn bits(&self) -> u32 {
        self.bits
    }
}

impl<R: RecordDim, E: Extents, L: Linearizer> Mapping<R> for BitpackIntSoADyn<R, E, L> {
    type Extents = E;
    const BLOB_COUNT: usize = R::FIELDS.len();

    #[inline(always)]
    fn extents(&self) -> &E {
        &self.extents
    }

    #[inline(always)]
    fn blob_size(&self, _i: usize) -> usize {
        packed_blob_size(self.extents.count(), self.bits)
    }

    fn fingerprint(&self) -> String {
        format!("BitpackIntSoADyn<{},{},{}>", R::NAME, self.bits, L::NAME)
    }

    #[inline(always)]
    unsafe fn shard_bounds(&self, lin: usize) -> Option<usize> {
        // See `BitpackIntSoA::shard_bounds`.
        if !L::LAST_DIM_CONTIGUOUS {
            return None;
        }
        Some(byte_aligned_shard_bound(lin, self.bits))
    }
}

impl<R: RecordDim, E: Extents, L: Linearizer> MemoryAccess<R> for BitpackIntSoADyn<R, E, L> {
    #[inline(always)]
    fn load<T: Scalar, S: BlobStorage>(&self, storage: &S, idx: &[usize], field: usize) -> T {
        let lin = L::linearize(&self.extents, idx);
        let (byte, shift, win) =
            bit_window(storage.blob_len(field), lin * self.bits as usize, self.bits);
        let raw = read_bits(storage.bytes(field, byte, win), shift, self.bits);
        if T::TYPE.is_signed_integral() {
            T::from_i128(sign_extend(raw, self.bits))
        } else {
            T::from_i128(raw as i128)
        }
    }

    #[inline(always)]
    fn store<T: Scalar, S: BlobStorage>(&self, storage: &mut S, idx: &[usize], field: usize, v: T) {
        let lin = L::linearize(&self.extents, idx);
        let raw = v.as_i128() as u64;
        let (byte, shift, win) =
            bit_window(storage.blob_len(field), lin * self.bits as usize, self.bits);
        write_bits(storage.bytes_mut(field, byte, win), shift, self.bits, raw);
    }
}

impl<R: RecordDim, E: Extents, L: Linearizer> SimdAccess<R> for BitpackIntSoADyn<R, E, L> {}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::blob::{alloc_view, HeapAlloc};
    use crate::extents::Dyn;

    #[test]
    fn bit_helpers_roundtrip() {
        let mut buf = vec![0u8; 64];
        // Write overlapping-free 13-bit values everywhere.
        for i in 0..30 {
            write_bits(&mut buf, i * 13, 13, (i * 97 % 8192) as u64);
        }
        for i in 0..30 {
            assert_eq!(read_bits(&buf, i * 13, 13), (i * 97 % 8192) as u64, "value {i}");
        }
    }

    #[test]
    fn bit_helpers_word_boundary() {
        let mut buf = vec![0u8; 32];
        write_bits(&mut buf, 60, 17, 0x1ABCD);
        assert_eq!(read_bits(&buf, 60, 17), 0x1ABCD);
        write_bits(&mut buf, 59, 64, u64::MAX - 5);
        assert_eq!(read_bits(&buf, 59, 64), u64::MAX - 5);
        // neighbours preserved
        write_bits(&mut buf, 0, 8, 0xAA);
        assert_eq!(read_bits(&buf, 0, 8), 0xAA);
    }

    #[test]
    fn bit_window_covers_exactly_the_value_bytes() {
        // Aligned 8-bit value: one byte.
        assert_eq!(bit_window(64, 16, 8), (2, 0, 1));
        // 13 bits starting mid-byte: bits 13..26 → bytes 1..=3.
        assert_eq!(bit_window(64, 13, 13), (1, 5, 3));
        // Worst case: shift 7 + 64 bits spills into a ninth byte.
        assert_eq!(bit_window(64, 7, 64), (0, 7, 9));
        // Window clamps to the blob end (the +8 slack absorbs this in
        // real blobs; the clamp mirrors read_bits' old `avail` logic).
        assert_eq!(bit_window(4, 16, 64), (2, 0, 2));
        // Windowed read/write agree with whole-blob read/write.
        let mut a = vec![0u8; 32];
        let mut b = vec![0u8; 32];
        for (i, &(bit, nbits, val)) in
            [(3usize, 13u32, 0x1abcu64), (60, 17, 0x1ffff), (100, 7, 0x55)].iter().enumerate()
        {
            write_bits(&mut a, bit, nbits, val);
            let (byte, shift, win) = bit_window(b.len(), bit, nbits);
            write_bits(&mut b[byte..byte + win], shift, nbits, val);
            assert_eq!(a, b, "case {i}");
            assert_eq!(read_bits(&b[byte..byte + win], shift, nbits), val, "case {i}");
        }
    }

    #[test]
    fn sign_extension() {
        assert_eq!(sign_extend(0b111, 3), -1);
        assert_eq!(sign_extend(0b011, 3), 3);
        assert_eq!(sign_extend(0b100, 3), -4);
        assert_eq!(sign_extend(0xFFF, 12), -1);
        assert_eq!(sign_extend(u64::MAX, 64), -1);
    }

    crate::record! {
        pub struct Hit, mod hit {
            adc: u16,
            channel: i32,
            time: u64,
        }
    }

    #[test]
    fn roundtrip_unsigned_and_signed() {
        let mut v = alloc_view(BitpackIntSoA::<Hit, _, 12>::new((Dyn(100u32),)), &HeapAlloc);
        for i in 0..100usize {
            v.set(&[i], hit::adc, (i * 41 % 4096) as u16);
            v.set(&[i], hit::channel, (i as i32) - 50);
            v.set(&[i], hit::time, (i * 7) as u64);
        }
        for i in 0..100usize {
            assert_eq!(v.get::<u16, _>(&[i], hit::adc), (i * 41 % 4096) as u16);
            assert_eq!(v.get::<i32, _>(&[i], hit::channel), (i as i32) - 50);
            assert_eq!(v.get::<u64, _>(&[i], hit::time), (i * 7) as u64);
        }
    }

    #[test]
    fn storage_savings() {
        // 100 x 12 bits = 150 payload bytes vs 200 for u16.
        let m = BitpackIntSoA::<Hit, _, 12>::new((Dyn(100u32),));
        assert_eq!(m.blob_size(0), 150 + 8);
        let v = alloc_view(m, &HeapAlloc);
        assert!(v.storage().total_bytes() < 100 * (2 + 4 + 8));
    }

    #[test]
    fn truncation_wraps() {
        let mut v = alloc_view(BitpackIntSoA::<Hit, _, 8>::new((Dyn(4u32),)), &HeapAlloc);
        v.set(&[0], hit::adc, 0x1FFu16); // 9 bits -> low 8 kept
        assert_eq!(v.get::<u16, _>(&[0], hit::adc), 0xFF);
        v.set(&[1], hit::channel, -1i32); // 0xFF -> sign-extends back to -1
        assert_eq!(v.get::<i32, _>(&[1], hit::channel), -1);
        v.set(&[2], hit::channel, 127i32);
        assert_eq!(v.get::<i32, _>(&[2], hit::channel), 127);
        v.set(&[3], hit::channel, 128i32); // wraps to -128 in 8-bit
        assert_eq!(v.get::<i32, _>(&[3], hit::channel), -128);
    }

    #[test]
    fn dyn_variant_matches_const() {
        let mut a = alloc_view(BitpackIntSoA::<Hit, _, 17>::new((Dyn(64u32),)), &HeapAlloc);
        let mut b = alloc_view(BitpackIntSoADyn::<Hit, _>::new((Dyn(64u32),), 17), &HeapAlloc);
        for i in 0..64usize {
            let val = (i * 1003) as u64 % (1 << 17);
            a.set(&[i], hit::time, val);
            b.set(&[i], hit::time, val);
        }
        for i in 0..64usize {
            assert_eq!(a.get::<u64, _>(&[i], hit::time), b.get::<u64, _>(&[i], hit::time));
        }
        assert_eq!(a.storage().total_bytes(), b.storage().total_bytes());
    }

    #[test]
    fn adjacent_values_do_not_clobber() {
        let mut v = alloc_view(BitpackIntSoA::<Hit, _, 7>::new((Dyn(16u32),)), &HeapAlloc);
        for i in 0..16usize {
            v.set(&[i], hit::adc, (i as u16 * 9) % 128);
        }
        // Overwrite the middle, check neighbours.
        v.set(&[7], hit::adc, 127u16);
        v.set(&[8], hit::adc, 0u16);
        assert_eq!(v.get::<u16, _>(&[6], hit::adc), (6 * 9) % 128);
        assert_eq!(v.get::<u16, _>(&[7], hit::adc), 127);
        assert_eq!(v.get::<u16, _>(&[8], hit::adc), 0);
        assert_eq!(v.get::<u16, _>(&[9], hit::adc), (9 * 9) % 128);
    }
}
