//! `FieldAccessCount` (né `Trace`): count accesses per record field (§4).
//!
//! "The lightweight Trace counts the accumulated number of accesses per
//! record field ... Counting memory accesses is performed as side effect
//! of data access and costs one atomic increment to a dedicated memory
//! location per regular access." Extra memory is 2 counters per field
//! (reads and writes) — negligible. The measured cost (the paper reports
//! ~3× for a CUDA particle simulation) is reproduced by experiment E4
//! (`benches/instrumentation.rs`).
//!
//! The mapping forwards all layout logic to an arbitrary inner mapping and
//! can therefore instrument any of them, physical or computed.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

use crate::blob::BlobStorage;

use crate::mapping::{Mapping, MemoryAccess, SimdAccess};
use crate::record::{RecordDim, Scalar};
use crate::simd::{Simd, SimdElem};
use crate::util::CachePadded;

/// Per-field access counters for one instrumented view.
///
/// Shared (`Arc`) between mapping clones, so cloning a view keeps counting
/// into the same tallies — matching C++ LLAMA where the counters live with
/// the mapping instance.
///
/// Each counter is cache-line padded (E13 false-sharing audit): a
/// parallel traversal has every shard incrementing the *same* field's
/// counter — that contention is true sharing and padding cannot remove
/// it — but unpadded, eight adjacent `AtomicU64`s shared one line, so
/// incrementing field 0's read counter also bounced fields 1–3's
/// read/write lines across cores. Padding decouples the fields. Memory
/// goes from 16 B to 128 B per field, still negligible against the §4
/// budget (2 counters per field, independent of `n`).
#[derive(Debug, Default)]
pub struct AccessCounters {
    /// reads[f], writes[f] per flattened field index.
    reads: Vec<CachePadded<AtomicU64>>,
    writes: Vec<CachePadded<AtomicU64>>,
}

/// A coherent point-in-time copy of the per-field counters.
///
/// Produced by [`FieldAccessCount::snapshot`]. Unlike the ad-hoc
/// [`FieldAccessCount::field_counts`] reads, every counter in the snapshot
/// belongs to the same cut: no access was recorded between the two read
/// passes that produced it (see `snapshot` for the protocol).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct AccessSnapshot {
    /// `(reads, writes)` per flattened field index.
    pub counts: Vec<(u64, u64)>,
    /// Whether the double-read stabilized. `false` only under sustained
    /// concurrent traffic that outran the bounded retries; the last pass
    /// is still returned so callers can degrade gracefully.
    pub stable: bool,
}

impl AccessSnapshot {
    /// Sum of all reads and writes in the snapshot.
    pub fn total(&self) -> u64 {
        self.counts.iter().map(|&(r, w)| r + w).sum()
    }
}

/// One row of the access report.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct FieldAccessRow {
    /// Dotted field path.
    pub field: String,
    /// Number of loads.
    pub reads: u64,
    /// Number of stores.
    pub writes: u64,
}

/// Count loads/stores per field while forwarding to `M`.
#[derive(Clone, Debug)]
pub struct FieldAccessCount<R, M> {
    inner: M,
    counters: Arc<AccessCounters>,
    _pd: std::marker::PhantomData<R>,
}

impl<R: RecordDim, M: MemoryAccess<R>> FieldAccessCount<R, M> {
    /// Instrument `inner`.
    pub fn new(inner: M) -> Self {
        let n = R::FIELDS.len();
        FieldAccessCount {
            inner,
            counters: Arc::new(AccessCounters {
                reads: (0..n).map(|_| CachePadded::new(AtomicU64::new(0))).collect(),
                writes: (0..n).map(|_| CachePadded::new(AtomicU64::new(0))).collect(),
            }),
            _pd: std::marker::PhantomData,
        }
    }

    /// The inner mapping.
    pub fn inner(&self) -> &M {
        &self.inner
    }

    /// Total (reads, writes) for `field` (a raw index or a typed tag).
    pub fn field_counts(&self, field: impl crate::record::FieldIndex) -> (u64, u64) {
        let field = field.field_index();
        (
            self.counters.reads[field].load(Ordering::Relaxed),
            self.counters.writes[field].load(Ordering::Relaxed),
        )
    }

    /// Read *all* counters coherently.
    ///
    /// Individual relaxed loads can interleave with concurrent accesses,
    /// so a naive loop over [`FieldAccessCount::field_counts`] may mix
    /// counts from different instants. `snapshot` reads the whole counter
    /// vector repeatedly until two consecutive passes agree — then no
    /// counter changed between those passes, so the returned values form a
    /// single consistent cut. On a quiescent or read-only view the first
    /// retry already matches; under sustained concurrent writes the
    /// retries are bounded and the last pass is returned with
    /// `stable = false`.
    pub fn snapshot(&self) -> AccessSnapshot {
        let read_all = || -> Vec<(u64, u64)> {
            (0..R::FIELDS.len())
                .map(|f| {
                    (
                        self.counters.reads[f].load(Ordering::Relaxed),
                        self.counters.writes[f].load(Ordering::Relaxed),
                    )
                })
                .collect()
        };
        let mut prev = read_all();
        for _ in 0..8 {
            let cur = read_all();
            if cur == prev {
                return AccessSnapshot { counts: cur, stable: true };
            }
            prev = cur;
        }
        AccessSnapshot { counts: prev, stable: false }
    }

    /// Reset all counters to zero.
    pub fn reset(&self) {
        for c in self.counters.reads.iter().chain(self.counters.writes.iter()) {
            c.store(0, Ordering::Relaxed);
        }
    }

    /// Snapshot the per-field report.
    pub fn report(&self) -> Vec<FieldAccessRow> {
        R::FIELDS
            .iter()
            .enumerate()
            .map(|(f, fld)| FieldAccessRow {
                field: fld.dotted(),
                reads: self.counters.reads[f].load(Ordering::Relaxed),
                writes: self.counters.writes[f].load(Ordering::Relaxed),
            })
            .collect()
    }

    /// Render the report as an aligned text table (the tool output of §4).
    pub fn render_table(&self) -> String {
        let rows = self.report();
        let w = rows.iter().map(|r| r.field.len()).max().unwrap_or(5).max(5);
        let mut out = format!("{:w$}  {:>12}  {:>12}\n", "field", "reads", "writes", w = w);
        for r in &rows {
            out.push_str(&format!("{:w$}  {:>12}  {:>12}\n", r.field, r.reads, r.writes, w = w));
        }
        let (tr, tw): (u64, u64) = rows.iter().fold((0, 0), |a, r| (a.0 + r.reads, a.1 + r.writes));
        out.push_str(&format!("{:w$}  {:>12}  {:>12}\n", "TOTAL", tr, tw, w = w));
        out
    }
}

impl<R: RecordDim, M: MemoryAccess<R>> Mapping<R> for FieldAccessCount<R, M> {
    type Extents = M::Extents;
    const BLOB_COUNT: usize = M::BLOB_COUNT;

    #[inline(always)]
    fn extents(&self) -> &Self::Extents {
        self.inner.extents()
    }

    #[inline(always)]
    fn blob_size(&self, i: usize) -> usize {
        self.inner.blob_size(i)
    }

    fn fingerprint(&self) -> String {
        // Instrumentation is layout-transparent: same bytes as the inner
        // mapping (copy fast paths remain valid).
        self.inner.fingerprint()
    }

    #[inline(always)]
    unsafe fn shard_bounds(&self, lin: usize) -> Option<usize> {
        // The per-field counters are atomic (increments from concurrent
        // shards commute), so safety is the inner layout's.
        self.inner.shard_bounds(lin)
    }
}

impl<R: RecordDim, M: MemoryAccess<R>> MemoryAccess<R> for FieldAccessCount<R, M> {
    #[inline(always)]
    fn load<T: Scalar, S: BlobStorage>(&self, storage: &S, idx: &[usize], field: usize) -> T {
        // §4: one atomic increment per access.
        self.counters.reads[field].fetch_add(1, Ordering::Relaxed);
        self.inner.load(storage, idx, field)
    }

    #[inline(always)]
    fn store<T: Scalar, S: BlobStorage>(&self, storage: &mut S, idx: &[usize], field: usize, v: T) {
        self.counters.writes[field].fetch_add(1, Ordering::Relaxed);
        self.inner.store(storage, idx, field, v)
    }
}

impl<R: RecordDim, M: SimdAccess<R>> SimdAccess<R> for FieldAccessCount<R, M> {
    #[inline(always)]
    fn load_simd<T: Scalar + SimdElem, S: BlobStorage, const N: usize>(
        &self,
        storage: &S,
        idx: &[usize],
        field: usize,
    ) -> Simd<T, N> {
        // A SIMD load touches N elements of the field.
        self.counters.reads[field].fetch_add(N as u64, Ordering::Relaxed);
        self.inner.load_simd(storage, idx, field)
    }

    #[inline(always)]
    fn store_simd<T: Scalar + SimdElem, S: BlobStorage, const N: usize>(
        &self,
        storage: &mut S,
        idx: &[usize],
        field: usize,
        v: Simd<T, N>,
    ) {
        self.counters.writes[field].fetch_add(N as u64, Ordering::Relaxed);
        self.inner.store_simd(storage, idx, field, v)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::blob::{alloc_view, HeapAlloc};
    use crate::extents::Dyn;
    use crate::mapping::soa::SoA;

    crate::record! {
        pub struct P, mod p {
            x: f64,
            m: f32,
        }
    }

    #[test]
    fn counts_reads_and_writes() {
        let fac = FieldAccessCount::new(SoA::<P, _>::new((Dyn(16u32),)));
        let mut v = alloc_view(fac, &HeapAlloc);
        for i in 0..16usize {
            v.set(&[i], p::x, i as f64);
        }
        let mut acc = 0.0;
        for i in 0..16usize {
            acc += v.get::<f64, _>(&[i], p::x);
        }
        v.set(&[0], p::m, acc as f32);
        let rep = v.mapping().report();
        assert_eq!(rep[p::x.i()].reads, 16);
        assert_eq!(rep[p::x.i()].writes, 16);
        assert_eq!(rep[p::m.i()].reads, 0);
        assert_eq!(rep[p::m.i()].writes, 1);
        assert_eq!(rep[p::x.i()].field, "x");
    }

    #[test]
    fn simd_accesses_count_lanes() {
        let fac = FieldAccessCount::new(SoA::<P, _>::new((Dyn(16u32),)));
        let mut v = alloc_view(fac, &HeapAlloc);
        let s: crate::simd::Simd<f64, 4> = v.load_simd(&[0], p::x);
        v.store_simd(&[4], p::x, s);
        let (r, w) = v.mapping().field_counts(p::x);
        assert_eq!((r, w), (4, 4));
    }

    #[test]
    fn reset_and_render() {
        let fac = FieldAccessCount::new(SoA::<P, _>::new((Dyn(4u32),)));
        let mut v = alloc_view(fac, &HeapAlloc);
        v.set(&[1], p::x, 1.0f64);
        v.mapping().reset();
        assert_eq!(v.mapping().field_counts(p::x), (0, 0));
        let table = v.mapping().render_table();
        assert!(table.contains("field"));
        assert!(table.contains("TOTAL"));
    }

    #[test]
    fn snapshot_is_stable_and_matches_report() {
        let fac = FieldAccessCount::new(SoA::<P, _>::new((Dyn(8u32),)));
        let mut v = alloc_view(fac, &HeapAlloc);
        for i in 0..8usize {
            v.set(&[i], p::x, i as f64);
            let _ = v.get::<f32, _>(&[i], p::m);
        }
        let snap = v.mapping().snapshot();
        assert!(snap.stable);
        assert_eq!(snap.counts.len(), 2);
        assert_eq!(snap.counts[p::x.i()], (0, 8));
        assert_eq!(snap.counts[p::m.i()], (8, 0));
        assert_eq!(snap.total(), 16);
        // Snapshot of a quiescent view equals the ad-hoc report.
        let rep = v.mapping().report();
        for (f, row) in rep.iter().enumerate() {
            assert_eq!(snap.counts[f], (row.reads, row.writes));
        }
    }

    #[test]
    fn values_flow_through_unchanged() {
        let plain = SoA::<P, _>::new((Dyn(8u32),));
        let mut a = alloc_view(plain, &HeapAlloc);
        let mut b = alloc_view(FieldAccessCount::new(SoA::<P, _>::new((Dyn(8u32),))), &HeapAlloc);
        for i in 0..8usize {
            a.set(&[i], p::x, (i * i) as f64);
            b.set(&[i], p::x, (i * i) as f64);
        }
        for i in 0..8usize {
            assert_eq!(a.get::<f64, _>(&[i], p::x), b.get::<f64, _>(&[i], p::x));
        }
    }
}
