//! The `Split` mapping: partitions the record dimension between two inner
//! mappings.
//!
//! A field [`Selection`] goes to the first mapping, the complement to the
//! second. Both inner mappings must be constructed over the same extents
//! with matching field masks (use [`crate::mapping::FieldMask`] const
//! parameters on AoS/SoA/AoSoA, or a mask-oblivious mapping like
//! [`crate::mapping::null::NullMapping`]). Classic §3 use: hot fields →
//! SoA, cold fields → AoS; or cached subset → real storage, rest → Null.

use std::marker::PhantomData;

use crate::blob::BlobStorage;

use crate::mapping::{FieldMask, Mapping, MemoryAccess, SimdAccess, StaticMask};
use crate::record::{GroupTag, RecordDim, Scalar, Selection};
use crate::simd::{Simd, SimdElem};

/// Routes fields in `selection` to `M1`, the rest to `M2`. `M1`'s blobs
/// come first in the view's storage.
#[derive(Clone, Copy, Debug)]
pub struct Split<R, M1, M2> {
    first: M1,
    second: M2,
    selection: Selection,
    _pd: PhantomData<R>,
}

impl<R, M1, M2> Split<R, M1, M2>
where
    R: RecordDim,
    M1: MemoryAccess<R>,
    M2: MemoryAccess<R>,
{
    /// Split `selection` into `first`, complement into `second`.
    ///
    /// Accepts a runtime [`Selection`] or a typed selection tag from
    /// [`crate::record!`] (e.g. `p::pos`), which converts.
    ///
    /// The inner mappings see the full record dimension but must only be
    /// asked about their own fields; construct them with matching masks.
    pub fn new(first: M1, second: M2, selection: impl Into<Selection>) -> Self {
        Split { first, second, selection: selection.into(), _pd: PhantomData }
    }

    /// Construct a split whose routing is *proved* at compile time: the
    /// selection tag's fields must be covered by `M1`'s field mask and
    /// the complement by `M2`'s ([`StaticMask`]).
    ///
    /// ```
    /// use llama::prelude::*;
    /// llama::record! {
    ///     pub struct P, mod p { pos: { x: f64, y: f64 }, m: f32 }
    /// }
    /// const HOT: u64 = 0b011; // pos.*
    /// const COLD: u64 = 0b100; // m
    /// type M1 = SoA<P, (Dyn<u32>,), MultiBlob, RowMajor, HOT>;
    /// type M2 = SoA<P, (Dyn<u32>,), MultiBlob, RowMajor, COLD>;
    /// let e = (Dyn(4u32),);
    /// let split = Split::new_typed(M1::new(e), M2::new(e), p::pos);
    /// let mut v = alloc_view(split, &HeapAlloc);
    /// v.set(&[1], p::pos::y, 2.0f64);
    /// assert_eq!(v.get::<f64, _>(&[1], p::pos::y), 2.0);
    /// ```
    ///
    /// A selection one half does not map is a compile error (raised
    /// during monomorphization, like the typed access API's checks):
    ///
    /// ```compile_fail
    /// use llama::prelude::*;
    /// llama::record! {
    ///     pub struct P, mod p { pos: { x: f64, y: f64 }, m: f32 }
    /// }
    /// const WRONG: u64 = 0b100; // maps only `m`, not `pos.*`
    /// type M1 = SoA<P, (Dyn<u32>,), MultiBlob, RowMajor, WRONG>;
    /// let e = (Dyn(4u32),);
    /// // ERROR: `p::pos` is not covered by M1's field mask
    /// let _ = Split::new_typed(M1::new(e), NullMapping::<P, _>::new(e), p::pos);
    /// ```
    ///
    /// The runtime-checked [`new`](Split::new) remains for selections
    /// assembled at runtime or for inner mappings without a static mask.
    pub fn new_typed<G>(first: M1, second: M2, group: G) -> Self
    where
        G: GroupTag<Record = R>,
        M1: StaticMask,
        M2: StaticMask,
    {
        const {
            let sel = FieldMask::from_selection(G::SELECTION);
            assert!(
                sel.0 & !M1::FIELD_MASK == 0,
                "Split::new_typed: selection is not covered by the first mapping's field mask"
            );
            let rest = sel.complement(R::FIELDS.len());
            assert!(
                rest.0 & !M2::FIELD_MASK == 0,
                "Split::new_typed: complement is not covered by the second mapping's field mask"
            );
        }
        let _ = group;
        Split { first, second, selection: G::SELECTION, _pd: PhantomData }
    }

    /// The selection routed to the first mapping.
    pub fn selection(&self) -> Selection {
        self.selection
    }

    /// Access the first inner mapping.
    pub fn first(&self) -> &M1 {
        &self.first
    }

    /// Access the second inner mapping.
    pub fn second(&self) -> &M2 {
        &self.second
    }
}

/// Adapter presenting a suffix of a [`BlobStorage`] as its own storage, so
/// the second inner mapping sees blob indices starting at zero.
///
/// Forwards the byte-exact window methods too (not just the whole-blob
/// pair): in the parallel path the wrapped storage is the shard-worker
/// [`crate::blob::ShardBlobs`], whose whole-blob methods panic — the
/// defaults would route `bytes` through `blob`.
struct OffsetStorage<'s, S>(&'s S, usize);

impl<'s, S: BlobStorage> BlobStorage for OffsetStorage<'s, S> {
    fn blob_count(&self) -> usize {
        self.0.blob_count() - self.1
    }
    #[inline(always)]
    fn blob(&self, i: usize) -> &[u8] {
        self.0.blob(i + self.1)
    }
    fn blob_mut(&mut self, _i: usize) -> &mut [u8] {
        unreachable!("OffsetStorage is read-only")
    }
    #[inline(always)]
    fn blob_len(&self, i: usize) -> usize {
        self.0.blob_len(i + self.1)
    }
    #[inline(always)]
    fn bytes(&self, i: usize, off: usize, len: usize) -> &[u8] {
        self.0.bytes(i + self.1, off, len)
    }
    fn bytes_mut(&mut self, _i: usize, _off: usize, _len: usize) -> &mut [u8] {
        unreachable!("OffsetStorage is read-only")
    }
}

/// Mutable variant of [`OffsetStorage`].
struct OffsetStorageMut<'s, S>(&'s mut S, usize);

impl<'s, S: BlobStorage> BlobStorage for OffsetStorageMut<'s, S> {
    fn blob_count(&self) -> usize {
        self.0.blob_count() - self.1
    }
    #[inline(always)]
    fn blob(&self, i: usize) -> &[u8] {
        self.0.blob(i + self.1)
    }
    #[inline(always)]
    fn blob_mut(&mut self, i: usize) -> &mut [u8] {
        self.0.blob_mut(i + self.1)
    }
    #[inline(always)]
    fn blob_len(&self, i: usize) -> usize {
        self.0.blob_len(i + self.1)
    }
    #[inline(always)]
    fn bytes(&self, i: usize, off: usize, len: usize) -> &[u8] {
        self.0.bytes(i + self.1, off, len)
    }
    #[inline(always)]
    fn bytes_mut(&mut self, i: usize, off: usize, len: usize) -> &mut [u8] {
        self.0.bytes_mut(i + self.1, off, len)
    }
}

impl<R, M1, M2> Mapping<R> for Split<R, M1, M2>
where
    R: RecordDim,
    M1: MemoryAccess<R>,
    M2: MemoryAccess<R, Extents = M1::Extents>,
{
    type Extents = M1::Extents;
    const BLOB_COUNT: usize = M1::BLOB_COUNT + M2::BLOB_COUNT;

    #[inline(always)]
    fn extents(&self) -> &Self::Extents {
        self.first.extents()
    }

    #[inline(always)]
    fn blob_size(&self, i: usize) -> usize {
        if i < M1::BLOB_COUNT {
            self.first.blob_size(i)
        } else {
            self.second.blob_size(i - M1::BLOB_COUNT)
        }
    }

    fn fingerprint(&self) -> String {
        format!(
            "Split<{}..+{}|{}|{}>",
            self.selection.start,
            self.selection.len,
            self.first.fingerprint(),
            self.second.fingerprint()
        )
    }

    #[inline(always)]
    unsafe fn shard_bounds(&self, lin: usize) -> Option<usize> {
        // Both halves live in disjoint blobs, so a boundary is safe when
        // both inner mappings accept it: walk down to the first fixpoint
        // (0 is accepted by every shardable mapping, so this terminates).
        let mut b = lin;
        loop {
            let b1 = self.first.shard_bounds(b)?;
            let b2 = self.second.shard_bounds(b1)?;
            if b2 == b {
                return Some(b);
            }
            b = b2;
        }
    }
}

impl<R, M1, M2> MemoryAccess<R> for Split<R, M1, M2>
where
    R: RecordDim,
    M1: MemoryAccess<R>,
    M2: MemoryAccess<R, Extents = M1::Extents>,
{
    #[inline(always)]
    fn load<T: Scalar, S: BlobStorage>(&self, storage: &S, idx: &[usize], field: usize) -> T {
        if self.selection.contains(field) {
            self.first.load(storage, idx, field)
        } else {
            self.second.load(&OffsetStorage(storage, M1::BLOB_COUNT), idx, field)
        }
    }

    #[inline(always)]
    fn store<T: Scalar, S: BlobStorage>(&self, storage: &mut S, idx: &[usize], field: usize, v: T) {
        if self.selection.contains(field) {
            self.first.store(storage, idx, field, v)
        } else {
            self.second.store(&mut OffsetStorageMut(storage, M1::BLOB_COUNT), idx, field, v)
        }
    }
}

impl<R, M1, M2> SimdAccess<R> for Split<R, M1, M2>
where
    R: RecordDim,
    M1: SimdAccess<R>,
    M2: SimdAccess<R, Extents = M1::Extents>,
{
    #[inline(always)]
    fn load_simd<T: Scalar + SimdElem, S: BlobStorage, const N: usize>(
        &self,
        storage: &S,
        idx: &[usize],
        field: usize,
    ) -> Simd<T, N> {
        if self.selection.contains(field) {
            self.first.load_simd(storage, idx, field)
        } else {
            self.second.load_simd(&OffsetStorage(storage, M1::BLOB_COUNT), idx, field)
        }
    }

    #[inline(always)]
    fn store_simd<T: Scalar + SimdElem, S: BlobStorage, const N: usize>(
        &self,
        storage: &mut S,
        idx: &[usize],
        field: usize,
        v: Simd<T, N>,
    ) {
        if self.selection.contains(field) {
            self.first.store_simd(storage, idx, field, v)
        } else {
            self.second.store_simd(&mut OffsetStorageMut(storage, M1::BLOB_COUNT), idx, field, v)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::blob::{alloc_view, HeapAlloc};
    use crate::extents::Dyn;
    use crate::mapping::null::NullMapping;
    use crate::mapping::soa::{MultiBlob, SoA};
    use crate::extents::RowMajor;

    crate::record! {
        pub struct P, mod p {
            pos: { x: f64, y: f64, z: f64 },
            vel: { x: f64, y: f64, z: f64 },
            mass: f32,
        }
    }

    #[test]
    fn soa_plus_null_cache_view() {
        // Map only pos.* physically; vel/mass discarded (§3 Null use case).
        const POS: u64 = 0b0000111;
        type M1 = SoA<P, (Dyn<u32>,), MultiBlob, RowMajor, POS>;
        let e = (Dyn(8u32),);
        let split = Split::new_typed(M1::new(e), NullMapping::<P, _>::new(e), p::pos);
        let mut v = alloc_view(split, &HeapAlloc);
        assert_eq!(v.storage().blob_count(), 3);
        assert_eq!(v.storage().total_bytes(), 3 * 8 * 8);
        v.set(&[2], p::pos::y, 4.0f64);
        v.set(&[2], p::mass, 2.0f32); // discarded
        assert_eq!(v.get::<f64, _>(&[2], p::pos::y), 4.0);
        assert_eq!(v.get::<f32, _>(&[2], p::mass), 0.0);
    }

    #[test]
    fn soa_plus_soa_partition() {
        const HOT: u64 = 0b0000111; // pos -> first
        const COLD: u64 = 0b1111000; // vel+mass -> second
        type M1 = SoA<P, (Dyn<u32>,), MultiBlob, RowMajor, HOT>;
        type M2 = SoA<P, (Dyn<u32>,), MultiBlob, RowMajor, COLD>;
        let e = (Dyn(4u32),);
        let split = Split::new_typed(M1::new(e), M2::new(e), p::pos);
        let mut v = alloc_view(split, &HeapAlloc);
        assert_eq!(v.storage().blob_count(), 7);
        v.set(&[1], p::pos::x, 1.0f64);
        v.set(&[1], p::vel::z, -1.0f64);
        v.set(&[1], p::mass, 0.5f32);
        assert_eq!(v.get::<f64, _>(&[1], p::pos::x), 1.0);
        assert_eq!(v.get::<f64, _>(&[1], p::vel::z), -1.0);
        assert_eq!(v.get::<f32, _>(&[1], p::mass), 0.5);
    }
}
