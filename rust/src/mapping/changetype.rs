//! `Changetype`: store fields as a different scalar type (§3).
//!
//! Bit-packing pays shift/mask work on every access; when the desired
//! storage precision matches a hardware type (f32, f16, bf16, i16, ...),
//! a plain type conversion is cheaper because "the hardware may have
//! appropriate conversion instructions". `ChangeType` converts values
//! between the *algorithm* record dimension `R` and a *storage* record
//! dimension `RS`, then forwards to an arbitrary inner mapping over `RS` —
//! e.g. doubles stored as floats, or as the C++23 extended floating-point
//! types (here: [`crate::record::F16`], [`crate::record::Bf16`]).
//! Inspired by the Ginkgo accessor (paper ref. [9]).

use std::marker::PhantomData;

use crate::blob::BlobStorage;

use crate::mapping::{Mapping, MemoryAccess, SimdAccess};
use crate::record::{Bf16, RecordDim, Scalar, ScalarType, F16};

/// Convert scalar `a` to type `B`: integral↔integral via `i128` (exact),
/// anything involving floats via `f64`.
#[inline(always)]
pub fn convert_scalar<A: Scalar, B: Scalar>(a: A) -> B {
    if A::TYPE.is_integral() && B::TYPE.is_integral() {
        B::from_i128(a.as_i128())
    } else {
        B::from_f64(a.as_f64())
    }
}

/// Store `R`'s fields with the scalar types of `RS`, mapped by `M`.
///
/// `R` and `RS` must have the same field count (checked at construction);
/// field `i` of `R` is stored as field `i` of `RS`.
///
/// ```
/// use llama::prelude::*;
/// llama::record! { pub struct P,  mod p  { x: f64, y: f64 } }
/// llama::record! { pub struct Ps, mod ps { x: f32, y: f32 } }
/// let inner = SoA::<Ps, _>::new((Dyn(16u32),));
/// let mut v = alloc_view(ChangeType::<P, Ps, _>::new(inner), &HeapAlloc);
/// v.set(&[2], p::x, 0.5f64);                    // algorithm type: f64
/// assert_eq!(v.get::<f64, _>(&[2], p::x), 0.5);    // stored as f32
/// assert_eq!(v.storage().total_bytes(), 16 * 8); // half of the f64 SoA
/// ```
#[derive(Clone, Copy, Debug, Default)]
pub struct ChangeType<R, RS, M> {
    inner: M,
    _pd: PhantomData<(R, RS)>,
}

impl<R: RecordDim, RS: RecordDim, M: MemoryAccess<RS>> ChangeType<R, RS, M> {
    /// Wrap `inner` (a mapping over the storage record dimension `RS`).
    pub fn new(inner: M) -> Self {
        assert_eq!(
            R::FIELDS.len(),
            RS::FIELDS.len(),
            "ChangeType: algorithm and storage records must have the same field count"
        );
        ChangeType { inner, _pd: PhantomData }
    }

    /// The inner (storage) mapping.
    pub fn inner(&self) -> &M {
        &self.inner
    }
}

impl<R: RecordDim, RS: RecordDim, M: MemoryAccess<RS>> Mapping<R> for ChangeType<R, RS, M> {
    type Extents = M::Extents;
    const BLOB_COUNT: usize = M::BLOB_COUNT;

    #[inline(always)]
    fn extents(&self) -> &Self::Extents {
        self.inner.extents()
    }

    #[inline(always)]
    fn blob_size(&self, i: usize) -> usize {
        self.inner.blob_size(i)
    }

    fn fingerprint(&self) -> String {
        format!("ChangeType<{}->{}|{}>", R::NAME, RS::NAME, self.inner.fingerprint())
    }

    #[inline(always)]
    unsafe fn shard_bounds(&self, lin: usize) -> Option<usize> {
        // Type conversion is stateless; safety is the inner layout's.
        self.inner.shard_bounds(lin)
    }
}

/// Dispatch a typed inner load on the storage scalar type and convert to `T`.
macro_rules! dispatch_load {
    ($self:ident, $storage:ident, $idx:ident, $field:ident; $($tag:ident => $ty:ty),* $(,)?) => {
        match RS::FIELDS[$field].ty {
            $(ScalarType::$tag => {
                let stored: $ty = $self.inner.load($storage, $idx, $field);
                convert_scalar(stored)
            })*
        }
    };
}

/// Convert `v` to the storage scalar type and dispatch a typed inner store.
macro_rules! dispatch_store {
    ($self:ident, $storage:ident, $idx:ident, $field:ident, $v:ident; $($tag:ident => $ty:ty),* $(,)?) => {
        match RS::FIELDS[$field].ty {
            $(ScalarType::$tag => {
                let stored: $ty = convert_scalar($v);
                $self.inner.store($storage, $idx, $field, stored)
            })*
        }
    };
}

impl<R: RecordDim, RS: RecordDim, M: MemoryAccess<RS>> MemoryAccess<R> for ChangeType<R, RS, M> {
    #[inline(always)]
    fn load<T: Scalar, S: BlobStorage>(&self, storage: &S, idx: &[usize], field: usize) -> T {
        debug_assert!(R::FIELDS[field].ty.same(T::TYPE));
        dispatch_load!(self, storage, idx, field;
            F32 => f32, F64 => f64,
            I8 => i8, I16 => i16, I32 => i32, I64 => i64,
            U8 => u8, U16 => u16, U32 => u32, U64 => u64,
            Bool => bool, F16 => F16, Bf16 => Bf16,
        )
    }

    #[inline(always)]
    fn store<T: Scalar, S: BlobStorage>(&self, storage: &mut S, idx: &[usize], field: usize, v: T) {
        debug_assert!(R::FIELDS[field].ty.same(T::TYPE));
        dispatch_store!(self, storage, idx, field, v;
            F32 => f32, F64 => f64,
            I8 => i8, I16 => i16, I32 => i32, I64 => i64,
            U8 => u8, U16 => u16, U32 => u32, U64 => u64,
            Bool => bool, F16 => F16, Bf16 => Bf16,
        )
    }
}

impl<R: RecordDim, RS: RecordDim, M: MemoryAccess<RS>> SimdAccess<R> for ChangeType<R, RS, M> {}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::blob::{alloc_view, HeapAlloc};
    use crate::extents::Dyn;
    use crate::mapping::aos::AoS;
    use crate::mapping::soa::SoA;

    crate::record! {
        pub struct P, mod p {
            pos: { x: f64, y: f64 },
            count: i64,
        }
    }

    crate::record! {
        pub struct Pf32, mod _pf32 {
            pos: { x: f32, y: f32 },
            count: i32,
        }
    }

    crate::record! {
        pub struct Pbf16, mod _pbf16 {
            pos: { x: Bf16, y: Bf16 },
            count: i16,
        }
    }

    #[test]
    fn f64_stored_as_f32() {
        let inner = SoA::<Pf32, _>::new((Dyn(8u32),));
        let mut v = alloc_view(ChangeType::<P, Pf32, _>::new(inner), &HeapAlloc);
        v.set(&[1], p::pos::x, 2.5f64);
        v.set(&[1], p::count, -9i64);
        assert_eq!(v.get::<f64, _>(&[1], p::pos::x), 2.5);
        assert_eq!(v.get::<i64, _>(&[1], p::count), -9);
        // storage is f32-sized
        assert_eq!(v.storage().total_bytes(), 8 * (4 + 4 + 4));
    }

    #[test]
    fn f64_stored_as_bf16() {
        let inner = AoS::<Pbf16, _>::new((Dyn(8u32),));
        let mut v = alloc_view(ChangeType::<P, Pbf16, _>::new(inner), &HeapAlloc);
        v.set(&[0], p::pos::y, 1.0f64);
        assert_eq!(v.get::<f64, _>(&[0], p::pos::y), 1.0); // exact in bf16
        v.set(&[0], p::pos::x, 3.14159f64);
        let loaded = v.get::<f64, _>(&[0], p::pos::x);
        assert!((loaded - 3.14159).abs() < 0.02, "bf16 precision: {loaded}");
        // storage is 2+2+2 bytes per record
        assert_eq!(v.storage().total_bytes(), 8 * 6);
    }

    #[test]
    fn precision_loss_is_bounded() {
        let inner = SoA::<Pf32, _>::new((Dyn(4u32),));
        let mut v = alloc_view(ChangeType::<P, Pf32, _>::new(inner), &HeapAlloc);
        let x = 1.0 + 1e-12; // not representable in f32
        v.set(&[0], p::pos::x, x);
        let back = v.get::<f64, _>(&[0], p::pos::x);
        assert_eq!(back, 1.0); // rounded to f32
    }

    #[test]
    fn integral_conversion_is_exact_in_range() {
        let inner = SoA::<Pf32, _>::new((Dyn(4u32),));
        let mut v = alloc_view(ChangeType::<P, Pf32, _>::new(inner), &HeapAlloc);
        v.set(&[2], p::count, i64::from(i32::MAX));
        assert_eq!(v.get::<i64, _>(&[2], p::count), i64::from(i32::MAX));
    }
}
