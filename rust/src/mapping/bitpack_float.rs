//! `BitpackFloatSoA`: floats stored with arbitrary exponent/mantissa bits (§3).
//!
//! The user chooses the exponent and mantissa bit counts per value;
//! values are repacked on store and unpacked on load, bit-packed SoA like
//! [`crate::mapping::bitpack_int`]. ISO/IEC 60559 (IEEE 754) semantics are
//! preserved as best as possible (paper footnote 5):
//!
//! - NaNs and INFs are handled correctly,
//! - overflow during packing maps to INF,
//! - NaN cannot be represented at zero mantissa bits (so `MAN >= 1` when
//!   NaN round-tripping matters),
//! - at least one exponent bit is required to distinguish ordinary values
//!   from INF (asserted at construction),
//! - subnormals are packed/unpacked exactly, with round-to-nearest-even.
//!
//! The same pack/unpack primitives implement [`crate::record::F16`]
//! (e=5, m=10) and power the Pallas `bitpack` kernel oracle
//! (`python/compile/kernels/ref.py`), keeping L1 and L3 bit-identical.

use std::marker::PhantomData;

use crate::blob::BlobStorage;
use crate::extents::{Extents, Linearizer, RowMajor};
use crate::mapping::bitpack_int::{bit_window, packed_blob_size, read_bits, write_bits};
use crate::mapping::{Mapping, MemoryAccess, SimdAccess};
use crate::record::{RecordDim, Scalar};

/// Round-to-nearest-even of `sig` dropping the low `drop` bits.
#[inline]
fn rtne(sig: u64, drop: u32) -> u64 {
    if drop == 0 {
        return sig;
    }
    if drop > 63 {
        return 0;
    }
    let base = sig >> drop;
    let rem = sig & ((1u64 << drop) - 1);
    let half = 1u64 << (drop - 1);
    if rem > half || (rem == half && base & 1 == 1) {
        base + 1
    } else {
        base
    }
}

/// Pack an `f64` into a custom float format: 1 sign bit, `exp_bits`
/// exponent bits (biased), `man_bits` mantissa bits. Returns the packed
/// value in the low `1 + exp_bits + man_bits` bits.
pub fn pack_float_bits(v: f64, exp_bits: u32, man_bits: u32) -> u64 {
    assert!(exp_bits >= 1 && exp_bits <= 11, "exp_bits must be 1..=11");
    assert!(man_bits <= 52, "man_bits must be <= 52");
    let total = 1 + exp_bits + man_bits;
    debug_assert!(total <= 64);

    let bits = v.to_bits();
    let sign = bits >> 63;
    let exp_f64 = ((bits >> 52) & 0x7ff) as i64;
    let man_f64 = bits & ((1u64 << 52) - 1);

    let max_exp_t: u64 = (1u64 << exp_bits) - 1;
    let bias_t: i64 = (1i64 << (exp_bits - 1)) - 1;
    let sign_shifted = sign << (total - 1);

    // Specials.
    if exp_f64 == 0x7ff {
        if man_f64 == 0 {
            // INF: exponent all ones, mantissa zero.
            return sign_shifted | (max_exp_t << man_bits);
        }
        // NaN: exponent all ones, mantissa nonzero (needs man_bits >= 1;
        // at zero mantissa bits NaN degenerates to INF, per the paper).
        let payload = if man_bits == 0 { 0 } else { 1 };
        return sign_shifted | (max_exp_t << man_bits) | payload;
    }

    // Zero (and f64 values so small they have no set bits at all).
    if exp_f64 == 0 && man_f64 == 0 {
        return sign_shifted;
    }

    // Normalize to (unbiased exponent, 53-bit significand with implicit bit).
    let (unbiased, sig53) = if exp_f64 == 0 {
        // f64 subnormal: value = man * 2^-1074. Normalize.
        let lz = man_f64.leading_zeros() as i64 - 11; // bits above position 52
        let sig = man_f64 << (lz + 1);
        (-1022 - (lz + 1), (sig | (1u64 << 52)) & ((1u64 << 53) - 1))
    } else {
        (exp_f64 - 1023, (1u64 << 52) | man_f64)
    };

    // Target exponent; subnormalize if below the normal range.
    let mut et = unbiased + bias_t;
    let mut drop = 52 - man_bits as i64;
    if et <= 0 {
        drop += 1 - et;
        et = 0;
    }
    if drop > 53 {
        // All bits shifted out: underflow to signed zero.
        return sign_shifted;
    }
    let mut rounded = rtne(sig53, drop as u32);

    // Rounding may carry: normal -> next exponent; subnormal -> normal.
    let width = man_bits + 1; // significand width incl. implicit bit
    if et > 0 {
        if rounded >> (width - 1) >= 2 {
            rounded >>= 1;
            et += 1;
        }
    } else if rounded >> man_bits >= 1 {
        // Subnormal rounded up into the normal range (implicit bit now
        // carried by the exponent field).
        return sign_shifted | (1u64 << man_bits) | (rounded & ((1u64 << man_bits) - 1));
    }

    if (et as u64) >= max_exp_t {
        // Overflow -> INF (paper footnote 5).
        return sign_shifted | (max_exp_t << man_bits);
    }

    let mt = rounded & ((1u64 << man_bits) - 1);
    sign_shifted | ((et as u64) << man_bits) | mt
}

/// Unpack a custom-format float (see [`pack_float_bits`]) to `f64`
/// (exact: every representable custom value fits in f64 for
/// `exp_bits <= 11`, `man_bits <= 52`).
pub fn unpack_float_bits(packed: u64, exp_bits: u32, man_bits: u32) -> f64 {
    let total = 1 + exp_bits + man_bits;
    let sign = (packed >> (total - 1)) & 1;
    let et = (packed >> man_bits) & ((1u64 << exp_bits) - 1);
    let mt = packed & ((1u64 << man_bits) - 1);

    let max_exp_t: u64 = (1u64 << exp_bits) - 1;
    let bias_t: i64 = (1i64 << (exp_bits - 1)) - 1;

    let mag = if et == max_exp_t {
        if mt == 0 {
            f64::INFINITY
        } else {
            f64::NAN
        }
    } else if et == 0 {
        // Subnormal: mt * 2^(1 - bias - man_bits)
        (mt as f64) * (2f64).powi((1 - bias_t - man_bits as i64) as i32)
    } else {
        let frac = 1.0 + (mt as f64) / (1u64 << man_bits) as f64;
        frac * (2f64).powi((et as i64 - bias_t) as i32)
    };
    if sign == 1 {
        -mag
    } else {
        mag
    }
}

/// Bit-packed SoA float mapping with `EXP` exponent and `MAN` mantissa
/// bits per value (plus one sign bit).
///
/// ```
/// use llama::prelude::*;
/// llama::record! { pub struct V, mod v { e: f64 } }
/// // 16-bit custom floats: 1+8+7 = bfloat16-shaped storage for f64 fields.
/// let mut view = alloc_view(BitpackFloatSoA::<V, _, 8, 7>::new((Dyn(32u32),)), &HeapAlloc);
/// view.set(&[0], v::e, 1.5f64);
/// assert_eq!(view.get::<f64, _>(&[0], v::e), 1.5);
/// ```
#[derive(Clone, Copy, Debug, Default)]
pub struct BitpackFloatSoA<R, E, const EXP: u32, const MAN: u32, L = RowMajor> {
    extents: E,
    _pd: PhantomData<(R, L)>,
}

impl<R: RecordDim, E: Extents, const EXP: u32, const MAN: u32, L: Linearizer>
    BitpackFloatSoA<R, E, EXP, MAN, L>
{
    /// Total bits per stored value.
    pub const VALUE_BITS: u32 = 1 + EXP + MAN;

    /// Mapping over `extents`. Panics if a field is not floating-point or
    /// the bit counts are out of range.
    pub fn new(extents: E) -> Self {
        assert!(EXP >= 1, "at least one exponent bit is needed (paper footnote 5)");
        assert!(EXP <= 11 && MAN <= 52);
        for f in R::FIELDS {
            assert!(
                f.ty.is_float(),
                "BitpackFloatSoA requires float fields; {} is {:?}",
                f.path.join("."),
                f.ty
            );
        }
        BitpackFloatSoA { extents, _pd: PhantomData }
    }
}

impl<R: RecordDim, E: Extents, const EXP: u32, const MAN: u32, L: Linearizer> Mapping<R>
    for BitpackFloatSoA<R, E, EXP, MAN, L>
{
    type Extents = E;
    const BLOB_COUNT: usize = R::FIELDS.len();

    #[inline(always)]
    fn extents(&self) -> &E {
        &self.extents
    }

    #[inline(always)]
    fn blob_size(&self, _i: usize) -> usize {
        packed_blob_size(self.extents.count(), Self::VALUE_BITS)
    }

    fn fingerprint(&self) -> String {
        format!("BitpackFloatSoA<{},e{EXP}m{MAN},{}>", R::NAME, L::NAME)
    }

    #[inline(always)]
    unsafe fn shard_bounds(&self, lin: usize) -> Option<usize> {
        // Same argument as `BitpackIntSoA`: byte-aligned splits of the
        // packed stream are disjoint under the row-major linearizer.
        if !L::LAST_DIM_CONTIGUOUS {
            return None;
        }
        Some(crate::mapping::bitpack_int::byte_aligned_shard_bound(lin, Self::VALUE_BITS))
    }
}

impl<R: RecordDim, E: Extents, const EXP: u32, const MAN: u32, L: Linearizer> MemoryAccess<R>
    for BitpackFloatSoA<R, E, EXP, MAN, L>
{
    #[inline(always)]
    fn load<T: Scalar, S: BlobStorage>(&self, storage: &S, idx: &[usize], field: usize) -> T {
        let lin = L::linearize(&self.extents, idx);
        let bits = Self::VALUE_BITS;
        let (byte, shift, win) = bit_window(storage.blob_len(field), lin * bits as usize, bits);
        let raw = read_bits(storage.bytes(field, byte, win), shift, bits);
        T::from_f64(unpack_float_bits(raw, EXP, MAN))
    }

    #[inline(always)]
    fn store<T: Scalar, S: BlobStorage>(&self, storage: &mut S, idx: &[usize], field: usize, v: T) {
        let lin = L::linearize(&self.extents, idx);
        let bits = Self::VALUE_BITS;
        let raw = pack_float_bits(v.as_f64(), EXP, MAN);
        let (byte, shift, win) = bit_window(storage.blob_len(field), lin * bits as usize, bits);
        write_bits(storage.bytes_mut(field, byte, win), shift, bits, raw);
    }
}

impl<R: RecordDim, E: Extents, const EXP: u32, const MAN: u32, L: Linearizer> SimdAccess<R>
    for BitpackFloatSoA<R, E, EXP, MAN, L>
{
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::blob::{alloc_view, HeapAlloc};
    use crate::extents::Dyn;

    #[test]
    fn pack_unpack_f32_exact() {
        // e=8, m=23 is exactly binary32: round-trips every f32.
        for v in [0.0f32, -0.0, 1.0, -1.5, 3.14159, 1e30, 1e-30, f32::MIN_POSITIVE] {
            let p = pack_float_bits(v as f64, 8, 23);
            let u = unpack_float_bits(p, 8, 23) as f32;
            assert_eq!(u.to_bits(), v.to_bits(), "{v}");
        }
    }

    #[test]
    fn f32_subnormals_exact() {
        let sub = f32::from_bits(0x0000_0001); // smallest subnormal
        let p = pack_float_bits(sub as f64, 8, 23);
        assert_eq!(unpack_float_bits(p, 8, 23) as f32, sub);
        let sub2 = f32::from_bits(0x007f_ffff); // largest subnormal
        let p2 = pack_float_bits(sub2 as f64, 8, 23);
        assert_eq!(unpack_float_bits(p2, 8, 23) as f32, sub2);
    }

    #[test]
    fn specials() {
        // INF round-trips.
        let p = pack_float_bits(f64::INFINITY, 5, 10);
        assert_eq!(unpack_float_bits(p, 5, 10), f64::INFINITY);
        let p = pack_float_bits(f64::NEG_INFINITY, 5, 10);
        assert_eq!(unpack_float_bits(p, 5, 10), f64::NEG_INFINITY);
        // NaN round-trips when man_bits >= 1.
        let p = pack_float_bits(f64::NAN, 5, 10);
        assert!(unpack_float_bits(p, 5, 10).is_nan());
        // NaN at zero mantissa bits degenerates to INF (paper footnote 5).
        let p = pack_float_bits(f64::NAN, 5, 0);
        assert!(unpack_float_bits(p, 5, 0).is_infinite());
        // Overflow packs to INF.
        let p = pack_float_bits(1e300, 5, 10);
        assert_eq!(unpack_float_bits(p, 5, 10), f64::INFINITY);
        // Underflow packs to (signed) zero.
        let p = pack_float_bits(-1e-300, 5, 10);
        let u = unpack_float_bits(p, 5, 10);
        assert_eq!(u, 0.0);
        assert!(u.is_sign_negative());
    }

    #[test]
    fn round_to_nearest_even() {
        // With m=2, significands are x.00 x.01 x.10 x.11: 1.125 is halfway
        // between 1.00 and 1.25 -> rounds to even (1.00).
        let p = pack_float_bits(1.125, 8, 2);
        assert_eq!(unpack_float_bits(p, 8, 2), 1.0);
        // 1.375 halfway between 1.25 and 1.5 -> rounds to even (1.5).
        let p = pack_float_bits(1.375, 8, 2);
        assert_eq!(unpack_float_bits(p, 8, 2), 1.5);
    }

    #[test]
    fn carry_into_exponent() {
        // 1.9999... with m=2 rounds up to 2.0 (mantissa carry).
        let p = pack_float_bits(1.99, 8, 2);
        assert_eq!(unpack_float_bits(p, 8, 2), 2.0);
        // Largest normal rounds up -> INF.
        // e=5,m=2: max normal = 1.75 * 2^15; 1.99*2^15 rounds to 2*2^15 -> INF
        let p = pack_float_bits(1.99 * 32768.0, 5, 2);
        assert_eq!(unpack_float_bits(p, 5, 2), f64::INFINITY);
    }

    #[test]
    fn half_precision_reference_values() {
        // Known binary16 encodings (e=5, m=10).
        assert_eq!(pack_float_bits(1.0, 5, 10), 0x3C00);
        assert_eq!(pack_float_bits(-2.0, 5, 10), 0xC000);
        assert_eq!(pack_float_bits(65504.0, 5, 10), 0x7BFF); // max half
        assert_eq!(pack_float_bits(6.103515625e-5, 5, 10), 0x0400); // min normal
        assert_eq!(pack_float_bits(5.960464477539063e-8, 5, 10), 0x0001); // min subnormal
        assert_eq!(unpack_float_bits(0x3555, 5, 10), 0.333251953125); // ~1/3
    }

    crate::record! {
        pub struct Vec2, mod vec2 {
            x: f64,
            y: f32,
        }
    }

    #[test]
    fn view_roundtrip_mixed_precision() {
        let mut v =
            alloc_view(BitpackFloatSoA::<Vec2, _, 8, 23>::new((Dyn(64u32),)), &HeapAlloc);
        for i in 0..64usize {
            v.set(&[i], vec2::x, i as f64 * 0.25);
            v.set(&[i], vec2::y, -(i as f32) * 0.5);
        }
        for i in 0..64usize {
            // f64 through e8m23 loses precision to f32 granularity — exact
            // here because quarters are representable.
            assert_eq!(v.get::<f64, _>(&[i], vec2::x), i as f64 * 0.25);
            assert_eq!(v.get::<f32, _>(&[i], vec2::y), -(i as f32) * 0.5);
        }
    }

    #[test]
    fn storage_is_bit_exactly_sized() {
        let m = BitpackFloatSoA::<Vec2, _, 5, 10>::new((Dyn(100u32),));
        // 16 bits * 100 = 200 bytes payload + 8 slack
        assert_eq!(m.blob_size(0), 208);
    }

    #[test]
    fn exhaustive_e4m3_roundtrip() {
        // Every finite e4m3 value must round-trip pack(unpack(x)) == x.
        for raw in 0u64..256 {
            let v = unpack_float_bits(raw, 4, 3);
            if v.is_nan() {
                continue;
            }
            let repacked = pack_float_bits(v, 4, 3);
            assert_eq!(repacked, raw, "raw={raw:#x} v={v}");
        }
    }
}
