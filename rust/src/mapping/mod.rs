//! Memory mappings: the exchangeable rule locating every scalar in blobs.
//!
//! A mapping consumes the record dimension ([`crate::record::RecordDim`])
//! and array extents ([`crate::extents::Extents`]) and decides (a) how many
//! byte blobs the view needs and how big they are, and (b) where each
//! `(array index, field)` pair lives — either as a *physical* byte location
//! ([`PhysicalMapping`]) or as a *computed* value materialized on access
//! (bit-packed, type-changed, byte-split, discarded, counted...), the
//! paper's "support for computations during memory access".
//!
//! | Paper mapping (§3/§4) | Module |
//! |---|---|
//! | AoS (packed/aligned, field (re)order) | [`aos`] |
//! | SoA (single-blob / multi-blob) | [`soa`] |
//! | AoSoA (inner lane count) | [`aosoa`] |
//! | One (single record, for caches) | [`one`] |
//! | BitpackIntSoA | [`bitpack_int`] |
//! | BitpackFloatSoA | [`bitpack_float`] |
//! | Changetype | [`changetype`] |
//! | Bytesplit | [`bytesplit`] |
//! | Null | [`null`] |
//! | Split | [`split`] |
//! | Trace / FieldAccessCount | [`field_access_count`] |
//! | Heatmap | [`heatmap`] |

pub mod aos;
pub mod aosoa;
pub mod bitpack_float;
pub mod bitpack_int;
pub mod bytesplit;
pub mod changetype;
pub mod field_access_count;
pub mod heatmap;
pub mod null;
pub mod one;
pub mod soa;
pub mod split;

use crate::blob::BlobStorage;
use crate::extents::Extents;
use crate::record::{FieldIndex, RecordDim, Scalar};
use crate::simd::{Simd, SimdElem};

/// A subset of the record dimension's fields as a bitmask (field `i` ⇔ bit
/// `i`). Lets [`split::Split`] and cache views map only part of a record
/// (§3 Null: "a view acting as a cache ... that only works on a subset of
/// the record dimension").
#[derive(Clone, Copy, PartialEq, Eq, Debug, Hash)]
pub struct FieldMask(pub u64);

impl FieldMask {
    /// All fields selected.
    pub const ALL: FieldMask = FieldMask(u64::MAX);

    /// Mask with exactly the fields of `sel` set.
    pub const fn from_selection(sel: crate::record::Selection) -> Self {
        let mut m = 0u64;
        let mut i = sel.start;
        while i < sel.start + sel.len {
            m |= 1 << i;
            i += 1;
        }
        FieldMask(m)
    }

    /// Whether field `f` is in the mask.
    #[inline(always)]
    pub const fn contains(self, f: usize) -> bool {
        f < 64 && (self.0 >> f) & 1 == 1
    }

    /// Complement within the first `n` fields.
    pub const fn complement(self, n: usize) -> Self {
        let all = if n >= 64 { u64::MAX } else { (1u64 << n) - 1 };
        FieldMask(!self.0 & all)
    }

    /// Number of selected fields among the first `n`.
    pub const fn count(self, n: usize) -> usize {
        let all = if n >= 64 { u64::MAX } else { (1u64 << n) - 1 };
        (self.0 & all).count_ones() as usize
    }
}

impl Default for FieldMask {
    fn default() -> Self {
        FieldMask::ALL
    }
}

/// A maximal run of one field's values stored as consecutive bytes,
/// starting at a given linear record index — the currency of the bulk
/// traversal engine ([`crate::view::View::transform_simd`]) and of the
/// run-based copy strategy ([`crate::copy`]).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct FieldRun {
    /// Blob holding the run.
    pub blob: usize,
    /// Byte offset of the run's first value within the blob.
    pub offset: usize,
    /// Number of consecutive records covered (≥ 1).
    pub len: usize,
}

/// Core mapping interface: blob inventory + extents.
pub trait Mapping<R: RecordDim>: Clone + Send + Sync {
    /// The array-extents type (carries rank, static extents, index type).
    type Extents: Extents;
    /// Number of blobs this mapping distributes data over.
    const BLOB_COUNT: usize;

    /// The array extents of the view.
    fn extents(&self) -> &Self::Extents;
    /// Required byte size of blob `i < Self::BLOB_COUNT`.
    fn blob_size(&self, i: usize) -> usize;

    /// A string identifying layout-relevant parameters; two views whose
    /// mappings have equal fingerprints are bytewise-identical layouts
    /// (used by [`crate::copy`] for the blob-memcpy fast path).
    fn fingerprint(&self) -> String;

    /// Where (and for how many records) `field`'s values are stored as
    /// consecutive bytes starting at *linear* record index `lin`, or
    /// `None` if this mapping has no byte-contiguity for the field
    /// (AoS interleaving, computed mappings, instrumented wrappers —
    /// which must keep the scalar path so side effects still fire).
    ///
    /// Contiguous layouts override: SoA returns the remainder of the
    /// field's array, AoSoA the remainder of the current lane block.
    #[inline(always)]
    fn contiguous_run(&self, lin: usize, field: usize) -> Option<FieldRun> {
        let _ = (lin, field);
        None
    }

    /// [`contiguous_run`](Mapping::contiguous_run) accepting either a raw
    /// field index or a typed field tag from [`crate::record!`]
    /// ([`FieldIndex`]) — call sites name the field (`p::mass`) instead of
    /// spelling `p::mass.i()`.
    #[inline(always)]
    fn contiguous_run_t<F: FieldIndex>(&self, lin: usize, field: F) -> Option<FieldRun> {
        self.contiguous_run(lin, field.field_index())
    }

    /// Largest record index `b <= lin` at which the row-major traversal
    /// order may be split for concurrent access, or `None` if this mapping
    /// cannot prove any split safe.
    ///
    /// This is the safety proof carried by the parallel sharded traversal
    /// ([`crate::shard::ViewShards`]) and the run-based parallel copy
    /// ([`crate::copy::copy_view_par`]), analogous to how
    /// [`contiguous_run`] carries the vectorization proof: `Some(b)`
    /// asserts that every storage byte written through records with
    /// traversal position `< b` is disjoint from every byte *touched*
    /// through records `>= b` (and vice versa), and that any side-effect
    /// state shared across the split (instrumentation counters) is
    /// thread-safe. `lin` may be **any** linear (row-major) record index —
    /// the traversal splitter passes outermost-dimension row boundaries,
    /// the parallel copy arbitrary positions; callers re-validate after
    /// rounding, so implementations may return any safe `b <= lin` (with
    /// `shard_bounds(0) == Some(0)` for every shardable mapping).
    ///
    /// The conservative default refuses; mappings override with their
    /// proof: per-record byte disjointness lets the physical layouts and
    /// `Bytesplit` accept any boundary, the bit-packed layouts round down
    /// to a byte-aligned value boundary, wrappers delegate, and `One`
    /// (all indices alias one record) keeps the default `None`.
    ///
    /// # Safety
    ///
    /// The method is `unsafe` because the *implementation* carries an
    /// obligation (like `GlobalAlloc`): the parallel engine trusts a
    /// `Some(b)` for memory safety, so an override that asserts
    /// disjointness a layout does not have makes safe callers race.
    /// Callers have no preconditions. Only override with a boundary you
    /// can prove disjoint; when in doubt keep the default `None` (the
    /// engine then traverses serially).
    ///
    /// [`contiguous_run`]: Mapping::contiguous_run
    #[inline(always)]
    unsafe fn shard_bounds(&self, lin: usize) -> Option<usize> {
        let _ = lin;
        None
    }
}

/// A mapping whose every field location is a plain byte address
/// `(blob number, byte offset)` — AoS, SoA, AoSoA, One.
///
/// Instrumentation ([`heatmap::Heatmap`]) and the blanket load/store
/// helpers build on this.
pub trait PhysicalMapping<R: RecordDim>: Mapping<R> {
    /// Locate `(idx, field)`; `idx.len() == RANK`.
    fn blob_nr_and_offset(&self, idx: &[usize], field: usize) -> (usize, usize);

    /// [`blob_nr_and_offset`](PhysicalMapping::blob_nr_and_offset)
    /// accepting either a raw field index or a typed field tag
    /// ([`FieldIndex`]).
    #[inline(always)]
    fn blob_nr_and_offset_t<F: FieldIndex>(&self, idx: &[usize], field: F) -> (usize, usize) {
        self.blob_nr_and_offset(idx, field.field_index())
    }
}

/// A mapping type whose field coverage is a compile-time constant mask:
/// the maskable physical layouts ([`aos::AoS`], [`soa::SoA`],
/// [`aosoa::AoSoA`] carry a `const MASK: u64` parameter) and the
/// mask-oblivious [`null::NullMapping`] (which accepts every field).
///
/// This is the evidence [`split::Split::new_typed`] consumes to prove at
/// compile time that the fields routed to each half of a split are
/// actually mapped by it.
pub trait StaticMask {
    /// Fields this mapping type stores (bit `i` ⇔ flattened field `i`).
    const FIELD_MASK: u64;
}

/// Uniform scalar access through a mapping: the trait `View` talks to.
///
/// Physical mappings implement this via [`impl_memory_access_via_physical!`];
/// computed mappings implement it directly (pack/unpack, convert, count...).
///
/// The mapping layer deliberately stays on the erased `(idx: &[usize],
/// field: usize)` currency: 13 mapping implementations dispatch on runtime
/// metadata anyway, and the typed layer above
/// ([`crate::view::View::get_t`] and friends) resolves tags to constant
/// field indices and const-rank indices to slices *before* calling down,
/// so the generic bounds here never need the tag path. Type agreement is
/// debug-asserted against `R::FIELDS` ([`physical_load`]); the typed API
/// makes those asserts unreachable by construction.
pub trait MemoryAccess<R: RecordDim>: Mapping<R> {
    /// Load the scalar at `(idx, field)` as `T`.
    ///
    /// `T` must match the field's scalar type for physical mappings
    /// (debug-asserted); computed mappings define their own conversion.
    fn load<T: Scalar, S: BlobStorage>(&self, storage: &S, idx: &[usize], field: usize) -> T;

    /// Store the scalar at `(idx, field)`.
    fn store<T: Scalar, S: BlobStorage>(&self, storage: &mut S, idx: &[usize], field: usize, v: T);
}

/// Vector access through a mapping (§5): load/store `N` consecutive records'
/// worth of one field, vectorized where the layout allows.
///
/// The default implementations walk the SIMD axis (the last array dimension)
/// with scalar accesses — correct for every mapping. Contiguous layouts
/// (SoA, AoSoA within a lane block) override with slice copies that compile
/// to vector moves; AoS deliberately keeps the scalar walk, mirroring the
/// paper's observation that scalar loads beat `gather` on the tested CPU.
pub trait SimdAccess<R: RecordDim>: MemoryAccess<R> {
    /// Load `N` lanes of `field` starting at `idx` along the last dimension.
    #[inline]
    fn load_simd<T: Scalar + SimdElem, S: BlobStorage, const N: usize>(
        &self,
        storage: &S,
        idx: &[usize],
        field: usize,
    ) -> Simd<T, N> {
        let mut out = Simd::<T, N>::default();
        if idx.len() == 1 {
            // Rank-1 fast path (§Perf).
            for k in 0..N {
                out.0[k] = self.load(storage, &[idx[0] + k], field);
            }
            return out;
        }
        let mut idx_k = [0usize; crate::view::MAX_RANK];
        idx_k[..idx.len()].copy_from_slice(idx);
        let last = idx.len() - 1;
        for k in 0..N {
            idx_k[last] = idx[last] + k;
            out.0[k] = self.load(storage, &idx_k[..idx.len()], field);
        }
        out
    }

    /// Store `N` lanes of `field` starting at `idx` along the last dimension.
    #[inline]
    fn store_simd<T: Scalar + SimdElem, S: BlobStorage, const N: usize>(
        &self,
        storage: &mut S,
        idx: &[usize],
        field: usize,
        v: Simd<T, N>,
    ) {
        if idx.len() == 1 {
            for k in 0..N {
                self.store(storage, &[idx[0] + k], field, v.0[k]);
            }
            return;
        }
        let mut idx_k = [0usize; crate::view::MAX_RANK];
        idx_k[..idx.len()].copy_from_slice(idx);
        let last = idx.len() - 1;
        for k in 0..N {
            idx_k[last] = idx[last] + k;
            self.store(storage, &idx_k[..idx.len()], field, v.0[k]);
        }
    }
}

// ---------------------------------------------------------------------------
// Physical load/store helpers
// ---------------------------------------------------------------------------

/// Load a `T` from `blob` at byte offset `off` (little-endian; compiles to
/// one unaligned move for the arithmetic scalars).
///
/// §Perf: the arithmetic scalars use a raw unaligned read after one bounds
/// check — the `read_le`/`try_into` chain left LLVM with panic paths in
/// the n-body hot loop. `bool` keeps the byte-compare path (reading an
/// arbitrary byte as `bool` would be UB).
#[inline(always)]
pub fn load_scalar<T: Scalar>(blob: &[u8], off: usize) -> T {
    if T::TYPE.same(crate::record::ScalarType::Bool) {
        return T::read_le(&blob[off..off + T::SIZE]);
    }
    assert!(off + T::SIZE <= blob.len(), "scalar load out of bounds");
    // SAFETY: bounds just checked; T is a plain-old-data scalar (non-bool
    // branch) for which any bit pattern is valid; unaligned read is allowed
    // by read_unaligned.
    unsafe { (blob.as_ptr().add(off) as *const T).read_unaligned() }
}

/// Store a `T` into `blob` at byte offset `off`.
#[inline(always)]
pub fn store_scalar<T: Scalar>(blob: &mut [u8], off: usize, v: T) {
    if T::TYPE.same(crate::record::ScalarType::Bool) {
        v.write_le(&mut blob[off..off + T::SIZE]);
        return;
    }
    assert!(off + T::SIZE <= blob.len(), "scalar store out of bounds");
    // SAFETY: bounds just checked; see load_scalar.
    unsafe { (blob.as_mut_ptr().add(off) as *mut T).write_unaligned(v) }
}

/// Typed load through a [`PhysicalMapping`].
///
/// Byte-exact: materializes a reference over only the scalar's `T::SIZE`
/// bytes (never the whole blob), so the same monomorphization is sound on
/// the shard-worker storage ([`crate::blob::ShardBlobs`]) where other
/// threads concurrently access disjoint windows of the same blob.
#[inline(always)]
pub fn physical_load<R, M, T, S>(m: &M, storage: &S, idx: &[usize], field: usize) -> T
where
    R: RecordDim,
    M: PhysicalMapping<R>,
    T: Scalar,
    S: BlobStorage,
{
    debug_assert!(
        R::FIELDS[field].ty.same(T::TYPE),
        "field {} of {} is {:?}, accessed as {:?}",
        field,
        R::NAME,
        R::FIELDS[field].ty,
        T::TYPE
    );
    let (blob, off) = m.blob_nr_and_offset(idx, field);
    load_scalar(storage.bytes(blob, off, T::SIZE), 0)
}

/// Typed store through a [`PhysicalMapping`] (byte-exact; see
/// [`physical_load`]).
#[inline(always)]
pub fn physical_store<R, M, T, S>(m: &M, storage: &mut S, idx: &[usize], field: usize, v: T)
where
    R: RecordDim,
    M: PhysicalMapping<R>,
    T: Scalar,
    S: BlobStorage,
{
    debug_assert!(R::FIELDS[field].ty.same(T::TYPE));
    let (blob, off) = m.blob_nr_and_offset(idx, field);
    store_scalar(storage.bytes_mut(blob, off, T::SIZE), 0, v)
}

/// Implement [`MemoryAccess`] for a [`PhysicalMapping`] by plain byte access.
/// (A blanket impl would forbid computed mappings from implementing
/// [`MemoryAccess`] themselves under coherence rules.)
#[macro_export]
macro_rules! impl_memory_access_via_physical {
    ($ty:ident < R $(, $gen:ident $(: $bound:path)?)* >) => {
        impl<R: $crate::record::RecordDim $(, $gen $(: $bound)?)*>
            $crate::mapping::MemoryAccess<R> for $ty<R $(, $gen)*>
        where
            Self: $crate::mapping::PhysicalMapping<R>,
        {
            #[inline(always)]
            fn load<T: $crate::record::Scalar, S: $crate::blob::BlobStorage>(
                &self,
                storage: &S,
                idx: &[usize],
                field: usize,
            ) -> T {
                $crate::mapping::physical_load::<R, _, T, S>(self, storage, idx, field)
            }

            #[inline(always)]
            fn store<T: $crate::record::Scalar, S: $crate::blob::BlobStorage>(
                &self,
                storage: &mut S,
                idx: &[usize],
                field: usize,
                v: T,
            ) {
                $crate::mapping::physical_store::<R, _, T, S>(self, storage, idx, field, v)
            }
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::record::Selection;

    #[test]
    fn field_mask_ops() {
        let m = FieldMask::from_selection(Selection::new(2, 3));
        assert!(!m.contains(1));
        assert!(m.contains(2));
        assert!(m.contains(4));
        assert!(!m.contains(5));
        assert_eq!(m.count(7), 3);
        let c = m.complement(7);
        assert!(c.contains(0) && c.contains(1) && c.contains(5) && c.contains(6));
        assert!(!c.contains(3));
        assert_eq!(c.count(7), 4);
        assert_eq!(FieldMask::ALL.count(7), 7);
    }
}
