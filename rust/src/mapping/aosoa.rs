//! Array-of-Struct-of-Arrays mapping.
//!
//! Records are grouped into blocks of `LANES`; within a block each field's
//! `LANES` values are contiguous. The layout SIMD hardware wants: a vector
//! load of one field touches one cache line, while successive fields of the
//! same record stay close — LLAMA's `mapping::AoSoA<Lanes>`, the third
//! layout of the paper's Figure 3 (with its known single-loop overhead,
//! reproduced by E1).

use std::marker::PhantomData;

use crate::blob::BlobStorage;
use crate::extents::{Extents, Linearizer, RowMajor};
use crate::mapping::aos::{offsets_of, record_size_of, FieldOrderKind};
use crate::mapping::soa::{default_load_simd, default_store_simd};
use crate::mapping::{
    FieldMask, FieldRun, Mapping, MemoryAccess, PhysicalMapping, SimdAccess, StaticMask,
};
use crate::record::{RecordDim, Scalar};
use crate::simd::{Simd, SimdElem};

/// Array-of-Struct-of-Arrays with `LANES` records per block.
///
/// ```
/// use llama::prelude::*;
/// llama::record! { pub struct P, mod p { x: f32, y: f32 } }
/// let mut v = alloc_view(AoSoA::<P, _, 8>::new((Dyn(32u32),)), &HeapAlloc);
/// v.set(&[9], p::y, 3.0f32);
/// assert_eq!(v.get::<f32, _>(&[9], p::y), 3.0);
/// ```
#[derive(Clone, Copy, Debug, Default)]
pub struct AoSoA<R, E, const LANES: usize, L = RowMajor, const MASK: u64 = { u64::MAX }> {
    extents: E,
    _pd: PhantomData<(R, L)>,
}

impl<R: RecordDim, E: Extents, const LANES: usize, L: Linearizer, const MASK: u64>
    AoSoA<R, E, LANES, L, MASK>
{
    /// Mapping over `extents`.
    pub fn new(extents: E) -> Self {
        assert!(LANES > 0 && LANES.is_power_of_two(), "LANES must be a power of two");
        AoSoA { extents, _pd: PhantomData }
    }

    /// Packed record size over the masked fields (constant — §Perf).
    pub const RECORD_SIZE: usize = record_size_of(FieldOrderKind::Packed, R::FIELDS, MASK);

    /// Packed in-record offsets over the masked fields (constant LUT).
    pub const OFFSETS: [usize; crate::record::MAX_FIELDS] =
        offsets_of(FieldOrderKind::Packed, R::FIELDS, MASK);

    /// Per-field scalar sizes (constant LUT).
    pub const SIZES: [usize; crate::record::MAX_FIELDS] = crate::record::size_lut(R::FIELDS);

    /// Packed record size over the masked fields.
    #[inline(always)]
    fn record_size() -> usize {
        Self::RECORD_SIZE
    }

    /// Number of blocks needed for the extents.
    #[inline(always)]
    fn blocks(&self) -> usize {
        self.extents.count().div_ceil(LANES)
    }
}

impl<R, E, const LANES: usize, L, const MASK: u64> StaticMask for AoSoA<R, E, LANES, L, MASK> {
    const FIELD_MASK: u64 = MASK;
}

impl<R: RecordDim, E: Extents, const LANES: usize, L: Linearizer, const MASK: u64> Mapping<R>
    for AoSoA<R, E, LANES, L, MASK>
{
    type Extents = E;
    const BLOB_COUNT: usize = 1;

    #[inline(always)]
    fn extents(&self) -> &E {
        &self.extents
    }

    #[inline(always)]
    fn blob_size(&self, _i: usize) -> usize {
        self.blocks() * LANES * Self::record_size()
    }

    fn fingerprint(&self) -> String {
        format!(
            "AoSoA<{},{LANES},{},mask={MASK:x}>@{:?}",
            R::NAME,
            L::NAME,
            (0..E::RANK).map(|d| self.extents.extent(d)).collect::<Vec<_>>()
        )
    }

    #[inline(always)]
    fn contiguous_run(&self, lin: usize, field: usize) -> Option<FieldRun> {
        // Within a block, one field's LANES values are adjacent: the run
        // covers the remaining lanes of the current block (bulk engine
        // steps block by block).
        if !L::LAST_DIM_CONTIGUOUS || !FieldMask(MASK).contains(field) {
            return None;
        }
        let n = self.extents.count();
        if lin >= n {
            return None;
        }
        let block = lin / LANES;
        let lane = lin % LANES;
        let offset = block * LANES * Self::RECORD_SIZE
            + Self::OFFSETS[field] * LANES
            + lane * Self::SIZES[field];
        Some(FieldRun { blob: 0, offset, len: (LANES - lane).min(n - lin) })
    }

    #[inline(always)]
    unsafe fn shard_bounds(&self, lin: usize) -> Option<usize> {
        // Each record owns its disjoint lane slots inside its block, so
        // splitting is safe even mid-block — no rounding to LANES needed.
        Some(lin)
    }
}

impl<R: RecordDim, E: Extents, const LANES: usize, L: Linearizer, const MASK: u64>
    PhysicalMapping<R> for AoSoA<R, E, LANES, L, MASK>
{
    #[inline(always)]
    fn blob_nr_and_offset(&self, idx: &[usize], field: usize) -> (usize, usize) {
        debug_assert!(FieldMask(MASK).contains(field));
        let lin = L::linearize(&self.extents, idx);
        let block = lin / LANES;
        let lane = lin % LANES;
        let off = block * LANES * Self::RECORD_SIZE
            + Self::OFFSETS[field] * LANES
            + lane * Self::SIZES[field];
        (0, off)
    }
}

impl<R: RecordDim, E: Extents, const LANES: usize, L: Linearizer, const MASK: u64> MemoryAccess<R>
    for AoSoA<R, E, LANES, L, MASK>
{
    #[inline(always)]
    fn load<T: Scalar, S: BlobStorage>(&self, storage: &S, idx: &[usize], field: usize) -> T {
        crate::mapping::physical_load::<R, _, T, S>(self, storage, idx, field)
    }

    #[inline(always)]
    fn store<T: Scalar, S: BlobStorage>(&self, storage: &mut S, idx: &[usize], field: usize, v: T) {
        crate::mapping::physical_store::<R, _, T, S>(self, storage, idx, field, v)
    }
}

impl<R: RecordDim, E: Extents, const LANES: usize, L: Linearizer, const MASK: u64> SimdAccess<R>
    for AoSoA<R, E, LANES, L, MASK>
{
    #[inline(always)]
    fn load_simd<T: Scalar + SimdElem, S: BlobStorage, const N: usize>(
        &self,
        storage: &S,
        idx: &[usize],
        field: usize,
    ) -> Simd<T, N> {
        if L::LAST_DIM_CONTIGUOUS && N <= LANES {
            let lin = L::linearize(&self.extents, idx);
            // Contiguous only when the N lanes stay inside one block.
            if lin % LANES + N <= LANES {
                // Byte-exact window: sound on the shard-worker storage.
                let (b, off) = self.blob_nr_and_offset(idx, field);
                return Simd::from_le_bytes(storage.bytes(b, off, N * T::SIZE));
            }
        }
        default_load_simd(self, storage, idx, field)
    }

    #[inline(always)]
    fn store_simd<T: Scalar + SimdElem, S: BlobStorage, const N: usize>(
        &self,
        storage: &mut S,
        idx: &[usize],
        field: usize,
        v: Simd<T, N>,
    ) {
        if L::LAST_DIM_CONTIGUOUS && N <= LANES {
            let lin = L::linearize(&self.extents, idx);
            if lin % LANES + N <= LANES {
                let (b, off) = self.blob_nr_and_offset(idx, field);
                v.write_le_bytes(storage.bytes_mut(b, off, N * T::SIZE));
                return;
            }
        }
        default_store_simd(self, storage, idx, field, v)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::blob::{alloc_view, HeapAlloc};
    use crate::extents::Dyn;

    crate::record! {
        pub struct P, mod p {
            x: f32,
            y: f32,
            m: f64,
        }
    }

    #[test]
    fn layout() {
        // record_size = 4+4+8 = 16; LANES=4 => block = 64 bytes
        let m = AoSoA::<P, _, 4>::new((Dyn(10u32),));
        assert_eq!(m.blob_size(0), 3 * 4 * 16); // ceil(10/4)=3 blocks
        // record 5 = block 1, lane 1: field region + lane * scalar size
        assert_eq!(m.blob_nr_and_offset_t(&[5], p::x), (0, 64 + 4));
        assert_eq!(m.blob_nr_and_offset_t(&[5], p::y), (0, 64 + 16 + 4));
        assert_eq!(m.blob_nr_and_offset_t(&[5], p::m), (0, 64 + 32 + 8));
    }

    #[test]
    fn contiguous_runs_stop_at_block_edges() {
        use crate::mapping::FieldRun;
        let m = AoSoA::<P, _, 4>::new((Dyn(10u32),));
        // lane 1 of block 1 (byte 64 + 16 + 4): 3 lanes left in the block.
        assert_eq!(m.contiguous_run_t(5, p::y), Some(FieldRun { blob: 0, offset: 84, len: 3 }));
        // block start: full block available.
        assert_eq!(m.contiguous_run_t(4, p::x), Some(FieldRun { blob: 0, offset: 64, len: 4 }));
        // tail block is clipped to the extent (records 8, 9 only).
        assert_eq!(m.contiguous_run_t(8, p::x).unwrap().len, 2);
        assert_eq!(m.contiguous_run_t(10, p::x), None);
    }

    #[test]
    fn roundtrip_all_lanes() {
        let mut v = alloc_view(AoSoA::<P, _, 8>::new((Dyn(20u32),)), &HeapAlloc);
        for i in 0..20 {
            v.set(&[i], p::x, i as f32);
            v.set(&[i], p::m, -(i as f64));
        }
        for i in 0..20 {
            assert_eq!(v.get::<f32, _>(&[i], p::x), i as f32);
            assert_eq!(v.get::<f64, _>(&[i], p::m), -(i as f64));
        }
    }

    #[test]
    fn simd_within_block_is_contiguous() {
        let mut v = alloc_view(AoSoA::<P, _, 8>::new((Dyn(16u32),)), &HeapAlloc);
        for i in 0..16 {
            v.set(&[i], p::y, (10 + i) as f32);
        }
        let s: Simd<f32, 8> = v.load_simd(&[8], p::y);
        assert_eq!(s.0[0], 18.0);
        assert_eq!(s.0[7], 25.0);
        // Crossing a block boundary still works (fallback path).
        let s: Simd<f32, 4> = v.load_simd(&[6], p::y);
        assert_eq!(s.0, [16.0, 17.0, 18.0, 19.0]);
    }
}
