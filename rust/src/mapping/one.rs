//! The `One` mapping: every array index maps to the same single record.
//!
//! LLAMA's `mapping::One` — useful for broadcasting a shared record across
//! a data-parallel algorithm, and as the record side of scalar/SIMD
//! symmetry (Table 1: `SimdN<T, 1>` of a record is `One<T>`).

use std::marker::PhantomData;

use crate::blob::BlobStorage;
use crate::extents::Extents;
use crate::mapping::{Mapping, MemoryAccess, PhysicalMapping, SimdAccess};
use crate::record::{packed_offset, RecordDim, Scalar};

/// Maps all array indices onto one shared record (packed in one blob).
#[derive(Clone, Copy, Debug, Default)]
pub struct One<R, E> {
    extents: E,
    _pd: PhantomData<R>,
}

impl<R: RecordDim, E: Extents> One<R, E> {
    /// Mapping over `extents` (the extents only define the index space,
    /// not the storage — storage is always exactly one record).
    pub fn new(extents: E) -> Self {
        One { extents, _pd: PhantomData }
    }
}

impl<R: RecordDim, E: Extents> Mapping<R> for One<R, E> {
    type Extents = E;
    const BLOB_COUNT: usize = 1;

    #[inline(always)]
    fn extents(&self) -> &E {
        &self.extents
    }

    #[inline(always)]
    fn blob_size(&self, _i: usize) -> usize {
        R::PACKED_SIZE
    }

    fn fingerprint(&self) -> String {
        format!("One<{}>", R::NAME)
    }

    #[inline(always)]
    unsafe fn shard_bounds(&self, _lin: usize) -> Option<usize> {
        // Every array index aliases the same record bytes: no split of the
        // index space is byte-disjoint. The parallel engine falls back to
        // the serial traversal.
        None
    }
}

impl<R: RecordDim, E: Extents> PhysicalMapping<R> for One<R, E> {
    #[inline(always)]
    fn blob_nr_and_offset(&self, _idx: &[usize], field: usize) -> (usize, usize) {
        (0, packed_offset(R::FIELDS, field))
    }
}

impl<R: RecordDim, E: Extents> MemoryAccess<R> for One<R, E> {
    #[inline(always)]
    fn load<T: Scalar, S: BlobStorage>(&self, storage: &S, idx: &[usize], field: usize) -> T {
        crate::mapping::physical_load::<R, _, T, S>(self, storage, idx, field)
    }

    #[inline(always)]
    fn store<T: Scalar, S: BlobStorage>(&self, storage: &mut S, idx: &[usize], field: usize, v: T) {
        crate::mapping::physical_store::<R, _, T, S>(self, storage, idx, field, v)
    }
}

impl<R: RecordDim, E: Extents> SimdAccess<R> for One<R, E> {}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::blob::{alloc_view, HeapAlloc};
    use crate::extents::Dyn;

    crate::record! { pub struct P, mod p { a: f32, b: i64 } }

    #[test]
    fn all_indices_share_one_record() {
        let mut v = alloc_view(One::<P, _>::new((Dyn(100u32),)), &HeapAlloc);
        assert_eq!(v.storage().total_bytes(), 12);
        v.set(&[13], p::a, 3.5f32);
        assert_eq!(v.get::<f32, _>(&[99], p::a), 3.5);
        assert_eq!(v.get::<f32, _>(&[0], p::a), 3.5);
        v.set(&[0], p::b, -7i64);
        assert_eq!(v.get::<i64, _>(&[42], p::b), -7);
    }
}
