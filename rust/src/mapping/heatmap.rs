//! `Heatmap`: count accesses to storage bytes at configurable granularity (§4).
//!
//! "The heavyweight Heatmap counts accesses to storage bytes at a
//! configurable granularity such as bytes or cache lines ... the Heatmap
//! at highest granularity requires an extra counter per byte of memory.
//! For a 64-bit (8 bytes) counter this results in an 8x memory overhead."
//! — reproduced as experiment E5 (`benches/instrumentation.rs` memory
//! table) and the `llama-lab heatmap` CLI/`examples/heatmap_viz.rs`
//! renderers.
//!
//! Requires a [`PhysicalMapping`] inner (byte addresses must exist to be
//! counted). `GRANULARITY` is in bytes: 1 = per byte, 64 = per cache line.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

use crate::blob::BlobStorage;

use crate::mapping::{Mapping, MemoryAccess, PhysicalMapping, SimdAccess};
use crate::record::{RecordDim, Scalar};

/// A coherent point-in-time copy of the per-granule counters of every
/// blob, produced by [`Heatmap::snapshot`] (same double-read protocol as
/// `FieldAccessCount::snapshot`).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct HeatSnapshot {
    /// Granule size in bytes (the mapping's `GRANULARITY`).
    pub granularity: usize,
    /// `counts[blob][granule]`.
    pub blobs: Vec<Vec<u64>>,
    /// Whether the double-read stabilized (see
    /// [`crate::mapping::field_access_count::AccessSnapshot::stable`]).
    pub stable: bool,
}

/// Count accesses per `GRANULARITY`-byte granule of every blob, forwarding
/// to the inner physical mapping `M`.
#[derive(Clone, Debug)]
pub struct Heatmap<R, M, const GRANULARITY: usize = 1> {
    inner: M,
    /// counters[blob][granule]
    counters: Arc<Vec<Vec<AtomicU64>>>,
    _pd: std::marker::PhantomData<R>,
}

impl<R: RecordDim, M: PhysicalMapping<R> + MemoryAccess<R>, const GRANULARITY: usize>
    Heatmap<R, M, GRANULARITY>
{
    /// Instrument `inner`.
    pub fn new(inner: M) -> Self {
        assert!(GRANULARITY > 0);
        let counters = (0..M::BLOB_COUNT)
            .map(|b| {
                let granules = inner.blob_size(b).div_ceil(GRANULARITY);
                (0..granules).map(|_| AtomicU64::new(0)).collect()
            })
            .collect();
        Heatmap { inner, counters: Arc::new(counters), _pd: std::marker::PhantomData }
    }

    /// The inner mapping.
    pub fn inner(&self) -> &M {
        &self.inner
    }

    /// Bytes of counter memory (the §4 memory-overhead number: 8×payload
    /// at `GRANULARITY = 1`).
    pub fn counter_bytes(&self) -> usize {
        self.counters.iter().map(|b| b.len() * 8).sum()
    }

    /// Snapshot of the per-granule counts for `blob`.
    pub fn blob_counts(&self, blob: usize) -> Vec<u64> {
        self.counters[blob].iter().map(|c| c.load(Ordering::Relaxed)).collect()
    }

    /// Read all granule counters of all blobs coherently: the full counter
    /// matrix is re-read until two consecutive passes agree (bounded
    /// retries; under sustained concurrent traffic the last pass is
    /// returned with `stable = false`). [`Heatmap::blob_counts`] remains
    /// the cheap per-blob read when cross-blob consistency is not needed.
    pub fn snapshot(&self) -> HeatSnapshot {
        let read_all = || -> Vec<Vec<u64>> {
            self.counters
                .iter()
                .map(|b| b.iter().map(|c| c.load(Ordering::Relaxed)).collect())
                .collect()
        };
        let mut prev = read_all();
        for _ in 0..8 {
            let cur = read_all();
            if cur == prev {
                return HeatSnapshot { granularity: GRANULARITY, blobs: cur, stable: true };
            }
            prev = cur;
        }
        HeatSnapshot { granularity: GRANULARITY, blobs: prev, stable: false }
    }

    /// Reset all counters.
    pub fn reset(&self) {
        for b in self.counters.iter() {
            for c in b {
                c.store(0, Ordering::Relaxed);
            }
        }
    }

    #[inline(always)]
    fn record_access(&self, blob: usize, off: usize, len: usize) {
        let first = off / GRANULARITY;
        let last = (off + len - 1) / GRANULARITY;
        for g in first..=last {
            self.counters[blob][g].fetch_add(1, Ordering::Relaxed);
        }
    }

    /// Render an ASCII heatmap: one line per blob, one cell per bucket
    /// (granules are merged into at most `width` buckets), shaded by
    /// access count relative to the blob maximum.
    pub fn render_ascii(&self, width: usize) -> String {
        const SHADES: &[u8] = b" .:-=+*#%@";
        let mut out = String::new();
        for (bi, blob) in self.counters.iter().enumerate() {
            let counts: Vec<u64> = blob.iter().map(|c| c.load(Ordering::Relaxed)).collect();
            let buckets = width.min(counts.len()).max(1);
            let per = counts.len().div_ceil(buckets);
            let sums: Vec<u64> =
                counts.chunks(per).map(|c| c.iter().sum::<u64>() / c.len() as u64).collect();
            let max = *sums.iter().max().unwrap_or(&0);
            out.push_str(&format!("blob {bi:2} [{:>8} B] |", counts.len() * GRANULARITY));
            for s in &sums {
                let shade = if max == 0 {
                    0
                } else {
                    ((s * (SHADES.len() as u64 - 1)) / max) as usize
                };
                out.push(SHADES[shade] as char);
            }
            out.push_str("|\n");
        }
        out
    }

    /// Dump counts as CSV (`blob,granule_offset,count`), the paper's
    /// workflow for plotting heatmaps of access patterns.
    pub fn to_csv(&self) -> String {
        let mut out = String::from("blob,offset,count\n");
        for (bi, blob) in self.counters.iter().enumerate() {
            for (g, c) in blob.iter().enumerate() {
                let v = c.load(Ordering::Relaxed);
                if v != 0 {
                    out.push_str(&format!("{bi},{},{v}\n", g * GRANULARITY));
                }
            }
        }
        out
    }
}

impl<R: RecordDim, M: PhysicalMapping<R> + MemoryAccess<R>, const GRANULARITY: usize> Mapping<R>
    for Heatmap<R, M, GRANULARITY>
{
    type Extents = M::Extents;
    const BLOB_COUNT: usize = M::BLOB_COUNT;

    #[inline(always)]
    fn extents(&self) -> &Self::Extents {
        self.inner.extents()
    }

    #[inline(always)]
    fn blob_size(&self, i: usize) -> usize {
        self.inner.blob_size(i)
    }

    fn fingerprint(&self) -> String {
        self.inner.fingerprint()
    }

    #[inline(always)]
    unsafe fn shard_bounds(&self, lin: usize) -> Option<usize> {
        // The granule counters are atomic (shards may hit the same granule
        // concurrently; increments commute), so safety is the inner
        // layout's byte-disjointness.
        self.inner.shard_bounds(lin)
    }
}

impl<R: RecordDim, M: PhysicalMapping<R> + MemoryAccess<R>, const GRANULARITY: usize>
    MemoryAccess<R> for Heatmap<R, M, GRANULARITY>
{
    #[inline(always)]
    fn load<T: Scalar, S: BlobStorage>(&self, storage: &S, idx: &[usize], field: usize) -> T {
        let (blob, off) = self.inner.blob_nr_and_offset(idx, field);
        self.record_access(blob, off, T::SIZE);
        self.inner.load(storage, idx, field)
    }

    #[inline(always)]
    fn store<T: Scalar, S: BlobStorage>(&self, storage: &mut S, idx: &[usize], field: usize, v: T) {
        let (blob, off) = self.inner.blob_nr_and_offset(idx, field);
        self.record_access(blob, off, T::SIZE);
        self.inner.store(storage, idx, field, v)
    }
}

impl<R: RecordDim, M: PhysicalMapping<R> + MemoryAccess<R> + SimdAccess<R>, const G: usize>
    SimdAccess<R> for Heatmap<R, M, G>
{
    // Inherit the scalar-walk defaults: every lane's bytes are counted via
    // the scalar load/store above. (Vectorizing instrumented access would
    // undercount granule hits.)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::blob::{alloc_view, HeapAlloc};
    use crate::extents::Dyn;
    use crate::mapping::aos::AoS;
    use crate::mapping::soa::SoA;

    crate::record! {
        pub struct P, mod p {
            x: f64,
            m: f32,
        }
    }

    #[test]
    fn byte_granularity_counts_value_bytes() {
        let hm = Heatmap::<P, _, 1>::new(SoA::<P, _>::new((Dyn(4u32),)));
        let mut v = alloc_view(hm, &HeapAlloc);
        v.set(&[0], p::x, 1.0f64);
        let _ = v.get::<f64, _>(&[0], p::x);
        let counts = v.mapping().blob_counts(0);
        // bytes 0..8 touched twice (one store + one load)
        assert_eq!(&counts[..8], &[2; 8]);
        assert!(counts[8..].iter().all(|&c| c == 0));
    }

    #[test]
    fn cacheline_granularity() {
        let hm = Heatmap::<P, _, 64>::new(SoA::<P, _>::new((Dyn(64u32),)));
        let mut v = alloc_view(hm, &HeapAlloc);
        // Touch records 0..8 (bytes 0..64 of blob 0) => granule 0 only.
        for i in 0..8usize {
            v.set(&[i], p::x, 0.0f64);
        }
        let counts = v.mapping().blob_counts(0);
        assert_eq!(counts[0], 8);
        assert!(counts[1..].iter().all(|&c| c == 0));
    }

    #[test]
    fn memory_overhead_is_8x_at_byte_granularity() {
        // §4: 64-bit counter per byte = 8x memory overhead.
        let inner = AoS::<P, _>::new((Dyn(128u32),));
        let payload: usize = inner.blob_size(0);
        let hm = Heatmap::<P, _, 1>::new(inner);
        assert_eq!(hm.counter_bytes(), payload * 8);
        // At cache-line granularity the overhead collapses to 1/8.
        let inner = AoS::<P, _>::new((Dyn(128u32),));
        let hm64 = Heatmap::<P, _, 64>::new(inner);
        assert_eq!(hm64.counter_bytes(), payload.div_ceil(64) * 8);
    }

    #[test]
    fn accesses_spanning_granules_count_both() {
        // AoS Packed: f64 at offset 8 within 12-byte records lands across
        // 8-byte granules.
        let hm = Heatmap::<P, _, 8>::new(AoS::<P, _, crate::mapping::aos::Packed>::new((
            Dyn(4u32),
        ),));
        let mut v = alloc_view(hm, &HeapAlloc);
        v.set(&[1], p::x, 1.0f64); // record 1 starts at byte 12: spans granules 1 and 2
        let counts = v.mapping().blob_counts(0);
        assert_eq!(counts[1], 1);
        assert_eq!(counts[2], 1);
    }

    #[test]
    fn snapshot_matches_blob_counts() {
        let hm = Heatmap::<P, _, 8>::new(SoA::<P, _>::new((Dyn(4u32),)));
        let mut v = alloc_view(hm, &HeapAlloc);
        v.set(&[0], p::x, 1.0f64);
        v.set(&[0], p::m, 2.0f32);
        let snap = v.mapping().snapshot();
        assert!(snap.stable);
        assert_eq!(snap.granularity, 8);
        assert_eq!(snap.blobs.len(), 2);
        for (b, counts) in snap.blobs.iter().enumerate() {
            assert_eq!(counts, &v.mapping().blob_counts(b));
        }
    }

    #[test]
    fn renderers() {
        let hm = Heatmap::<P, _, 1>::new(SoA::<P, _>::new((Dyn(8u32),)));
        let mut v = alloc_view(hm, &HeapAlloc);
        v.set(&[0], p::x, 1.0f64);
        let ascii = v.mapping().render_ascii(16);
        assert!(ascii.contains("blob  0"));
        let csv = v.mapping().to_csv();
        assert!(csv.starts_with("blob,offset,count\n"));
        assert!(csv.contains("0,0,1"));
    }
}
