//! Array-of-Structs mapping.
//!
//! One blob, records stored consecutively. The in-record field layout is a
//! policy ([`FieldOrder`]): packed declaration order, naturally aligned
//! declaration order (what a C compiler does to the equivalent struct), or
//! padding-minimizing order (fields sorted by descending alignment) —
//! LLAMA's `mapping::AoS` with its `fieldAlignment`/`PermuteFields`
//! parameters.

use std::marker::PhantomData;

use crate::extents::{Extents, Linearizer, RowMajor};
use crate::mapping::{FieldMask, Mapping, MemoryAccess, PhysicalMapping, SimdAccess, StaticMask};
use crate::record::{Field, RecordDim, Scalar};
use crate::simd::SimdElem;

/// Const-dispatch discriminant for [`FieldOrder`] policies, letting the
/// offset math run in `const` contexts (trait methods cannot be `const`).
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub enum FieldOrderKind {
    /// Declaration order, no padding.
    Packed,
    /// Declaration order, natural alignment.
    Aligned,
    /// Descending-alignment order, no padding.
    MinPad,
}

/// In-record field placement policy for [`AoS`].
pub trait FieldOrder: Copy + Default + Send + Sync + 'static {
    /// Name for fingerprints/reports.
    const NAME: &'static str;
    /// Const discriminant (drives the compile-time offset LUTs).
    const KIND: FieldOrderKind;
    /// Size of one record under this policy (over the masked fields).
    fn record_size(fields: &[Field], mask: FieldMask) -> usize {
        record_size_of(Self::KIND, fields, mask.0)
    }
    /// Offset of `field` within one record under this policy.
    fn field_offset(fields: &[Field], field: usize, mask: FieldMask) -> usize {
        offsets_of(Self::KIND, fields, mask.0)[field]
    }
}

/// Whether field `a` is placed before field `b` under MinPad order
/// (descending alignment, stable by declaration index).
const fn minpad_precedes(fields: &[Field], a: usize, b: usize) -> bool {
    let (aa, ab) = (fields[a].align(), fields[b].align());
    aa > ab || (aa == ab && a < b)
}

/// Record size under `kind` over the fields selected by `mask`
/// (const-evaluable; see [`FieldOrderKind`]).
pub const fn record_size_of(kind: FieldOrderKind, fields: &[Field], mask: u64) -> usize {
    let m = FieldMask(mask);
    match kind {
        FieldOrderKind::Packed | FieldOrderKind::MinPad => {
            let mut s = 0;
            let mut i = 0;
            while i < fields.len() {
                if m.contains(i) {
                    s += fields[i].size();
                }
                i += 1;
            }
            s
        }
        FieldOrderKind::Aligned => {
            let mut off = 0;
            let mut max_a = 1;
            let mut i = 0;
            while i < fields.len() {
                if m.contains(i) {
                    let a = fields[i].align();
                    off = (off + a - 1) / a * a + fields[i].size();
                    if a > max_a {
                        max_a = a;
                    }
                }
                i += 1;
            }
            (off + max_a - 1) / max_a * max_a
        }
    }
}

/// In-record field offsets under `kind` as a fixed LUT (const-evaluable;
/// entries for masked-out or absent fields are 0).
pub const fn offsets_of(
    kind: FieldOrderKind,
    fields: &[Field],
    mask: u64,
) -> [usize; crate::record::MAX_FIELDS] {
    let m = FieldMask(mask);
    let mut lut = [0usize; crate::record::MAX_FIELDS];
    match kind {
        FieldOrderKind::Packed => {
            let mut off = 0;
            let mut i = 0;
            while i < fields.len() {
                if m.contains(i) {
                    lut[i] = off;
                    off += fields[i].size();
                }
                i += 1;
            }
        }
        FieldOrderKind::Aligned => {
            let mut off = 0;
            let mut i = 0;
            while i < fields.len() {
                if m.contains(i) {
                    let a = fields[i].align();
                    off = (off + a - 1) / a * a;
                    lut[i] = off;
                    off += fields[i].size();
                }
                i += 1;
            }
        }
        FieldOrderKind::MinPad => {
            let mut f = 0;
            while f < fields.len() {
                if m.contains(f) {
                    let mut off = 0;
                    let mut i = 0;
                    while i < fields.len() {
                        if i != f && m.contains(i) && minpad_precedes(fields, i, f) {
                            off += fields[i].size();
                        }
                        i += 1;
                    }
                    lut[f] = off;
                }
                f += 1;
            }
        }
    }
    lut
}

/// Packed, declaration order: no padding, fields may be unaligned.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct Packed;

impl FieldOrder for Packed {
    const NAME: &'static str = "Packed";
    const KIND: FieldOrderKind = FieldOrderKind::Packed;
}

/// Naturally aligned, declaration order: each field aligned to its scalar
/// alignment, record size rounded to max alignment — the layout of the
/// equivalent flattened `#[repr(C)]` struct.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct Aligned;

impl FieldOrder for Aligned {
    const NAME: &'static str = "Aligned";
    const KIND: FieldOrderKind = FieldOrderKind::Aligned;
}

/// Padding-minimizing order: fields sorted by descending alignment (stable
/// by declaration index). With natural scalar sizes this eliminates all
/// padding while keeping every field aligned — LLAMA's
/// `PermuteFieldsMinimizePadding`.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct MinPad;

impl FieldOrder for MinPad {
    const NAME: &'static str = "MinPad";
    const KIND: FieldOrderKind = FieldOrderKind::MinPad;
}

/// Array-of-Structs: records consecutive in one blob.
///
/// ```
/// use llama::prelude::*;
/// llama::record! { pub struct P, mod p { x: f64, m: f32 } }
/// let aos = AoS::<P, _>::new((Dyn(8u32),));
/// let mut v = alloc_view(aos, &HeapAlloc);
/// v.set(&[2], p::m, 5.0f32);
/// assert_eq!(v.get::<f32, _>(&[2], p::m), 5.0);
/// ```
#[derive(Clone, Copy, Debug, Default)]
pub struct AoS<R, E, FO = Aligned, L = RowMajor, const MASK: u64 = { u64::MAX }> {
    extents: E,
    _pd: PhantomData<(R, FO, L)>,
}

impl<R: RecordDim, E: Extents, FO: FieldOrder, L: Linearizer, const MASK: u64>
    AoS<R, E, FO, L, MASK>
{
    /// Mapping over `extents`.
    pub fn new(extents: E) -> Self {
        AoS { extents, _pd: PhantomData }
    }

    /// The field mask as a value.
    pub const fn mask() -> FieldMask {
        FieldMask(MASK)
    }

    /// Bytes of one record (computed once per monomorphization — §Perf:
    /// keeps the offset math out of the access hot path).
    pub const RECORD_SIZE: usize = record_size_of(FO::KIND, R::FIELDS, MASK);

    /// In-record field offsets (constant LUT).
    pub const OFFSETS: [usize; crate::record::MAX_FIELDS] =
        offsets_of(FO::KIND, R::FIELDS, MASK);

    /// Bytes of one record under the field-order policy.
    #[inline(always)]
    pub fn record_size() -> usize {
        Self::RECORD_SIZE
    }
}

impl<R, E, FO, L, const MASK: u64> StaticMask for AoS<R, E, FO, L, MASK> {
    const FIELD_MASK: u64 = MASK;
}

impl<R: RecordDim, E: Extents, FO: FieldOrder, L: Linearizer, const MASK: u64> Mapping<R>
    for AoS<R, E, FO, L, MASK>
{
    type Extents = E;
    const BLOB_COUNT: usize = 1;

    #[inline(always)]
    fn extents(&self) -> &E {
        &self.extents
    }

    #[inline(always)]
    fn blob_size(&self, _i: usize) -> usize {
        self.extents.count() * Self::RECORD_SIZE
    }

    fn fingerprint(&self) -> String {
        format!(
            "AoS<{},{},{},mask={MASK:x}>@{:?}",
            R::NAME,
            FO::NAME,
            L::NAME,
            (0..E::RANK).map(|d| self.extents.extent(d)).collect::<Vec<_>>()
        )
    }

    #[inline(always)]
    unsafe fn shard_bounds(&self, lin: usize) -> Option<usize> {
        // Every record owns the disjoint byte range
        // `[lin * RECORD_SIZE, (lin + 1) * RECORD_SIZE)`, so any partition
        // of the index space is byte-disjoint (under any linearizer: it is
        // a bijection into the same per-record slots).
        Some(lin)
    }
}

impl<R: RecordDim, E: Extents, FO: FieldOrder, L: Linearizer, const MASK: u64> PhysicalMapping<R>
    for AoS<R, E, FO, L, MASK>
{
    #[inline(always)]
    fn blob_nr_and_offset(&self, idx: &[usize], field: usize) -> (usize, usize) {
        let lin = L::linearize(&self.extents, idx);
        (0, lin * Self::RECORD_SIZE + Self::OFFSETS[field])
    }
}

impl<R: RecordDim, E: Extents, FO: FieldOrder, L: Linearizer, const MASK: u64> MemoryAccess<R>
    for AoS<R, E, FO, L, MASK>
{
    #[inline(always)]
    fn load<T: Scalar, S: crate::blob::BlobStorage>(
        &self,
        storage: &S,
        idx: &[usize],
        field: usize,
    ) -> T {
        crate::mapping::physical_load::<R, _, T, S>(self, storage, idx, field)
    }

    #[inline(always)]
    fn store<T: Scalar, S: crate::blob::BlobStorage>(
        &self,
        storage: &mut S,
        idx: &[usize],
        field: usize,
        v: T,
    ) {
        crate::mapping::physical_store::<R, _, T, S>(self, storage, idx, field, v)
    }
}

// AoS keeps the default (scalar-walk) SIMD access: strided element loads.
// The paper notes LLAMA's scalar loads beat manual `gather` for AoS on the
// tested CPU — the same structure applies here.
impl<R: RecordDim, E: Extents, FO: FieldOrder, L: Linearizer, const MASK: u64> SimdAccess<R>
    for AoS<R, E, FO, L, MASK>
{
}

// Allow `SimdElem` bound to appear in doc/blanket positions without warnings.
#[allow(unused)]
fn _simd_elem_used<T: SimdElem>() {}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::blob::{alloc_view, HeapAlloc};
    use crate::extents::Dyn;

    crate::record! {
        pub struct P, mod p {
            pos: { x: f64, y: f64, z: f64 },
            mass: f32,
            flag: bool,
        }
    }

    #[test]
    fn aligned_layout_matches_c_struct() {
        // f64 x3 (24) + f32 (4) + bool (1) -> pad to 8 => 32... wait:
        // offsets: x=0 y=8 z=16 (24), mass=24 (28), flag=28, size pad to 8 => 32
        assert_eq!(AoS::<P, (Dyn<u32>,)>::record_size(), 32);
        let m = AoS::<P, _>::new((Dyn(4u32),));
        assert_eq!(m.blob_size(0), 4 * 32);
        assert_eq!(m.blob_nr_and_offset_t(&[1], p::pos::z), (0, 32 + 16));
        assert_eq!(m.blob_nr_and_offset_t(&[2], p::mass), (0, 64 + 24));
        assert_eq!(m.blob_nr_and_offset_t(&[2], p::flag), (0, 64 + 28));
    }

    #[test]
    fn packed_layout() {
        assert_eq!(AoS::<P, (Dyn<u32>,), Packed>::record_size(), 29);
        let m = AoS::<P, (Dyn<u32>,), Packed>::new((Dyn(4u32),));
        assert_eq!(m.blob_nr_and_offset_t(&[1], p::pos::x), (0, 29));
        assert_eq!(m.blob_nr_and_offset_t(&[0], p::flag), (0, 28));
    }

    #[test]
    fn minpad_layout() {
        // desc align: x,y,z (8) then mass (4) then flag (1) — same as decl
        // here, so offsets match packed; size has no padding.
        assert_eq!(AoS::<P, (Dyn<u32>,), MinPad>::record_size(), 29);
        let m = AoS::<P, (Dyn<u32>,), MinPad>::new((Dyn(2u32),));
        assert_eq!(m.blob_nr_and_offset_t(&[0], p::mass), (0, 24));
    }

    crate::record! {
        pub struct Shuffled, mod sh {
            a: u8,
            b: f64,
            c: u16,
            d: f32,
        }
    }

    #[test]
    fn minpad_reorders() {
        // aligned decl order: a=0, b=8(pad 7), c=16, d=20, size=24
        assert_eq!(AoS::<Shuffled, (Dyn<u32>,), Aligned>::record_size(), 24);
        // minpad order: b(8) d(4) c(2) a(1) => size 15, offsets b=0 d=8 c=12 a=14
        assert_eq!(AoS::<Shuffled, (Dyn<u32>,), MinPad>::record_size(), 15);
        let m = AoS::<Shuffled, (Dyn<u32>,), MinPad>::new((Dyn(2u32),));
        assert_eq!(m.blob_nr_and_offset_t(&[0], sh::b), (0, 0));
        assert_eq!(m.blob_nr_and_offset_t(&[0], sh::d), (0, 8));
        assert_eq!(m.blob_nr_and_offset_t(&[0], sh::c), (0, 12));
        assert_eq!(m.blob_nr_and_offset_t(&[0], sh::a), (0, 14));
    }

    #[test]
    fn masked_aos() {
        // only pos.* mapped (fields 0..3): mask 0b00111
        const M: u64 = 0b00111;
        let m = AoS::<P, (Dyn<u32>,), Aligned, RowMajor, M>::new((Dyn(4u32),));
        assert_eq!(AoS::<P, (Dyn<u32>,), Aligned, RowMajor, M>::record_size(), 24);
        assert_eq!(m.blob_size(0), 96);
        assert_eq!(m.blob_nr_and_offset_t(&[1], p::pos::y), (0, 32));
    }

    #[test]
    fn roundtrip_through_view() {
        let mut v = alloc_view(AoS::<P, _>::new((Dyn(8u32),)), &HeapAlloc);
        v.set(&[3], p::pos::y, -2.5f64);
        v.set(&[3], p::mass, 7.5f32);
        v.set(&[3], p::flag, true);
        assert_eq!(v.get::<f64, _>(&[3], p::pos::y), -2.5);
        assert_eq!(v.get::<f32, _>(&[3], p::mass), 7.5);
        assert!(v.get::<bool, _>(&[3], p::flag));
        // neighbours untouched
        assert_eq!(v.get::<f64, _>(&[2], p::pos::y), 0.0);
        assert_eq!(v.get::<f64, _>(&[4], p::pos::y), 0.0);
    }

    #[test]
    fn stateless_when_static_extents() {
        use crate::extents::Fix;
        type M = AoS<P, (Fix<u32, 16>,)>;
        assert_eq!(std::mem::size_of::<M>(), 0);
    }
}
