//! `Bytesplit`: split each value into bytes and regroup by byte order (§3).
//!
//! "Many compression algorithms are more efficient when compressing a
//! stream of zeros. If the values in an integer array are small, the
//! higher-order bytes may often be just zero. Splitting the values into
//! their bytes and regrouping those by their order can effectively
//! colocate many zero-bytes and thus lead to higher compression ratios"
//! — the BYTE_STREAM_SPLIT idea from Apache Parquet, generalized to
//! records.
//!
//! Layout: one blob per field; inside a field's blob, byte-plane-major —
//! plane `b` (the `b`-th byte of every value, little-endian) occupies
//! `count` consecutive bytes starting at `b * count`. C++ LLAMA forwards
//! the byte record to an arbitrary inner mapping; this implementation
//! fixes the inner layout to SoA-of-byte-planes (the case that matters
//! for compression — see DESIGN.md *Substitutions*). The experiment E6
//! (`benches/bytesplit.rs`) feeds these blobs to RLE/deflate/zstd.

use std::marker::PhantomData;

use crate::blob::BlobStorage;
use crate::extents::{Extents, Linearizer, RowMajor};
use crate::mapping::{Mapping, MemoryAccess, SimdAccess};
use crate::record::{RecordDim, Scalar};

/// Byte-plane SoA mapping (BYTE_STREAM_SPLIT per field).
///
/// ```
/// use llama::prelude::*;
/// llama::record! { pub struct T, mod t { v: u32 } }
/// let mut view = alloc_view(Bytesplit::<T, _>::new((Dyn(4u32),)), &HeapAlloc);
/// view.set(&[0], t::v, 0x01020304u32);
/// assert_eq!(view.get::<u32, _>(&[0], t::v), 0x01020304);
/// // plane 0 holds the low bytes of all 4 values first:
/// assert_eq!(view.storage().blob(0)[0], 0x04);
/// assert_eq!(view.storage().blob(0)[4], 0x03); // plane 1 starts at count=4
/// ```
#[derive(Clone, Copy, Debug, Default)]
pub struct Bytesplit<R, E, L = RowMajor> {
    extents: E,
    _pd: PhantomData<(R, L)>,
}

impl<R: RecordDim, E: Extents, L: Linearizer> Bytesplit<R, E, L> {
    /// Mapping over `extents`.
    pub fn new(extents: E) -> Self {
        Bytesplit { extents, _pd: PhantomData }
    }
}

impl<R: RecordDim, E: Extents, L: Linearizer> Mapping<R> for Bytesplit<R, E, L> {
    type Extents = E;
    const BLOB_COUNT: usize = R::FIELDS.len();

    #[inline(always)]
    fn extents(&self) -> &E {
        &self.extents
    }

    #[inline(always)]
    fn blob_size(&self, i: usize) -> usize {
        self.extents.count() * R::FIELDS[i].size()
    }

    fn fingerprint(&self) -> String {
        format!(
            "Bytesplit<{},{}>@{:?}",
            R::NAME,
            L::NAME,
            (0..E::RANK).map(|d| self.extents.extent(d)).collect::<Vec<_>>()
        )
    }

    #[inline(always)]
    unsafe fn shard_bounds(&self, lin: usize) -> Option<usize> {
        // Byte `b` of record `lin` lives at the unique offset `b * n + lin`
        // of its field blob: records never share bytes, any split is safe.
        Some(lin)
    }
}

impl<R: RecordDim, E: Extents, L: Linearizer> MemoryAccess<R> for Bytesplit<R, E, L> {
    #[inline(always)]
    fn load<T: Scalar, S: BlobStorage>(&self, storage: &S, idx: &[usize], field: usize) -> T {
        debug_assert!(R::FIELDS[field].ty.same(T::TYPE));
        let lin = L::linearize(&self.extents, idx);
        let n = self.extents.count();
        let mut bytes = [0u8; 16];
        // Byte-exact: the planes are `n` bytes apart, so each of the
        // value's bytes is its own one-byte window (sound on the
        // shard-worker storage — record `lin` owns offset `b*n + lin` of
        // every plane exclusively).
        for (b, byte) in bytes[..T::SIZE].iter_mut().enumerate() {
            *byte = storage.bytes(field, b * n + lin, 1)[0];
        }
        T::read_le(&bytes[..T::SIZE])
    }

    #[inline(always)]
    fn store<T: Scalar, S: BlobStorage>(&self, storage: &mut S, idx: &[usize], field: usize, v: T) {
        debug_assert!(R::FIELDS[field].ty.same(T::TYPE));
        let lin = L::linearize(&self.extents, idx);
        let n = self.extents.count();
        let mut bytes = [0u8; 16];
        v.write_le(&mut bytes[..T::SIZE]);
        for (b, &byte) in bytes[..T::SIZE].iter().enumerate() {
            storage.bytes_mut(field, b * n + lin, 1)[0] = byte;
        }
    }
}

impl<R: RecordDim, E: Extents, L: Linearizer> SimdAccess<R> for Bytesplit<R, E, L> {}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::blob::{alloc_view, HeapAlloc};
    use crate::extents::Dyn;

    crate::record! {
        pub struct Rec, mod rec {
            small: u32,
            wide: u64,
            flt: f32,
        }
    }

    #[test]
    fn roundtrip() {
        let mut v = alloc_view(Bytesplit::<Rec, _>::new((Dyn(64u32),)), &HeapAlloc);
        for i in 0..64usize {
            v.set(&[i], rec::small, (i * 3) as u32);
            v.set(&[i], rec::wide, u64::MAX - i as u64);
            v.set(&[i], rec::flt, i as f32 / 7.0);
        }
        for i in 0..64usize {
            assert_eq!(v.get::<u32, _>(&[i], rec::small), (i * 3) as u32);
            assert_eq!(v.get::<u64, _>(&[i], rec::wide), u64::MAX - i as u64);
            assert_eq!(v.get::<f32, _>(&[i], rec::flt), i as f32 / 7.0);
        }
    }

    #[test]
    fn zero_planes_are_colocated() {
        // Small values => upper 3 byte planes of `small` are all zeros.
        let mut v = alloc_view(Bytesplit::<Rec, _>::new((Dyn(256u32),)), &HeapAlloc);
        for i in 0..256usize {
            v.set(&[i], rec::small, (i % 100) as u32); // < 256: one byte
        }
        let blob = v.storage().blob(rec::small.i());
        assert_eq!(blob.len(), 1024);
        // planes 1..3 (bytes 256..1024) must be entirely zero
        assert!(blob[256..].iter().all(|&b| b == 0));
        // plane 0 holds the values
        assert_eq!(blob[5], 5);
    }

    #[test]
    fn total_size_equals_packed() {
        let m = Bytesplit::<Rec, _>::new((Dyn(10u32),));
        let total: usize = (0..3).map(|i| m.blob_size(i)).sum();
        assert_eq!(total, 10 * (4 + 8 + 4));
    }
}
