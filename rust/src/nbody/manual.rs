//! Manually written n-body versions — the baselines of Figure 3.
//!
//! Each layout (`AoS`, `SoA` multi-blob, `AoSoA`) is hand-coded against
//! its concrete data structure, scalar and SIMD, exactly as a programmer
//! without LLAMA would write them. The SIMD AoS *move* uses per-lane
//! scalar loads rather than gathers — the paper found the compiler
//! produces better code that way on the tested CPU, and made the same
//! replacement for the final figure.

use super::{pp_interaction, ParticleData, EPS2, TIMESTEP};
use crate::simd::Simd;

// ---------------------------------------------------------------------------
// AoS
// ---------------------------------------------------------------------------

/// Array-of-structs particle store.
#[derive(Clone, Debug)]
pub struct AosSim {
    /// The particles.
    pub ps: Vec<ParticleData>,
}

impl AosSim {
    /// Build from shared initial conditions.
    pub fn new(init: &[ParticleData]) -> Self {
        AosSim { ps: init.to_vec() }
    }

    /// Extract particles for validation.
    pub fn snapshot(&self) -> Vec<ParticleData> {
        self.ps.clone()
    }

    /// Scalar all-pairs update.
    pub fn update_scalar(&mut self) {
        let n = self.ps.len();
        for i in 0..n {
            let pi = self.ps[i];
            let mut acc = (0.0f32, 0.0f32, 0.0f32);
            for j in 0..n {
                let pj = &self.ps[j];
                pp_interaction(
                    pi.pos.x, pi.pos.y, pi.pos.z, pj.pos.x, pj.pos.y, pj.pos.z, pj.mass, &mut acc,
                );
            }
            self.ps[i].vel.x += acc.0;
            self.ps[i].vel.y += acc.1;
            self.ps[i].vel.z += acc.2;
        }
    }

    /// Scalar move.
    pub fn move_scalar(&mut self) {
        for p in &mut self.ps {
            p.pos.x += p.vel.x * TIMESTEP;
            p.pos.y += p.vel.y * TIMESTEP;
            p.pos.z += p.vel.z * TIMESTEP;
        }
    }

    /// SIMD update: `LANES` particles per outer iteration, per-lane scalar
    /// loads from the interleaved layout (the "multiple scalar loads"
    /// variant the paper settled on instead of gathers).
    pub fn update_simd<const LANES: usize>(&mut self) {
        let n = self.ps.len();
        assert_eq!(n % LANES, 0);
        for i in (0..n).step_by(LANES) {
            let mut pix = Simd::<f32, LANES>::default();
            let mut piy = Simd::<f32, LANES>::default();
            let mut piz = Simd::<f32, LANES>::default();
            for k in 0..LANES {
                pix.0[k] = self.ps[i + k].pos.x;
                piy.0[k] = self.ps[i + k].pos.y;
                piz.0[k] = self.ps[i + k].pos.z;
            }
            let mut ax = Simd::<f32, LANES>::default();
            let mut ay = Simd::<f32, LANES>::default();
            let mut az = Simd::<f32, LANES>::default();
            for j in 0..n {
                let pj = &self.ps[j];
                simd_interaction(
                    pix,
                    piy,
                    piz,
                    Simd::splat(pj.pos.x),
                    Simd::splat(pj.pos.y),
                    Simd::splat(pj.pos.z),
                    Simd::splat(pj.mass),
                    &mut ax,
                    &mut ay,
                    &mut az,
                );
            }
            for k in 0..LANES {
                self.ps[i + k].vel.x += ax.0[k];
                self.ps[i + k].vel.y += ay.0[k];
                self.ps[i + k].vel.z += az.0[k];
            }
        }
    }

    /// SIMD move with per-lane scalar loads/stores.
    pub fn move_simd<const LANES: usize>(&mut self) {
        let n = self.ps.len();
        assert_eq!(n % LANES, 0);
        let dt = Simd::<f32, LANES>::splat(TIMESTEP);
        for i in (0..n).step_by(LANES) {
            let mut px = Simd::<f32, LANES>::default();
            let mut py = Simd::<f32, LANES>::default();
            let mut pz = Simd::<f32, LANES>::default();
            let mut vx = Simd::<f32, LANES>::default();
            let mut vy = Simd::<f32, LANES>::default();
            let mut vz = Simd::<f32, LANES>::default();
            for k in 0..LANES {
                let p = &self.ps[i + k];
                px.0[k] = p.pos.x;
                py.0[k] = p.pos.y;
                pz.0[k] = p.pos.z;
                vx.0[k] = p.vel.x;
                vy.0[k] = p.vel.y;
                vz.0[k] = p.vel.z;
            }
            px += vx * dt;
            py += vy * dt;
            pz += vz * dt;
            for k in 0..LANES {
                let p = &mut self.ps[i + k];
                p.pos.x = px.0[k];
                p.pos.y = py.0[k];
                p.pos.z = pz.0[k];
            }
        }
    }
}

// ---------------------------------------------------------------------------
// SoA (multi-blob: one Vec per field)
// ---------------------------------------------------------------------------

/// Struct-of-arrays particle store, one allocation per field ("SoA MB").
#[derive(Clone, Debug)]
pub struct SoaSim {
    /// Position components.
    pub px: Vec<f32>,
    /// Position y.
    pub py: Vec<f32>,
    /// Position z.
    pub pz: Vec<f32>,
    /// Velocity x.
    pub vx: Vec<f32>,
    /// Velocity y.
    pub vy: Vec<f32>,
    /// Velocity z.
    pub vz: Vec<f32>,
    /// Masses.
    pub mass: Vec<f32>,
}

impl SoaSim {
    /// Build from shared initial conditions.
    pub fn new(init: &[ParticleData]) -> Self {
        SoaSim {
            px: init.iter().map(|p| p.pos.x).collect(),
            py: init.iter().map(|p| p.pos.y).collect(),
            pz: init.iter().map(|p| p.pos.z).collect(),
            vx: init.iter().map(|p| p.vel.x).collect(),
            vy: init.iter().map(|p| p.vel.y).collect(),
            vz: init.iter().map(|p| p.vel.z).collect(),
            mass: init.iter().map(|p| p.mass).collect(),
        }
    }

    /// Extract particles for validation.
    pub fn snapshot(&self) -> Vec<ParticleData> {
        (0..self.px.len())
            .map(|i| ParticleData {
                pos: super::PVec { x: self.px[i], y: self.py[i], z: self.pz[i] },
                vel: super::PVec { x: self.vx[i], y: self.vy[i], z: self.vz[i] },
                mass: self.mass[i],
            })
            .collect()
    }

    /// Scalar all-pairs update.
    pub fn update_scalar(&mut self) {
        let n = self.px.len();
        for i in 0..n {
            let (pix, piy, piz) = (self.px[i], self.py[i], self.pz[i]);
            let mut acc = (0.0f32, 0.0f32, 0.0f32);
            for j in 0..n {
                pp_interaction(
                    pix, piy, piz, self.px[j], self.py[j], self.pz[j], self.mass[j], &mut acc,
                );
            }
            self.vx[i] += acc.0;
            self.vy[i] += acc.1;
            self.vz[i] += acc.2;
        }
    }

    /// Scalar move.
    pub fn move_scalar(&mut self) {
        let n = self.px.len();
        for i in 0..n {
            self.px[i] += self.vx[i] * TIMESTEP;
            self.py[i] += self.vy[i] * TIMESTEP;
            self.pz[i] += self.vz[i] * TIMESTEP;
        }
    }

    /// SIMD update with contiguous vector loads.
    pub fn update_simd<const LANES: usize>(&mut self) {
        let n = self.px.len();
        assert_eq!(n % LANES, 0);
        for i in (0..n).step_by(LANES) {
            let pix = Simd::<f32, LANES>::from_slice(&self.px[i..]);
            let piy = Simd::<f32, LANES>::from_slice(&self.py[i..]);
            let piz = Simd::<f32, LANES>::from_slice(&self.pz[i..]);
            let mut ax = Simd::<f32, LANES>::default();
            let mut ay = Simd::<f32, LANES>::default();
            let mut az = Simd::<f32, LANES>::default();
            for j in 0..n {
                simd_interaction(
                    pix,
                    piy,
                    piz,
                    Simd::splat(self.px[j]),
                    Simd::splat(self.py[j]),
                    Simd::splat(self.pz[j]),
                    Simd::splat(self.mass[j]),
                    &mut ax,
                    &mut ay,
                    &mut az,
                );
            }
            let vx = Simd::<f32, LANES>::from_slice(&self.vx[i..]) + ax;
            let vy = Simd::<f32, LANES>::from_slice(&self.vy[i..]) + ay;
            let vz = Simd::<f32, LANES>::from_slice(&self.vz[i..]) + az;
            vx.write_to_slice(&mut self.vx[i..]);
            vy.write_to_slice(&mut self.vy[i..]);
            vz.write_to_slice(&mut self.vz[i..]);
        }
    }

    /// SIMD move with contiguous vector loads/stores.
    pub fn move_simd<const LANES: usize>(&mut self) {
        let n = self.px.len();
        assert_eq!(n % LANES, 0);
        let dt = Simd::<f32, LANES>::splat(TIMESTEP);
        for i in (0..n).step_by(LANES) {
            let px = Simd::<f32, LANES>::from_slice(&self.px[i..])
                + Simd::<f32, LANES>::from_slice(&self.vx[i..]) * dt;
            let py = Simd::<f32, LANES>::from_slice(&self.py[i..])
                + Simd::<f32, LANES>::from_slice(&self.vy[i..]) * dt;
            let pz = Simd::<f32, LANES>::from_slice(&self.pz[i..])
                + Simd::<f32, LANES>::from_slice(&self.vz[i..]) * dt;
            px.write_to_slice(&mut self.px[i..]);
            py.write_to_slice(&mut self.py[i..]);
            pz.write_to_slice(&mut self.pz[i..]);
        }
    }
}

// ---------------------------------------------------------------------------
// AoSoA
// ---------------------------------------------------------------------------

/// One AoSoA block: `L` values of each field.
#[derive(Clone, Copy, Debug)]
pub struct AosoaBlock<const L: usize> {
    /// pos.x lanes.
    pub px: [f32; L],
    /// pos.y lanes.
    pub py: [f32; L],
    /// pos.z lanes.
    pub pz: [f32; L],
    /// vel.x lanes.
    pub vx: [f32; L],
    /// vel.y lanes.
    pub vy: [f32; L],
    /// vel.z lanes.
    pub vz: [f32; L],
    /// mass lanes.
    pub mass: [f32; L],
}

impl<const L: usize> Default for AosoaBlock<L> {
    fn default() -> Self {
        AosoaBlock {
            px: [0.0; L],
            py: [0.0; L],
            pz: [0.0; L],
            vx: [0.0; L],
            vy: [0.0; L],
            vz: [0.0; L],
            mass: [0.0; L],
        }
    }
}

/// Array-of-struct-of-arrays particle store with `L`-wide blocks.
#[derive(Clone, Debug)]
pub struct AosoaSim<const L: usize> {
    /// The blocks.
    pub blocks: Vec<AosoaBlock<L>>,
}

impl<const L: usize> AosoaSim<L> {
    /// Build from shared initial conditions (`n % L == 0`).
    pub fn new(init: &[ParticleData]) -> Self {
        assert_eq!(init.len() % L, 0);
        let mut blocks = vec![AosoaBlock::default(); init.len() / L];
        for (i, p) in init.iter().enumerate() {
            let b = &mut blocks[i / L];
            let k = i % L;
            b.px[k] = p.pos.x;
            b.py[k] = p.pos.y;
            b.pz[k] = p.pos.z;
            b.vx[k] = p.vel.x;
            b.vy[k] = p.vel.y;
            b.vz[k] = p.vel.z;
            b.mass[k] = p.mass;
        }
        AosoaSim { blocks }
    }

    /// Extract particles for validation.
    pub fn snapshot(&self) -> Vec<ParticleData> {
        let mut out = Vec::with_capacity(self.blocks.len() * L);
        for b in &self.blocks {
            for k in 0..L {
                out.push(ParticleData {
                    pos: super::PVec { x: b.px[k], y: b.py[k], z: b.pz[k] },
                    vel: super::PVec { x: b.vx[k], y: b.vy[k], z: b.vz[k] },
                    mass: b.mass[k],
                });
            }
        }
        out
    }

    /// Scalar update using the two nested loops that match the block
    /// structure (the optimization footnote 13 says a single flat loop
    /// cannot get).
    pub fn update_scalar(&mut self) {
        let nb = self.blocks.len();
        for bi in 0..nb {
            for k in 0..L {
                let (pix, piy, piz) =
                    (self.blocks[bi].px[k], self.blocks[bi].py[k], self.blocks[bi].pz[k]);
                let mut acc = (0.0f32, 0.0f32, 0.0f32);
                for bj in 0..nb {
                    let b = &self.blocks[bj];
                    for l in 0..L {
                        pp_interaction(
                            pix, piy, piz, b.px[l], b.py[l], b.pz[l], b.mass[l], &mut acc,
                        );
                    }
                }
                let b = &mut self.blocks[bi];
                b.vx[k] += acc.0;
                b.vy[k] += acc.1;
                b.vz[k] += acc.2;
            }
        }
    }

    /// Scalar move.
    pub fn move_scalar(&mut self) {
        for b in &mut self.blocks {
            for k in 0..L {
                b.px[k] += b.vx[k] * TIMESTEP;
                b.py[k] += b.vy[k] * TIMESTEP;
                b.pz[k] += b.vz[k] * TIMESTEP;
            }
        }
    }

    /// SIMD update: whole blocks are native vectors.
    pub fn update_simd(&mut self) {
        let nb = self.blocks.len();
        for bi in 0..nb {
            let pix = Simd::<f32, L>(self.blocks[bi].px);
            let piy = Simd::<f32, L>(self.blocks[bi].py);
            let piz = Simd::<f32, L>(self.blocks[bi].pz);
            let mut ax = Simd::<f32, L>::default();
            let mut ay = Simd::<f32, L>::default();
            let mut az = Simd::<f32, L>::default();
            for bj in 0..nb {
                let b = &self.blocks[bj];
                for l in 0..L {
                    simd_interaction(
                        pix,
                        piy,
                        piz,
                        Simd::splat(b.px[l]),
                        Simd::splat(b.py[l]),
                        Simd::splat(b.pz[l]),
                        Simd::splat(b.mass[l]),
                        &mut ax,
                        &mut ay,
                        &mut az,
                    );
                }
            }
            let b = &mut self.blocks[bi];
            b.vx = (Simd::<f32, L>(b.vx) + ax).0;
            b.vy = (Simd::<f32, L>(b.vy) + ay).0;
            b.vz = (Simd::<f32, L>(b.vz) + az).0;
        }
    }

    /// SIMD move: whole blocks are native vectors.
    pub fn move_simd(&mut self) {
        let dt = Simd::<f32, L>::splat(TIMESTEP);
        for b in &mut self.blocks {
            b.px = (Simd::<f32, L>(b.px) + Simd::<f32, L>(b.vx) * dt).0;
            b.py = (Simd::<f32, L>(b.py) + Simd::<f32, L>(b.vy) * dt).0;
            b.pz = (Simd::<f32, L>(b.pz) + Simd::<f32, L>(b.vz) * dt).0;
        }
    }
}

/// Vectorized `pPInteraction`: `LANES` i-particles against one broadcast
/// j-particle.
#[inline(always)]
#[allow(clippy::too_many_arguments)]
pub fn simd_interaction<const LANES: usize>(
    pix: Simd<f32, LANES>,
    piy: Simd<f32, LANES>,
    piz: Simd<f32, LANES>,
    pjx: Simd<f32, LANES>,
    pjy: Simd<f32, LANES>,
    pjz: Simd<f32, LANES>,
    pjmass: Simd<f32, LANES>,
    ax: &mut Simd<f32, LANES>,
    ay: &mut Simd<f32, LANES>,
    az: &mut Simd<f32, LANES>,
) {
    let dx = pjx - pix;
    let dy = pjy - piy;
    let dz = pjz - piz;
    let dist_sqr = Simd::splat(EPS2) + dx * dx + dy * dy + dz * dz;
    let dist_sixth = dist_sqr * dist_sqr * dist_sqr;
    let inv_dist_cube = Simd::splat(1.0f32) / dist_sixth.sqrt();
    let sts = pjmass * inv_dist_cube * Simd::splat(TIMESTEP);
    *ax += dx * sts;
    *ay += dy * sts;
    *az += dz * sts;
}

#[cfg(test)]
mod tests {
    use super::super::{init_particles, max_pos_delta, total_energy};
    use super::*;

    const N: usize = 64;
    const STEPS: usize = 4;

    fn reference() -> Vec<ParticleData> {
        let mut sim = AosSim::new(&init_particles(N, 7));
        for _ in 0..STEPS {
            sim.update_scalar();
            sim.move_scalar();
        }
        sim.snapshot()
    }

    #[test]
    fn soa_scalar_matches_aos_scalar() {
        let mut sim = SoaSim::new(&init_particles(N, 7));
        for _ in 0..STEPS {
            sim.update_scalar();
            sim.move_scalar();
        }
        assert_eq!(max_pos_delta(&reference(), &sim.snapshot()), 0.0);
    }

    #[test]
    fn aosoa_scalar_matches() {
        let mut sim = AosoaSim::<8>::new(&init_particles(N, 7));
        for _ in 0..STEPS {
            sim.update_scalar();
            sim.move_scalar();
        }
        assert_eq!(max_pos_delta(&reference(), &sim.snapshot()), 0.0);
    }

    #[test]
    fn simd_variants_match_within_tolerance() {
        // SIMD summation order differs; allow small drift.
        let r = reference();
        let mut aos = AosSim::new(&init_particles(N, 7));
        let mut soa = SoaSim::new(&init_particles(N, 7));
        let mut aosoa = AosoaSim::<8>::new(&init_particles(N, 7));
        for _ in 0..STEPS {
            aos.update_simd::<8>();
            aos.move_simd::<8>();
            soa.update_simd::<8>();
            soa.move_simd::<8>();
            aosoa.update_simd();
            aosoa.move_simd();
        }
        assert!(max_pos_delta(&r, &aos.snapshot()) < 1e-4);
        assert!(max_pos_delta(&r, &soa.snapshot()) < 1e-4);
        assert!(max_pos_delta(&r, &aosoa.snapshot()) < 1e-4);
        // SIMD variants agree with each other exactly or near-exactly.
        assert!(max_pos_delta(&aos.snapshot(), &soa.snapshot()) < 1e-6);
    }

    #[test]
    fn energy_drift_is_small() {
        let init = init_particles(N, 7);
        let e0 = total_energy(&init);
        let mut sim = AosSim::new(&init);
        for _ in 0..STEPS {
            sim.update_scalar();
            sim.move_scalar();
        }
        let e1 = total_energy(&sim.snapshot());
        assert!((e1 - e0).abs() / e0.abs() < 1e-3, "energy drift {e0} -> {e1}");
    }
}
