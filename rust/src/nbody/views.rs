//! LLAMA-view n-body — the layout-generic versions of Figure 3.
//!
//! One scalar routine and one SIMD routine (the Figure 2 code), written
//! once against the bulk-traversal engine
//! ([`crate::view::View::transform_simd`]) and instantiated for AoS, SoA
//! multi-blob, and AoSoA. Exchanging the memory layout touches *only* the
//! mapping type — the algorithm below never changes; the engine picks the
//! per-mapping access path (SoA: contiguous vector moves, AoSoA: in-block
//! lane vectors, AoS: scalar walk). Matching the manual versions' runtime
//! is the paper's zero-overhead claim (experiment E1).
//!
//! The kernels use the *typed* tag API (`load_t`/`store_t`/`get_t`,
//! `field`/`set_field`): scalar types are inferred from the tags and
//! checked at compile time. [`update_simd_idx`]/[`move_simd_idx`] keep
//! the identical kernels on the legacy `usize`-index path — the
//! `fig3_nbody` bench runs both so the typed path's zero cost stays
//! measured.

use super::{particle, pp_interaction, Particle, ParticleData, EPS2, TIMESTEP};
use crate::blob::{alloc_view, AlignedAlloc, AlignedStorage};
use crate::extents::Extents;
use crate::mapping::{MemoryAccess, SimdAccess};
use crate::nbody::manual::simd_interaction;
use crate::pool::WorkerPool;
use crate::simd::Simd;
use crate::view::{Chunk, RecordRefMut, View};

/// Fill a view from shared initial conditions (typed API: the rank-1
/// index shape is part of the signature).
pub fn fill_view<M, S>(view: &mut View<Particle, M, S>, init: &[ParticleData])
where
    M: MemoryAccess<Particle>,
    M::Extents: Extents<ArrayIndex = [usize; 1]>,
    S: crate::blob::BlobStorage,
{
    for (i, p) in init.iter().enumerate() {
        view.set_t([i], particle::pos::x, p.pos.x);
        view.set_t([i], particle::pos::y, p.pos.y);
        view.set_t([i], particle::pos::z, p.pos.z);
        view.set_t([i], particle::vel::x, p.vel.x);
        view.set_t([i], particle::vel::y, p.vel.y);
        view.set_t([i], particle::vel::z, p.vel.z);
        view.set_t([i], particle::mass, p.mass);
    }
}

/// Read a view back into plain particle data (validation).
pub fn snapshot_view<M, S>(view: &View<Particle, M, S>) -> Vec<ParticleData>
where
    M: MemoryAccess<Particle>,
    M::Extents: Extents<ArrayIndex = [usize; 1]>,
    S: crate::blob::BlobStorage,
{
    (0..view.count())
        .map(|i| ParticleData {
            pos: super::PVec {
                x: view.get_t([i], particle::pos::x),
                y: view.get_t([i], particle::pos::y),
                z: view.get_t([i], particle::pos::z),
            },
            vel: super::PVec {
                x: view.get_t([i], particle::vel::x),
                y: view.get_t([i], particle::vel::y),
                z: view.get_t([i], particle::vel::z),
            },
            mass: view.get_t([i], particle::mass),
        })
        .collect()
}

/// One chunk of the scalar update (Table 1's `N == 1` case) — the shared
/// kernel of [`update_scalar`] and [`update_scalar_par`]. Reads `pos` and
/// `mass` of every particle, stores only the chunk's own `vel`.
#[inline(always)]
fn update_scalar_chunk<M, S>(c: &mut Chunk<'_, Particle, M, S, 1>)
where
    M: SimdAccess<Particle>,
    S: crate::blob::BlobStorage,
{
    let i = c.base();
    let pix = c.get_t(i, particle::pos::x);
    let piy = c.get_t(i, particle::pos::y);
    let piz = c.get_t(i, particle::pos::z);
    let mut acc = (0.0f32, 0.0f32, 0.0f32);
    for j in 0..c.count() {
        pp_interaction(
            pix,
            piy,
            piz,
            c.get_t(j, particle::pos::x),
            c.get_t(j, particle::pos::y),
            c.get_t(j, particle::pos::z),
            c.get_t(j, particle::mass),
            &mut acc,
        );
    }
    let vx = c.get_t(i, particle::vel::x);
    let vy = c.get_t(i, particle::vel::y);
    let vz = c.get_t(i, particle::vel::z);
    c.set_t(i, particle::vel::x, vx + acc.0);
    c.set_t(i, particle::vel::y, vy + acc.1);
    c.set_t(i, particle::vel::z, vz + acc.2);
}

/// Layout-generic scalar update (the original LLAMA paper's routine),
/// expressed as a 1-lane bulk traversal — Table 1's `N == 1` case. The
/// operation order is exactly the manual scalar loop's, so results stay
/// bit-identical to `manual::AosSim::update_scalar`.
pub fn update_scalar<M, S>(view: &mut View<Particle, M, S>)
where
    M: SimdAccess<Particle>,
    S: crate::blob::BlobStorage,
{
    view.transform_simd::<1>(|c| update_scalar_chunk(c));
}

/// [`update_scalar`] sharded over `threads` workers. Each particle's new
/// velocity depends only on the pre-pass state (the pass stores `vel`,
/// the cross-shard j-loop reads only `pos`/`mass`), so results are
/// bit-identical to the serial engine at any thread count.
pub fn update_scalar_par<M, S>(view: &mut View<Particle, M, S>, threads: usize)
where
    M: SimdAccess<Particle>,
    S: crate::blob::BlobStorage + Send + Sync,
{
    // SAFETY: the kernel stores only its own record's `vel`; its
    // cross-shard reads touch only `pos` and `mass`, which no shard
    // stores during this pass.
    unsafe { view.par_transform_simd_with::<1, _>(threads, |c| update_scalar_chunk(c)) }
}

/// [`update_scalar_par`] dispatched on an explicit [`WorkerPool`] (the
/// coordinator runs native jobs here with a leased thread budget).
pub fn update_scalar_par_on<M, S>(
    view: &mut View<Particle, M, S>,
    pool: &WorkerPool,
    threads: usize,
) where
    M: SimdAccess<Particle>,
    S: crate::blob::BlobStorage + Send + Sync,
{
    // SAFETY: as in `update_scalar_par`.
    unsafe { view.par_transform_simd_on::<1, _>(pool, threads, |c| update_scalar_chunk(c)) }
}

/// One record of the scalar move — the shared kernel of [`move_scalar`]
/// and [`move_scalar_par`]. Touches only the record's own fields.
#[inline(always)]
fn move_record<M, S>(r: &mut RecordRefMut<'_, Particle, M, S>)
where
    M: MemoryAccess<Particle>,
    S: crate::blob::BlobStorage,
{
    let px = r.field(particle::pos::x);
    let py = r.field(particle::pos::y);
    let pz = r.field(particle::pos::z);
    let vx = r.field(particle::vel::x);
    let vy = r.field(particle::vel::y);
    let vz = r.field(particle::vel::z);
    r.set_field(particle::pos::x, px + vx * TIMESTEP);
    r.set_field(particle::pos::y, py + vy * TIMESTEP);
    r.set_field(particle::pos::z, pz + vz * TIMESTEP);
}

/// Layout-generic scalar move: a plain record-wise bulk traversal
/// ([`View::for_each`]).
pub fn move_scalar<M, S>(view: &mut View<Particle, M, S>)
where
    M: MemoryAccess<Particle>,
    S: crate::blob::BlobStorage,
{
    view.for_each(|r| move_record(r));
}

/// [`move_scalar`] sharded over `threads` workers (each record only
/// touches itself: trivially race-free and bit-identical).
pub fn move_scalar_par<M, S>(view: &mut View<Particle, M, S>, threads: usize)
where
    M: MemoryAccess<Particle>,
    S: crate::blob::BlobStorage + Send + Sync,
{
    view.par_for_each_with(threads, |r| move_record(r));
}

/// [`move_scalar_par`] dispatched on an explicit [`WorkerPool`].
pub fn move_scalar_par_on<M, S>(view: &mut View<Particle, M, S>, pool: &WorkerPool, threads: usize)
where
    M: MemoryAccess<Particle>,
    S: crate::blob::BlobStorage + Send + Sync,
{
    view.par_for_each_on(pool, threads, |r| move_record(r));
}

/// One chunk of the SIMD update — the shared kernel of [`update_simd`]
/// and [`update_simd_par`].
#[inline(always)]
fn update_chunk<const N: usize, M, S>(c: &mut Chunk<'_, Particle, M, S, N>)
where
    M: SimdAccess<Particle>,
    S: crate::blob::BlobStorage,
{
    // llama::loadSimd(particleView(i), simdParticles)
    let pix: Simd<f32, N> = c.load_t(particle::pos::x);
    let piy: Simd<f32, N> = c.load_t(particle::pos::y);
    let piz: Simd<f32, N> = c.load_t(particle::pos::z);
    let mut ax = Simd::<f32, N>::default();
    let mut ay = Simd::<f32, N>::default();
    let mut az = Simd::<f32, N>::default();
    for j in 0..c.count() {
        simd_interaction(
            pix,
            piy,
            piz,
            Simd::splat(c.get_t(j, particle::pos::x)),
            Simd::splat(c.get_t(j, particle::pos::y)),
            Simd::splat(c.get_t(j, particle::pos::z)),
            Simd::splat(c.get_t(j, particle::mass)),
            &mut ax,
            &mut ay,
            &mut az,
        );
    }
    // llama::storeSimd(simdParticles(tag::Vel{}), particleView(i)(tag::Vel{}))
    let vx: Simd<f32, N> = c.load_t(particle::vel::x);
    let vy: Simd<f32, N> = c.load_t(particle::vel::y);
    let vz: Simd<f32, N> = c.load_t(particle::vel::z);
    c.store_t(particle::vel::x, vx + ax);
    c.store_t(particle::vel::y, vy + ay);
    c.store_t(particle::vel::z, vz + az);
}

/// Layout-generic SIMD update — the Figure 2 routine through the bulk
/// engine: each chunk loads `N` particles as SIMD records (`loadSimd`
/// via the mapping's fastest path), interacts with all `n` scalar
/// particles, and stores the velocity sub-record back.
pub fn update_simd<const N: usize, M, S>(view: &mut View<Particle, M, S>)
where
    M: SimdAccess<Particle>,
    S: crate::blob::BlobStorage,
{
    view.transform_simd::<N>(|c| update_chunk(c));
}

/// [`update_simd`] sharded over `threads` workers: SIMD lanes along the
/// particle axis, threads across shards of it — the layout × parallelism
/// matrix from one kernel. Bit-identical to the serial engine (stores
/// touch only the chunk's `vel`; cross-shard reads touch only `pos` and
/// `mass`, which the pass never writes).
pub fn update_simd_par<const N: usize, M, S>(view: &mut View<Particle, M, S>, threads: usize)
where
    M: SimdAccess<Particle>,
    S: crate::blob::BlobStorage + Send + Sync,
{
    // SAFETY: the kernel stores only its own chunk's `vel` lanes; its
    // cross-shard reads touch only `pos` and `mass`, which no shard
    // stores during this pass.
    unsafe { view.par_transform_simd_with::<N, _>(threads, |c| update_chunk(c)) }
}

/// [`update_simd_par`] dispatched on an explicit [`WorkerPool`] (the
/// coordinator runs native jobs here with a leased thread budget).
pub fn update_simd_par_on<const N: usize, M, S>(
    view: &mut View<Particle, M, S>,
    pool: &WorkerPool,
    threads: usize,
) where
    M: SimdAccess<Particle>,
    S: crate::blob::BlobStorage + Send + Sync,
{
    // SAFETY: as in `update_simd_par`.
    unsafe { view.par_transform_simd_on::<N, _>(pool, threads, |c| update_chunk(c)) }
}

/// [`update_simd_par`] forced onto the per-call scoped-spawn dispatch —
/// the pooled-vs-scoped comparison row of the `fig3_nbody` bench.
pub fn update_simd_par_scoped<const N: usize, M, S>(view: &mut View<Particle, M, S>, threads: usize)
where
    M: SimdAccess<Particle>,
    S: crate::blob::BlobStorage + Send + Sync,
{
    // SAFETY: as in `update_simd_par`.
    unsafe { view.par_transform_simd_scoped_with::<N, _>(threads, |c| update_chunk(c)) }
}

/// One chunk of the SIMD move — the shared kernel of [`move_simd`] and
/// [`move_simd_par`].
#[inline(always)]
fn move_chunk<const N: usize, M, S>(c: &mut Chunk<'_, Particle, M, S, N>)
where
    M: SimdAccess<Particle>,
    S: crate::blob::BlobStorage,
{
    let dt = Simd::<f32, N>::splat(TIMESTEP);
    let px: Simd<f32, N> = c.load_t(particle::pos::x);
    let py: Simd<f32, N> = c.load_t(particle::pos::y);
    let pz: Simd<f32, N> = c.load_t(particle::pos::z);
    let vx: Simd<f32, N> = c.load_t(particle::vel::x);
    let vy: Simd<f32, N> = c.load_t(particle::vel::y);
    let vz: Simd<f32, N> = c.load_t(particle::vel::z);
    c.store_t(particle::pos::x, px + vx * dt);
    c.store_t(particle::pos::y, py + vy * dt);
    c.store_t(particle::pos::z, pz + vz * dt);
}

/// Layout-generic SIMD move through the bulk engine.
pub fn move_simd<const N: usize, M, S>(view: &mut View<Particle, M, S>)
where
    M: SimdAccess<Particle>,
    S: crate::blob::BlobStorage,
{
    view.transform_simd::<N>(|c| move_chunk(c));
}

/// [`move_simd`] sharded over `threads` workers (chunks only touch their
/// own records: trivially race-free and bit-identical).
pub fn move_simd_par<const N: usize, M, S>(view: &mut View<Particle, M, S>, threads: usize)
where
    M: SimdAccess<Particle>,
    S: crate::blob::BlobStorage + Send + Sync,
{
    // SAFETY: the kernel loads and stores only its own chunk's records.
    unsafe { view.par_transform_simd_with::<N, _>(threads, |c| move_chunk(c)) }
}

/// [`move_simd_par`] dispatched on an explicit [`WorkerPool`].
pub fn move_simd_par_on<const N: usize, M, S>(
    view: &mut View<Particle, M, S>,
    pool: &WorkerPool,
    threads: usize,
) where
    M: SimdAccess<Particle>,
    S: crate::blob::BlobStorage + Send + Sync,
{
    // SAFETY: the kernel loads and stores only its own chunk's records.
    unsafe { view.par_transform_simd_on::<N, _>(pool, threads, |c| move_chunk(c)) }
}

/// [`move_simd_par`] forced onto the per-call scoped-spawn dispatch —
/// the pooled-vs-scoped comparison row of the `fig3_nbody` bench.
pub fn move_simd_par_scoped<const N: usize, M, S>(view: &mut View<Particle, M, S>, threads: usize)
where
    M: SimdAccess<Particle>,
    S: crate::blob::BlobStorage + Send + Sync,
{
    // SAFETY: the kernel loads and stores only its own chunk's records.
    unsafe { view.par_transform_simd_scoped_with::<N, _>(threads, |c| move_chunk(c)) }
}

/// [`update_simd`] on the *legacy* `usize`-index access path: the same
/// kernel with every tag converted to its flattened index up front
/// (`tag.i()`), exercising `Chunk::load`/`store`/`get` instead of the
/// typed `*_t` entry points. Identical operations in identical order —
/// results are bit-identical to [`update_simd`], and the `fig3_nbody`
/// bench row pair (typed vs `legacy-idx`) demonstrates the typed path is
/// zero-cost.
pub fn update_simd_idx<const N: usize, M, S>(view: &mut View<Particle, M, S>)
where
    M: SimdAccess<Particle>,
    S: crate::blob::BlobStorage,
{
    const PX: usize = particle::pos::x.i();
    const PY: usize = particle::pos::y.i();
    const PZ: usize = particle::pos::z.i();
    const VX: usize = particle::vel::x.i();
    const VY: usize = particle::vel::y.i();
    const VZ: usize = particle::vel::z.i();
    const MASS: usize = particle::mass.i();
    view.transform_simd::<N>(|c| {
        let pix: Simd<f32, N> = c.load(PX);
        let piy: Simd<f32, N> = c.load(PY);
        let piz: Simd<f32, N> = c.load(PZ);
        let mut ax = Simd::<f32, N>::default();
        let mut ay = Simd::<f32, N>::default();
        let mut az = Simd::<f32, N>::default();
        for j in 0..c.count() {
            simd_interaction(
                pix,
                piy,
                piz,
                Simd::splat(c.get(j, PX)),
                Simd::splat(c.get(j, PY)),
                Simd::splat(c.get(j, PZ)),
                Simd::splat(c.get(j, MASS)),
                &mut ax,
                &mut ay,
                &mut az,
            );
        }
        let vx: Simd<f32, N> = c.load(VX);
        let vy: Simd<f32, N> = c.load(VY);
        let vz: Simd<f32, N> = c.load(VZ);
        c.store(VX, vx + ax);
        c.store(VY, vy + ay);
        c.store(VZ, vz + az);
    });
}

/// [`move_simd`] on the legacy `usize`-index access path (see
/// [`update_simd_idx`]).
pub fn move_simd_idx<const N: usize, M, S>(view: &mut View<Particle, M, S>)
where
    M: SimdAccess<Particle>,
    S: crate::blob::BlobStorage,
{
    const PX: usize = particle::pos::x.i();
    const PY: usize = particle::pos::y.i();
    const PZ: usize = particle::pos::z.i();
    const VX: usize = particle::vel::x.i();
    const VY: usize = particle::vel::y.i();
    const VZ: usize = particle::vel::z.i();
    view.transform_simd::<N>(|c| {
        let dt = Simd::<f32, N>::splat(TIMESTEP);
        let px: Simd<f32, N> = c.load(PX);
        let py: Simd<f32, N> = c.load(PY);
        let pz: Simd<f32, N> = c.load(PZ);
        let vx: Simd<f32, N> = c.load(VX);
        let vy: Simd<f32, N> = c.load(VY);
        let vz: Simd<f32, N> = c.load(VZ);
        c.store(PX, px + vx * dt);
        c.store(PY, py + vy * dt);
        c.store(PZ, pz + vz * dt);
    });
}

/// The rank-1 u32-indexed extents used by all Figure-3 views
/// (§2: 32-bit index arithmetic).
pub type Ext1 = (crate::extents::Dyn<u32>,);

/// AoS mapping for the figure.
pub type AosMap = crate::mapping::aos::AoS<Particle, Ext1>;
/// SoA multi-blob mapping for the figure.
pub type SoaMbMap = crate::mapping::soa::SoA<Particle, Ext1, crate::mapping::soa::MultiBlob>;
/// AoSoA (8 lanes = AVX2 f32 width) mapping for the figure.
pub type AosoaMap = crate::mapping::aosoa::AoSoA<Particle, Ext1, 8>;

/// Allocate + fill an AoS view (cache-line aligned, like the manual Vec).
pub fn make_aos_view(init: &[ParticleData]) -> View<Particle, AosMap, AlignedStorage> {
    let mut v =
        alloc_view(AosMap::new((crate::extents::Dyn(init.len() as u32),)), &AlignedAlloc::<64>);
    fill_view(&mut v, init);
    v
}

/// Allocate + fill a SoA multi-blob view.
pub fn make_soa_view(init: &[ParticleData]) -> View<Particle, SoaMbMap, AlignedStorage> {
    let mut v =
        alloc_view(SoaMbMap::new((crate::extents::Dyn(init.len() as u32),)), &AlignedAlloc::<64>);
    fill_view(&mut v, init);
    v
}

/// Allocate + fill an AoSoA-8 view.
pub fn make_aosoa_view(init: &[ParticleData]) -> View<Particle, AosoaMap, AlignedStorage> {
    let mut v =
        alloc_view(AosoaMap::new((crate::extents::Dyn(init.len() as u32),)), &AlignedAlloc::<64>);
    fill_view(&mut v, init);
    v
}

// Re-export EPS2 for the kernel-side oracle tests.
pub use super::EPS2 as SOFTENING;
const _: () = assert!(EPS2 > 0.0);

#[cfg(test)]
mod tests {
    use super::super::{init_particles, max_pos_delta};
    use super::*;
    use crate::nbody::manual::AosSim;

    const N: usize = 64;
    const STEPS: usize = 4;

    fn reference() -> Vec<ParticleData> {
        let mut sim = AosSim::new(&init_particles(N, 7));
        for _ in 0..STEPS {
            sim.update_scalar();
            sim.move_scalar();
        }
        sim.snapshot()
    }

    #[test]
    fn llama_scalar_matches_manual_exactly_all_layouts() {
        let init = init_particles(N, 7);
        let r = reference();

        let mut aos = make_aos_view(&init);
        let mut soa = make_soa_view(&init);
        let mut aosoa = make_aosoa_view(&init);
        for _ in 0..STEPS {
            update_scalar(&mut aos);
            move_scalar(&mut aos);
            update_scalar(&mut soa);
            move_scalar(&mut soa);
            update_scalar(&mut aosoa);
            move_scalar(&mut aosoa);
        }
        // Same summation order as the manual scalar loop => bit-identical.
        assert_eq!(max_pos_delta(&r, &snapshot_view(&aos)), 0.0);
        assert_eq!(max_pos_delta(&r, &snapshot_view(&soa)), 0.0);
        assert_eq!(max_pos_delta(&r, &snapshot_view(&aosoa)), 0.0);
    }

    #[test]
    fn llama_simd_matches_manual_simd() {
        let init = init_particles(N, 7);
        let mut manual = crate::nbody::manual::SoaSim::new(&init);
        let mut view = make_soa_view(&init);
        for _ in 0..STEPS {
            manual.update_simd::<8>();
            manual.move_simd::<8>();
            update_simd::<8, _, _>(&mut view);
            move_simd::<8, _, _>(&mut view);
        }
        // Identical operations order => bit-identical results.
        assert_eq!(max_pos_delta(&manual.snapshot(), &snapshot_view(&view)), 0.0);
    }

    #[test]
    fn llama_simd_all_layouts_agree() {
        let init = init_particles(N, 7);
        let mut aos = make_aos_view(&init);
        let mut soa = make_soa_view(&init);
        let mut aosoa = make_aosoa_view(&init);
        for _ in 0..STEPS {
            update_simd::<8, _, _>(&mut aos);
            move_simd::<8, _, _>(&mut aos);
            update_simd::<8, _, _>(&mut soa);
            move_simd::<8, _, _>(&mut soa);
            update_simd::<8, _, _>(&mut aosoa);
            move_simd::<8, _, _>(&mut aosoa);
        }
        let s = snapshot_view(&soa);
        assert_eq!(max_pos_delta(&snapshot_view(&aos), &s), 0.0);
        assert_eq!(max_pos_delta(&snapshot_view(&aosoa), &s), 0.0);
    }

    #[test]
    fn legacy_index_kernels_bit_identical_to_typed() {
        // The typed-tag path and the usize-index path are the same kernel;
        // results must agree bit for bit on every layout.
        let init = init_particles(N, 7);
        macro_rules! check_layout {
            ($make:ident) => {{
                let mut typed = $make(&init);
                let mut legacy = $make(&init);
                for _ in 0..STEPS {
                    update_simd::<8, _, _>(&mut typed);
                    move_simd::<8, _, _>(&mut typed);
                    update_simd_idx::<8, _, _>(&mut legacy);
                    move_simd_idx::<8, _, _>(&mut legacy);
                }
                assert_eq!(
                    max_pos_delta(&snapshot_view(&typed), &snapshot_view(&legacy)),
                    0.0
                );
            }};
        }
        check_layout!(make_aos_view);
        check_layout!(make_soa_view);
        check_layout!(make_aosoa_view);
    }

    #[test]
    fn simd_vs_scalar_tolerance() {
        let init = init_particles(N, 7);
        let r = reference();
        let mut soa = make_soa_view(&init);
        for _ in 0..STEPS {
            update_simd::<8, _, _>(&mut soa);
            move_simd::<8, _, _>(&mut soa);
        }
        assert!(max_pos_delta(&r, &snapshot_view(&soa)) < 1e-4);
    }

    #[test]
    fn parallel_simd_bit_identical_to_serial_all_layouts() {
        // n deliberately not divisible by the lane count or any thread
        // count: exercises the SIMD tail and ragged shard boundaries.
        let n = 101;
        let init = init_particles(n, 13);

        macro_rules! check_layout {
            ($make:ident) => {{
                let mut serial = $make(&init);
                for _ in 0..STEPS {
                    update_simd::<8, _, _>(&mut serial);
                    move_simd::<8, _, _>(&mut serial);
                }
                let reference = snapshot_view(&serial);
                for threads in [1usize, 2, 3, 4] {
                    let mut par = $make(&init);
                    for _ in 0..STEPS {
                        update_simd_par::<8, _, _>(&mut par, threads);
                        move_simd_par::<8, _, _>(&mut par, threads);
                    }
                    assert_eq!(
                        max_pos_delta(&reference, &snapshot_view(&par)),
                        0.0,
                        "{} threads",
                        threads
                    );
                }
            }};
        }
        check_layout!(make_aos_view);
        check_layout!(make_soa_view);
        check_layout!(make_aosoa_view);
    }

    #[test]
    fn pool_dispatched_kernels_bit_identical_to_serial() {
        // The `_on` (explicit pool, as the coordinator uses) and
        // `_scoped` (pre-pool spawn, as the bench baseline uses)
        // dispatch targets are plumbing only: bit-identical results.
        let n = 101;
        let init = init_particles(n, 13);
        let pool = crate::pool::WorkerPool::with_pinning(3, false);
        let mut serial = make_soa_view(&init);
        let mut pooled = make_soa_view(&init);
        let mut scoped = make_soa_view(&init);
        let mut scalar_serial = make_soa_view(&init);
        let mut scalar_pooled = make_soa_view(&init);
        for _ in 0..STEPS {
            update_simd::<8, _, _>(&mut serial);
            move_simd::<8, _, _>(&mut serial);
            update_simd_par_on::<8, _, _>(&mut pooled, &pool, 3);
            move_simd_par_on::<8, _, _>(&mut pooled, &pool, 3);
            update_simd_par_scoped::<8, _, _>(&mut scoped, 3);
            move_simd_par_scoped::<8, _, _>(&mut scoped, 3);
            update_scalar(&mut scalar_serial);
            move_scalar(&mut scalar_serial);
            update_scalar_par_on(&mut scalar_pooled, &pool, 3);
            move_scalar_par_on(&mut scalar_pooled, &pool, 3);
        }
        let r = snapshot_view(&serial);
        assert_eq!(max_pos_delta(&r, &snapshot_view(&pooled)), 0.0);
        assert_eq!(max_pos_delta(&r, &snapshot_view(&scoped)), 0.0);
        let rs = snapshot_view(&scalar_serial);
        assert_eq!(max_pos_delta(&rs, &snapshot_view(&scalar_pooled)), 0.0);
    }

    #[test]
    fn parallel_scalar_bit_identical_to_serial() {
        let n = 67;
        let init = init_particles(n, 5);
        let mut serial = make_soa_view(&init);
        for _ in 0..STEPS {
            update_scalar(&mut serial);
            move_scalar(&mut serial);
        }
        let reference = snapshot_view(&serial);
        for threads in [2usize, 4, 7] {
            let mut par = make_soa_view(&init);
            for _ in 0..STEPS {
                update_scalar_par(&mut par, threads);
                move_scalar_par(&mut par, threads);
            }
            assert_eq!(max_pos_delta(&reference, &snapshot_view(&par)), 0.0);
        }
    }
}
